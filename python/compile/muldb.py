"""Approximate-multiplier database (the EvoApprox8b substitute).

The paper searches over the 37 unsigned 8x8-bit multipliers of the
EvoApprox library.  That library's synthesized netlists / PDK45 power
numbers are not available offline, so we build a *synthetic family of 37
deterministic u8 x u8 -> u32 approximate multipliers* spanning the same
qualitative accuracy/power Pareto spread, using the classic approximation
techniques from the literature:

  - ``trunc``    operand LSB truncation
  - ``bam``      broken-array multiplier (partial-product bits with
                 ``i + j < h`` omitted)
  - ``bamc``     BAM with constant error compensation (adds the expected
                 value of the dropped partial products under uniform inputs)
  - ``drum``     DRUM-style dynamic-range multiplier (k significant bits
                 from the leading one, LSB of the kept segment forced to 1
                 for unbiasing)
  - ``mitch``    Mitchell logarithmic multiplier (F fraction bits)
  - ``loa``      lower-part OR approximation of the low x low partial
                 product block
  - ``otrunc``   output LSB truncation
  - ``otruncc``  output truncation with half-LSB compensation

Every instance is a pure function of the two operand *codes* (it operates
on raw u8 codes exactly like a hardware multiplier would, before any
zero-point correction).  The full behaviour of each instance is captured
by a 256x256 i32 lookup table (LUT); the power model is a structural proxy
(fraction of the 64-bit partial-product array that is actually built, plus
small per-technique overheads), calibrated so the family spans relative
power ~0.05 .. 1.0 like EvoApprox's mul8u corner.

The Rust crate (``rust/src/muldb``) re-implements exactly the same
definitions; ``python/tests/test_muldb.py`` and the Rust golden test both
check the SHA-256 of the serialized LUT stack so the two sides can never
drift apart.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import struct
from typing import Callable, Dict, List

import numpy as np

N_OPERAND = 256
LUT_ENTRIES = N_OPERAND * N_OPERAND


# ---------------------------------------------------------------------------
# Multiplier behavioural definitions (scalar, integer-exact).
# ---------------------------------------------------------------------------


def mul_exact(a: int, b: int) -> int:
    return a * b


def mul_trunc_op(a: int, b: int, k: int) -> int:
    """Zero the k LSBs of both operands before an exact multiply."""
    mask = ~((1 << k) - 1) & 0xFF
    return (a & mask) * (b & mask)


def _bam_kept_terms(h: int) -> List[tuple]:
    return [(i, j) for i in range(8) for j in range(8) if i + j >= h]


def mul_bam(a: int, b: int, h: int) -> int:
    """Broken-array multiplier: omit partial-product bits with i + j < h."""
    acc = 0
    for i in range(8):
        if not (a >> i) & 1:
            continue
        for j in range(8):
            if (b >> j) & 1 and i + j >= h:
                acc += 1 << (i + j)
    return acc


def bam_compensation(h: int) -> int:
    """Expected value of the dropped PP bits for uniform random operands.

    Each partial-product bit a_i * b_j is 1 with probability 1/4.
    """
    total = sum((1 << (i + j)) for i in range(8) for j in range(8) if i + j < h)
    return (total + 2) // 4  # round(total / 4), ties away from zero not needed


def mul_bamc(a: int, b: int, h: int) -> int:
    return mul_bam(a, b, h) + bam_compensation(h)


def _drum_approx_operand(x: int, k: int) -> int:
    if x < (1 << k):
        return x
    msb = x.bit_length() - 1
    shift = msb - k + 1
    return ((x >> shift) | 1) << shift


def mul_drum(a: int, b: int, k: int) -> int:
    """DRUM-k: keep k bits from the leading one, force kept LSB to 1."""
    if a == 0 or b == 0:
        return 0
    return _drum_approx_operand(a, k) * _drum_approx_operand(b, k)


def mul_mitchell(a: int, b: int, frac_bits: int) -> int:
    """Mitchell's logarithmic multiplier with ``frac_bits`` fraction bits.

    log2(x) ~= msb(x) + (x - 2^msb)/2^msb ; the sum of the two logs is
    converted back with the same linear antilog approximation.
    """
    if a == 0 or b == 0:
        return 0
    f = frac_bits
    la = a.bit_length() - 1
    lb = b.bit_length() - 1
    fa = ((a - (1 << la)) << f) >> la  # fraction in Q0.f
    fb = ((b - (1 << lb)) << f) >> lb
    lsum = ((la + lb) << f) + fa + fb
    k = lsum >> f
    frac = lsum & ((1 << f) - 1)
    # antilog: (1 + frac) * 2^k, computed in integer arithmetic
    return (((1 << f) + frac) << k) >> f


def mul_loa(a: int, b: int, h: int) -> int:
    """Exact high/cross partial products; the low x low block is OR-ed.

    Splits both operands at bit ``h``; the (h x h)-bit low block
    ``al * bl`` is replaced by ``al | bl`` (a lower-part-OR style
    approximation: cheap, slightly biased low).
    """
    mask = (1 << h) - 1
    ah, al = a >> h, a & mask
    bh, bl = b >> h, b & mask
    return ((ah * bh) << (2 * h)) + (((ah * bl) + (bh * al)) << h) + (al | bl)


def mul_otrunc(a: int, b: int, k: int) -> int:
    """Exact product with the k LSBs of the result zeroed."""
    return (a * b) & (~((1 << k) - 1) & 0xFFFFFFFF)


def mul_otruncc(a: int, b: int, k: int) -> int:
    """Output truncation with half-LSB constant compensation."""
    return mul_otrunc(a, b, k) + (1 << (k - 1))


# ---------------------------------------------------------------------------
# Power model: structural proxy, relative to the exact 8x8 array (= 1.0).
# ---------------------------------------------------------------------------


def _bam_power(h: int) -> float:
    kept = len(_bam_kept_terms(h))
    return kept / 64.0


def power_model(technique: str, param: int) -> float:
    if technique == "exact":
        return 1.0
    if technique == "trunc":
        return ((8 - param) / 8.0) ** 2
    if technique == "bam":
        return _bam_power(param)
    if technique == "bamc":
        return _bam_power(param) + 0.01
    if technique == "drum":
        return (param * param) / 64.0 + 0.08
    if technique == "mitch":
        return 0.11 + param * 0.012
    if technique == "loa":
        return (64 - param * param) / 64.0 + 0.008
    if technique == "otrunc":
        return 1.0 - param * 0.06
    if technique == "otruncc":
        return 1.0 - param * 0.06 + 0.005
    raise ValueError(f"unknown technique {technique!r}")


@dataclasses.dataclass(frozen=True)
class MultiplierSpec:
    """One approximate-multiplier instance in the search space."""

    mid: int  # dense id, 0 = exact
    name: str
    technique: str
    param: int
    power: float  # relative to the accurate multiplier

    def fn(self) -> Callable[[int, int], int]:
        t, p = self.technique, self.param
        table: Dict[str, Callable[[int, int], int]] = {
            "exact": lambda a, b: mul_exact(a, b),
            "trunc": lambda a, b: mul_trunc_op(a, b, p),
            "bam": lambda a, b: mul_bam(a, b, p),
            "bamc": lambda a, b: mul_bamc(a, b, p),
            "drum": lambda a, b: mul_drum(a, b, p),
            "mitch": lambda a, b: mul_mitchell(a, b, p),
            "loa": lambda a, b: mul_loa(a, b, p),
            "otrunc": lambda a, b: mul_otrunc(a, b, p),
            "otruncc": lambda a, b: mul_otruncc(a, b, p),
        }
        return table[t]


def build_family() -> List[MultiplierSpec]:
    """The fixed 37-instance search space (order defines the dense ids)."""
    specs: List[tuple] = [("exact", 0)]
    specs += [("trunc", k) for k in (1, 2, 3, 4)]
    specs += [("bam", h) for h in range(3, 11)]
    specs += [("bamc", h) for h in range(3, 9)]
    specs += [("drum", k) for k in (3, 4, 5, 6)]
    specs += [("mitch", f) for f in (7, 5, 3)]
    specs += [("loa", h) for h in (3, 4, 5, 6)]
    specs += [("otrunc", k) for k in (2, 4, 6, 8)]
    specs += [("otruncc", k) for k in (4, 6, 8)]
    assert len(specs) == 37
    out = []
    for mid, (tech, param) in enumerate(specs):
        name = "am8u_exact" if tech == "exact" else f"am8u_{tech}{param}"
        out.append(
            MultiplierSpec(
                mid=mid,
                name=name,
                technique=tech,
                param=param,
                power=power_model(tech, param),
            )
        )
    return out


# ---------------------------------------------------------------------------
# LUT construction + vectorized error statistics.
# ---------------------------------------------------------------------------


def build_lut(spec: MultiplierSpec) -> np.ndarray:
    """256x256 i32 table: lut[a, b] = approx_mul(a, b)."""
    fn = spec.fn()
    lut = np.empty((N_OPERAND, N_OPERAND), dtype=np.int64)
    for a in range(N_OPERAND):
        for b in range(N_OPERAND):
            lut[a, b] = fn(a, b)
    assert lut.min() >= 0 and lut.max() < 2**31
    return lut.astype(np.int32)


_EXACT = None


def exact_lut() -> np.ndarray:
    global _EXACT
    if _EXACT is None:
        v = np.arange(N_OPERAND, dtype=np.int64)
        _EXACT = np.outer(v, v).astype(np.int32)
    return _EXACT


def error_map(lut: np.ndarray) -> np.ndarray:
    """err[a, b] = approx(a, b) - a * b as f64."""
    return (lut.astype(np.int64) - exact_lut().astype(np.int64)).astype(np.float64)


def error_stats(lut: np.ndarray) -> Dict[str, float]:
    """Classic AM error metrics over the uniform operand distribution."""
    err = error_map(lut)
    exact = exact_lut().astype(np.float64)
    mean = float(err.mean())
    std = float(err.std())
    med = float(np.abs(err).mean())  # mean error distance
    with np.errstate(divide="ignore", invalid="ignore"):
        red = np.where(exact > 0, np.abs(err) / exact, 0.0)
    mred = float(red[exact > 0].mean())
    wce = float(np.abs(err).max())
    return {"mean": mean, "std": std, "med": med, "mred": mred, "wce": wce}


def lowrank_error(lut: np.ndarray, rank: int = 16) -> tuple:
    """Rank-``rank`` factorization  err ~= U @ V.T  (U, V: 256 x rank f32).

    Used by the L2 training graph: a LUT product inside a matmul is
    equivalent to  exact_matmul + sum_r  (U_r o A) @ (V_r o W)  which keeps
    retraining a pure-matmul computation.  BAM-style errors are *exactly*
    low-rank (sum of dropped rank-1 bit outer-products), the smooth
    techniques are numerically low-rank.
    """
    err = error_map(lut)
    u, s, vt = np.linalg.svd(err, full_matrices=False)
    r = min(rank, len(s))
    U = (u[:, :r] * np.sqrt(s[:r])).astype(np.float32)
    V = (vt[:r, :].T * np.sqrt(s[:r])).astype(np.float32)
    return U, V


# ---------------------------------------------------------------------------
# Serialization (artifacts/muldb.json + artifacts/luts.bin + lowrank.bin).
# ---------------------------------------------------------------------------


def lut_stack(family: List[MultiplierSpec] | None = None) -> np.ndarray:
    family = family or build_family()
    return np.stack([build_lut(s) for s in family], axis=0)


def serialize_luts(stack: np.ndarray) -> bytes:
    """m x 256 x 256 i32, little-endian, C order, with a tiny header."""
    header = struct.pack("<4sII", b"QLUT", stack.shape[0], LUT_ENTRIES)
    return header + stack.astype("<i4").tobytes(order="C")


def family_digest(stack: np.ndarray) -> str:
    return hashlib.sha256(serialize_luts(stack)).hexdigest()


def write_artifacts(outdir: str, rank: int = 16) -> dict:
    import os

    os.makedirs(outdir, exist_ok=True)
    family = build_family()
    stack = lut_stack(family)
    blob = serialize_luts(stack)
    with open(os.path.join(outdir, "luts.bin"), "wb") as f:
        f.write(blob)

    lr_u = np.zeros((len(family), N_OPERAND, rank), dtype=np.float32)
    lr_v = np.zeros((len(family), N_OPERAND, rank), dtype=np.float32)
    for i, _ in enumerate(family):
        U, V = lowrank_error(stack[i], rank)
        lr_u[i, :, : U.shape[1]] = U
        lr_v[i, :, : V.shape[1]] = V
    with open(os.path.join(outdir, "lowrank.bin"), "wb") as f:
        f.write(struct.pack("<4sIII", b"QLRK", len(family), N_OPERAND, rank))
        f.write(lr_u.astype("<f4").tobytes(order="C"))
        f.write(lr_v.astype("<f4").tobytes(order="C"))

    meta = {
        "format": 1,
        "count": len(family),
        "rank": rank,
        "digest_sha256": family_digest(stack),
        "multipliers": [
            {
                "id": s.mid,
                "name": s.name,
                "technique": s.technique,
                "param": s.param,
                "power": s.power,
                **error_stats(stack[s.mid]),
            }
            for s in family
        ],
    }
    with open(os.path.join(outdir, "muldb.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


if __name__ == "__main__":
    import sys

    meta = write_artifacts(sys.argv[1] if len(sys.argv) > 1 else "../artifacts")
    print(f"wrote {meta['count']} multipliers, digest {meta['digest_sha256'][:16]}")
