"""Layer statistics export for the error model (paper Fig. 1, [16]).

For every approximable layer we sample, from training data under the QAT
forward:

  * the histogram of the layer's quantized *input codes* (256 bins),
  * the histogram of its quantized *weight codes* (256 bins),
  * the fan-in K (MACs per output element) and total MAC count,
  * the quantization scales / zero points,
  * the post-BN scale factor RMS( gamma_c / sqrt(var_c + eps) ) that maps
    accumulator-domain error std into the (post-BN) domain where the AGN
    sigma_g lives,
  * the RMS of the post-BN pre-activation output (sanity/normalization).

The Rust error model (rust/src/errmodel) combines these with each
multiplier's LUT error map into the sigma_e matrix:

  sigma_e[j, k] = sqrt( K_k * Var_{a~pa_k, w~pw_k}[ err_j(a, w) ] )
                  * s_a,k * s_w,k * bn_scale_k
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .executor import BN_EPS, RunConfig, forward
from .graph import Graph
from .quant import quantize_codes


def collect_layer_stats(
    graph: Graph,
    params: dict,
    quant_meta: dict,
    images: np.ndarray,
    batches: int = 4,
    batch: int = 64,
) -> Dict[str, dict]:
    run = RunConfig(mode="qat", quant=quant_meta, bn_train=False, collect_acts=True)
    fwd = jax.jit(lambda p, x: forward(graph, p, x, run)[1]["acts"])

    hist_in = {n.name: np.zeros(256, np.float64) for n in graph.approx_layers()}
    out_sq = {n.name: 0.0 for n in graph.approx_layers()}
    out_n = {n.name: 0 for n in graph.approx_layers()}

    for b in range(batches):
        acts = fwd(params, jnp.asarray(images[b * batch : (b + 1) * batch]))
        for name, d in acts.items():
            qp = quant_meta[name]["in"]
            codes = np.asarray(quantize_codes(jnp.asarray(d["x"]), qp)).astype(np.int64).ravel()
            hist_in[name] += np.bincount(codes, minlength=256)
            y = np.asarray(d["y"])
            out_sq[name] += float((y.astype(np.float64) ** 2).sum())
            out_n[name] += y.size

    stats = {}
    for node in graph.approx_layers():
        name = node.name
        p = params[name]
        qp_in = quant_meta[name]["in"]
        qp_w = quant_meta[name]["w"]
        w_codes = np.asarray(quantize_codes(jnp.asarray(p["w"]), qp_w)).astype(np.int64).ravel()
        w_hist = np.bincount(w_codes, minlength=256).astype(np.float64)
        if node.has_bn:
            g = np.asarray(p["gamma"], np.float64)
            v = np.asarray(p["var"], np.float64)
            bn_scale = float(np.sqrt(np.mean((g / np.sqrt(v + BN_EPS)) ** 2)))
        else:
            bn_scale = 1.0
        pa = hist_in[name] / max(hist_in[name].sum(), 1.0)
        pw = w_hist / max(w_hist.sum(), 1.0)
        stats[name] = {
            "act_hist": pa.tolist(),
            "w_hist": pw.tolist(),
            "k_fanin": node.macs_per_out,
            "macs_total": node.macs_total,
            "s_act": qp_in.scale,
            "z_act": qp_in.zero_point,
            "s_w": qp_w.scale,
            "z_w": qp_w.zero_point,
            "bn_scale": bn_scale,
            "out_rms": float(np.sqrt(out_sq[name] / max(out_n[name], 1))),
        }
    return stats


BIAS_RESIDUAL = 0.1  # must match rust/src/errmodel BIAS_RESIDUAL


def sigma_e_reference(stats: Dict[str, dict], err_map: np.ndarray, bias_residual: float = BIAS_RESIDUAL) -> Dict[str, float]:
    """Python reference of the Rust error model (used in cross-checks).

    ``err_map``: (256, 256) f64 error of one multiplier.  Returns the
    post-BN-domain error std estimate per layer:
        sqrt(K var + (bias_residual K |mean|)^2) * s_a * s_w * bn_scale
    (bias_residual = 0 recovers the paper's variance-only model).
    """
    out = {}
    for name, s in stats.items():
        pa = np.asarray(s["act_hist"])
        pw = np.asarray(s["w_hist"])
        mean = pa @ err_map @ pw
        second = pa @ (err_map**2) @ pw
        var = max(second - mean * mean, 0.0)
        k = s["k_fanin"]
        bias = bias_residual * k * abs(mean)
        std_acc = np.sqrt(k * var + bias * bias)
        out[name] = float(std_acc * s["s_act"] * s["s_w"] * s["bn_scale"])
    return out
