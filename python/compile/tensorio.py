"""QTEN: a minimal named-tensor container (the offline npz substitute).

Layout:  b"QTEN" | u32 header_len | header JSON (utf-8) | raw data.
Header: {"tensors": [{"name", "dtype", "shape", "offset", "nbytes"}]}
dtypes: f32 | i32 | u8  (little-endian, C order).

The Rust reader lives in ``rust/src/util/tensorio.rs``; the format is
covered by a cross-language golden test.
"""

from __future__ import annotations

import json
import struct
from typing import Dict

import numpy as np

_DT = {"float32": "f32", "int32": "i32", "uint8": "u8"}
_DT_REV = {"f32": np.float32, "i32": np.int32, "u8": np.uint8}


def save(path: str, tensors: Dict[str, np.ndarray]) -> None:
    entries = []
    blobs = []
    offset = 0
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype == np.float64:
            arr = arr.astype(np.float32)
        if arr.dtype == np.int64:
            arr = arr.astype(np.int32)
        dt = _DT.get(arr.dtype.name)
        if dt is None:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        raw = arr.tobytes(order="C")
        entries.append(
            {"name": name, "dtype": dt, "shape": list(arr.shape), "offset": offset, "nbytes": len(raw)}
        )
        blobs.append(raw)
        offset += len(raw)
    header = json.dumps({"tensors": entries}).encode("utf-8")
    with open(path, "wb") as f:
        f.write(b"QTEN")
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def load(path: str) -> Dict[str, np.ndarray]:
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == b"QTEN", f"bad magic {magic!r} in {path}"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen).decode("utf-8"))
        base = f.tell()
        out = {}
        for e in header["tensors"]:
            f.seek(base + e["offset"])
            raw = f.read(e["nbytes"])
            arr = np.frombuffer(raw, dtype=_DT_REV[e["dtype"]]).reshape(e["shape"])
            out[e["name"]] = arr.copy()
    return out
