"""Training loops: float baseline, QAT, and approximate retraining.

SGD with momentum 0.9 throughout (the paper's optimizer).  BatchNorm uses
batch statistics during training with EMA running-stat updates; running
stats are frozen once QAT finishes so that per-operating-point fine-tuning
only moves (gamma, beta) — exactly the paper's low-overhead scheme.

``retrain_approx`` covers the paper's three Table-4 strategies:
  * ``mode="none"``   deploy without retraining
  * ``mode="full"``   retrain all parameters (one full set per OP)
  * ``mode="bn"``     freeze weights, tune only BN gamma/beta (+ biases)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quant as q
from .executor import RunConfig, forward
from .graph import Graph

BN_MOMENTUM = 0.9


def cross_entropy(logits, y):
    return -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], axis=1))


def _tree_sgd(params, grads, vel, lr: float, momentum: float, trainable) -> Tuple[dict, dict]:
    new_p, new_v = {}, {}
    for lname, group in params.items():
        new_p[lname], new_v[lname] = {}, {}
        for k, v in group.items():
            g = grads[lname][k] if lname in grads and k in grads[lname] else None
            if g is None or not trainable(lname, k):
                new_p[lname][k] = v
                new_v[lname][k] = vel[lname][k]
                continue
            nv = momentum * vel[lname][k] - lr * g
            new_p[lname][k] = v + nv
            new_v[lname][k] = nv
    return new_p, new_v


def _zeros_like_tree(params):
    return {ln: {k: jnp.zeros_like(v) for k, v in g.items()} for ln, g in params.items()}


def _update_bn_running(params, bn_stats):
    for lname, (mean, var) in bn_stats.items():
        p = params[lname]
        p["mean"] = BN_MOMENTUM * p["mean"] + (1 - BN_MOMENTUM) * mean
        p["var"] = BN_MOMENTUM * p["var"] + (1 - BN_MOMENTUM) * var
    return params


@dataclasses.dataclass
class TrainConfig:
    epochs: int = 10
    batch: int = 64
    lr: float = 0.1
    momentum: float = 0.9
    lr_decay_at: Tuple[float, ...] = (0.5, 0.75)  # fractions of total epochs
    lr_decay: float = 0.1
    augment: bool = True
    seed: int = 0


def _lr_at(cfg: TrainConfig, epoch: int) -> float:
    lr = cfg.lr
    for frac in cfg.lr_decay_at:
        threshold = max(1, int(frac * cfg.epochs))
        if epoch >= threshold:
            lr *= cfg.lr_decay
    return lr


def _epoch_batches(n: int, batch: int, seed: int):
    order = np.random.default_rng(seed).permutation(n)
    for s in range(n // batch):
        yield order[s * batch : (s + 1) * batch]


def train(
    graph: Graph,
    params: dict,
    images: np.ndarray,
    labels: np.ndarray,
    cfg: TrainConfig,
    mode: str = "float",
    quant_meta: Optional[dict] = None,
    uv: Optional[dict] = None,
    res_noise: Optional[dict] = None,
    trainable_fn=None,
    log=print,
    eval_every: int = 0,
    eval_data=None,
) -> dict:
    """Generic SGD loop over the executor; returns trained params."""
    from . import datasets as ds

    trainable_fn = trainable_fn or (lambda lname, k: k not in ("mean", "var"))
    bn_train = mode in ("float", "qat")

    def loss_fn(p, x, y, key):
        run = RunConfig(mode=mode, quant=quant_meta, uv=uv, res_noise=res_noise, bn_train=bn_train)
        logits, aux = forward(graph, p, x, run, rng=key)
        return cross_entropy(logits, y), aux

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    vel = _zeros_like_tree(params)
    n = images.shape[0]
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)

    for ep in range(cfg.epochs):
        lr = _lr_at(cfg, ep)
        ep_imgs = ds.augment(images, rng) if cfg.augment else images
        losses = []
        for idx in _epoch_batches(n, cfg.batch, cfg.seed * 1000 + ep):
            key, sub = jax.random.split(key)
            (loss, aux), grads = grad_fn(params, jnp.asarray(ep_imgs[idx]), jnp.asarray(labels[idx]), sub)
            params, vel = _tree_sgd(params, grads, vel, lr, cfg.momentum, trainable_fn)
            if bn_train and aux["bn"]:
                params = _update_bn_running(params, aux["bn"])
            losses.append(float(loss))
        msg = f"  [{mode}] epoch {ep + 1}/{cfg.epochs} lr={lr:.4f} loss={np.mean(losses):.4f}"
        if eval_every and (ep + 1) % eval_every == 0 and eval_data is not None:
            acc = evaluate(graph, params, eval_data[0], eval_data[1], mode, quant_meta, uv)
            msg += f" top1={acc['top1']:.3f}"
        log(msg)
    return params


def evaluate(
    graph: Graph,
    params: dict,
    images: np.ndarray,
    labels: np.ndarray,
    mode: str = "float",
    quant_meta: Optional[dict] = None,
    uv: Optional[dict] = None,
    batch: int = 128,
) -> Dict[str, float]:
    """Top-1/Top-5 accuracy."""
    run = RunConfig(mode=mode, quant=quant_meta, uv=uv, bn_train=False)
    fwd = jax.jit(lambda p, x: forward(graph, p, x, run)[0])
    n = images.shape[0]
    top1 = top5 = 0
    for s in range(0, n, batch):
        x = jnp.asarray(images[s : s + batch])
        y = labels[s : s + batch]
        logits = np.asarray(fwd(params, x))
        pred = np.argsort(-logits, axis=1)
        top1 += int((pred[:, 0] == y).sum())
        top5 += int((pred[:, :5] == y[:, None]).any(axis=1).sum())
    return {"top1": top1 / n, "top5": top5 / n, "n": n}


# ---------------------------------------------------------------------------
# Quantization calibration
# ---------------------------------------------------------------------------


def calibrate_quant(graph: Graph, params: dict, images: np.ndarray, batches: int = 4, batch: int = 64) -> dict:
    """Per-layer input/weight QParams from float-mode activation samples."""
    run = RunConfig(mode="float", bn_train=False, collect_acts=True)
    fwd = jax.jit(lambda p, x: forward(graph, p, x, run)[1]["acts"])
    samples: Dict[str, list] = {}
    for b in range(batches):
        acts = fwd(params, jnp.asarray(images[b * batch : (b + 1) * batch]))
        for name, d in acts.items():
            samples.setdefault(name, []).append(np.asarray(d["x"]).ravel())
    meta = {}
    for node in graph.approx_layers():
        xs = np.concatenate(samples[node.name])
        meta[node.name] = {
            "in": q.calibrate_activation(xs),
            "w": q.weight_qparams(np.asarray(params[node.name]["w"])),
        }
    return meta


def refresh_weight_qparams(graph: Graph, params: dict, quant_meta: dict) -> dict:
    for node in graph.approx_layers():
        quant_meta[node.name]["w"] = q.weight_qparams(np.asarray(params[node.name]["w"]))
    return quant_meta


# ---------------------------------------------------------------------------
# Approximate retraining (paper Sec. 3.3)
# ---------------------------------------------------------------------------


def uv_for_assignment(graph: Graph, assignment: Dict[str, int], lr_u: np.ndarray, lr_v: np.ndarray, rank: int) -> dict:
    """Per-layer (U, V) tables for an {layer name -> multiplier id} map."""
    uv = {}
    for node in graph.approx_layers():
        mid = assignment[node.name]
        if mid == 0:
            continue  # exact multiplier: no error term
        uv[node.name] = (
            jnp.asarray(lr_u[mid][:, :rank]),
            jnp.asarray(lr_v[mid][:, :rank]),
        )
    return uv


def residual_noise_for_assignment(
    graph: Graph,
    assignment: Dict[str, int],
    layer_stats: dict,
    lr_u: np.ndarray,
    lr_v: np.ndarray,
    rank: int,
) -> Dict[str, float]:
    """Pre-BN std of the rank-truncation residual per layer.

    For multipliers whose error map is not low-rank (output truncation),
    the surrogate U@V' drops a high-frequency residual; we match its
    second moment with additive Gaussian noise during retraining:
        std = sqrt(K * Var_{a~pa,w~pw}[residual]) * s_a * s_w.
    """
    from . import muldb as muldb_mod

    fam = muldb_mod.build_family()
    out: Dict[str, float] = {}
    for node in graph.approx_layers():
        mid = assignment[node.name]
        if mid == 0:
            continue
        st = layer_stats[node.name]
        err = muldb_mod.error_map(muldb_mod.build_lut(fam[mid]))
        res = err - lr_u[mid][:, :rank].astype(np.float64) @ lr_v[mid][:, :rank].astype(np.float64).T
        pa = np.asarray(st["act_hist"])
        pw = np.asarray(st["w_hist"])
        mean = pa @ res @ pw
        second = pa @ (res**2) @ pw
        var = max(second - mean * mean, 0.0)
        std = float(np.sqrt(st["k_fanin"] * var) * st["s_act"] * st["s_w"])
        if std > 0.0:
            out[node.name] = std
    return out


def retrain_approx(
    graph: Graph,
    params: dict,
    quant_meta: dict,
    uv: dict,
    images: np.ndarray,
    labels: np.ndarray,
    mode: str,
    cfg: TrainConfig,
    res_noise: Optional[dict] = None,
    log=print,
) -> dict:
    """Retrain under approximate forward.  mode in {none, full, bn}."""
    if mode == "none":
        return params
    if mode == "full":
        trainable = lambda lname, k: k not in ("mean", "var")
    elif mode == "bn":
        trainable = lambda lname, k: k in ("gamma", "beta", "b")
    else:
        raise ValueError(mode)
    return train(
        graph,
        params,
        images,
        labels,
        cfg,
        mode="approx",
        quant_meta=quant_meta,
        uv=uv,
        res_noise=res_noise,
        trainable_fn=trainable,
        log=log,
    )
