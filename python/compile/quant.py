"""8-bit affine quantization with straight-through-estimator fake-quant.

Conventions (shared bit-for-bit with the Rust engine, ``rust/src/nn``):

  * all quantized tensors are **u8 codes** ``q`` in ``[0, 255]`` with a
    per-tensor ``scale s`` (f32) and **integer zero point** ``z``:
    ``x_f = s * (q - z)``.
  * approximate multipliers operate on the raw u8 *codes* (like the
    hardware would); the zero-point cross terms are corrected exactly with
    adder sums, so an exact multiplier reproduces float conv up to
    rounding:  sum (a-za)(w-zw) = sum lut[a,w] - za*SW - zw*SA + K*za*zw
    + sum err[a,w].
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

QMIN = 0.0
QMAX = 255.0


@dataclasses.dataclass(frozen=True)
class QParams:
    """Per-tensor affine quantization parameters."""

    scale: float
    zero_point: int

    @staticmethod
    def from_range(lo: float, hi: float) -> "QParams":
        """Affine params covering [lo, hi] (always includes 0)."""
        lo = min(float(lo), 0.0)
        hi = max(float(hi), 1e-6)
        scale = (hi - lo) / QMAX
        zp = int(round(-lo / scale))
        zp = max(0, min(255, zp))
        return QParams(scale=scale, zero_point=zp)

    def to_json(self) -> dict:
        return {"scale": self.scale, "zero_point": self.zero_point}


def quantize_codes(x, qp: QParams):
    """float -> u8 codes (rounded, clipped). Non-differentiable."""
    return jnp.clip(jnp.round(x / qp.scale) + qp.zero_point, QMIN, QMAX)


def dequantize(q, qp: QParams):
    return (q - qp.zero_point) * qp.scale


def fake_quant(x, qp: QParams):
    """Quantize-dequantize with a straight-through gradient estimator."""
    q = quantize_codes(x, qp)
    y = dequantize(q, qp)
    return x + jax.lax.stop_gradient(y - x)


def codes_ste(x, qp: QParams):
    """u8 codes of ``x`` with identity (scaled) gradient back to ``x``.

    d codes / d x = 1/scale through the STE, which is what the low-rank
    error-surrogate path needs when weights are being retrained.
    """
    q = quantize_codes(x, qp)
    lin = x / qp.scale + qp.zero_point
    return lin + jax.lax.stop_gradient(q - lin)


def weight_qparams(w: np.ndarray) -> QParams:
    """Per-tensor weight quantization covering the full range."""
    return QParams.from_range(float(np.min(w)), float(np.max(w)))


def calibrate_activation(samples: np.ndarray, pct: float = 99.9) -> QParams:
    """Percentile-calibrated activation range (robust to outliers)."""
    lo = float(np.percentile(samples, 100.0 - pct))
    hi = float(np.percentile(samples, pct))
    return QParams.from_range(lo, hi)


class EmaRange:
    """Exponential-moving-average min/max tracker used during QAT."""

    def __init__(self, decay: float = 0.99):
        self.decay = decay
        self.lo: float | None = None
        self.hi: float | None = None

    def update(self, x: np.ndarray) -> None:
        lo, hi = float(np.min(x)), float(np.max(x))
        if self.lo is None:
            self.lo, self.hi = lo, hi
        else:
            d = self.decay
            self.lo = d * self.lo + (1 - d) * lo
            self.hi = d * self.hi + (1 - d) * hi

    def qparams(self) -> QParams:
        assert self.lo is not None, "EmaRange never updated"
        return QParams.from_range(self.lo, self.hi)
