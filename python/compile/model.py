"""L2 serving graphs that get AOT-lowered to HLO text (see aot.py).

Two artifacts per experiment:

  * ``model.hlo.txt`` — the full network forward in ``approx`` mode with
    the trained weights folded in as constants and the **per-operating-
    point tensors as runtime inputs**: for every approximable layer its
    low-rank error tables (U, V) and its BN overlay (gamma, beta) or bias.
    One compiled PJRT executable therefore serves *all* operating points;
    the Rust coordinator switches OPs by swapping input buffers
    (DESIGN.md "reconfiguration = input buffers").

  * ``kernel.hlo.txt`` — the L1 Pallas LUT-matmul kernel lowered stand-
    alone (interpret mode) for bit-exact single-layer execution from Rust;
    proves the L1 -> L3 path composes and anchors integration tests.

Input signature (order matters; mirrored in hlo_signature.json):

    x, then per approx layer (graph order):
      <layer>.U (256, r) f32, <layer>.V (256, r) f32,
      <layer>.gamma (cout,) f32 + <layer>.beta (cout,) f32   [if has_bn]
      <layer>.b (cout,) f32                                  [otherwise]
"""

from __future__ import annotations

from typing import List

from .executor import RunConfig, forward
from .graph import Graph
from .kernels import lut_matmul as lm


def serving_signature(graph: Graph, rank: int, batch: int) -> List[dict]:
    """Ordered input spec for model.hlo.txt."""
    h, w, c = graph.input_shape
    sig = [{"name": "x", "shape": [batch, h, w, c], "dtype": "f32"}]
    for n in graph.approx_layers():
        sig.append({"name": f"{n.name}.U", "shape": [256, rank], "dtype": "f32"})
        sig.append({"name": f"{n.name}.V", "shape": [256, rank], "dtype": "f32"})
        if n.has_bn:
            sig.append({"name": f"{n.name}.gamma", "shape": [n.cout], "dtype": "f32"})
            sig.append({"name": f"{n.name}.beta", "shape": [n.cout], "dtype": "f32"})
        else:
            sig.append({"name": f"{n.name}.b", "shape": [n.cout], "dtype": "f32"})
    return sig


def make_serving_fn(graph: Graph, params: dict, quant_meta: dict):
    """Returns f(x, *op_tensors) -> (logits,) with weights closed over.

    ``op_tensors`` follow serving_signature order (sans x).  A zero U/V
    pair degenerates to the exact multiplier (the error term vanishes),
    so the exact OP needs no special casing.
    """
    layers = graph.approx_layers()

    def fn(x, *op_tensors):
        uv = {}
        p = {k: dict(v) for k, v in params.items()}
        i = 0
        for n in layers:
            u, v = op_tensors[i], op_tensors[i + 1]
            i += 2
            uv[n.name] = (u, v)
            if n.has_bn:
                p[n.name]["gamma"] = op_tensors[i]
                p[n.name]["beta"] = op_tensors[i + 1]
                i += 2
            else:
                p[n.name]["b"] = op_tensors[i]
                i += 1
        run = RunConfig(mode="approx", quant=quant_meta, uv=uv, bn_train=False)
        logits, _ = forward(graph, p, x, run)
        return (logits,)

    return fn


def make_kernel_fn():
    """Stand-alone L1 kernel artifact: fused LUT matmul + requant."""

    def fn(a, w, lut, scale, zps):
        return (lm.lut_matmul_requant_dyn(a, w, lut, scale, zps),)

    return fn


def kernel_signature(m: int, k: int, n: int) -> List[dict]:
    return [
        {"name": "a", "shape": [m, k], "dtype": "i32"},
        {"name": "w", "shape": [k, n], "dtype": "i32"},
        {"name": "lut", "shape": [256, 256], "dtype": "i32"},
        {"name": "scale", "shape": [1], "dtype": "f32"},
        {"name": "zps", "shape": [3], "dtype": "i32"},
    ]
