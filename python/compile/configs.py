"""Experiment configuration system.

Every paper experiment (and the CI-scale ``quick`` profile) is a named,
JSON-serializable ``ExperimentConfig``.  The Rust CLI reads the exported
``exp.json`` so both sides agree on the workload; CLI flags on either side
can override individual fields.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple


@dataclasses.dataclass
class ExperimentConfig:
    name: str
    dataset: str
    model: str
    width: float = 1.0
    # training schedule (epochs)
    float_epochs: int = 8
    qat_epochs: int = 4
    agn_epochs: int = 3
    retrain_epochs: int = 2
    batch: int = 64
    lr: float = 0.05
    retrain_lr: float = 2e-3
    # search
    n_multipliers: int = 4  # n: clustered AM subset size
    scales: Tuple[float, ...] = (1.0,)  # S: one entry per operating point
    # AGN hyper-parameters.  The paper uses lambda=0.1, sigma_max=0.05,
    # sigma_init=0.001 on its normalization; our noise is injected post-BN
    # where activations have RMS ~1, so the equivalent working point that
    # yields a *differentiated* sigma_g (verified empirically) is:
    agn_lambda: float = 0.05
    agn_sigma_max: float = 0.5
    agn_sigma_init: float = 0.01
    rank: int = 8  # low-rank error surrogate rank
    # Deterministic-error safety factor: the AGN search measures tolerance
    # to *fresh random* noise; deterministic multiplier error of equal std
    # is correlated across MACs (shared weights) and constant across
    # inference passes, so the usable tolerance is a fraction of sigma_g.
    # Applied uniformly to every mapping method (ours and baselines).
    tolerance_factor: float = 0.3
    seed: int = 0
    export_batch: int = 8  # HLO serving batch
    stats_batches: int = 4

    @property
    def num_ops(self) -> int:
        return len(self.scales)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["scales"] = list(self.scales)
        return d

    @staticmethod
    def from_json(d: dict) -> "ExperimentConfig":
        d = dict(d)
        d["scales"] = tuple(d["scales"])
        return ExperimentConfig(**d)


def _hw_for(dataset: str) -> int:
    return {"synthcifar10": 32, "synthcifar100": 32, "synthtin": 64, "microcifar": 16}[dataset]


EXPERIMENTS: Dict[str, ExperimentConfig] = {}


def _reg(cfg: ExperimentConfig) -> ExperimentConfig:
    EXPERIMENTS[cfg.name] = cfg
    return cfg


# CI / unit-test scale: a few seconds of training.
_reg(
    ExperimentConfig(
        name="quick",
        dataset="microcifar",
        model="resnet8",
        width=0.5,
        float_epochs=3,
        qat_epochs=2,
        agn_epochs=2,
        retrain_epochs=2,
        batch=64,
        n_multipliers=3,
        scales=(0.3, 1.0),
        rank=8,
    )
)

# Table 2: CIFAR-10, single operating point.
for depth, n in [(8, 4), (14, 4), (20, 3), (32, 3)]:
    _reg(
        ExperimentConfig(
            name=f"table2_resnet{depth}",
            dataset="synthcifar10",
            model=f"resnet{depth}",
            width=1.0,
            float_epochs=10,
            qat_epochs=4,
            agn_epochs=3,
            retrain_epochs=3,
            n_multipliers=n,
            scales=(1.0,),
        )
    )

# Table 3: CIFAR-100, single operating point, n = 3.
for depth in (20, 32):
    _reg(
        ExperimentConfig(
            name=f"table3_resnet{depth}",
            dataset="synthcifar100",
            model=f"resnet{depth}",
            width=1.0,
            float_epochs=12,
            qat_epochs=4,
            agn_epochs=3,
            retrain_epochs=3,
            n_multipliers=3,
            scales=(1.0,),
        )
    )

# Table 4 / Fig 3: MobileNetV2 on TinyImageNet-like data, o = 3, n = 4.
_reg(
    ExperimentConfig(
        name="table4_mnv2",
        dataset="synthtin",
        model="mobilenet_v2",
        width=0.5,
        float_epochs=10,
        qat_epochs=3,
        agn_epochs=2,
        retrain_epochs=2,
        batch=48,
        n_multipliers=4,
        scales=(0.1, 0.3, 1.0),
        retrain_lr=2e-3,
    )
)


def get(name: str) -> ExperimentConfig:
    if name not in EXPERIMENTS:
        raise KeyError(f"unknown experiment {name!r}; have {sorted(EXPERIMENTS)}")
    return EXPERIMENTS[name]


def hw(cfg: ExperimentConfig) -> int:
    return _hw_for(cfg.dataset)


def num_classes(cfg: ExperimentConfig) -> int:
    from .datasets import SPECS

    return SPECS[cfg.dataset].num_classes


def save(cfg: ExperimentConfig, path: str) -> None:
    with open(path, "w") as f:
        json.dump(cfg.to_json(), f, indent=1)
