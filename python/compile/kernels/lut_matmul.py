"""L1 Pallas kernel: LUT-based approximate quantized matmul.

The compute hot-spot of the whole system: a matrix multiplication whose
scalar product is an *approximate multiplier* evaluated through its
256x256 lookup table,

    out[m, n] = sum_k lut[a[m, k], w[k, n]]          (raw accumulation)

plus a fused variant that applies the zero-point correction and float
requantization in the same kernel:

    corr[m, n] = acc - za * SW[n] - zw * SA[m] + K * za * zw
    out_q      = clip(round(corr * s_a * s_w / s_o) + zo, 0, 255)

TPU mapping (see DESIGN.md §Hardware-Adaptation): the LUT (256 KiB, i32)
is VMEM-resident and *unblocked* (its BlockSpec index_map pins block
(0, 0) for every grid step), while `a` tiles stream along the M grid axis
and `w` tiles along N.  Product lookup is a VPU gather; the K reduction
is kept inside the block so the accumulator tile never round-trips to
HBM.  Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls; real-TPU numbers are estimated
analytically (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 32
DEFAULT_BN = 32


def _lut_matmul_kernel(a_ref, w_ref, lut_ref, o_ref):
    """One (bm, bn) output tile; full K reduction in-block."""
    a = a_ref[...]  # (bm, K) i32 codes
    w = w_ref[...]  # (K, bn) i32 codes
    lut = lut_ref[...].reshape(-1)  # (65536,) i32, flattened for 1-D gather
    # flat index a*256 + w over the (bm, K, bn) product cube
    idx = a[:, :, None] * 256 + w[None, :, :]
    prod = jnp.take(lut, idx, axis=0)
    o_ref[...] = jnp.sum(prod, axis=1, dtype=jnp.int32)


def lut_matmul(a, w, lut, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """Raw LUT accumulation: (M, K) x (K, N) -> (M, N) i32.

    ``a``/``w`` are u8 codes stored as i32; ``lut`` is (256, 256) i32.
    M and N must be divisible by the block sizes (pad at the call site;
    helpers in model.py handle it).
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _lut_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((256, 256), lambda i, j: (0, 0)),  # LUT VMEM-resident
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a.astype(jnp.int32), w.astype(jnp.int32), lut.astype(jnp.int32))


def _lut_matmul_requant_kernel(a_ref, w_ref, lut_ref, scale_ref, zps_ref, o_ref):
    a = a_ref[...]
    w = w_ref[...]
    lut = lut_ref[...].reshape(-1)
    scale = scale_ref[0]  # s_a * s_w / s_o
    za = zps_ref[0]
    zw = zps_ref[1]
    zo = zps_ref[2]
    idx = a[:, :, None] * 256 + w[None, :, :]
    acc = jnp.sum(jnp.take(lut, idx, axis=0), axis=1, dtype=jnp.int32)
    k = a.shape[1]
    sa = jnp.sum(a, axis=1, dtype=jnp.int32)  # (bm,)
    sw = jnp.sum(w, axis=0, dtype=jnp.int32)  # (bn,)
    corr = acc - za * sw[None, :] - zw * sa[:, None] + k * za * zw
    q = jnp.round(corr.astype(jnp.float32) * scale) + zo.astype(jnp.float32)
    o_ref[...] = jnp.clip(q, 0.0, 255.0).astype(jnp.int32)


def lut_matmul_requant(a, w, lut, scale: float, za: int, zw: int, zo: int, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """Fused LUT matmul + zero-point correction + u8 requantization."""
    m, k = a.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    scale_arr = jnp.asarray([scale], jnp.float32)
    zps = jnp.asarray([za, zw, zo], jnp.int32)
    return pl.pallas_call(
        _lut_matmul_requant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((256, 256), lambda i, j: (0, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a.astype(jnp.int32), w.astype(jnp.int32), lut.astype(jnp.int32), scale_arr, zps)


def lut_matmul_requant_dyn(a, w, lut, scale, zps, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """Like lut_matmul_requant but with *traced* scale / zero points.

    Used by the stand-alone kernel HLO artifact (kernel.hlo.txt) so the
    Rust runtime can feed requantization parameters at execute time.
    ``scale``: (1,) f32 = s_a*s_w/s_o; ``zps``: (3,) i32 = [za, zw, zo].
    """
    m, k = a.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _lut_matmul_requant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((256, 256), lambda i, j: (0, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a.astype(jnp.int32), w.astype(jnp.int32), lut.astype(jnp.int32), scale.astype(jnp.float32), zps.astype(jnp.int32))


def vmem_footprint_bytes(bm: int, bn: int, k: int) -> dict:
    """Analytic VMEM budget for one grid step (DESIGN.md §Perf).

    The (bm, k, bn) gather cube dominates; the LUT is a constant 256 KiB.
    """
    lut = 256 * 256 * 4
    a_tile = bm * k * 4
    w_tile = k * bn * 4
    cube = bm * k * bn * 4
    acc = bm * bn * 4
    total = lut + a_tile + w_tile + cube + acc
    return {
        "lut": lut,
        "a_tile": a_tile,
        "w_tile": w_tile,
        "gather_cube": cube,
        "acc": acc,
        "total": total,
        "fits_16MiB_vmem": total <= 16 * 1024 * 1024,
    }
