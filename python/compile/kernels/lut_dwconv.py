"""L1 Pallas kernel: depthwise LUT convolution.

MobileNetV2's depthwise 3x3 layers are a poor fit for the im2col +
``lut_matmul`` path (K = 9, one output channel per group), so they get a
dedicated kernel: every channel convolves its own k*k filter, products
looked up through the approximate multiplier's LUT:

    out[m, c] = sum_t lut[patches[m, t, c], w[t, c]]

with ``patches`` the pre-extracted (M, k*k, C) code tensor (padding taps
already filled with the zero-point code, matching the engine / executor
contract).  Grid over M tiles; the LUT is VMEM-resident and unblocked as
in lut_matmul; the tap loop is unrolled (taps = 9 for 3x3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 64


def _dwconv_kernel(p_ref, w_ref, lut_ref, o_ref):
    patches = p_ref[...]  # (bm, taps, C) i32 codes
    w = w_ref[...]  # (taps, C) i32 codes
    lut = lut_ref[...].reshape(-1)
    idx = patches * 256 + w[None, :, :]
    prod = jnp.take(lut, idx, axis=0)
    o_ref[...] = jnp.sum(prod, axis=1, dtype=jnp.int32)


def lut_dwconv(patches, w, lut, *, bm: int = DEFAULT_BM):
    """Depthwise LUT conv: (M, taps, C) x (taps, C) -> (M, C) i32.

    M must be divisible by ``bm`` (pad at the call site).
    """
    m, taps, c = patches.shape
    t2, c2 = w.shape
    assert (taps, c) == (t2, c2), (patches.shape, w.shape)
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _dwconv_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, taps, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((taps, c), lambda i: (0, 0)),
            pl.BlockSpec((256, 256), lambda i: (0, 0)),  # LUT VMEM-resident
        ],
        out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, c), jnp.int32),
        interpret=True,
    )(patches.astype(jnp.int32), w.astype(jnp.int32), lut.astype(jnp.int32))


def extract_patches(codes, hw: int, c: int, ksize: int, stride: int, pad: int, za: int):
    """NHWC code tensor (B, H, W, C) -> (B*OH*OW, k*k, C) with za padding."""
    b = codes.shape[0]
    p = pad
    padded = jnp.pad(codes, ((0, 0), (p, p), (p, p), (0, 0)), constant_values=za)
    oh = (hw + 2 * p - ksize) // stride + 1
    rows = []
    for ky in range(ksize):
        for kx in range(ksize):
            sl = padded[:, ky : ky + oh * stride : stride, kx : kx + oh * stride : stride, :]
            rows.append(sl.reshape(b * oh * oh, c))
    return jnp.stack(rows, axis=1)  # (M, taps, C)


def dwconv_ref(patches, w, lut):
    """Pure-jnp oracle."""
    flat = lut.astype(jnp.int32).reshape(-1)
    idx = patches.astype(jnp.int32) * 256 + w.astype(jnp.int32)[None, :, :]
    return jnp.sum(jnp.take(flat, idx, axis=0), axis=1, dtype=jnp.int32)
