"""Pure-jnp oracles for the Pallas kernels (bit-exact references)."""

from __future__ import annotations

import jax.numpy as jnp


def lut_matmul_ref(a, w, lut):
    """out[m, n] = sum_k lut[a[m, k], w[k, n]]  — (M, N) i32."""
    a = a.astype(jnp.int32)
    w = w.astype(jnp.int32)
    flat = lut.astype(jnp.int32).reshape(-1)
    idx = a[:, :, None] * 256 + w[None, :, :]
    return jnp.sum(jnp.take(flat, idx, axis=0), axis=1, dtype=jnp.int32)


def lut_matmul_requant_ref(a, w, lut, scale: float, za: int, zw: int, zo: int):
    a = a.astype(jnp.int32)
    w = w.astype(jnp.int32)
    acc = lut_matmul_ref(a, w, lut)
    k = a.shape[1]
    sa = jnp.sum(a, axis=1, dtype=jnp.int32)
    sw = jnp.sum(w, axis=0, dtype=jnp.int32)
    corr = acc - za * sw[None, :] - zw * sa[:, None] + k * za * zw
    q = jnp.round(corr.astype(jnp.float32) * scale) + float(zo)
    return jnp.clip(q, 0.0, 255.0).astype(jnp.int32)
