"""Deterministic synthetic image-classification datasets.

Offline substitutes for CIFAR-10/100 and TinyImageNet (see DESIGN.md).
Each class is a procedural texture generator: an oriented grating with a
class-specific frequency / orientation / color palette, modulated by a
class-positioned Gaussian envelope, plus per-sample jitter (orientation
noise, translation, brightness, additive pixel noise).  Classes are far
enough apart to be learnable by a small CNN in a few epochs and close
enough that approximation-induced error shows up as graded accuracy loss
(the property the paper's experiments rely on).

All generation is a pure function of ``(dataset seed, split, index)``, so
the Python training side and any re-generation for the Rust evaluation
set agree exactly.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_classes: int
    hw: int
    n_train: int
    n_test: int
    seed: int


SPECS = {
    "synthcifar10": DatasetSpec("synthcifar10", 10, 32, 4096, 1024, 0xC1FA10),
    "synthcifar100": DatasetSpec("synthcifar100", 100, 32, 8192, 2048, 0xC1FA64),
    "synthtin": DatasetSpec("synthtin", 200, 64, 6000, 1500, 0x71F200),
    # reduced variants for unit tests / CI-speed runs
    "microcifar": DatasetSpec("microcifar", 10, 16, 512, 256, 0x3C0FFE),
}


def _class_params(spec: DatasetSpec, cls: int) -> dict:
    rng = np.random.default_rng(np.uint64(spec.seed) + np.uint64(7919 * cls + 13))
    return {
        "theta": rng.uniform(0, np.pi),
        "freq": rng.uniform(2.0, 7.0),
        "phase": rng.uniform(0, 2 * np.pi),
        "color": rng.uniform(0.25, 1.0, size=3),
        "color2": rng.uniform(0.0, 0.75, size=3),
        "cx": rng.uniform(0.25, 0.75),
        "cy": rng.uniform(0.25, 0.75),
        "sigma": rng.uniform(0.18, 0.42),
        "checker": rng.uniform(0.0, 1.0) > 0.5,
    }


def _render(spec: DatasetSpec, params: dict, rng: np.random.Generator) -> np.ndarray:
    hw = spec.hw
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32) / hw
    theta = params["theta"] + rng.normal(0, 0.12)
    freq = params["freq"] * (1.0 + rng.normal(0, 0.08))
    cx = params["cx"] + rng.normal(0, 0.06)
    cy = params["cy"] + rng.normal(0, 0.06)
    u = np.cos(theta) * xx + np.sin(theta) * yy
    v = -np.sin(theta) * xx + np.cos(theta) * yy
    wave = np.sin(2 * np.pi * freq * u + params["phase"])
    if params["checker"]:
        wave = wave * np.sin(2 * np.pi * freq * v + params["phase"])
    env = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * params["sigma"] ** 2)))
    pattern = 0.5 + 0.5 * wave * env
    img = (
        pattern[..., None] * params["color"][None, None, :]
        + (1 - pattern[..., None]) * params["color2"][None, None, :]
    )
    img = img * (1.0 + rng.normal(0, 0.08))  # brightness jitter
    img = img + rng.normal(0, 0.04, size=img.shape)  # pixel noise
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def generate(spec_name: str, split: str) -> tuple:
    """Returns (images NHWC f32 in [0,1], labels i32)."""
    spec = SPECS[spec_name]
    n = spec.n_train if split == "train" else spec.n_test
    salt = 0 if split == "train" else 0x5EED
    cls_params = [_class_params(spec, c) for c in range(spec.num_classes)]
    imgs = np.empty((n, spec.hw, spec.hw, 3), dtype=np.float32)
    labels = np.empty((n,), dtype=np.int32)
    for i in range(n):
        cls = i % spec.num_classes
        rng = np.random.default_rng(np.uint64(spec.seed) + np.uint64(salt) * 1_000_003 + np.uint64(i) * 7907 + 1)
        imgs[i] = _render(spec, cls_params[cls], rng)
        labels[i] = cls
    # deterministic shuffle so batches are class-mixed
    order = np.random.default_rng(np.uint64(spec.seed) ^ np.uint64(salt + 99)).permutation(n)
    return imgs[order], labels[order]


def augment(imgs: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Light train-time augmentation: flips + up-to-2px translations."""
    out = imgs.copy()
    n, hw = imgs.shape[0], imgs.shape[1]
    flip = rng.random(n) < 0.5
    out[flip] = out[flip, :, ::-1]
    shifts = rng.integers(-2, 3, size=(n, 2))
    for i in range(n):
        dy, dx = shifts[i]
        out[i] = np.roll(out[i], (dy, dx), axis=(0, 1))
    return out
