"""AGN layer-sensitivity search (paper Sec. 3.1 / Trommer et al. [16]).

Injects additive Gaussian noise ``sigma_k * N(0, 1)`` after every
approximable layer's BN/bias and optimizes the vector ``sigma`` (one entry
per layer) by gradient descent while the network parameters stay frozen.
The loss trades task performance against the *amount* of tolerated noise:

    L = CE(logits) + lambda * mean_k( -log(sigma_k / sigma_max) )

The second term rewards pushing sigma up toward ``sigma_max`` (robust
layers drift high); the CE term pushes sigma down wherever the task
actually suffers (sensitive layers stay low).  ``sigma`` is kept in
[sigma_min, sigma_max] by projection after every step — the paper's
hyper-parameters (lambda = 0.1, sigma_max = 0.05, sigma_init = 0.001) are
the defaults.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .executor import RunConfig, forward
from .graph import Graph


@dataclasses.dataclass
class AgnConfig:
    lam: float = 0.1
    sigma_max: float = 0.05
    sigma_init: float = 0.001
    sigma_min: float = 1e-5
    lr: float = 0.05
    momentum: float = 0.9
    epochs: int = 5


def search(
    graph: Graph,
    params: dict,
    quant: dict,
    images: np.ndarray,
    labels: np.ndarray,
    cfg: AgnConfig,
    batch: int = 64,
    seed: int = 0,
    log=print,
) -> np.ndarray:
    """Returns the optimized per-layer noise tolerance sigma_g (l,)."""
    l = len(graph.approx_layers())
    sigma = jnp.full((l,), cfg.sigma_init, jnp.float32)
    vel = jnp.zeros_like(sigma)

    def loss_fn(sig, x, y, rng):
        run = RunConfig(mode="agn", quant=quant, sigma=sig, rng=rng, bn_train=False)
        logits, _ = forward(graph, params, x, run)
        ce = -jnp.mean(
            jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], axis=1)
        )
        reg = jnp.mean(-jnp.log(sig / cfg.sigma_max))
        return ce + cfg.lam * reg

    grad_fn = jax.jit(jax.grad(loss_fn))

    n = images.shape[0]
    key = jax.random.PRNGKey(seed)
    steps_per_epoch = max(1, n // batch)
    for ep in range(cfg.epochs):
        order = np.random.default_rng(seed + ep).permutation(n)
        for s in range(steps_per_epoch):
            idx = order[s * batch : (s + 1) * batch]
            key, sub = jax.random.split(key)
            g = grad_fn(sigma, jnp.asarray(images[idx]), jnp.asarray(labels[idx]), sub)
            vel = cfg.momentum * vel - cfg.lr * g
            sigma = jnp.clip(sigma + vel, cfg.sigma_min, cfg.sigma_max)
        log(
            f"  agn epoch {ep + 1}/{cfg.epochs}: sigma mean={float(sigma.mean()):.4f} "
            f"min={float(sigma.min()):.5f} max={float(sigma.max()):.5f}"
        )
    return np.asarray(sigma)
