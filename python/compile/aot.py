"""AOT build orchestrator — the *only* entry point that runs Python.

Stage A (``build``): train baseline -> QAT -> AGN sensitivity search ->
layer statistics -> export all artifacts the Rust side needs::

    artifacts/
      muldb.json  luts.bin  lowrank.bin         (shared, once)
      <exp>/
        exp.json           experiment config + baseline accuracies
        graph.json         topology + MACs + quantization parameters
        params.qten        QAT parameters (weights, BN, biases)
        sensitivity.json   sigma_g from the AGN search
        layer_stats.json   histograms etc. for the error model
        testset.qten       evaluation images (f32) + labels (i32)
        trainset.qten      retraining data for stage B
        model.hlo.txt      serving graph (per-OP tensors as inputs)
        kernel.hlo.txt     stand-alone L1 Pallas kernel
        hlo_signature.json input ordering for both HLO artifacts

Stage B (``retrain``): consume the Rust-produced ``assignment.json`` and
fine-tune per operating point (none / full / bn), exporting per-OP BN
overlays + a retrain report.  Stage B is still build-time Python; the
request path stays pure Rust.

HLO is emitted as **text** via StableHLO -> XlaComputation: jax >= 0.5
serialized protos use 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import configs, datasets, model as model_mod, models, muldb, stats as stats_mod, tensorio
from .agn import AgnConfig, search as agn_search
from .executor import bn_param_count, init_params, num_params
from .graph import Graph
from .quant import QParams
from .train import (
    TrainConfig,
    calibrate_quant,
    evaluate,
    refresh_weight_qparams,
    residual_noise_for_assignment,
    retrain_approx,
    train,
    uv_for_assignment,
)


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big literals as
    # "constant({...})" and the 0.5.1-era text parser silently zero-fills
    # them — the exported weights would all read as zero on the Rust side.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), {"f32": jnp.float32, "i32": jnp.int32}[dtype])


# ---------------------------------------------------------------------------
# Stage A
# ---------------------------------------------------------------------------


def _params_to_tensors(graph: Graph, params: dict) -> dict:
    out = {}
    for n in graph.approx_layers():
        for k, v in params[n.name].items():
            out[f"{n.name}.{k}"] = np.asarray(v)
    return out


def _tensors_to_params(graph: Graph, tensors: dict) -> dict:
    params = {}
    for n in graph.approx_layers():
        group = {}
        for key, v in tensors.items():
            ln, _, pk = key.rpartition(".")
            if ln == n.name:
                group[pk] = jnp.asarray(v)
        params[n.name] = group
    return params


def _quant_to_json(quant_meta: dict) -> dict:
    return {
        name: {"in": d["in"].to_json(), "w": d["w"].to_json()}
        for name, d in quant_meta.items()
    }


def _quant_from_json(d: dict) -> dict:
    return {
        name: {
            "in": QParams(v["in"]["scale"], v["in"]["zero_point"]),
            "w": QParams(v["w"]["scale"], v["w"]["zero_point"]),
        }
        for name, v in d.items()
    }


def build_graph(cfg: configs.ExperimentConfig) -> Graph:
    return models.build(cfg.model, configs.num_classes(cfg), configs.hw(cfg), cfg.width)


def stage_a(cfg: configs.ExperimentConfig, outdir: str, log=print) -> dict:
    t0 = time.time()
    exp_dir = os.path.join(outdir, cfg.name)
    os.makedirs(exp_dir, exist_ok=True)

    # shared multiplier artifacts (idempotent)
    if not os.path.exists(os.path.join(outdir, "muldb.json")):
        log("building multiplier LUT family...")
        muldb.write_artifacts(outdir, rank=16)

    log(f"[{cfg.name}] generating dataset {cfg.dataset}...")
    tr_x, tr_y = datasets.generate(cfg.dataset, "train")
    te_x, te_y = datasets.generate(cfg.dataset, "test")

    graph = build_graph(cfg)
    params = init_params(graph, cfg.seed)
    log(f"[{cfg.name}] model {cfg.model} w={cfg.width}: "
        f"{len(graph.approx_layers())} approx layers, {num_params(params):,} params")

    log(f"[{cfg.name}] float training ({cfg.float_epochs} epochs)...")
    tc = TrainConfig(epochs=cfg.float_epochs, batch=cfg.batch, lr=cfg.lr, seed=cfg.seed)
    params = train(graph, params, tr_x, tr_y, tc, mode="float", log=log)
    acc_float = evaluate(graph, params, te_x, te_y, "float")
    log(f"[{cfg.name}] float top1={acc_float['top1']:.3f} top5={acc_float['top5']:.3f}")

    log(f"[{cfg.name}] calibrating quantization + QAT ({cfg.qat_epochs} epochs)...")
    quant_meta = calibrate_quant(graph, params, tr_x)
    tcq = TrainConfig(epochs=cfg.qat_epochs, batch=cfg.batch, lr=cfg.lr * 0.1, seed=cfg.seed + 1)
    params = train(graph, params, tr_x, tr_y, tcq, mode="qat", quant_meta=quant_meta, log=log)
    quant_meta = refresh_weight_qparams(graph, params, quant_meta)
    acc_qat = evaluate(graph, params, te_x, te_y, "qat", quant_meta)
    log(f"[{cfg.name}] qat top1={acc_qat['top1']:.3f} top5={acc_qat['top5']:.3f}")

    log(f"[{cfg.name}] AGN sensitivity search ({cfg.agn_epochs} epochs)...")
    agn_cfg = AgnConfig(
        lam=cfg.agn_lambda,
        sigma_max=cfg.agn_sigma_max,
        sigma_init=cfg.agn_sigma_init,
        epochs=cfg.agn_epochs,
    )
    sigma_g = agn_search(graph, params, quant_meta, tr_x, tr_y, agn_cfg, batch=cfg.batch, seed=cfg.seed, log=log)

    log(f"[{cfg.name}] collecting layer statistics...")
    layer_stats = stats_mod.collect_layer_stats(graph, params, quant_meta, tr_x, batches=cfg.stats_batches, batch=cfg.batch)

    # ---- exports ----
    tensorio.save(os.path.join(exp_dir, "params.qten"), _params_to_tensors(graph, params))
    tensorio.save(os.path.join(exp_dir, "testset.qten"), {"images": te_x, "labels": te_y})
    tensorio.save(os.path.join(exp_dir, "trainset.qten"), {"images": tr_x, "labels": tr_y})

    with open(os.path.join(exp_dir, "graph.json"), "w") as f:
        json.dump(graph.to_json(qmeta=_quant_to_json(quant_meta)), f, indent=1)
    names = [n.name for n in graph.approx_layers()]
    with open(os.path.join(exp_dir, "sensitivity.json"), "w") as f:
        json.dump({"layers": names, "sigma_g": sigma_g.tolist(),
                   "lambda": cfg.agn_lambda, "sigma_max": cfg.agn_sigma_max}, f, indent=1)
    with open(os.path.join(exp_dir, "layer_stats.json"), "w") as f:
        json.dump(layer_stats, f)

    export_hlo(cfg, graph, params, quant_meta, exp_dir, log=log)

    summary = {
        "config": cfg.to_json(),
        "acc_float": acc_float,
        "acc_qat": acc_qat,
        "n_params": num_params(params),
        "bn_overlay_params": bn_param_count(graph),
        "build_seconds": time.time() - t0,
    }
    with open(os.path.join(exp_dir, "exp.json"), "w") as f:
        json.dump(summary, f, indent=1)
    log(f"[{cfg.name}] stage A done in {summary['build_seconds']:.1f}s")
    return summary


def export_hlo(cfg, graph: Graph, params: dict, quant_meta: dict, exp_dir: str, log=print) -> None:
    log(f"[{cfg.name}] lowering serving graph to HLO text...")
    sig = model_mod.serving_signature(graph, cfg.rank, cfg.export_batch)
    fn = model_mod.make_serving_fn(graph, params, quant_meta)
    specs = [_spec(s["shape"], s["dtype"]) for s in sig]
    lowered = jax.jit(fn).lower(*specs)
    with open(os.path.join(exp_dir, "model.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    # stand-alone L1 kernel artifact (first-layer-like shape)
    km, kk, kn = 64, 32, 32
    ksig = model_mod.kernel_signature(km, kk, kn)
    kfn = model_mod.make_kernel_fn()
    klowered = jax.jit(kfn).lower(*[_spec(s["shape"], s["dtype"]) for s in ksig])
    with open(os.path.join(exp_dir, "kernel.hlo.txt"), "w") as f:
        f.write(to_hlo_text(klowered))

    with open(os.path.join(exp_dir, "hlo_signature.json"), "w") as f:
        json.dump({"model": sig, "kernel": ksig, "rank": cfg.rank,
                   "export_batch": cfg.export_batch}, f, indent=1)


# ---------------------------------------------------------------------------
# Stage B: per-operating-point fine-tuning from a Rust assignment
# ---------------------------------------------------------------------------


def load_experiment(outdir: str, name: str):
    exp_dir = os.path.join(outdir, name)
    with open(os.path.join(exp_dir, "exp.json")) as f:
        summary = json.load(f)
    cfg = configs.ExperimentConfig.from_json(summary["config"])
    graph = build_graph(cfg)
    with open(os.path.join(exp_dir, "graph.json")) as f:
        gj = json.load(f)
    quant_meta = _quant_from_json({n["name"]: n["quant"] for n in gj["nodes"] if "quant" in n})
    params = _tensors_to_params(graph, tensorio.load(os.path.join(exp_dir, "params.qten")))
    return cfg, graph, params, quant_meta, exp_dir


def _load_lowrank(outdir: str):
    import struct

    with open(os.path.join(outdir, "lowrank.bin"), "rb") as f:
        magic, count, nop, rank = struct.unpack("<4sIII", f.read(16))
        assert magic == b"QLRK"
        u = np.frombuffer(f.read(count * nop * rank * 4), "<f4").reshape(count, nop, rank)
        v = np.frombuffer(f.read(count * nop * rank * 4), "<f4").reshape(count, nop, rank)
    return u, v


def stage_b(outdir: str, name: str, modes=("none", "full", "bn"), log=print) -> dict:
    cfg, graph, base_params, quant_meta, exp_dir = load_experiment(outdir, name)
    with open(os.path.join(exp_dir, "assignment.json")) as f:
        assignment = json.load(f)
    with open(os.path.join(exp_dir, "layer_stats.json")) as f:
        layer_stats = json.load(f)
    lr_u, lr_v = _load_lowrank(outdir)

    tr = tensorio.load(os.path.join(exp_dir, "trainset.qten"))
    te = tensorio.load(os.path.join(exp_dir, "testset.qten"))
    tr_x, tr_y = tr["images"], tr["labels"].astype(np.int32)
    te_x, te_y = te["images"], te["labels"].astype(np.int32)

    report = {"experiment": name, "ops": []}
    rtc = TrainConfig(
        epochs=cfg.retrain_epochs, batch=cfg.batch, lr=cfg.retrain_lr,
        lr_decay_at=(0.5,), lr_decay=0.1, augment=False, seed=cfg.seed + 7,
    )

    for op in assignment["operating_points"]:
        op_idx = op["index"]
        amap = {k: int(v) for k, v in op["assignment"].items()}
        uv = uv_for_assignment(graph, amap, lr_u, lr_v, cfg.rank)
        res_noise = residual_noise_for_assignment(graph, amap, layer_stats, lr_u, lr_v, cfg.rank)
        entry = {"index": op_idx, "scale": op.get("scale"), "power": op.get("relative_power"), "modes": {}}
        for mode in modes:
            log(f"[{name}] OP{op_idx} retrain mode={mode}...")
            p = retrain_approx(graph, jax.tree_util.tree_map(lambda x: x, base_params),
                               quant_meta, uv, tr_x, tr_y, mode, rtc, res_noise=res_noise, log=log)
            acc = evaluate(graph, p, te_x, te_y, "approx", quant_meta, uv)
            entry["modes"][mode] = acc
            log(f"[{name}] OP{op_idx} {mode}: top1={acc['top1']:.3f} top5={acc['top5']:.3f}")
            if mode == "bn":
                overlay = {}
                for n in graph.approx_layers():
                    for k in ("gamma", "beta", "b"):
                        if k in p[n.name]:
                            overlay[f"{n.name}.{k}"] = np.asarray(p[n.name][k])
                tensorio.save(os.path.join(exp_dir, f"bn_op{op_idx}.qten"), overlay)
            if mode == "full":
                tensorio.save(os.path.join(exp_dir, f"params_full_op{op_idx}.qten"),
                              _params_to_tensors(graph, p))
        report["ops"].append(entry)

    with open(os.path.join(exp_dir, "retrain_report.json"), "w") as f:
        json.dump(report, f, indent=1)
    return report


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description="QoS-Nets AOT build pipeline")
    ap.add_argument("command", choices=["build", "retrain", "muldb"])
    ap.add_argument("--exp", default="quick", help="experiment name (see configs.py)")
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--modes", default="none,full,bn", help="retrain modes for stage B")
    args = ap.parse_args()

    if args.command == "muldb":
        meta = muldb.write_artifacts(args.out)
        print(f"wrote {meta['count']} multipliers, digest {meta['digest_sha256'][:16]}")
    elif args.command == "build":
        stage_a(configs.get(args.exp), args.out)
    elif args.command == "retrain":
        stage_b(args.out, args.exp, modes=tuple(args.modes.split(",")))
    else:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
