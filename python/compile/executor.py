"""JAX executor for the graph IR — one forward, four modes.

  * ``float``   plain f32 forward (baseline training)
  * ``qat``     fake-quantized weights + layer inputs (STE), QAT
  * ``agn``     QAT forward + per-layer additive Gaussian noise whose
                std vector sigma is a differentiable parameter (the
                sensitivity search of Trommer et al. [16] / paper Sec 3.1)
  * ``approx``  quantized forward with the per-layer approximate-multiplier
                error added through the low-rank surrogate
                err[a, w] ~= sum_r U_r[a] * V_r[w]
                (see muldb.lowrank_error), which keeps retraining a pure
                conv/matmul computation.

Numeric contract with the Rust engine (rust/src/engine):

  fake_quant(x) = s_a * (a - za)           [a = u8 code]
  conv(fake_quant(x), fake_quant(w)) = s_a * s_w * sum (a - za)(w - zw)
  err term                          = s_a * s_w * sum err[a, w]
  sum lut[a,w] - za*SW - zw*SA + K*za*zw = sum (a-za)(w-zw) + sum err[a,w]

so ``approx`` mode computes exactly the corrected integer LUT accumulation
the Rust engine performs (up to f32 rounding and the rank truncation of
the surrogate).  Padding is materialized as zero-point codes *before* the
error gather so both sides feed padded taps through the multiplier.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, Node
from .quant import QParams, fake_quant

BN_EPS = 1e-5


@dataclasses.dataclass
class RunConfig:
    mode: str = "float"  # float | qat | agn | approx
    quant: Optional[Dict[str, Dict[str, QParams]]] = None  # name -> {in, w}
    # agn
    sigma: Optional[jnp.ndarray] = None  # (l,) noise std per approx layer
    rng: Optional[jax.Array] = None
    # approx: name -> (U (256,r) f32, V (256,r) f32)
    uv: Optional[Dict[str, Tuple[jnp.ndarray, jnp.ndarray]]] = None
    # approx: per-layer std of the rank-truncation residual, injected as
    # additive Gaussian noise during retraining (zero-mean, pre-BN) so the
    # training-time error statistics match the bit-exact LUT semantics
    # even for multipliers whose error map is not low-rank (otrunc*).
    res_noise: Optional[Dict[str, float]] = None
    bn_train: bool = False
    collect_acts: bool = False  # record each approx layer's input + output


def init_params(graph: Graph, seed: int = 0) -> dict:
    """He-initialized parameter pytree."""
    rng = np.random.default_rng(seed)
    params = {}
    for n in graph.approx_layers():
        if n.kind == "conv":
            fan_in = n.ksize * n.ksize * (n.cin // n.groups)
            shape = (n.ksize, n.ksize, n.cin // n.groups, n.cout)
        else:
            fan_in = n.cin
            shape = (n.cin, n.cout)
        std = float(np.sqrt(2.0 / fan_in))
        p = {"w": jnp.asarray(rng.normal(0, std, size=shape), dtype=jnp.float32)}
        if n.has_bn:
            p["gamma"] = jnp.ones((n.cout,), jnp.float32)
            p["beta"] = jnp.zeros((n.cout,), jnp.float32)
            p["mean"] = jnp.zeros((n.cout,), jnp.float32)
            p["var"] = jnp.ones((n.cout,), jnp.float32)
        else:
            p["b"] = jnp.zeros((n.cout,), jnp.float32)
        params[n.name] = p
    return params


def _interp_gather(table: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Differentiable LUT row lookup: linear interpolation over the index.

    ``table``: (256, r); ``pos``: float codes in [0, 255] (integral in the
    forward pass thanks to the STE).  The interpolation only matters for
    the backward pass, where it provides a local slope for d err / d code.
    """
    pos = jnp.clip(pos, 0.0, 255.0)
    lo = jnp.floor(pos)
    frac = pos - lo
    ilo = lo.astype(jnp.int32)
    ihi = jnp.minimum(ilo + 1, 255)
    tlo = table[ilo]
    thi = table[ihi]
    return tlo + frac[..., None] * (thi - tlo)


def _codes_ste(x, qp: QParams):
    q = jnp.clip(jnp.round(x / qp.scale) + qp.zero_point, 0.0, 255.0)
    lin = x / qp.scale + qp.zero_point
    return lin + jax.lax.stop_gradient(q - lin)


def _conv(x, w, node: Node, padding) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(node.stride, node.stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=node.groups,
    )


def _approx_err_conv(x, w, node: Node, qp_in: QParams, qp_w: QParams, U, V) -> jnp.ndarray:
    """s_a*s_w * conv(U[a], V[w]) with padded taps routed through the LUT."""
    r = U.shape[1]
    a_pos = _codes_ste(x, qp_in)
    if node.pad > 0:
        p = node.pad
        a_pos = jnp.pad(
            a_pos, ((0, 0), (p, p), (p, p), (0, 0)), constant_values=float(qp_in.zero_point)
        )
    w_pos = _codes_ste(w, qp_w)
    ua = _interp_gather(U, a_pos)  # (B, H', W', Cin, r)
    vw = _interp_gather(V, w_pos)  # (kh, kw, Cin/g, Cout, r)
    b, hh, ww, cin = a_pos.shape
    ua = ua.reshape(b, hh, ww, cin * r)
    kh, kw, cing, cout = w.shape
    vw = jnp.transpose(vw, (0, 1, 2, 4, 3)).reshape(kh, kw, cing * r, cout)
    err = jax.lax.conv_general_dilated(
        ua,
        vw,
        window_strides=(node.stride, node.stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=node.groups,
    )
    return qp_in.scale * qp_w.scale * err


def _approx_err_dense(x, w, qp_in: QParams, qp_w: QParams, U, V) -> jnp.ndarray:
    r = U.shape[1]
    a_pos = _codes_ste(x, qp_in)
    w_pos = _codes_ste(w, qp_w)
    ua = _interp_gather(U, a_pos).reshape(x.shape[0], -1)  # (B, cin*r)
    vw = jnp.transpose(_interp_gather(V, w_pos), (0, 2, 1)).reshape(-1, w.shape[1])
    return qp_in.scale * qp_w.scale * (ua @ vw)


def _batchnorm(y, p, train: bool):
    if train:
        axes = tuple(range(y.ndim - 1))
        mean = jnp.mean(y, axis=axes)
        var = jnp.var(y, axis=axes)
        yn = (y - mean) / jnp.sqrt(var + BN_EPS)
        return yn * p["gamma"] + p["beta"], (mean, var)
    yn = (y - p["mean"]) / jnp.sqrt(p["var"] + BN_EPS)
    return yn * p["gamma"] + p["beta"], None


def _act(y, kind: str):
    if kind == "relu":
        return jax.nn.relu(y)
    if kind == "relu6":
        return jnp.clip(y, 0.0, 6.0)
    return y


def forward(graph: Graph, params: dict, x: jnp.ndarray, cfg: RunConfig, rng=None):
    """Run the graph; returns (logits, aux).

    aux = {"bn": {name: (mean, var)}, "acts": {name: (x_in, y_preact)}}
    ``rng`` overrides cfg.rng (lets jitted training loops thread fresh keys).
    """
    mode = cfg.mode
    vals: Dict[int, jnp.ndarray] = {0: x}
    aux = {"bn": {}, "acts": {}}
    approx_idx = 0
    rng = rng if rng is not None else cfg.rng

    for n in graph.nodes[1:]:
        if n.kind in ("conv", "dense"):
            xin = vals[n.inputs[0]]
            if n.kind == "dense" and xin.ndim > 2:
                xin = xin.reshape(xin.shape[0], -1)
            p = params[n.name]
            w = p["w"]
            if mode in ("qat", "agn", "approx"):
                qp_in = cfg.quant[n.name]["in"]
                qp_w = cfg.quant[n.name]["w"]
                xq = fake_quant(xin, qp_in)
                wq = fake_quant(w, qp_w)
            else:
                xq, wq = xin, w

            if cfg.collect_acts:
                aux["acts"][n.name] = {"x": xq}

            if n.kind == "conv":
                pad = [(n.pad, n.pad), (n.pad, n.pad)]
                y = _conv(xq, wq, n, pad)
            else:
                y = xq @ wq

            if mode == "approx" and n.name in (cfg.uv or {}):
                U, V = cfg.uv[n.name]
                if n.kind == "conv":
                    y = y + _approx_err_conv(xin, w, n, qp_in, qp_w, U, V)
                else:
                    y = y + _approx_err_dense(xin, w, qp_in, qp_w, U, V)
                std = (cfg.res_noise or {}).get(n.name, 0.0)
                if std > 0.0 and rng is not None:
                    rng, sub = jax.random.split(rng)
                    y = y + std * jax.random.normal(sub, y.shape)

            if n.has_bn:
                y, stats = _batchnorm(y, p, cfg.bn_train)
                if stats is not None:
                    aux["bn"][n.name] = stats
            else:
                y = y + p["b"]

            if mode == "agn":
                assert cfg.sigma is not None and rng is not None
                rng, sub = jax.random.split(rng)
                y = y + cfg.sigma[approx_idx] * jax.random.normal(sub, y.shape)

            if cfg.collect_acts:
                aux["acts"][n.name]["y"] = y

            y = _act(y, n.act)
            vals[n.nid] = y
            approx_idx += 1
        elif n.kind == "add":
            y = vals[n.inputs[0]] + vals[n.inputs[1]]
            vals[n.nid] = _act(y, n.act)
        elif n.kind == "gap":
            v = vals[n.inputs[0]]
            vals[n.nid] = jnp.mean(v, axis=(1, 2))
        elif n.kind == "output":
            return vals[n.inputs[0]], aux
        else:
            raise ValueError(f"unhandled node kind {n.kind}")
    raise ValueError("graph has no output node")


def num_params(params: dict) -> int:
    return int(sum(np.prod(v.shape) for p in params.values() for v in p.values()))


def bn_param_count(graph: Graph) -> int:
    """Parameters a per-operating-point BN overlay adds (gamma+beta [+bias])."""
    total = 0
    for n in graph.approx_layers():
        total += 2 * n.cout if n.has_bn else n.cout
    return total
