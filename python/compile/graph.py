"""Tiny SSA graph IR shared between the Python (L2) and Rust (L3) sides.

A model is a list of nodes; each node consumes earlier nodes by id and
produces one tensor (NHWC).  The same graph is executed by

  * the JAX executor (``executor.py``) in float / QAT / AGN / approx modes
    (training + artifact export), and
  * the Rust native engine (``rust/src/engine``) with bit-exact integer
    LUT arithmetic (deployment / evaluation / serving).

``conv`` and ``dense`` nodes are the *approximable layers*: the units the
paper assigns approximate multipliers to.  The exported ``graph.json``
carries everything the Rust side needs: topology, shapes, MAC counts and
quantization parameters.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class Node:
    nid: int
    kind: str  # input | conv | dense | add | gap | output
    inputs: List[int]
    name: str = ""
    # conv attrs
    cin: int = 0
    cout: int = 0
    ksize: int = 0
    stride: int = 1
    pad: int = 0
    groups: int = 1
    has_bn: bool = False
    act: str = "none"  # none | relu | relu6
    # filled by shape inference
    out_shape: Tuple[int, ...] = ()
    macs_per_out: int = 0  # K: MACs per output element (error-model fan-in)
    macs_total: int = 0


class Graph:
    def __init__(self, input_shape: Tuple[int, int, int], name: str):
        """input_shape = (H, W, C) without the batch dim."""
        self.name = name
        self.nodes: List[Node] = []
        self.input_shape = input_shape
        n = Node(nid=0, kind="input", inputs=[], name="input", out_shape=input_shape)
        self.nodes.append(n)

    def _push(self, node: Node) -> int:
        node.nid = len(self.nodes)
        self.nodes.append(node)
        return node.nid

    def conv(
        self,
        src: int,
        cout: int,
        ksize: int,
        stride: int = 1,
        groups: int = 1,
        act: str = "relu",
        has_bn: bool = True,
        name: str = "",
    ) -> int:
        h, w, cin = self.nodes[src].out_shape
        pad = (ksize - 1) // 2
        oh = (h + 2 * pad - ksize) // stride + 1
        ow = (w + 2 * pad - ksize) // stride + 1
        k_fanin = ksize * ksize * (cin // groups)
        node = Node(
            nid=-1,
            kind="conv",
            inputs=[src],
            name=name or f"conv{len(self.nodes)}",
            cin=cin,
            cout=cout,
            ksize=ksize,
            stride=stride,
            pad=pad,
            groups=groups,
            has_bn=has_bn,
            act=act,
            out_shape=(oh, ow, cout),
            macs_per_out=k_fanin,
            macs_total=oh * ow * cout * k_fanin,
        )
        return self._push(node)

    def dense(self, src: int, cout: int, act: str = "none", has_bn: bool = False, name: str = "") -> int:
        shape = self.nodes[src].out_shape
        cin = int(_prod(shape))
        node = Node(
            nid=-1,
            kind="dense",
            inputs=[src],
            name=name or f"dense{len(self.nodes)}",
            cin=cin,
            cout=cout,
            has_bn=has_bn,
            act=act,
            out_shape=(cout,),
            macs_per_out=cin,
            macs_total=cin * cout,
        )
        return self._push(node)

    def add(self, a: int, b: int, act: str = "none", name: str = "") -> int:
        assert self.nodes[a].out_shape == self.nodes[b].out_shape, (
            self.nodes[a].out_shape,
            self.nodes[b].out_shape,
        )
        node = Node(
            nid=-1,
            kind="add",
            inputs=[a, b],
            name=name or f"add{len(self.nodes)}",
            act=act,
            out_shape=self.nodes[a].out_shape,
        )
        return self._push(node)

    def gap(self, src: int, name: str = "") -> int:
        h, w, c = self.nodes[src].out_shape
        node = Node(
            nid=-1,
            kind="gap",
            inputs=[src],
            name=name or "gap",
            out_shape=(c,),
        )
        return self._push(node)

    def output(self, src: int) -> int:
        node = Node(nid=-1, kind="output", inputs=[src], name="output", out_shape=self.nodes[src].out_shape)
        return self._push(node)

    # ------------------------------------------------------------------
    def approx_layers(self) -> List[Node]:
        """The l layers the mapping problem assigns multipliers to."""
        return [n for n in self.nodes if n.kind in ("conv", "dense")]

    def total_macs(self) -> int:
        return sum(n.macs_total for n in self.approx_layers())

    def to_json(self, qmeta: Optional[Dict[str, dict]] = None) -> dict:
        nodes = []
        for n in self.nodes:
            d = {
                "id": n.nid,
                "kind": n.kind,
                "inputs": n.inputs,
                "name": n.name,
                "out_shape": list(n.out_shape),
            }
            if n.kind in ("conv", "dense"):
                d.update(
                    cin=n.cin,
                    cout=n.cout,
                    ksize=n.ksize,
                    stride=n.stride,
                    pad=n.pad,
                    groups=n.groups,
                    has_bn=n.has_bn,
                    act=n.act,
                    macs_per_out=n.macs_per_out,
                    macs_total=n.macs_total,
                )
                if qmeta and n.name in qmeta:
                    d["quant"] = qmeta[n.name]
            if n.kind == "add":
                d["act"] = n.act
            nodes.append(d)
        return {
            "name": self.name,
            "input_shape": list(self.input_shape),
            "nodes": nodes,
            "n_approx_layers": len(self.approx_layers()),
            "total_macs": self.total_macs(),
        }


def _prod(t) -> int:
    out = 1
    for v in t:
        out *= int(v)
    return out
