"""Model zoo: CIFAR-style ResNet-{8,14,20,32} and MobileNetV2.

Topologies follow the paper's experimental setup:

  * ResNet-N (N = 6n + 2) with the standard CIFAR three-stage layout
    [He et al. 2016]; all convolutions and the final classifier are
    approximable layers.
  * MobileNetV2 [Sandler et al. 2018] with the stem stride reduced to 1
    (the paper's TinyImageNet adaptation for 64x64 inputs).  With the
    standard 17 inverted-residual blocks this yields exactly the paper's
    **53 approximable target layers** (stem + 50 block convs + head conv
    + classifier).

A ``width`` multiplier scales channel counts so the models train in
CPU-minutes on the synthetic datasets (see DESIGN.md substitutions);
``width=1.0`` reproduces the full architectures.
"""

from __future__ import annotations

from .graph import Graph


def _c(ch: int, width: float, divisor: int = 8) -> int:
    """MobileNet-style divisible channel rounding."""
    v = max(divisor, int(ch * width + divisor / 2) // divisor * divisor)
    if v < 0.9 * ch * width:
        v += divisor
    return v


def resnet(depth: int, num_classes: int, input_hw: int = 32, width: float = 1.0) -> Graph:
    assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6n+2"
    n = (depth - 2) // 6
    g = Graph((input_hw, input_hw, 3), name=f"resnet{depth}")
    w16, w32, w64 = _c(16, width), _c(32, width), _c(64, width)

    x = g.conv(0, w16, 3, name="stem")
    for stage, (ch, stride0) in enumerate([(w16, 1), (w32, 2), (w64, 2)]):
        for blk in range(n):
            stride = stride0 if blk == 0 else 1
            pre = x
            y = g.conv(x, ch, 3, stride=stride, name=f"s{stage}b{blk}c1")
            y = g.conv(y, ch, 3, act="none", name=f"s{stage}b{blk}c2")
            if stride != 1 or g.nodes[pre].out_shape[-1] != ch:
                pre = g.conv(pre, ch, 1, stride=stride, act="none", name=f"s{stage}b{blk}proj")
            x = g.add(y, pre, act="relu", name=f"s{stage}b{blk}add")
    x = g.gap(x)
    x = g.dense(x, num_classes, name="fc")
    g.output(x)
    return g


# MobileNetV2 inverted-residual config: (expansion t, channels c, repeats n, stride s)
_MBV2_CFG = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def mobilenet_v2(num_classes: int, input_hw: int = 64, width: float = 1.0, stem_stride: int = 1) -> Graph:
    g = Graph((input_hw, input_hw, 3), name="mobilenet_v2")
    ch_in = _c(32, width)
    x = g.conv(0, ch_in, 3, stride=stem_stride, act="relu6", name="stem")

    blk = 0
    for t, c, n, s in _MBV2_CFG:
        cout = _c(c, width)
        for i in range(n):
            stride = s if i == 0 else 1
            cin = g.nodes[x].out_shape[-1]
            hidden = cin * t
            pre = x
            y = x
            if t != 1:
                y = g.conv(y, hidden, 1, act="relu6", name=f"b{blk}expand")
            y = g.conv(y, hidden, 3, stride=stride, groups=hidden, act="relu6", name=f"b{blk}dw")
            y = g.conv(y, cout, 1, act="none", name=f"b{blk}project")
            if stride == 1 and cin == cout:
                y = g.add(y, pre, name=f"b{blk}add")
            x = y
            blk += 1

    head = _c(1280, width) if width > 1.0 else max(_c(1280, width), 1280 if width >= 1.0 else _c(1280, width))
    x = g.conv(x, head, 1, act="relu6", name="head")
    x = g.gap(x)
    x = g.dense(x, num_classes, name="fc")
    g.output(x)
    return g


_ZOO = {
    "resnet8": lambda nc, hw, w: resnet(8, nc, hw, w),
    "resnet14": lambda nc, hw, w: resnet(14, nc, hw, w),
    "resnet20": lambda nc, hw, w: resnet(20, nc, hw, w),
    "resnet32": lambda nc, hw, w: resnet(32, nc, hw, w),
    "mobilenet_v2": lambda nc, hw, w: mobilenet_v2(nc, hw, w),
}


def build(name: str, num_classes: int, input_hw: int, width: float = 1.0) -> Graph:
    if name not in _ZOO:
        raise KeyError(f"unknown model {name!r}; have {sorted(_ZOO)}")
    return _ZOO[name](num_classes, input_hw, width)
