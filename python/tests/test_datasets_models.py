"""Dataset determinism + model-zoo topology invariants."""

import numpy as np
import pytest

from compile import datasets, models


def test_dataset_deterministic():
    a_x, a_y = datasets.generate("microcifar", "test")
    b_x, b_y = datasets.generate("microcifar", "test")
    np.testing.assert_array_equal(a_x, b_x)
    np.testing.assert_array_equal(a_y, b_y)


def test_dataset_shapes_and_ranges():
    spec = datasets.SPECS["microcifar"]
    x, y = datasets.generate("microcifar", "train")
    assert x.shape == (spec.n_train, spec.hw, spec.hw, 3)
    assert x.dtype == np.float32
    assert 0.0 <= x.min() and x.max() <= 1.0
    assert set(np.unique(y)) == set(range(spec.num_classes))


def test_train_test_disjoint_generation():
    tr, _ = datasets.generate("microcifar", "train")
    te, _ = datasets.generate("microcifar", "test")
    # different split salt -> different pixels
    assert not np.array_equal(tr[: len(te)], te)


def test_classes_are_distinguishable():
    """Nearest-class-mean on raw pixels beats chance by a wide margin —
    the datasets must be learnable for the paper's experiments to work."""
    x, y = datasets.generate("microcifar", "train")
    te_x, te_y = datasets.generate("microcifar", "test")
    n_cls = 10
    means = np.stack([x[y == c].mean(axis=0).ravel() for c in range(n_cls)])
    preds = []
    for img in te_x[:200]:
        d = ((means - img.ravel()) ** 2).sum(axis=1)
        preds.append(np.argmin(d))
    acc = (np.asarray(preds) == te_y[:200]).mean()
    assert acc > 0.3, f"nearest-mean accuracy {acc}"


def test_augment_preserves_shape_and_range():
    x, _ = datasets.generate("microcifar", "test")
    out = datasets.augment(x[:32], np.random.default_rng(0))
    assert out.shape == x[:32].shape
    assert 0.0 <= out.min() and out.max() <= 1.0


@pytest.mark.parametrize(
    "depth,layers", [(8, 10), (14, 16), (20, 22), (32, 34)]
)
def test_resnet_layer_counts(depth, layers):
    g = models.resnet(depth, 10, 32)
    assert len(g.approx_layers()) == layers
    assert g.nodes[-1].kind == "output"


def test_mobilenet_v2_has_53_target_layers():
    """The paper's Fig. 3 shows 53 MobileNetV2 target layers."""
    g = models.mobilenet_v2(200, 64, width=0.5)
    assert len(g.approx_layers()) == 53


def test_mobilenet_depthwise_groups():
    g = models.mobilenet_v2(10, 32, width=0.5)
    dw = [n for n in g.approx_layers() if n.groups > 1]
    assert dw, "expected depthwise layers"
    for n in dw:
        assert n.groups == n.cin == n.cout


def test_graph_shape_inference_consistency():
    g = models.resnet(8, 10, 32)
    for n in g.approx_layers():
        if n.kind == "conv":
            assert n.macs_total == int(np.prod(n.out_shape)) * n.macs_per_out


def test_graph_json_roundtrip_fields():
    g = models.resnet(8, 10, 32)
    j = g.to_json()
    assert j["n_approx_layers"] == 10
    assert j["total_macs"] == g.total_macs()
    kinds = {n["kind"] for n in j["nodes"]}
    assert kinds == {"input", "conv", "dense", "add", "gap", "output"}
