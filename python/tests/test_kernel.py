"""L1 kernel correctness: Pallas LUT matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes / block sizes / multiplier instances; every case
must be **bit-exact** against ref.py (the kernel computes integers).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import muldb
from compile.kernels import lut_matmul as lm
from compile.kernels import ref

FAMILY = muldb.build_family()
_LUT_CACHE = {}


def lut_for(mid: int) -> np.ndarray:
    if mid not in _LUT_CACHE:
        _LUT_CACHE[mid] = muldb.build_lut(FAMILY[mid])
    return _LUT_CACHE[mid]


def rand_codes(rng, shape):
    return rng.integers(0, 256, size=shape, dtype=np.int64)


@settings(max_examples=25, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    k=st.integers(1, 96),
    mid=st.integers(0, len(FAMILY) - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_matmul_matches_ref(bm, bn, mt, nt, k, mid, seed):
    rng = np.random.default_rng(seed)
    m, n = bm * mt, bn * nt
    a = rand_codes(rng, (m, k))
    w = rand_codes(rng, (k, n))
    lut = lut_for(mid)
    out = lm.lut_matmul(jnp.asarray(a), jnp.asarray(w), jnp.asarray(lut), bm=bm, bn=bn)
    exp = ref.lut_matmul_ref(jnp.asarray(a), jnp.asarray(w), jnp.asarray(lut))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(1, 64),
    mid=st.integers(0, len(FAMILY) - 1),
    za=st.integers(0, 255),
    zw=st.integers(0, 255),
    zo=st.integers(0, 255),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_matmul_requant_matches_ref(k, mid, za, zw, zo, seed):
    rng = np.random.default_rng(seed)
    a = rand_codes(rng, (32, k))
    w = rand_codes(rng, (k, 32))
    lut = lut_for(mid)
    scale = float(rng.uniform(1e-6, 1e-3))
    out = lm.lut_matmul_requant(jnp.asarray(a), jnp.asarray(w), jnp.asarray(lut), scale, za, zw, zo)
    exp = ref.lut_matmul_requant_ref(jnp.asarray(a), jnp.asarray(w), jnp.asarray(lut), scale, za, zw, zo)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_exact_lut_equals_integer_matmul():
    rng = np.random.default_rng(7)
    a = rand_codes(rng, (64, 80))
    w = rand_codes(rng, (80, 64))
    out = lm.lut_matmul(jnp.asarray(a), jnp.asarray(w), jnp.asarray(muldb.exact_lut()))
    np.testing.assert_array_equal(np.asarray(out), a @ w)


def test_zero_point_correction_identity():
    """With the exact LUT, the corrected accumulation equals the
    zero-point-shifted integer matmul — the numeric contract the whole
    quantized pipeline relies on."""
    rng = np.random.default_rng(11)
    a = rand_codes(rng, (32, 40))
    w = rand_codes(rng, (40, 32))
    za, zw = 131, 117
    acc = np.asarray(ref.lut_matmul_ref(jnp.asarray(a), jnp.asarray(w), jnp.asarray(muldb.exact_lut())))
    corr = acc - za * w.sum(axis=0)[None, :] - zw * a.sum(axis=1)[:, None] + 40 * za * zw
    direct = (a - za) @ (w - zw)
    np.testing.assert_array_equal(corr, direct)


@pytest.mark.parametrize("mid", [0, 9, 19, 23, 30])
def test_kernel_constant_operands(mid):
    """Degenerate inputs: all-zero and all-max codes."""
    lut = lut_for(mid)
    for val in (0, 255):
        a = np.full((16, 8), val, dtype=np.int64)
        w = np.full((8, 16), val, dtype=np.int64)
        out = lm.lut_matmul(jnp.asarray(a), jnp.asarray(w), jnp.asarray(lut), bm=16, bn=16)
        assert (np.asarray(out) == 8 * int(lut[val, val])).all()


def test_vmem_budget_default_blocks():
    fp = lm.vmem_footprint_bytes(lm.DEFAULT_BM, lm.DEFAULT_BN, 1152)
    assert fp["fits_16MiB_vmem"], fp
