"""Multiplier-family invariants + the cross-language golden digest."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import muldb

FAMILY = muldb.build_family()

# Golden SHA-256 of the serialized LUT stack.  The Rust generator
# (rust/src/muldb) asserts the same value: if either side's behavioural
# definitions drift, both this test and the Rust test fail.
GOLDEN_DIGEST = "351117ce8837aa4c469a02f8a2c6d5f6a3a9aab0cba8f4c4c29d05926d27c723"


def test_family_size_and_ids():
    assert len(FAMILY) == 37
    assert [s.mid for s in FAMILY] == list(range(37))
    assert FAMILY[0].technique == "exact"
    names = [s.name for s in FAMILY]
    assert len(set(names)) == 37


def test_digest_golden():
    assert muldb.family_digest(muldb.lut_stack(FAMILY)) == GOLDEN_DIGEST


def test_power_model_bounds():
    for s in FAMILY:
        assert 0.0 < s.power <= 1.0, s.name
    assert FAMILY[0].power == 1.0


@settings(max_examples=60, deadline=None)
@given(a=st.integers(0, 255), b=st.integers(0, 255), mid=st.integers(0, 36))
def test_scalar_functions_nonnegative_and_bounded(a, b, mid):
    v = FAMILY[mid].fn()(a, b)
    assert v >= 0
    # bounded by max exact product + worst constant compensation
    assert v <= 255 * 255 + 70000


@settings(max_examples=40, deadline=None)
@given(a=st.integers(0, 255), b=st.integers(0, 255))
def test_trunc_is_lower_bound(a, b):
    for k in (1, 2, 3, 4):
        assert muldb.mul_trunc_op(a, b, k) <= a * b


@settings(max_examples=40, deadline=None)
@given(a=st.integers(0, 255), b=st.integers(0, 255))
def test_bam_monotone_in_h(a, b):
    prev = a * b
    for h in range(3, 11):
        v = muldb.mul_bam(a, b, h)
        assert v <= prev + 1e-9  # dropping more PP bits can only decrease
        prev = v


@settings(max_examples=40, deadline=None)
@given(a=st.integers(1, 255), b=st.integers(1, 255))
def test_drum_relative_error_bounded(a, b):
    # DRUM-k relative error is bounded by ~2^-(k-1) per operand
    for k in (4, 5, 6):
        v = muldb.mul_drum(a, b, k)
        rel = abs(v - a * b) / (a * b)
        assert rel <= 2.0 ** (-(k - 1)) * 2.5, (k, a, b, v)


@settings(max_examples=40, deadline=None)
@given(a=st.integers(0, 255), b=st.integers(0, 255))
def test_mitchell_underestimates(a, b):
    # Mitchell's approximation never overestimates the product
    assert muldb.mul_mitchell(a, b, 7) <= a * b


def test_zero_operand_maps_to_zero():
    for s in FAMILY:
        if s.technique in ("bamc", "otruncc", "loa"):
            continue  # constant compensation / OR-block shift zero
        fn = s.fn()
        assert fn(0, 0) == 0, s.name
        assert fn(0, 137) == 0, s.name


def test_error_stats_match_lut():
    lut = muldb.build_lut(FAMILY[7])  # bam5
    st_ = muldb.error_stats(lut)
    err = muldb.error_map(lut)
    assert st_["mean"] == pytest.approx(err.mean())
    assert st_["std"] == pytest.approx(err.std())


def test_lowrank_reconstruction_bam_exact():
    """BAM error maps are exactly low-rank (sum of <=8 bit outer products)."""
    for mid in (5, 9, 12):  # bam instances
        lut = muldb.build_lut(FAMILY[mid])
        U, V = muldb.lowrank_error(lut, rank=8)
        err = muldb.error_map(lut)
        rec = U.astype(np.float64) @ V.astype(np.float64).T
        rel = np.linalg.norm(err - rec) / max(np.linalg.norm(err), 1e-12)
        assert rel < 1e-5, (FAMILY[mid].name, rel)


def test_serialize_header():
    stack = muldb.lut_stack(FAMILY[:2] + FAMILY[2:3])
    blob = muldb.serialize_luts(stack)
    assert blob[:4] == b"QLUT"
    assert int.from_bytes(blob[4:8], "little") == 3
    assert int.from_bytes(blob[8:12], "little") == 65536
