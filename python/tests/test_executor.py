"""Executor semantics: mode equivalences + the low-rank error surrogate
against a direct LUT evaluation (the L2 <-> engine contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, muldb, quant
from compile.executor import RunConfig, bn_param_count, forward, init_params, num_params
from compile.graph import Graph

FAMILY = muldb.build_family()


def tiny_graph():
    g = Graph((8, 8, 3), name="tiny")
    x = g.conv(0, 8, 3, name="c1")
    x = g.conv(x, 8, 3, stride=2, name="c2")
    x = g.gap(x)
    x = g.dense(x, 5, name="fc")
    g.output(x)
    return g


def quant_meta_for(graph, params, scale_in=0.05):
    return {
        n.name: {
            "in": quant.QParams(scale_in, 128),
            "w": quant.weight_qparams(np.asarray(params[n.name]["w"])),
        }
        for n in graph.approx_layers()
    }


def test_float_and_qat_shapes():
    g = tiny_graph()
    p = init_params(g, 0)
    x = jnp.asarray(np.random.default_rng(0).random((2, 8, 8, 3)), jnp.float32)
    logits, _ = forward(g, p, x, RunConfig(mode="float", bn_train=True))
    assert logits.shape == (2, 5)
    qm = quant_meta_for(g, p)
    logits2, _ = forward(g, p, x, RunConfig(mode="qat", quant=qm))
    assert logits2.shape == (2, 5)


def test_exact_uv_is_identity():
    """Zero U/V tables must reproduce the plain QAT forward exactly."""
    g = tiny_graph()
    p = init_params(g, 1)
    qm = quant_meta_for(g, p)
    x = jnp.asarray(np.random.default_rng(1).random((2, 8, 8, 3)), jnp.float32)
    base, _ = forward(g, p, x, RunConfig(mode="qat", quant=qm))
    uv = {
        n.name: (jnp.zeros((256, 4), jnp.float32), jnp.zeros((256, 4), jnp.float32))
        for n in g.approx_layers()
    }
    approx, _ = forward(g, p, x, RunConfig(mode="approx", quant=qm, uv=uv))
    np.testing.assert_allclose(np.asarray(base), np.asarray(approx), atol=1e-5)


def lut_layer_reference(x, w, qp_in, qp_w, lut):
    """Direct dense-layer LUT evaluation: s_a*s_w * (corrected acc)."""
    a = np.clip(np.round(np.asarray(x) / qp_in.scale) + qp_in.zero_point, 0, 255).astype(np.int64)
    wq = np.clip(np.round(np.asarray(w) / qp_w.scale) + qp_w.zero_point, 0, 255).astype(np.int64)
    acc = lut[a[:, :, None], wq[None, :, :]].sum(axis=1)
    k = a.shape[1]
    corr = (
        acc
        - qp_in.zero_point * wq.sum(axis=0)[None, :]
        - qp_w.zero_point * a.sum(axis=1)[:, None]
        + k * qp_in.zero_point * qp_w.zero_point
    )
    return qp_in.scale * qp_w.scale * corr


@pytest.mark.parametrize("mid", [7, 9, 19, 26])  # low-rank-friendly instances
def test_surrogate_matches_direct_lut_dense(mid):
    """For exactly-low-rank multipliers the surrogate dense layer equals a
    direct LUT evaluation (up to f32 arithmetic)."""
    g = Graph((4,), name="d")
    d = g.dense(0, 6, name="fc", has_bn=False)
    g.output(d)
    rng = np.random.default_rng(mid)
    p = {"fc": {"w": jnp.asarray(rng.normal(0, 0.4, (4, 6)), jnp.float32), "b": jnp.zeros(6, jnp.float32)}}
    qm = quant_meta_for(g, p, scale_in=0.02)
    lut = muldb.build_lut(FAMILY[mid])
    U, V = muldb.lowrank_error(lut, rank=8)
    uv = {"fc": (jnp.asarray(U), jnp.asarray(V))}
    x = jnp.asarray(rng.uniform(-1, 1, (16, 4)), jnp.float32)
    out, _ = forward(g, p, x, RunConfig(mode="approx", quant=qm, uv=uv))
    expect = lut_layer_reference(x, p["fc"]["w"], qm["fc"]["in"], qm["fc"]["w"], lut.astype(np.int64))
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-4, atol=2e-3)


def test_residual_noise_changes_output_only_with_rng():
    g = tiny_graph()
    p = init_params(g, 2)
    qm = quant_meta_for(g, p)
    lut = muldb.build_lut(FAMILY[9])
    U, V = muldb.lowrank_error(lut, 8)
    uv = {n.name: (jnp.asarray(U), jnp.asarray(V)) for n in g.approx_layers()}
    noise = {n.name: 0.5 for n in g.approx_layers()}
    x = jnp.asarray(np.random.default_rng(2).random((2, 8, 8, 3)), jnp.float32)
    cfg = RunConfig(mode="approx", quant=qm, uv=uv, res_noise=noise)
    a, _ = forward(g, p, x, cfg)  # no rng: deterministic
    b, _ = forward(g, p, x, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c, _ = forward(g, p, x, cfg, rng=jax.random.PRNGKey(0))
    assert float(jnp.abs(c - a).max()) > 0.0


def test_param_counting():
    g = models.resnet(8, 10, 32, width=1.0)
    p = init_params(g)
    # full ResNet8 ~78k params (conv + bn + fc)
    assert 70_000 < num_params(p) < 90_000
    overlay = bn_param_count(g)
    # BN overlay is a small fraction (paper: ~2-3%)
    assert overlay / num_params(p) < 0.03


def test_agn_mode_perturbs():
    g = tiny_graph()
    p = init_params(g, 3)
    qm = quant_meta_for(g, p)
    x = jnp.asarray(np.random.default_rng(3).random((2, 8, 8, 3)), jnp.float32)
    base, _ = forward(g, p, x, RunConfig(mode="qat", quant=qm))
    noisy, _ = forward(
        g, p, x,
        RunConfig(mode="agn", quant=qm, sigma=jnp.full((3,), 0.2), rng=jax.random.PRNGKey(1)),
    )
    assert float(jnp.abs(noisy - base).max()) > 0.0
