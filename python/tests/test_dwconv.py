"""Depthwise LUT-conv kernel vs its oracle and vs lax depthwise conv."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import muldb
from compile.kernels import lut_dwconv as dw

FAMILY = muldb.build_family()


@settings(max_examples=15, deadline=None)
@given(
    bm=st.sampled_from([16, 64]),
    tiles=st.integers(1, 3),
    taps=st.sampled_from([1, 9]),
    c=st.integers(1, 16),
    mid=st.integers(0, 36),
    seed=st.integers(0, 2**31 - 1),
)
def test_dwconv_matches_ref(bm, tiles, taps, c, mid, seed):
    rng = np.random.default_rng(seed)
    m = bm * tiles
    patches = rng.integers(0, 256, (m, taps, c))
    w = rng.integers(0, 256, (taps, c))
    lut = muldb.build_lut(FAMILY[mid])
    out = dw.lut_dwconv(jnp.asarray(patches), jnp.asarray(w), jnp.asarray(lut), bm=bm)
    exp = dw.dwconv_ref(jnp.asarray(patches), jnp.asarray(w), jnp.asarray(lut))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_exact_dwconv_equals_lax_conv():
    """With the exact LUT and zero-point-corrected codes the kernel must
    reproduce a depthwise lax.conv on the dequantized values."""
    rng = np.random.default_rng(0)
    b, hw, c, k = 2, 8, 4, 3
    za, zw = 128, 120
    codes = rng.integers(0, 256, (b, hw, hw, c))
    wcodes = rng.integers(0, 256, (k * k, c))

    patches = dw.extract_patches(jnp.asarray(codes), hw, c, k, 1, 1, za)
    acc = np.asarray(dw.lut_dwconv(patches, jnp.asarray(wcodes), jnp.asarray(muldb.exact_lut())))
    # corrections: acc - za*SW_c - zw*SA - taps*za*zw per output element
    sw = wcodes.sum(axis=0)
    sa = np.asarray(patches).sum(axis=1)
    corr = acc - za * sw[None, :] - zw * sa + k * k * za * zw

    x = (codes - za).astype(np.float32)
    w = (wcodes - zw).astype(np.float32).reshape(k, k, 1, c)
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(x),
        jnp.asarray(w),
        (1, 1),
        [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    np.testing.assert_allclose(corr.reshape(b, hw, hw, c), np.asarray(ref), atol=0.5)


def test_extract_patches_padding_uses_zero_point():
    codes = jnp.zeros((1, 4, 4, 2), jnp.int32) + 7
    patches = dw.extract_patches(codes, 4, 2, 3, 1, 1, 99)
    p = np.asarray(patches).reshape(4, 4, 9, 2)
    # top-left output's top-left tap is padding
    assert (p[0, 0, 0] == 99).all()
    # center taps are real values
    assert (p[1, 1, 4] == 7).all()
