"""Quantization semantics: the numeric contract both engines rely on."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant


@settings(max_examples=50, deadline=None)
@given(lo=st.floats(-100, 0), hi=st.floats(0.01, 100))
def test_qparams_cover_range(lo, hi):
    qp = quant.QParams.from_range(lo, hi)
    assert 0 <= qp.zero_point <= 255
    assert qp.scale > 0
    # zero is exactly representable
    zero = (qp.zero_point - qp.zero_point) * qp.scale
    assert zero == 0.0


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-4, 1.0))
def test_fake_quant_roundtrip_error_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    qp = quant.QParams(scale=scale, zero_point=128)
    x = jnp.asarray(rng.uniform(-100 * scale, 100 * scale, size=64), jnp.float32)
    y = np.asarray(quant.fake_quant(x, qp))
    assert np.max(np.abs(y - np.asarray(x))) <= scale / 2 + 1e-6


def test_fake_quant_clips_to_range():
    qp = quant.QParams(scale=0.1, zero_point=128)
    x = jnp.asarray([1e6, -1e6], jnp.float32)
    y = np.asarray(quant.fake_quant(x, qp))
    np.testing.assert_allclose(y[0], (255 - 128) * 0.1, rtol=1e-2)
    np.testing.assert_allclose(y[1], (0 - 128) * 0.1, rtol=1e-2)


def test_fake_quant_ste_gradient_is_identity():
    qp = quant.QParams(scale=0.05, zero_point=128)
    g = jax.grad(lambda x: jnp.sum(quant.fake_quant(x, qp)))(jnp.asarray([0.3, -0.7]))
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0])


def test_codes_ste_forward_integral():
    qp = quant.QParams(scale=0.05, zero_point=100)
    x = jnp.asarray([0.0, 0.12, -0.3, 100.0], jnp.float32)
    codes = np.asarray(quant.codes_ste(x, qp))
    assert np.all(codes == np.round(codes))
    assert codes.min() >= 0 and codes.max() <= 255
    assert codes[0] == 100  # zero maps to the zero point


def test_weight_qparams_cover_extremes():
    w = np.asarray([-0.8, 0.0, 0.4], np.float32)
    qp = quant.weight_qparams(w)
    codes = np.clip(np.round(w / qp.scale) + qp.zero_point, 0, 255)
    deq = (codes - qp.zero_point) * qp.scale
    assert np.max(np.abs(deq - w)) <= qp.scale / 2 + 1e-7


def test_ema_range_tracks():
    ema = quant.EmaRange(decay=0.5)
    ema.update(np.asarray([0.0, 1.0]))
    ema.update(np.asarray([-1.0, 3.0]))
    qp = ema.qparams()
    assert qp.scale > 0
    # second update pulls the range toward [-1, 3]
    assert ema.lo < 0.0 and ema.hi > 1.0
