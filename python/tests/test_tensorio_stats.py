"""QTEN container + layer statistics / error-model reference."""

import numpy as np
import pytest

from compile import muldb, stats, tensorio


def test_qten_roundtrip(tmp_path):
    path = str(tmp_path / "t.qten")
    tensors = {
        "a.w": np.random.default_rng(0).normal(size=(3, 3, 2, 4)).astype(np.float32),
        "labels": np.asarray([1, 2, 3], np.int32),
        "codes": np.asarray([0, 128, 255], np.uint8),
    }
    tensorio.save(path, tensors)
    out = tensorio.load(path)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_qten_f64_i64_coercion(tmp_path):
    path = str(tmp_path / "c.qten")
    tensorio.save(path, {"x": np.asarray([1.5], np.float64), "y": np.asarray([7], np.int64)})
    out = tensorio.load(path)
    assert out["x"].dtype == np.float32
    assert out["y"].dtype == np.int32


def test_qten_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.qten"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        tensorio.load(str(p))


def test_sigma_e_reference_uniform_matches_closed_form():
    """Under uniform operand histograms the reference model must agree
    with the LUT's global error statistics."""
    fam = muldb.build_family()
    lut = muldb.build_lut(fam[9])
    err = muldb.error_map(lut)
    st = {
        "l0": {
            "act_hist": [1 / 256] * 256,
            "w_hist": [1 / 256] * 256,
            "k_fanin": 144,
            "s_act": 0.01,
            "s_w": 0.02,
            "bn_scale": 0.5,
        }
    }
    out = stats.sigma_e_reference(st, err, bias_residual=0.0)
    expect = np.sqrt(144 * err.var()) * 0.01 * 0.02 * 0.5
    assert out["l0"] == pytest.approx(expect, rel=1e-9)
    # with the residual-bias term the estimate can only grow
    out_bias = stats.sigma_e_reference(st, err)
    assert out_bias["l0"] >= out["l0"]


def test_sigma_e_reference_exact_is_zero():
    err = np.zeros((256, 256))
    st = {
        "l0": {
            "act_hist": [1 / 256] * 256,
            "w_hist": [1 / 256] * 256,
            "k_fanin": 100,
            "s_act": 1.0,
            "s_w": 1.0,
            "bn_scale": 1.0,
        }
    }
    assert stats.sigma_e_reference(st, err)["l0"] == 0.0
