//! Quickstart: the whole QoS-Nets flow on the `quick` artifacts.
//!
//!   make artifacts && cargo build --release
//!   cargo run --release --example quickstart
//!
//! Loads the exported experiment, runs the constrained multi-operating-
//! point search, evaluates every operating point with the bit-exact LUT
//! engine and prints a paper-style summary.

use std::sync::Arc;

use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::plan;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let exp = Experiment::load(&artifacts, "quick")?;
    let db = Arc::new(MulDb::load(&artifacts)?);

    println!("experiment: {} ({} approximable layers)", exp.name, exp.layer_names.len());
    println!("search space: {} multipliers, n = {}, scales = {:?}", db.len(), exp.n_multipliers(), exp.scales());

    // 1. constrained multi-OP search through the unified Planner API
    //    (error model -> preference vectors -> k-means -> per-centroid
    //    multiplier pick); any registered --algo goes through this path
    let plan = plan::plan_experiment("qos", &exp, &db)?;
    plan.save_for(&exp)?;
    println!("\nselected subset:");
    for m in &plan.subset {
        println!("  {} (relative power {:.3})", m.name, m.power);
    }

    // 2. evaluate the exact baseline + every operating point
    let exact = pipeline::exact_operating_point(&exp)?;
    let base = pipeline::eval_operating_point(&exp, &db, &exact, 32, Some(256))?;
    println!("\n8-bit baseline (exact multipliers): top1 {:.2}%", 100.0 * base.top1);

    // the same plan -> OperatingPoint handoff eval/serve use ("bn"
    // picks up the stage-B overlays when they exist)
    for (op, pop) in plan.load_operating_points(&exp, "bn")?.iter().zip(&plan.ops) {
        let r = pipeline::eval_operating_point(&exp, &db, op, 32, Some(256))?;
        println!(
            "{}: multiplication power {:.1}% | top1 {:.2}% ({:+.2}pp vs baseline) [scale {:.2}]",
            pop.name,
            100.0 * pop.relative_power,
            100.0 * r.top1,
            100.0 * (r.top1 - base.top1),
            pop.scale,
        );
    }
    println!("\n(run `python -m compile.aot retrain --exp quick` for the BN overlays)");
    Ok(())
}
