//! Quickstart: the whole QoS-Nets flow on the `quick` artifacts.
//!
//!   make artifacts && cargo build --release
//!   cargo run --release --example quickstart
//!
//! Loads the exported experiment, runs the constrained multi-operating-
//! point search, evaluates every operating point with the bit-exact LUT
//! engine and prints a paper-style summary.

use std::sync::Arc;

use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let exp = Experiment::load(&artifacts, "quick")?;
    let db = Arc::new(MulDb::load(&artifacts)?);

    println!("experiment: {} ({} approximable layers)", exp.name, exp.layer_names.len());
    println!("search space: {} multipliers, n = {}, scales = {:?}", db.len(), exp.n_multipliers(), exp.scales());

    // 1. constrained multi-OP search (error model -> preference vectors
    //    -> k-means -> per-centroid multiplier pick)
    let (_, sol) = pipeline::run_search(&exp, &db);
    pipeline::write_assignment(&exp, &db, &sol)?;
    println!("\nselected subset:");
    for &mid in &sol.subset {
        println!("  {} (relative power {:.3})", db.specs[mid].name, db.power(mid));
    }

    // 2. evaluate the exact baseline + every operating point
    let exact = pipeline::exact_operating_point(&exp)?;
    let base = pipeline::eval_operating_point(&exp, &db, &exact, 32, Some(256))?;
    println!("\n8-bit baseline (exact multipliers): top1 {:.2}%", 100.0 * base.top1);

    for (i, assignment) in sol.assignment.iter().enumerate() {
        let amap = exp
            .layer_names
            .iter()
            .cloned()
            .zip(assignment.iter().cloned())
            .collect();
        // use the BN-tuned overlay when stage B has produced one
        let overlay = exp.dir.join(format!("bn_op{i}.qten"));
        let op = pipeline::build_operating_point(
            &exp,
            &format!("op{i}"),
            amap,
            sol.power[i],
            overlay.exists().then_some(overlay.as_path()),
        )?;
        let r = pipeline::eval_operating_point(&exp, &db, &op, 32, Some(256))?;
        println!(
            "OP{i}: multiplication power {:.1}% | top1 {:.2}% ({:+.2}pp vs baseline){}",
            100.0 * sol.power[i],
            100.0 * r.top1,
            100.0 * (r.top1 - base.top1),
            if overlay.exists() { " [BN-tuned]" } else { " [no retraining]" },
        );
    }
    println!("\n(run `python -m compile.aot retrain --exp quick` for the BN overlays)");
    Ok(())
}
