//! Pareto sweep: how the subset size n and the operating-point scale set
//! shape the accuracy/power trade-off (the design space behind paper
//! Secs. 3.1-3.2).
//!
//!   cargo run --release --example pareto_sweep -- [exp]
//!
//! Uses the error model as the quality proxy (no retraining), so the
//! sweep runs in milliseconds and prints the predicted Pareto table.

use std::sync::Arc;

use qos_nets::baselines::quality_penalty;
use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::Experiment;
use qos_nets::selection::{search, SearchConfig};

fn main() -> anyhow::Result<()> {
    let exp_name = std::env::args().nth(1).unwrap_or_else(|| "quick".into());
    let exp = Experiment::load("artifacts", &exp_name)?;
    let db = Arc::new(MulDb::load("artifacts")?);
    let se = errmodel::sigma_e(&db, &exp.stats);

    println!("# n-constraint sweep (single operating point, scale 1.0)");
    println!("{:>3} {:>10} {:>10} {:>8} {:>9}", "n", "power", "penalty", "#AMs", "inertia");
    for n in 1..=8 {
        let cfg = SearchConfig {
            n_multipliers: n,
            scales: vec![1.0],
            seed: exp.seed(),
            restarts: 8,
        };
        let sol = search(&db, &se, &exp.sigma_g, &exp.stats, &cfg);
        println!(
            "{:>3} {:>9.2}% {:>10.4} {:>8} {:>9.3}",
            n,
            100.0 * sol.power[0],
            quality_penalty(&se, &exp.sigma_g, &sol.assignment[0]),
            sol.subset.len(),
            sol.kmeans_inertia
        );
    }

    println!("\n# operating-point ladder sweep (n = {})", exp.n_multipliers());
    let ladders: Vec<Vec<f64>> = vec![
        vec![1.0],
        vec![0.3, 1.0],
        vec![0.1, 0.3, 1.0],
        vec![0.05, 0.1, 0.3, 1.0],
    ];
    for scales in ladders {
        let cfg = SearchConfig {
            n_multipliers: exp.n_multipliers(),
            scales: scales.clone(),
            seed: exp.seed(),
            restarts: 8,
        };
        let sol = search(&db, &se, &exp.sigma_g, &exp.stats, &cfg);
        let powers: Vec<String> = sol.power.iter().map(|p| format!("{:.1}%", 100.0 * p)).collect();
        let subset: Vec<&str> = sol.subset.iter().map(|&m| db.specs[m].name.as_str()).collect();
        println!("S={scales:?}: powers=[{}] subset={subset:?}", powers.join(", "));
    }
    Ok(())
}
