//! Edge-platform simulation: battery + thermal environment driving QoS.
//!
//! Couples the environmental simulator (battery SoC, thermal RC node,
//! governor) to the QoS controller and the elastic batching server: as
//! the battery drains / the die heats, the governor shrinks the power
//! budget and the controller walks DOWN the operating-point ladder with
//! immediate switches (graceful degradation instead of the paper's
//! "binary failure mode"); harvest or idle periods recover the budget
//! and accuracy climbs back through draining switches that never let a
//! batch span the OP change.
//!
//!   cargo run --release --example edge_platform -- [exp] [sim_secs]

use std::sync::Arc;
use std::time::{Duration, Instant};

use qos_nets::backend::OpTable;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::Experiment;
use qos_nets::plan::OpPlan;
use qos_nets::qos::envsim::{EnvConfig, EnvSimulator};
use qos_nets::qos::{QosConfig, QosController};
use qos_nets::server::{BatcherConfig, Server};
use qos_nets::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let exp_name = args.first().map(|s| s.as_str()).unwrap_or("quick");
    let sim_secs: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(5.0);

    let exp = Experiment::load("artifacts", exp_name)?;
    let db = Arc::new(MulDb::load("artifacts")?);
    let ops = OpPlan::load_for(&exp)?.load_operating_points(&exp, "bn")?;
    anyhow::ensure!(!ops.is_empty(), "run `qos-nets search --exp {exp_name}` first");
    let table = OpTable::new(ops);
    let mut controller = QosController::new(table.ladder(), QosConfig::default());
    // an elastic 1..3 worker pool: the edge box also sheds compute
    // threads when the queue is empty
    let server = Server::start_native(
        exp.graph.clone(),
        db,
        table,
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(4),
            workers: 1,
            min_workers: 1,
            max_workers: 3,
            ..BatcherConfig::default()
        },
    )?;

    // a small battery under heavy load: forces the full QoS ladder walk
    let mut env = EnvSimulator::new(EnvConfig {
        battery_capacity: 150.0,
        initial_soc: 0.75,
        harvest_peak: 6.0,
        full_power_draw: 12.0,
        ..Default::default()
    });

    let (images, _) = exp.load_testset()?;
    let elems = exp.image_elems();
    let n_img = images.len() / elems;
    let mut rng = Rng::new(1);

    println!("t[s]  SoC    temp°C  budget  OP  power  workers");
    let started = Instant::now();
    let mut receivers = Vec::new();
    let mut last_op = usize::MAX;
    let steps = (sim_secs / 0.05) as usize;
    for step in 0..steps {
        // each wall 50 ms simulates 10 s of platform time (battery scale)
        let served_power = server.ops()[server.operating_point()].relative_power;
        let budget = env.step(10.0, served_power);
        if let Some((idx, mode)) = controller.observe_with_mode(budget, Instant::now()) {
            server.set_operating_point_with(idx, mode)?;
        }
        if server.operating_point() != last_op || step % 20 == 0 {
            last_op = server.operating_point();
            let st = env.state();
            println!(
                "{:5.1} {:6.2} {:7.1} {:7.2} {:>3} {:6.1}% {:>8}",
                st.t,
                st.soc,
                st.temperature,
                st.budget,
                last_op,
                100.0 * server.ops()[last_op].relative_power,
                server.live_workers()
            );
        }
        let deadline = started + Duration::from_millis(50 * (step as u64 + 1));
        while Instant::now() < deadline {
            let i = rng.below(n_img);
            receivers.push(server.submit(images[i * elems..(i + 1) * elems].to_vec())?);
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let mut done = 0u64;
    for rx in receivers {
        if rx.recv_timeout(Duration::from_secs(20)).is_ok() {
            done += 1;
        }
    }
    let m = server.shutdown();
    println!(
        "\ncompleted {done} requests; OP switches {}; budget violations {}; \
         mean latency {:.2} ms; peak workers {} (+{}/-{})",
        controller.switches,
        controller.budget_violations,
        m.latency.mean_us() / 1e3,
        m.peak_workers,
        m.scale_ups,
        m.scale_downs
    );
    Ok(())
}
