//! End-to-end QoS serving driver (the repo's headline example).
//!
//! Loads a searched + fine-tuned experiment, starts the elastic batching
//! inference server with all operating points resident, replays a
//! synthetic power-budget trace through the QoS controller (draining
//! upgrades, immediate downgrades), and reports latency / throughput /
//! per-OP latency attribution / worker-scaling activity — the runtime
//! behaviour the paper's "QoS scaling" section describes.
//!
//!   cargo run --release --example qos_serving -- [exp] [secs] [trace]
//!
//! Defaults: quick, 6 seconds, "steps" trace.

use std::sync::Arc;
use std::time::{Duration, Instant};

use qos_nets::backend::OpTable;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::plan::OpPlan;
use qos_nets::qos::{budget_trace, QosConfig, QosController, SwitchMode};
use qos_nets::server::{BatcherConfig, Server};
use qos_nets::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let exp_name = args.first().map(|s| s.as_str()).unwrap_or("quick");
    let secs: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6.0);
    let trace_kind = args.get(2).map(|s| s.as_str()).unwrap_or("steps");

    let exp = Experiment::load("artifacts", exp_name)?;
    let db = Arc::new(MulDb::load("artifacts")?);
    // the stored plan's operating points, BN-tuned when stage B
    // overlays exist (same handoff the `serve` command uses)
    let ops = OpPlan::load_for(&exp)?.load_operating_points(&exp, "bn")?;
    anyhow::ensure!(!ops.is_empty(), "run `qos-nets search --exp {exp_name}` first");
    let table = OpTable::new(ops);
    let mut controller = QosController::new(table.ladder(), QosConfig::default());

    // measure per-OP accuracy up front (what QoS the user gets per rung)
    println!("operating-point ladder:");
    for op in table.ops() {
        let r = pipeline::eval_operating_point(&exp, &db, op, 32, Some(128))?;
        println!(
            "  {} power={:.1}% top1={:.1}%",
            op.name,
            100.0 * op.relative_power,
            100.0 * r.top1
        );
    }

    let op_names: Vec<String> = table.ops().iter().map(|o| o.name.clone()).collect();
    let server = Server::start_native(
        exp.graph.clone(),
        db.clone(),
        table,
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            workers: 2,
            min_workers: 1,
            max_workers: 4,
            ..BatcherConfig::default()
        },
    )?;

    let (images, labels) = exp.load_testset()?;
    let elems = exp.image_elems();
    let n_img = labels.len();

    let steps = (secs * 20.0) as usize;
    let trace = budget_trace(trace_kind, steps, 7);
    let mut rng = Rng::new(99);
    let started = Instant::now();
    let mut pending = Vec::new();
    let mut submitted = 0u64;
    let mut switch_log = Vec::new();

    for (step, &budget) in trace.iter().enumerate() {
        if let Some((idx, mode)) = controller.observe_with_mode(budget, Instant::now()) {
            server.set_operating_point_with(idx, mode)?;
            switch_log.push((started.elapsed().as_millis(), budget, idx, mode));
        }
        let deadline = started + Duration::from_millis(50 * (step as u64 + 1));
        while Instant::now() < deadline {
            let i = rng.below(n_img);
            pending.push((i, server.submit(images[i * elems..(i + 1) * elems].to_vec())?));
            submitted += 1;
            std::thread::sleep(Duration::from_micros(800));
        }
    }

    // drain + accuracy-in-flight
    let mut correct = 0u64;
    let mut done = 0u64;
    for (img_idx, rx) in pending {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(30)) {
            done += 1;
            let arg = resp
                .logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            if arg == labels[img_idx] as usize {
                correct += 1;
            }
        }
    }
    let wall = started.elapsed().as_secs_f64();
    let live = server.live_workers();
    let m = server.shutdown();

    println!("\n=== serving report ({trace_kind} budget trace, {:.1}s) ===", wall);
    println!("requests: {submitted} submitted, {done} completed ({:.1} req/s)", done as f64 / wall);
    println!(
        "online top-1 accuracy across OP switches: {:.2}%",
        100.0 * correct as f64 / done.max(1) as f64
    );
    println!(
        "latency: mean {:.2} ms | p50 <= {:.2} ms | p99 <= {:.2} ms | max {:.2} ms",
        m.latency.mean_us() / 1e3,
        m.latency.percentile_us(50.0) as f64 / 1e3,
        m.latency.percentile_us(99.0) as f64 / 1e3,
        m.latency.max_us() as f64 / 1e3
    );
    println!("mean batch size: {:.2}", m.mean_batch());
    println!(
        "workers: live={live} peak={} scale-ups={} scale-downs={}",
        m.peak_workers, m.scale_ups, m.scale_downs
    );
    println!("per-OP latency attribution:");
    for (i, c) in m.per_op_requests.iter().enumerate() {
        let h = &m.per_op_latency[i];
        println!(
            "  OP{i} ({}): {c} requests  mean={:.2} ms  p99<={:.2} ms",
            op_names[i],
            h.mean_us() / 1e3,
            h.percentile_us(99.0) as f64 / 1e3
        );
    }
    println!(
        "OP switches: {} (budget violations {})",
        controller.switches, controller.budget_violations
    );
    for (ms, budget, idx, mode) in switch_log {
        let tag = match mode {
            SwitchMode::Drain => "drain",
            SwitchMode::Immediate => "immediate",
        };
        println!("  t={ms:>6}ms budget={budget:.2} -> OP{idx} ({tag})");
    }
    Ok(())
}
