//! Baseline shoot-out on one experiment: **every registered planner**
//! (paper Table 1) run through the one `Planner` code path on identical
//! inputs, evaluated with the bit-exact LUT engine (no retraining —
//! isolates the *mapping* quality).
//!
//!   cargo run --release --example compare_baselines -- [exp] [limit]

use std::sync::Arc;

use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::plan::{self, PlanInputs, Planner};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let exp_name = args.first().map(|s| s.as_str()).unwrap_or("quick");
    let limit: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);

    let exp = Experiment::load("artifacts", exp_name)?;
    let db = Arc::new(MulDb::load("artifacts")?);
    let se = errmodel::sigma_e(&db, &exp.stats);
    let inputs = PlanInputs::from_experiment(&exp, &db, &se);

    let exact = pipeline::exact_operating_point(&exp)?;
    let base = pipeline::eval_operating_point(&exp, &db, &exact, 32, Some(limit))?;
    println!("baseline top1 {:.2}% (n={})\n", 100.0 * base.top1, base.n);
    println!(
        "{:14} {:>8} {:>7} {:>9} {:>10}",
        "planner", "power", "#AMs", "top1", "loss[pp]"
    );
    for planner in plan::all_planners() {
        let p = planner.plan(&inputs)?;
        // judge every method at the same tolerance: the scale-1.0 rung
        let pop = p.ops.last().expect("plan has no operating points");
        let op = pipeline::build_operating_point(
            &exp,
            planner.name(),
            p.assignment_map(p.ops.len() - 1),
            pop.relative_power,
            None,
        )?;
        let r = pipeline::eval_operating_point(&exp, &db, &op, 32, Some(limit))?;
        let distinct: std::collections::BTreeSet<usize> =
            pop.assignment.iter().cloned().collect();
        println!(
            "{:14} {:>7.2}% {:>7} {:>8.2}% {:>10.2}",
            planner.name(),
            100.0 * pop.relative_power,
            distinct.len(),
            100.0 * r.top1,
            100.0 * (base.top1 - r.top1)
        );
    }
    Ok(())
}
