//! Baseline shoot-out on one experiment: every mapping algorithm from
//! paper Table 1 run on identical inputs, evaluated with the bit-exact
//! LUT engine (no retraining — isolates the *mapping* quality).
//!
//!   cargo run --release --example compare_baselines -- [exp] [limit]

use std::collections::HashMap;
use std::sync::Arc;

use qos_nets::baselines::{self, alwann};
use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let exp_name = args.first().map(|s| s.as_str()).unwrap_or("quick");
    let limit: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);

    let exp = Experiment::load("artifacts", exp_name)?;
    let db = Arc::new(MulDb::load("artifacts")?);
    let se = errmodel::sigma_e(&db, &exp.stats);

    let mut methods: Vec<(String, Vec<usize>)> = vec![
        (
            "gradient_search[16]".into(),
            baselines::gradient_search(&db, &se, &exp.sigma_g, 1.0),
        ),
        (
            "lvrm_style[15]".into(),
            baselines::lvrm_divide_conquer(&db, &se, &exp.sigma_g, 1.0),
        ),
        (
            "pnam_style[14]".into(),
            baselines::pnam_mapping(&db, &se, &exp.sigma_g, &exp.stats, 1.0),
        ),
        (
            "tpm_style[13]".into(),
            baselines::tpm_threshold(&db, &se, &exp.sigma_g, 1.0),
        ),
    ];
    let hom = baselines::homogeneous_pick(&db, &se, &exp.sigma_g, &exp.stats, 0.0);
    methods.push((format!("homogeneous[2] ({})", db.specs[hom].name), vec![hom; se.l]));
    let front = alwann::evolve(
        &db,
        &se,
        &exp.sigma_g,
        &exp.stats,
        &alwann::GaConfig { n_tiles: exp.n_multipliers(), seed: 1, ..Default::default() },
    );
    if let Some(best) = alwann::pick_feasible(&front) {
        methods.push(("alwann_ga[9]".into(), best.chromosome.assignment()));
    }
    let (_, sol) = pipeline::run_search(&exp, &db);
    methods.push((
        format!("qos_nets (n={})", exp.n_multipliers()),
        sol.assignment.last().unwrap().clone(),
    ));

    let exact = pipeline::exact_operating_point(&exp)?;
    let base = pipeline::eval_operating_point(&exp, &db, &exact, 32, Some(limit))?;
    println!("baseline top1 {:.2}% (n={})\n", 100.0 * base.top1, base.n);
    println!(
        "{:32} {:>8} {:>7} {:>9} {:>10}",
        "method", "power", "#AMs", "top1", "loss[pp]"
    );
    for (name, assignment) in methods {
        let amap: HashMap<String, usize> = exp
            .layer_names
            .iter()
            .cloned()
            .zip(assignment.iter().cloned())
            .collect();
        let power = errmodel::relative_power(&db, &exp.stats, &assignment);
        let distinct: std::collections::BTreeSet<usize> = assignment.iter().cloned().collect();
        let op = pipeline::build_operating_point(&exp, &name, amap, power, None)?;
        let r = pipeline::eval_operating_point(&exp, &db, &op, 32, Some(limit))?;
        println!(
            "{:32} {:>7.2}% {:>7} {:>8.2}% {:>10.2}",
            name,
            100.0 * power,
            distinct.len(),
            100.0 * r.top1,
            100.0 * (base.top1 - r.top1)
        );
    }
    Ok(())
}
