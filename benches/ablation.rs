//! Ablations of the design choices DESIGN.md calls out:
//!
//! * Eq. 3 outlier reweighting  f(x) = 1 + ln(x)  vs raw ratios vs hard
//!   clipping — effect on subset quality (power at zero penalty)
//! * k-means restarts — solution stability / inertia
//! * multi-OP joint clustering (Sec. 3.2) vs per-OP independent searches
//!   — subset size and power trade-off

use std::sync::Arc;

use qos_nets::baselines::quality_penalty;
use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::Experiment;
use qos_nets::selection::{self, kmeans, SearchConfig};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "quick".into());
    let Ok(exp) = Experiment::load("artifacts", &name) else {
        println!("artifacts/{name} missing — ablation skipped");
        return Ok(());
    };
    let db = Arc::new(MulDb::load("artifacts")?);
    let se = errmodel::sigma_e(&db, &exp.stats);
    let scales = exp.scales();
    let usable = selection::usable_multipliers(&se, &exp.sigma_g, &scales);

    // --- ablation 1: reweighting function ---
    println!("=== Eq.3 reweighting ablation (n = {}) ===", exp.n_multipliers());
    for (label, f) in [
        ("f(x)=x (raw)", Box::new(|x: f64| x) as Box<dyn Fn(f64) -> f64>),
        ("f(x)=min(x,1) (clip)", Box::new(|x: f64| x.min(1.0))),
        ("f(x)=1+ln(x) (paper)", Box::new(selection::reweight)),
    ] {
        // rebuild preference vectors with the candidate transform
        let mut points = Vec::new();
        for &s in &scales {
            for k in 0..se.l {
                let tol = (s * exp.sigma_g[k]).max(1e-12);
                points.push(usable.iter().map(|&j| f(se.get(j, k) / tol)).collect::<Vec<f64>>());
            }
        }
        let km = kmeans::kmeans(&points, exp.n_multipliers(), 0, 8);
        let muls: Vec<usize> = km
            .centroids
            .iter()
            .map(|c| selection::pick_for_centroid(c, &usable, &db))
            .collect();
        let l = se.l;
        let mut total_power = 0.0;
        let mut total_pen = 0.0;
        for (opi, _) in scales.iter().enumerate() {
            let a: Vec<usize> = (0..l).map(|k| muls[km.assignment[opi * l + k]]).collect();
            total_power += errmodel::relative_power(&db, &exp.stats, &a);
            total_pen += quality_penalty(&se, &exp.sigma_g, &a);
        }
        println!(
            "{:24} mean power {:.2}%  mean penalty {:.4}  inertia {:.3}",
            label,
            100.0 * total_power / scales.len() as f64,
            total_pen / scales.len() as f64,
            km.inertia
        );
    }

    // --- ablation 1b: residual-bias coefficient in the error model ---
    println!("\n=== error-model residual-bias ablation (paper = 0.0) ===");
    for bias in [0.0f64, 0.05, 0.1, 0.2] {
        let se_b = errmodel::sigma_e_with_bias(&db, &exp.stats, bias);
        let cfg = SearchConfig {
            n_multipliers: exp.n_multipliers(),
            scales: scales.clone(),
            seed: 0,
            restarts: 8,
        };
        let sol = selection::search(&db, &se_b, &exp.sigma_g, &exp.stats, &cfg);
        let names: Vec<&str> = sol.subset.iter().map(|&m| db.specs[m].name.as_str()).collect();
        println!(
            "bias_residual {bias:>4}: power {:?} subset {names:?}",
            sol.power.iter().map(|p| format!("{:.1}%", 100.0 * p)).collect::<Vec<_>>()
        );
    }

    // --- ablation 2: k-means restarts ---
    println!("\n=== k-means restart ablation ===");
    for restarts in [1usize, 2, 4, 8, 16] {
        let cfg = SearchConfig {
            n_multipliers: exp.n_multipliers(),
            scales: scales.clone(),
            seed: 0,
            restarts,
        };
        let sol = selection::search(&db, &se, &exp.sigma_g, &exp.stats, &cfg);
        println!(
            "restarts {restarts:>2}: inertia {:.4}  power {:?}",
            sol.kmeans_inertia,
            sol.power.iter().map(|p| format!("{:.1}%", 100.0 * p)).collect::<Vec<_>>()
        );
    }

    // --- ablation 3: joint vs independent per-OP clustering ---
    println!("\n=== joint (Sec. 3.2) vs independent per-OP clustering ===");
    let joint = selection::search(
        &db,
        &se,
        &exp.sigma_g,
        &exp.stats,
        &SearchConfig {
            n_multipliers: exp.n_multipliers(),
            scales: scales.clone(),
            seed: 0,
            restarts: 8,
        },
    );
    let mut indep_subset: std::collections::BTreeSet<usize> = Default::default();
    let mut indep_power = Vec::new();
    for &s in &scales {
        let sol = selection::search(
            &db,
            &se,
            &exp.sigma_g,
            &exp.stats,
            &SearchConfig {
                n_multipliers: exp.n_multipliers(),
                scales: vec![s],
                seed: 0,
                restarts: 8,
            },
        );
        indep_subset.extend(sol.subset.iter().cloned());
        indep_power.push(sol.power[0]);
    }
    println!(
        "joint:       subset {:>2} instances, power {:?}",
        joint.subset.len(),
        joint.power.iter().map(|p| format!("{:.1}%", 100.0 * p)).collect::<Vec<_>>()
    );
    println!(
        "independent: subset {:>2} instances (violates the n-constraint across OPs), power {:?}",
        indep_subset.len(),
        indep_power.iter().map(|p| format!("{:.1}%", 100.0 * p)).collect::<Vec<_>>()
    );
    Ok(())
}
