//! Regenerates **paper Table 4**: MobileNetV2 / TinyImageNet(synth),
//! o = 3 operating points — relative multiplication power and Top-5
//! accuracy loss for every (method, retraining strategy), plus the
//! multiplier-instance count and parameter overhead columns.

use std::collections::HashMap;
use std::sync::Arc;

use qos_nets::baselines;
use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::plan::OpPlan;
use qos_nets::util::json;

fn main() -> anyhow::Result<()> {
    println!("=== Table 4: MobileNetV2 / synthtin, o = 3 operating points ===\n");
    let Ok(exp) = Experiment::load("artifacts", "table4_mnv2") else {
        println!("[table4_mnv2] artifacts missing — skipped (run scripts_queue.sh)");
        return Ok(());
    };
    let db = Arc::new(MulDb::load("artifacts")?);
    let se = errmodel::sigma_e(&db, &exp.stats);
    let limit = std::env::var("TABLE4_LIMIT").ok().and_then(|s| s.parse().ok()).unwrap_or(300);

    // parameter accounting (exp.json from stage A)
    let exp_meta = json::parse(&std::fs::read_to_string(exp.dir.join("exp.json"))?)
        .map_err(anyhow::Error::msg)?;
    let n_params = exp_meta.get("n_params").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let bn_overlay = exp_meta.get("bn_overlay_params").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let o = exp.scales().len() as f64;

    let exact = pipeline::exact_operating_point(&exp)?;
    let base = pipeline::eval_operating_point(&exp, &db, &exact, 16, Some(limit))?;
    println!(
        "baseline (8-bit, exact mult): top5 {:.2}%  params {:.2}M\n",
        100.0 * base.top5,
        n_params / 1e6
    );

    let plan = OpPlan::load_for(&exp)?;
    println!(
        "{:30} {:>6} {:>22} {:>22} {:>6} {:>9}",
        "method", "", "rel. power / OP", "top5 loss [pp] / OP", "#AMs", "params"
    );

    // --- QoS-Nets rows: none / full / bn ---
    for (mode, label, params_str) in [
        ("none", "QoS-Nets w/o retraining", format!("{:.2}M", n_params / 1e6)),
        ("full", "QoS-Nets full retraining", format!("{:.2}M", n_params * o / 1e6)),
        ("bn", "QoS-Nets BN tuning", format!("{:.2}M", (n_params + bn_overlay * o) / 1e6)),
    ] {
        let mut powers = Vec::new();
        let mut losses = Vec::new();
        let mut used: std::collections::BTreeSet<usize> = Default::default();
        for (i, pop) in plan.ops.iter().enumerate() {
            used.extend(pop.assignment.iter().cloned());
            let overlay = match mode {
                "bn" => Some(exp.dir.join(format!("bn_op{i}.qten"))),
                "full" => Some(exp.dir.join(format!("params_full_op{i}.qten"))),
                _ => None,
            }
            .filter(|p| p.exists());
            let op = pipeline::build_operating_point(
                &exp,
                &pop.name,
                plan.assignment_map(i),
                pop.relative_power,
                overlay.as_deref(),
            )?;
            let r = pipeline::eval_operating_point(&exp, &db, &op, 16, Some(limit))?;
            powers.push(format!("{:.1}%", 100.0 * pop.relative_power));
            losses.push(format!("{:.2}", 100.0 * (base.top5 - r.top5)));
        }
        println!(
            "{:30} {:>6} {:>22} {:>22} {:>6} {:>9}",
            label,
            "",
            powers.join(" / "),
            losses.join(" / "),
            used.len(),
            params_str
        );
    }

    // --- unconstrained gradient search [16] per scale (no retraining) ---
    {
        let mut powers = Vec::new();
        let mut losses = Vec::new();
        let mut used: std::collections::BTreeSet<usize> = Default::default();
        for &s in &exp.scales() {
            let a = baselines::gradient_search(&db, &se, &exp.sigma_g, s);
            used.extend(a.iter().cloned());
            let power = errmodel::relative_power(&db, &exp.stats, &a);
            let amap: HashMap<String, usize> = exp
                .layer_names
                .iter()
                .cloned()
                .zip(a.iter().cloned())
                .collect();
            let op = pipeline::build_operating_point(&exp, "gs", amap, power, None)?;
            let r = pipeline::eval_operating_point(&exp, &db, &op, 16, Some(limit))?;
            powers.push(format!("{:.1}%", 100.0 * power));
            losses.push(format!("{:.2}", 100.0 * (base.top5 - r.top5)));
        }
        println!(
            "{:30} {:>6} {:>22} {:>22} {:>6} {:>9}",
            "Gradient Search [16] (raw)",
            "",
            powers.join(" / "),
            losses.join(" / "),
            used.len(),
            format!("{:.2}M", n_params * o / 1e6)
        );
    }

    // --- homogeneous rows: nearest-power instances to each OP ---
    {
        let mut powers = Vec::new();
        let mut losses = Vec::new();
        let mut used: std::collections::BTreeSet<usize> = Default::default();
        for pop in &plan.ops {
            // pick the single instance whose network power is closest
            let power = pop.relative_power;
            let sweep = baselines::homogeneous_sweep(&db, &se, &exp.sigma_g, &exp.stats);
            let (mid, p, _) = sweep
                .into_iter()
                .min_by(|a, b| {
                    (a.1 - power).abs().partial_cmp(&(b.1 - power).abs()).unwrap()
                })
                .unwrap();
            used.insert(mid);
            let amap: HashMap<String, usize> = exp
                .layer_names
                .iter()
                .map(|n| (n.clone(), mid))
                .collect();
            let op = pipeline::build_operating_point(&exp, "hom", amap, p, None)?;
            let r = pipeline::eval_operating_point(&exp, &db, &op, 16, Some(limit))?;
            powers.push(format!("{:.1}%", 100.0 * p));
            losses.push(format!("{:.2}", 100.0 * (base.top5 - r.top5)));
        }
        println!(
            "{:30} {:>6} {:>22} {:>22} {:>6} {:>9}",
            "Homogeneous [2] (raw)",
            "",
            powers.join(" / "),
            losses.join(" / "),
            used.len(),
            format!("{:.2}M", n_params * o / 1e6)
        );
    }

    println!("\npaper reference (MobileNetV2/TinyImageNet, power / top-5 loss):");
    println!("  Homogeneous          84.1/70.6/60.6%   0.85/0.51/15.86   3 AMs  7.44M");
    println!("  Gradient Search [16] 83.7/70.5/55.9%   0.08/0.47/2.02   16 AMs  7.44M");
    println!("  QoS-Nets w/o retrain 84.7/69.4/57.2%   30.0/76.8/76.7    4 AMs  2.48M");
    println!("  QoS-Nets full        84.7/69.4/57.2%   0.10/0.52/1.65    4 AMs  7.44M");
    println!("  QoS-Nets BN tuning   84.7/69.4/57.2%   0.30/0.71/2.33    4 AMs  2.54M");
    Ok(())
}
