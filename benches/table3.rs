//! Regenerates **paper Table 3**: CIFAR-100 (synth) power reduction vs
//! top-1 loss for ResNet-20/32, n = 3, o = 1 — QoS-Nets vs the TPM- and
//! PNAM-style baselines.

use std::collections::HashMap;
use std::sync::Arc;

use qos_nets::baselines;
use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};

const PAPER: &[(&str, &str, f64, f64)] = &[
    ("resnet20", "TPM [13]", 3.0, 0.5),
    ("resnet20", "PNAM [14]", 20.0, 0.5),
    ("resnet20", "QoS-Nets o=1 n=3", 21.0, 0.0),
    ("resnet32", "TPM [13]", 3.0, 0.5),
    ("resnet32", "PNAM [14]", 22.0, 0.5),
    ("resnet32", "QoS-Nets o=1 n=3", 24.0, -0.2),
];

fn main() -> anyhow::Result<()> {
    println!("=== Table 3: (synth)CIFAR-100, power reduction vs top-1 loss ===\n");
    let db = Arc::new(MulDb::load("artifacts").or_else(|_| -> anyhow::Result<MulDb> { Ok(MulDb::generate()) })?);

    for depth in [20usize, 32] {
        let name = format!("table3_resnet{depth}");
        let Ok(exp) = Experiment::load("artifacts", &name) else {
            println!("[{name}] artifacts missing — skipped (run scripts_queue.sh)");
            continue;
        };
        println!("--- ResNet-{depth} / synthcifar100 ---");
        let se = errmodel::sigma_e(&db, &exp.stats);
        let exact = pipeline::exact_operating_point(&exp)?;
        let base = pipeline::eval_operating_point(&exp, &db, &exact, 32, Some(512))?;
        println!("baseline (8-bit exact) top1 {:.2}%", 100.0 * base.top1);

        let mut methods: Vec<(String, Vec<usize>)> = vec![
            ("TPM-style [13]".into(), baselines::tpm_threshold(&db, &se, &exp.sigma_g, 1.0)),
            ("PNAM-style [14]".into(), baselines::pnam_mapping(&db, &se, &exp.sigma_g, &exp.stats, 1.0)),
        ];
        let plan = qos_nets::plan::OpPlan::load_for(&exp).ok();
        if let Some(op) = plan.as_ref().and_then(|p| p.ops.last()) {
            methods.push((format!("QoS-Nets o=1 n={}", exp.n_multipliers()), op.assignment.clone()));
        }

        println!("{:28} {:>10} {:>7} {:>14}", "method", "power red.", "#AMs", "top1 loss[pp]");
        for (mname, a) in methods {
            let power = errmodel::relative_power(&db, &exp.stats, &a);
            let distinct: std::collections::BTreeSet<usize> = a.iter().cloned().collect();
            let amap: HashMap<String, usize> = exp
                .layer_names
                .iter()
                .cloned()
                .zip(a.iter().cloned())
                .collect();
            // use the full-retrained overlay for QoS-Nets when available
            let overlay = if mname.starts_with("QoS-Nets") {
                let idx = plan.as_ref().map(|p| p.ops.len()).unwrap_or(1) - 1;
                let p = exp.dir.join(format!("params_full_op{idx}.qten"));
                p.exists().then_some(p)
            } else {
                None
            };
            let op = pipeline::build_operating_point(&exp, &mname, amap, power, overlay.as_deref())?;
            let r = pipeline::eval_operating_point(&exp, &db, &op, 32, Some(512))?;
            println!(
                "{:28} {:>9.1}% {:>7} {:>14.2}",
                mname,
                100.0 * (1.0 - power),
                distinct.len(),
                100.0 * (base.top1 - r.top1)
            );
        }
        println!("paper reference:");
        for (_, meth, pr, loss) in PAPER.iter().filter(|(m, ..)| *m == format!("resnet{depth}")) {
            println!("  {:26} {:>9.1}% {:>22.2}", meth, pr, loss);
        }
        println!();
    }
    Ok(())
}
