//! Planner benchmarks: wall time of every registered mapper over the
//! generated multiplier family at several layer counts, so future
//! planner work has a perf trajectory.  Entirely in-memory (synthetic
//! layer statistics), so this bench always runs — no artifacts needed.
//!
//!   cargo bench --bench perf_search

use std::time::Instant;

use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::nn::LayerStats;
use qos_nets::plan::{self, PlanInputs, Planner};

fn synthetic_stats(l: usize) -> Vec<LayerStats> {
    (0..l)
        .map(|i| LayerStats {
            name: format!("l{i}"),
            act_hist: vec![1.0 / 256.0; 256],
            w_hist: vec![1.0 / 256.0; 256],
            k_fanin: 32 << (i % 4),
            macs_total: 50_000 * (1 + i % 5),
            s_act: 0.02,
            z_act: 128,
            s_w: 0.01,
            z_w: 128,
            bn_scale: 0.4,
            out_rms: 1.0,
        })
        .collect()
}

fn main() {
    let db = MulDb::generate();
    println!(
        "=== planner wall time ({} multipliers, scales [0.3, 1.0], n=4) ===",
        db.len()
    );
    println!(
        "{:>6} {:>14} {:>12} {:>12} {:>8} {:>6}",
        "layers", "planner", "plan ms", "sigma_e ms", "power%", "#AMs"
    );
    for &l in &[8usize, 16, 32, 64] {
        let stats = synthetic_stats(l);
        let t0 = Instant::now();
        let se = errmodel::sigma_e(&db, &stats);
        let sigma_e_ms = t0.elapsed().as_secs_f64() * 1e3;
        let sigma_g: Vec<f64> = (0..l).map(|i| 0.05 + 0.02 * (i % 7) as f64).collect();
        let layer_names: Vec<String> = (0..l).map(|i| format!("l{i}")).collect();
        let inputs = PlanInputs {
            db: &db,
            se: &se,
            sigma_g: &sigma_g,
            stats: &stats,
            layer_names: &layer_names,
            scales: vec![0.3, 1.0],
            n_multipliers: 4,
            seed: 7,
            experiment: "synthetic".into(),
        };
        for planner in plan::all_planners() {
            // best-of-3: planners are deterministic, so the spread is
            // allocator/cache noise only
            let mut best = f64::MAX;
            let mut last = None;
            for _ in 0..3 {
                let t0 = Instant::now();
                let p = planner.plan(&inputs).expect("planner failed");
                best = best.min(t0.elapsed().as_secs_f64() * 1e3);
                last = Some(p);
            }
            let p = last.unwrap();
            let frugal = p.ops.last().unwrap();
            println!(
                "{:>6} {:>14} {:>12.3} {:>12.1} {:>7.1}% {:>6}",
                l,
                planner.name(),
                best,
                sigma_e_ms,
                100.0 * frugal.relative_power,
                p.subset.len()
            );
        }
    }
}
