//! Regenerates **paper Figure 3**: per-layer multiplier assignment for
//! every operating point of the MobileNetV2 experiment, plus each OP's
//! combined relative power line (the horizontal line in the paper plot).
//! Emits CSV series ready for plotting; also regenerates **Figures 1-2**
//! data (sigma_g / sigma_e preparation and the scaled preference-vector
//! clustering) as summary statistics.

use std::sync::Arc;

use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::Experiment;
use qos_nets::selection::{self, SearchConfig};

fn main() -> anyhow::Result<()> {
    let name = std::env::var("FIG3_EXP").unwrap_or_else(|_| "table4_mnv2".into());
    let Ok(exp) = Experiment::load("artifacts", &name) else {
        println!("[{name}] artifacts missing — falling back to quick");
        return run("quick");
    };
    let _ = exp;
    run(&name)
}

fn run(name: &str) -> anyhow::Result<()> {
    let exp = Experiment::load("artifacts", name)?;
    let db = Arc::new(MulDb::load("artifacts")?);

    // --- Fig. 1: sigma_g vector + sigma_e matrix summary ---
    let se = errmodel::sigma_e(&db, &exp.stats);
    println!("# Fig1: l x m error-estimation matrix, l={} layers, m={} multipliers", se.l, se.m);
    println!("layer,sigma_g,min_sigma_e_nonexact,median_sigma_e");
    for (k, lname) in exp.layer_names.iter().enumerate() {
        let mut col: Vec<f64> = (1..se.m).map(|j| se.get(j, k)).collect();
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!("{lname},{:.5},{:.5},{:.5}", exp.sigma_g[k], col[0], col[col.len() / 2]);
    }

    // --- Fig. 2: preference-vector clustering summary ---
    let usable = selection::usable_multipliers(&se, &exp.sigma_g, &exp.scales());
    let points = selection::preference_vectors(&se, &exp.sigma_g, &exp.scales(), &usable);
    println!("\n# Fig2: clustering space: {} preference vectors (o={} x l={}), dim={}",
        points.len(), exp.scales().len(), se.l, usable.len());
    // this figure reports search *internals* (cluster -> multiplier
    // picks), so it calls selection::search directly; the plan-level
    // view of the same run lives in `report fig3` / the OpPlan artifact
    let sol = selection::search(
        &db,
        &se,
        &exp.sigma_g,
        &exp.stats,
        &SearchConfig {
            n_multipliers: exp.n_multipliers(),
            scales: exp.scales(),
            seed: exp.seed(),
            restarts: 8,
        },
    );
    println!("clusters -> multipliers: {:?}",
        sol.cluster_muls.iter().map(|&m| db.specs[m].name.as_str()).collect::<Vec<_>>());

    // --- Fig. 3: the assignment plot series ---
    println!("\n# Fig3: per-layer assignment per operating point");
    for (i, a) in sol.assignment.iter().enumerate() {
        println!("## OP{i} scale={} relative_power={:.4} (horizontal line)", exp.scales()[i], sol.power[i]);
        println!("layer_index,layer,multiplier,multiplier_power");
        for (k, lname) in exp.layer_names.iter().enumerate() {
            println!("{k},{lname},{},{:.3}", db.specs[a[k]].name, db.power(a[k]));
        }
    }
    // shape checks mirroring the paper's description
    assert_eq!(sol.assignment.len(), exp.scales().len());
    let distinct: std::collections::BTreeSet<usize> = sol.assignment.iter().flatten().cloned().collect();
    assert!(distinct.len() <= exp.n_multipliers());
    println!("\n# {} layers x {} OPs assigned to {} multiplier instances (n = {})",
        exp.layer_names.len(), sol.assignment.len(), distinct.len(), exp.n_multipliers());
    Ok(())
}
