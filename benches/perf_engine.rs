//! Engine micro/macro benchmarks (§Perf deliverable, L3 hot path).
//!
//! * blocked LUT matmul GMAC/s across shapes (the hot loop)
//! * exact-multiplier fast path vs LUT path
//! * end-to-end engine images/s on the quick model per operating point

use std::sync::Arc;

use qos_nets::engine::{lutmm, Engine};
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::util::bench::{bench, report};
use qos_nets::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let db = Arc::new(MulDb::generate());
    let mut rng = Rng::new(0);

    println!("=== LUT matmul hot loop ===");
    for &(m, k, n) in &[(1024usize, 144usize, 64usize), (4096, 288, 64), (256, 1152, 128), (4096, 64, 64)] {
        let at: Vec<i32> = (0..k * m).map(|_| rng.below(256) as i32).collect();
        let wt: Vec<i32> = (0..n * k).map(|_| rng.below(256) as i32).collect();
        let wlut = lutmm::transpose_lut(db.lut(9));
        let mut out = vec![0i32; m * n];
        let macs = (m * k * n) as f64;
        let r = bench(&format!("lut_matmul {m}x{k}x{n}"), 1, 5, || {
            lutmm::lut_matmul_acc(&at, &wt, &wlut, m, k, n, &mut out);
        });
        report(&r, Some((macs / 1e9, "GMAC/s")));

        let mut out2 = vec![0i32; m * n];
        let r2 = bench(&format!("exact_matmul {m}x{k}x{n}"), 1, 5, || {
            lutmm::exact_matmul_corrected(&at, &wt, m, k, n, 128, 128, &mut out2);
        });
        report(&r2, Some((macs / 1e9, "GMAC/s")));
    }

    println!("\n=== end-to-end engine (quick model) ===");
    let Ok(exp) = Experiment::load("artifacts", "quick") else {
        println!("artifacts/quick missing — engine macro bench skipped");
        return Ok(());
    };
    let db = Arc::new(MulDb::load("artifacts")?);
    let (images, _) = exp.load_testset()?;
    let elems = exp.image_elems();
    let batch = 32usize;

    for (label, op) in [
        ("exact OP", pipeline::exact_operating_point(&exp)?),
        ("approx OP", {
            let plan = qos_nets::plan::OpPlan::load_for(&exp).ok();
            if let Some((p, pop)) = plan.as_ref().and_then(|p| p.ops.last().map(|o| (p, o))) {
                pipeline::build_operating_point(
                    &exp,
                    "approx",
                    p.assignment_map(p.ops.len() - 1),
                    pop.relative_power,
                    None,
                )?
            } else {
                pipeline::exact_operating_point(&exp)?
            }
        }),
    ] {
        let mut eng = Engine::new(exp.graph.clone(), db.clone());
        let r = bench(&format!("engine fwd b{batch} [{label}]"), 1, 5, || {
            eng.forward(&op, &images[..batch * elems], batch).unwrap();
        });
        report(&r, Some((batch as f64, "img/s")));
    }

    // MAC-rate view of the end-to-end number
    let total_macs = exp.graph.total_macs as f64;
    println!("\nmodel MACs/image: {:.1}M", total_macs / 1e6);
    Ok(())
}
