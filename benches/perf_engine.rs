//! Engine micro/macro benchmarks (§Perf deliverable, L3 hot path).
//!
//! * per-kernel throughput: every registered `LutKernel` (scalar, AVX2
//!   where detected, threaded) across the blocked-matmul shapes, LUT
//!   path and exact-multiplier fast path
//! * the free-function scalar entry points on one shape (API smoke)
//! * end-to-end engine images/s on the quick model per operating point
//!   and per kernel

use std::sync::Arc;

use qos_nets::engine::lutmm::LutKernel;
use qos_nets::engine::{lutmm, Engine};
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::util::bench::{bench, report};
use qos_nets::util::rng::Rng;

const SHAPES: [(usize, usize, usize); 4] =
    [(1024, 144, 64), (4096, 288, 64), (256, 1152, 128), (4096, 64, 64)];

fn main() -> anyhow::Result<()> {
    let db = Arc::new(MulDb::generate());
    let mut rng = Rng::new(0);

    // one shape through the free-function scalar entry points (the
    // per-kernel section below covers scalar across all of SHAPES —
    // this only keeps the selftest/test-facing API exercised)
    println!("=== free-function scalar entry points ===");
    {
        let (m, k, n) = SHAPES[0];
        let at: Vec<i32> = (0..k * m).map(|_| rng.below(256) as i32).collect();
        let wt: Vec<i32> = (0..n * k).map(|_| rng.below(256) as i32).collect();
        let wlut = lutmm::transpose_lut(db.lut(9));
        let mut out = vec![0i32; m * n];
        let macs = (m * k * n) as f64;
        let r = bench(&format!("lut_matmul {m}x{k}x{n}"), 1, 5, || {
            lutmm::lut_matmul_acc(&at, &wt, &wlut, m, k, n, &mut out);
        });
        report(&r, Some((macs / 1e9, "GMAC/s")));

        let mut out2 = vec![0i32; m * n];
        let r2 = bench(&format!("exact_matmul {m}x{k}x{n}"), 1, 5, || {
            lutmm::exact_matmul_corrected(&at, &wt, m, k, n, 128, 128, &mut out2);
        });
        report(&r2, Some((macs / 1e9, "GMAC/s")));
    }

    println!("\n=== per-kernel LUT matmul throughput ===");
    let kernels = lutmm::available_kernels();
    println!(
        "registered kernels: {} (auto resolves to {})",
        kernels.iter().map(|k| k.name().to_string()).collect::<Vec<_>>().join(", "),
        lutmm::detect_kernel().name()
    );
    for &(m, k, n) in &SHAPES {
        let at: Vec<i32> = (0..k * m).map(|_| rng.below(256) as i32).collect();
        let wt: Vec<i32> = (0..n * k).map(|_| rng.below(256) as i32).collect();
        let wlut = lutmm::transpose_lut(db.lut(9));
        let macs = (m * k * n) as f64;
        for kernel in &kernels {
            let mut out = vec![0i32; m * n];
            let r = bench(&format!("lut[{}] {m}x{k}x{n}", kernel.name()), 1, 5, || {
                kernel.matmul_acc(&at, &wt, &wlut, m, k, n, &mut out);
            });
            report(&r, Some((macs / 1e9, "GMAC/s")));
            let mut out2 = vec![0i32; m * n];
            let r2 = bench(&format!("exact[{}] {m}x{k}x{n}", kernel.name()), 1, 5, || {
                kernel.exact_corrected(&at, &wt, m, k, n, 128, 128, &mut out2);
            });
            report(&r2, Some((macs / 1e9, "GMAC/s")));
        }
    }

    println!("\n=== end-to-end engine (quick model) ===");
    let Ok(exp) = Experiment::load("artifacts", "quick") else {
        println!("artifacts/quick missing — engine macro bench skipped");
        return Ok(());
    };
    let db = Arc::new(MulDb::load("artifacts")?);
    let (images, _) = exp.load_testset()?;
    let elems = exp.image_elems();
    let batch = 32usize;

    for (label, op) in [
        ("exact OP", pipeline::exact_operating_point(&exp)?),
        ("approx OP", {
            let plan = qos_nets::plan::OpPlan::load_for(&exp).ok();
            if let Some((p, pop)) = plan.as_ref().and_then(|p| p.ops.last().map(|o| (p, o))) {
                pipeline::build_operating_point(
                    &exp,
                    "approx",
                    p.assignment_map(p.ops.len() - 1),
                    pop.relative_power,
                    None,
                )?
            } else {
                pipeline::exact_operating_point(&exp)?
            }
        }),
    ] {
        for kernel in lutmm::available_kernels() {
            let kname = kernel.name().to_string();
            let mut eng = Engine::with_kernel(exp.graph.clone(), db.clone(), kernel);
            let r = bench(&format!("engine fwd b{batch} [{label}] [{kname}]"), 1, 5, || {
                eng.forward(&op, &images[..batch * elems], batch).unwrap();
            });
            report(&r, Some((batch as f64, "img/s")));
        }
    }

    // MAC-rate view of the end-to-end number
    let total_macs = exp.graph.total_macs as f64;
    println!("\nmodel MACs/image: {:.1}M", total_macs / 1e6);
    Ok(())
}
