//! Fleet serving benchmarks (stub-backed, always runs): loopback
//! scatter/gather throughput vs a direct in-process backend at several
//! worker counts and batch sizes, plus the cost of the two fleet-wide
//! switch broadcasts (Immediate fire-and-forget vs Drain acked by every
//! worker).

use std::net::TcpListener;
use std::time::{Duration, Instant};

use qos_nets::backend::stub::stub_op;
use qos_nets::backend::{Backend, StubBackend};
use qos_nets::engine::OperatingPoint;
use qos_nets::fleet::{worker, FleetBackend, WorkerHandle};
use qos_nets::qos::SwitchMode;

fn catalog() -> Vec<OperatingPoint> {
    vec![stub_op("hi", 1.0), stub_op("lo", 0.5)]
}

fn spawn_workers(n: usize, delay: Duration) -> (Vec<WorkerHandle>, Vec<String>) {
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let handle = worker::spawn(listener, "bench-worker", "", catalog(), move |_conn| {
            Ok(StubBackend::new(10).with_delay(delay))
        })
        .unwrap();
        addrs.push(handle.addr().to_string());
        handles.push(handle);
    }
    (handles, addrs)
}

fn throughput_section() -> anyhow::Result<()> {
    println!("=== loopback fleet scatter/gather throughput (stub, 1 ms/chunk) ===");
    println!(
        "{:>8} {:>7} {:>9} {:>12} {:>12}",
        "workers", "batch", "rounds", "images/s", "ms/forward"
    );
    let elems = 64usize;
    let delay = Duration::from_millis(1);
    for &workers in &[1usize, 2, 4] {
        let (handles, addrs) = spawn_workers(workers, delay);
        let mut fleet = FleetBackend::connect(&addrs)?;
        fleet.prepare(&catalog())?;
        for &batch in &[8usize, 64] {
            let images: Vec<f32> = (0..batch * elems).map(|i| (i % 10) as f32).collect();
            let rounds = 50usize;
            // warmup
            fleet.forward(0, &images, batch)?;
            let t0 = Instant::now();
            for _ in 0..rounds {
                fleet.forward(0, &images, batch)?;
            }
            let wall = t0.elapsed();
            println!(
                "{:>8} {:>7} {:>9} {:>12.0} {:>12.3}",
                workers,
                batch,
                rounds,
                (rounds * batch) as f64 / wall.as_secs_f64(),
                wall.as_secs_f64() * 1e3 / rounds as f64,
            );
        }
        fleet.shutdown_fleet();
        for h in handles {
            h.join();
        }
    }
    // the in-process baseline the fleet overhead is measured against
    let mut local = StubBackend::new(10).with_delay(delay);
    local.prepare(&catalog())?;
    let images: Vec<f32> = (0..64 * elems).map(|i| (i % 10) as f32).collect();
    let t0 = Instant::now();
    for _ in 0..50 {
        local.forward(0, &images, 64)?;
    }
    println!(
        "   local      64        50 {:>12.0} {:>12.3}   (no wire)",
        (50.0 * 64.0) / t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64() * 1e3 / 50.0,
    );
    Ok(())
}

fn switch_broadcast_section() -> anyhow::Result<()> {
    println!();
    println!("=== fleet-wide OP switch broadcast cost (idle workers) ===");
    println!("{:>8} {:>16} {:>16}", "workers", "immediate us", "drain us");
    for &workers in &[1usize, 2, 4] {
        let (handles, addrs) = spawn_workers(workers, Duration::ZERO);
        let mut fleet = FleetBackend::connect(&addrs)?;
        fleet.prepare(&catalog())?;
        let rounds = 200usize;
        let t0 = Instant::now();
        for i in 0..rounds {
            fleet.set_operating_point(i % 2, SwitchMode::Immediate)?;
        }
        let imm = t0.elapsed();
        let t0 = Instant::now();
        for i in 0..rounds {
            fleet.set_operating_point(i % 2, SwitchMode::Drain)?;
        }
        let drain = t0.elapsed();
        println!(
            "{:>8} {:>16.1} {:>16.1}",
            workers,
            imm.as_micros() as f64 / rounds as f64,
            drain.as_micros() as f64 / rounds as f64,
        );
        fleet.shutdown_fleet();
        for h in handles {
            h.join();
        }
    }
    println!("(immediate = fire-and-forget writes; drain = every worker acks a barrier)");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    throughput_section()?;
    switch_broadcast_section()
}
