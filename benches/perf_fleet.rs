//! Fleet serving benchmarks (stub-backed, always runs): loopback
//! scatter/gather throughput vs a direct in-process backend at several
//! worker counts and batch sizes, pipelined vs lockstep dispatch on a
//! latency-skewed fleet, plus the cost of the two fleet-wide switch
//! broadcasts (Immediate fire-and-forget vs Drain acked by every
//! worker).

use std::net::TcpListener;
use std::time::{Duration, Instant};

use qos_nets::backend::stub::stub_op;
use qos_nets::backend::{Backend, StubBackend};
use qos_nets::engine::OperatingPoint;
use qos_nets::fleet::{worker, FleetBackend, FleetStats, WorkerHandle};
use qos_nets::qos::SwitchMode;

fn catalog() -> Vec<OperatingPoint> {
    vec![stub_op("hi", 1.0), stub_op("lo", 0.5)]
}

fn spawn_workers(n: usize, delay: Duration) -> (Vec<WorkerHandle>, Vec<String>) {
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let handle = worker::spawn(listener, "bench-worker", "", catalog(), move |_conn| {
            Ok(StubBackend::new(10).with_delay(delay))
        })
        .unwrap();
        addrs.push(handle.addr().to_string());
        handles.push(handle);
    }
    (handles, addrs)
}

fn throughput_section() -> anyhow::Result<()> {
    println!("=== loopback fleet scatter/gather throughput (stub, 1 ms/chunk) ===");
    println!(
        "{:>8} {:>7} {:>9} {:>12} {:>12}",
        "workers", "batch", "rounds", "images/s", "ms/forward"
    );
    let elems = 64usize;
    let delay = Duration::from_millis(1);
    for &workers in &[1usize, 2, 4] {
        let (handles, addrs) = spawn_workers(workers, delay);
        let mut fleet = FleetBackend::connect(&addrs)?;
        fleet.prepare(&catalog())?;
        for &batch in &[8usize, 64] {
            let images: Vec<f32> = (0..batch * elems).map(|i| (i % 10) as f32).collect();
            let rounds = 50usize;
            // warmup
            fleet.forward(0, &images, batch)?;
            let t0 = Instant::now();
            for _ in 0..rounds {
                fleet.forward(0, &images, batch)?;
            }
            let wall = t0.elapsed();
            println!(
                "{:>8} {:>7} {:>9} {:>12.0} {:>12.3}",
                workers,
                batch,
                rounds,
                (rounds * batch) as f64 / wall.as_secs_f64(),
                wall.as_secs_f64() * 1e3 / rounds as f64,
            );
        }
        fleet.shutdown_fleet();
        for h in handles {
            h.join();
        }
    }
    // the in-process baseline the fleet overhead is measured against
    let mut local = StubBackend::new(10).with_delay(delay);
    local.prepare(&catalog())?;
    let images: Vec<f32> = (0..64 * elems).map(|i| (i % 10) as f32).collect();
    let t0 = Instant::now();
    for _ in 0..50 {
        local.forward(0, &images, 64)?;
    }
    println!(
        "   local      64        50 {:>12.0} {:>12.3}   (no wire)",
        (50.0 * 64.0) / t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64() * 1e3 / 50.0,
    );
    Ok(())
}

fn spawn_skewed(delays: &[Duration]) -> anyhow::Result<(Vec<WorkerHandle>, Vec<String>)> {
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for &delay in delays {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let handle = worker::spawn(listener, "bench-worker", "", catalog(), move |_conn| {
            Ok(StubBackend::new(10).with_delay(delay))
        })?;
        addrs.push(handle.addr().to_string());
        handles.push(handle);
    }
    Ok((handles, addrs))
}

/// The tentpole comparison: the same three-speed fleet driven lockstep
/// (window 1, one chunk in flight per worker — the pre-pipelining data
/// plane) vs pipelined (several id-tagged Forwards in flight, chunk
/// sizes skewed by the latency EWMA).  Lockstep is paced by the
/// slowest box; pipelined keeps the fast one busy.
fn pipelined_vs_lockstep_section() -> anyhow::Result<()> {
    println!();
    println!("=== pipelined vs lockstep scatter/gather (latency-skewed fleet) ===");
    let delays = [Duration::from_micros(200), Duration::from_millis(1), Duration::from_millis(3)];
    println!(
        "{:>10} {:>7} {:>7} {:>9} {:>12} {:>12}",
        "mode", "window", "batch", "rounds", "images/s", "ms/forward"
    );
    let elems = 64usize;
    let (batch, rounds) = (96usize, 30usize);
    let mut lockstep_ips = 0.0f64;
    for &(label, window) in &[("lockstep", 1usize), ("pipelined", 6)] {
        let (handles, addrs) = spawn_skewed(&delays)?;
        let stats = FleetStats::default();
        let fleet = FleetBackend::connect_with(&addrs, stats.clone())?;
        let mut fleet = fleet.with_pipeline_window(window);
        fleet.prepare(&catalog())?;
        let images: Vec<f32> = (0..batch * elems).map(|i| (i % 10) as f32).collect();
        // warmup rounds let the latency EWMA learn the skew
        for _ in 0..5 {
            fleet.forward(0, &images, batch)?;
        }
        let t0 = Instant::now();
        for _ in 0..rounds {
            fleet.forward(0, &images, batch)?;
        }
        let wall = t0.elapsed().as_secs_f64();
        let ips = (rounds * batch) as f64 / wall;
        let tail = if label == "lockstep" {
            lockstep_ips = ips;
            String::new()
        } else {
            format!("   ({:.2}x lockstep)", ips / lockstep_ips.max(1e-9))
        };
        println!(
            "{label:>10} {window:>7} {batch:>7} {rounds:>9} {ips:>12.0} {:>12.3}{tail}",
            wall * 1e3 / rounds as f64,
        );
        // per-worker attribution: chunk sizing should favor the fast box
        let (ws, _, _) = stats.snapshot();
        let share: Vec<String> = addrs
            .iter()
            .map(|a| {
                let images = ws.iter().find(|(k, _)| k == a).map(|(_, w)| w.requests);
                format!("{}", images.unwrap_or(0))
            })
            .collect();
        println!("           per-worker images (0.2/1/3 ms): {}", share.join(" / "));
        fleet.shutdown_fleet();
        for h in handles {
            h.join();
        }
    }
    Ok(())
}

fn switch_broadcast_section() -> anyhow::Result<()> {
    println!();
    println!("=== fleet-wide OP switch broadcast cost (idle workers) ===");
    println!("{:>8} {:>16} {:>16}", "workers", "immediate us", "drain us");
    for &workers in &[1usize, 2, 4] {
        let (handles, addrs) = spawn_workers(workers, Duration::ZERO);
        let mut fleet = FleetBackend::connect(&addrs)?;
        fleet.prepare(&catalog())?;
        let rounds = 200usize;
        let t0 = Instant::now();
        for i in 0..rounds {
            fleet.set_operating_point(i % 2, SwitchMode::Immediate)?;
        }
        let imm = t0.elapsed();
        let t0 = Instant::now();
        for i in 0..rounds {
            fleet.set_operating_point(i % 2, SwitchMode::Drain)?;
        }
        let drain = t0.elapsed();
        println!(
            "{:>8} {:>16.1} {:>16.1}",
            workers,
            imm.as_micros() as f64 / rounds as f64,
            drain.as_micros() as f64 / rounds as f64,
        );
        fleet.shutdown_fleet();
        for h in handles {
            h.join();
        }
    }
    println!("(immediate = fire-and-forget writes; drain = every worker acks a barrier)");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    throughput_section()?;
    pipelined_vs_lockstep_section()?;
    switch_broadcast_section()
}
