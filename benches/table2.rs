//! Regenerates **paper Table 2**: power-consumption reduction vs Top-1
//! accuracy loss on (synth)CIFAR-10 for ResNet-8/14/20/32, comparing the
//! baseline mapping algorithms against QoS-Nets (o = 1).
//!
//! Requires the `table2_*` artifacts:
//!   python -m compile.aot build --exp table2_resnetN
//!   qos-nets search --exp table2_resnetN
//!   python -m compile.aot retrain --exp table2_resnetN
//! (scripts_queue.sh drives all of this.)  Experiments that have not been
//! built yet are skipped with a notice, so `cargo bench` always runs.

use std::collections::HashMap;
use std::sync::Arc;

use qos_nets::baselines::{self, alwann};
use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::plan::OpPlan;

// Paper Table 2 reference rows: (model, method, power reduction %, top-1 loss pp)
const PAPER: &[(&str, &str, f64, f64)] = &[
    ("resnet8", "ALWANN [9]", 30.0, 1.7),
    ("resnet8", "Homogeneous [2]", 47.0, 1.5),
    ("resnet8", "QoS-Nets o=1 n=4", 41.0, 0.8),
    ("resnet14", "ALWANN [9]", 30.0, 0.9),
    ("resnet14", "Homogeneous [2]", 47.0, 0.9),
    ("resnet14", "QoS-Nets o=1 n=4", 46.0, 0.8),
    ("resnet20", "LVRM [15]", 17.0, 0.5),
    ("resnet20", "PNAM [14]", 19.0, 0.5),
    ("resnet20", "Homogeneous [2]", 29.0, 0.5),
    ("resnet20", "QoS-Nets o=1 n=3", 38.0, 0.3),
    ("resnet32", "LVRM [15]", 18.0, 0.5),
    ("resnet32", "PNAM [14]", 22.0, 1.0),
    ("resnet32", "Homogeneous [2]", 29.0, 0.2),
    ("resnet32", "QoS-Nets o=1 n=3", 40.0, 0.5),
];

fn main() -> anyhow::Result<()> {
    println!("=== Table 2: (synth)CIFAR-10, power reduction vs top-1 loss ===\n");
    let db = Arc::new(MulDb::load("artifacts").or_else(|_| -> anyhow::Result<MulDb> { Ok(MulDb::generate()) })?);

    for depth in [8usize, 14, 20, 32] {
        let name = format!("table2_resnet{depth}");
        let Ok(exp) = Experiment::load("artifacts", &name) else {
            println!("[{name}] artifacts missing — skipped (run scripts_queue.sh)");
            continue;
        };
        println!("--- ResNet-{depth} ---");
        let se = errmodel::sigma_e(&db, &exp.stats);
        let exact = pipeline::exact_operating_point(&exp)?;
        let base = pipeline::eval_operating_point(&exp, &db, &exact, 32, Some(512))?;
        println!("baseline (8-bit exact) top1 {:.2}%", 100.0 * base.top1);

        // method assignments (single OP, scale 1.0)
        let mut methods: Vec<(String, Vec<usize>)> = Vec::new();
        let front = alwann::evolve(
            &db,
            &se,
            &exp.sigma_g,
            &exp.stats,
            &alwann::GaConfig { n_tiles: exp.n_multipliers(), seed: 0, ..Default::default() },
        );
        if let Some(best) = alwann::pick_feasible(&front) {
            methods.push(("ALWANN-style GA [9]".into(), best.chromosome.assignment()));
        }
        let hom = baselines::homogeneous_pick(&db, &se, &exp.sigma_g, &exp.stats, 0.0);
        methods.push((format!("Homogeneous [2] ({})", db.specs[hom].name), vec![hom; se.l]));
        methods.push(("LVRM-style [15]".into(), baselines::lvrm_divide_conquer(&db, &se, &exp.sigma_g, 1.0)));
        methods.push(("PNAM-style [14]".into(), baselines::pnam_mapping(&db, &se, &exp.sigma_g, &exp.stats, 1.0)));
        let plan = OpPlan::load_for(&exp).ok();
        if let Some(op) = plan.as_ref().and_then(|p| p.ops.last()) {
            methods.push((format!("QoS-Nets o=1 n={}", exp.n_multipliers()), op.assignment.clone()));
        }

        println!(
            "{:34} {:>10} {:>7} {:>16} {:>16}",
            "method", "power red.", "#AMs", "loss[pp] raw", "loss[pp] tuned"
        );
        for (mname, a) in methods {
            let power = errmodel::relative_power(&db, &exp.stats, &a);
            let distinct: std::collections::BTreeSet<usize> = a.iter().cloned().collect();
            let amap: HashMap<String, usize> = exp
                .layer_names
                .iter()
                .cloned()
                .zip(a.iter().cloned())
                .collect();
            let op = pipeline::build_operating_point(&exp, &mname, amap.clone(), power, None)?;
            let raw = pipeline::eval_operating_point(&exp, &db, &op, 32, Some(512))?;
            // the QoS-Nets row additionally gets its stage-B retrained overlay
            let tuned = if mname.starts_with("QoS-Nets") {
                let idx = plan.as_ref().map(|p| p.ops.len()).unwrap_or(1) - 1;
                let overlay = exp.dir.join(format!("params_full_op{idx}.qten"));
                if overlay.exists() {
                    let op2 = pipeline::build_operating_point(&exp, &mname, amap, power, Some(&overlay))?;
                    let r = pipeline::eval_operating_point(&exp, &db, &op2, 32, Some(512))?;
                    format!("{:.2}", 100.0 * (base.top1 - r.top1))
                } else {
                    "n/a".into()
                }
            } else {
                "-".into()
            };
            println!(
                "{:34} {:>9.1}% {:>7} {:>16.2} {:>16}",
                mname,
                100.0 * (1.0 - power),
                distinct.len(),
                100.0 * (base.top1 - raw.top1),
                tuned
            );
        }
        println!("paper reference:");
        for (m, meth, pr, loss) in PAPER.iter().filter(|(m, ..)| *m == format!("resnet{depth}")) {
            let _ = m;
            println!("  {:32} {:>9.1}% {:>24.2}", meth, pr, loss);
        }
        println!();
    }
    Ok(())
}
