//! Server/coordinator benchmarks (§Perf deliverable, L3 coordination):
//! throughput + latency percentiles vs offered load, batcher settings and
//! worker counts; elastic scaling under a burst (stub-backed, always
//! runs); OP-switch cost for both switch modes.

use std::sync::Arc;
use std::time::{Duration, Instant};

use qos_nets::backend::stub::stub_op;
use qos_nets::backend::{OpTable, StubBackend};
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::server::{BatcherConfig, Server, SwitchMode};
use qos_nets::util::rng::Rng;

/// Elastic scaling under a burst: stub backend with a fixed per-batch
/// cost, so the numbers isolate the supervisor/batcher behaviour.
fn elastic_stub_section() -> anyhow::Result<()> {
    println!("=== elastic scaling under a burst (stub backend, 5 ms/batch) ===");
    println!(
        "{:>12} {:>8} {:>10} {:>10} {:>10} {:>10}",
        "max_workers", "reqs", "wall ms", "p99 ms", "peak", "scale +/-"
    );
    for &max_workers in &[1usize, 2, 4] {
        let server = Server::start(
            |_w| Ok(StubBackend::new(10).with_delay(Duration::from_millis(5))),
            OpTable::new(vec![stub_op("only", 1.0)]),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                workers: 1,
                min_workers: 1,
                max_workers,
                scale_interval: Duration::from_millis(10),
                scale_up_queue: 8,
                scale_up_wait: Duration::from_millis(10),
                scale_up_after: 1,
                scale_down_after: 10,
                ..BatcherConfig::default()
            },
        )?;
        let n = 400usize;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|i| server.submit(vec![(i % 10) as f32]).unwrap())
            .collect();
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(30));
        }
        let wall = t0.elapsed();
        let m = server.shutdown().snapshot();
        println!(
            "{:>12} {:>8} {:>10.1} {:>10.2} {:>10} {:>7}/{}",
            max_workers,
            n,
            wall.as_secs_f64() * 1e3,
            m.latency.p99_us as f64 / 1e3,
            m.peak_workers,
            m.scale_ups,
            m.scale_downs
        );
    }
    println!();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // the stub sections need no artifacts, so the bench always reports
    elastic_stub_section()?;

    let Ok(exp) = Experiment::load("artifacts", "quick") else {
        println!("artifacts/quick missing — model-backed server bench skipped");
        return Ok(());
    };
    let db = Arc::new(MulDb::load("artifacts")?);
    let (images, _) = exp.load_testset()?;
    let elems = exp.image_elems();
    let n_img = images.len() / elems;
    let op = pipeline::exact_operating_point(&exp)?;

    println!("=== throughput/latency vs batcher config (2s runs, open loop) ===");
    println!(
        "{:>8} {:>10} {:>8} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "workers", "max_batch", "rate/s", "done/s", "mean ms", "p50 ms", "p99 ms", "batch"
    );
    for &workers in &[1usize, 2, 4] {
        for &max_batch in &[1usize, 8, 16, 32] {
            let server = Server::start_native(
                exp.graph.clone(),
                db.clone(),
                OpTable::new(vec![op.clone()]),
                BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(3),
                    workers,
                    ..BatcherConfig::default()
                },
            )?;
            let rate = 400.0f64;
            let mut rng = Rng::new(5);
            let started = Instant::now();
            let mut rxs = Vec::new();
            while started.elapsed() < Duration::from_secs(2) {
                let i = rng.below(n_img);
                rxs.push(server.submit(images[i * elems..(i + 1) * elems].to_vec())?);
                std::thread::sleep(Duration::from_secs_f64(1.0 / rate));
            }
            let submitted = rxs.len();
            for rx in rxs {
                let _ = rx.recv_timeout(Duration::from_secs(20));
            }
            let wall = started.elapsed().as_secs_f64();
            let m = server.shutdown().snapshot();
            println!(
                "{:>8} {:>10} {:>8.0} {:>10.1} {:>10.2} {:>10.2} {:>10.2} {:>8.2}",
                workers,
                max_batch,
                submitted as f64 / wall,
                m.completed as f64 / wall,
                m.latency.mean_us / 1e3,
                m.latency.p50_us as f64 / 1e3,
                m.latency.p99_us as f64 / 1e3,
                m.mean_batch
            );
        }
    }

    println!("\n=== operating-point switch cost ===");
    let plan = qos_nets::plan::OpPlan::load_for(&exp).ok();
    if let Some((p, pop)) = plan.as_ref().and_then(|p| p.ops.last().map(|o| (p, o))) {
        let op2 = pipeline::build_operating_point(
            &exp,
            "op",
            p.assignment_map(p.ops.len() - 1),
            pop.relative_power,
            None,
        )?;
        let server = Server::start_native(
            exp.graph.clone(),
            db.clone(),
            OpTable::new(vec![op.clone(), op2]),
            BatcherConfig::default(),
        )?;
        let t0 = Instant::now();
        let iters = 10_000;
        for i in 0..iters {
            server.set_operating_point(i % 2);
        }
        let per = t0.elapsed().as_nanos() as f64 / iters as f64;
        println!("set_operating_point(Immediate): {per:.1} ns/switch (atomic store)");

        // the draining barrier round-trips through the batcher thread
        let t0 = Instant::now();
        let drain_iters = 200;
        for i in 0..drain_iters {
            server.set_operating_point_with(i % 2, SwitchMode::Drain)?;
        }
        let per_us = t0.elapsed().as_micros() as f64 / drain_iters as f64;
        println!("set_operating_point(Drain):     {per_us:.1} us/switch (barrier round-trip)");

        // exercise both OPs so the per-OP attribution shows up
        for phase in 0..2usize {
            server.set_operating_point_with(phase, SwitchMode::Drain)?;
            let rxs: Vec<_> = (0..64)
                .map(|j| {
                    let i = j % n_img;
                    server.submit(images[i * elems..(i + 1) * elems].to_vec()).unwrap()
                })
                .collect();
            for rx in rxs {
                let _ = rx.recv_timeout(Duration::from_secs(20));
            }
        }
        let m = server.shutdown().snapshot();
        println!("per-OP latency attribution:");
        for (i, o) in m.per_op.iter().enumerate() {
            println!(
                "  OP{i}: {} requests  mean={:.2} ms  p99<={:.2} ms",
                o.latency.count,
                o.latency.mean_us / 1e3,
                o.latency.p99_us as f64 / 1e3
            );
        }
    }
    Ok(())
}
