//! Server/coordinator benchmarks (§Perf deliverable, L3 coordination):
//! throughput + latency percentiles vs offered load, batcher settings and
//! worker counts; OP-switch cost.

use std::sync::Arc;
use std::time::{Duration, Instant};

use qos_nets::backend::OpTable;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::server::{BatcherConfig, Server};
use qos_nets::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let Ok(exp) = Experiment::load("artifacts", "quick") else {
        println!("artifacts/quick missing — server bench skipped");
        return Ok(());
    };
    let db = Arc::new(MulDb::load("artifacts")?);
    let (images, _) = exp.load_testset()?;
    let elems = exp.image_elems();
    let n_img = images.len() / elems;
    let op = pipeline::exact_operating_point(&exp)?;

    println!("=== throughput/latency vs batcher config (2s runs, open loop) ===");
    println!(
        "{:>8} {:>10} {:>8} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "workers", "max_batch", "rate/s", "done/s", "mean ms", "p50 ms", "p99 ms", "batch"
    );
    for &workers in &[1usize, 2, 4] {
        for &max_batch in &[1usize, 8, 16, 32] {
            let server = Server::start_native(
                exp.graph.clone(),
                db.clone(),
                OpTable::new(vec![op.clone()]),
                BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(3),
                    workers,
                },
            )?;
            let rate = 400.0f64;
            let mut rng = Rng::new(5);
            let started = Instant::now();
            let mut rxs = Vec::new();
            while started.elapsed() < Duration::from_secs(2) {
                let i = rng.below(n_img);
                rxs.push(server.submit(images[i * elems..(i + 1) * elems].to_vec())?);
                std::thread::sleep(Duration::from_secs_f64(1.0 / rate));
            }
            let submitted = rxs.len();
            for rx in rxs {
                let _ = rx.recv_timeout(Duration::from_secs(20));
            }
            let wall = started.elapsed().as_secs_f64();
            let m = server.shutdown();
            println!(
                "{:>8} {:>10} {:>8.0} {:>10.1} {:>10.2} {:>10.2} {:>10.2} {:>8.2}",
                workers,
                max_batch,
                submitted as f64 / wall,
                m.completed as f64 / wall,
                m.latency.mean_us() / 1e3,
                m.latency.percentile_us(50.0) as f64 / 1e3,
                m.latency.percentile_us(99.0) as f64 / 1e3,
                m.mean_batch()
            );
        }
    }

    println!("\n=== operating-point switch cost ===");
    let assignments = pipeline::read_assignment(&exp).unwrap_or_default();
    if let Some((_, power, amap)) = assignments.last() {
        let op2 = pipeline::build_operating_point(&exp, "op", amap.clone(), *power, None)?;
        let server = Server::start_native(
            exp.graph.clone(),
            db.clone(),
            OpTable::new(vec![op.clone(), op2]),
            BatcherConfig::default(),
        )?;
        let t0 = Instant::now();
        let iters = 10_000;
        for i in 0..iters {
            server.set_operating_point(i % 2);
        }
        let per = t0.elapsed().as_nanos() as f64 / iters as f64;
        println!("set_operating_point: {per:.1} ns/switch (atomic store)");
        server.shutdown();
    }
    Ok(())
}
