//! Observability integration tests: the Prometheus exposition contract
//! (stable family names; quantiles equal to the exact
//! `LatencyHistogram::summary()` numbers the reports print), the
//! flight-recorder ring bounds + dump schema round-trip, and the
//! recorded event order across a drained OP switch with a fleet worker
//! behind the fault-injection chaos proxy.
//!
//! The ordering test is the one that pins the tentpole's semantic
//! guarantee: a drain-mode `OpSwitch` event is published only after
//! every surviving worker acked the barrier, so in the recorded
//! sequence every pre-switch `FleetChunk` precedes it and every
//! post-switch one follows it — even when the transport under one
//! worker is splitting and delaying frames.

mod common;

use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::chaos::{ChaosConfig, ChaosProxy};
use common::stub_op;
use qos_nets::backend::{OpTable, StubBackend};
use qos_nets::engine::OperatingPoint;
use qos_nets::fleet::{worker, FleetBackend, FleetStats, WorkerHandle};
use qos_nets::obs::{
    self, EventRecord, FlightDump, ObsEvent, Recorder, Registry, FLIGHT_DUMP_VERSION,
};
use qos_nets::qos::SwitchMode;
use qos_nets::server::{BatcherConfig, Server};
use qos_nets::util::json;

/// Spawn one loopback stub worker; returns its handle and address.
fn stub_worker(delay: Duration, catalog: Vec<OperatingPoint>) -> (WorkerHandle, String) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = worker::spawn(listener, "obs-worker", "", catalog, move |_conn| {
        Ok(StubBackend::new(4).with_delay(delay))
    })
    .unwrap();
    let addr = handle.addr().to_string();
    (handle, addr)
}

#[test]
fn exposition_names_are_stable_and_quantiles_match_the_histogram() {
    let table = OpTable::new(vec![stub_op("hi", 1.0), stub_op("lo", 0.5)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4)),
        table,
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            workers: 2,
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    let mut rxs = Vec::new();
    for i in 0..64 {
        rxs.push(server.submit(vec![(i % 4) as f32, 0.0]).unwrap());
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
    }
    // responses can land a hair before the worker's metrics critical
    // section; wait for the counter, then everything below is stable
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.metrics().completed < 64 {
        assert!(Instant::now() < deadline, "completed counter never reached 64");
        std::thread::sleep(Duration::from_millis(5));
    }

    // a private registry so parallel tests in this binary cannot feed
    // families into the assertion; the collector is the identical
    // closure `serve --metrics-addr` registers globally
    let reg = Registry::default();
    reg.register("server", server.metrics_collector());
    let text = reg.render();

    // the scrape contract: renaming any of these breaks dashboards, so
    // the list is pinned here (event-derived counter families render
    // their headers even with zero samples)
    for name in [
        "qos_nets_requests_completed_total",
        "qos_nets_batches_total",
        "qos_nets_batches_retagged_total",
        "qos_nets_inflight",
        "qos_nets_workers",
        "qos_nets_latency_us",
        "qos_nets_latency_us_count",
        "qos_nets_latency_us_sum",
        "qos_nets_queue_latency_us",
        "qos_nets_op_latency_us",
        "qos_nets_op_requests_total",
        "qos_nets_op_switches_total",
        "qos_nets_autopilot_ticks_total",
        "qos_nets_autopilot_actions_total",
        "qos_nets_scale_events_total",
        "qos_nets_fleet_transitions_total",
        "qos_nets_fleet_heartbeat_misses_total",
        "qos_nets_fleet_requeues_total",
        "qos_nets_fleet_evictions_total",
        "qos_nets_log_messages_total",
        "qos_nets_flight_dumps_total",
    ] {
        assert!(text.contains(&format!("# TYPE {name} ")), "missing family {name} in:\n{text}");
    }

    // quantile samples are exactly the LatencyHistogram::summary()
    // numbers every report prints — same histogram, same bounds
    let m = server.metrics();
    let s = m.latency.summary();
    assert_eq!(reg.value("qos_nets_requests_completed_total", &[]), Some(m.completed as f64));
    assert_eq!(reg.value("qos_nets_latency_us", &[("quantile", "0.5")]), Some(s.p50_us as f64));
    assert_eq!(reg.value("qos_nets_latency_us", &[("quantile", "0.95")]), Some(s.p95_us as f64));
    assert_eq!(reg.value("qos_nets_latency_us", &[("quantile", "0.99")]), Some(s.p99_us as f64));
    assert_eq!(reg.value("qos_nets_latency_us_count", &[]), Some(s.count as f64));
    // per-OP families carry the OP *name* as the label (label order
    // must not matter to lookups)
    assert!(reg.value("qos_nets_op_latency_us", &[("quantile", "0.99"), ("op", "hi")]).is_some());
    assert_eq!(reg.value("qos_nets_op_requests_total", &[("op", "hi")]), Some(64.0));
    assert_eq!(reg.value("qos_nets_op_requests_total", &[("op", "lo")]), Some(0.0));
    server.shutdown();
}

#[test]
fn flight_ring_is_bounded_and_the_dump_schema_round_trips() {
    // capacity bound: 20 in, 8 survive, oldest evicted first
    let rec = Recorder::new(Duration::from_secs(3600), 8);
    for i in 0..20u64 {
        rec.record(EventRecord {
            seq: i,
            t_us: 1_000 + i,
            event: ObsEvent::HeartbeatMiss { addr: format!("w{i}") },
        });
    }
    assert_eq!(rec.len(), 8);
    let seqs: Vec<u64> = rec.snapshot().iter().map(|e| e.seq).collect();
    assert_eq!(seqs, (12..20).collect::<Vec<_>>());

    // retention bound: an event past the window expels what it no
    // longer covers
    let rec2 = Recorder::new(Duration::from_secs(1), 64);
    rec2.record(EventRecord {
        seq: 0,
        t_us: 0,
        event: ObsEvent::Requeue { images: 1, attempts: 1 },
    });
    rec2.record(EventRecord {
        seq: 1,
        t_us: 5_000_000,
        event: ObsEvent::Requeue { images: 2, attempts: 1 },
    });
    assert_eq!(rec2.snapshot().iter().map(|e| e.seq).collect::<Vec<_>>(), vec![1]);

    // dump -> JSON text -> parse -> FlightDump is the identity
    let dump = rec.dump("unit-test");
    assert_eq!(dump.version, FLIGHT_DUMP_VERSION);
    assert_eq!(dump.reason, "unit-test");
    let text = json::to_string_pretty(&dump.to_json());
    let back = FlightDump::from_json(&json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, dump);
    assert!(matches!(&back.events[0].event, ObsEvent::HeartbeatMiss { addr } if addr == "w12"));

    // a wrong version must be a hard error, not a best-effort parse
    let mut wrong = dump.to_json();
    if let json::Json::Obj(pairs) = &mut wrong {
        for (k, v) in pairs.iter_mut() {
            if k == "version" {
                *v = json::Json::num((FLIGHT_DUMP_VERSION + 1) as f64);
            }
        }
    }
    assert!(FlightDump::from_json(&wrong).is_err());

    // the file path dump_to writes is re-readable through the same API
    let dir = std::env::temp_dir().join(format!("qos_nets_obs_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = rec.dump_to(&dir, "unit/test").unwrap();
    let on_disk = std::fs::read_to_string(&path).unwrap();
    let from_disk = FlightDump::from_json(&json::parse(&on_disk).unwrap()).unwrap();
    assert_eq!(from_disk.events.len(), 8);
    assert_eq!(from_disk.reason, "unit/test");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn drain_switch_event_order_holds_under_the_chaos_proxy() {
    let catalog = vec![stub_op("hi", 1.0), stub_op("lo", 0.5)];
    let (h1, a1) = stub_worker(Duration::from_millis(1), catalog.clone());
    let (h2, a2) = stub_worker(Duration::from_millis(1), catalog);
    // one worker behind a jittering transport: every frame split in
    // two and delayed up to 2 ms, so completions reorder across the
    // fleet while the barrier guarantee must still hold
    let proxy = ChaosProxy::spawn(
        a1,
        11,
        ChaosConfig {
            split_writes: true,
            delay: Some((Duration::ZERO, Duration::from_millis(2))),
            ..ChaosConfig::default()
        },
    );
    let proxied = proxy.addr().to_string();
    let stats = FleetStats::default();
    let mut fleet = FleetBackend::connect_with(&[proxied.clone(), a2.clone()], stats).unwrap();

    let rec = Arc::new(Recorder::with_defaults());
    obs::attach_recorder(rec.clone());

    let images: Vec<f32> = (0..16).map(|i| (i % 4) as f32).collect();
    for _ in 0..3 {
        fleet.forward(0, &images, 8).unwrap();
    }
    fleet.set_operating_point(1, SwitchMode::Drain).unwrap();
    for _ in 0..3 {
        fleet.forward(1, &images, 8).unwrap();
    }

    obs::detach_recorder(&rec);
    let events = rec.snapshot();
    // other tests in this binary may publish concurrently (the bus is
    // process-wide), so every filter pins this fleet's addresses
    let mine = |addr: &str| addr == proxied || addr == a2;
    let pre_max = events
        .iter()
        .filter_map(|e| match &e.event {
            ObsEvent::FleetChunk { addr, op: 0, .. } if mine(addr) => Some(e.seq),
            _ => None,
        })
        .max()
        .expect("no pre-switch FleetChunk events recorded");
    let switch_seq = events
        .iter()
        .filter_map(|e| match &e.event {
            ObsEvent::OpSwitch { op: 1, mode, trigger, .. }
                if mode == "drain" && trigger == "fleet" =>
            {
                Some(e.seq)
            }
            _ => None,
        })
        .min()
        .expect("no drain OpSwitch event recorded");
    let post_min = events
        .iter()
        .filter_map(|e| match &e.event {
            ObsEvent::FleetChunk { addr, op: 1, .. } if mine(addr) => Some(e.seq),
            _ => None,
        })
        .min()
        .expect("no post-switch FleetChunk events recorded");
    assert!(
        pre_max < switch_seq,
        "pre-switch chunk (seq {pre_max}) recorded after the drain switch (seq {switch_seq})"
    );
    assert!(
        switch_seq < post_min,
        "post-switch chunk (seq {post_min}) recorded before the drain switch (seq {switch_seq})"
    );

    fleet.shutdown_fleet();
    drop(proxy);
    h1.join();
    h2.join();
}
