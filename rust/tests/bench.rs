//! Integration tests for the `qos-nets bench` load harness: builtin
//! scenario round-trips, malformed-spec rejection, arrival-trace
//! determinism, short end-to-end smoke runs (steady_state on the
//! native synthetic model, ladder_thrash for both switch modes,
//! slo_pressure for the autopilot's shed-before-violate ordering), and
//! schema validation of the committed `BENCH_steady_state.json` and
//! `BENCH_slo_pressure.json` baselines.

use std::path::Path;

use qos_nets::autopilot::OpAction;
use qos_nets::bench::driver::{run_scenario, BenchOpts};
use qos_nets::bench::report::{BenchReport, REPORT_VERSION};
use qos_nets::bench::scenario::{builtin, Scenario, BUILTIN_NAMES};
use qos_nets::bench::{arrivals, synthetic};
use qos_nets::util::json;

#[test]
fn all_builtin_scenarios_round_trip_and_validate() {
    for name in BUILTIN_NAMES {
        let sc = builtin(name).unwrap_or_else(|| panic!("missing builtin {name}"));
        sc.validate().unwrap();
        let text = json::to_string_pretty(&sc.to_json());
        let back = Scenario::from_json(&json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(back, sc, "{name} mutated across the JSON round trip");
    }
}

#[test]
fn malformed_arrival_specs_are_rejected_at_load() {
    // non-positive rate
    let mut sc = builtin("steady_state").unwrap();
    sc.arrivals[0].rate_rps = -3.0;
    let v = json::parse(&json::to_string(&sc.to_json())).unwrap();
    assert!(Scenario::from_json(&v).is_err());

    // empty phase list
    let mut sc = builtin("steady_state").unwrap();
    sc.arrivals.clear();
    let v = json::parse(&json::to_string(&sc.to_json())).unwrap();
    assert!(Scenario::from_json(&v).is_err());

    // unknown process tag straight from JSON text
    let text = r#"{"name":"bad","duration_s":1,"seed":0,"tick_ms":50,"interval_ms":500,
        "arrivals":[{"dur_s":1,"rate_rps":10,"process":"lognormal"}],
        "batch_mix":[{"size":1,"weight":1}],
        "deployment":{"backend":"stub","workers":1,"max_batch":4,"max_wait_ms":2},
        "qos":{"source":"constant","budget":1.0},"events":[]}"#;
    let err = Scenario::from_json(&json::parse(text).unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains("lognormal"), "{err:#}");
}

#[test]
fn same_seed_produces_identical_request_traces() {
    let sc = builtin("flash_crowd").unwrap();
    let pool = synthetic::POOL_IMAGES as u32;
    let a = arrivals::generate(&sc, 3.0, 42, pool);
    let b = arrivals::generate(&sc, 3.0, 42, pool);
    assert_eq!(a, b, "same seed must replay the same trace");
    assert_eq!(arrivals::trace_hash(&a), arrivals::trace_hash(&b));
    let c = arrivals::generate(&sc, 3.0, 43, pool);
    assert_ne!(arrivals::trace_hash(&a), arrivals::trace_hash(&c));
}

#[test]
fn steady_state_smoke_run_emits_a_complete_report() {
    let sc = builtin("steady_state").unwrap();
    let opts = BenchOpts { seed: Some(7), secs: Some(2.0), ..BenchOpts::default() };
    let report = run_scenario(&sc, &opts).unwrap();

    assert_eq!(report.version, REPORT_VERSION);
    assert_eq!(report.scenario, "steady_state");
    assert_eq!(report.provenance.seed, 7);
    assert_eq!(report.provenance.config_hash.len(), 16);
    assert_eq!(report.provenance.trace_hash.len(), 16);
    assert!(report.throughput.submitted > 0, "load generator sent nothing");
    assert!(report.throughput.completed > 0, "server completed nothing");
    assert!(report.throughput.img_per_s > 0.0);
    assert_eq!(report.throughput.ok, report.throughput.submitted, "requests were dropped");
    assert!(report.latency.p99_us >= report.latency.p50_us);
    assert_eq!(report.per_op.len(), 3, "native ladder has three rungs");
    let served: u64 = report.per_op.iter().map(|o| o.requests).sum();
    assert_eq!(served, report.throughput.completed);
    assert!(!report.intervals.is_empty());
    assert!(report.fleet.is_none());

    // the report must survive its own serialization
    let text = json::to_string_pretty(&report.to_json());
    let back = BenchReport::from_json(&json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, report);
}

#[test]
fn identical_seeds_agree_on_provenance_and_trace() {
    let sc = builtin("steady_state").unwrap();
    let opts = BenchOpts { seed: Some(9), secs: Some(1.0), ..BenchOpts::default() };
    let a = run_scenario(&sc, &opts).unwrap();
    let b = run_scenario(&sc, &opts).unwrap();
    assert_eq!(a.provenance.config_hash, b.provenance.config_hash);
    assert_eq!(a.provenance.trace_hash, b.provenance.trace_hash);
    assert_eq!(a.throughput.submitted, b.throughput.submitted);
}

#[test]
fn ladder_thrash_records_both_switch_modes() {
    let sc = builtin("ladder_thrash").unwrap();
    let opts = BenchOpts { seed: Some(19), secs: Some(2.0), ..BenchOpts::default() };
    let report = run_scenario(&sc, &opts).unwrap();
    assert!(report.switches.drain >= 1, "expected a draining upgrade, got {:?}", report.switches);
    assert!(
        report.switches.immediate >= 1,
        "expected an immediate downgrade, got {:?}",
        report.switches
    );
    assert_eq!(
        report.switches.total as usize,
        report.switches.timeline.len(),
        "timeline must account for every switch"
    );
    // the timeline's modes re-add to the counters
    let drain = report.switches.timeline.iter().filter(|r| r.mode == "drain").count() as u64;
    assert_eq!(drain, report.switches.drain);
}

#[test]
fn slo_pressure_smoke_sheds_accuracy_before_violating_the_slo() {
    // truncated to the cruise phase plus half the peak: long enough for
    // the baseline to blow through the SLO and for the autopilot to
    // shed first, short enough for CI (the paired run doubles it)
    let sc = builtin("slo_pressure").unwrap();
    let opts = BenchOpts { seed: Some(29), secs: Some(8.0), ..BenchOpts::default() };
    let report = run_scenario(&sc, &opts).unwrap();

    let ap = report.autopilot.as_ref().expect("slo_pressure must engage the autopilot");
    assert_eq!(ap.slo_p95_ms, 100.0);
    let down = ap.first_downgrade_t_s.expect("the overload must trigger an accuracy shed");
    if let Some(v) = ap.first_violation_t_s {
        assert!(
            down < v,
            "autopilot shed accuracy at {down}s only after the SLO broke at {v}s"
        );
    }
    assert!(!ap.decisions.is_empty(), "decision log must not be empty");
    let base = ap.baseline.as_ref().expect("the paired run embeds the uncontrolled baseline");
    assert!(
        base.slo_violation_ticks > 0,
        "the uncontrolled run should violate the SLO under the peak"
    );
    assert!(!base.p95_timeline.is_empty());

    // the report round-trips with its autopilot section intact
    let text = json::to_string_pretty(&report.to_json());
    let back = BenchReport::from_json(&json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, report);
}

#[test]
fn autopilot_off_run_still_records_the_slo_trajectory() {
    let sc = builtin("slo_pressure").unwrap();
    let opts =
        BenchOpts { seed: Some(29), secs: Some(2.0), autopilot: Some(false), ..BenchOpts::default() };
    let report = run_scenario(&sc, &opts).unwrap();
    let ap = report.autopilot.as_ref().expect("SLO scenarios report their trajectory even when off");
    assert!(ap.decisions.is_empty(), "no autopilot, no decisions");
    assert!(ap.first_downgrade_t_s.is_none());
    let base = ap.baseline.as_ref().expect("an off run doubles as its own baseline");
    assert!(!base.p95_timeline.is_empty());
}

#[test]
fn autopilot_on_requires_an_slo_scenario() {
    let sc = builtin("steady_state").unwrap();
    let opts =
        BenchOpts { seed: Some(7), secs: Some(1.0), autopilot: Some(true), ..BenchOpts::default() };
    let err = run_scenario(&sc, &opts).unwrap_err();
    assert!(format!("{err:#}").contains("slo_p95_ms"), "{err:#}");
}

#[test]
fn committed_baseline_report_parses_and_matches_schema() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_steady_state.json");
    let report = BenchReport::read_from(&path)
        .unwrap_or_else(|e| panic!("committed baseline is schema-stale: {e:#}"));
    assert_eq!(report.version, REPORT_VERSION);
    assert_eq!(report.scenario, "steady_state");
    assert_eq!(report.provenance.seed, 7);
    // the baseline's config hash must match what this build derives
    // from the builtin scenario, so scenario edits force a re-record
    let sc = builtin("steady_state").unwrap();
    assert_eq!(
        report.provenance.config_hash,
        format!("{:016x}", sc.config_hash()),
        "builtin steady_state changed: re-record BENCH_steady_state.json \
         (cargo run --release --no-default-features -- bench --scenario steady_state --seed 7)"
    );
    assert!(report.throughput.completed > 0);
    assert!(!report.intervals.is_empty());
}

#[test]
fn committed_slo_pressure_report_shows_the_autopilot_protecting_the_slo() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_slo_pressure.json");
    let report = BenchReport::read_from(&path)
        .unwrap_or_else(|e| panic!("committed autopilot baseline is schema-stale: {e:#}"));
    assert_eq!(report.version, REPORT_VERSION);
    assert_eq!(report.scenario, "slo_pressure");
    let sc = builtin("slo_pressure").unwrap();
    assert_eq!(
        report.provenance.config_hash,
        format!("{:016x}", sc.config_hash()),
        "builtin slo_pressure changed: re-record BENCH_slo_pressure.json \
         (cargo run --release --no-default-features -- bench --scenario slo_pressure --seed 29)"
    );

    let ap = report.autopilot.as_ref().expect("autopilot section missing");
    assert_eq!(ap.slo_p95_ms, 100.0);
    // the acceptance ordering: accuracy shed strictly before any
    // p95-over-SLO interval, and accuracy recovered afterwards
    let down = ap.first_downgrade_t_s.expect("no accuracy downgrade recorded");
    if let Some(v) = ap.first_violation_t_s {
        assert!(down < v, "downgrade at {down}s must precede the first violation at {v}s");
    }
    assert!(
        ap.decisions.iter().any(|d| d.op_action == OpAction::Up && d.t_s > down),
        "no accuracy recovery after the shed"
    );
    // the uncontrolled run of the same seed sustains SLO violations
    let base = ap.baseline.as_ref().expect("baseline timeline missing");
    assert!(
        base.slo_violation_ticks >= 10,
        "baseline should violate the SLO for a sustained stretch, got {} ticks",
        base.slo_violation_ticks
    );
    assert!(base.first_violation_t_s.is_some());
    assert!(!base.p95_timeline.is_empty());
}

#[test]
fn tenant_contention_smoke_splits_traffic_and_labels_decisions() {
    // truncated two-class run: the classless baseline pass and the
    // tenanted closed-loop pass share one seed, so the report carries
    // the per-class slice next to the uncontrolled trajectory
    let sc = builtin("tenant_contention").unwrap();
    let opts = BenchOpts { seed: Some(31), secs: Some(6.0), ..BenchOpts::default() };
    let report = run_scenario(&sc, &opts).unwrap();

    let tenants = report.tenants.as_ref().expect("tenant_contention must report per-class slices");
    assert_eq!(tenants.len(), 2);
    assert_eq!(tenants[0].name, "premium");
    assert_eq!(tenants[1].name, "best_effort");
    assert!(tenants[0].priority < tenants[1].priority);
    for t in tenants {
        assert!(t.submitted > 0, "class {} got no traffic", t.name);
        assert_eq!(t.rejected, 0, "no admission ceiling in this scenario");
    }
    let total: u64 = tenants.iter().map(|t| t.submitted).sum();
    assert_eq!(total, report.throughput.submitted, "every request belongs to exactly one class");

    // per-class decision records: both pilots ran and stamped their
    // class label into the log
    let ap = report.autopilot.as_ref().expect("tenants ride the autopilot");
    for name in ["premium", "best_effort"] {
        assert!(
            ap.decisions.iter().any(|d| d.class.as_deref() == Some(name)),
            "no decision records for class {name}"
        );
    }
    ap.baseline.as_ref().expect("the paired run embeds the classless baseline");

    // the tenant section survives its own serialization
    let text = json::to_string_pretty(&report.to_json());
    let back = BenchReport::from_json(&json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, report);
}

#[test]
fn committed_tenant_contention_report_shows_premium_shielded_from_shedding() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_tenant_contention.json");
    let report = BenchReport::read_from(&path)
        .unwrap_or_else(|e| panic!("committed tenancy baseline is schema-stale: {e:#}"));
    assert_eq!(report.version, REPORT_VERSION);
    assert_eq!(report.scenario, "tenant_contention");
    let sc = builtin("tenant_contention").unwrap();
    assert_eq!(
        report.provenance.config_hash,
        format!("{:016x}", sc.config_hash()),
        "builtin tenant_contention changed: re-record BENCH_tenant_contention.json \
         (cargo run --release --no-default-features -- bench --scenario tenant_contention --seed 31)"
    );

    let tenants = report.tenants.as_ref().expect("tenant section missing");
    assert_eq!(tenants.len(), 2);
    let premium = &tenants[0];
    let best_effort = &tenants[1];
    assert_eq!(premium.name, "premium");
    assert_eq!(best_effort.name, "best_effort");

    // the acceptance ordering: under the shared overload the premium
    // class's SLO-violation ticks sit strictly below the classless
    // baseline pass of the same seed, and every shed/retagged batch is
    // attributed to best-effort
    let ap = report.autopilot.as_ref().expect("autopilot section missing");
    let base = ap.baseline.as_ref().expect("baseline timeline missing");
    assert!(
        premium.slo_violation_ticks < base.slo_violation_ticks,
        "premium saw {} violation ticks, not below the classless baseline's {}",
        premium.slo_violation_ticks,
        base.slo_violation_ticks
    );
    assert!(
        best_effort.slo_violation_ticks >= premium.slo_violation_ticks,
        "best-effort must absorb the shedding, not premium"
    );
    assert_eq!(premium.rejected, 0, "premium requests were bounced");
    assert_eq!(premium.retagged_batches, 0, "premium batches were retagged to a cheaper rung");
    // the strict-priority envelope squeezed best-effort's ladder, not
    // premium's: every saturated-shed tick is a best-effort tick
    assert_eq!(premium.cap_saturated_ticks, 0);
    assert!(base.slo_violation_ticks >= 10, "baseline should sustain violations under the peak");
}
