//! Backend-trait tests: the unified inference API over the native
//! engine, plus the stub backend driving the evaluate loop, the generic
//! batching server and the QoS controller.

mod common;

use std::time::{Duration, Instant};

use common::{build_tiny, stub_op};
use qos_nets::backend::{self, Backend, NativeBackend, OpTable, StubBackend};
use qos_nets::engine::Engine;
use qos_nets::qos::{QosConfig, QosController};
use qos_nets::server::{BatcherConfig, Server};

/// Acceptance check: OP switching through the trait produces logits
/// identical to the pre-refactor direct-engine path on a fixed seed.
#[test]
fn native_backend_op_switching_matches_direct_engine() {
    let (graph, db, op, images, _, _) = build_tiny();
    let mut frugal = op.clone();
    frugal.name = "frugal".into();
    frugal.assignment.insert("c1".to_string(), 9); // bam7
    frugal.relative_power = 0.6;
    let ops = vec![op, frugal];

    let mut be = NativeBackend::new(graph.clone(), db.clone());
    be.prepare(&ops).unwrap();

    // the reference path: one engine, per-OP forward (what `evaluate`
    // and the server did before the Backend trait existed)
    let mut eng = Engine::new(graph, db);

    // interleave indices to exercise live switching in both directions
    for &i in &[0usize, 1, 0, 1, 1, 0] {
        let got = be.forward(i, &images, 2).unwrap();
        let want = eng.forward(&ops[i], &images, 2).unwrap();
        assert_eq!(got, want, "op {i}: trait path diverged from engine path");
    }
    // both rungs must actually differ, or the switch test is vacuous
    let a = be.forward(0, &images, 2).unwrap();
    let b = be.forward(1, &images, 2).unwrap();
    assert_ne!(a, b, "operating points produced identical logits");
}

#[test]
fn native_backend_rejects_unprepared_index() {
    let (graph, db, op, images, _, _) = build_tiny();
    let mut be = NativeBackend::new(graph, db);
    be.prepare(std::slice::from_ref(&op)).unwrap();
    assert!(be.forward(1, &images, 2).is_err());
}

#[test]
fn backend_reports_model_classes() {
    let (graph, db, ..) = build_tiny();
    let be = NativeBackend::new(graph, db);
    assert_eq!(be.num_classes(), 2);
    assert_eq!(be.name(), "native");
}

#[test]
fn evaluate_counts_top1_and_top5_via_stub() {
    // stub scoring: argmax == first pixel, top-5 == {x0 .. x0+4} mod C
    let classes = 10usize;
    let mut be = StubBackend::new(classes);
    let n = 10usize;
    let images: Vec<f32> = (0..n).map(|i| i as f32).collect(); // 1 elem/image
    let labels: Vec<i32> = (0..n)
        .map(|i| match i {
            0..=4 => i as i32,                      // top-1 hits
            5..=7 => ((i + 2) % classes) as i32,    // top-5 only
            _ => ((i + 7) % classes) as i32,        // misses
        })
        .collect();
    let r = backend::evaluate(&mut be, 0, &images, &labels, 1, 4, None).unwrap();
    assert_eq!(r.n, 10);
    assert!((r.top1 - 0.5).abs() < 1e-9, "top1 {}", r.top1);
    assert!((r.top5 - 0.8).abs() < 1e-9, "top5 {}", r.top5);
    // batch 4 over 10 samples -> 4 + 4 + 2
    assert_eq!(be.forward_calls, vec![(0, 4), (0, 4), (0, 2)]);
}

#[test]
fn evaluate_limit_caps_the_sample_count() {
    let mut be = StubBackend::new(4);
    let images: Vec<f32> = (0..8).map(|i| (i % 4) as f32).collect();
    let labels: Vec<i32> = (0..8).map(|i| (i % 4) as i32).collect();
    let r = backend::evaluate(&mut be, 0, &images, &labels, 1, 3, Some(5)).unwrap();
    assert_eq!(r.n, 5);
    assert!((r.top1 - 1.0).abs() < 1e-9);
}

#[test]
fn generic_server_routes_batches_through_stub_backend() {
    let table = OpTable::new(vec![stub_op("hi", 1.0), stub_op("lo", 0.5)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4)),
        table,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(3),
            workers: 1,
            ..BatcherConfig::default()
        },
    )
    .unwrap();

    // phase 1 on OP0, then switch and serve phase 2 on OP1
    let mut rxs = Vec::new();
    for i in 0..8 {
        if i == 4 {
            std::thread::sleep(Duration::from_millis(40)); // drain phase 1
            server.set_operating_point(1);
        }
        rxs.push(server.submit(vec![(i % 4) as f32, 0.0]).unwrap());
    }
    let mut per_op = [0usize; 2];
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.logits.len(), 4);
        // stub semantics: argmax == first pixel
        let arg = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        assert_eq!(arg, i % 4);
        per_op[resp.op_index] += 1;
    }
    assert!(per_op[0] >= 4, "per_op {per_op:?}");
    assert!(per_op[1] >= 1, "per_op {per_op:?}");
    let m = server.shutdown();
    assert_eq!(m.completed, 8);
    assert_eq!(m.per_op_requests.iter().sum::<u64>(), 8);
}

#[test]
fn server_deadline_flush_completes_partial_batches() {
    // a single sub-max_batch request must still complete, via the
    // deadline-triggered flush
    let table = OpTable::new(vec![stub_op("only", 1.0)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(3)),
        table,
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(10),
            workers: 1,
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    let t0 = Instant::now();
    let rx = server.submit(vec![2.0, 0.0]).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert!(t0.elapsed() < Duration::from_secs(2));
    assert_eq!(resp.logits.len(), 3);
    let m = server.shutdown();
    assert_eq!(m.completed, 1);
    assert_eq!(m.batches, 1);
}

#[test]
fn server_start_fails_when_every_worker_fails() {
    let table = OpTable::new(vec![stub_op("only", 1.0)]);
    let res = Server::<StubBackend>::start(
        |w| Err(anyhow::anyhow!("worker {w}: no accelerator")),
        table,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            workers: 2,
            ..BatcherConfig::default()
        },
    );
    let err = format!("{:#}", res.err().expect("start must fail with zero live workers"));
    assert!(err.contains("every worker failed"), "unexpected error: {err}");
}

#[test]
fn qos_controller_drives_server_with_shuffled_op_table() {
    // the OpTable is NOT power-descending: controller answers must be
    // table indices (carried in LadderEntry), or the server would serve
    // the wrong rung (the ROADMAP-flagged observe() fragility)
    let table = OpTable::new(vec![
        stub_op("mid", 0.7),
        stub_op("accurate", 0.9),
        stub_op("frugal", 0.5),
    ]);
    let mut controller = QosController::new(
        table.ladder(),
        QosConfig {
            upgrade_margin: 0.0,
            min_dwell: Duration::ZERO,
        },
    );
    let server =
        Server::start(|_w| Ok(StubBackend::new(4)), table.clone(), BatcherConfig::default())
            .unwrap();
    let t = Instant::now();
    for (budget, expect_name) in [(1.0, "accurate"), (0.55, "frugal"), (0.75, "mid")] {
        if let Some(idx) = controller.observe(budget, t + Duration::from_millis(1)) {
            server.set_operating_point(idx);
        }
        assert_eq!(
            table.get(server.operating_point()).name,
            expect_name,
            "budget {budget}"
        );
        assert_eq!(controller.current_entry().name, expect_name);
        assert_eq!(controller.current_table_index(), server.operating_point());
    }
    server.shutdown();
}

#[test]
fn qos_controller_drives_generic_server_op_ladder() {
    let table = OpTable::new(vec![
        stub_op("accurate", 0.9),
        stub_op("mid", 0.7),
        stub_op("frugal", 0.5),
    ]);
    let mut controller = QosController::new(
        table.ladder(),
        QosConfig {
            upgrade_margin: 0.0,
            min_dwell: Duration::ZERO,
        },
    );
    let server = Server::start(|_w| Ok(StubBackend::new(4)), table, BatcherConfig::default()).unwrap();

    // budget walk: plenty -> collapse -> recovery; the controller output
    // is applied to the server verbatim
    let t = Instant::now();
    for (budget, expect_op) in [(1.0, 0usize), (0.55, 2), (0.75, 1), (1.0, 0)] {
        if let Some(idx) = controller.observe(budget, t + Duration::from_millis(1)) {
            server.set_operating_point(idx);
        }
        assert_eq!(server.operating_point(), expect_op, "budget {budget}");
        let rx = server.submit(vec![1.0, 0.0]).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.op_index, expect_op);
    }
    server.shutdown();
}
