//! Cross-kernel bit-exactness: every registered [`LutKernel`] (scalar,
//! AVX2 where the host has it, threaded over both) must agree with the
//! naive LUT oracle on every shape — tail M-tiles, odd/even K (the
//! unroll remainder), grouped convs, and whole `Backend::forward`
//! passes across `--kernel` values.  Integer accumulation is exact, so
//! "agree" means `assert_eq!`, not a tolerance.

mod common;

use std::sync::Arc;

use common::{build_residual_grouped, build_tiny};
use qos_nets::backend::{Backend, NativeBackend};
use qos_nets::engine::lutmm::{self, LutKernel, ScalarKernel, ThreadedKernel, M_TILE};
use qos_nets::engine::Engine;
use qos_nets::muldb::MulDb;
use qos_nets::util::rng::Rng;

/// The naive oracle straight off the math: `out[m,n] = Σ_k lut[a, w]`.
fn naive(a: &[i32], w: &[i32], lut: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    for mm in 0..m {
        for nn in 0..n {
            let mut acc = 0;
            for kk in 0..k {
                acc += lut[(a[mm * k + kk] as usize) * 256 + w[kk * n + nn] as usize];
            }
            out[mm * n + nn] = acc;
        }
    }
    out
}

fn transpose(x: &[i32], rows: usize, cols: usize) -> Vec<i32> {
    let mut t = vec![0i32; x.len()];
    for r in 0..rows {
        for c in 0..cols {
            t[c * rows + r] = x[r * cols + c];
        }
    }
    t
}

/// Every kernel under test: the host registry (scalar, avx2 when
/// detected, threaded over the detected kernel) plus explicit threaded
/// configurations that force shard-boundary edge cases.
fn kernels_under_test() -> Vec<Arc<dyn LutKernel>> {
    let mut out = lutmm::available_kernels();
    for threads in [2usize, 3, 64] {
        out.push(Arc::new(ThreadedKernel::new(Arc::new(ScalarKernel), threads)));
        out.push(Arc::new(ThreadedKernel::new(lutmm::detect_kernel(), threads)));
    }
    out
}

#[test]
fn every_kernel_matches_the_naive_oracle_across_the_shape_matrix() {
    let db = MulDb::generate();
    let mut rng = Rng::new(0xC0FFEE);
    let kernels = kernels_under_test();
    // deliberate edges: m around/above M_TILE (tail tiles), odd and
    // even K (2-way unroll remainder), K=1, N=1, single row
    let mut shapes = vec![
        (1usize, 1usize, 1usize),
        (1, 7, 3),
        (5, 2, 9),
        (33, 17, 4),
        (M_TILE - 1, 8, 6),
        (M_TILE, 9, 5),
        (M_TILE + 1, 10, 4),
        (2 * M_TILE + 37, 11, 7),
        (3 * M_TILE, 6, 3),
    ];
    // plus a random sweep
    for _ in 0..6 {
        shapes.push((1 + rng.below(700), 1 + rng.below(40), 1 + rng.below(24)));
    }
    for (m, k, n) in shapes {
        let mid = 1 + rng.below(db.len() - 1);
        let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
        let at = transpose(&a, m, k);
        let wt = transpose(&w, k, n);
        let wlut = lutmm::transpose_lut(db.lut(mid));
        let want = naive(&a, &w, db.lut(mid), m, k, n);
        let (za, zw) = (128i32, 117i32);
        let exact_want = {
            let mut out = vec![0i32; m * n];
            ScalarKernel.exact_corrected(&at, &wt, m, k, n, za, zw, &mut out);
            out
        };
        for kernel in &kernels {
            let mut got = vec![0i32; m * n];
            kernel.matmul_acc(&at, &wt, &wlut, m, k, n, &mut got);
            assert_eq!(got, want, "{}: lut path m{m} k{k} n{n} mid{mid}", kernel.name());
            let mut exact = vec![0i32; m * n];
            kernel.exact_corrected(&at, &wt, m, k, n, za, zw, &mut exact);
            assert_eq!(exact, exact_want, "{}: exact path m{m} k{k} n{n}", kernel.name());
        }
    }
}

#[test]
fn backend_forward_is_identical_across_kernel_flags() {
    // the `--kernel` acceptance check: NativeBackend over each kernel
    // produces bit-identical logits for every prepared OP, on both the
    // exact fast path (multiplier 0) and the LUT path
    let (graph, db, op, images, _, _) = build_tiny();
    let mut approx = op.clone();
    approx.name = "approx".into();
    approx.assignment.insert("c1".to_string(), 9);
    approx.relative_power = 0.6;
    let ops = vec![op, approx];

    let mut reference = NativeBackend::with_kernel(graph.clone(), db.clone(), Arc::new(ScalarKernel));
    reference.prepare(&ops).unwrap();
    let want: Vec<Vec<f32>> = (0..ops.len())
        .map(|i| reference.forward(i, &images, 2).unwrap())
        .collect();

    for kernel in kernels_under_test() {
        let name = kernel.name().to_string();
        let mut be = NativeBackend::with_kernel(graph.clone(), db.clone(), kernel);
        be.prepare(&ops).unwrap();
        for (i, w) in want.iter().enumerate() {
            let got = be.forward(i, &images, 2).unwrap();
            assert_eq!(&got, w, "{name}: OP{i} logits diverged");
        }
    }
}

#[test]
fn grouped_conv_and_residual_graph_agree_across_kernels() {
    let (graph, db, op, images) = build_residual_grouped();
    let mut approx = op.clone();
    approx.name = "approx".into();
    approx.assignment.insert("c2".to_string(), 9); // the grouped layer
    approx.assignment.insert("fc".to_string(), 13);

    let mut reference = Engine::with_kernel(graph.clone(), db.clone(), Arc::new(ScalarKernel));
    let want_exact = reference.forward(&op, &images, 2).unwrap();
    let want_approx = reference.forward(&approx, &images, 2).unwrap();
    assert_ne!(want_exact, want_approx, "approx assignment had no effect");

    for kernel in kernels_under_test() {
        let name = kernel.name().to_string();
        let mut eng = Engine::with_kernel(graph.clone(), db.clone(), kernel);
        assert_eq!(eng.forward(&op, &images, 2).unwrap(), want_exact, "{name}: exact");
        assert_eq!(eng.forward(&approx, &images, 2).unwrap(), want_approx, "{name}: approx");
    }
}

#[test]
fn residual_graph_batch_invariance_with_activation_dropping() {
    // one batch of 4 == four batches of 1 on the multi-consumer graph:
    // pins that the last-use activation dropping never frees a value a
    // later consumer (the add node) still needs
    let (graph, db, op, _) = build_residual_grouped();
    let mut rng = Rng::new(31);
    let elems = 4 * 4 * 2;
    let images: Vec<f32> = (0..4 * elems).map(|_| rng.f64() as f32).collect();
    let mut eng = Engine::with_kernel(graph, db, Arc::new(ScalarKernel));
    let joint = eng.forward(&op, &images, 4).unwrap();
    for b in 0..4 {
        let single = eng.forward(&op, &images[b * elems..(b + 1) * elems], 1).unwrap();
        assert_eq!(&joint[b * 2..(b + 1) * 2], &single[..], "batch member {b}");
    }
}

#[test]
fn default_kernel_is_always_available() {
    // `--kernel auto` must resolve on every host (AVX2 or not)
    let k = lutmm::detect_kernel();
    assert!(!k.name().is_empty());
    let d = lutmm::default_kernel();
    assert!(!d.name().is_empty());
}
