//! Cross-module integration tests.
//!
//! Tests that need the exported `artifacts/quick` bundle skip gracefully
//! when it is absent (run `make artifacts` first); everything else builds
//! its fixtures in-memory (see `tests/common/mod.rs`).

mod common;

use std::path::Path;
use std::sync::Arc;

use common::{build_tiny, naive_reference};
use qos_nets::engine::Engine;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::plan::{self, OpPlan};

fn artifacts_ready() -> bool {
    Path::new("artifacts/quick/exp.json").exists()
}

#[test]
fn engine_matches_naive_reference_exact_multiplier() {
    let (graph, db, op, images, w1, wfc) = build_tiny();
    let mut eng = Engine::new(graph, db);
    let logits = eng.forward(&op, &images, 2).unwrap();
    for b in 0..2 {
        let expect = naive_reference(&images[b * 32..(b + 1) * 32], &w1, &wfc);
        for n in 0..2 {
            let got = logits[b * 2 + n];
            assert!(
                (got - expect[n]).abs() < 1e-4,
                "b{b} n{n}: {got} vs {}",
                expect[n]
            );
        }
    }
}

#[test]
fn engine_approximate_differs_but_is_close() {
    let (graph, db, op, images, _, _) = build_tiny();
    let mut eng = Engine::new(graph.clone(), db.clone());
    let exact = eng.forward(&op, &images, 2).unwrap();

    let mut approx_op = op.clone();
    approx_op.name = "approx".into();
    approx_op.assignment.insert("c1".to_string(), 13); // bamc3: tiny unbiased error
    let approx = eng.forward(&approx_op, &images, 2).unwrap();
    let max_delta: f32 = exact
        .iter()
        .zip(&approx)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(max_delta > 0.0, "approximate multiplier had no effect");
    assert!(max_delta < 0.3, "bamc3 error too large: {max_delta}");
}

#[test]
fn engine_batch_invariance() {
    // one batch of 4 == four batches of 1
    let (graph, db, op, _, _, _) = build_tiny();
    let mut rng = qos_nets::util::rng::Rng::new(3);
    let images: Vec<f32> = (0..4 * 32).map(|_| rng.f64() as f32).collect();
    let mut eng = Engine::new(graph, db);
    let joint = eng.forward(&op, &images, 4).unwrap();
    for b in 0..4 {
        let single = eng.forward(&op, &images[b * 32..(b + 1) * 32], 1).unwrap();
        assert_eq!(&joint[b * 2..(b + 1) * 2], &single[..]);
    }
}

#[test]
fn repreparing_a_same_named_op_with_new_weights_evicts_the_stale_cache() {
    // the wt_cache is keyed by (op, layer, group) but tagged with a
    // weight-code fingerprint: a reloaded plan / full-retrain overlay
    // that changes weights under the same OP name must not be served
    // from the stale transposed codes
    let (graph, db, op, images, _, _) = build_tiny();
    let mut eng = Engine::new(graph.clone(), db.clone());
    eng.prepare_op(&op).unwrap();
    let before = eng.forward(&op, &images, 2).unwrap();

    let mut overlaid = op.clone(); // same name, different weights
    let lp = overlaid.params.layers.get_mut("c1").unwrap();
    for c in lp.w_codes.iter_mut() {
        *c = 255 - *c;
    }
    eng.prepare_op(&overlaid).unwrap();
    let after = eng.forward(&overlaid, &images, 2).unwrap();
    assert_ne!(before, after, "stale weight cache served the old codes");

    // a fresh engine that never saw the original weights agrees
    let mut fresh = Engine::new(graph, db);
    assert_eq!(fresh.forward(&overlaid, &images, 2).unwrap(), after);
}

#[test]
fn lazy_forward_detects_weight_flips_without_prepare() {
    let (graph, db, op, images, _, _) = build_tiny();
    let mut eng = Engine::new(graph, db);
    let before = eng.forward(&op, &images, 2).unwrap();
    let mut overlaid = op.clone();
    let lp = overlaid.params.layers.get_mut("c1").unwrap();
    for c in lp.w_codes.iter_mut() {
        *c = 255 - *c;
    }
    let after = eng.forward(&overlaid, &images, 2).unwrap();
    assert_ne!(before, after, "lazy cache path served stale codes");
}

#[test]
fn engine_prepare_op_is_equivalent_to_lazy_caching() {
    let (graph, db, op, images, _, _) = build_tiny();
    let mut lazy = Engine::new(graph.clone(), db.clone());
    let want = lazy.forward(&op, &images, 2).unwrap();

    let mut eager = Engine::new(graph, db);
    eager.prepare_op(&op).unwrap();
    let got = eager.forward(&op, &images, 2).unwrap();
    assert_eq!(got, want);
}

// ---------------------------------------------------------------------------
// Artifact-backed tests (skip when `make artifacts` has not run).
// ---------------------------------------------------------------------------

#[test]
fn quick_experiment_loads_and_searches() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/quick missing");
        return;
    }
    let exp = Experiment::load("artifacts", "quick").unwrap();
    let db = Arc::new(MulDb::load("artifacts").unwrap());
    assert_eq!(exp.layer_names.len(), exp.sigma_g.len());
    assert_eq!(db.len(), 37);
    let sol = plan::plan_experiment("qos", &exp, &db).unwrap();
    assert!(sol.subset.len() <= exp.n_multipliers());
    assert_eq!(sol.ops.len(), exp.scales().len());
    for op in &sol.ops {
        assert!(op.relative_power > 0.0 && op.relative_power <= 1.0);
        assert_eq!(op.assignment.len(), exp.layer_names.len());
    }
    // determinism: the whole typed artifact, provenance included
    let sol2 = plan::plan_experiment("qos", &exp, &db).unwrap();
    assert_eq!(sol, sol2);
}

#[test]
fn quick_exact_eval_beats_chance_by_far() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/quick missing");
        return;
    }
    let exp = Experiment::load("artifacts", "quick").unwrap();
    let db = Arc::new(MulDb::load("artifacts").unwrap());
    let op = pipeline::exact_operating_point(&exp).unwrap();
    let r = pipeline::eval_operating_point(&exp, &db, &op, 32, Some(128)).unwrap();
    assert!(r.top1 > 0.5, "exact top1 {} too low", r.top1);
}

#[test]
fn assignment_roundtrip_through_json() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/quick missing");
        return;
    }
    let exp = Experiment::load("artifacts", "quick").unwrap();
    let db = Arc::new(MulDb::load("artifacts").unwrap());
    let plan = plan::plan_experiment("qos", &exp, &db).unwrap();
    plan.save_for(&exp).unwrap();
    let read = OpPlan::load_for(&exp).unwrap();
    // the full typed artifact survives the disk round trip
    assert_eq!(read, plan);
    // and the assignment maps keep the layer -> multiplier pairing
    for (op_idx, op) in plan.ops.iter().enumerate() {
        let amap = read.assignment_map(op_idx);
        for (k, name) in exp.layer_names.iter().enumerate() {
            assert_eq!(amap[name], op.assignment[k]);
        }
    }
}

// ---------------------------------------------------------------------------
// Server integration (in-memory model, native backend).
// ---------------------------------------------------------------------------

#[test]
fn server_round_trip_and_op_switching() {
    use qos_nets::backend::OpTable;
    use qos_nets::server::{BatcherConfig, Server};
    use std::time::Duration;

    let (graph, db, op, images, _, _) = build_tiny();
    let mut op2 = op.clone();
    op2.name = "frugal".into();
    op2.assignment.insert("c1".to_string(), 9);
    op2.relative_power = 0.6;

    let server = Server::start_native(
        graph,
        db,
        OpTable::new(vec![op, op2]),
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            workers: 2,
            ..BatcherConfig::default()
        },
    )
    .unwrap();

    let mut rxs = Vec::new();
    for i in 0..20 {
        if i == 10 {
            // let phase-1 batches drain before switching so both OPs serve
            std::thread::sleep(Duration::from_millis(50));
            server.set_operating_point(1);
        }
        rxs.push(server.submit(images[..32].to_vec()).unwrap());
    }
    let mut op_seen = [0usize; 2];
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(resp.logits.len(), 2);
        op_seen[resp.op_index] += 1;
    }
    assert!(op_seen[0] > 0 && op_seen[1] > 0, "both OPs must serve: {op_seen:?}");
    let m = server.shutdown();
    assert_eq!(m.completed, 20);
    assert!(m.mean_batch() >= 1.0);
}
