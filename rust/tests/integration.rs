//! Cross-module integration tests.
//!
//! Tests that need the exported `artifacts/quick` bundle skip gracefully
//! when it is absent (run `make artifacts` first); everything else builds
//! its fixtures in-memory.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use qos_nets::engine::{Engine, OperatingPoint};
use qos_nets::muldb::MulDb;
use qos_nets::nn::{Graph, LayerParams, ModelParams};
use qos_nets::pipeline::{self, Experiment};
use qos_nets::util::json;

fn artifacts_ready() -> bool {
    Path::new("artifacts/quick/exp.json").exists()
}

// ---------------------------------------------------------------------------
// In-memory fixture: a 1-conv + dense graph with hand-built parameters,
// checked against a naive f32 reference convolution.
// ---------------------------------------------------------------------------

fn tiny_graph_json() -> json::Json {
    json::parse(
        r#"{
        "name": "tiny", "input_shape": [4, 4, 2], "total_macs": 1184,
        "nodes": [
          {"id":0,"kind":"input","inputs":[],"name":"input","out_shape":[4,4,2]},
          {"id":1,"kind":"conv","inputs":[0],"name":"c1","out_shape":[4,4,4],
           "cin":2,"cout":4,"ksize":3,"stride":1,"pad":1,"groups":1,
           "has_bn":false,"act":"relu","macs_per_out":18,"macs_total":1152,
           "quant":{"in":{"scale":0.01,"zero_point":128},"w":{"scale":0.02,"zero_point":128}}},
          {"id":2,"kind":"gap","inputs":[1],"name":"gap","out_shape":[4]},
          {"id":3,"kind":"dense","inputs":[2],"name":"fc","out_shape":[2],
           "cin":4,"cout":2,"ksize":0,"stride":1,"pad":0,"groups":1,
           "has_bn":false,"act":"none","macs_per_out":4,"macs_total":8,
           "quant":{"in":{"scale":0.02,"zero_point":100},"w":{"scale":0.02,"zero_point":128}}},
          {"id":4,"kind":"output","inputs":[3],"name":"output","out_shape":[2]}
        ]}"#,
    )
    .unwrap()
}

/// Naive float conv reference with quantize->dequantize operand semantics.
#[allow(clippy::needless_range_loop)]
fn naive_reference(images: &[f32], w1: &[f32], wfc: &[f32]) -> Vec<f32> {
    let (h, wd, cin, cout) = (4usize, 4usize, 2usize, 4usize);
    let q = |x: f32, s: f32, z: i32| -> f32 {
        let code = ((x / s).round_ties_even() as i32 + z).clamp(0, 255);
        s * (code - z) as f32
    };
    // conv, pad 1, stride 1, relu
    let mut conv = vec![0f32; h * wd * cout];
    for oy in 0..h {
        for ox in 0..wd {
            for oc in 0..cout {
                let mut acc = 0f32;
                for ky in 0..3usize {
                    for kx in 0..3usize {
                        let iy = oy as isize + ky as isize - 1;
                        let ix = ox as isize + kx as isize - 1;
                        if iy < 0 || iy >= h as isize || ix < 0 || ix >= wd as isize {
                            continue;
                        }
                        for ic in 0..cin {
                            let xv = q(images[((iy as usize) * wd + ix as usize) * cin + ic], 0.01, 128);
                            let wv = q(w1[((ky * 3 + kx) * cin + ic) * cout + oc], 0.02, 128);
                            acc += xv * wv;
                        }
                    }
                }
                conv[(oy * wd + ox) * cout + oc] = acc.max(0.0);
            }
        }
    }
    // gap
    let mut pooled = vec![0f32; cout];
    for pos in 0..h * wd {
        for c in 0..cout {
            pooled[c] += conv[pos * cout + c];
        }
    }
    for c in 0..cout {
        pooled[c] /= (h * wd) as f32;
    }
    // dense
    let mut out = vec![0f32; 2];
    for n in 0..2 {
        for k in 0..cout {
            out[n] += q(pooled[k], 0.02, 100) * q(wfc[k * 2 + n], 0.02, 128);
        }
    }
    out
}

fn build_tiny() -> (Arc<Graph>, Arc<MulDb>, OperatingPoint, Vec<f32>, Vec<f32>, Vec<f32>) {
    let graph = Arc::new(Graph::from_json(&tiny_graph_json()).unwrap());
    let db = Arc::new(MulDb::generate());
    let mut rng = qos_nets::util::rng::Rng::new(11);
    let w1: Vec<f32> = (0..3 * 3 * 2 * 4).map(|_| rng.normal() as f32 * 0.2).collect();
    let wfc: Vec<f32> = (0..4 * 2).map(|_| rng.normal() as f32 * 0.3).collect();
    let images: Vec<f32> = (0..2 * 4 * 4 * 2).map(|_| rng.f64() as f32).collect();

    let q_codes = |w: &[f32], s: f32, z: i32| -> Vec<i32> {
        w.iter()
            .map(|&x| ((x / s).round_ties_even() as i32 + z).clamp(0, 255))
            .collect()
    };
    let mut layers = HashMap::new();
    layers.insert(
        "c1".to_string(),
        LayerParams {
            w_codes: q_codes(&w1, 0.02, 128),
            w_shape: vec![3, 3, 2, 4],
            post_scale: vec![0.01 * 0.02; 4],
            post_bias: vec![0.0; 4],
        },
    );
    layers.insert(
        "fc".to_string(),
        LayerParams {
            w_codes: q_codes(&wfc, 0.02, 128),
            w_shape: vec![4, 2],
            post_scale: vec![0.02 * 0.02; 2],
            post_bias: vec![0.0; 2],
        },
    );
    let op = OperatingPoint {
        name: "exact".into(),
        assignment: [("c1".to_string(), 0usize), ("fc".to_string(), 0usize)]
            .into_iter()
            .collect(),
        params: ModelParams { layers },
        relative_power: 1.0,
    };
    (graph, db, op, images, w1, wfc)
}

#[test]
fn engine_matches_naive_reference_exact_multiplier() {
    let (graph, db, op, images, w1, wfc) = build_tiny();
    let mut eng = Engine::new(graph, db);
    let logits = eng.forward(&op, &images, 2).unwrap();
    for b in 0..2 {
        let expect = naive_reference(&images[b * 32..(b + 1) * 32], &w1, &wfc);
        for n in 0..2 {
            let got = logits[b * 2 + n];
            assert!(
                (got - expect[n]).abs() < 1e-4,
                "b{b} n{n}: {got} vs {}",
                expect[n]
            );
        }
    }
}

#[test]
fn engine_approximate_differs_but_is_close() {
    let (graph, db, op, images, _, _) = build_tiny();
    let mut eng = Engine::new(graph.clone(), db.clone());
    let exact = eng.forward(&op, &images, 2).unwrap();

    let mut approx_op = op.clone();
    approx_op.assignment.insert("c1".to_string(), 13); // bamc3: tiny unbiased error
    let approx = eng.forward(&approx_op, &images, 2).unwrap();
    let max_delta: f32 = exact
        .iter()
        .zip(&approx)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(max_delta > 0.0, "approximate multiplier had no effect");
    assert!(max_delta < 0.3, "bamc3 error too large: {max_delta}");
}

#[test]
fn engine_batch_invariance() {
    // one batch of 4 == four batches of 1
    let (graph, db, op, _, _, _) = build_tiny();
    let mut rng = qos_nets::util::rng::Rng::new(3);
    let images: Vec<f32> = (0..4 * 32).map(|_| rng.f64() as f32).collect();
    let mut eng = Engine::new(graph, db);
    let joint = eng.forward(&op, &images, 4).unwrap();
    for b in 0..4 {
        let single = eng.forward(&op, &images[b * 32..(b + 1) * 32], 1).unwrap();
        assert_eq!(&joint[b * 2..(b + 1) * 2], &single[..]);
    }
}

// ---------------------------------------------------------------------------
// Artifact-backed tests (skip when `make artifacts` has not run).
// ---------------------------------------------------------------------------

#[test]
fn quick_experiment_loads_and_searches() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/quick missing");
        return;
    }
    let exp = Experiment::load("artifacts", "quick").unwrap();
    let db = Arc::new(MulDb::load("artifacts").unwrap());
    assert_eq!(exp.layer_names.len(), exp.sigma_g.len());
    let (se, sol) = pipeline::run_search(&exp, &db);
    assert_eq!(se.m, 37);
    assert!(sol.subset.len() <= exp.n_multipliers());
    assert_eq!(sol.assignment.len(), exp.scales().len());
    for p in &sol.power {
        assert!(*p > 0.0 && *p <= 1.0);
    }
    // determinism
    let (_, sol2) = pipeline::run_search(&exp, &db);
    assert_eq!(sol.assignment, sol2.assignment);
}

#[test]
fn quick_exact_eval_beats_chance_by_far() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/quick missing");
        return;
    }
    let exp = Experiment::load("artifacts", "quick").unwrap();
    let db = Arc::new(MulDb::load("artifacts").unwrap());
    let op = pipeline::exact_operating_point(&exp).unwrap();
    let r = pipeline::eval_operating_point(&exp, &db, &op, 32, Some(128)).unwrap();
    assert!(r.top1 > 0.5, "exact top1 {} too low", r.top1);
}

#[test]
fn assignment_roundtrip_through_json() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/quick missing");
        return;
    }
    let exp = Experiment::load("artifacts", "quick").unwrap();
    let db = Arc::new(MulDb::load("artifacts").unwrap());
    let (_, sol) = pipeline::run_search(&exp, &db);
    pipeline::write_assignment(&exp, &db, &sol).unwrap();
    let read = pipeline::read_assignment(&exp).unwrap();
    assert_eq!(read.len(), sol.assignment.len());
    for (op_idx, (_, power, amap)) in read.iter().enumerate() {
        assert!((power - sol.power[op_idx]).abs() < 1e-9);
        for (k, name) in exp.layer_names.iter().enumerate() {
            assert_eq!(amap[name], sol.assignment[op_idx][k]);
        }
    }
}

// ---------------------------------------------------------------------------
// Server integration (in-memory model).
// ---------------------------------------------------------------------------

#[test]
fn server_round_trip_and_op_switching() {
    use qos_nets::server::{BatcherConfig, Server};
    use std::time::Duration;

    let (graph, db, op, images, _, _) = build_tiny();
    let mut op2 = op.clone();
    op2.name = "frugal".into();
    op2.assignment.insert("c1".to_string(), 9);
    op2.relative_power = 0.6;

    let server = Server::start(
        graph,
        db,
        vec![op, op2],
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            workers: 2,
        },
    )
    .unwrap();

    let mut rxs = Vec::new();
    for i in 0..20 {
        if i == 10 {
            // let phase-1 batches drain before switching so both OPs serve
            std::thread::sleep(Duration::from_millis(50));
            server.set_operating_point(1);
        }
        rxs.push(server.submit(images[..32].to_vec()).unwrap());
    }
    let mut op_seen = [0usize; 2];
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(resp.logits.len(), 2);
        op_seen[resp.op_index] += 1;
    }
    assert!(op_seen[0] > 0 && op_seen[1] > 0, "both OPs must serve: {op_seen:?}");
    let m = server.shutdown();
    assert_eq!(m.completed, 20);
    assert!(m.mean_batch() >= 1.0);
}
