//! Planner/OpPlan tests: the JSON round trip, legacy `assignment.json`
//! compatibility, and the registry contract (every registered planner
//! resolves and produces a budget-respecting plan) — all in-memory, no
//! exported artifacts needed.

mod common;

use common::synthetic_stats;
use qos_nets::errmodel::{self, SigmaE};
use qos_nets::muldb::MulDb;
use qos_nets::nn::LayerStats;
use qos_nets::plan::{self, OpPlan, PlanInputs, Planner, QosNetsPlanner};
use qos_nets::util::json;

struct Fixture {
    db: MulDb,
    se: SigmaE,
    sigma_g: Vec<f64>,
    stats: Vec<LayerStats>,
    layer_names: Vec<String>,
}

fn fixture(l: usize) -> Fixture {
    let db = MulDb::generate();
    let stats = synthetic_stats(l);
    let se = errmodel::sigma_e(&db, &stats);
    // generous tolerances so every mapper has room to move
    let sigma_g: Vec<f64> = (0..l).map(|i| 0.05 + 0.03 * i as f64).collect();
    let layer_names: Vec<String> = (0..l).map(|i| format!("l{i}")).collect();
    Fixture {
        db,
        se,
        sigma_g,
        stats,
        layer_names,
    }
}

fn inputs(f: &Fixture) -> PlanInputs<'_> {
    PlanInputs {
        db: &f.db,
        se: &f.se,
        sigma_g: &f.sigma_g,
        stats: &f.stats,
        layer_names: &f.layer_names,
        scales: vec![0.3, 1.0],
        n_multipliers: 4,
        seed: 7,
        experiment: "synthetic".into(),
    }
}

#[test]
fn opplan_json_roundtrip_is_lossless() {
    let f = fixture(10);
    let plan = QosNetsPlanner.plan(&inputs(&f)).unwrap();
    assert!(plan.kmeans_inertia.is_some());
    assert!(plan.provenance.is_some());

    // serialize -> print -> parse -> deserialize must reproduce the
    // typed artifact exactly (version, provenance, floats included)
    let text = json::to_string_pretty(&plan.to_json());
    let parsed = json::parse(&text).unwrap();
    let back = OpPlan::from_json(&parsed).unwrap();
    assert_eq!(back, plan);

    // and a second hop stays fixed (no drift through the writer)
    let text2 = json::to_string_pretty(&back.to_json());
    assert_eq!(text2, text);
}

#[test]
fn opplan_save_load_roundtrip_on_disk() {
    let f = fixture(6);
    let plan = QosNetsPlanner.plan(&inputs(&f)).unwrap();
    let path = std::env::temp_dir().join(format!("qos_nets_plan_test_{}.json", std::process::id()));
    plan.save(&path).unwrap();
    let back = OpPlan::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(back, plan);
}

#[test]
fn legacy_assignment_without_version_still_loads() {
    // the exact shape solution_to_json wrote in PR 0-2: no version, no
    // layer_names header, no per-OP names, no provenance
    let legacy = r#"{
        "experiment": "quick",
        "n_multipliers": 3,
        "subset": [
            {"id": 0, "name": "am8u_exact", "power": 1.0},
            {"id": 9, "name": "am8u_bam7", "power": 0.55}
        ],
        "operating_points": [
            {"index": 0, "scale": 0.3, "relative_power": 0.9,
             "assignment": {"c1": 0, "c2": 9, "fc": 0}},
            {"index": 1, "scale": 1.0, "relative_power": 0.6,
             "assignment": {"c1": 9, "c2": 9, "fc": 0}}
        ],
        "kmeans_inertia": 1.25
    }"#;
    let plan = OpPlan::from_json(&json::parse(legacy).unwrap()).unwrap();
    assert_eq!(plan.version, 0, "legacy files parse as version 0");
    assert_eq!(plan.experiment, "quick");
    assert_eq!(plan.n_multipliers, 3);
    // the layer header is recovered from assignment key order
    assert_eq!(plan.layer_names, vec!["c1", "c2", "fc"]);
    assert_eq!(plan.ops.len(), 2);
    assert_eq!(plan.ops[0].name, "op0");
    assert_eq!(plan.ops[0].scale, 0.3);
    assert_eq!(plan.ops[0].assignment, vec![0, 9, 0]);
    assert_eq!(plan.ops[1].assignment, vec![9, 9, 0]);
    assert_eq!(plan.ops[1].relative_power, 0.6);
    assert_eq!(plan.subset.len(), 2);
    assert_eq!(plan.subset[1].id, 9);
    assert_eq!(plan.kmeans_inertia, Some(1.25));
    assert!(plan.provenance.is_none());

    // re-serializing a legacy plan upgrades it to the current version
    let upgraded = OpPlan::from_json(&plan.to_json()).unwrap();
    assert_eq!(upgraded.version, plan::PLAN_VERSION);
    assert_eq!(upgraded.layer_names, plan.layer_names);
    assert_eq!(upgraded.ops, plan.ops);
}

#[test]
fn newer_plan_versions_are_rejected_not_defaulted() {
    // a future format must fail loudly instead of parsing into
    // defaulted (exact-multiplier) assignments
    let future = r#"{"version": 2, "operating_points": []}"#;
    let err = OpPlan::from_json(&json::parse(future).unwrap()).unwrap_err();
    assert!(err.to_string().contains("version 2"), "{err:#}");
}

#[test]
fn registry_resolves_every_planner_and_plans_respect_budgets() {
    let f = fixture(8);
    let ins = inputs(&f);
    for name in plan::PLANNER_NAMES {
        let planner = plan::planner_by_name(name)
            .unwrap_or_else(|| panic!("registered planner {name:?} must resolve"));
        assert_eq!(planner.name(), name);
        assert!(!planner.describe().is_empty());

        let p = planner.plan(&ins).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(p.version, plan::PLAN_VERSION);
        assert_eq!(p.experiment, "synthetic");
        assert_eq!(p.layer_names, f.layer_names);
        assert_eq!(p.ops.len(), 2, "{name}: one OP per scale");
        for op in &p.ops {
            assert_eq!(op.assignment.len(), f.layer_names.len(), "{name}");
            assert!(op.relative_power > 0.0 && op.relative_power <= 1.0, "{name}");
            for &mid in &op.assignment {
                assert!(mid < f.db.len(), "{name}: multiplier id {mid} out of range");
            }
        }
        // the deployed subset never exceeds the budget the plan declares
        assert!(!p.subset.is_empty(), "{name}");
        assert!(
            p.subset.len() <= p.n_multipliers,
            "{name}: subset {} > declared budget {}",
            p.subset.len(),
            p.n_multipliers
        );
        // the QoS-Nets planner additionally honors the *shared* budget n
        if name == "qos" {
            assert!(p.subset.len() <= ins.n_multipliers);
            assert!(p.kmeans_inertia.is_some());
        }
        let prov = p.provenance.expect("planners stamp provenance");
        assert_eq!(prov.planner, name);
        assert_eq!(prov.seed, ins.seed);
    }
}

#[test]
fn diff_of_identical_plans_is_empty() {
    let f = fixture(8);
    let plan = QosNetsPlanner.plan(&inputs(&f)).unwrap();
    let d = plan.diff(&plan.clone());
    assert!(d.is_same_deployment(), "{d:?}");
    assert_eq!(d.ops.len(), plan.ops.len());
    for op in &d.ops {
        assert!(op.changed.is_empty());
        assert_eq!(op.power_delta(), Some(0.0));
    }
    assert!(d.subset_only_a.is_empty());
    assert!(d.subset_only_b.is_empty());
    // provenance travels on both sides
    assert_eq!(d.provenance_a, plan.provenance);
    assert_eq!(d.provenance_b, plan.provenance);
}

#[test]
fn diff_reports_layer_power_subset_and_ladder_length_deltas() {
    let f = fixture(6);
    let a = QosNetsPlanner.plan(&inputs(&f)).unwrap();

    // b: perturb one layer of OP0, change its power, and drop the last
    // OP from the ladder entirely
    let mut b = a.clone();
    let old_mid = b.ops[0].assignment[2];
    let new_mid = old_mid + 1;
    b.ops[0].assignment[2] = new_mid;
    b.ops[0].relative_power = a.ops[0].relative_power + 0.05;
    let dropped = b.ops.pop().expect("fixture plans have two OPs");

    let d = a.diff(&b);
    assert!(!d.is_same_deployment());
    assert_eq!(d.ops.len(), a.ops.len());

    // OP0: exactly the perturbed layer, with the exact from/to ids
    let op0 = &d.ops[0];
    assert_eq!(op0.changed.len(), 1);
    assert_eq!(op0.changed[0].layer, f.layer_names[2]);
    assert_eq!(op0.changed[0].from, Some(old_mid));
    assert_eq!(op0.changed[0].to, Some(new_mid));
    let delta = op0.power_delta().unwrap();
    assert!((delta - 0.05).abs() < 1e-12, "power delta {delta}");

    // the dropped OP shows up as a-only with every layer changed to None
    let last = d.ops.last().unwrap();
    assert_eq!(last.name_a.as_deref(), Some(dropped.name.as_str()));
    assert_eq!(last.name_b, None);
    assert_eq!(last.power_delta(), None);
    assert_eq!(last.changed.len(), f.layer_names.len());
    assert!(last.changed.iter().all(|c| c.to.is_none()));
}

#[test]
fn diff_tracks_subset_membership_changes() {
    let f = fixture(6);
    let a = QosNetsPlanner.plan(&inputs(&f)).unwrap();
    let mut b = a.clone();
    // retarget every use of one approximate subset member to id 0 (the
    // exact multiplier) and rebuild b's subset; the subset is derived
    // from the assignments, so the member is guaranteed to be in use
    let Some(gone) = b.subset.iter().map(|m| m.id).rfind(|&id| id != 0) else {
        // an all-exact plan has nothing to retarget; the fixture's
        // generous tolerances make this unreachable in practice
        return;
    };
    for op in &mut b.ops {
        for mid in &mut op.assignment {
            if *mid == gone {
                *mid = 0;
            }
        }
    }
    b.subset.retain(|m| m.id != gone);
    if !b.subset.iter().any(|m| m.id == 0) {
        b.subset.insert(
            0,
            plan::MulRef {
                id: 0,
                name: "am8u_exact".into(),
                power: 1.0,
            },
        );
    }
    let d = a.diff(&b);
    assert!(d.subset_only_a.contains(&gone), "{:?}", d.subset_only_a);
    assert!(!d.subset_only_b.contains(&gone));
    // and the assignment deltas point at the retargeted layers
    let total_changed: usize = d.ops.iter().map(|o| o.changed.len()).sum();
    assert!(total_changed > 0);
    assert!(d
        .ops
        .iter()
        .flat_map(|o| o.changed.iter())
        .all(|c| c.from == Some(gone) && c.to == Some(0)));
}

#[test]
fn unknown_planner_name_does_not_resolve() {
    assert!(plan::planner_by_name("nope").is_none());
    assert!(plan::planner_by_name("").is_none());
}

#[test]
fn plan_ladder_feeds_the_qos_controller() {
    use qos_nets::qos::{QosConfig, QosController};

    let f = fixture(8);
    let p = QosNetsPlanner.plan(&inputs(&f)).unwrap();
    let ladder = p.ladder();
    assert_eq!(ladder.len(), p.ops.len());
    for (i, e) in ladder.iter().enumerate() {
        assert_eq!(e.table_index, i);
        assert_eq!(e.name, p.ops[i].name);
    }
    // a controller built straight from the stored plan answers in plan
    // (= OpTable) indices
    let mut c = QosController::new(ladder, QosConfig::default());
    let idx = c
        .observe(1.0, std::time::Instant::now())
        .unwrap_or_else(|| c.current_table_index());
    assert!(idx < p.ops.len());
}
