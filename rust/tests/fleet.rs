//! Fleet integration tests: loopback worker daemons on `127.0.0.1:0`
//! driven by a coordinator `FleetBackend` — bit-exactness against a
//! single local `NativeBackend`, failure injection (a worker killed
//! mid-stream must not lose a request), heartbeat-timeout eviction,
//! fleet-wide drain-barrier ordering, and the raw wire conversation.

mod common;

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{build_tiny, stub_op};
use qos_nets::backend::{Backend, NativeBackend, OpTable, StubBackend};
use qos_nets::engine::OperatingPoint;
use qos_nets::fleet::wire::{self, Frame, LadderRung, PROTOCOL_VERSION};
use qos_nets::fleet::{worker, FleetBackend, FleetStats, WorkerHandle, WorkerOptions};
use qos_nets::qos::SwitchMode;
use qos_nets::server::{BatcherConfig, Server};

/// Spawn one loopback stub worker; returns its handle and address.
fn stub_worker(
    classes: usize,
    delay: Duration,
    catalog: Vec<OperatingPoint>,
) -> (WorkerHandle, String) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = worker::spawn(listener, "stub-worker", "", catalog, move |_conn| {
        Ok(StubBackend::new(classes).with_delay(delay))
    })
    .unwrap();
    let addr = handle.addr().to_string();
    (handle, addr)
}

fn stub_catalog() -> Vec<OperatingPoint> {
    vec![stub_op("hi", 1.0), stub_op("lo", 0.5)]
}

#[test]
fn loopback_fleet_is_bit_identical_to_single_native_backend() {
    let (graph, db, op, images, _, _) = build_tiny();
    let mut frugal = op.clone();
    frugal.name = "frugal".into();
    frugal.assignment.insert("c1".to_string(), 9); // bam7
    frugal.relative_power = 0.6;
    let ops = vec![op, frugal];

    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let g = graph.clone();
        let d = db.clone();
        let handle = worker::spawn(listener, "native-worker", "bn", ops.clone(), move |_conn| {
            Ok(NativeBackend::new(g.clone(), d.clone()))
        })
        .unwrap();
        addrs.push(handle.addr().to_string());
        handles.push(handle);
    }

    let mut fleet = FleetBackend::connect(&addrs).unwrap();
    fleet.prepare(&ops).unwrap();
    assert_eq!(fleet.name(), "fleet");

    let mut local = NativeBackend::new(graph, db);
    local.prepare(&ops).unwrap();
    assert_eq!(fleet.num_classes(), local.num_classes());

    // the same request stream through both paths, interleaving OP
    // switches and batch sizes (1 exercises batch < workers; odd sizes
    // exercise uneven splits)
    let elems = images.len() / 2;
    for round in 0..4usize {
        for &op_idx in &[0usize, 1, 0] {
            let batch = 1 + (round + op_idx) % 5;
            let mut buf = Vec::with_capacity(batch * elems);
            for i in 0..batch {
                let src = (i + round) % 2;
                buf.extend_from_slice(&images[src * elems..(src + 1) * elems]);
            }
            let got = fleet.forward(op_idx, &buf, batch).unwrap();
            let want = local.forward(op_idx, &buf, batch).unwrap();
            assert_eq!(got, want, "round {round} op {op_idx} batch {batch}: fleet diverged");
        }
    }

    // orderly teardown: every worker daemon acks Shutdown and exits
    assert_eq!(fleet.shutdown_fleet(), 2);
    for handle in handles {
        handle.join();
    }
}

#[test]
fn worker_killed_mid_stream_loses_no_request_and_logits_match() {
    let classes = 7usize;
    let catalog = vec![stub_op("only", 1.0)];
    let mut handles: Vec<Option<WorkerHandle>> = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..3 {
        // a slow-ish stub so the kill lands while a forward is in flight
        let (h, addr) = stub_worker(classes, Duration::from_millis(30), catalog.clone());
        handles.push(Some(h));
        addrs.push(addr);
    }
    let mut fleet = FleetBackend::connect(&addrs).unwrap();
    fleet.prepare(&catalog).unwrap();
    let mut local = StubBackend::new(classes);
    local.prepare(&catalog).unwrap();

    let mut completed = 0usize;
    let mut killer = None;
    for step in 0..20usize {
        let batch = 9usize;
        let images: Vec<f32> = (0..batch)
            .flat_map(|i| {
                let x0 = ((step + i) % classes) as f32;
                [x0, 0.0, 0.0]
            })
            .collect();
        if step == 8 {
            // kill one worker while the next forward is on the wire
            let victim = handles[1].take().unwrap();
            killer = Some(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(10));
                victim.kill();
            }));
        }
        let got = fleet.forward(0, &images, batch).unwrap();
        let want = local.forward(0, &images, batch).unwrap();
        assert_eq!(got, want, "step {step}: logits diverged after failover");
        completed += batch;
        assert_eq!(got.len(), batch * classes);
    }
    killer.unwrap().join().unwrap();

    assert_eq!(completed, 20 * 9, "every request must complete despite the kill");
    assert_eq!(fleet.live_workers(), 2, "the killed worker must be evicted");
    let (workers, requeues, evictions) = fleet.stats().snapshot();
    assert_eq!(evictions, 1);
    assert!(requeues >= 1, "the dead worker's chunk must have been requeued");
    let survivors: u64 = workers
        .iter()
        .filter(|(_, w)| !w.evicted)
        .map(|(_, w)| w.requests)
        .sum();
    assert!(survivors > 0);

    for handle in handles.into_iter().flatten() {
        handle.kill();
    }
}

#[test]
fn heartbeat_timeout_evicts_unresponsive_worker() {
    let (healthy, addr0) = stub_worker(4, Duration::ZERO, stub_catalog());

    // a worker that answers the handshake and then goes silent: the
    // timeout path, not the connection-reset path
    let silent = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr1 = silent.local_addr().unwrap().to_string();
    let silent_thread = std::thread::spawn(move || {
        let (mut s, _) = silent.accept().unwrap();
        let (frame, _) = wire::read_frame(&mut s).unwrap();
        assert!(matches!(frame, Frame::Hello { .. }));
        wire::write_frame(
            &mut s,
            &Frame::HelloAck {
                worker: "silent".into(),
                backend: "stub".into(),
                mode: String::new(),
                classes: 4,
                catalog: vec!["hi".into(), "lo".into()],
                hb_interval_ms: 1000,
                hb_timeout_ms: 500,
            },
            &[],
        )
        .unwrap();
        // swallow every later frame without answering
        use std::io::Read;
        let mut buf = [0u8; 1024];
        while let Ok(n) = s.read(&mut buf) {
            if n == 0 {
                break;
            }
        }
    });

    let addrs = vec![addr0, addr1.clone()];
    let mut fleet = FleetBackend::connect(&addrs).unwrap();
    assert_eq!(fleet.live_workers(), 2);

    let t0 = Instant::now();
    let live = fleet.heartbeat(Duration::from_millis(100));
    assert_eq!(live, 1, "the silent worker must be evicted by timeout");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "heartbeat must time out promptly, took {:?}",
        t0.elapsed()
    );
    let (workers, _, evictions) = fleet.stats().snapshot();
    assert_eq!(evictions, 1);
    assert!(workers.iter().any(|(a, w)| *a == addr1 && w.evicted));

    // a healthy fleet member keeps answering after the probe
    assert_eq!(fleet.heartbeat(Duration::from_millis(500)), 1);

    drop(fleet); // closes the silent socket; the thread sees EOF
    silent_thread.join().unwrap();
    healthy.kill();
}

#[test]
fn advertised_heartbeat_cadence_reaches_the_coordinator_as_fleet_minimum() {
    // one default-cadence worker plus one short-leashed worker: the
    // coordinator's probe hints must take the fleet-wide minimum, so
    // the short leash tightens eviction time for the whole deployment
    let (slow, addr_slow) = stub_worker(4, Duration::ZERO, stub_catalog());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let opts = WorkerOptions::new("edge", "")
        .heartbeat(Duration::from_millis(120), Duration::from_millis(60));
    let fast = worker::spawn_with(listener, opts, stub_catalog(), move |_conn| {
        Ok(StubBackend::new(4))
    })
    .unwrap();
    let addr_fast = fast.addr().to_string();

    let fleet = FleetBackend::connect(&[addr_slow.clone(), addr_fast]).unwrap();
    assert_eq!(fleet.hb_interval(), Duration::from_millis(120));
    assert_eq!(fleet.hb_timeout(), Duration::from_millis(60));
    drop(fleet);

    // a fleet of defaults keeps the legacy cadence
    let fleet = FleetBackend::connect(std::slice::from_ref(&addr_slow)).unwrap();
    assert_eq!(fleet.hb_interval(), Duration::from_millis(1000));
    assert_eq!(fleet.hb_timeout(), Duration::from_millis(500));
    drop(fleet);

    slow.kill();
    fast.kill();
}

#[test]
fn fleet_drain_switch_acks_only_after_inflight_forwards_complete() {
    let delay = Duration::from_millis(400);
    let (handle, addr) = stub_worker(4, delay, stub_catalog());
    let catalog = stub_catalog();

    let mut data = FleetBackend::connect(std::slice::from_ref(&addr)).unwrap();
    data.prepare(&catalog).unwrap();
    // the control plane has its own connections (like `serve --fleet`)
    let mut control = FleetBackend::connect(std::slice::from_ref(&addr)).unwrap();

    let forward_ok = Arc::new(AtomicBool::new(false));
    let started = Instant::now();
    std::thread::scope(|s| {
        let flag = forward_ok.clone();
        let data_ref = &mut data;
        s.spawn(move || {
            data_ref.forward(0, &[1.0, 0.0], 1).unwrap();
            flag.store(true, Ordering::Release);
        });
        // give the forward ample time to be in flight worker-side
        std::thread::sleep(Duration::from_millis(100));
        let acks = control.set_operating_point(1, SwitchMode::Drain).unwrap();
        let t_ack = started.elapsed();
        assert_eq!(acks, 1, "the surviving worker must ack the drain switch");
        assert!(
            t_ack >= Duration::from_millis(300),
            "drain acked after {t_ack:?}, before the in-flight forward could have finished"
        );
    });
    assert!(forward_ok.load(Ordering::Acquire));

    // an Immediate broadcast is fire-and-forget: it returns while a
    // fresh slow forward is still in flight
    std::thread::scope(|s| {
        let data_ref = &mut data;
        s.spawn(move || {
            data_ref.forward(0, &[2.0, 0.0], 1).unwrap();
        });
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        let n = control.set_operating_point(0, SwitchMode::Immediate).unwrap();
        assert_eq!(n, 1);
        assert!(
            t0.elapsed() < Duration::from_millis(300),
            "immediate switch must not wait for in-flight work ({:?})",
            t0.elapsed()
        );
    });

    handle.kill();
}

#[test]
fn raw_wire_conversation_covers_setop_current_op_and_drain() {
    let (handle, addr) = stub_worker(4, Duration::ZERO, stub_catalog());
    let mut s = std::net::TcpStream::connect(&addr).unwrap();

    // handshake
    wire::write_frame(&mut s, &Frame::Hello { version: PROTOCOL_VERSION }, &[]).unwrap();
    let (ack, _) = wire::read_frame(&mut s).unwrap();
    match ack {
        Frame::HelloAck { classes, catalog, .. } => {
            assert_eq!(classes, 4);
            assert_eq!(catalog, vec!["hi".to_string(), "lo".to_string()]);
        }
        other => panic!("expected HelloAck, got {other:?}"),
    }

    // prepare the worker's own ladder order (reversed on purpose)
    wire::write_frame(
        &mut s,
        &Frame::Prepare {
            ladder: vec![
                LadderRung { name: "lo".into(), power: 0.5 },
                LadderRung { name: "hi".into(), power: 1.0 },
            ],
        },
        &[],
    )
    .unwrap();
    assert!(matches!(wire::read_frame(&mut s).unwrap().0, Frame::Ok));

    // fire-and-forget SetOp, then a Forward that omits `op`: it must
    // run under the worker's current OP — observable via Pong
    wire::write_frame(&mut s, &Frame::SetOp { op: 1, drain: false }, &[]).unwrap();
    wire::write_frame(&mut s, &Frame::Forward { op: None, batch: 2 }, &[1.0, 0.0, 3.0, 0.0])
        .unwrap();
    let (logits, payload) = wire::read_frame(&mut s).unwrap();
    assert!(matches!(logits, Frame::Logits { classes: 4 }));
    assert_eq!(payload.len(), 2 * 4);

    wire::write_frame(&mut s, &Frame::Heartbeat, &[]).unwrap();
    match wire::read_frame(&mut s).unwrap().0 {
        Frame::Pong { current_op, served } => {
            assert_eq!(current_op, 1, "fire-and-forget SetOp must have applied");
            assert_eq!(served, 2);
        }
        other => panic!("expected Pong, got {other:?}"),
    }

    // standalone drain barrier acks on an idle worker
    wire::write_frame(&mut s, &Frame::Drain, &[]).unwrap();
    assert!(matches!(wire::read_frame(&mut s).unwrap().0, Frame::Ok));

    // version mismatch is refused
    wire::write_frame(&mut s, &Frame::Hello { version: 999 }, &[]).unwrap();
    match wire::read_frame(&mut s).unwrap().0 {
        Frame::Err { message } => assert!(message.contains("version"), "{message}"),
        other => panic!("expected Err, got {other:?}"),
    }

    // shutdown winds the daemon down
    wire::write_frame(&mut s, &Frame::Shutdown, &[]).unwrap();
    assert!(matches!(wire::read_frame(&mut s).unwrap().0, Frame::Ok));
    handle.join();
}

#[test]
fn prepare_rejects_catalog_and_power_mismatches_but_connection_survives() {
    let (handle, addr) = stub_worker(4, Duration::ZERO, stub_catalog());
    let addrs = vec![addr];
    let mut fleet = FleetBackend::connect(&addrs).unwrap();

    let err = fleet.prepare(&[stub_op("nope", 1.0)]).unwrap_err();
    assert!(format!("{err:#}").contains("not in this worker's catalog"), "{err:#}");

    let err = fleet.prepare(&[stub_op("hi", 0.25)]).unwrap_err();
    assert!(format!("{err:#}").contains("power mismatch"), "{err:#}");

    // an application-level rejection must not poison the connection
    fleet.prepare(&[stub_op("hi", 1.0), stub_op("lo", 0.5)]).unwrap();
    let out = fleet.forward(1, &[2.0, 0.0], 1).unwrap();
    assert_eq!(out.len(), 4);
    assert_eq!(fleet.live_workers(), 1);
    handle.kill();
}

#[test]
fn coordinator_mode_cross_check_catches_mismatched_workers() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = worker::spawn(listener, "w", "none", stub_catalog(), move |_conn| {
        Ok(StubBackend::new(4))
    })
    .unwrap();
    let addrs = vec![handle.addr().to_string()];
    let fleet = FleetBackend::connect(&addrs).unwrap();
    // powers are mode-independent, so Prepare alone cannot catch this;
    // the handshake-advertised mode can
    let err = fleet.check_mode("bn").unwrap_err();
    assert!(format!("{err:#}").contains("--mode"), "{err:#}");
    fleet.check_mode("none").unwrap();
    drop(fleet);

    // workers advertising no mode (in-process tests) are skipped
    let (h2, addr2) = stub_worker(4, Duration::ZERO, stub_catalog());
    let fleet = FleetBackend::connect(&[addr2]).unwrap();
    fleet.check_mode("bn").unwrap();
    drop(fleet);
    handle.kill();
    h2.kill();
}

#[test]
fn fleet_workers_must_agree_on_classifier_width() {
    let (h4, addr4) = stub_worker(4, Duration::ZERO, stub_catalog());
    let (h6, addr6) = stub_worker(6, Duration::ZERO, stub_catalog());
    let err = FleetBackend::connect(&[addr4, addr6]).unwrap_err();
    assert!(format!("{err:#}").contains("disagree"), "{err:#}");
    h4.kill();
    h6.kill();
}

#[test]
fn server_over_fleet_serves_waves_across_a_drain_switch() {
    let catalog = stub_catalog();
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let (h, addr) = stub_worker(4, Duration::from_millis(2), catalog.clone());
        handles.push(h);
        addrs.push(addr);
    }

    let stats = FleetStats::default();
    let control_stats = stats.clone();
    let factory_addrs = addrs.clone();
    let factory_stats = stats.clone();
    let server = Server::start(
        move |_w| FleetBackend::connect_with(&factory_addrs, factory_stats.clone()),
        OpTable::new(catalog),
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 1,
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    let mut control = FleetBackend::connect_with(&addrs, control_stats).unwrap();

    // wave 1 under OP0, then a coordinator-initiated fleet-wide drain
    // switch that every worker acks, then wave 2 under OP1
    let wave1: Vec<_> = (0..20)
        .map(|i| server.submit(vec![(i % 4) as f32, 0.0]).unwrap())
        .collect();
    let acks = control.set_operating_point(1, SwitchMode::Drain).unwrap();
    assert_eq!(acks, 2, "every surviving worker must ack before the switch is reported");
    server.set_operating_point_with(1, SwitchMode::Drain).unwrap();
    let wave2: Vec<_> = (0..20)
        .map(|i| server.submit(vec![(i % 4) as f32, 0.0]).unwrap())
        .collect();

    for rx in wave1 {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.op_index, 0);
        assert_eq!(resp.logits.len(), 4);
    }
    for rx in wave2 {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.op_index, 1);
    }

    let m = server.shutdown();
    assert_eq!(m.completed, 40);
    let (workers, _requeues, evictions) = stats.snapshot();
    assert_eq!(evictions, 0);
    let served: u64 = workers.iter().map(|(_, w)| w.requests).sum();
    assert_eq!(served, 40, "per-worker attribution must cover every request");

    for handle in handles {
        handle.kill();
    }
}
