//! Fleet integration tests: loopback worker daemons on `127.0.0.1:0`
//! driven by a coordinator `FleetBackend` — bit-exactness against a
//! single local backend (including under pipelined, out-of-order
//! completion), deterministic fault injection through the chaos proxy
//! (`common::chaos`): mid-frame severs, split writes, stalls, eviction
//! and rejoin, latency-aware chunk sizing, drain-barrier ordering
//! behind pipelined forwards, registry-driven fleet growth, wire-level
//! fuzzing, and version skew.  Every failure scenario is scripted by a
//! SplitMix64 seed, not by wall-clock races.

mod common;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::chaos::{ChaosConfig, ChaosProxy};
use common::{build_tiny, stub_op};
use qos_nets::backend::{Backend, NativeBackend, OpTable, StubBackend};
use qos_nets::engine::OperatingPoint;
use qos_nets::fleet::wire::{self, Frame, LadderRung, MAX_HEADER_BYTES, PROTOCOL_VERSION};
use qos_nets::fleet::{
    register_with, worker, FleetBackend, FleetRegistry, FleetStats, MemberState, WorkerHandle,
    WorkerOptions, WorkerStats, WORKER_MAX_INFLIGHT,
};
use qos_nets::qos::SwitchMode;
use qos_nets::server::{BatcherConfig, Server};
use qos_nets::util::rng::Rng;

/// Spawn one loopback stub worker; returns its handle and address.
fn stub_worker(
    classes: usize,
    delay: Duration,
    catalog: Vec<OperatingPoint>,
) -> (WorkerHandle, String) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = worker::spawn(listener, "stub-worker", "", catalog, move |_conn| {
        Ok(StubBackend::new(classes).with_delay(delay))
    })
    .unwrap();
    let addr = handle.addr().to_string();
    (handle, addr)
}

fn stub_catalog() -> Vec<OperatingPoint> {
    vec![stub_op("hi", 1.0), stub_op("lo", 0.5)]
}

/// Raw QFLT frame bytes from an arbitrary header string — for speaking
/// protocol dialects the `Frame` enum cannot (version-skew tests) and
/// for seeding the fuzzer.
fn raw_frame(header: &str, payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(b"QFLT");
    buf.extend_from_slice(&(header.len() as u32).to_le_bytes());
    buf.extend_from_slice(header.as_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// One worker's stats row out of a snapshot.
fn stats_of(stats: &FleetStats, addr: &str) -> WorkerStats {
    stats
        .snapshot()
        .0
        .into_iter()
        .find(|(a, _)| a == addr)
        .map(|(_, w)| w)
        .unwrap_or_default()
}

#[test]
fn loopback_fleet_is_bit_identical_to_single_native_backend() {
    let (graph, db, op, images, _, _) = build_tiny();
    let mut frugal = op.clone();
    frugal.name = "frugal".into();
    frugal.assignment.insert("c1".to_string(), 9); // bam7
    frugal.relative_power = 0.6;
    let ops = vec![op, frugal];

    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let g = graph.clone();
        let d = db.clone();
        let handle = worker::spawn(listener, "native-worker", "bn", ops.clone(), move |_conn| {
            Ok(NativeBackend::new(g.clone(), d.clone()))
        })
        .unwrap();
        addrs.push(handle.addr().to_string());
        handles.push(handle);
    }

    let mut fleet = FleetBackend::connect(&addrs).unwrap();
    fleet.prepare(&ops).unwrap();
    assert_eq!(fleet.name(), "fleet");

    let mut local = NativeBackend::new(graph, db);
    local.prepare(&ops).unwrap();
    assert_eq!(fleet.num_classes(), local.num_classes());

    // the same request stream through both paths, interleaving OP
    // switches and batch sizes (1 exercises batch < workers; odd sizes
    // exercise uneven splits)
    let elems = images.len() / 2;
    for round in 0..4usize {
        for &op_idx in &[0usize, 1, 0] {
            let batch = 1 + (round + op_idx) % 5;
            let mut buf = Vec::with_capacity(batch * elems);
            for i in 0..batch {
                let src = (i + round) % 2;
                buf.extend_from_slice(&images[src * elems..(src + 1) * elems]);
            }
            let got = fleet.forward(op_idx, &buf, batch).unwrap();
            let want = local.forward(op_idx, &buf, batch).unwrap();
            assert_eq!(got, want, "round {round} op {op_idx} batch {batch}: fleet diverged");
        }
    }

    // orderly teardown: every worker daemon acks Shutdown and exits
    assert_eq!(fleet.shutdown_fleet(), 2);
    for handle in handles {
        handle.join();
    }
}

#[test]
fn worker_severed_mid_stream_loses_no_request_and_logits_match() {
    let classes = 7usize;
    let catalog = vec![stub_op("only", 1.0)];
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    let mut victim_proxy = None;
    for w in 0..3 {
        let (h, addr) = stub_worker(classes, Duration::from_millis(5), catalog.clone());
        if w == 1 {
            // worker 1 talks through the chaos proxy, which cuts the
            // link mid-frame on its 11th forwarded frame — well inside
            // the data-plane stream (4 frames go to handshake+prepare)
            let proxy = ChaosProxy::spawn(
                addr,
                0xC0FFEE,
                ChaosConfig {
                    sever_on_frame: Some(11),
                    sever_mid_frame: true,
                    ..ChaosConfig::default()
                },
            );
            addrs.push(proxy.addr().to_string());
            victim_proxy = Some(proxy);
        } else {
            addrs.push(addr);
        }
        handles.push(h);
    }
    let victim_addr = addrs[1].clone();
    let proxy = victim_proxy.unwrap();

    let mut fleet = FleetBackend::connect(&addrs).unwrap();
    fleet.prepare(&catalog).unwrap();
    let mut local = StubBackend::new(classes);
    local.prepare(&catalog).unwrap();

    let mut completed = 0usize;
    for step in 0..20usize {
        let batch = 9usize;
        let images: Vec<f32> = (0..batch)
            .flat_map(|i| [((step + i) % classes) as f32, 0.0, 0.0])
            .collect();
        let got = fleet.forward(0, &images, batch).unwrap();
        let want = local.forward(0, &images, batch).unwrap();
        assert_eq!(got, want, "step {step}: logits diverged after failover");
        completed += batch;
        assert_eq!(got.len(), batch * classes);
    }

    assert_eq!(completed, 20 * 9, "every request must complete despite the sever");
    assert!(proxy.is_severed(), "the scripted sever must have fired");
    assert_eq!(fleet.live_workers(), 2, "the severed worker must be evicted");
    assert_eq!(fleet.stats().state_of(&victim_addr), MemberState::Evicted);
    let (workers, requeues, evictions) = fleet.stats().snapshot();
    assert_eq!(evictions, 1);
    assert!(requeues >= 1, "the severed worker's in-flight chunk must have been requeued");
    let survivors: u64 = workers
        .iter()
        .filter(|(_, w)| !w.evicted)
        .map(|(_, w)| w.requests)
        .sum();
    assert!(survivors > 0);

    for handle in handles {
        handle.kill();
    }
}

#[test]
fn chaos_delay_skew_reassembles_out_of_order_completions_bit_exact() {
    let classes = 5usize;
    let catalog = vec![stub_op("only", 1.0)];
    let (h0, addr0) = stub_worker(classes, Duration::ZERO, catalog.clone());
    let (h1, addr1) = stub_worker(classes, Duration::ZERO, catalog.clone());
    // worker 1's frames lag by a seeded 4-12 ms each way, so worker 0
    // races ahead and logits complete far from submission order
    let proxy = ChaosProxy::spawn(
        addr1,
        0x0DD_5EED,
        ChaosConfig {
            delay: Some((Duration::from_millis(4), Duration::from_millis(12))),
            ..ChaosConfig::default()
        },
    );
    let addrs = vec![addr0, proxy.addr().to_string()];
    // an explicit window keeps this pipelined even under the
    // QOS_NETS_FLEET_PIPELINE=off compatibility leg
    let mut fleet = FleetBackend::connect(&addrs).unwrap().with_pipeline_window(6);
    fleet.prepare(&catalog).unwrap();
    let mut local = StubBackend::new(classes);
    local.prepare(&catalog).unwrap();

    for step in 0..10usize {
        let batch = 24 + step; // odd sizes exercise uneven splits
        let images: Vec<f32> = (0..batch)
            .flat_map(|i| [((i * 7 + step) % classes) as f32, 0.5])
            .collect();
        let got = fleet.forward(0, &images, batch).unwrap();
        let want = local.forward(0, &images, batch).unwrap();
        assert_eq!(got, want, "step {step}: out-of-order gather reassembled wrong");
    }

    assert!(
        proxy.frames_forwarded() > 4,
        "the delayed worker must have seen data-plane traffic, saw {} frames",
        proxy.frames_forwarded()
    );
    let (_, _, evictions) = fleet.stats().snapshot();
    assert_eq!(evictions, 0, "delays are not failures");
    h0.kill();
    h1.kill();
}

#[test]
fn chaos_split_writes_and_stalls_do_not_corrupt_the_stream() {
    let classes = 4usize;
    let catalog = stub_catalog();
    let (h0, addr0) = stub_worker(classes, Duration::ZERO, catalog.clone());
    // every frame is torn at a seeded offset and flushed in two pieces,
    // and the 9th frame stalls 120 ms — an alive-but-slow link
    let proxy = ChaosProxy::spawn(
        addr0,
        0x5EED_5711,
        ChaosConfig {
            split_writes: true,
            stall: Some((9, Duration::from_millis(120))),
            ..ChaosConfig::default()
        },
    );
    let mut fleet = FleetBackend::connect(&[proxy.addr().to_string()]).unwrap();
    fleet.prepare(&catalog).unwrap();
    let mut local = StubBackend::new(classes);
    local.prepare(&catalog).unwrap();

    for step in 0..8usize {
        let batch = 5usize;
        let images: Vec<f32> =
            (0..batch).flat_map(|i| [((step + i) % classes) as f32, 0.0]).collect();
        let got = fleet.forward(0, &images, batch).unwrap();
        let want = local.forward(0, &images, batch).unwrap();
        assert_eq!(got, want, "step {step}: logits diverged over the torn link");
    }

    let (workers, requeues, evictions) = fleet.stats().snapshot();
    assert_eq!(
        (requeues, evictions),
        (0, 0),
        "torn writes and stalls must not look like failures"
    );
    assert!(workers.iter().all(|(_, w)| w.state == MemberState::Live));
    h0.kill();
}

#[test]
fn evicted_worker_rejoins_with_its_stats_preserved() {
    let classes = 4usize;
    let catalog = stub_catalog();
    let (h0, addr0) = stub_worker(classes, Duration::ZERO, catalog.clone());
    let (h1, addr1) = stub_worker(classes, Duration::ZERO, catalog.clone());
    let proxy = ChaosProxy::spawn(addr1, 0xA11CE, ChaosConfig::default());
    let paddr = proxy.addr().to_string();

    let stats = FleetStats::default();
    let mut fleet =
        FleetBackend::connect_with(&[addr0.clone(), paddr.clone()], stats.clone()).unwrap();
    fleet.prepare(&catalog).unwrap();
    fleet.set_operating_point(1, SwitchMode::Immediate).unwrap();

    let images = |step: usize, batch: usize| -> Vec<f32> {
        (0..batch).flat_map(|i| [((step + i) % classes) as f32, 0.0]).collect()
    };

    // drive traffic until the proxied worker has history worth keeping
    let mut before = 0u64;
    for step in 0..200usize {
        fleet.forward(1, &images(step, 16), 16).unwrap();
        before = stats_of(&stats, &paddr).requests;
        if before > 0 {
            break;
        }
    }
    assert!(before > 0, "the proxied worker never served — cannot test preservation");

    // cut the link: first strike suspects, the failed quick-readmit on
    // the next forward evicts
    proxy.sever_now();
    for step in 0..3usize {
        fleet.forward(1, &images(step, 8), 8).unwrap();
    }
    assert_eq!(fleet.live_workers(), 1);
    assert_eq!(stats.state_of(&paddr), MemberState::Evicted);
    let w = stats_of(&stats, &paddr);
    assert_eq!(w.requests, before, "eviction must not touch serving history");
    assert_eq!(w.rejoins, 0);

    // a re-probe against a still-severed link changes nothing
    assert_eq!(fleet.reprobe(), 0);
    assert_eq!(stats.state_of(&paddr), MemberState::Evicted);

    // heal and re-probe: fresh handshake, ladder + OP replay, Live again
    proxy.heal();
    assert_eq!(fleet.reprobe(), 1);
    assert_eq!(fleet.live_workers(), 2);
    let w = stats_of(&stats, &paddr);
    assert_eq!(w.state, MemberState::Live);
    assert_eq!(w.rejoins, 1);
    assert_eq!(w.requests, before, "history must survive the evict → rejoin round trip");

    // and the rejoined worker serves again, still bit-exact
    let mut local = StubBackend::new(classes);
    local.prepare(&catalog).unwrap();
    let mut served_again = false;
    for step in 0..200usize {
        let got = fleet.forward(1, &images(step, 16), 16).unwrap();
        let want = local.forward(1, &images(step, 16), 16).unwrap();
        assert_eq!(got, want, "step {step} after rejoin");
        if stats_of(&stats, &paddr).requests > before {
            served_again = true;
            break;
        }
    }
    assert!(served_again, "a rejoined worker must take traffic again");
    h0.kill();
    h1.kill();
}

#[test]
fn latency_skewed_fleet_gets_latency_skewed_chunk_sizes() {
    let catalog = vec![stub_op("only", 1.0)];
    let classes = 3usize;
    let (hf, fast) = stub_worker(classes, Duration::ZERO, catalog.clone());
    let (hs, slow) = stub_worker(classes, Duration::from_millis(25), catalog.clone());
    let stats = FleetStats::default();
    let mut fleet = FleetBackend::connect_with(&[fast.clone(), slow.clone()], stats.clone())
        .unwrap()
        .with_pipeline_window(4);
    fleet.prepare(&catalog).unwrap();

    let batch = 48usize;
    let images: Vec<f32> = (0..batch).flat_map(|i| [(i % classes) as f32, 0.0]).collect();
    for _ in 0..12 {
        let out = fleet.forward(0, &images, batch).unwrap();
        assert_eq!(out.len(), batch * classes);
    }

    let (_, _, evictions) = stats.snapshot();
    assert_eq!(evictions, 0);
    let (f, s) = (stats_of(&stats, &fast), stats_of(&stats, &slow));
    assert!(
        f.requests > s.requests,
        "the fast worker must serve more images ({} vs {})",
        f.requests,
        s.requests
    );
    let mean = |w: &WorkerStats| w.requests as f64 / w.batches.max(1) as f64;
    assert!(
        mean(&f) > mean(&s),
        "chunk sizing must skew toward the fast worker ({:.1} vs {:.1} images/chunk)",
        mean(&f),
        mean(&s)
    );
    assert_eq!(stats.state_of(&slow), MemberState::Live, "slow is not dead");
    hf.kill();
    hs.kill();
}

#[test]
fn registry_join_grows_the_fleet_and_siblings_adopt_the_newcomer() {
    let classes = 4usize;
    let catalog = stub_catalog();
    let (h0, addr0) = stub_worker(classes, Duration::ZERO, catalog.clone());
    let stats = FleetStats::default();
    let mut fleet = FleetBackend::connect_with(&[addr0.clone()], stats.clone()).unwrap();
    fleet.prepare(&catalog).unwrap();
    fleet.forward(0, &[1.0, 0.0], 1).unwrap();

    // a new worker announces itself via the registry while the fleet
    // is already serving
    let reg = FleetRegistry::bind("127.0.0.1:0").unwrap();
    let (h1, addr1) = stub_worker(classes, Duration::ZERO, catalog.clone());
    register_with(&reg.addr().to_string(), &addr1).unwrap();
    let newcomers = reg.take_new();
    assert_eq!(newcomers, vec![addr1.clone()]);
    assert_eq!(fleet.admit(&newcomers), 1);
    assert_eq!(fleet.live_workers(), 2);

    let mut local = StubBackend::new(classes);
    local.prepare(&catalog).unwrap();
    let mut newcomer_served = false;
    for step in 0..200usize {
        let batch = 8usize;
        let images: Vec<f32> =
            (0..batch).flat_map(|i| [((step + i) % classes) as f32, 0.0]).collect();
        let got = fleet.forward(0, &images, batch).unwrap();
        let want = local.forward(0, &images, batch).unwrap();
        assert_eq!(got, want, "step {step} with the admitted worker");
        if stats_of(&stats, &addr1).requests > 0 {
            newcomer_served = true;
            break;
        }
    }
    assert!(newcomer_served, "an admitted worker must end up serving traffic");

    // a sibling backend sharing the stats registry adopts the newcomer
    // on its next forward — `serve --fleet` batcher threads see joins
    // without their own registry plumbing
    let mut sib = FleetBackend::connect_with(&[addr0.clone()], stats.clone()).unwrap();
    sib.prepare(&catalog).unwrap();
    sib.forward(0, &[1.0, 0.0], 1).unwrap();
    assert_eq!(sib.live_workers(), 2, "sibling must adopt the registry-admitted worker");

    h0.kill();
    h1.kill();
}

#[test]
fn heartbeat_timeout_evicts_unresponsive_worker() {
    let (healthy, addr0) = stub_worker(4, Duration::ZERO, stub_catalog());

    // a worker that answers the handshake and then goes silent: the
    // timeout path, not the connection-reset path.  The probe suspects
    // it, the in-call readmit gives it its second strike (the fresh
    // hello times out too), and it leaves the live set evicted.
    let silent = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr1 = silent.local_addr().unwrap().to_string();
    let silent_thread = std::thread::spawn(move || {
        let (mut s, _) = silent.accept().unwrap();
        let (frame, _) = wire::read_frame(&mut s).unwrap();
        assert!(matches!(frame, Frame::Hello { .. }));
        wire::write_frame(
            &mut s,
            &Frame::HelloAck {
                worker: "silent".into(),
                backend: "stub".into(),
                mode: String::new(),
                classes: 4,
                catalog: vec!["hi".into(), "lo".into()],
                hb_interval_ms: 1000,
                hb_timeout_ms: 500,
                max_inflight: 1,
            },
            &[],
        )
        .unwrap();
        // swallow every later frame without answering
        use std::io::Read;
        let mut buf = [0u8; 1024];
        while let Ok(n) = s.read(&mut buf) {
            if n == 0 {
                break;
            }
        }
    });

    let addrs = vec![addr0, addr1.clone()];
    let mut fleet = FleetBackend::connect(&addrs).unwrap();
    assert_eq!(fleet.live_workers(), 2);

    let t0 = Instant::now();
    let live = fleet.heartbeat(Duration::from_millis(100));
    assert_eq!(live, 1, "the silent worker must be evicted by timeout");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "heartbeat must time out promptly, took {:?}",
        t0.elapsed()
    );
    let (workers, _, evictions) = fleet.stats().snapshot();
    assert_eq!(evictions, 1);
    assert!(workers.iter().any(|(a, w)| *a == addr1 && w.evicted));
    assert_eq!(fleet.stats().state_of(&addr1), MemberState::Evicted);

    // a healthy fleet member keeps answering after the probe
    assert_eq!(fleet.heartbeat(Duration::from_millis(500)), 1);

    drop(fleet); // closes the silent socket; the thread sees EOF
    silent_thread.join().unwrap();
    healthy.kill();
}

#[test]
fn advertised_heartbeat_cadence_reaches_the_coordinator_as_fleet_minimum() {
    // one default-cadence worker plus one short-leashed worker: the
    // coordinator's probe hints must take the fleet-wide minimum, so
    // the short leash tightens eviction time for the whole deployment
    let (slow, addr_slow) = stub_worker(4, Duration::ZERO, stub_catalog());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let opts = WorkerOptions::new("edge", "")
        .heartbeat(Duration::from_millis(120), Duration::from_millis(60));
    let fast = worker::spawn_with(listener, opts, stub_catalog(), move |_conn| {
        Ok(StubBackend::new(4))
    })
    .unwrap();
    let addr_fast = fast.addr().to_string();

    let fleet = FleetBackend::connect(&[addr_slow.clone(), addr_fast]).unwrap();
    assert_eq!(fleet.hb_interval(), Duration::from_millis(120));
    assert_eq!(fleet.hb_timeout(), Duration::from_millis(60));
    drop(fleet);

    // a fleet of defaults keeps the legacy cadence
    let fleet = FleetBackend::connect(std::slice::from_ref(&addr_slow)).unwrap();
    assert_eq!(fleet.hb_interval(), Duration::from_millis(1000));
    assert_eq!(fleet.hb_timeout(), Duration::from_millis(500));
    drop(fleet);

    slow.kill();
    fast.kill();
}

#[test]
fn fleet_drain_switch_acks_only_after_inflight_forwards_complete() {
    let delay = Duration::from_millis(400);
    let (handle, addr) = stub_worker(4, delay, stub_catalog());
    let catalog = stub_catalog();

    let mut data = FleetBackend::connect(std::slice::from_ref(&addr)).unwrap();
    data.prepare(&catalog).unwrap();
    // the control plane has its own connections (like `serve --fleet`)
    let mut control = FleetBackend::connect(std::slice::from_ref(&addr)).unwrap();

    let forward_ok = Arc::new(AtomicBool::new(false));
    let started = Instant::now();
    std::thread::scope(|s| {
        let flag = forward_ok.clone();
        let data_ref = &mut data;
        s.spawn(move || {
            data_ref.forward(0, &[1.0, 0.0], 1).unwrap();
            flag.store(true, Ordering::Release);
        });
        // give the forward ample time to be in flight worker-side
        std::thread::sleep(Duration::from_millis(100));
        let acks = control.set_operating_point(1, SwitchMode::Drain).unwrap();
        let t_ack = started.elapsed();
        assert_eq!(acks, 1, "the surviving worker must ack the drain switch");
        assert!(
            t_ack >= Duration::from_millis(300),
            "drain acked after {t_ack:?}, before the in-flight forward could have finished"
        );
    });
    assert!(forward_ok.load(Ordering::Acquire));

    // an Immediate broadcast is fire-and-forget: it returns while a
    // fresh slow forward is still in flight
    std::thread::scope(|s| {
        let data_ref = &mut data;
        s.spawn(move || {
            data_ref.forward(0, &[2.0, 0.0], 1).unwrap();
        });
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        let n = control.set_operating_point(0, SwitchMode::Immediate).unwrap();
        assert_eq!(n, 1);
        assert!(
            t0.elapsed() < Duration::from_millis(300),
            "immediate switch must not wait for in-flight work ({:?})",
            t0.elapsed()
        );
    });

    handle.kill();
}

#[test]
fn raw_wire_drain_barrier_orders_behind_pipelined_forwards() {
    let (handle, addr) = stub_worker(4, Duration::from_millis(40), stub_catalog());
    let mut s = TcpStream::connect(&addr).unwrap();
    wire::write_frame(&mut s, &Frame::Hello { version: PROTOCOL_VERSION }, &[]).unwrap();
    assert!(matches!(wire::read_frame(&mut s).unwrap().0, Frame::HelloAck { .. }));
    wire::write_frame(
        &mut s,
        &Frame::Prepare {
            ladder: vec![
                LadderRung { name: "hi".into(), power: 1.0 },
                LadderRung { name: "lo".into(), power: 0.5 },
            ],
        },
        &[],
    )
    .unwrap();
    assert!(matches!(wire::read_frame(&mut s).unwrap().0, Frame::Ok));

    // two pipelined forwards and the drain-switch barrier, written
    // back-to-back without reading a single reply: the worker's FIFO
    // execution must answer both forwards before acking the barrier
    let t0 = Instant::now();
    wire::write_frame(
        &mut s,
        &Frame::Forward { id: Some(7), op: Some(0), batch: 1, class: None },
        &[1.0, 0.0],
    )
    .unwrap();
    wire::write_frame(
        &mut s,
        &Frame::Forward { id: Some(8), op: Some(0), batch: 1, class: None },
        &[2.0, 0.0],
    )
    .unwrap();
    wire::write_frame(&mut s, &Frame::SetOp { op: 1, drain: true, class: None }, &[]).unwrap();

    match wire::read_frame(&mut s).unwrap().0 {
        Frame::Logits { id, classes } => {
            assert_eq!((id, classes), (Some(7), 4));
        }
        other => panic!("expected the first logits, got {other:?}"),
    }
    match wire::read_frame(&mut s).unwrap().0 {
        Frame::Logits { id, .. } => assert_eq!(id, Some(8)),
        other => panic!("expected the second logits, got {other:?}"),
    }
    assert!(matches!(wire::read_frame(&mut s).unwrap().0, Frame::Ok));
    assert!(
        t0.elapsed() >= Duration::from_millis(70),
        "barrier acked after {:?} — before both 40 ms forwards could have run",
        t0.elapsed()
    );
    handle.kill();
}

#[test]
fn raw_wire_conversation_covers_setop_current_op_and_drain() {
    let (handle, addr) = stub_worker(4, Duration::ZERO, stub_catalog());
    let mut s = TcpStream::connect(&addr).unwrap();

    // handshake; the worker advertises its pipelining capability
    wire::write_frame(&mut s, &Frame::Hello { version: PROTOCOL_VERSION }, &[]).unwrap();
    let (ack, _) = wire::read_frame(&mut s).unwrap();
    match ack {
        Frame::HelloAck { classes, catalog, max_inflight, .. } => {
            assert_eq!(classes, 4);
            assert_eq!(catalog, vec!["hi".to_string(), "lo".to_string()]);
            assert_eq!(max_inflight, WORKER_MAX_INFLIGHT);
        }
        other => panic!("expected HelloAck, got {other:?}"),
    }

    // prepare the worker's own ladder order (reversed on purpose)
    wire::write_frame(
        &mut s,
        &Frame::Prepare {
            ladder: vec![
                LadderRung { name: "lo".into(), power: 0.5 },
                LadderRung { name: "hi".into(), power: 1.0 },
            ],
        },
        &[],
    )
    .unwrap();
    assert!(matches!(wire::read_frame(&mut s).unwrap().0, Frame::Ok));

    // fire-and-forget SetOp, then an id-less legacy Forward omitting
    // `op`: it must run under the worker's current OP, and the reply to
    // an id-less request carries no id either
    wire::write_frame(&mut s, &Frame::SetOp { op: 1, drain: false, class: None }, &[]).unwrap();
    wire::write_frame(
        &mut s,
        &Frame::Forward { id: None, op: None, batch: 2, class: None },
        &[1.0, 0.0, 3.0, 0.0],
    )
    .unwrap();
    let (logits, payload) = wire::read_frame(&mut s).unwrap();
    assert!(matches!(logits, Frame::Logits { id: None, classes: 4 }));
    assert_eq!(payload.len(), 2 * 4);

    wire::write_frame(&mut s, &Frame::Heartbeat, &[]).unwrap();
    match wire::read_frame(&mut s).unwrap().0 {
        Frame::Pong { current_op, served } => {
            assert_eq!(current_op, 1, "fire-and-forget SetOp must have applied");
            assert_eq!(served, 2);
        }
        other => panic!("expected Pong, got {other:?}"),
    }

    // standalone drain barrier acks on an idle worker
    wire::write_frame(&mut s, &Frame::Drain, &[]).unwrap();
    assert!(matches!(wire::read_frame(&mut s).unwrap().0, Frame::Ok));

    // version mismatch is refused
    wire::write_frame(&mut s, &Frame::Hello { version: 999 }, &[]).unwrap();
    match wire::read_frame(&mut s).unwrap().0 {
        Frame::Err { message, .. } => assert!(message.contains("version"), "{message}"),
        other => panic!("expected Err, got {other:?}"),
    }

    // shutdown winds the daemon down
    wire::write_frame(&mut s, &Frame::Shutdown, &[]).unwrap();
    assert!(matches!(wire::read_frame(&mut s).unwrap().0, Frame::Ok));
    handle.join();
}

#[test]
fn version_skew_worker_with_unknown_frames_is_rejected_cleanly() {
    // a future-protocol worker answers Hello with a frame type this
    // coordinator has never heard of; the connect must fail with an
    // error naming the unknown frame, not hang or panic
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let t = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let (frame, _) = wire::read_frame(&mut s).unwrap();
        assert!(matches!(frame, Frame::Hello { .. }));
        s.write_all(&raw_frame(r#"{"type":"teleport","hops":3}"#, &[])).unwrap();
        s.flush().unwrap();
        // hold the socket open until the coordinator gives up
        use std::io::Read;
        let mut buf = [0u8; 64];
        let _ = s.read(&mut buf);
    });

    let err = FleetBackend::connect(&[addr]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown frame type"), "{msg}");
    assert!(msg.contains("hello ack"), "{msg}");
    t.join().unwrap();
}

#[test]
fn wire_fuzz_mutated_frames_error_cleanly_and_respect_caps() {
    // seeded corpus: every frame kind, with and without payloads
    let mut bases: Vec<Vec<u8>> = Vec::new();
    let corpus: Vec<(Frame, Vec<f32>)> = vec![
        (Frame::Hello { version: PROTOCOL_VERSION }, vec![]),
        (
            Frame::HelloAck {
                worker: "w".into(),
                backend: "stub".into(),
                mode: "bn".into(),
                classes: 10,
                catalog: vec!["hi".into(), "lo".into()],
                hb_interval_ms: 1000,
                hb_timeout_ms: 500,
                max_inflight: 64,
            },
            vec![],
        ),
        (
            Frame::Prepare {
                ladder: vec![
                    LadderRung { name: "hi".into(), power: 1.0 },
                    LadderRung { name: "lo".into(), power: 0.5 },
                ],
            },
            vec![],
        ),
        (Frame::Forward { id: Some(42), op: Some(1), batch: 3, class: None }, vec![1.0; 9]),
        (Frame::Forward { id: Some(43), op: Some(1), batch: 3, class: Some(1) }, vec![1.0; 9]),
        (Frame::Logits { id: Some(42), classes: 3 }, vec![0.5; 9]),
        (Frame::SetOp { op: 1, drain: true, class: None }, vec![]),
        (Frame::SetOp { op: 2, drain: true, class: Some(0) }, vec![]),
        (Frame::Heartbeat, vec![]),
        (Frame::Pong { current_op: 1, served: 99 }, vec![]),
        (Frame::Register { addr: "10.0.0.9:7070".into() }, vec![]),
        (Frame::err("boom"), vec![]),
    ];
    for (frame, payload) in &corpus {
        let mut buf = Vec::new();
        wire::write_frame(&mut buf, frame, payload).unwrap();
        // sanity: the unmutated bytes round-trip
        let (back, pay) = wire::read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(&back, frame);
        assert_eq!(&pay, payload);
        bases.push(buf);
    }

    // random mutations: bit flips, truncations, hostile length stamps.
    // The parser may accept a mutation that lands in a don't-care byte;
    // it must never panic, hang, or allocate past the caps.
    let mut rng = Rng::new(0xF0_55E_D);
    for _ in 0..600 {
        let mut bytes = bases[rng.below(bases.len())].clone();
        match rng.below(3) {
            0 => {
                let i = rng.below(bytes.len());
                bytes[i] ^= 1 << rng.below(8);
            }
            1 => {
                bytes.truncate(rng.below(bytes.len()));
            }
            _ => {
                let i = rng.below(bytes.len().saturating_sub(4).max(1));
                let stamp = (rng.next_u64() as u32).to_le_bytes();
                let end = (i + 4).min(bytes.len());
                bytes[i..end].copy_from_slice(&stamp[..end - i]);
            }
        }
        let _ = wire::read_frame(&mut bytes.as_slice()); // must not panic
    }

    // a header length just past the cap is refused before any read
    let mut bytes = raw_frame(r#"{"type":"heartbeat"}"#, &[]);
    bytes[4..8].copy_from_slice(&((MAX_HEADER_BYTES as u32) + 1).to_le_bytes());
    let err = wire::read_frame(&mut bytes.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("out of range"), "{err:#}");

    // ...and so is a payload length past the cap, or a misaligned one
    let header = r#"{"type":"heartbeat"}"#;
    let plen_at = 8 + header.len();
    let mut bytes = raw_frame(header, &[]);
    bytes[plen_at..plen_at + 4].copy_from_slice(&(1u32 << 31).to_le_bytes());
    let err = wire::read_frame(&mut bytes.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("payload length"), "{err:#}");
    let mut bytes = raw_frame(header, &[]);
    bytes[plen_at..plen_at + 4].copy_from_slice(&6u32.to_le_bytes());
    let err = wire::read_frame(&mut bytes.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("payload length"), "{err:#}");

    // bad magic fails loudly
    let mut bytes = raw_frame(header, &[]);
    bytes[0] = b'X';
    let err = wire::read_frame(&mut bytes.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("magic"), "{err:#}");
}

#[test]
fn prepare_rejects_catalog_and_power_mismatches_but_connection_survives() {
    let (handle, addr) = stub_worker(4, Duration::ZERO, stub_catalog());
    let addrs = vec![addr];
    let mut fleet = FleetBackend::connect(&addrs).unwrap();

    let err = fleet.prepare(&[stub_op("nope", 1.0)]).unwrap_err();
    assert!(format!("{err:#}").contains("not in this worker's catalog"), "{err:#}");

    let err = fleet.prepare(&[stub_op("hi", 0.25)]).unwrap_err();
    assert!(format!("{err:#}").contains("power mismatch"), "{err:#}");

    // an application-level rejection must not poison the connection
    fleet.prepare(&[stub_op("hi", 1.0), stub_op("lo", 0.5)]).unwrap();
    let out = fleet.forward(1, &[2.0, 0.0], 1).unwrap();
    assert_eq!(out.len(), 4);
    assert_eq!(fleet.live_workers(), 1);
    handle.kill();
}

#[test]
fn coordinator_mode_cross_check_catches_mismatched_workers() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = worker::spawn(listener, "w", "none", stub_catalog(), move |_conn| {
        Ok(StubBackend::new(4))
    })
    .unwrap();
    let addrs = vec![handle.addr().to_string()];
    let fleet = FleetBackend::connect(&addrs).unwrap();
    // powers are mode-independent, so Prepare alone cannot catch this;
    // the handshake-advertised mode can
    let err = fleet.check_mode("bn").unwrap_err();
    assert!(format!("{err:#}").contains("--mode"), "{err:#}");
    fleet.check_mode("none").unwrap();
    drop(fleet);

    // workers advertising no mode (in-process tests) are skipped
    let (h2, addr2) = stub_worker(4, Duration::ZERO, stub_catalog());
    let fleet = FleetBackend::connect(&[addr2]).unwrap();
    fleet.check_mode("bn").unwrap();
    drop(fleet);
    handle.kill();
    h2.kill();
}

#[test]
fn fleet_workers_must_agree_on_classifier_width() {
    let (h4, addr4) = stub_worker(4, Duration::ZERO, stub_catalog());
    let (h6, addr6) = stub_worker(6, Duration::ZERO, stub_catalog());
    let err = FleetBackend::connect(&[addr4, addr6]).unwrap_err();
    assert!(format!("{err:#}").contains("disagree"), "{err:#}");
    h4.kill();
    h6.kill();
}

#[test]
fn server_over_fleet_serves_waves_across_a_drain_switch() {
    let catalog = stub_catalog();
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let (h, addr) = stub_worker(4, Duration::from_millis(2), catalog.clone());
        handles.push(h);
        addrs.push(addr);
    }

    let stats = FleetStats::default();
    let control_stats = stats.clone();
    let factory_addrs = addrs.clone();
    let factory_stats = stats.clone();
    let server = Server::start(
        move |_w| FleetBackend::connect_with(&factory_addrs, factory_stats.clone()),
        OpTable::new(catalog),
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 1,
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    let mut control = FleetBackend::connect_with(&addrs, control_stats).unwrap();

    // wave 1 under OP0, then a coordinator-initiated fleet-wide drain
    // switch that every worker acks, then wave 2 under OP1
    let wave1: Vec<_> = (0..20)
        .map(|i| server.submit(vec![(i % 4) as f32, 0.0]).unwrap())
        .collect();
    let acks = control.set_operating_point(1, SwitchMode::Drain).unwrap();
    assert_eq!(acks, 2, "every surviving worker must ack before the switch is reported");
    server.set_operating_point_with(1, SwitchMode::Drain).unwrap();
    let wave2: Vec<_> = (0..20)
        .map(|i| server.submit(vec![(i % 4) as f32, 0.0]).unwrap())
        .collect();

    for rx in wave1 {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.op_index, 0);
        assert_eq!(resp.logits.len(), 4);
    }
    for rx in wave2 {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.op_index, 1);
    }

    let m = server.shutdown();
    assert_eq!(m.completed, 40);
    let (workers, _requeues, evictions) = stats.snapshot();
    assert_eq!(evictions, 0);
    let served: u64 = workers.iter().map(|(_, w)| w.requests).sum();
    assert_eq!(served, 40, "per-worker attribution must cover every request");

    for handle in handles {
        handle.kill();
    }
}

/// Seeded churn soak: continuous forwards compared bit-exact against a
/// local `StubBackend` while workers are severed, healed and
/// re-admitted and the fleet OP flips between Drain and Immediate
/// switches.  `cargo test -q --test fleet -- --ignored soak` runs it;
/// `QOS_NETS_SOAK_SEED` / `QOS_NETS_SOAK_SECS` override the script
/// (the CI advisory job runs a 3-seed matrix).
#[test]
#[ignore = "30 s churn soak; run explicitly (the CI advisory job does)"]
fn soak_kill_rejoin_churn_stays_bit_exact() {
    let env_u64 = |key: &str, default: u64| {
        std::env::var(key).ok().and_then(|v| v.trim().parse().ok()).unwrap_or(default)
    };
    let seed = env_u64("QOS_NETS_SOAK_SEED", 1);
    let secs = env_u64("QOS_NETS_SOAK_SECS", 30);
    let classes = 6usize;
    let catalog = stub_catalog();
    let mut rng = Rng::new(seed);

    let mut handles = Vec::new();
    let mut proxies = Vec::new();
    let mut addrs = Vec::new();
    for i in 0..3u64 {
        let (h, addr) = stub_worker(classes, Duration::from_millis(1), catalog.clone());
        let proxy = ChaosProxy::spawn(addr, rng.fork(i).next_u64(), ChaosConfig::default());
        addrs.push(proxy.addr().to_string());
        proxies.push(proxy);
        handles.push(h);
    }
    let stats = FleetStats::default();
    let mut fleet = FleetBackend::connect_with(&addrs, stats.clone()).unwrap();
    fleet.prepare(&catalog).unwrap();
    let mut local = StubBackend::new(classes);
    local.prepare(&catalog).unwrap();

    let t0 = Instant::now();
    let mut severed: Option<usize> = None;
    let mut iter = 0u64;
    let mut op = 0usize;
    while t0.elapsed() < Duration::from_secs(secs) {
        iter += 1;
        // churn: sever one proxy, then heal + re-admit it a few dozen
        // forwards later; at most one worker is down at a time, so
        // every forward retains quorum
        if iter % 17 == 0 {
            match severed.take() {
                Some(i) => {
                    proxies[i].heal();
                    fleet.reprobe();
                }
                None => {
                    let i = rng.below(proxies.len());
                    proxies[i].sever_now();
                    severed = Some(i);
                }
            }
        }
        // OP churn: both switch modes, against live traffic
        if iter % 29 == 0 {
            op = 1 - op;
            let mode = if rng.below(2) == 0 { SwitchMode::Drain } else { SwitchMode::Immediate };
            let _ = fleet.set_operating_point(op, mode);
        }
        let batch = 1 + rng.below(24);
        let images: Vec<f32> =
            (0..batch).flat_map(|_| [rng.below(classes) as f32, 0.0]).collect();
        let got = fleet.forward(op, &images, batch).unwrap();
        let want = local.forward(op, &images, batch).unwrap();
        assert_eq!(got, want, "soak iter {iter} (seed {seed}) diverged");
    }

    // settle: heal everything and re-admit the stragglers
    for p in &proxies {
        p.heal();
    }
    fleet.reprobe();
    assert_eq!(fleet.live_workers(), 3, "every worker must be re-admitted after the churn");
    let (workers, _, evictions) = stats.snapshot();
    let rejoins: u64 = workers.iter().map(|(_, w)| w.rejoins).sum();
    assert!(
        evictions >= 1 && rejoins >= 1,
        "the churn script must exercise evict + rejoin (seed {seed}: {evictions} evictions, {rejoins} rejoins over {iter} iters)"
    );
    for h in handles {
        h.kill();
    }
}
