//! Deterministic fault-injection TCP proxy for the fleet tests.
//!
//! A [`ChaosProxy`] sits between a coordinator and one worker and
//! forwards whole QFLT frames (it parses the `magic | header_len |
//! header | payload_len | payload` framing as raw bytes, without
//! interpreting headers), injecting faults driven by a SplitMix64
//! stream forked per connection and direction — so *which* frames get
//! delayed, split or severed is a pure function of the seed, not of
//! thread timing:
//!
//! * **delay** — sleep a seeded duration from a range before
//!   forwarding each frame (reorders completion across workers);
//! * **stall** — one long pause before the Nth forwarded frame
//!   (a worker that is alive but unresponsive);
//! * **split writes** — cut every frame at a seeded byte offset and
//!   flush the two halves separately (exercises short-read handling);
//! * **sever** — on the Nth forwarded frame, optionally emit a partial
//!   frame prefix, then cut both directions (a worker dying
//!   mid-stream, with a torn frame on the wire).
//!
//! After a sever — scripted via [`ChaosConfig::sever_on_frame`] or
//! manual via [`ChaosProxy::sever_now`] — the proxy accepts new
//! connections and immediately closes them, so coordinator re-probes
//! fail fast and deterministically instead of hanging; [`heal`]
//! restores full pass-through, letting the (still running) worker
//! rejoin without rebinding its listener.
//!
//! [`heal`]: ChaosProxy::heal

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use qos_nets::util::rng::Rng;

/// Fault script for one proxy; `default()` is transparent pass-through.
#[derive(Debug, Clone, Default)]
pub struct ChaosConfig {
    /// Sleep a seeded duration in this range before forwarding each
    /// frame.
    pub delay: Option<(Duration, Duration)>,
    /// Before forwarding the Nth frame (1-based, across connections
    /// and directions), pause this long.
    pub stall: Option<(u64, Duration)>,
    /// Cut every frame at a seeded byte offset and flush the halves
    /// separately, with a short pause in between.
    pub split_writes: bool,
    /// Sever the link on the Nth forwarded frame (1-based).
    pub sever_on_frame: Option<u64>,
    /// When severing, first emit a seeded-length prefix of the frame —
    /// the victim sees a torn frame, not a clean EOF.
    pub sever_mid_frame: bool,
}

struct ProxyShared {
    target: String,
    cfg: ChaosConfig,
    seed: u64,
    stop: AtomicBool,
    severed: AtomicBool,
    /// Frames fully or partially forwarded, across connections and
    /// directions (the counter the stall/sever scripts key on).
    forwarded: AtomicU64,
    /// Live stream clones, so `sever_now` can cut mid-read.
    conns: Mutex<Vec<TcpStream>>,
}

/// In-process fault-injection TCP proxy; see the module docs.
pub struct ChaosProxy {
    addr: SocketAddr,
    shared: Arc<ProxyShared>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ChaosProxy {
    /// Proxy `127.0.0.1:<ephemeral>` → `target`, with faults scripted
    /// by `cfg` and randomness derived from `seed`.
    pub fn spawn(target: impl Into<String>, seed: u64, cfg: ChaosConfig) -> ChaosProxy {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind chaos proxy");
        let addr = listener.local_addr().expect("chaos proxy address");
        listener.set_nonblocking(true).expect("chaos proxy nonblocking");
        let shared = Arc::new(ProxyShared {
            target: target.into(),
            cfg,
            seed,
            stop: AtomicBool::new(false),
            severed: AtomicBool::new(false),
            forwarded: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
        });
        let shared2 = shared.clone();
        let accept = std::thread::spawn(move || {
            let mut conn_id = 0u64;
            while !shared2.stop.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((client, _peer)) => {
                        if shared2.severed.load(Ordering::Acquire) {
                            // refuse fast: accept-then-close reads as
                            // EOF on the coordinator's handshake
                            let _ = client.shutdown(Shutdown::Both);
                            continue;
                        }
                        let Ok(upstream) = TcpStream::connect(&shared2.target) else {
                            let _ = client.shutdown(Shutdown::Both);
                            continue;
                        };
                        let _ = client.set_nodelay(true);
                        let _ = upstream.set_nodelay(true);
                        spawn_pumps(&shared2, client, upstream, conn_id);
                        conn_id += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            // unblock any pump still stuck in a read
            for c in shared2.conns.lock().unwrap().iter() {
                let _ = c.shutdown(Shutdown::Both);
            }
        });
        ChaosProxy { addr, shared, accept: Some(accept) }
    }

    /// The address coordinators should connect to instead of the
    /// worker's own.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Frames forwarded so far (fully or as a sever prefix).
    pub fn frames_forwarded(&self) -> u64 {
        self.shared.forwarded.load(Ordering::Acquire)
    }

    /// Whether the link is currently severed (scripted or manual).
    pub fn is_severed(&self) -> bool {
        self.shared.severed.load(Ordering::Acquire)
    }

    /// Cut every proxied connection now and refuse new ones until
    /// [`heal`](Self::heal) — the worker behind the proxy stays alive.
    pub fn sever_now(&self) {
        self.shared.severed.store(true, Ordering::Release);
        for c in self.shared.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(Shutdown::Both);
        }
    }

    /// Restore pass-through after a sever; new connections reach the
    /// worker again (the rejoin path).
    pub fn heal(&self) {
        self.shared.severed.store(false, Ordering::Release);
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        self.sever_now();
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

/// Start the two directional pumps for one proxied connection, each
/// with its own decorrelated RNG stream (tagged by connection id and
/// direction) so fault placement is deterministic per seed.
fn spawn_pumps(shared: &Arc<ProxyShared>, client: TcpStream, upstream: TcpStream, conn_id: u64) {
    let (Ok(client2), Ok(upstream2)) = (client.try_clone(), upstream.try_clone()) else {
        let _ = client.shutdown(Shutdown::Both);
        let _ = upstream.shutdown(Shutdown::Both);
        return;
    };
    {
        let mut conns = shared.conns.lock().unwrap();
        if let (Ok(c), Ok(u)) = (client.try_clone(), upstream.try_clone()) {
            conns.push(c);
            conns.push(u);
        }
    }
    let s1 = shared.clone();
    let rng1 = Rng::new(s1.seed).fork(conn_id * 2);
    std::thread::spawn(move || pump(client, upstream, &s1, rng1));
    let s2 = shared.clone();
    let rng2 = Rng::new(s2.seed).fork(conn_id * 2 + 1);
    std::thread::spawn(move || pump(upstream2, client2, &s2, rng2));
}

/// Read one raw QFLT frame (without interpreting the header).  Length
/// caps mirror the real parser's, so a desynchronized stream fails
/// instead of allocating garbage.
fn read_raw_frame(from: &mut TcpStream) -> std::io::Result<Vec<u8>> {
    let mut head = [0u8; 8]; // magic + header_len
    from.read_exact(&mut head)?;
    let hlen = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
    if &head[..4] != b"QFLT" || hlen == 0 || hlen > (1 << 20) {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad frame"));
    }
    let mut buf = vec![0u8; 8 + hlen + 4];
    buf[..8].copy_from_slice(&head);
    from.read_exact(&mut buf[8..])?;
    let plen = u32::from_le_bytes(buf[8 + hlen..].try_into().unwrap()) as usize;
    if plen > (1 << 30) {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad payload len"));
    }
    let at = buf.len();
    buf.resize(at + plen, 0);
    from.read_exact(&mut buf[at..])?;
    Ok(buf)
}

/// One direction of one proxied connection: forward whole frames,
/// injecting the scripted faults.
fn pump(mut from: TcpStream, mut to: TcpStream, shared: &ProxyShared, mut rng: Rng) {
    loop {
        if shared.stop.load(Ordering::Acquire) || shared.severed.load(Ordering::Acquire) {
            break;
        }
        let frame = match read_raw_frame(&mut from) {
            Ok(f) => f,
            Err(_) => break,
        };
        let n = shared.forwarded.fetch_add(1, Ordering::AcqRel) + 1;
        if let Some((at, pause)) = shared.cfg.stall {
            if n == at {
                std::thread::sleep(pause);
            }
        }
        if let Some((lo, hi)) = shared.cfg.delay {
            let span = hi.saturating_sub(lo);
            std::thread::sleep(lo + span.mul_f64(rng.f64()));
        }
        if shared.cfg.sever_on_frame == Some(n) {
            if shared.cfg.sever_mid_frame && frame.len() > 1 {
                // a torn frame: prefix only, then the cut
                let cut = 1 + rng.below(frame.len() - 1);
                let _ = to.write_all(&frame[..cut]);
                let _ = to.flush();
            }
            shared.severed.store(true, Ordering::Release);
            break;
        }
        let written = if shared.cfg.split_writes && frame.len() > 1 {
            let cut = 1 + rng.below(frame.len() - 1);
            to.write_all(&frame[..cut])
                .and_then(|()| to.flush())
                .and_then(|()| {
                    std::thread::sleep(Duration::from_millis(1));
                    to.write_all(&frame[cut..])
                })
        } else {
            to.write_all(&frame)
        };
        if written.and_then(|()| to.flush()).is_err() {
            break;
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}
