//! Shared in-memory fixtures for the integration test crates: a tiny
//! 1-conv + dense graph with hand-built parameters, plus a naive f32
//! reference convolution to check the engine against.

#![allow(dead_code)] // each test crate uses a subset of these helpers

pub mod chaos;

use std::collections::HashMap;
use std::sync::Arc;

use qos_nets::engine::OperatingPoint;
use qos_nets::muldb::MulDb;
use qos_nets::nn::{Graph, LayerParams, LayerStats, ModelParams};
use qos_nets::util::json;

/// Synthetic per-layer statistics for planner/error-model tests: flat
/// operand histograms, growing fan-in and MAC counts.
pub fn synthetic_stats(n: usize) -> Vec<LayerStats> {
    (0..n)
        .map(|i| LayerStats {
            name: format!("l{i}"),
            act_hist: vec![1.0 / 256.0; 256],
            w_hist: vec![1.0 / 256.0; 256],
            k_fanin: 64 * (i + 1),
            macs_total: 10_000 * (i + 1),
            s_act: 0.02,
            z_act: 128,
            s_w: 0.01,
            z_w: 128,
            bn_scale: 0.5,
            out_rms: 1.0,
        })
        .collect()
}

pub fn tiny_graph_json() -> json::Json {
    json::parse(
        r#"{
        "name": "tiny", "input_shape": [4, 4, 2], "total_macs": 1184,
        "nodes": [
          {"id":0,"kind":"input","inputs":[],"name":"input","out_shape":[4,4,2]},
          {"id":1,"kind":"conv","inputs":[0],"name":"c1","out_shape":[4,4,4],
           "cin":2,"cout":4,"ksize":3,"stride":1,"pad":1,"groups":1,
           "has_bn":false,"act":"relu","macs_per_out":18,"macs_total":1152,
           "quant":{"in":{"scale":0.01,"zero_point":128},"w":{"scale":0.02,"zero_point":128}}},
          {"id":2,"kind":"gap","inputs":[1],"name":"gap","out_shape":[4]},
          {"id":3,"kind":"dense","inputs":[2],"name":"fc","out_shape":[2],
           "cin":4,"cout":2,"ksize":0,"stride":1,"pad":0,"groups":1,
           "has_bn":false,"act":"none","macs_per_out":4,"macs_total":8,
           "quant":{"in":{"scale":0.02,"zero_point":100},"w":{"scale":0.02,"zero_point":128}}},
          {"id":4,"kind":"output","inputs":[3],"name":"output","out_shape":[2]}
        ]}"#,
    )
    .unwrap()
}

/// Naive float conv reference with quantize->dequantize operand semantics.
#[allow(clippy::needless_range_loop)]
pub fn naive_reference(images: &[f32], w1: &[f32], wfc: &[f32]) -> Vec<f32> {
    let (h, wd, cin, cout) = (4usize, 4usize, 2usize, 4usize);
    let q = |x: f32, s: f32, z: i32| -> f32 {
        let code = ((x / s).round_ties_even() as i32 + z).clamp(0, 255);
        s * (code - z) as f32
    };
    // conv, pad 1, stride 1, relu
    let mut conv = vec![0f32; h * wd * cout];
    for oy in 0..h {
        for ox in 0..wd {
            for oc in 0..cout {
                let mut acc = 0f32;
                for ky in 0..3usize {
                    for kx in 0..3usize {
                        let iy = oy as isize + ky as isize - 1;
                        let ix = ox as isize + kx as isize - 1;
                        if iy < 0 || iy >= h as isize || ix < 0 || ix >= wd as isize {
                            continue;
                        }
                        for ic in 0..cin {
                            let xv = q(images[((iy as usize) * wd + ix as usize) * cin + ic], 0.01, 128);
                            let wv = q(w1[((ky * 3 + kx) * cin + ic) * cout + oc], 0.02, 128);
                            acc += xv * wv;
                        }
                    }
                }
                conv[(oy * wd + ox) * cout + oc] = acc.max(0.0);
            }
        }
    }
    // gap
    let mut pooled = vec![0f32; cout];
    for pos in 0..h * wd {
        for c in 0..cout {
            pooled[c] += conv[pos * cout + c];
        }
    }
    for c in 0..cout {
        pooled[c] /= (h * wd) as f32;
    }
    // dense
    let mut out = vec![0f32; 2];
    for n in 0..2 {
        for k in 0..cout {
            out[n] += q(pooled[k], 0.02, 100) * q(wfc[k * 2 + n], 0.02, 128);
        }
    }
    out
}

/// The tiny fixture: graph + multiplier family + exact OP + a batch of
/// two images (and the raw float weights for the naive reference).
pub fn build_tiny() -> (Arc<Graph>, Arc<MulDb>, OperatingPoint, Vec<f32>, Vec<f32>, Vec<f32>) {
    let graph = Arc::new(Graph::from_json(&tiny_graph_json()).unwrap());
    let db = Arc::new(MulDb::generate());
    let mut rng = qos_nets::util::rng::Rng::new(11);
    let w1: Vec<f32> = (0..3 * 3 * 2 * 4).map(|_| rng.normal() as f32 * 0.2).collect();
    let wfc: Vec<f32> = (0..4 * 2).map(|_| rng.normal() as f32 * 0.3).collect();
    let images: Vec<f32> = (0..2 * 4 * 4 * 2).map(|_| rng.f64() as f32).collect();

    let q_codes = |w: &[f32], s: f32, z: i32| -> Vec<i32> {
        w.iter()
            .map(|&x| ((x / s).round_ties_even() as i32 + z).clamp(0, 255))
            .collect()
    };
    let mut layers = HashMap::new();
    layers.insert(
        "c1".to_string(),
        LayerParams {
            w_codes: q_codes(&w1, 0.02, 128),
            w_shape: vec![3, 3, 2, 4],
            post_scale: vec![0.01 * 0.02; 4],
            post_bias: vec![0.0; 4],
        },
    );
    layers.insert(
        "fc".to_string(),
        LayerParams {
            w_codes: q_codes(&wfc, 0.02, 128),
            w_shape: vec![4, 2],
            post_scale: vec![0.02 * 0.02; 2],
            post_bias: vec![0.0; 2],
        },
    );
    let op = OperatingPoint {
        name: "exact".into(),
        assignment: [("c1".to_string(), 0usize), ("fc".to_string(), 0usize)]
            .into_iter()
            .collect(),
        params: ModelParams { layers },
        relative_power: 1.0,
    };
    (graph, db, op, images, w1, wfc)
}

/// A parameter-free OperatingPoint for stub-backend tests — the shared
/// constructor lives next to the stub backend itself.
pub fn stub_op(name: &str, relative_power: f64) -> OperatingPoint {
    qos_nets::backend::stub::stub_op(name, relative_power)
}

fn residual_grouped_graph_json() -> json::Json {
    json::parse(
        r#"{
        "name": "resgrp", "input_shape": [4, 4, 2], "total_macs": 3896,
        "nodes": [
          {"id":0,"kind":"input","inputs":[],"name":"input","out_shape":[4,4,2]},
          {"id":1,"kind":"conv","inputs":[0],"name":"c1","out_shape":[4,4,4],
           "cin":2,"cout":4,"ksize":3,"stride":1,"pad":1,"groups":1,
           "has_bn":false,"act":"relu","macs_per_out":18,"macs_total":1152,
           "quant":{"in":{"scale":0.01,"zero_point":128},"w":{"scale":0.02,"zero_point":128}}},
          {"id":2,"kind":"conv","inputs":[1],"name":"c2","out_shape":[4,4,4],
           "cin":4,"cout":4,"ksize":3,"stride":1,"pad":1,"groups":2,
           "has_bn":false,"act":"relu","macs_per_out":18,"macs_total":1152,
           "quant":{"in":{"scale":0.02,"zero_point":120},"w":{"scale":0.02,"zero_point":130}}},
          {"id":3,"kind":"add","inputs":[1,2],"name":"res","out_shape":[4,4,4],"act":"relu"},
          {"id":4,"kind":"gap","inputs":[3],"name":"gap","out_shape":[4]},
          {"id":5,"kind":"dense","inputs":[4],"name":"fc","out_shape":[2],
           "cin":4,"cout":2,"ksize":0,"stride":1,"pad":0,"groups":1,
           "has_bn":false,"act":"none","macs_per_out":4,"macs_total":8,
           "quant":{"in":{"scale":0.02,"zero_point":100},"w":{"scale":0.02,"zero_point":128}}},
          {"id":6,"kind":"output","inputs":[5],"name":"output","out_shape":[2]}
        ]}"#,
    )
    .unwrap()
}

/// A residual fixture with a *grouped* conv: c1 feeds both c2 and the
/// add node (multi-consumer activation), c2 runs groups=2.  Exercises
/// the engine's grouped im2col path and the activation last-use
/// dropping in `forward` — returns graph, family, exact OP, and a
/// batch of two images.
pub fn build_residual_grouped() -> (Arc<Graph>, Arc<MulDb>, OperatingPoint, Vec<f32>) {
    let graph = Arc::new(Graph::from_json(&residual_grouped_graph_json()).unwrap());
    let db = Arc::new(MulDb::generate());
    let mut rng = qos_nets::util::rng::Rng::new(23);
    let mut codes = |n: usize| -> Vec<i32> { (0..n).map(|_| rng.below(256) as i32).collect() };
    let mut layers = HashMap::new();
    // weight codes are stored (K, cout) row-major; K = kh*kw*cin/groups
    layers.insert(
        "c1".to_string(),
        LayerParams {
            w_codes: codes(3 * 3 * 2 * 4),
            w_shape: vec![3, 3, 2, 4],
            post_scale: vec![0.01 * 0.02; 4],
            post_bias: vec![0.01; 4],
        },
    );
    layers.insert(
        "c2".to_string(),
        LayerParams {
            w_codes: codes(3 * 3 * 2 * 4),
            w_shape: vec![3, 3, 2, 4],
            post_scale: vec![0.02 * 0.02; 4],
            post_bias: vec![-0.01; 4],
        },
    );
    layers.insert(
        "fc".to_string(),
        LayerParams {
            w_codes: codes(4 * 2),
            w_shape: vec![4, 2],
            post_scale: vec![0.02 * 0.02; 2],
            post_bias: vec![0.0; 2],
        },
    );
    let op = OperatingPoint {
        name: "exact".into(),
        assignment: [
            ("c1".to_string(), 0usize),
            ("c2".to_string(), 0usize),
            ("fc".to_string(), 0usize),
        ]
        .into_iter()
        .collect(),
        params: ModelParams { layers },
        relative_power: 1.0,
    };
    let images: Vec<f32> = (0..2 * 4 * 4 * 2).map(|_| rng.f64() as f32).collect();
    (graph, db, op, images)
}
