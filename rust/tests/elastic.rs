//! Elastic-server tests: the scaling supervisor (burst -> grow, idle ->
//! retire), the draining OP-switch barrier, and per-OP latency
//! attribution — all stub-backed, no model artifacts needed.

mod common;

use std::time::{Duration, Instant};

use common::stub_op;
use qos_nets::backend::{OpTable, StubBackend};
use qos_nets::server::{scale_up_count, BatcherConfig, Server, SwitchMode};

/// Poll `cond` until it holds or `secs` elapse; panics with `what` on
/// timeout.  Scaling is asynchronous, so assertions must wait, not race.
fn wait_for(what: &str, secs: u64, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn elastic_cfg() -> BatcherConfig {
    BatcherConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        workers: 1,
        min_workers: 1,
        max_workers: 4,
        scale_interval: Duration::from_millis(10),
        scale_up_queue: 4,
        scale_up_wait: Duration::from_millis(10),
        scale_up_after: 1,
        scale_down_after: 5,
        ..BatcherConfig::default()
    }
}

#[test]
fn worker_pool_grows_under_burst_and_retires_when_idle() {
    // a slow stub: every batch costs 5 ms, so a burst builds real queue
    // depth that one worker cannot absorb
    let table = OpTable::new(vec![stub_op("only", 1.0)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4).with_delay(Duration::from_millis(5))),
        table,
        elastic_cfg(),
    )
    .unwrap();
    assert_eq!(server.live_workers(), 1, "pool must start at the floor");

    let mut rxs = Vec::new();
    for i in 0..300 {
        rxs.push(server.submit(vec![(i % 4) as f32, 0.0]).unwrap());
    }
    wait_for("worker pool to grow above its floor", 20, || {
        server.live_workers() > 1
    });

    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    // burst served: the supervisor must retire back down to the floor
    wait_for("worker pool to retire to its floor", 20, || {
        server.live_workers() == 1
    });

    let m = server.shutdown();
    assert_eq!(m.completed, 300);
    assert!(m.scale_ups >= 1, "scale_ups {}", m.scale_ups);
    assert!(m.scale_downs >= 1, "scale_downs {}", m.scale_downs);
    assert!(m.peak_workers >= 2, "peak_workers {}", m.peak_workers);
    assert!(m.peak_workers <= 4, "peak_workers {}", m.peak_workers);
}

#[test]
fn scale_up_count_spawns_one_worker_per_depth_threshold_multiple() {
    // wait-time pressure alone (queue shallower than one threshold):
    // a single spawn, as before scale-up batching
    assert_eq!(scale_up_count(5, 8, 1, 4), 1);
    // one full multiple -> 1, two -> 2, clamped by the ceiling headroom
    assert_eq!(scale_up_count(8, 8, 1, 4), 1);
    assert_eq!(scale_up_count(16, 8, 1, 4), 2);
    assert_eq!(scale_up_count(80, 8, 1, 4), 3);
    assert_eq!(scale_up_count(80, 8, 3, 4), 1);
    // no headroom: nothing to spawn
    assert_eq!(scale_up_count(80, 8, 4, 4), 0);
    // degenerate threshold must not divide by zero
    assert_eq!(scale_up_count(10, 0, 1, 4), 3);
}

#[test]
fn deep_burst_reaches_the_ceiling_in_one_pressured_tick() {
    // a long supervisor interval so only one or two ticks fire while
    // the burst is deep: reaching the 4-worker ceiling from the floor
    // requires the batched (multi-worker) spawn path
    let table = OpTable::new(vec![stub_op("only", 1.0)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4).with_delay(Duration::from_millis(5))),
        table,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 1,
            min_workers: 1,
            max_workers: 4,
            scale_interval: Duration::from_millis(100),
            scale_up_queue: 4,
            scale_up_wait: Duration::from_millis(10),
            scale_up_after: 1,
            scale_down_after: 10_000, // never retire during the test
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    assert_eq!(server.live_workers(), 1);

    // ~2000 requests at 5 ms per batch of 4 = seconds of single-worker
    // backlog: every supervisor tick sees hundreds of threshold
    // multiples until the pool catches up
    let mut rxs = Vec::new();
    for i in 0..2000 {
        rxs.push(server.submit(vec![(i % 4) as f32, 0.0]).unwrap());
    }
    wait_for("pool to reach the ceiling", 20, || server.live_workers() == 4);

    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 2000);
    assert_eq!(m.peak_workers, 4);
    // floor -> ceiling is exactly three spawns; batching must not
    // overshoot the ceiling or double-count
    assert_eq!(m.scale_ups, 3, "scale_ups {}", m.scale_ups);
    assert_eq!(m.scale_downs, 0);
}

#[test]
fn explicit_pool_target_overrides_watermark_scaling() {
    // idle server (no load at all): the watermark heuristics would
    // never grow the pool, so reaching 3 workers proves the explicit
    // target drove the supervisor
    let table = OpTable::new(vec![stub_op("only", 1.0)]);
    let server = Server::start(|_w| Ok(StubBackend::new(4)), table, elastic_cfg()).unwrap();
    assert_eq!(server.live_workers(), 1);
    assert_eq!(server.pool_target(), None);

    // target above the ceiling clamps to it; 3 is in range and sticks
    assert_eq!(server.set_pool_target(100), 4);
    assert_eq!(server.set_pool_target(3), 3);
    assert_eq!(server.pool_target(), Some(3));
    wait_for("pool to grow to the explicit target", 20, || {
        server.live_workers() == 3
    });

    // shrink target: the supervisor retires back down, one per tick
    assert_eq!(server.set_pool_target(0), 1);
    wait_for("pool to shrink to the explicit target", 20, || {
        server.live_workers() == 1
    });

    // releasing the target hands control back to the heuristics (the
    // idle pool just stays at the floor)
    server.clear_pool_target();
    assert_eq!(server.pool_target(), None);
    let m = server.shutdown();
    assert!(m.scale_ups >= 2, "scale_ups {}", m.scale_ups);
    assert!(m.scale_downs >= 2, "scale_downs {}", m.scale_downs);
}

#[test]
fn static_pool_never_scales() {
    // default bounds (0/0 = "same as workers"): no supervisor, fixed pool
    let table = OpTable::new(vec![stub_op("only", 1.0)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4).with_delay(Duration::from_millis(2))),
        table,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 2,
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    let mut rxs = Vec::new();
    for i in 0..100 {
        rxs.push(server.submit(vec![(i % 4) as f32, 0.0]).unwrap());
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    assert_eq!(server.live_workers(), 2);
    let m = server.shutdown();
    assert_eq!(m.scale_ups, 0);
    assert_eq!(m.scale_downs, 0);
    assert_eq!(m.peak_workers, 2);
}

#[test]
fn drain_switch_never_lets_a_batch_span_the_op_change() {
    // single slow worker so batches queue up across the switch point
    let table = OpTable::new(vec![stub_op("hi", 1.0), stub_op("lo", 0.5)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4).with_delay(Duration::from_millis(2))),
        table,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 1,
            ..BatcherConfig::default()
        },
    )
    .unwrap();

    // alternate request waves and draining switches; every wave must be
    // answered entirely under the OP that was current when it was
    // submitted, with no batch mixing op_index values
    let mut waves = Vec::new();
    for wave in 0..4usize {
        let op = wave % 2;
        let mut rxs = Vec::new();
        for i in 0..25 {
            rxs.push(server.submit(vec![(i % 4) as f32, 0.0]).unwrap());
        }
        waves.push((op, rxs));
        server.set_operating_point_with((op + 1) % 2, SwitchMode::Drain).unwrap();
    }

    let mut batch_ops: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
    for (expect_op, rxs) in waves {
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(
                resp.op_index, expect_op,
                "a drained switch leaked a request onto the wrong OP"
            );
            // two responses sharing a batch_seq must share an op_index
            let prev = batch_ops.insert(resp.batch_seq, resp.op_index);
            if let Some(p) = prev {
                assert_eq!(p, resp.op_index, "batch {} spans an OP switch", resp.batch_seq);
            }
        }
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 100);
    assert_eq!(m.per_op_requests, vec![50, 50]);
}

/// Build a deep already-formed backlog under OP0, then switch
/// immediately to `target`; returns the per-OP request counts and the
/// retagged-batch count.  The slow single worker guarantees most
/// batches are still queued (formed, worker-channel) when the switch
/// fires, and every request is submitted *before* it — so any response
/// tagged with the new OP can only come from execution-time retagging.
fn immediate_switch_over_backlog(retag: bool, target: usize) -> (Vec<u64>, u64, Vec<usize>) {
    let table = OpTable::new(vec![stub_op("expensive", 1.0), stub_op("cheap", 0.5)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4).with_delay(Duration::from_millis(10))),
        table,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 1,
            retag_downgrades: retag,
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = (0..80)
        .map(|i| server.submit(vec![(i % 4) as f32, 0.0]).unwrap())
        .collect();
    // let the batcher form every batch (size-triggered, fast) and the
    // worker chew through a few of them under OP0
    std::thread::sleep(Duration::from_millis(40));
    server.set_operating_point(target); // Immediate switch
    let op_indices: Vec<usize> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap().op_index)
        .collect();
    let m = server.shutdown();
    assert_eq!(m.completed, 80);
    (m.per_op_requests.clone(), m.retagged_batches, op_indices)
}

#[test]
fn immediate_downgrade_retags_already_formed_batches_when_enabled() {
    // policy ON, downgrade (1.0 -> 0.5): the queued backlog must not
    // all finish at the old power
    let (per_op, retagged, op_indices) = immediate_switch_over_backlog(true, 1);
    assert!(
        per_op[1] > 0,
        "no request ran under the cheaper OP despite retagging: {per_op:?}"
    );
    assert!(retagged > 0, "retagged_batches must count the policy's work");
    // early batches legitimately ran under OP0 before the switch; after
    // the first OP1 response the backlog must stay on the cheap rung
    let first_cheap = op_indices.iter().position(|&op| op == 1).unwrap();
    assert!(
        op_indices[first_cheap..].iter().all(|&op| op == 1),
        "backlog bounced back to the expensive OP after the downgrade"
    );
}

#[test]
fn immediate_downgrade_without_retag_finishes_backlog_at_old_power() {
    // policy OFF (strict formation-time tagging, the PR-2 trade-off):
    // every request was submitted and formed before the switch, so the
    // whole backlog completes under OP0
    let (per_op, retagged, _) = immediate_switch_over_backlog(false, 1);
    assert_eq!(per_op, vec![80, 0], "formation tags must be honored verbatim");
    assert_eq!(retagged, 0);
}

#[test]
fn drain_switch_never_retags_even_with_policy_enabled() {
    // a Drain switch promises every pre-barrier request the old OP;
    // the retag policy must not break that promise (it only arms on
    // Immediate switches)
    let table = OpTable::new(vec![stub_op("expensive", 1.0), stub_op("cheap", 0.5)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4).with_delay(Duration::from_millis(10))),
        table,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 1,
            retag_downgrades: true,
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = (0..40)
        .map(|i| server.submit(vec![(i % 4) as f32, 0.0]).unwrap())
        .collect();
    std::thread::sleep(Duration::from_millis(30));
    // drained downgrade over a deep backlog: pre-barrier batches keep OP0
    server.set_operating_point_with(1, SwitchMode::Drain).unwrap();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.op_index, 0, "a Drain switch must honor formation tags");
    }
    let m = server.shutdown();
    assert_eq!(m.retagged_batches, 0);
    assert_eq!(m.per_op_requests, vec![40, 0]);
}

#[test]
fn immediate_upgrade_never_retags_queued_batches() {
    // policy ON, but the switch goes cheap -> expensive: the backlog
    // formed under the cheap rung must keep its tag — retagging only
    // ever *lowers* power, never spends accuracy requests were not
    // promised
    let table = OpTable::new(vec![stub_op("expensive", 1.0), stub_op("cheap", 0.5)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4).with_delay(Duration::from_millis(10))),
        table,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 1,
            retag_downgrades: true,
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    server.set_operating_point(1); // start on the cheap rung
    let rxs: Vec<_> = (0..40)
        .map(|i| server.submit(vec![(i % 4) as f32, 0.0]).unwrap())
        .collect();
    std::thread::sleep(Duration::from_millis(30));
    server.set_operating_point(0); // Immediate *upgrade*
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(
            resp.op_index, 1,
            "an upgrade retagged a batch that was promised the cheap rung"
        );
    }
    let m = server.shutdown();
    assert_eq!(m.retagged_batches, 0);
}

#[test]
fn per_op_latency_histograms_attribute_every_request() {
    let table = OpTable::new(vec![
        stub_op("accurate", 0.9),
        stub_op("mid", 0.7),
        stub_op("frugal", 0.5),
    ]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4)),
        table,
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            workers: 1,
            ..BatcherConfig::default()
        },
    )
    .unwrap();

    // serve a few requests under every OP, separated by drain barriers
    // so the attribution is exact
    for op in 0..3usize {
        server.set_operating_point_with(op, SwitchMode::Drain).unwrap();
        let rxs: Vec<_> = (0..10)
            .map(|i| server.submit(vec![(i % 4) as f32, 0.0]).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.op_index, op);
        }
    }

    let m = server.shutdown();
    assert_eq!(m.completed, 30);
    assert_eq!(m.per_op_requests, vec![10, 10, 10]);
    for op in 0..3 {
        assert_eq!(
            m.per_op_latency[op].count(),
            10,
            "per-OP histogram {op} must hold exactly its requests"
        );
        assert!(m.per_op_latency[op].mean_us() > 0.0);
    }
    // the aggregate histogram is the union of the per-OP ones
    assert_eq!(m.latency.count(), 30);
}
