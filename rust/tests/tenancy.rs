//! Multi-tenant serving: weighted admission under overload, per-class
//! metrics attribution, and the per-class operating-point words.
//!
//! The admission test pins the tenancy tentpole's core promise: when
//! the deployment saturates its `max_inflight` ceiling, every rejected
//! request is best-effort until the deployment is *hard-full* — only
//! then does premium start bouncing.

mod common;

use std::time::Duration;

use common::stub_op;
use qos_nets::backend::{OpTable, StubBackend};
use qos_nets::qos::ClassSet;
use qos_nets::server::{BatcherConfig, Server, SwitchMode};

/// Two classes out of the serve-command flag syntax: premium (class 0,
/// share 3) and best_effort (class 1, share 1).
fn two_classes() -> ClassSet {
    ClassSet::from_flags(&["premium:100:3".to_string(), "best_effort:250:1".to_string()])
        .expect("valid tenant flags")
}

fn tenant_cfg(classes: &ClassSet, max_inflight: usize) -> BatcherConfig {
    BatcherConfig {
        max_batch: 2,
        max_wait: Duration::from_millis(2),
        workers: 1,
        classes: classes.len(),
        class_names: classes.names(),
        admit_fracs: classes.admit_fracs(),
        max_inflight,
        ..BatcherConfig::default()
    }
}

#[test]
fn overload_rejects_best_effort_first_and_premium_only_when_hard_full() {
    let classes = two_classes();
    // premium reaches the whole ceiling; best_effort only its share
    // slice: floor(1/4 * 8) = 2 in-flight requests
    let fracs = classes.admit_fracs();
    assert!((fracs[0] - 1.0).abs() < 1e-9, "premium frac {fracs:?}");
    assert!((fracs[1] - 0.25).abs() < 1e-9, "best_effort frac {fracs:?}");

    // a slow backend keeps everything in flight for the whole test, so
    // admission decisions depend only on the submission order
    let table = OpTable::new(vec![stub_op("hi", 1.0), stub_op("lo", 0.5)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4).with_delay(Duration::from_millis(150))),
        table,
        tenant_cfg(&classes, 8),
    )
    .unwrap();

    let mut rxs = Vec::new();
    // 4 best-effort submissions: the first two fill the class's slice,
    // the next two bounce while premium's share stays untouched
    let mut be_rejected = 0u64;
    for i in 0..4 {
        match server.submit_class(1, vec![(i % 4) as f32, 0.0]).unwrap() {
            Some(rx) => rxs.push(rx),
            None => be_rejected += 1,
        }
    }
    assert_eq!(be_rejected, 2, "best_effort over its slice must bounce");

    // premium fills the remaining ceiling (2 in flight, cap 8): six
    // more all admitted — none of the best-effort rejections freed
    // capacity premium could not reach anyway
    for i in 0..6 {
        let rx = server
            .submit_class(0, vec![(i % 4) as f32, 0.0])
            .unwrap()
            .expect("premium must be admitted until the deployment is hard-full");
        rxs.push(rx);
    }
    // hard-full: 8 in flight = the ceiling; now premium bounces too
    assert!(
        server.submit_class(0, vec![0.0, 0.0]).unwrap().is_none(),
        "premium must only bounce when the deployment is hard-full"
    );

    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.per_class.len(), 2);
    assert_eq!(m.per_class[0].submitted, 7);
    assert_eq!(m.per_class[0].completed, 6);
    assert_eq!(m.per_class[0].rejected, 1);
    assert_eq!(m.per_class[1].submitted, 4);
    assert_eq!(m.per_class[1].completed, 2);
    assert_eq!(m.per_class[1].rejected, 2);
    // every rejection before the hard-full probe was best-effort
    assert_eq!(m.per_class[1].rejected, be_rejected);
}

#[test]
fn unlimited_inflight_admits_every_class_and_splits_metrics() {
    let classes = two_classes();
    let table = OpTable::new(vec![stub_op("hi", 1.0), stub_op("lo", 0.5)]);
    let server = Server::start(
        |_w| Ok(StubBackend::new(4)),
        table,
        tenant_cfg(&classes, 0), // 0 = no admission control
    )
    .unwrap();

    // steer only best_effort onto the frugal rung; premium batches must
    // keep the exact OP
    server.set_class_operating_point_with(1, 1, SwitchMode::Drain).unwrap();

    let mut rxs = Vec::new();
    for i in 0..6 {
        let class = i % 2;
        let rx = server
            .submit_class(class, vec![(i % 4) as f32, 0.0])
            .unwrap()
            .expect("max_inflight 0 admits everything");
        rxs.push((class, rx));
    }
    for (class, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        let want_op = if class == 0 { 0 } else { 1 };
        assert_eq!(resp.op_index, want_op, "class {class} ran on the wrong OP");
    }
    let m = server.shutdown();
    assert_eq!(m.per_class[0].submitted, 3);
    assert_eq!(m.per_class[1].submitted, 3);
    assert_eq!(m.per_class[0].rejected + m.per_class[1].rejected, 0);
    assert_eq!(m.per_class[0].completed + m.per_class[1].completed, 6);
}
