//! Lloyd's k-Means with k-means++ seeding (deterministic PRNG).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct KMeansResult {
    pub centroids: Vec<Vec<f64>>,
    pub assignment: Vec<usize>,
    pub inertia: f64,
    pub iterations: usize,
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// k-means++ initial centroids.
fn seed_centroids(points: &[Vec<f64>], k: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    let mut centroids = Vec::with_capacity(k);
    centroids.push(points[rng.below(points.len())].clone());
    let mut d2: Vec<f64> = points.iter().map(|p| dist2(p, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // all points coincide with a centroid: pick uniformly
            points[rng.below(points.len())].clone()
        } else {
            let mut target = rng.f64() * total;
            let mut chosen = points.len() - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d;
                if target <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            points[chosen].clone()
        };
        for (i, p) in points.iter().enumerate() {
            d2[i] = d2[i].min(dist2(p, &next));
        }
        centroids.push(next);
    }
    centroids
}

/// One full Lloyd run.
fn lloyd(points: &[Vec<f64>], k: usize, rng: &mut Rng, max_iter: usize) -> KMeansResult {
    let dim = points[0].len();
    let mut centroids = seed_centroids(points, k, rng);
    let mut assignment = vec![0usize; points.len()];
    let mut iterations = 0;
    for it in 0..max_iter {
        iterations = it + 1;
        // assign
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let mut best = 0;
            let mut bestd = f64::MAX;
            for (c, cen) in centroids.iter().enumerate() {
                let d = dist2(p, cen);
                if d < bestd {
                    bestd = d;
                    best = c;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        if !changed && it > 0 {
            break;
        }
        // update
        let mut sums = vec![vec![0.0f64; dim]; k];
        let mut counts = vec![0usize; k];
        for (p, &a) in points.iter().zip(&assignment) {
            counts[a] += 1;
            for (s, v) in sums[a].iter_mut().zip(p) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster at the point farthest from its centroid
                let far = points
                    .iter()
                    .enumerate()
                    .max_by(|(_, a), (_, b)| {
                        dist2(a, &centroids[assignment[0]])
                            .partial_cmp(&dist2(b, &centroids[assignment[0]]))
                            .unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                centroids[c] = points[far].clone();
            } else {
                for (j, s) in sums[c].iter().enumerate() {
                    centroids[c][j] = s / counts[c] as f64;
                }
            }
        }
    }
    let inertia = points
        .iter()
        .zip(&assignment)
        .map(|(p, &a)| dist2(p, &centroids[a]))
        .sum();
    KMeansResult {
        centroids,
        assignment,
        inertia,
        iterations,
    }
}

/// k-Means with `restarts` independent k-means++ seeds; best inertia wins.
/// `k` is clamped to the number of distinct points.
pub fn kmeans(points: &[Vec<f64>], k: usize, seed: u64, restarts: usize) -> KMeansResult {
    assert!(!points.is_empty(), "kmeans on empty input");
    let k = k.min(points.len()).max(1);
    let mut rng = Rng::new(seed);
    let mut best: Option<KMeansResult> = None;
    for _ in 0..restarts.max(1) {
        let res = lloyd(points, k, &mut rng, 100);
        if best.as_ref().map(|b| res.inertia < b.inertia).unwrap_or(true) {
            best = Some(res);
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(rng: &mut Rng, center: &[f64], n: usize, spread: f64) -> Vec<Vec<f64>> {
        (0..n)
            .map(|_| center.iter().map(|c| c + spread * rng.normal()).collect())
            .collect()
    }

    #[test]
    fn separates_clear_blobs() {
        let mut rng = Rng::new(1);
        let mut pts = blob(&mut rng, &[0.0, 0.0], 30, 0.1);
        pts.extend(blob(&mut rng, &[10.0, 10.0], 30, 0.1));
        pts.extend(blob(&mut rng, &[-10.0, 10.0], 30, 0.1));
        let res = kmeans(&pts, 3, 42, 4);
        // each blob is one cluster
        for chunk in 0..3 {
            let first = res.assignment[chunk * 30];
            for i in 0..30 {
                assert_eq!(res.assignment[chunk * 30 + i], first, "blob {chunk}");
            }
        }
    }

    #[test]
    fn k_clamped_to_point_count() {
        let pts = vec![vec![1.0], vec![2.0]];
        let res = kmeans(&pts, 10, 0, 2);
        assert!(res.centroids.len() <= 2);
    }

    #[test]
    fn inertia_zero_for_k_equals_n_distinct() {
        let pts = vec![vec![0.0], vec![5.0], vec![9.0]];
        let res = kmeans(&pts, 3, 7, 8);
        assert!(res.inertia < 1e-18, "inertia {}", res.inertia);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(9);
        let pts = blob(&mut rng, &[0.0, 1.0, 2.0], 50, 1.0);
        let a = kmeans(&pts, 4, 123, 3);
        let b = kmeans(&pts, 4, 123, 3);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.inertia, b.inertia);
    }
}
