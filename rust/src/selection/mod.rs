//! QoS-Nets multiplier selection (paper Sec. 3.1 + 3.2).
//!
//! Pipeline: sigma_e matrix + sigma_g vector
//!   -> per-(layer, operating point) preference vectors  (Eq. 1, Eq. 4)
//!   -> outlier reweighting f(x) = x | 1 + ln(x)          (Eq. 3)
//!   -> k-Means into n clusters                           (Sec. 3.1)
//!   -> per-centroid multiplier pick (cheapest accurate-enough entry)
//!   -> assignment of one AM instance per (layer, OP).

pub mod kmeans;

use std::collections::BTreeSet;

use crate::errmodel::SigmaE;
use crate::muldb::MulDb;
use crate::nn::LayerStats;

/// Eq. 3: squash insufficient-accuracy entries (x > 1) logarithmically so
/// they keep their ordering but lose their drag on the clustering.
#[inline]
pub fn reweight(x: f64) -> f64 {
    if x <= 1.0 {
        x
    } else {
        1.0 + x.ln()
    }
}

/// Filter step from Sec. 3.1: drop multipliers that are not accurate
/// enough for *any* layer at the most accurate operating point — they can
/// never be part of a solution.  Returns the retained multiplier ids.
pub fn usable_multipliers(se: &SigmaE, sigma_g: &[f64], scales: &[f64]) -> Vec<usize> {
    let smin = scales.iter().cloned().fold(f64::MAX, f64::min);
    (0..se.m)
        .filter(|&j| {
            (0..se.l).any(|k| se.get(j, k) <= smin * sigma_g[k])
        })
        .collect()
}

/// One preference vector per (operating point, layer): entry per usable
/// multiplier, sigma_e / (s * sigma_g), reweighted (Eq. 1, 3, 4).
pub fn preference_vectors(
    se: &SigmaE,
    sigma_g: &[f64],
    scales: &[f64],
    usable: &[usize],
) -> Vec<Vec<f64>> {
    let mut out = Vec::with_capacity(scales.len() * se.l);
    for &s in scales {
        for k in 0..se.l {
            let tol = (s * sigma_g[k]).max(1e-12);
            let v: Vec<f64> = usable.iter().map(|&j| reweight(se.get(j, k) / tol)).collect();
            out.push(v);
        }
    }
    out
}

/// Pick, for one centroid, the cheapest usable multiplier whose centroid
/// entry signals sufficient accuracy (< 1).  Falls back to the most
/// accurate entry if none qualifies (soft-constraint escape hatch).
pub fn pick_for_centroid(centroid: &[f64], usable: &[usize], db: &MulDb) -> usize {
    let mut best: Option<(f64, usize)> = None;
    for (i, &j) in usable.iter().enumerate() {
        if centroid[i] < 1.0 {
            let p = db.power(j);
            if best.map(|(bp, _)| p < bp).unwrap_or(true) {
                best = Some((p, j));
            }
        }
    }
    if let Some((_, j)) = best {
        return j;
    }
    // no entry accurate enough on average: take the most accurate one
    usable[centroid
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)]
}

/// Full QoS-Nets solution.
#[derive(Debug, Clone)]
pub struct Solution {
    /// Multiplier id chosen per cluster.
    pub cluster_muls: Vec<usize>,
    /// `assignment[op][layer]` = multiplier id.
    pub assignment: Vec<Vec<usize>>,
    /// Distinct multipliers used (<= n).
    pub subset: Vec<usize>,
    /// MAC-weighted relative power per operating point.
    pub power: Vec<f64>,
    pub kmeans_inertia: f64,
}

#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub n_multipliers: usize,
    pub scales: Vec<f64>,
    pub seed: u64,
    pub restarts: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            n_multipliers: 4,
            scales: vec![1.0],
            seed: 0,
            restarts: 8,
        }
    }
}

/// The constrained multi-operating-point search (paper Sec. 3.1 + 3.2).
pub fn search(
    db: &MulDb,
    se: &SigmaE,
    sigma_g: &[f64],
    stats: &[LayerStats],
    cfg: &SearchConfig,
) -> Solution {
    assert_eq!(se.l, sigma_g.len());
    assert_eq!(se.l, stats.len());
    let usable = usable_multipliers(se, sigma_g, &cfg.scales);
    assert!(!usable.is_empty(), "no usable multipliers in search space");

    let points = preference_vectors(se, sigma_g, &cfg.scales, &usable);
    let km = kmeans::kmeans(&points, cfg.n_multipliers, cfg.seed, cfg.restarts);

    let cluster_muls: Vec<usize> = km
        .centroids
        .iter()
        .map(|c| pick_for_centroid(c, &usable, db))
        .collect();

    let o = cfg.scales.len();
    let l = se.l;
    let mut assignment = vec![vec![0usize; l]; o];
    for (idx, &cluster) in km.assignment.iter().enumerate() {
        let op = idx / l;
        let layer = idx % l;
        assignment[op][layer] = cluster_muls[cluster];
    }

    let power: Vec<f64> = assignment
        .iter()
        .map(|a| crate::errmodel::relative_power(db, stats, a))
        .collect();

    let subset: Vec<usize> = {
        let s: BTreeSet<usize> = assignment.iter().flatten().cloned().collect();
        s.into_iter().collect()
    };

    Solution {
        cluster_muls,
        assignment,
        subset,
        power,
        kmeans_inertia: km.inertia,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::errmodel::sigma_e;
    use crate::muldb::MulDb;
    use crate::nn::LayerStats;

    fn fake_stats(n: usize) -> Vec<LayerStats> {
        (0..n)
            .map(|i| LayerStats {
                name: format!("l{i}"),
                act_hist: vec![1.0 / 256.0; 256],
                w_hist: vec![1.0 / 256.0; 256],
                k_fanin: 64 * (i + 1),
                macs_total: 10_000 * (i + 1),
                s_act: 0.02,
                z_act: 128,
                s_w: 0.01,
                z_w: 128,
                bn_scale: 0.5,
                out_rms: 1.0,
            })
            .collect()
    }

    #[test]
    fn reweight_monotone_and_continuous() {
        assert_eq!(reweight(0.5), 0.5);
        assert_eq!(reweight(1.0), 1.0);
        assert!((reweight(1.0 + 1e-12) - 1.0).abs() < 1e-9);
        let mut prev = 0.0;
        for i in 1..1000 {
            let x = i as f64 * 0.01;
            let y = reweight(x);
            assert!(y >= prev);
            prev = y;
        }
    }

    #[test]
    fn solution_respects_n_constraint() {
        let db = MulDb::generate();
        let stats = fake_stats(12);
        let se = sigma_e(&db, &stats);
        // generous tolerances so plenty of multipliers are usable
        let sigma_g: Vec<f64> = (0..12).map(|i| 0.05 + 0.03 * i as f64).collect();
        for n in [2usize, 3, 4] {
            let cfg = SearchConfig {
                n_multipliers: n,
                scales: vec![0.3, 1.0],
                seed: 1,
                restarts: 4,
            };
            let sol = search(&db, &se, &sigma_g, &stats, &cfg);
            assert!(sol.subset.len() <= n, "n={n}: got {:?}", sol.subset);
            assert_eq!(sol.assignment.len(), 2);
            assert_eq!(sol.assignment[0].len(), 12);
        }
    }

    #[test]
    fn more_aggressive_scale_never_costs_more_power_on_average() {
        let db = MulDb::generate();
        let stats = fake_stats(10);
        let se = sigma_e(&db, &stats);
        let sigma_g: Vec<f64> = (0..10).map(|i| 0.08 + 0.05 * i as f64).collect();
        let cfg = SearchConfig {
            n_multipliers: 4,
            scales: vec![0.1, 1.0],
            seed: 3,
            restarts: 6,
        };
        let sol = search(&db, &se, &sigma_g, &stats, &cfg);
        // scale 0.1 = accuracy-first OP; scale 1.0 = power-first OP
        assert!(
            sol.power[0] >= sol.power[1] - 1e-9,
            "power {:?} not ordered",
            sol.power
        );
    }

    #[test]
    fn exact_always_usable() {
        let db = MulDb::generate();
        let stats = fake_stats(4);
        let se = sigma_e(&db, &stats);
        let sigma_g = vec![1e-9; 4]; // impossibly tight
        let usable = usable_multipliers(&se, &sigma_g, &[1.0]);
        assert!(usable.contains(&0), "exact must survive the filter");
    }
}
