//! QoS controller: runtime operating-point selection (the paper's
//! "gracefully adjusting the platform's Quality of Service").
//!
//! The ladder holds the searched operating points sorted from most
//! accurate (highest power) to most frugal.  The controller receives a
//! time-varying *power budget* (relative multiplication power the
//! platform can currently afford — e.g. from a battery / thermal
//! governor) and picks the most accurate OP that fits, with hysteresis
//! (switch margin + minimum dwell time) so budget noise does not cause
//! oscillation.
//!
//! Besides *which* OP to run, the controller also decides *how* the
//! switch is applied ([`SwitchMode`]): budget-driven downgrades are
//! urgent and applied immediately, while upgrades drain the in-flight
//! work first so every batch stays strictly OP-tagged.  See
//! `docs/ARCHITECTURE.md` for how this couples to the serving stack.

pub mod envsim;
pub mod tenants;

pub use tenants::{ClassSet, TenantClass};

use std::time::{Duration, Instant};

/// How an operating-point switch is applied by the serving stack
/// (consumed by `crate::server::Server::set_operating_point_with` and,
/// fleet-wide, by `crate::fleet::FleetBackend::set_operating_point`,
/// where `Drain` means every surviving remote worker acks a barrier
/// before the switch is reported complete and `Immediate` is a
/// fire-and-forget broadcast).
///
/// Either way a single batch never mixes logits from two OPs — batches
/// are OP-tagged at formation time.  The modes differ in what happens
/// to requests that are already queued when the switch fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwitchMode {
    /// Apply at the next batch formation: requests still waiting in the
    /// batcher run under the *new* OP.  This is a single atomic store —
    /// the right choice for urgent downgrades (budget collapse), where
    /// honoring the power budget beats finishing the queue at the old
    /// accuracy.  Batches already formed and queued to workers keep
    /// their old tag, so a deep backlog rides out the switch at the old
    /// power for those batches — the price of strict OP-tagging.
    Immediate,
    /// Install a barrier: the batcher first flushes every request
    /// enqueued before the switch as batches tagged with the *old* OP,
    /// then applies the new index.  Requests submitted after the
    /// barrier is installed are guaranteed to run under the new OP —
    /// strict OP-tagging for accounting and accuracy attribution.
    Drain,
}

/// One rung of the operating-point ladder as the controller sees it.
///
/// Ladders come from a live `OpTable` (`crate::backend::OpTable::ladder`)
/// or straight from a stored plan (`crate::plan::OpPlan::ladder`); both
/// hand out the same table indices, so a controller can be built before
/// any backend exists.
#[derive(Debug, Clone)]
pub struct LadderEntry {
    /// Operating-point name (matches `OperatingPoint::name`).
    pub name: String,
    /// MAC-weighted relative multiplication power of this OP.
    pub power: f64,
    /// Index of this entry in the `OpTable` it was built from.  The
    /// controller sorts its ladder internally by power; this field is
    /// what [`QosController::observe`] reports, so results stay valid
    /// for servers/backends even when the table is not stored in
    /// power-descending order.
    pub table_index: usize,
}

/// Hysteresis knobs for [`QosController`].
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// Extra headroom a *more expensive* OP must have before we upgrade
    /// (fraction of budget).  Downgrades happen immediately.
    pub upgrade_margin: f64,
    /// Minimum time between switches.
    pub min_dwell: Duration,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            upgrade_margin: 0.05,
            min_dwell: Duration::from_millis(100),
        }
    }
}

/// Budget-driven operating-point selector with hysteresis.
///
/// Feed it budget samples via [`observe`](QosController::observe) (or
/// [`observe_with_mode`](QosController::observe_with_mode) when driving
/// a live server); it answers with the `OpTable` index to switch to.
#[derive(Debug)]
pub struct QosController {
    ladder: Vec<LadderEntry>, // sorted by power descending (most accurate first)
    cfg: QosConfig,
    current: usize, // position in the sorted ladder, NOT a table index
    last_switch: Option<Instant>,
    last_cap_saturated: bool,
    /// Number of switches fired so far.
    pub switches: u64,
    /// Number of budget samples observed while the current OP exceeded
    /// the budget (including samples where nothing cheaper existed).
    pub budget_violations: u64,
    /// Number of capped observations that found the cap pinning the
    /// controller at the frugal floor with nothing left to shed (the
    /// `CapSaturated` signal of
    /// [`observe_capped_signal`](Self::observe_capped_signal)).
    pub cap_saturations: u64,
}

impl QosController {
    /// Build a controller from a ladder (e.g. `OpTable::ladder()`).
    /// Entries are sorted internally by descending power; the original
    /// table indices are preserved in [`LadderEntry::table_index`] and
    /// used for every externally visible answer.
    ///
    /// Sorting uses `total_cmp`, so non-finite powers cannot panic here;
    /// they are rejected upstream, at `OpPlan` load time (a NaN rung
    /// would sort as "most accurate" but can never satisfy
    /// `power <= budget`, so it is simply never selected).
    pub fn new(mut ladder: Vec<LadderEntry>, cfg: QosConfig) -> Self {
        assert!(!ladder.is_empty());
        ladder.sort_by(|a, b| b.power.total_cmp(&a.power));
        // start at the most frugal OP until a budget arrives
        let current = ladder.len() - 1;
        QosController {
            ladder,
            cfg,
            current,
            last_switch: None,
            last_cap_saturated: false,
            switches: 0,
            budget_violations: 0,
            cap_saturations: 0,
        }
    }

    /// The internally sorted ladder (power descending).
    pub fn ladder(&self) -> &[LadderEntry] {
        &self.ladder
    }

    /// Position of the current OP in the *sorted* ladder (0 = most
    /// accurate).  Use [`current_table_index`](Self::current_table_index)
    /// when indexing an `OpTable` or a server.
    pub fn current(&self) -> usize {
        self.current
    }

    /// `OpTable` index of the current OP.
    pub fn current_table_index(&self) -> usize {
        self.ladder[self.current].table_index
    }

    /// The current OP's ladder entry.
    pub fn current_entry(&self) -> &LadderEntry {
        &self.ladder[self.current]
    }

    /// Ideal rung for a budget: position (in the sorted ladder) of the
    /// most accurate entry with power <= budget; falls back to the most
    /// frugal one if nothing fits.
    pub fn ideal_for(&self, budget: f64) -> usize {
        self.ladder
            .iter()
            .position(|e| e.power <= budget)
            .unwrap_or(self.ladder.len() - 1)
    }

    /// Feed a budget sample; returns `Some(table_index)` when a switch
    /// fires.  The returned value indexes the original `OpTable` (see
    /// [`LadderEntry::table_index`]), so it can be handed to
    /// `Server::set_operating_point` verbatim.
    pub fn observe(&mut self, budget: f64, now: Instant) -> Option<usize> {
        self.observe_capped(budget, 0, now)
    }

    /// Like [`observe`](Self::observe), but with an accuracy *cap*: the
    /// controller never settles on a rung more accurate than sorted
    /// position `cap` (0 = uncapped), regardless of budget.  This is
    /// the autopilot's latency lever — latency pressure pushes the cap
    /// toward frugal rungs while the *real* power budget keeps flowing
    /// through unchanged, so upgrade-margin hysteresis still works on
    /// genuine budget recovery instead of stalling against a synthetic
    /// capped budget.
    pub fn observe_capped(&mut self, budget: f64, cap: usize, now: Instant) -> Option<usize> {
        self.observe_capped_signal(budget, cap, now).0
    }

    /// [`observe_capped`](Self::observe_capped) that also reports cap
    /// saturation: `true` when the cap pins the controller at the
    /// frugal floor with nothing left to shed — the "wanted to shed
    /// further but couldn't" signal a latency autopilot needs to stop
    /// silently ratcheting a cap that no longer buys anything.  The
    /// rising edge is logged at debug level; [`Self::cap_saturations`]
    /// counts every saturated observation.
    pub fn observe_capped_signal(
        &mut self,
        budget: f64,
        cap: usize,
        now: Instant,
    ) -> (Option<usize>, bool) {
        let floor = self.ladder.len() - 1;
        let cap_eff = cap.min(floor);
        let cur_power = self.ladder[self.current].power;
        if cur_power > budget {
            self.budget_violations += 1;
        }
        let saturated = cap_eff > 0 && cap_eff == floor && self.current == floor;
        if saturated {
            self.cap_saturations += 1;
            if !self.last_cap_saturated {
                crate::obs_log!(
                    Debug,
                    "cap saturated: rung cap {cap} pins the ladder at its frugal floor ({floor})"
                );
            }
        }
        self.last_cap_saturated = saturated;
        let ideal = self.ideal_for(budget).max(cap_eff);
        if ideal == self.current {
            return (None, saturated);
        }
        let upgrading = ideal < self.current; // towards higher accuracy/power
        if upgrading {
            // hysteresis: require headroom and dwell time
            let target_power = self.ladder[ideal].power;
            if target_power > budget * (1.0 - self.cfg.upgrade_margin) {
                return (None, saturated);
            }
            if let Some(t) = self.last_switch {
                if now.duration_since(t) < self.cfg.min_dwell {
                    return (None, saturated);
                }
            }
        }
        // downgrades (over budget) are immediate
        self.current = ideal;
        self.last_switch = Some(now);
        self.switches += 1;
        (Some(self.ladder[ideal].table_index), saturated)
    }

    /// Like [`observe`](Self::observe), but also chooses how the switch
    /// should be applied: downgrades (towards lower power) are urgent
    /// and return [`SwitchMode::Immediate`]; upgrades can afford the
    /// draining barrier and return [`SwitchMode::Drain`].
    pub fn observe_with_mode(&mut self, budget: f64, now: Instant) -> Option<(usize, SwitchMode)> {
        self.observe_with_mode_capped(budget, 0, now)
    }

    /// [`observe_capped`](Self::observe_capped) with the
    /// [`observe_with_mode`](Self::observe_with_mode) switch-mode
    /// policy: capped downgrades are `Immediate`, upgrades `Drain`.
    pub fn observe_with_mode_capped(
        &mut self,
        budget: f64,
        cap: usize,
        now: Instant,
    ) -> Option<(usize, SwitchMode)> {
        self.observe_with_mode_capped_signal(budget, cap, now).0
    }

    /// [`observe_with_mode_capped`](Self::observe_with_mode_capped)
    /// that also reports the cap-saturation signal of
    /// [`observe_capped_signal`](Self::observe_capped_signal).
    pub fn observe_with_mode_capped_signal(
        &mut self,
        budget: f64,
        cap: usize,
        now: Instant,
    ) -> (Option<(usize, SwitchMode)>, bool) {
        let before = self.ladder[self.current].power;
        let (idx, saturated) = self.observe_capped_signal(budget, cap, now);
        let Some(idx) = idx else {
            return (None, saturated);
        };
        let after = self.ladder[self.current].power;
        let mode = if after > before {
            SwitchMode::Drain
        } else {
            SwitchMode::Immediate
        };
        (Some((idx, mode)), saturated)
    }
}

/// Deterministic synthetic budget traces for experiments and the serving
/// example: diurnal-ish sinusoid, step pattern, and random walk.
pub fn budget_trace(kind: &str, steps: usize, seed: u64) -> Vec<f64> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    match kind {
        "sine" => (0..steps)
            .map(|i| {
                let t = i as f64 / steps.max(1) as f64;
                0.75 + 0.25 * (2.0 * std::f64::consts::PI * 3.0 * t).sin()
            })
            .collect(),
        "steps" => (0..steps)
            .map(|i| match (i * 4) / steps.max(1) {
                0 => 1.0,
                1 => 0.7,
                2 => 0.55,
                _ => 0.85,
            })
            .collect(),
        "walk" => {
            let mut v = 0.8;
            (0..steps)
                .map(|_| {
                    v = (v + 0.06 * rng.normal()).clamp(0.4, 1.0);
                    v
                })
                .collect()
        }
        other => panic!("unknown budget trace {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> Vec<LadderEntry> {
        vec![
            LadderEntry { name: "op0".into(), power: 0.85, table_index: 0 },
            LadderEntry { name: "op1".into(), power: 0.69, table_index: 1 },
            LadderEntry { name: "op2".into(), power: 0.57, table_index: 2 },
        ]
    }

    #[test]
    fn picks_most_accurate_within_budget() {
        let c = QosController::new(ladder(), QosConfig::default());
        assert_eq!(c.ideal_for(1.0), 0);
        assert_eq!(c.ideal_for(0.7), 1);
        assert_eq!(c.ideal_for(0.6), 2);
        assert_eq!(c.ideal_for(0.1), 2); // nothing fits -> most frugal
    }

    #[test]
    fn ideal_for_is_deterministic_on_exact_rung_boundaries() {
        // a budget landing exactly on a rung's power selects that rung
        // (power <= budget is inclusive), on every rung of the ladder —
        // the autopilot feeds synthesized budgets equal to rung powers,
        // so boundary ties must never fall through to a cheaper OP
        let c = QosController::new(ladder(), QosConfig::default());
        assert_eq!(c.ideal_for(0.85), 0);
        assert_eq!(c.ideal_for(0.69), 1);
        assert_eq!(c.ideal_for(0.57), 2);
        // and the pick is stable across repeated evaluation
        for _ in 0..10 {
            assert_eq!(c.ideal_for(0.69), 1);
        }
        // one ulp below the boundary falls to the next rung down
        assert_eq!(c.ideal_for(f64::from_bits(0.69f64.to_bits() - 1)), 2);
    }

    #[test]
    fn downgrades_immediately_upgrades_with_dwell() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::from_millis(50),
            },
        );
        let t0 = Instant::now();
        // plenty of budget: upgrade allowed (no prior switch)
        assert_eq!(c.observe(1.0, t0), Some(0));
        // budget collapse: immediate downgrade
        assert_eq!(c.observe(0.58, t0), Some(2));
        // budget back up, but dwell not elapsed
        assert_eq!(c.observe(1.0, t0 + Duration::from_millis(1)), None);
        // after dwell: upgrade
        assert_eq!(c.observe(1.0, t0 + Duration::from_millis(60)), Some(0));
        assert_eq!(c.switches, 3);
    }

    #[test]
    fn margin_blocks_borderline_upgrades() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.10,
                min_dwell: Duration::ZERO,
            },
        );
        let t = Instant::now();
        c.observe(0.6, t); // settle at op2
        // op1 costs 0.69; budget 0.70 fits but not with 10% margin
        assert_eq!(c.observe(0.70, t), None);
        // 0.69/(1-0.1)=0.766...: now it clears the margin
        assert_eq!(c.observe(0.78, t), Some(1));
    }

    #[test]
    fn counts_budget_violations_while_over_budget() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::ZERO,
            },
        );
        let t = Instant::now();
        assert_eq!(c.observe(1.0, t), Some(0)); // op0 (0.85), within budget
        assert_eq!(c.budget_violations, 0);
        // budget collapses below even the cheapest rung: violation is
        // counted and the controller falls to the most frugal OP
        assert_eq!(c.observe(0.5, t), Some(2));
        assert_eq!(c.budget_violations, 1);
        // still over budget at the floor: every sample counts a violation
        assert_eq!(c.observe(0.5, t), None);
        assert_eq!(c.observe(0.5, t), None);
        assert_eq!(c.budget_violations, 3);
        // back within budget: no further violations accrue
        assert_eq!(c.observe(0.6, t), None);
        assert_eq!(c.budget_violations, 3);
    }

    #[test]
    fn min_dwell_blocks_upgrade_until_elapsed_then_allows_it() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::from_millis(100),
            },
        );
        let t0 = Instant::now();
        assert_eq!(c.observe(1.0, t0), Some(0)); // first upgrade: no prior switch
        assert_eq!(c.observe(0.58, t0), Some(2)); // collapse: immediate downgrade
        // ample budget again, but dwell not elapsed: upgrade deferred
        for ms in [1u64, 20, 50, 99] {
            assert_eq!(c.observe(1.0, t0 + Duration::from_millis(ms)), None);
        }
        assert_eq!(c.observe(1.0, t0 + Duration::from_millis(101)), Some(0));
        assert_eq!(c.switches, 3);
    }

    #[test]
    fn observe_capped_never_settles_above_the_cap() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::ZERO,
            },
        );
        let t = Instant::now();
        // ample budget but cap at the middle rung: the controller rises
        // only to position 1, never to the most accurate rung
        assert_eq!(c.observe_capped(1.0, 1, t), Some(1));
        assert_eq!(c.observe_capped(1.0, 1, t), None);
        assert_eq!(c.current(), 1);
        // tightening the cap forces an immediate downgrade even with
        // the budget unchanged
        assert_eq!(
            c.observe_with_mode_capped(1.0, 2, t),
            Some((2, SwitchMode::Immediate))
        );
        // releasing the cap lets the ample budget lift it back up (a
        // draining upgrade, as ever)
        assert_eq!(c.observe_with_mode_capped(1.0, 0, t), Some((0, SwitchMode::Drain)));
        // a cap past the ladder end clamps to the most frugal rung
        assert_eq!(c.observe_capped(1.0, 99, t), Some(2));
    }

    #[test]
    fn cap_at_the_frugal_floor_raises_the_saturation_signal() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::ZERO,
            },
        );
        let t = Instant::now();
        // a mid-ladder cap never saturates, even while it forces a rung
        let (sw, sat) = c.observe_capped_signal(1.0, 1, t);
        assert_eq!(sw, Some(1));
        assert!(!sat);
        // cap at the frugal floor: the first call still gets to shed...
        let (sw, sat) = c.observe_capped_signal(1.0, 2, t);
        assert_eq!(sw, Some(2));
        assert!(!sat, "the shed to the floor is productive, not saturated");
        // ...but once pinned there, every capped observation reports
        // saturation ("wanted to shed further but couldn't")
        for i in 1..=3u64 {
            let (sw, sat) = c.observe_capped_signal(1.0, 2, t);
            assert_eq!(sw, None);
            assert!(sat);
            assert_eq!(c.cap_saturations, i);
        }
        // releasing the cap clears the signal and lets the rung recover
        let (sw, sat) = c.observe_with_mode_capped_signal(1.0, 0, t);
        assert_eq!(sw, Some((0, SwitchMode::Drain)));
        assert!(!sat);
        assert_eq!(c.cap_saturations, 3);
    }

    #[test]
    fn observe_returns_table_indices_for_shuffled_ladder() {
        // the table is NOT power-descending: the controller must answer
        // with table indices, not positions in its internally sorted
        // ladder (the ROADMAP-flagged index fragility)
        let shuffled = vec![
            LadderEntry { name: "mid".into(), power: 0.69, table_index: 0 },
            LadderEntry { name: "accurate".into(), power: 0.85, table_index: 1 },
            LadderEntry { name: "frugal".into(), power: 0.57, table_index: 2 },
        ];
        let mut c = QosController::new(
            shuffled,
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::ZERO,
            },
        );
        let t = Instant::now();
        // most accurate OP lives at table slot 1
        assert_eq!(c.observe(1.0, t), Some(1));
        assert_eq!(c.current_entry().name, "accurate");
        assert_eq!(c.current_table_index(), 1);
        // collapse to the most frugal (table slot 2)
        assert_eq!(c.observe(0.58, t), Some(2));
        // recover to the middle rung (table slot 0)
        assert_eq!(c.observe(0.75, t), Some(0));
        assert_eq!(c.current_entry().name, "mid");
    }

    #[test]
    fn observe_with_mode_drains_upgrades_and_drops_immediately() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::ZERO,
            },
        );
        let t = Instant::now();
        // first move is an upgrade from the frugal floor: drain
        assert_eq!(c.observe_with_mode(1.0, t), Some((0, SwitchMode::Drain)));
        // budget collapse: the downgrade must be immediate
        assert_eq!(c.observe_with_mode(0.58, t), Some((2, SwitchMode::Immediate)));
        // steady budget: no switch, no mode
        assert_eq!(c.observe_with_mode(0.58, t), None);
    }

    #[test]
    fn controller_survives_non_finite_powers() {
        // a NaN rung (rejected at OpPlan load, but hand-built ladders
        // can still carry one) must not panic the sort and must never
        // be selected by a budget
        let mut l = ladder();
        l.push(LadderEntry { name: "broken".into(), power: f64::NAN, table_index: 3 });
        let mut c = QosController::new(
            l,
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::ZERO,
            },
        );
        let t = Instant::now();
        assert_eq!(c.observe(1.0, t), Some(0));
        assert_eq!(c.observe(0.58, t), Some(2));
        assert_ne!(c.current_table_index(), 3);
    }

    #[test]
    fn traces_are_deterministic_and_bounded() {
        for kind in ["sine", "steps", "walk"] {
            let a = budget_trace(kind, 200, 9);
            let b = budget_trace(kind, 200, 9);
            assert_eq!(a, b);
            assert!(a.iter().all(|&v| (0.0..=1.01).contains(&v)), "{kind}");
        }
    }
}
