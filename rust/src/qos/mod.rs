//! QoS controller: runtime operating-point selection (the paper's
//! "gracefully adjusting the platform's Quality of Service").
//!
//! The ladder holds the searched operating points sorted from most
//! accurate (highest power) to most frugal.  The controller receives a
//! time-varying *power budget* (relative multiplication power the
//! platform can currently afford — e.g. from a battery / thermal
//! governor) and picks the most accurate OP that fits, with hysteresis
//! (switch margin + minimum dwell time) so budget noise does not cause
//! oscillation.

pub mod envsim;

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct LadderEntry {
    pub name: String,
    /// MAC-weighted relative multiplication power of this OP.
    pub power: f64,
}

#[derive(Debug, Clone)]
pub struct QosConfig {
    /// Extra headroom a *more expensive* OP must have before we upgrade
    /// (fraction of budget).  Downgrades happen immediately.
    pub upgrade_margin: f64,
    /// Minimum time between switches.
    pub min_dwell: Duration,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            upgrade_margin: 0.05,
            min_dwell: Duration::from_millis(100),
        }
    }
}

#[derive(Debug)]
pub struct QosController {
    ladder: Vec<LadderEntry>, // sorted by power descending (most accurate first)
    cfg: QosConfig,
    current: usize,
    last_switch: Option<Instant>,
    pub switches: u64,
    pub budget_violations: u64,
}

impl QosController {
    /// `ladder` entries are sorted internally by descending power.
    pub fn new(mut ladder: Vec<LadderEntry>, cfg: QosConfig) -> Self {
        assert!(!ladder.is_empty());
        ladder.sort_by(|a, b| b.power.partial_cmp(&a.power).unwrap());
        // start at the most frugal OP until a budget arrives
        let current = ladder.len() - 1;
        QosController {
            ladder,
            cfg,
            current,
            last_switch: None,
            switches: 0,
            budget_violations: 0,
        }
    }

    pub fn ladder(&self) -> &[LadderEntry] {
        &self.ladder
    }

    pub fn current(&self) -> usize {
        self.current
    }

    pub fn current_entry(&self) -> &LadderEntry {
        &self.ladder[self.current]
    }

    /// Ideal OP for a budget: most accurate entry with power <= budget;
    /// falls back to the most frugal one if nothing fits.
    pub fn ideal_for(&self, budget: f64) -> usize {
        self.ladder
            .iter()
            .position(|e| e.power <= budget)
            .unwrap_or(self.ladder.len() - 1)
    }

    /// Feed a budget sample; returns Some(new index) when a switch fires.
    pub fn observe(&mut self, budget: f64, now: Instant) -> Option<usize> {
        let cur_power = self.ladder[self.current].power;
        if cur_power > budget {
            self.budget_violations += 1;
        }
        let ideal = self.ideal_for(budget);
        if ideal == self.current {
            return None;
        }
        let upgrading = ideal < self.current; // towards higher accuracy/power
        if upgrading {
            // hysteresis: require headroom and dwell time
            let target_power = self.ladder[ideal].power;
            if target_power > budget * (1.0 - self.cfg.upgrade_margin) {
                return None;
            }
            if let Some(t) = self.last_switch {
                if now.duration_since(t) < self.cfg.min_dwell {
                    return None;
                }
            }
        }
        // downgrades (over budget) are immediate
        self.current = ideal;
        self.last_switch = Some(now);
        self.switches += 1;
        Some(ideal)
    }
}

/// Deterministic synthetic budget traces for experiments and the serving
/// example: diurnal-ish sinusoid, step pattern, and random walk.
pub fn budget_trace(kind: &str, steps: usize, seed: u64) -> Vec<f64> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    match kind {
        "sine" => (0..steps)
            .map(|i| {
                let t = i as f64 / steps.max(1) as f64;
                0.75 + 0.25 * (2.0 * std::f64::consts::PI * 3.0 * t).sin()
            })
            .collect(),
        "steps" => (0..steps)
            .map(|i| match (i * 4) / steps.max(1) {
                0 => 1.0,
                1 => 0.7,
                2 => 0.55,
                _ => 0.85,
            })
            .collect(),
        "walk" => {
            let mut v = 0.8;
            (0..steps)
                .map(|_| {
                    v = (v + 0.06 * rng.normal()).clamp(0.4, 1.0);
                    v
                })
                .collect()
        }
        other => panic!("unknown budget trace {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> Vec<LadderEntry> {
        vec![
            LadderEntry { name: "op0".into(), power: 0.85 },
            LadderEntry { name: "op1".into(), power: 0.69 },
            LadderEntry { name: "op2".into(), power: 0.57 },
        ]
    }

    #[test]
    fn picks_most_accurate_within_budget() {
        let c = QosController::new(ladder(), QosConfig::default());
        assert_eq!(c.ideal_for(1.0), 0);
        assert_eq!(c.ideal_for(0.7), 1);
        assert_eq!(c.ideal_for(0.6), 2);
        assert_eq!(c.ideal_for(0.1), 2); // nothing fits -> most frugal
    }

    #[test]
    fn downgrades_immediately_upgrades_with_dwell() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::from_millis(50),
            },
        );
        let t0 = Instant::now();
        // plenty of budget: upgrade allowed (no prior switch)
        assert_eq!(c.observe(1.0, t0), Some(0));
        // budget collapse: immediate downgrade
        assert_eq!(c.observe(0.58, t0), Some(2));
        // budget back up, but dwell not elapsed
        assert_eq!(c.observe(1.0, t0 + Duration::from_millis(1)), None);
        // after dwell: upgrade
        assert_eq!(c.observe(1.0, t0 + Duration::from_millis(60)), Some(0));
        assert_eq!(c.switches, 3);
    }

    #[test]
    fn margin_blocks_borderline_upgrades() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.10,
                min_dwell: Duration::ZERO,
            },
        );
        let t = Instant::now();
        c.observe(0.6, t); // settle at op2
        // op1 costs 0.69; budget 0.70 fits but not with 10% margin
        assert_eq!(c.observe(0.70, t), None);
        // 0.69/(1-0.1)=0.766...: now it clears the margin
        assert_eq!(c.observe(0.78, t), Some(1));
    }

    #[test]
    fn counts_budget_violations_while_over_budget() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::ZERO,
            },
        );
        let t = Instant::now();
        assert_eq!(c.observe(1.0, t), Some(0)); // op0 (0.85), within budget
        assert_eq!(c.budget_violations, 0);
        // budget collapses below even the cheapest rung: violation is
        // counted and the controller falls to the most frugal OP
        assert_eq!(c.observe(0.5, t), Some(2));
        assert_eq!(c.budget_violations, 1);
        // still over budget at the floor: every sample counts a violation
        assert_eq!(c.observe(0.5, t), None);
        assert_eq!(c.observe(0.5, t), None);
        assert_eq!(c.budget_violations, 3);
        // back within budget: no further violations accrue
        assert_eq!(c.observe(0.6, t), None);
        assert_eq!(c.budget_violations, 3);
    }

    #[test]
    fn min_dwell_blocks_upgrade_until_elapsed_then_allows_it() {
        let mut c = QosController::new(
            ladder(),
            QosConfig {
                upgrade_margin: 0.0,
                min_dwell: Duration::from_millis(100),
            },
        );
        let t0 = Instant::now();
        assert_eq!(c.observe(1.0, t0), Some(0)); // first upgrade: no prior switch
        assert_eq!(c.observe(0.58, t0), Some(2)); // collapse: immediate downgrade
        // ample budget again, but dwell not elapsed: upgrade deferred
        for ms in [1u64, 20, 50, 99] {
            assert_eq!(c.observe(1.0, t0 + Duration::from_millis(ms)), None);
        }
        assert_eq!(c.observe(1.0, t0 + Duration::from_millis(101)), Some(0));
        assert_eq!(c.switches, 3);
    }

    #[test]
    fn traces_are_deterministic_and_bounded() {
        for kind in ["sine", "steps", "walk"] {
            let a = budget_trace(kind, 200, 9);
            let b = budget_trace(kind, 200, 9);
            assert_eq!(a, b);
            assert!(a.iter().all(|&v| (0.0..=1.01).contains(&v)), "{kind}");
        }
    }
}
