//! Environmental simulator: the "changing environmental conditions" the
//! paper's QoS scaling reacts to, made concrete.
//!
//! Models a battery-powered edge platform:
//!   * battery state-of-charge drained by (base load + inference power),
//!     optionally recharged by a diurnal harvest profile (solar-ish);
//!   * a first-order thermal RC node heated by compute power with
//!     ambient coupling;
//!   * a governor that converts (SoC, temperature) into the relative
//!     multiplication-power *budget* the QosController consumes:
//!     plenty of charge + cool die => budget 1.0; low charge or thermal
//!     throttling => budget shrinks toward the cheapest operating point.
//!
//! Deterministic given the seed/config — used by the serving example and
//! the failure-injection tests.

use crate::util::rng::Rng;

/// Platform parameters for [`EnvSimulator`].
#[derive(Debug, Clone)]
pub struct EnvConfig {
    /// battery capacity in joule-equivalents (arbitrary units)
    pub battery_capacity: f64,
    /// starting state of charge, 0..1
    pub initial_soc: f64,
    /// watts drawn at budget 1.0 by the accelerator (a.u.)
    pub full_power_draw: f64,
    /// constant platform draw independent of inference load (a.u.)
    pub base_draw: f64,
    /// harvest amplitude (0 disables recharging)
    pub harvest_peak: f64,
    /// thermal RC
    pub thermal_r: f64,   // K per watt
    pub thermal_c: f64,   // J per K
    pub ambient: f64,     // deg C
    pub throttle_start: f64, // deg C where the governor starts cutting
    pub throttle_full: f64,  // deg C where only the cheapest OP fits
    /// SoC below which the governor degrades linearly
    pub soc_knee: f64,
    /// PRNG seed for the harvest noise (trajectories are reproducible)
    pub seed: u64,
}

impl Default for EnvConfig {
    fn default() -> Self {
        EnvConfig {
            battery_capacity: 2000.0,
            initial_soc: 0.9,
            full_power_draw: 10.0,
            base_draw: 1.0,
            harvest_peak: 4.0,
            thermal_r: 4.0,
            thermal_c: 20.0,
            ambient: 25.0,
            throttle_start: 70.0,
            throttle_full: 95.0,
            soc_knee: 0.5,
            seed: 0,
        }
    }
}

/// Instantaneous platform state, readable after every `step`.
#[derive(Debug, Clone, Copy)]
pub struct EnvState {
    /// simulated time, seconds
    pub t: f64,
    /// battery state of charge, 0..1
    pub soc: f64,
    /// die temperature, deg C
    pub temperature: f64,
    /// power budget the governor currently grants, 0.05..1
    pub budget: f64,
}

/// A scripted disturbance injected into a running [`EnvSimulator`] via
/// [`EnvSimulator::apply`] — how bench scenarios stress the governor at
/// a chosen instant instead of waiting for the physics to get there
/// (a battery brown-out, a hot spell, clouds over the solar panel).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EnvEvent {
    /// Instantly remove `delta` (0..1) state-of-charge.
    BatteryDrop { delta: f64 },
    /// Instantly heat the die by `delta_c` degrees C.
    ThermalSpike { delta_c: f64 },
    /// Scale the harvest amplitude by `factor` from now on (0 = the
    /// panel goes dark, 2 = double insolation).
    HarvestScale { factor: f64 },
    /// A grid tariff window: for the next `secs` simulated seconds the
    /// governor multiplies its budget by `scale` (0..1) — peak-price
    /// hours where the operator caps draw by policy, not physics.  A
    /// new window replaces any window still in force.
    TariffWindow { scale: f64, secs: f64 },
}

/// Battery + thermal + governor model; see the module docs.
pub struct EnvSimulator {
    cfg: EnvConfig,
    state: EnvState,
    rng: Rng,
    /// active tariff window, as (budget scale, simulated end time)
    tariff: Option<(f64, f64)>,
}

impl EnvSimulator {
    /// A fresh platform at `cfg.initial_soc` charge and ambient temp.
    pub fn new(cfg: EnvConfig) -> Self {
        let state = EnvState {
            t: 0.0,
            soc: cfg.initial_soc,
            temperature: cfg.ambient,
            budget: 1.0,
        };
        let rng = Rng::new(cfg.seed);
        EnvSimulator { cfg, state, rng, tariff: None }
    }

    /// The current platform state.
    pub fn state(&self) -> EnvState {
        self.state
    }

    /// Inject a scripted disturbance; the next [`step`](Self::step)
    /// integrates from the perturbed state (the budget is not
    /// recomputed here — the governor only runs inside `step`, exactly
    /// as it would for a real sensor reading).
    pub fn apply(&mut self, event: EnvEvent) {
        match event {
            EnvEvent::BatteryDrop { delta } => {
                self.state.soc = (self.state.soc - delta).clamp(0.0, 1.0);
            }
            EnvEvent::ThermalSpike { delta_c } => {
                self.state.temperature += delta_c;
            }
            EnvEvent::HarvestScale { factor } => {
                self.cfg.harvest_peak *= factor.max(0.0);
            }
            EnvEvent::TariffWindow { scale, secs } => {
                self.tariff = Some((scale.clamp(0.0, 1.0), self.state.t + secs.max(0.0)));
            }
        }
    }

    /// Whether a tariff window is currently capping the budget.
    pub fn tariff_active(&self) -> bool {
        self.tariff.is_some_and(|(_, until)| self.state.t < until)
    }

    /// Harvest power at time t: half-sine "daylight" with noise.
    fn harvest(&mut self, t: f64) -> f64 {
        let day = (2.0 * std::f64::consts::PI * t / 600.0).sin().max(0.0);
        (self.cfg.harvest_peak * day * (1.0 + 0.1 * self.rng.normal())).max(0.0)
    }

    /// Advance by dt seconds while the platform runs at `power_frac` of
    /// full accelerator power (i.e. the mean relative multiplication
    /// power actually served). Returns the new budget.
    pub fn step(&mut self, dt: f64, power_frac: f64) -> f64 {
        let c = self.cfg.clone();
        let draw = c.base_draw + c.full_power_draw * power_frac.clamp(0.0, 1.0);
        let harvest = self.harvest(self.state.t);
        let net = harvest - draw;
        self.state.soc = (self.state.soc + net * dt / c.battery_capacity).clamp(0.0, 1.0);

        // first-order thermal node: C dT/dt = P - (T - Ta)/R
        let p_heat = draw;
        let dtemp = (p_heat - (self.state.temperature - c.ambient) / c.thermal_r) / c.thermal_c;
        self.state.temperature += dtemp * dt;

        // governor
        let soc_factor = if self.state.soc >= c.soc_knee {
            1.0
        } else {
            (self.state.soc / c.soc_knee).max(0.0)
        };
        let thermal_factor = if self.state.temperature <= c.throttle_start {
            1.0
        } else if self.state.temperature >= c.throttle_full {
            0.0
        } else {
            1.0 - (self.state.temperature - c.throttle_start) / (c.throttle_full - c.throttle_start)
        };
        // tariff windows cap the budget by policy on top of the physics
        let tariff_factor = match self.tariff {
            Some((scale, until)) if self.state.t < until => scale,
            Some(_) => {
                self.tariff = None; // expired window
                1.0
            }
            None => 1.0,
        };
        // budget floor > 0: the cheapest OP must always be schedulable
        self.state.budget = (soc_factor * thermal_factor * tariff_factor).max(0.05);
        self.state.t += dt;
        self.state.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_battery_cool_die_gives_full_budget() {
        let mut sim = EnvSimulator::new(EnvConfig {
            harvest_peak: 0.0,
            ..Default::default()
        });
        let b = sim.step(0.1, 0.5);
        assert!(b > 0.95, "budget {b}");
    }

    #[test]
    fn sustained_load_drains_battery_and_cuts_budget() {
        let mut sim = EnvSimulator::new(EnvConfig {
            battery_capacity: 100.0,
            harvest_peak: 0.0,
            initial_soc: 0.6,
            ..Default::default()
        });
        let mut budget = 1.0;
        for _ in 0..2000 {
            budget = sim.step(0.1, 1.0);
        }
        assert!(sim.state().soc < 0.3);
        assert!(budget < 0.6, "budget should degrade, got {budget}");
        assert!(budget >= 0.05, "budget floor");
    }

    #[test]
    fn thermal_throttling_engages_under_heavy_load() {
        let mut sim = EnvSimulator::new(EnvConfig {
            battery_capacity: 1e9, // battery not the limit
            full_power_draw: 30.0, // hot accelerator
            harvest_peak: 0.0,
            thermal_r: 3.0,
            thermal_c: 5.0,
            ..Default::default()
        });
        for _ in 0..5000 {
            sim.step(0.1, 1.0);
        }
        assert!(sim.state().temperature > 70.0, "temp {}", sim.state().temperature);
        assert!(sim.state().budget < 1.0);
    }

    #[test]
    fn idle_platform_cools_back_down() {
        let mut sim = EnvSimulator::new(EnvConfig {
            battery_capacity: 1e9,
            full_power_draw: 30.0,
            harvest_peak: 0.0,
            thermal_r: 3.0,
            thermal_c: 5.0,
            ..Default::default()
        });
        for _ in 0..5000 {
            sim.step(0.1, 1.0);
        }
        let hot = sim.state().temperature;
        for _ in 0..10000 {
            sim.step(0.1, 0.0);
        }
        assert!(sim.state().temperature < hot - 10.0);
    }

    #[test]
    fn scripted_events_perturb_the_next_step() {
        let cfg = EnvConfig { harvest_peak: 0.0, ..Default::default() };
        let mut sim = EnvSimulator::new(cfg.clone());
        sim.step(0.1, 0.5);
        let before = sim.state();

        // a brown-out below the knee must cut the budget on the very
        // next governor pass
        sim.apply(EnvEvent::BatteryDrop { delta: before.soc - 0.1 });
        let b = sim.step(0.1, 0.5);
        assert!(sim.state().soc < 0.15);
        assert!(b < 0.5, "budget {b} should reflect the brown-out");

        // a thermal spike past throttle_full pins the budget at the floor
        let mut sim = EnvSimulator::new(cfg.clone());
        sim.apply(EnvEvent::ThermalSpike { delta_c: 100.0 });
        let b = sim.step(0.1, 0.0);
        assert!((b - 0.05).abs() < 1e-9, "budget {b} should hit the floor");

        // killing the harvest makes the SoC trajectory strictly worse
        let trajectory = |scale: Option<f64>| {
            let mut sim =
                EnvSimulator::new(EnvConfig { harvest_peak: 4.0, ..Default::default() });
            if let Some(factor) = scale {
                sim.apply(EnvEvent::HarvestScale { factor });
            }
            for _ in 0..500 {
                sim.step(1.0, 0.0);
            }
            sim.state().soc
        };
        assert!(trajectory(Some(0.0)) < trajectory(None));
    }

    #[test]
    fn tariff_window_caps_budget_then_expires() {
        let mut sim = EnvSimulator::new(EnvConfig {
            harvest_peak: 0.0,
            battery_capacity: 1e9,
            ..Default::default()
        });
        let full = sim.step(0.1, 0.0);
        assert!(full > 0.95, "baseline budget {full}");

        sim.apply(EnvEvent::TariffWindow { scale: 0.5, secs: 1.0 });
        assert!(sim.tariff_active());
        let capped = sim.step(0.1, 0.0);
        assert!((capped - 0.5 * full).abs() < 0.05, "capped budget {capped}");

        // ten more 0.1 s steps walk past the 1 s window end
        let mut last = capped;
        for _ in 0..10 {
            last = sim.step(0.1, 0.0);
        }
        assert!(!sim.tariff_active());
        assert!(last > 0.95, "budget {last} should recover after the window");
    }

    #[test]
    fn tariff_window_respects_budget_floor() {
        let mut sim = EnvSimulator::new(EnvConfig {
            harvest_peak: 0.0,
            ..Default::default()
        });
        sim.apply(EnvEvent::TariffWindow { scale: 0.0, secs: 100.0 });
        let b = sim.step(0.1, 0.0);
        assert!((b - 0.05).abs() < 1e-9, "budget {b} should sit on the floor");
    }

    #[test]
    fn new_tariff_window_replaces_the_old_one() {
        let mut sim = EnvSimulator::new(EnvConfig {
            harvest_peak: 0.0,
            battery_capacity: 1e9,
            ..Default::default()
        });
        sim.apply(EnvEvent::TariffWindow { scale: 0.2, secs: 1000.0 });
        sim.apply(EnvEvent::TariffWindow { scale: 0.8, secs: 0.5 });
        let b = sim.step(0.1, 0.0);
        assert!((b - 0.8).abs() < 0.05, "budget {b} should follow the newer window");
        for _ in 0..10 {
            sim.step(0.1, 0.0);
        }
        // the long 0.2 window is gone — replaced, not stacked
        assert!(sim.state().budget > 0.95, "budget {}", sim.state().budget);
    }

    #[test]
    fn deterministic_given_seed() {
        // harvest noise differs per seed -> SoC trajectories differ, but
        // the same seed reproduces them exactly
        let run = |seed| {
            let mut sim = EnvSimulator::new(EnvConfig { seed, ..Default::default() });
            (0..100)
                .map(|_| {
                    sim.step(1.0, 0.7);
                    sim.state().soc
                })
                .collect::<Vec<f64>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }
}
