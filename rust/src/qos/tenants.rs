//! Tenant classes: first-class multi-tenancy over one shared ladder.
//!
//! QoS-Nets' shared-subset design means one deployment holds several
//! operating points over the same resident parameters — which maps
//! directly onto several *tenants* sharing one serving stack, each
//! steered along its own rung ladder.  A [`TenantClass`] names one such
//! tenant: a strict scheduling priority (0 = premium, sheds last), a
//! per-class p95 SLO, and an admission share that decides who gets
//! rejected first under overload.  A [`ClassSet`] is the validated
//! registry the rest of the stack carries: class ids are positions in
//! the set (premium-first), and every layer — batcher queues, per-class
//! `(op, mode)` words, autopilot pilots, fleet drain barriers, metric
//! labels — indexes by that id.
//!
//! Class sets load from a `tenants.json` file
//! ([`ClassSet::from_json_file`], `{"tenants": [{...}]}` with the same
//! per-class keys as the bench scenario schema) or from repeated
//! `--tenant name:slo_ms:share` flags ([`ClassSet::from_flags`]).  A
//! deployment that configures neither runs the [`ClassSet::single`]
//! default — one class, full share — which keeps every single-tenant
//! code path byte-identical to the pre-tenancy stack.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One tenant class.  See the module docs for how ids are assigned.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantClass {
    pub name: String,
    /// Strict scheduling priority: 0 = premium.  Lower values are
    /// admitted first, drained first, and shed *last*.
    pub priority: u32,
    /// Per-class p95 latency SLO, ms (`None` = no per-class SLO; the
    /// class rides the deployment-wide objective).
    pub slo_p95_ms: Option<f64>,
    /// Admission weight against the other classes under overload.
    pub share: f64,
}

/// A validated, premium-first ordered set of tenant classes.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassSet {
    classes: Vec<TenantClass>,
}

impl ClassSet {
    /// The single-tenant default: one class holding the whole share.
    pub fn single() -> ClassSet {
        ClassSet {
            classes: vec![TenantClass {
                name: "default".to_string(),
                priority: 0,
                slo_p95_ms: None,
                share: 1.0,
            }],
        }
    }

    /// Build a set from explicit classes; sorts premium-first (stable,
    /// so equal priorities keep their given order) and validates.
    pub fn new(mut classes: Vec<TenantClass>) -> Result<ClassSet> {
        if classes.is_empty() {
            bail!("tenant class set: no classes");
        }
        classes.sort_by_key(|c| c.priority);
        for (i, c) in classes.iter().enumerate() {
            if c.name.is_empty() {
                bail!("tenant class {i}: empty name");
            }
            if classes[..i].iter().any(|o| o.name == c.name) {
                bail!("tenant class {i}: duplicate name {:?}", c.name);
            }
            if !(c.share.is_finite() && c.share > 0.0) {
                bail!("tenant class {:?}: share must be finite and > 0", c.name);
            }
            if let Some(slo) = c.slo_p95_ms {
                if !(slo.is_finite() && slo > 0.0) {
                    bail!("tenant class {:?}: slo_p95_ms must be finite and > 0", c.name);
                }
            }
        }
        Ok(ClassSet { classes })
    }

    /// Parse repeated `--tenant name:slo_ms:share` flags.  The empty
    /// list yields [`ClassSet::single`].
    pub fn from_flags(flags: &[String]) -> Result<ClassSet> {
        if flags.is_empty() {
            return Ok(ClassSet::single());
        }
        let classes = flags
            .iter()
            .enumerate()
            .map(|(i, flag)| {
                let parts: Vec<&str> = flag.split(':').collect();
                if parts.len() != 3 {
                    bail!("--tenant {flag:?}: expected name:slo_ms:share");
                }
                let slo: f64 = parts[1]
                    .parse()
                    .with_context(|| format!("--tenant {flag:?}: bad slo_ms"))?;
                let share: f64 = parts[2]
                    .parse()
                    .with_context(|| format!("--tenant {flag:?}: bad share"))?;
                Ok(TenantClass {
                    name: parts[0].to_string(),
                    // flag order is priority order: first flag = premium
                    priority: i as u32,
                    slo_p95_ms: Some(slo),
                    share,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        ClassSet::new(classes)
    }

    /// Parse a `tenants.json` value: `{"tenants": [{"name": ...,
    /// "priority": ..., "slo_p95_ms": ..., "share": ...}, ...]}`.
    pub fn from_json(v: &Json) -> Result<ClassSet> {
        let arr = v
            .get("tenants")
            .and_then(|x| x.as_arr())
            .context("tenants.json: missing tenants array")?;
        let classes = arr
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let name = t
                    .get("name")
                    .and_then(|x| x.as_str())
                    .with_context(|| format!("tenants.json: tenant {i}: missing name"))?
                    .to_string();
                Ok(TenantClass {
                    name,
                    priority: t.get("priority").and_then(|x| x.as_usize()).unwrap_or(i) as u32,
                    slo_p95_ms: t.get("slo_p95_ms").and_then(|x| x.as_f64()),
                    share: t.get("share").and_then(|x| x.as_f64()).unwrap_or(1.0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        ClassSet::new(classes)
    }

    /// Load [`ClassSet::from_json`] from a file path.
    pub fn from_json_file(path: &std::path::Path) -> Result<ClassSet> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read tenants file {}", path.display()))?;
        let v = crate::util::json::parse(&text).map_err(anyhow::Error::msg)?;
        ClassSet::from_json(&v)
    }

    pub fn len(&self) -> usize {
        self.classes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.classes.is_empty()
    }

    /// More than one class configured.
    pub fn is_multi(&self) -> bool {
        self.classes.len() > 1
    }

    pub fn get(&self, id: usize) -> &TenantClass {
        &self.classes[id]
    }

    pub fn iter(&self) -> impl Iterator<Item = &TenantClass> {
        self.classes.iter()
    }

    /// Class id for a name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.classes.iter().position(|c| c.name == name)
    }

    /// Class names in id order (metric label values).
    pub fn names(&self) -> Vec<String> {
        self.classes.iter().map(|c| c.name.clone()).collect()
    }

    /// Fraction of the admission capacity class `id` may fill before
    /// it is rejected: strictly-higher-priority classes' shares are
    /// reserved out of its reach, so under moderate overload the
    /// best-effort classes hit their fraction (and start bouncing)
    /// while premium still admits.  The highest-priority class always
    /// gets 1.0 — premium is only rejected when the deployment is
    /// hard-full.
    pub fn admit_frac(&self, id: usize) -> f64 {
        let total: f64 = self.classes.iter().map(|c| c.share).sum();
        let higher: f64 = self
            .classes
            .iter()
            .filter(|c| c.priority < self.classes[id].priority)
            .map(|c| c.share)
            .sum();
        if total <= 0.0 {
            return 1.0;
        }
        ((total - higher) / total).clamp(0.0, 1.0)
    }

    /// Admission fractions for every class, in id order (the shape
    /// `server::BatcherConfig` carries).
    pub fn admit_fracs(&self) -> Vec<f64> {
        (0..self.classes.len()).map(|i| self.admit_frac(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn two_classes() -> ClassSet {
        ClassSet::new(vec![
            TenantClass {
                name: "premium".into(),
                priority: 0,
                slo_p95_ms: Some(100.0),
                share: 3.0,
            },
            TenantClass {
                name: "best_effort".into(),
                priority: 1,
                slo_p95_ms: Some(250.0),
                share: 1.0,
            },
        ])
        .unwrap()
    }

    #[test]
    fn single_default_spans_the_whole_share() {
        let s = ClassSet::single();
        assert_eq!(s.len(), 1);
        assert!(!s.is_multi());
        assert_eq!(s.admit_frac(0), 1.0);
        assert_eq!(s.get(0).priority, 0);
    }

    #[test]
    fn class_ids_are_premium_first_and_admission_reserves_premium_share() {
        let s = ClassSet::new(vec![
            TenantClass { name: "be".into(), priority: 5, slo_p95_ms: None, share: 1.0 },
            TenantClass { name: "prem".into(), priority: 0, slo_p95_ms: None, share: 3.0 },
        ])
        .unwrap();
        // sorted premium-first regardless of the input order
        assert_eq!(s.get(0).name, "prem");
        assert_eq!(s.index_of("be"), Some(1));
        // premium always admits; best-effort only up to its slice
        assert_eq!(s.admit_frac(0), 1.0);
        assert!((s.admit_frac(1) - 0.25).abs() < 1e-12);
        assert_eq!(s.admit_fracs(), vec![1.0, 0.25]);
    }

    #[test]
    fn flags_parse_in_priority_order_and_reject_malformed_specs() {
        let s = ClassSet::from_flags(&[
            "premium:100:3".to_string(),
            "best_effort:250:1".to_string(),
        ])
        .unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(0).name, "premium");
        assert_eq!(s.get(0).slo_p95_ms, Some(100.0));
        assert_eq!(s.get(1).share, 1.0);
        // no flags = the single-tenant default
        assert_eq!(ClassSet::from_flags(&[]).unwrap(), ClassSet::single());
        // malformed specs name the offending flag
        assert!(ClassSet::from_flags(&["premium:100".to_string()]).is_err());
        assert!(ClassSet::from_flags(&["premium:abc:1".to_string()]).is_err());
        assert!(ClassSet::from_flags(&["premium:100:0".to_string()]).is_err());
    }

    #[test]
    fn json_round_trip_and_validation() {
        let text = r#"{"tenants":[
            {"name":"premium","priority":0,"slo_p95_ms":100,"share":3},
            {"name":"best_effort","priority":1,"slo_p95_ms":250,"share":1}
        ]}"#;
        let s = ClassSet::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(s, two_classes());

        // duplicate names are rejected
        let dup = r#"{"tenants":[{"name":"a","share":1},{"name":"a","share":1}]}"#;
        assert!(ClassSet::from_json(&json::parse(dup).unwrap()).is_err());
        // an empty set is rejected
        let empty = r#"{"tenants":[]}"#;
        assert!(ClassSet::from_json(&json::parse(empty).unwrap()).is_err());
    }
}
