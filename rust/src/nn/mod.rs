//! Model description + parameter loading (the Rust view of graph.json,
//! params.qten and layer_stats.json exported by the Python build path).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};
use crate::util::tensorio::{self, Tensor};

pub const BN_EPS: f32 = 1e-5;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    Relu6,
}

impl Activation {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => Activation::None,
            "relu" => Activation::Relu,
            "relu6" => Activation::Relu6,
            other => bail!("unknown activation {other}"),
        })
    }

    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::None => x,
            Activation::Relu => x.max(0.0),
            Activation::Relu6 => x.clamp(0.0, 6.0),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum NodeKind {
    Input,
    Conv,
    Dense,
    Add,
    Gap,
    Output,
}

#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub kind: NodeKind,
    pub inputs: Vec<usize>,
    pub name: String,
    pub out_shape: Vec<usize>, // HWC for spatial, [C] for vectors
    pub act: Activation,
    // conv / dense attrs
    pub cin: usize,
    pub cout: usize,
    pub ksize: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
    pub has_bn: bool,
    pub macs_per_out: usize,
    pub macs_total: usize,
    pub quant_in: Option<QParams>,
    pub quant_w: Option<QParams>,
}

#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub nodes: Vec<Node>,
    pub total_macs: usize,
}

impl Graph {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let raw = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        let v = json::parse(&raw).map_err(anyhow::Error::msg)?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let name = v.get("name").and_then(|x| x.as_str()).unwrap_or("model").to_string();
        let input_shape: Vec<usize> = v
            .req("input_shape")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("input_shape")?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect();
        let mut nodes = Vec::new();
        for n in v.req("nodes").map_err(anyhow::Error::msg)?.as_arr().unwrap_or(&[]) {
            let kind = match n.get("kind").and_then(|x| x.as_str()).unwrap_or("") {
                "input" => NodeKind::Input,
                "conv" => NodeKind::Conv,
                "dense" => NodeKind::Dense,
                "add" => NodeKind::Add,
                "gap" => NodeKind::Gap,
                "output" => NodeKind::Output,
                other => bail!("unknown node kind {other}"),
            };
            let get_usize = |k: &str| n.get(k).and_then(|x| x.as_usize()).unwrap_or(0);
            let quant = n.get("quant");
            let parse_qp = |which: &str| -> Option<QParams> {
                quant.and_then(|q| q.get(which)).map(|q| QParams {
                    scale: q.get("scale").and_then(|x| x.as_f64()).unwrap_or(1.0) as f32,
                    zero_point: q.get("zero_point").and_then(|x| x.as_i64()).unwrap_or(0) as i32,
                })
            };
            nodes.push(Node {
                id: get_usize("id"),
                kind,
                inputs: n
                    .get("inputs")
                    .and_then(|x| x.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                name: n.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                out_shape: n
                    .get("out_shape")
                    .and_then(|x| x.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                act: Activation::parse(n.get("act").and_then(|x| x.as_str()).unwrap_or("none"))?,
                cin: get_usize("cin"),
                cout: get_usize("cout"),
                ksize: get_usize("ksize"),
                stride: get_usize("stride").max(1),
                pad: get_usize("pad"),
                groups: get_usize("groups").max(1),
                has_bn: n.get("has_bn").and_then(|x| x.as_bool()).unwrap_or(false),
                macs_per_out: get_usize("macs_per_out"),
                macs_total: get_usize("macs_total"),
                quant_in: parse_qp("in"),
                quant_w: parse_qp("w"),
            });
        }
        let total_macs = v.get("total_macs").and_then(|x| x.as_usize()).unwrap_or(0);
        Ok(Graph {
            name,
            input_shape,
            nodes,
            total_macs,
        })
    }

    /// The l approximable layers, in graph order.
    pub fn approx_layers(&self) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Conv | NodeKind::Dense))
            .collect()
    }

    pub fn layer_index(&self) -> HashMap<String, usize> {
        self.approx_layers()
            .iter()
            .enumerate()
            .map(|(i, n)| (n.name.clone(), i))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Parameters
// ---------------------------------------------------------------------------

/// Per-layer parameters in deployment form.
#[derive(Debug, Clone)]
pub struct LayerParams {
    /// Weight codes (u8 stored widened to i32 for the LUT hot loop),
    /// conv: [kh, kw, cin/groups, cout] flattened; dense: [cin, cout].
    pub w_codes: Vec<i32>,
    pub w_shape: Vec<usize>,
    /// Per-channel fused output transform:
    /// `out_f = post_scale[c] * acc_corrected + post_bias[c]`
    pub post_scale: Vec<f32>,
    pub post_bias: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct ModelParams {
    pub layers: HashMap<String, LayerParams>,
}

impl ModelParams {
    /// Build deployment parameters from a params.qten (+ optional BN
    /// overlay replacing gamma/beta/b — the per-operating-point tensors).
    pub fn load(
        graph: &Graph,
        params_path: impl AsRef<Path>,
        overlay_path: Option<&Path>,
    ) -> Result<Self> {
        let tensors = tensorio::load(params_path)?;
        let overlay = match overlay_path {
            Some(p) => tensorio::load(p)?,
            None => HashMap::new(),
        };
        Self::from_tensors(graph, &tensors, &overlay)
    }

    pub fn from_tensors(
        graph: &Graph,
        tensors: &HashMap<String, Tensor>,
        overlay: &HashMap<String, Tensor>,
    ) -> Result<Self> {
        let mut layers = HashMap::new();
        for node in graph.approx_layers() {
            let name = &node.name;
            let get = |suffix: &str| -> Option<&Tensor> {
                overlay
                    .get(&format!("{name}.{suffix}"))
                    .or_else(|| tensors.get(&format!("{name}.{suffix}")))
            };
            let w = get("w").with_context(|| format!("{name}: missing weights"))?;
            let wq = node.quant_w.with_context(|| format!("{name}: missing weight qparams"))?;
            let w_f = w.as_f32()?;
            let w_codes: Vec<i32> = w_f
                .iter()
                .map(|&x| ((x / wq.scale).round() as i32 + wq.zero_point).clamp(0, 255))
                .collect();

            // fused output transform: dequant * BN (eval stats) + bias
            let sa = node.quant_in.with_context(|| format!("{name}: missing act qparams"))?;
            let deq = sa.scale * wq.scale;
            let (post_scale, post_bias) = if node.has_bn {
                let gamma = get("gamma").context("gamma")?.as_f32()?.to_vec();
                let beta = get("beta").context("beta")?.as_f32()?.to_vec();
                let mean = tensors
                    .get(&format!("{name}.mean"))
                    .context("mean")?
                    .as_f32()?
                    .to_vec();
                let var = tensors
                    .get(&format!("{name}.var"))
                    .context("var")?
                    .as_f32()?
                    .to_vec();
                let mut ps = vec![0.0f32; node.cout];
                let mut pb = vec![0.0f32; node.cout];
                for c in 0..node.cout {
                    let inv = gamma[c] / (var[c] + BN_EPS).sqrt();
                    ps[c] = deq * inv;
                    pb[c] = beta[c] - mean[c] * inv;
                }
                (ps, pb)
            } else {
                let b = get("b").context("bias")?.as_f32()?.to_vec();
                (vec![deq; node.cout], b)
            };

            layers.insert(
                name.clone(),
                LayerParams {
                    w_codes,
                    w_shape: w.shape().to_vec(),
                    post_scale,
                    post_bias,
                },
            );
        }
        Ok(ModelParams { layers })
    }
}

// ---------------------------------------------------------------------------
// Layer statistics (error-model inputs)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct LayerStats {
    pub name: String,
    pub act_hist: Vec<f64>, // 256 probabilities
    pub w_hist: Vec<f64>,   // 256 probabilities
    pub k_fanin: usize,
    pub macs_total: usize,
    pub s_act: f64,
    pub z_act: i32,
    pub s_w: f64,
    pub z_w: i32,
    pub bn_scale: f64,
    pub out_rms: f64,
}

pub fn load_layer_stats(path: impl AsRef<Path>, order: &[String]) -> Result<Vec<LayerStats>> {
    let raw = std::fs::read_to_string(path.as_ref())?;
    let v = json::parse(&raw).map_err(anyhow::Error::msg)?;
    let mut out = Vec::new();
    for name in order {
        let s = v.req(name).map_err(anyhow::Error::msg)?;
        out.push(LayerStats {
            name: name.clone(),
            act_hist: s.req("act_hist").map_err(anyhow::Error::msg)?.f64_vec().context("act_hist")?,
            w_hist: s.req("w_hist").map_err(anyhow::Error::msg)?.f64_vec().context("w_hist")?,
            k_fanin: s.get("k_fanin").and_then(|x| x.as_usize()).context("k_fanin")?,
            macs_total: s.get("macs_total").and_then(|x| x.as_usize()).context("macs_total")?,
            s_act: s.get("s_act").and_then(|x| x.as_f64()).context("s_act")?,
            z_act: s.get("z_act").and_then(|x| x.as_i64()).unwrap_or(0) as i32,
            s_w: s.get("s_w").and_then(|x| x.as_f64()).context("s_w")?,
            z_w: s.get("z_w").and_then(|x| x.as_i64()).unwrap_or(0) as i32,
            bn_scale: s.get("bn_scale").and_then(|x| x.as_f64()).unwrap_or(1.0),
            out_rms: s.get("out_rms").and_then(|x| x.as_f64()).unwrap_or(1.0),
        });
    }
    Ok(out)
}

/// sigma_g vector from sensitivity.json, ordered like `order`.
pub fn load_sensitivity(path: impl AsRef<Path>) -> Result<(Vec<String>, Vec<f64>)> {
    let raw = std::fs::read_to_string(path.as_ref())?;
    let v = json::parse(&raw).map_err(anyhow::Error::msg)?;
    let layers: Vec<String> = v
        .req("layers")
        .map_err(anyhow::Error::msg)?
        .as_arr()
        .context("layers")?
        .iter()
        .map(|x| x.as_str().unwrap_or("").to_string())
        .collect();
    let sigma = v.req("sigma_g").map_err(anyhow::Error::msg)?.f64_vec().context("sigma_g")?;
    if layers.len() != sigma.len() {
        bail!("sensitivity.json: layers/sigma length mismatch");
    }
    Ok((layers, sigma))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activation_semantics() {
        assert_eq!(Activation::Relu.apply(-1.0), 0.0);
        assert_eq!(Activation::Relu6.apply(9.0), 6.0);
        assert_eq!(Activation::None.apply(-3.5), -3.5);
    }

    #[test]
    fn graph_from_json_minimal() {
        let src = r#"{
          "name": "tiny", "input_shape": [4,4,3], "total_macs": 432,
          "nodes": [
            {"id":0,"kind":"input","inputs":[],"name":"input","out_shape":[4,4,3]},
            {"id":1,"kind":"conv","inputs":[0],"name":"c1","out_shape":[4,4,8],
             "cin":3,"cout":8,"ksize":3,"stride":1,"pad":1,"groups":1,
             "has_bn":true,"act":"relu","macs_per_out":27,"macs_total":432,
             "quant":{"in":{"scale":0.01,"zero_point":128},"w":{"scale":0.005,"zero_point":120}}},
            {"id":2,"kind":"output","inputs":[1],"name":"output","out_shape":[4,4,8]}
          ]}"#;
        let g = Graph::from_json(&json::parse(src).unwrap()).unwrap();
        assert_eq!(g.approx_layers().len(), 1);
        let c = &g.approx_layers()[0];
        assert_eq!(c.quant_in.unwrap().zero_point, 128);
        assert_eq!(c.act, Activation::Relu);
    }
}
