//! Open-loop arrival traces: the scenario's arrival schedule expanded
//! into a concrete, fully materialized list of (time, image-count,
//! image-index) events *before* the run starts.
//!
//! Materializing up front is what makes runs replayable: the trace is a
//! pure function of (scenario, seed, duration), its FNV-1a hash goes
//! into the report's provenance block, and the same seed reproduces the
//! same byte-identical trace on any machine — the load generator never
//! consults the clock to decide *what* to send, only *when*.

use crate::bench::scenario::{ArrivalProcess, Scenario};
use crate::util::hash::fnv1a_words;
use crate::util::rng::Rng;

/// One arrival event: at `at_us` microseconds into the run, submit
/// `count` copies drawn from pool image `image`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    pub at_us: u64,
    pub count: u32,
    pub image: u32,
}

/// Expand the scenario's arrival phases into a trace covering
/// `duration_s` seconds (phases cycle if the duration outlives the
/// schedule).  `image_pool` is the number of distinct images the
/// deployment can serve; indices are sampled uniformly from it.
///
/// Determinism contract: identical `(scenario arrivals/batch_mix,
/// seed, duration_s, image_pool)` inputs yield an identical trace.
/// Three decorrelated PRNG streams are forked from the seed so adding
/// a mix entry cannot perturb the arrival *times*.
pub fn generate(sc: &Scenario, duration_s: f64, seed: u64, image_pool: u32) -> Vec<Arrival> {
    let mut root = Rng::new(seed);
    let mut gaps = root.fork(1);
    let mut mix = root.fork(2);
    let mut imgs = root.fork(3);

    let horizon_us = (duration_s * 1e6) as u64;
    let mut out = Vec::new();
    let mut t_us = 0u64;
    let mut phase = 0usize;
    let mut phase_end_us = (sc.arrivals[0].dur_s * 1e6) as u64;
    while t_us < horizon_us {
        let p = sc.arrivals[phase % sc.arrivals.len()];
        let events = match p.process {
            ArrivalProcess::Burst { size } => size,
            _ => 1,
        };
        for _ in 0..events {
            out.push(Arrival {
                at_us: t_us,
                count: sample_mix(sc, &mut mix),
                image: imgs.below(image_pool as usize) as u32,
            });
        }
        let gap_s = match p.process {
            ArrivalProcess::Poisson => gaps.exp(p.rate_rps),
            ArrivalProcess::Uniform => 1.0 / p.rate_rps,
            ArrivalProcess::Burst { size } => size as f64 / p.rate_rps,
        };
        // floor of 1 us so a pathological rate cannot stall the clock
        t_us += ((gap_s * 1e6) as u64).max(1);
        while t_us >= phase_end_us {
            phase += 1;
            phase_end_us += (sc.arrivals[phase % sc.arrivals.len()].dur_s * 1e6) as u64;
        }
    }
    out
}

/// Weighted pick from the batch-size mix.
fn sample_mix(sc: &Scenario, rng: &mut Rng) -> u32 {
    let total: f64 = sc.batch_mix.iter().map(|m| m.weight).sum();
    let mut x = rng.f64() * total;
    for m in &sc.batch_mix {
        x -= m.weight;
        if x <= 0.0 {
            return m.size as u32;
        }
    }
    sc.batch_mix.last().map(|m| m.size as u32).unwrap_or(1)
}

/// FNV-1a over the trace's (at_us, count, image) triples — the
/// provenance fingerprint recorded in `BENCH_*.json`.
pub fn trace_hash(trace: &[Arrival]) -> u64 {
    fnv1a_words(
        trace
            .iter()
            .flat_map(|a| [a.at_us, a.count as u64, a.image as u64]),
    )
}

/// Offered images across the whole trace (sum of counts).
pub fn offered_images(trace: &[Arrival]) -> u64 {
    trace.iter().map(|a| a.count as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::scenario::builtin;

    #[test]
    fn same_seed_means_identical_trace_and_hash() {
        let sc = builtin("steady_state").unwrap();
        let a = generate(&sc, 2.0, 7, 16);
        let b = generate(&sc, 2.0, 7, 16);
        assert_eq!(a, b);
        assert_eq!(trace_hash(&a), trace_hash(&b));
        assert!(!a.is_empty());
    }

    #[test]
    fn different_seeds_diverge() {
        let sc = builtin("steady_state").unwrap();
        let a = generate(&sc, 2.0, 7, 16);
        let b = generate(&sc, 2.0, 8, 16);
        assert_ne!(trace_hash(&a), trace_hash(&b));
    }

    #[test]
    fn uniform_arrivals_tick_like_a_metronome() {
        let sc = builtin("ladder_thrash").unwrap(); // uniform 200 rps
        let trace = generate(&sc, 1.0, 5, 16);
        let gap = trace[1].at_us - trace[0].at_us;
        assert_eq!(gap, 5_000, "200 rps -> 5 ms gaps");
        for w in trace.windows(2) {
            assert_eq!(w[1].at_us - w[0].at_us, gap);
        }
    }

    #[test]
    fn burst_phases_emit_simultaneous_fronts() {
        let sc = builtin("incast_burst").unwrap(); // bursts of 48
        let trace = generate(&sc, 2.0, 5, 16);
        let first_at = trace[0].at_us;
        let front: Vec<_> = trace.iter().take_while(|a| a.at_us == first_at).collect();
        assert_eq!(front.len(), 48);
    }

    #[test]
    fn phases_cycle_when_the_duration_outlives_the_schedule() {
        let sc = builtin("steady_state").unwrap(); // single 10 s phase
        let trace = generate(&sc, 25.0, 7, 16);
        let last = trace.last().unwrap().at_us;
        assert!(last >= 24_000_000, "trace should reach ~25 s, got {last} us");
    }

    #[test]
    fn mix_sampling_respects_the_declared_sizes() {
        let sc = builtin("steady_state").unwrap(); // sizes 1 and 4
        let trace = generate(&sc, 3.0, 7, 16);
        assert!(trace.iter().all(|a| a.count == 1 || a.count == 4));
        assert!(trace.iter().any(|a| a.count == 1));
        assert!(trace.iter().any(|a| a.count == 4));
    }
}
