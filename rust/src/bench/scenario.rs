//! Scenario schema: the declarative input of the bench orchestrator.
//!
//! A scenario is a JSON file naming everything a load run needs —
//! arrival process, duration, batch-size mix, deployment shape and a
//! script of QoS/environment events — so a perf trajectory recorded
//! today can be replayed bit-identically against next month's code.
//! Seven built-ins cover the serving stack's interesting regimes
//! ([`BUILTIN_NAMES`]); arbitrary scenarios load from files via
//! [`Scenario::from_json`], which validates aggressively so a malformed
//! spec fails before any thread spawns.

use anyhow::{bail, Context, Result};

use crate::util::hash::fnv1a_bytes;
use crate::util::json::{self, Json};

/// Inter-arrival process of one [`ArrivalPhase`].  Rates count arrival
/// *events* per second; each event submits a [`MixEntry`]-sampled
/// number of images, so `rate_rps * mean(mix)` is the offered img/s.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Exponential gaps (memoryless open-loop clients).
    Poisson,
    /// Fixed `1/rate` gaps (a metronome — isolates queueing from
    /// arrival variance).
    Uniform,
    /// `size` simultaneous events, then a `size/rate` silence: the
    /// incast pattern that stresses batch formation and scale-up.
    Burst { size: usize },
}

impl ArrivalProcess {
    fn tag(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Uniform => "uniform",
            ArrivalProcess::Burst { .. } => "burst",
        }
    }
}

/// One stretch of the arrival schedule.  Phases play in order and the
/// schedule cycles when a duration override outlives it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalPhase {
    /// Phase length, seconds.
    pub dur_s: f64,
    /// Arrival events per second.
    pub rate_rps: f64,
    pub process: ArrivalProcess,
}

/// One entry of the batch-size mix: an arrival event submits `size`
/// images with probability proportional to `weight`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MixEntry {
    pub size: usize,
    pub weight: f64,
}

/// Which substrate the deployment under test runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The in-tree synthetic tiny model on the native LUT engine
    /// ([`crate::bench::synthetic`]) — real inference, no artifacts.
    Native,
    /// [`crate::backend::StubBackend`] with a configurable delay —
    /// isolates the serving machinery from compute.
    Stub,
}

/// One loopback fleet worker the driver spawns for the run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetWorkerSpec {
    /// Simulated compute time per forward call, microseconds.
    pub delay_us: u64,
    /// Heartbeat cadence this worker advertises in `HelloAck`.
    pub hb_interval_ms: u64,
    pub hb_timeout_ms: u64,
}

/// Shape of the deployment under test.
#[derive(Debug, Clone, PartialEq)]
pub struct Deployment {
    pub backend: BackendKind,
    /// Initial server worker count.
    pub workers: usize,
    /// Elastic-pool bounds (0 = fixed pool, `server::BatcherConfig`
    /// semantics).
    pub min_workers: usize,
    pub max_workers: usize,
    pub max_batch: usize,
    pub max_wait_ms: u64,
    pub retag_downgrades: bool,
    /// Stub-backend compute delay, microseconds (ignored for native).
    pub stub_delay_us: u64,
    /// Scale the stub delay by each OP's relative power (frugal rungs
    /// run faster) — the causal latency/accuracy link the autopilot
    /// exploits.  In-process stub deployments only.
    pub op_delay_scaling: bool,
    /// Supervisor scaling-cadence overrides, `server::BatcherConfig`
    /// semantics; 0 = library default.  Elastic pools only.
    pub scale_interval_ms: u64,
    pub scale_up_after: u32,
    pub scale_down_after: u32,
    /// In-flight Forwards per fleet worker connection: 0 = library
    /// default (or the `QOS_NETS_FLEET_PIPELINE` override), 1 =
    /// lockstep request/response.  Fleet deployments only.
    pub pipeline: usize,
    /// Rejoining re-probe cadence, ms; 0 = library default.  Fleet
    /// deployments only.
    pub reprobe_interval_ms: u64,
    /// Non-empty = spin up these loopback fleet workers and serve
    /// through a `FleetBackend` (scatter/gather + fleet-wide switch
    /// broadcast) instead of in-process backends.
    pub fleet: Vec<FleetWorkerSpec>,
}

/// One tenant class of a multi-tenant scenario: a share of the arrival
/// stream pinned to its own SLO and admission weight.  Classes are
/// listed premium-first (non-decreasing `priority`, 0 = premium) and
/// their listed order is the class id every other layer uses.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Strict scheduling priority, 0 = premium (sheds last).
    pub priority: u32,
    /// Admission weight against the other classes under overload.
    pub share: f64,
    /// Per-class p95 latency SLO, ms.
    pub slo_p95_ms: f64,
    /// Relative weight of this class in the arrival mix.
    pub weight: f64,
}

/// Where each tick's power budget comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum QosSource {
    /// A fixed budget, mutated only by scripted `budget` events.
    Constant(f64),
    /// A synthetic [`crate::qos::budget_trace`] kind
    /// (`sine`/`steps`/`walk`), one sample per tick.
    Trace(String),
    /// The battery/thermal [`crate::qos::envsim::EnvSimulator`],
    /// stepped `env_time_scale` sim-seconds per wall-second.
    Env,
}

/// QoS-controller and budget-source configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct QosSpec {
    pub source: QosSource,
    pub upgrade_margin: f64,
    pub min_dwell_ms: u64,
    /// Simulated seconds per wall second for [`QosSource::Env`] (the
    /// simulator's diurnal cycle spans 600 sim-seconds; 60 compresses
    /// a "day" into ten wall seconds).
    pub env_time_scale: f64,
}

/// What a scripted [`Event`] does when its time comes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Set the budget (only meaningful with [`QosSource::Constant`]).
    Budget(f64),
    /// Force an operating-point switch, bypassing the controller.
    SetOp { op: usize, drain: bool },
    /// [`crate::qos::envsim::EnvEvent::BatteryDrop`] (env source only).
    BatteryDrop(f64),
    /// [`crate::qos::envsim::EnvEvent::ThermalSpike`] (env source only).
    ThermalSpike(f64),
    /// [`crate::qos::envsim::EnvEvent::HarvestScale`] (env source only).
    HarvestScale(f64),
    /// [`crate::qos::envsim::EnvEvent::TariffWindow`] (env source only):
    /// cap the budget by `scale` for `secs` *simulated* seconds (wall
    /// seconds x `env_time_scale`).
    TariffWindow { scale: f64, secs: f64 },
}

/// One scripted disturbance, fired once when the run clock passes
/// `at_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub at_s: f64,
    pub kind: EventKind,
}

/// A complete bench scenario; see the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    /// Nominal run length, seconds (a `--secs` override cycles the
    /// arrival phases to cover itself).
    pub duration_s: f64,
    /// Default seed; `--seed` overrides without editing the file.
    pub seed: u64,
    /// Control-loop tick (budget sampling, event dispatch), ms.
    pub tick_ms: u64,
    /// Snapshot interval, ms; must be a multiple of `tick_ms`.
    pub interval_ms: u64,
    pub arrivals: Vec<ArrivalPhase>,
    pub batch_mix: Vec<MixEntry>,
    pub deployment: Deployment,
    pub qos: QosSpec,
    /// p95 latency SLO, ms — enables the autopilot for this scenario
    /// (`None` = plain budget-driven QoS control, the pre-autopilot
    /// behavior).
    pub slo_p95_ms: Option<f64>,
    /// Operator power envelope in (0, 1], capping the budget the
    /// autopilot hands its controller.  Requires `slo_p95_ms`.
    pub power_envelope: Option<f64>,
    /// Tenant classes sharing the deployment (empty = the classic
    /// single-tenant scenario; the canonical JSON omits the section so
    /// pre-tenancy `config_hash`es are unchanged).  Requires
    /// `slo_p95_ms` — per-class steering rides the autopilot.
    pub tenants: Vec<TenantSpec>,
    pub events: Vec<Event>,
}

/// Every built-in scenario name, in presentation order.
pub const BUILTIN_NAMES: [&str; 8] = [
    "steady_state",
    "diurnal_ramp",
    "incast_burst",
    "flash_crowd",
    "ladder_thrash",
    "heterogeneous_fleet",
    "slo_pressure",
    "tenant_contention",
];

/// Rungs every bench ladder has (native synthetic and stub/fleet
/// alike), so `set_op` events can be validated before a deployment
/// exists.
pub const LADDER_RUNGS: usize = 3;

impl Scenario {
    /// FNV-1a over the canonical JSON encoding — the provenance tag
    /// that ties a `BENCH_*.json` report to the exact scenario (and
    /// code-side defaults) that produced it.
    pub fn config_hash(&self) -> u64 {
        fnv1a_bytes(json::to_string(&self.to_json()).bytes())
    }

    /// Serialize; [`Scenario::from_json`] inverts this exactly.
    pub fn to_json(&self) -> Json {
        let arrivals = self
            .arrivals
            .iter()
            .map(|p| {
                let mut pairs = vec![
                    ("dur_s", Json::num(p.dur_s)),
                    ("rate_rps", Json::num(p.rate_rps)),
                    ("process", Json::str(p.process.tag())),
                ];
                if let ArrivalProcess::Burst { size } = p.process {
                    pairs.push(("burst_size", Json::num(size as f64)));
                }
                Json::obj(pairs)
            })
            .collect();
        let mix = self
            .batch_mix
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("size", Json::num(m.size as f64)),
                    ("weight", Json::num(m.weight)),
                ])
            })
            .collect();
        let fleet = self
            .deployment
            .fleet
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("delay_us", Json::num(w.delay_us as f64)),
                    ("hb_interval_ms", Json::num(w.hb_interval_ms as f64)),
                    ("hb_timeout_ms", Json::num(w.hb_timeout_ms as f64)),
                ])
            })
            .collect();
        let backend = match self.deployment.backend {
            BackendKind::Native => "native",
            BackendKind::Stub => "stub",
        };
        let mut deployment_pairs = vec![
            ("backend", Json::str(backend)),
            ("workers", Json::num(self.deployment.workers as f64)),
            ("min_workers", Json::num(self.deployment.min_workers as f64)),
            ("max_workers", Json::num(self.deployment.max_workers as f64)),
            ("max_batch", Json::num(self.deployment.max_batch as f64)),
            ("max_wait_ms", Json::num(self.deployment.max_wait_ms as f64)),
            ("retag_downgrades", Json::Bool(self.deployment.retag_downgrades)),
            ("stub_delay_us", Json::num(self.deployment.stub_delay_us as f64)),
        ];
        // optional knobs are emitted only when set, so the canonical
        // JSON (and with it `config_hash`) of scenarios predating each
        // knob is unchanged and committed baselines stay comparable
        if self.deployment.op_delay_scaling {
            deployment_pairs.push(("op_delay_scaling", Json::Bool(true)));
        }
        if self.deployment.scale_interval_ms > 0 {
            deployment_pairs
                .push(("scale_interval_ms", Json::num(self.deployment.scale_interval_ms as f64)));
        }
        if self.deployment.scale_up_after > 0 {
            deployment_pairs
                .push(("scale_up_after", Json::num(self.deployment.scale_up_after as f64)));
        }
        if self.deployment.scale_down_after > 0 {
            deployment_pairs
                .push(("scale_down_after", Json::num(self.deployment.scale_down_after as f64)));
        }
        if self.deployment.pipeline > 0 {
            deployment_pairs.push(("pipeline", Json::num(self.deployment.pipeline as f64)));
        }
        if self.deployment.reprobe_interval_ms > 0 {
            deployment_pairs.push((
                "reprobe_interval_ms",
                Json::num(self.deployment.reprobe_interval_ms as f64),
            ));
        }
        deployment_pairs.push(("fleet", Json::Arr(fleet)));
        let deployment = Json::obj(deployment_pairs);
        let mut qos_pairs: Vec<(&str, Json)> = Vec::new();
        match &self.qos.source {
            QosSource::Constant(b) => {
                qos_pairs.push(("source", Json::str("constant")));
                qos_pairs.push(("budget", Json::num(*b)));
            }
            QosSource::Trace(kind) => {
                qos_pairs.push(("source", Json::str("trace")));
                qos_pairs.push(("trace", Json::str(kind.clone())));
            }
            QosSource::Env => qos_pairs.push(("source", Json::str("env"))),
        }
        qos_pairs.push(("upgrade_margin", Json::num(self.qos.upgrade_margin)));
        qos_pairs.push(("min_dwell_ms", Json::num(self.qos.min_dwell_ms as f64)));
        qos_pairs.push(("env_time_scale", Json::num(self.qos.env_time_scale)));
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut pairs = vec![("at_s", Json::num(e.at_s))];
                match e.kind {
                    EventKind::Budget(b) => {
                        pairs.push(("kind", Json::str("budget")));
                        pairs.push(("budget", Json::num(b)));
                    }
                    EventKind::SetOp { op, drain } => {
                        pairs.push(("kind", Json::str("set_op")));
                        pairs.push(("op", Json::num(op as f64)));
                        pairs.push(("drain", Json::Bool(drain)));
                    }
                    EventKind::BatteryDrop(delta) => {
                        pairs.push(("kind", Json::str("battery_drop")));
                        pairs.push(("delta", Json::num(delta)));
                    }
                    EventKind::ThermalSpike(delta_c) => {
                        pairs.push(("kind", Json::str("thermal_spike")));
                        pairs.push(("delta_c", Json::num(delta_c)));
                    }
                    EventKind::HarvestScale(factor) => {
                        pairs.push(("kind", Json::str("harvest_scale")));
                        pairs.push(("factor", Json::num(factor)));
                    }
                    EventKind::TariffWindow { scale, secs } => {
                        pairs.push(("kind", Json::str("tariff_window")));
                        pairs.push(("scale", Json::num(scale)));
                        pairs.push(("secs", Json::num(secs)));
                    }
                }
                Json::obj(pairs)
            })
            .collect();
        let mut top = vec![
            ("name", Json::str(self.name.clone())),
            ("description", Json::str(self.description.clone())),
            ("duration_s", Json::num(self.duration_s)),
            ("seed", Json::num(self.seed as f64)),
            ("tick_ms", Json::num(self.tick_ms as f64)),
            ("interval_ms", Json::num(self.interval_ms as f64)),
            ("arrivals", Json::Arr(arrivals)),
            ("batch_mix", Json::Arr(mix)),
            ("deployment", deployment),
            ("qos", Json::obj(qos_pairs)),
        ];
        // omitted when unset — see the deployment-knob note above
        if let Some(slo) = self.slo_p95_ms {
            top.push(("slo_p95_ms", Json::num(slo)));
        }
        if let Some(envelope) = self.power_envelope {
            top.push(("power_envelope", Json::num(envelope)));
        }
        if !self.tenants.is_empty() {
            let tenants = self
                .tenants
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        ("name", Json::str(t.name.clone())),
                        ("priority", Json::num(t.priority as f64)),
                        ("share", Json::num(t.share)),
                        ("slo_p95_ms", Json::num(t.slo_p95_ms)),
                        ("weight", Json::num(t.weight)),
                    ])
                })
                .collect();
            top.push(("tenants", Json::Arr(tenants)));
        }
        top.push(("events", Json::Arr(events)));
        Json::obj(top)
    }

    /// Parse + validate; every rejection names the offending field.
    pub fn from_json(v: &Json) -> Result<Scenario> {
        let name = req_str(v, "name")?.to_string();
        let description = v.get("description").and_then(|x| x.as_str()).unwrap_or("").to_string();
        let duration_s = req_f64(v, "duration_s")?;
        let seed = req_f64(v, "seed")? as u64;
        let tick_ms = req_f64(v, "tick_ms")? as u64;
        let interval_ms = req_f64(v, "interval_ms")? as u64;

        let arrivals = v
            .get("arrivals")
            .and_then(|x| x.as_arr())
            .context("scenario: missing arrivals array")?
            .iter()
            .map(parse_phase)
            .collect::<Result<Vec<_>>>()?;
        let batch_mix = v
            .get("batch_mix")
            .and_then(|x| x.as_arr())
            .context("scenario: missing batch_mix array")?
            .iter()
            .map(parse_mix)
            .collect::<Result<Vec<_>>>()?;
        let deployment =
            parse_deployment(v.get("deployment").context("scenario: missing deployment")?)?;
        let qos = parse_qos(v.get("qos").context("scenario: missing qos")?)?;
        let slo_p95_ms = v.get("slo_p95_ms").and_then(|x| x.as_f64());
        let power_envelope = v.get("power_envelope").and_then(|x| x.as_f64());
        let tenants = v
            .get("tenants")
            .and_then(|x| x.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(parse_tenant)
            .collect::<Result<Vec<_>>>()?;
        let events = v
            .get("events")
            .and_then(|x| x.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(parse_event)
            .collect::<Result<Vec<_>>>()?;

        let sc = Scenario {
            name,
            description,
            duration_s,
            seed,
            tick_ms,
            interval_ms,
            arrivals,
            batch_mix,
            deployment,
            qos,
            slo_p95_ms,
            power_envelope,
            tenants,
            events,
        };
        sc.validate()?;
        Ok(sc)
    }

    /// Structural validation (also run by [`from_json`](Self::from_json)).
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("scenario: empty name");
        }
        if !(self.duration_s.is_finite() && self.duration_s > 0.0) {
            bail!("scenario {}: duration_s must be finite and > 0", self.name);
        }
        if self.tick_ms == 0 {
            bail!("scenario {}: tick_ms must be > 0", self.name);
        }
        if self.interval_ms == 0 || self.interval_ms % self.tick_ms != 0 {
            bail!(
                "scenario {}: interval_ms ({}) must be a positive multiple of tick_ms ({})",
                self.name,
                self.interval_ms,
                self.tick_ms
            );
        }
        if self.arrivals.is_empty() {
            bail!("scenario {}: no arrival phases", self.name);
        }
        for (i, p) in self.arrivals.iter().enumerate() {
            if !(p.dur_s.is_finite() && p.dur_s > 0.0) {
                bail!("scenario {}: arrival phase {i}: dur_s must be finite and > 0", self.name);
            }
            if !(p.rate_rps.is_finite() && p.rate_rps > 0.0) {
                bail!("scenario {}: arrival phase {i}: rate_rps must be finite and > 0", self.name);
            }
            if let ArrivalProcess::Burst { size } = p.process {
                if size == 0 {
                    bail!("scenario {}: arrival phase {i}: burst_size must be >= 1", self.name);
                }
            }
        }
        if self.batch_mix.is_empty() {
            bail!("scenario {}: empty batch_mix", self.name);
        }
        for (i, m) in self.batch_mix.iter().enumerate() {
            if m.size == 0 {
                bail!("scenario {}: batch_mix entry {i}: size must be >= 1", self.name);
            }
            if !(m.weight.is_finite() && m.weight > 0.0) {
                bail!("scenario {}: batch_mix entry {i}: weight must be finite and > 0", self.name);
            }
        }
        let d = &self.deployment;
        if d.workers == 0 {
            bail!("scenario {}: deployment.workers must be >= 1", self.name);
        }
        if d.max_batch == 0 || d.max_wait_ms == 0 {
            bail!("scenario {}: deployment max_batch and max_wait_ms must be >= 1", self.name);
        }
        if d.max_workers > 0 && d.max_workers < d.min_workers {
            bail!("scenario {}: deployment.max_workers < min_workers", self.name);
        }
        if !d.fleet.is_empty() && d.backend != BackendKind::Stub {
            bail!("scenario {}: loopback fleet workers serve the stub backend", self.name);
        }
        if d.pipeline > 0 && d.fleet.is_empty() {
            bail!(
                "scenario {}: deployment.pipeline only applies to fleet deployments",
                self.name
            );
        }
        if d.reprobe_interval_ms > 0 && d.fleet.is_empty() {
            bail!(
                "scenario {}: deployment.reprobe_interval_ms only applies to fleet deployments",
                self.name
            );
        }
        if d.op_delay_scaling && (d.backend != BackendKind::Stub || !d.fleet.is_empty()) {
            bail!(
                "scenario {}: op_delay_scaling applies to in-process stub deployments",
                self.name
            );
        }
        if (d.scale_interval_ms > 0 || d.scale_up_after > 0 || d.scale_down_after > 0)
            && d.max_workers == 0
        {
            bail!(
                "scenario {}: supervisor cadence knobs need an elastic pool (max_workers > 0)",
                self.name
            );
        }
        for (i, w) in d.fleet.iter().enumerate() {
            if w.hb_interval_ms == 0 || w.hb_timeout_ms == 0 {
                bail!("scenario {}: fleet worker {i}: heartbeat cadence must be > 0 ms", self.name);
            }
        }
        match &self.qos.source {
            QosSource::Constant(b) => {
                if !(b.is_finite() && *b > 0.0 && *b <= 1.0) {
                    bail!("scenario {}: constant budget must be in (0, 1]", self.name);
                }
            }
            QosSource::Trace(kind) => {
                if !matches!(kind.as_str(), "sine" | "steps" | "walk") {
                    bail!(
                        "scenario {}: unknown budget trace {kind:?} (sine|steps|walk)",
                        self.name
                    );
                }
            }
            QosSource::Env => {}
        }
        if !(self.qos.upgrade_margin.is_finite() && self.qos.upgrade_margin >= 0.0) {
            bail!("scenario {}: upgrade_margin must be finite and >= 0", self.name);
        }
        if !(self.qos.env_time_scale.is_finite() && self.qos.env_time_scale > 0.0) {
            bail!("scenario {}: env_time_scale must be finite and > 0", self.name);
        }
        if let Some(slo) = self.slo_p95_ms {
            if !(slo.is_finite() && slo > 0.0) {
                bail!("scenario {}: slo_p95_ms must be finite and > 0", self.name);
            }
        }
        if let Some(envelope) = self.power_envelope {
            if !(envelope.is_finite() && envelope > 0.0 && envelope <= 1.0) {
                bail!("scenario {}: power_envelope must be in (0, 1]", self.name);
            }
            if self.slo_p95_ms.is_none() {
                bail!("scenario {}: power_envelope needs slo_p95_ms (the autopilot SLO)", self.name);
            }
        }
        if !self.tenants.is_empty() {
            if self.slo_p95_ms.is_none() {
                bail!(
                    "scenario {}: tenants need slo_p95_ms (per-class steering rides the autopilot)",
                    self.name
                );
            }
            for (i, t) in self.tenants.iter().enumerate() {
                if t.name.is_empty() {
                    bail!("scenario {}: tenant {i}: empty name", self.name);
                }
                if self.tenants[..i].iter().any(|o| o.name == t.name) {
                    bail!("scenario {}: tenant {i}: duplicate name {:?}", self.name, t.name);
                }
                if !(t.share.is_finite() && t.share > 0.0) {
                    bail!("scenario {}: tenant {i}: share must be finite and > 0", self.name);
                }
                if !(t.weight.is_finite() && t.weight > 0.0) {
                    bail!("scenario {}: tenant {i}: weight must be finite and > 0", self.name);
                }
                if !(t.slo_p95_ms.is_finite() && t.slo_p95_ms > 0.0) {
                    bail!("scenario {}: tenant {i}: slo_p95_ms must be finite and > 0", self.name);
                }
                if i > 0 && t.priority < self.tenants[i - 1].priority {
                    bail!(
                        "scenario {}: tenant {i}: classes must be listed premium-first \
                         (non-decreasing priority)",
                        self.name
                    );
                }
            }
        }
        for (i, e) in self.events.iter().enumerate() {
            if !(e.at_s.is_finite() && e.at_s >= 0.0) {
                bail!("scenario {}: event {i}: at_s must be finite and >= 0", self.name);
            }
            match e.kind {
                EventKind::Budget(b) => {
                    if !(b.is_finite() && b > 0.0 && b <= 1.0) {
                        bail!("scenario {}: event {i}: budget must be in (0, 1]", self.name);
                    }
                    if !matches!(self.qos.source, QosSource::Constant(_)) {
                        bail!(
                            "scenario {}: event {i}: budget events need qos.source = constant",
                            self.name
                        );
                    }
                }
                EventKind::SetOp { op, .. } => {
                    if op >= LADDER_RUNGS {
                        bail!(
                            "scenario {}: event {i}: set_op op {op} out of range (ladders have {LADDER_RUNGS} rungs)",
                            self.name
                        );
                    }
                }
                EventKind::BatteryDrop(_)
                | EventKind::ThermalSpike(_)
                | EventKind::HarvestScale(_)
                | EventKind::TariffWindow { .. } => {
                    if self.qos.source != QosSource::Env {
                        bail!(
                            "scenario {}: event {i}: environment events need qos.source = env",
                            self.name
                        );
                    }
                    if let EventKind::TariffWindow { scale, secs } = e.kind {
                        if !(scale.is_finite() && (0.0..=1.0).contains(&scale)) {
                            bail!(
                                "scenario {}: event {i}: tariff scale must be in [0, 1]",
                                self.name
                            );
                        }
                        if !(secs.is_finite() && secs > 0.0) {
                            bail!(
                                "scenario {}: event {i}: tariff secs must be finite and > 0",
                                self.name
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

fn req_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(|x| x.as_f64())
        .with_context(|| format!("scenario: missing or non-numeric {key:?}"))
}

fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(|x| x.as_str())
        .with_context(|| format!("scenario: missing or non-string {key:?}"))
}

fn parse_phase(v: &Json) -> Result<ArrivalPhase> {
    let process = match req_str(v, "process")? {
        "poisson" => ArrivalProcess::Poisson,
        "uniform" => ArrivalProcess::Uniform,
        "burst" => ArrivalProcess::Burst {
            size: req_f64(v, "burst_size").context("burst phases need burst_size")? as usize,
        },
        other => bail!("unknown arrival process {other:?} (poisson|uniform|burst)"),
    };
    Ok(ArrivalPhase {
        dur_s: req_f64(v, "dur_s")?,
        rate_rps: req_f64(v, "rate_rps")?,
        process,
    })
}

fn parse_mix(v: &Json) -> Result<MixEntry> {
    Ok(MixEntry {
        size: req_f64(v, "size")? as usize,
        weight: req_f64(v, "weight")?,
    })
}

fn parse_deployment(v: &Json) -> Result<Deployment> {
    let backend = match req_str(v, "backend")? {
        "native" => BackendKind::Native,
        "stub" => BackendKind::Stub,
        other => bail!("unknown deployment backend {other:?} (native|stub)"),
    };
    let fleet = v
        .get("fleet")
        .and_then(|x| x.as_arr())
        .unwrap_or(&[])
        .iter()
        .map(|w| {
            Ok(FleetWorkerSpec {
                delay_us: req_f64(w, "delay_us")? as u64,
                hb_interval_ms: req_f64(w, "hb_interval_ms")? as u64,
                hb_timeout_ms: req_f64(w, "hb_timeout_ms")? as u64,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Deployment {
        backend,
        workers: req_f64(v, "workers")? as usize,
        min_workers: v.get("min_workers").and_then(|x| x.as_usize()).unwrap_or(0),
        max_workers: v.get("max_workers").and_then(|x| x.as_usize()).unwrap_or(0),
        max_batch: req_f64(v, "max_batch")? as usize,
        max_wait_ms: req_f64(v, "max_wait_ms")? as u64,
        retag_downgrades: v.get("retag_downgrades").and_then(|x| x.as_bool()).unwrap_or(false),
        stub_delay_us: v.get("stub_delay_us").and_then(|x| x.as_usize()).unwrap_or(0) as u64,
        op_delay_scaling: v.get("op_delay_scaling").and_then(|x| x.as_bool()).unwrap_or(false),
        scale_interval_ms: v.get("scale_interval_ms").and_then(|x| x.as_usize()).unwrap_or(0)
            as u64,
        scale_up_after: v.get("scale_up_after").and_then(|x| x.as_usize()).unwrap_or(0) as u32,
        scale_down_after: v.get("scale_down_after").and_then(|x| x.as_usize()).unwrap_or(0) as u32,
        pipeline: v.get("pipeline").and_then(|x| x.as_usize()).unwrap_or(0),
        reprobe_interval_ms: v.get("reprobe_interval_ms").and_then(|x| x.as_usize()).unwrap_or(0)
            as u64,
        fleet,
    })
}

fn parse_tenant(v: &Json) -> Result<TenantSpec> {
    Ok(TenantSpec {
        name: req_str(v, "name")?.to_string(),
        priority: req_f64(v, "priority")? as u32,
        share: req_f64(v, "share")?,
        slo_p95_ms: req_f64(v, "slo_p95_ms")?,
        weight: req_f64(v, "weight")?,
    })
}

fn parse_qos(v: &Json) -> Result<QosSpec> {
    let source = match req_str(v, "source")? {
        "constant" => QosSource::Constant(req_f64(v, "budget")?),
        "trace" => QosSource::Trace(req_str(v, "trace")?.to_string()),
        "env" => QosSource::Env,
        other => bail!("unknown qos source {other:?} (constant|trace|env)"),
    };
    Ok(QosSpec {
        source,
        upgrade_margin: v.get("upgrade_margin").and_then(|x| x.as_f64()).unwrap_or(0.05),
        min_dwell_ms: v.get("min_dwell_ms").and_then(|x| x.as_usize()).unwrap_or(100) as u64,
        env_time_scale: v.get("env_time_scale").and_then(|x| x.as_f64()).unwrap_or(60.0),
    })
}

fn parse_event(v: &Json) -> Result<Event> {
    let kind = match req_str(v, "kind")? {
        "budget" => EventKind::Budget(req_f64(v, "budget")?),
        "set_op" => EventKind::SetOp {
            op: req_f64(v, "op")? as usize,
            drain: v.get("drain").and_then(|x| x.as_bool()).unwrap_or(true),
        },
        "battery_drop" => EventKind::BatteryDrop(req_f64(v, "delta")?),
        "thermal_spike" => EventKind::ThermalSpike(req_f64(v, "delta_c")?),
        "harvest_scale" => EventKind::HarvestScale(req_f64(v, "factor")?),
        "tariff_window" => EventKind::TariffWindow {
            scale: req_f64(v, "scale")?,
            secs: req_f64(v, "secs")?,
        },
        other => bail!("unknown event kind {other:?}"),
    };
    Ok(Event { at_s: req_f64(v, "at_s")?, kind })
}

/// Look up a built-in scenario by name.
pub fn builtin(name: &str) -> Option<Scenario> {
    let sc = match name {
        "steady_state" => steady_state(),
        "diurnal_ramp" => diurnal_ramp(),
        "incast_burst" => incast_burst(),
        "flash_crowd" => flash_crowd(),
        "ladder_thrash" => ladder_thrash(),
        "heterogeneous_fleet" => heterogeneous_fleet(),
        "slo_pressure" => slo_pressure(),
        "tenant_contention" => tenant_contention(),
        _ => return None,
    };
    debug_assert!(sc.validate().is_ok(), "builtin {name} must validate");
    Some(sc)
}

fn base_deployment(backend: BackendKind) -> Deployment {
    Deployment {
        backend,
        workers: 2,
        min_workers: 0,
        max_workers: 0,
        max_batch: 16,
        max_wait_ms: 4,
        retag_downgrades: false,
        stub_delay_us: 0,
        op_delay_scaling: false,
        scale_interval_ms: 0,
        scale_up_after: 0,
        scale_down_after: 0,
        pipeline: 0,
        reprobe_interval_ms: 0,
        fleet: Vec::new(),
    }
}

fn base_qos(source: QosSource) -> QosSpec {
    QosSpec {
        source,
        upgrade_margin: 0.05,
        min_dwell_ms: 100,
        env_time_scale: 60.0,
    }
}

/// The trajectory anchor: fixed pool, Poisson arrivals, sine budget —
/// the run CI records as `BENCH_steady_state.json` every build.
fn steady_state() -> Scenario {
    Scenario {
        name: "steady_state".into(),
        description: "fixed pool under steady Poisson load with a sine budget — the \
                      perf-trajectory anchor run"
            .into(),
        duration_s: 10.0,
        seed: 7,
        tick_ms: 50,
        interval_ms: 500,
        arrivals: vec![ArrivalPhase {
            dur_s: 10.0,
            rate_rps: 250.0,
            process: ArrivalProcess::Poisson,
        }],
        batch_mix: vec![
            MixEntry { size: 1, weight: 0.75 },
            MixEntry { size: 4, weight: 0.25 },
        ],
        deployment: base_deployment(BackendKind::Native),
        qos: base_qos(QosSource::Trace("sine".into())),
        slo_p95_ms: None,
        power_envelope: None,
        tenants: Vec::new(),
        events: Vec::new(),
    }
}

/// Day/night load swing against the battery/thermal simulator, with a
/// scripted cloud front killing the harvest mid-run.
fn diurnal_ramp() -> Scenario {
    Scenario {
        name: "diurnal_ramp".into(),
        description: "slow load ramp against the battery/thermal env simulator; a scripted \
                      cloud front kills the harvest mid-run"
            .into(),
        duration_s: 20.0,
        seed: 11,
        tick_ms: 50,
        interval_ms: 1000,
        arrivals: vec![
            ArrivalPhase { dur_s: 6.0, rate_rps: 120.0, process: ArrivalProcess::Poisson },
            ArrivalPhase { dur_s: 8.0, rate_rps: 320.0, process: ArrivalProcess::Poisson },
            ArrivalPhase { dur_s: 6.0, rate_rps: 120.0, process: ArrivalProcess::Poisson },
        ],
        batch_mix: vec![MixEntry { size: 1, weight: 1.0 }],
        deployment: Deployment {
            min_workers: 1,
            max_workers: 4,
            workers: 1,
            ..base_deployment(BackendKind::Native)
        },
        qos: base_qos(QosSource::Env),
        slo_p95_ms: None,
        power_envelope: None,
        tenants: Vec::new(),
        events: vec![Event { at_s: 12.0, kind: EventKind::HarvestScale(0.0) }],
    }
}

/// Synchronized burst arrivals (the incast pattern): batch formation
/// and scale-up under simultaneous request fronts.
fn incast_burst() -> Scenario {
    Scenario {
        name: "incast_burst".into(),
        description: "synchronized 48-wide request fronts into an elastic pool — stresses \
                      batch formation and the scaling supervisor"
            .into(),
        duration_s: 8.0,
        seed: 13,
        tick_ms: 50,
        interval_ms: 500,
        arrivals: vec![ArrivalPhase {
            dur_s: 8.0,
            rate_rps: 24.0,
            process: ArrivalProcess::Burst { size: 48 },
        }],
        batch_mix: vec![MixEntry { size: 1, weight: 1.0 }],
        deployment: Deployment {
            workers: 1,
            min_workers: 1,
            max_workers: 6,
            stub_delay_us: 300,
            ..base_deployment(BackendKind::Stub)
        },
        qos: base_qos(QosSource::Constant(1.0)),
        slo_p95_ms: None,
        power_envelope: None,
        tenants: Vec::new(),
        events: Vec::new(),
    }
}

/// A 16x offered-load spike and recovery, with downgrade retagging on
/// so immediate switches reach the backlog.
fn flash_crowd() -> Scenario {
    Scenario {
        name: "flash_crowd".into(),
        description: "16x offered-load spike and recovery under a step budget, with \
                      retag_downgrades letting immediate switches reach the backlog"
            .into(),
        duration_s: 12.0,
        seed: 17,
        tick_ms: 50,
        interval_ms: 500,
        arrivals: vec![
            ArrivalPhase { dur_s: 4.0, rate_rps: 50.0, process: ArrivalProcess::Poisson },
            ArrivalPhase { dur_s: 3.0, rate_rps: 800.0, process: ArrivalProcess::Poisson },
            ArrivalPhase { dur_s: 5.0, rate_rps: 50.0, process: ArrivalProcess::Poisson },
        ],
        batch_mix: vec![
            MixEntry { size: 1, weight: 0.5 },
            MixEntry { size: 2, weight: 0.5 },
        ],
        deployment: Deployment {
            workers: 1,
            min_workers: 1,
            max_workers: 8,
            stub_delay_us: 200,
            retag_downgrades: true,
            ..base_deployment(BackendKind::Stub)
        },
        qos: base_qos(QosSource::Trace("steps".into())),
        slo_p95_ms: None,
        power_envelope: None,
        tenants: Vec::new(),
        events: Vec::new(),
    }
}

/// Scripted budget square wave that forces the controller to alternate
/// draining upgrades and immediate downgrades every 0.4 s — the
/// acceptance scenario for recording >= 1 of each switch mode.
fn ladder_thrash() -> Scenario {
    let mut events = Vec::new();
    for i in 0..14u32 {
        let budget = if i % 2 == 0 { 0.5 } else { 1.0 };
        events.push(Event {
            at_s: 0.4 * (i + 1) as f64,
            kind: EventKind::Budget(budget),
        });
    }
    Scenario {
        name: "ladder_thrash".into(),
        description: "0.4 s budget square wave forcing alternating draining upgrades and \
                      immediate downgrades"
            .into(),
        duration_s: 6.0,
        seed: 19,
        tick_ms: 50,
        interval_ms: 500,
        arrivals: vec![ArrivalPhase {
            dur_s: 6.0,
            rate_rps: 200.0,
            process: ArrivalProcess::Uniform,
        }],
        batch_mix: vec![MixEntry { size: 1, weight: 1.0 }],
        deployment: Deployment {
            stub_delay_us: 100,
            ..base_deployment(BackendKind::Stub)
        },
        qos: base_qos(QosSource::Constant(1.0)),
        slo_p95_ms: None,
        power_envelope: None,
        tenants: Vec::new(),
        events,
    }
}

/// A three-speed loopback fleet with mixed heartbeat leashes:
/// per-worker attribution under pipelined scatter/gather — the
/// latency EWMA must skew chunk sizes toward the fast box — plus the
/// advertised-cadence minimum.  The pipeline window is pinned so the
/// recorded report does not depend on `QOS_NETS_FLEET_PIPELINE`.
fn heterogeneous_fleet() -> Scenario {
    Scenario {
        name: "heterogeneous_fleet".into(),
        description: "three loopback fleet workers at 100/400/1200 us with mixed heartbeat \
                      leashes — latency-skewed chunk sizing under a pinned pipeline window, \
                      per-worker attribution and fast-eviction cadence"
            .into(),
        duration_s: 8.0,
        seed: 23,
        tick_ms: 50,
        interval_ms: 500,
        arrivals: vec![ArrivalPhase {
            dur_s: 8.0,
            rate_rps: 150.0,
            process: ArrivalProcess::Poisson,
        }],
        batch_mix: vec![
            MixEntry { size: 2, weight: 0.5 },
            MixEntry { size: 6, weight: 0.5 },
        ],
        deployment: Deployment {
            workers: 2,
            pipeline: 4,
            fleet: vec![
                FleetWorkerSpec { delay_us: 100, hb_interval_ms: 1000, hb_timeout_ms: 500 },
                FleetWorkerSpec { delay_us: 400, hb_interval_ms: 400, hb_timeout_ms: 200 },
                FleetWorkerSpec { delay_us: 1200, hb_interval_ms: 150, hb_timeout_ms: 80 },
            ],
            ..base_deployment(BackendKind::Stub)
        },
        qos: base_qos(QosSource::Trace("sine".into())),
        slo_p95_ms: None,
        power_envelope: None,
        tenants: Vec::new(),
        events: Vec::new(),
    }
}

/// A grid tariff window scripted against the SLO autopilot: a fixed
/// two-worker pool (accuracy is the only lever) runs a stub whose delay
/// scales with OP power, so shedding rungs genuinely buys throughput.
/// The tariff window (budget 0.9) pushes the deployment off the exact
/// rung onto mid, and a load peak beyond the mid rung's capacity lands
/// inside the window; the autopilot must trade accuracy for latency
/// *before* the p95 crosses the SLO, and recover accuracy once the
/// window ends — while an autopilot-off run of the same seed sits at
/// the mid rung and violates the SLO for the whole peak.
///
/// Capacity math (2 workers, max_batch 8, 8 ms base delay): exact
/// 2000 img/s, mid 2500 img/s, frugal 3333 img/s.  The peak offers
/// 2750 img/s — above mid, below frugal.  `env_time_scale` is 1 so the
/// battery/thermal physics stay flat over the 12 s run and the scripted
/// tariff window is the only budget driver.  `upgrade_margin` must be 0
/// here: the top rung's relative power is 1.0, so any positive margin
/// would block the frugal->exact settle forever and the run would cruise
/// at the floor with nothing left to shed.
fn slo_pressure() -> Scenario {
    Scenario {
        name: "slo_pressure".into(),
        description: "load peak beyond the mid rung inside a grid tariff window — the \
                      autopilot must shed accuracy before the p95 SLO breaks and recover \
                      after the window ends"
            .into(),
        duration_s: 12.0,
        seed: 29,
        tick_ms: 50,
        interval_ms: 500,
        arrivals: vec![
            ArrivalPhase { dur_s: 4.0, rate_rps: 75.0, process: ArrivalProcess::Poisson },
            ArrivalPhase { dur_s: 5.0, rate_rps: 687.5, process: ArrivalProcess::Poisson },
            ArrivalPhase { dur_s: 3.0, rate_rps: 75.0, process: ArrivalProcess::Poisson },
        ],
        batch_mix: vec![MixEntry { size: 4, weight: 1.0 }],
        deployment: Deployment {
            workers: 2,
            max_batch: 8,
            stub_delay_us: 8000,
            op_delay_scaling: true,
            ..base_deployment(BackendKind::Stub)
        },
        qos: QosSpec {
            source: QosSource::Env,
            upgrade_margin: 0.0,
            min_dwell_ms: 100,
            env_time_scale: 1.0,
        },
        slo_p95_ms: Some(100.0),
        power_envelope: None,
        tenants: Vec::new(),
        events: vec![Event {
            at_s: 4.0,
            kind: EventKind::TariffWindow { scale: 0.9, secs: 5.0 },
        }],
    }
}

/// The slo_pressure overload shared by two tenant classes: a premium
/// class (priority 0, 1/4 of the arrivals, tight SLO) and a best-effort
/// class (priority 1, 3/4 of the arrivals, loose SLO) ride the same
/// two-worker stub pool through the same tariff window and load peak.
/// The per-class autopilot must shed the best-effort ladder first, so
/// the committed `BENCH_tenant_contention.json` shows the premium
/// class's violation-tick count strictly below the classless baseline
/// pass while every shed/retag lands on best-effort.
fn tenant_contention() -> Scenario {
    Scenario {
        name: "tenant_contention".into(),
        description: "two tenant classes share the slo_pressure overload — the per-class \
                      autopilot sheds the best-effort ladder first and keeps the premium \
                      p95 inside its SLO"
            .into(),
        duration_s: 12.0,
        seed: 31,
        tick_ms: 50,
        interval_ms: 500,
        arrivals: vec![
            ArrivalPhase { dur_s: 4.0, rate_rps: 75.0, process: ArrivalProcess::Poisson },
            ArrivalPhase { dur_s: 5.0, rate_rps: 687.5, process: ArrivalProcess::Poisson },
            ArrivalPhase { dur_s: 3.0, rate_rps: 75.0, process: ArrivalProcess::Poisson },
        ],
        batch_mix: vec![MixEntry { size: 4, weight: 1.0 }],
        deployment: Deployment {
            workers: 2,
            max_batch: 8,
            stub_delay_us: 8000,
            op_delay_scaling: true,
            ..base_deployment(BackendKind::Stub)
        },
        qos: QosSpec {
            source: QosSource::Env,
            upgrade_margin: 0.0,
            min_dwell_ms: 100,
            env_time_scale: 1.0,
        },
        slo_p95_ms: Some(100.0),
        power_envelope: None,
        tenants: vec![
            TenantSpec {
                name: "premium".into(),
                priority: 0,
                share: 3.0,
                slo_p95_ms: 100.0,
                weight: 1.0,
            },
            TenantSpec {
                name: "best_effort".into(),
                priority: 1,
                share: 1.0,
                slo_p95_ms: 250.0,
                weight: 3.0,
            },
        ],
        events: vec![Event {
            at_s: 4.0,
            kind: EventKind::TariffWindow { scale: 0.9, secs: 5.0 },
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_round_trips_through_json() {
        for name in BUILTIN_NAMES {
            let sc = builtin(name).unwrap();
            sc.validate().unwrap();
            let text = json::to_string(&sc.to_json());
            let back = Scenario::from_json(&json::parse(&text).unwrap())
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(back, sc, "{name} changed across a JSON round trip");
            assert_eq!(back.config_hash(), sc.config_hash());
        }
    }

    #[test]
    fn builtin_lookup_is_total_over_names_and_rejects_unknown() {
        for name in BUILTIN_NAMES {
            assert!(builtin(name).is_some(), "{name}");
        }
        assert!(builtin("nope").is_none());
    }

    #[test]
    fn config_hash_is_sensitive_to_every_field_it_claims_to_cover() {
        let base = builtin("steady_state").unwrap();
        let mut v = base.clone();
        v.seed = 8;
        assert_ne!(v.config_hash(), base.config_hash());
        let mut v = base.clone();
        v.arrivals[0].rate_rps = 251.0;
        assert_ne!(v.config_hash(), base.config_hash());
        let mut v = base.clone();
        v.deployment.max_batch = 8;
        assert_ne!(v.config_hash(), base.config_hash());
    }

    #[test]
    fn malformed_arrival_specs_are_rejected() {
        let mut sc = builtin("steady_state").unwrap();
        sc.arrivals.clear();
        assert!(sc.validate().unwrap_err().to_string().contains("no arrival phases"));

        let mut sc = builtin("steady_state").unwrap();
        sc.arrivals[0].rate_rps = 0.0;
        assert!(sc.validate().unwrap_err().to_string().contains("rate_rps"));

        let mut sc = builtin("steady_state").unwrap();
        sc.arrivals[0].rate_rps = f64::NAN;
        assert!(sc.validate().is_err());

        let mut sc = builtin("steady_state").unwrap();
        sc.arrivals[0].dur_s = -1.0;
        assert!(sc.validate().unwrap_err().to_string().contains("dur_s"));

        let mut sc = builtin("incast_burst").unwrap();
        sc.arrivals[0].process = ArrivalProcess::Burst { size: 0 };
        assert!(sc.validate().unwrap_err().to_string().contains("burst_size"));

        // unknown process tag fails at parse time
        let text = r#"{"name":"x","duration_s":1,"seed":0,"tick_ms":50,"interval_ms":500,
            "arrivals":[{"dur_s":1,"rate_rps":10,"process":"zipf"}],
            "batch_mix":[{"size":1,"weight":1}],
            "deployment":{"backend":"stub","workers":1,"max_batch":4,"max_wait_ms":2},
            "qos":{"source":"constant","budget":1.0},"events":[]}"#;
        let err = Scenario::from_json(&json::parse(text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("zipf"), "{err:#}");
    }

    #[test]
    fn semantic_cross_field_rules_are_enforced() {
        // budget events need a constant source
        let mut sc = builtin("ladder_thrash").unwrap();
        sc.qos.source = QosSource::Trace("sine".into());
        assert!(sc.validate().unwrap_err().to_string().contains("constant"));

        // env events need the env source
        let mut sc = builtin("diurnal_ramp").unwrap();
        sc.qos.source = QosSource::Constant(1.0);
        assert!(sc.validate().unwrap_err().to_string().contains("env"));

        // fleet workers imply the stub backend
        let mut sc = builtin("heterogeneous_fleet").unwrap();
        sc.deployment.backend = BackendKind::Native;
        assert!(sc.validate().unwrap_err().to_string().contains("stub"));

        // set_op must stay inside the bench ladder
        let mut sc = builtin("steady_state").unwrap();
        sc.events.push(Event { at_s: 1.0, kind: EventKind::SetOp { op: 9, drain: false } });
        assert!(sc.validate().unwrap_err().to_string().contains("out of range"));

        // snapshot interval must tile into ticks
        let mut sc = builtin("steady_state").unwrap();
        sc.interval_ms = 75;
        assert!(sc.validate().unwrap_err().to_string().contains("multiple"));

        // tariff windows are env events with bounded scale
        let mut sc = builtin("slo_pressure").unwrap();
        sc.qos.source = QosSource::Constant(1.0);
        assert!(sc.validate().unwrap_err().to_string().contains("env"));
        let mut sc = builtin("slo_pressure").unwrap();
        sc.events[0].kind = EventKind::TariffWindow { scale: 1.5, secs: 5.0 };
        assert!(sc.validate().unwrap_err().to_string().contains("tariff scale"));
        let mut sc = builtin("slo_pressure").unwrap();
        sc.events[0].kind = EventKind::TariffWindow { scale: 0.9, secs: 0.0 };
        assert!(sc.validate().unwrap_err().to_string().contains("tariff secs"));

        // the power envelope is only meaningful with an SLO
        let mut sc = builtin("steady_state").unwrap();
        sc.power_envelope = Some(0.8);
        assert!(sc.validate().unwrap_err().to_string().contains("slo_p95_ms"));
        let mut sc = builtin("slo_pressure").unwrap();
        sc.slo_p95_ms = Some(0.0);
        assert!(sc.validate().unwrap_err().to_string().contains("slo_p95_ms"));

        // op_delay_scaling needs the in-process stub
        let mut sc = builtin("slo_pressure").unwrap();
        sc.deployment.backend = BackendKind::Native;
        assert!(sc.validate().unwrap_err().to_string().contains("op_delay_scaling"));

        // supervisor cadence knobs need an elastic pool
        let mut sc = builtin("steady_state").unwrap();
        sc.deployment.scale_interval_ms = 10;
        assert!(sc.validate().unwrap_err().to_string().contains("elastic"));

        // the reprobe cadence knob is fleet-only
        let mut sc = builtin("steady_state").unwrap();
        sc.deployment.reprobe_interval_ms = 200;
        assert!(sc.validate().unwrap_err().to_string().contains("fleet"));
    }

    #[test]
    fn tenant_sections_validate_premium_first_ordering_and_shapes() {
        let sc = builtin("tenant_contention").unwrap();
        assert_eq!(sc.tenants.len(), 2);
        assert_eq!(sc.tenants[0].name, "premium");
        assert!(sc.tenants[0].priority <= sc.tenants[1].priority);

        // classes must be listed premium-first
        let mut bad = sc.clone();
        bad.tenants.swap(0, 1);
        assert!(bad.validate().unwrap_err().to_string().contains("premium-first"));

        // duplicate names are rejected
        let mut bad = sc.clone();
        bad.tenants[1].name = "premium".into();
        assert!(bad.validate().unwrap_err().to_string().contains("duplicate"));

        // tenants ride the autopilot, so the scenario SLO is required
        let mut bad = sc.clone();
        bad.slo_p95_ms = None;
        assert!(bad.validate().unwrap_err().to_string().contains("slo_p95_ms"));

        // shares and weights must be positive
        let mut bad = sc.clone();
        bad.tenants[1].share = 0.0;
        assert!(bad.validate().unwrap_err().to_string().contains("share"));
    }

    #[test]
    fn new_optional_fields_are_omitted_when_unset() {
        // committed config_hashes from before the autopilot PR must
        // survive: a scenario not using the new knobs serializes to
        // JSON that never mentions them
        let text = json::to_string(&builtin("steady_state").unwrap().to_json());
        for key in [
            "slo_p95_ms",
            "power_envelope",
            "op_delay_scaling",
            "scale_interval_ms",
            "scale_up_after",
            "scale_down_after",
            "reprobe_interval_ms",
            "tenants",
        ] {
            assert!(!text.contains(key), "steady_state JSON should omit {key}: {text}");
        }
        // and a scenario that does use them round-trips exactly
        let mut sc = builtin("slo_pressure").unwrap();
        sc.power_envelope = Some(0.9);
        sc.deployment.max_workers = 4;
        sc.deployment.min_workers = 1;
        sc.deployment.workers = 1;
        sc.deployment.scale_interval_ms = 10;
        sc.deployment.scale_up_after = 1;
        sc.deployment.scale_down_after = 5;
        let back =
            Scenario::from_json(&json::parse(&json::to_string(&sc.to_json())).unwrap()).unwrap();
        assert_eq!(back, sc);
        assert_eq!(back.config_hash(), sc.config_hash());
    }
}
