//! The bench run loop: spin up the deployment a scenario describes,
//! replay its materialized arrival trace open-loop against the elastic
//! server, walk the OP ladder from the scenario's budget source, and
//! condense everything observed into a [`BenchReport`].
//!
//! One generic loop ([`run_on`]) serves every deployment shape — the
//! native synthetic model, the delayed stub, and a loopback fleet of
//! stub workers — exactly like the `serve` command's `drive`, so the
//! harness measures the same code paths production serving uses.
//!
//! Scenarios that declare an `slo_p95_ms` target engage the
//! [`Autopilot`]: the run happens twice on the same seed (uncontrolled
//! baseline first, then closed-loop), and the report's `autopilot`
//! section carries both trajectories plus the per-tick decision log.

use std::collections::VecDeque;
use std::net::TcpListener;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::autopilot::{
    Autopilot, AutopilotConfig, ChunkAction, Decision, MultiAutopilot, OpAction, PoolAction,
    TickInputs,
};
use crate::backend::{Backend, NativeBackend, OpTable, StubBackend};
use crate::bench::arrivals::{self, Arrival};
use crate::bench::dashboard::Dashboard;
use crate::bench::report::{
    AutopilotBaseline, AutopilotReport, BenchReport, FleetReport, FleetWorkerReport, Interval,
    OpReport, Provenance, Scaling, SwitchRecord, Switches, TenantReport, Throughput,
    REPORT_VERSION,
};
use crate::bench::scenario::{BackendKind, EventKind, QosSource, Scenario, TenantSpec};
use crate::bench::synthetic;
use crate::fleet::worker::{self, WorkerHandle, WorkerOptions};
use crate::fleet::{FleetBackend, FleetStats};
use crate::obs::{self, metrics::{CollectFn, Kind, MetricFamily, Sample}, MetricsServer, ObsEvent};
use crate::qos::envsim::{EnvConfig, EnvEvent, EnvSimulator};
use crate::qos::{budget_trace, QosConfig, QosController, SwitchMode};
use crate::server::{BatcherConfig, Server};
use crate::util::rng::Rng;
use crate::util::stats::LatencyHistogram;

/// CLI-level overrides for one bench run.
#[derive(Debug, Clone, Default)]
pub struct BenchOpts {
    /// Replaces the scenario's seed (recorded in provenance).
    pub seed: Option<u64>,
    /// Replaces the scenario's duration (arrival phases cycle).
    pub secs: Option<f64>,
    /// Render the live ANSI dashboard while running.
    pub dashboard: bool,
    /// Force the autopilot on/off; `None` = on iff the scenario
    /// declares `slo_p95_ms`.
    pub autopilot: Option<bool>,
    /// Serve the Prometheus text endpoint here for the whole run
    /// (both passes of an autopilot pairing share the listener).
    pub metrics_addr: Option<String>,
}

/// Whether one pass actuates the autopilot or only observes the SLO.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ApMode {
    /// Plain QoS walk; when the scenario has an SLO the p95 trajectory
    /// is still tracked and reported (the "autopilot off" baseline).
    Observe,
    /// The autopilot owns OP, pool and chunk-plan decisions.
    Autopilot,
}

/// Where each tick's power budget comes from at run time.
enum BudgetSource {
    Constant(f64),
    /// Pre-sampled synthetic trace, one value per tick.
    Trace(Vec<f64>),
    /// Live simulator advanced `scale` sim-seconds per wall-second.
    Env(Box<EnvSimulator>, f64),
}

impl BudgetSource {
    fn build(sc: &Scenario, seed: u64, total_ticks: usize) -> BudgetSource {
        match &sc.qos.source {
            QosSource::Constant(b) => BudgetSource::Constant(*b),
            QosSource::Trace(kind) => BudgetSource::Trace(budget_trace(kind, total_ticks, seed)),
            QosSource::Env => {
                let sim = EnvSimulator::new(EnvConfig { seed, ..EnvConfig::default() });
                BudgetSource::Env(Box::new(sim), sc.qos.env_time_scale)
            }
        }
    }

    /// The budget for tick `i`; `power_frac` is the relative power of
    /// the OP currently in force (drains the simulated battery).
    fn sample(&mut self, i: usize, tick_s: f64, power_frac: f64) -> f64 {
        match self {
            BudgetSource::Constant(b) => *b,
            BudgetSource::Trace(v) => v[i.min(v.len() - 1)],
            BudgetSource::Env(sim, scale) => sim.step(tick_s * *scale, power_frac),
        }
    }
}

/// Fleet control plane + spawned loopback workers (teardown handle).
struct FleetRig {
    control: FleetBackend,
    stats: FleetStats,
    handles: Vec<WorkerHandle>,
}

/// Everything [`run_on`] needs besides the server itself.
struct RunCtx<'a> {
    sc: &'a Scenario,
    seed: u64,
    duration_s: f64,
    dashboard: bool,
    mode: ApMode,
    pool: Vec<f32>,
    elems: usize,
}

/// Sliding-window p95 bookkeeping for scenarios with an SLO: a ring of
/// cumulative latency histograms (one per tick) differenced against the
/// oldest entry, so the p95 the controller sees covers roughly the last
/// reporting interval rather than the whole run.
struct SloTracker {
    slo_ms: f64,
    min_window: u64,
    window_ticks: usize,
    hist: VecDeque<LatencyHistogram>,
    violation_ticks: u64,
    first_violation_t_s: Option<f64>,
    p95_timeline: Vec<(f64, f64)>,
}

impl SloTracker {
    fn new(cfg: &AutopilotConfig, window_ticks: usize) -> SloTracker {
        SloTracker {
            slo_ms: cfg.slo_p95_ms,
            min_window: cfg.min_window,
            window_ticks: window_ticks.max(1),
            hist: VecDeque::new(),
            violation_ticks: 0,
            first_violation_t_s: None,
            p95_timeline: Vec::new(),
        }
    }

    /// Fold in this tick's cumulative histogram; returns the windowed
    /// `(p95_ms, samples, violated)` triple.
    fn observe(&mut self, cur: LatencyHistogram, t_s: f64) -> (f64, u64, bool) {
        let win = match self.hist.front() {
            Some(earlier) => cur.since(earlier),
            None => cur.clone(),
        };
        self.hist.push_back(cur);
        if self.hist.len() > self.window_ticks {
            self.hist.pop_front();
        }
        let p95_ms = win.percentile_us(95.0) as f64 / 1000.0;
        let window = win.count();
        self.p95_timeline.push((t_s, p95_ms));
        let violated = window >= self.min_window && p95_ms > self.slo_ms;
        if violated {
            self.violation_ticks += 1;
            if self.first_violation_t_s.is_none() {
                self.first_violation_t_s = Some(t_s);
            }
        }
        (p95_ms, window, violated)
    }
}

/// Execute one scenario end to end and return its report.
///
/// With the autopilot engaged (explicit `--autopilot on`, or by default
/// whenever the scenario declares `slo_p95_ms`), the scenario runs
/// twice on the same seed — uncontrolled first, then closed-loop — and
/// the uncontrolled p95 timeline lands in `autopilot.baseline` so one
/// report carries both trajectories.
pub fn run_scenario(sc: &Scenario, opts: &BenchOpts) -> Result<BenchReport> {
    sc.validate()?;
    // one listener outlives both passes of an autopilot pairing; the
    // per-pass collectors re-register under the same ids, so a scrape
    // always reflects the pass currently running
    let _metrics = match opts.metrics_addr.as_deref() {
        Some(addr) => {
            let srv = MetricsServer::start(addr, None).context("bench metrics endpoint")?;
            obs::log!(Info, "metrics endpoint on http://{}/metrics", srv.local_addr());
            Some(srv)
        }
        None => None,
    };
    let autopilot_on = match opts.autopilot {
        Some(on) => {
            anyhow::ensure!(
                !on || sc.slo_p95_ms.is_some(),
                "--autopilot on requires a scenario that declares `slo_p95_ms`"
            );
            on
        }
        None => sc.slo_p95_ms.is_some(),
    };
    if !autopilot_on {
        return run_once(sc, opts, ApMode::Observe);
    }
    let base = run_once(sc, opts, ApMode::Observe)?;
    let mut report = run_once(sc, opts, ApMode::Autopilot)?;
    if let Some(ap) = report.autopilot.as_mut() {
        ap.baseline = base.autopilot.and_then(|b| b.baseline);
    }
    Ok(report)
}

/// One pass over the scenario: build the deployment, run the loop.
fn run_once(sc: &Scenario, opts: &BenchOpts, mode: ApMode) -> Result<BenchReport> {
    let seed = opts.seed.unwrap_or(sc.seed);
    let duration_s = opts.secs.unwrap_or(sc.duration_s);
    anyhow::ensure!(
        duration_s.is_finite() && duration_s > 0.0,
        "bench duration must be finite and > 0"
    );
    let cfg = batcher_config(sc, tenanted(sc, mode));

    match sc.deployment.backend {
        BackendKind::Native => {
            let (graph, db, ops) = synthetic::native_ladder();
            let (pool, elems) = synthetic::native_image_pool(seed);
            let server = Server::start(
                move |_w| Ok(NativeBackend::new(graph.clone(), db.clone())),
                OpTable::new(ops),
                cfg,
            )?;
            let ctx = RunCtx { sc, seed, duration_s, dashboard: opts.dashboard, mode, pool, elems };
            run_on(ctx, server, None)
        }
        BackendKind::Stub if sc.deployment.fleet.is_empty() => {
            let delay = Duration::from_micros(sc.deployment.stub_delay_us);
            let scaled = sc.deployment.op_delay_scaling;
            let (pool, elems) = synthetic::stub_image_pool();
            let server = Server::start(
                move |_w| {
                    let be = StubBackend::new(synthetic::STUB_CLASSES).with_delay(delay);
                    Ok(if scaled { be.with_op_delay_scaling() } else { be })
                },
                OpTable::new(synthetic::stub_ladder()),
                cfg,
            )?;
            let ctx = RunCtx { sc, seed, duration_s, dashboard: opts.dashboard, mode, pool, elems };
            run_on(ctx, server, None)
        }
        BackendKind::Stub => {
            let rig_ops = synthetic::stub_ladder();
            let mut handles = Vec::new();
            let mut addrs = Vec::new();
            for (i, w) in sc.deployment.fleet.iter().enumerate() {
                let listener =
                    TcpListener::bind("127.0.0.1:0").context("binding loopback fleet worker")?;
                addrs.push(listener.local_addr()?.to_string());
                let delay = Duration::from_micros(w.delay_us);
                let wopts = WorkerOptions::new(format!("bench-w{i}"), "").heartbeat(
                    Duration::from_millis(w.hb_interval_ms),
                    Duration::from_millis(w.hb_timeout_ms),
                );
                handles.push(worker::spawn_with(listener, wopts, rig_ops.clone(), move |_c| {
                    Ok(StubBackend::new(synthetic::STUB_CLASSES).with_delay(delay))
                })?);
            }
            let stats = FleetStats::default();
            // scenario pipeline knob: 0 = library default / env override,
            // otherwise pin the in-flight window so recorded runs don't
            // depend on the environment
            let pipeline = sc.deployment.pipeline;
            let window = |be: FleetBackend| {
                if pipeline > 0 {
                    be.with_pipeline_window(pipeline)
                } else {
                    be
                }
            };
            let control = window(FleetBackend::connect_with(&addrs, stats.clone())?);
            let st = stats.clone();
            let server = Server::start(
                move |_w| {
                    let be = FleetBackend::connect_with(&addrs, st.clone())?;
                    Ok(if pipeline > 0 {
                        be.with_pipeline_window(pipeline)
                    } else {
                        be
                    })
                },
                OpTable::new(rig_ops),
                cfg,
            )?;
            let (pool, elems) = synthetic::stub_image_pool();
            let ctx = RunCtx { sc, seed, duration_s, dashboard: opts.dashboard, mode, pool, elems };
            run_on(ctx, server, Some(FleetRig { control, stats, handles }))
        }
    }
}

/// Whether this pass splits the traffic into tenant classes: only the
/// closed-loop pass of a multi-tenant scenario.  The baseline pass runs
/// classless on the identical seed so the committed report's tenant
/// numbers compare against exactly the trajectory tenancy replaced,
/// and single-tenant scenarios never leave the classic path.
fn tenanted(sc: &Scenario, mode: ApMode) -> bool {
    mode == ApMode::Autopilot && sc.tenants.len() >= 2
}

fn batcher_config(sc: &Scenario, tenanted: bool) -> BatcherConfig {
    let d = &sc.deployment;
    let mut cfg = BatcherConfig {
        max_batch: d.max_batch,
        max_wait: Duration::from_millis(d.max_wait_ms),
        workers: d.workers,
        min_workers: d.min_workers,
        max_workers: d.max_workers,
        retag_downgrades: d.retag_downgrades,
        ..BatcherConfig::default()
    };
    // supervisor cadence knobs: 0 keeps the library default
    if d.scale_interval_ms > 0 {
        cfg.scale_interval = Duration::from_millis(d.scale_interval_ms);
    }
    if d.scale_up_after > 0 {
        cfg.scale_up_after = d.scale_up_after;
    }
    if d.scale_down_after > 0 {
        cfg.scale_down_after = d.scale_down_after;
    }
    if tenanted {
        cfg.classes = sc.tenants.len();
        cfg.class_names = sc.tenants.iter().map(|t| t.name.clone()).collect();
    }
    cfg
}

/// The measurement loop, written once for every backend.
fn run_on<B: Backend + 'static>(
    ctx: RunCtx<'_>,
    server: Server<B>,
    mut fleet: Option<FleetRig>,
) -> Result<BenchReport> {
    let sc = ctx.sc;
    let trace = arrivals::generate(sc, ctx.duration_s, ctx.seed, synthetic::POOL_IMAGES as u32);
    let tick = Duration::from_millis(sc.tick_ms);
    let tick_s = sc.tick_ms as f64 / 1000.0;
    let total_ticks = (ctx.duration_s * 1000.0 / sc.tick_ms as f64).ceil() as usize;
    let ticks_per_interval = (sc.interval_ms / sc.tick_ms) as usize;

    let mut controller = QosController::new(
        server.op_table().ladder(),
        QosConfig {
            upgrade_margin: sc.qos.upgrade_margin,
            min_dwell: Duration::from_millis(sc.qos.min_dwell_ms),
        },
    );
    let mut source = BudgetSource::build(sc, ctx.seed, total_ticks);
    let powers: Vec<f64> = server.ops().iter().map(|o| o.relative_power).collect();
    let op_names: Vec<String> = server.ops().iter().map(|o| o.name.clone()).collect();

    // hand this pass's sources to the process-wide registry in one
    // atomic rotation: event counters restart from zero *and* the
    // server/fleet/bench collectors replace the previous pass's by id
    // under the same critical section, so a live scrape (and the
    // dashboard, which reads the same registry) sees the previous pass
    // or this one — never stale per-OP families over zeroed counters
    let registry = obs::registry();
    let gauges = Arc::new(Mutex::new(BenchGauges::default()));
    let mut sources: Vec<(String, CollectFn)> =
        vec![("server".into(), Box::new(server.metrics_collector()))];
    if let Some(rig) = fleet.as_ref() {
        sources.push(("fleet".into(), Box::new(rig.stats.metrics_collector())));
    } else {
        registry.unregister("fleet");
    }
    {
        let g = Arc::clone(&gauges);
        let powers = powers.clone();
        let envelope = sc.power_envelope.unwrap_or(1.0);
        sources.push((
            "bench".into(),
            Box::new(move || bench_families(&g.lock().unwrap(), &powers, envelope)),
        ));
    }
    registry.rotate_collectors(sources);

    // SLO tracking runs whenever the scenario declares a p95 target;
    // the autopilot itself actuates only in `ApMode::Autopilot`.
    let slo_cfg = sc.slo_p95_ms.map(|slo| AutopilotConfig {
        slo_p95_ms: slo,
        power_envelope: sc.power_envelope.unwrap_or(1.0),
        // express the time-based defaults in this scenario's tick units
        recover_after: (1000 / sc.tick_ms).max(1) as u32,
        pool_recover_after: (2500 / sc.tick_ms).max(1) as u32,
        cooldown_ticks: (200 / sc.tick_ms).max(1) as u32,
        ..AutopilotConfig::default()
    });
    let mut tracker = slo_cfg.as_ref().map(|cfg| SloTracker::new(cfg, ticks_per_interval));
    let run_tenanted = tenanted(sc, ctx.mode);
    let mut pilot = match (&slo_cfg, ctx.mode) {
        (Some(cfg), ApMode::Autopilot) if !run_tenanted => Some(Autopilot::new(
            server.op_table().ladder(),
            QosConfig {
                upgrade_margin: sc.qos.upgrade_margin,
                min_dwell: Duration::from_millis(sc.qos.min_dwell_ms),
            },
            cfg.clone(),
        )),
        _ => None,
    };
    // multi-tenant closed loop: one pilot and one sliding p95 window
    // per class, steering per-class rungs under the shared envelope
    // with strict priority (premium first, so it sheds last)
    let mut class_trackers: Vec<SloTracker> = Vec::new();
    let mut multi = if run_tenanted {
        let base = slo_cfg.clone().expect("tenants require slo_p95_ms (scenario validation)");
        let mut pilots = Vec::with_capacity(sc.tenants.len());
        for t in &sc.tenants {
            let cfg = AutopilotConfig { slo_p95_ms: t.slo_p95_ms, ..base.clone() };
            class_trackers.push(SloTracker::new(&cfg, ticks_per_interval));
            pilots.push(
                Autopilot::new(
                    server.op_table().ladder(),
                    QosConfig {
                        upgrade_margin: sc.qos.upgrade_margin,
                        min_dwell: Duration::from_millis(sc.qos.min_dwell_ms),
                    },
                    cfg,
                )
                .with_class(t.name.clone()),
            );
        }
        let weights = sc.tenants.iter().map(|t| t.weight).collect();
        Some(MultiAutopilot::new(pilots, weights))
    } else {
        None
    };
    // class picks draw from their own stream so the arrival trace (and
    // with it `trace_hash`) is untouched by tenancy
    let mut class_rng = Rng::new(ctx.seed ^ 0x7e4a_9c1d_5b3f_2081);
    // effective pool bounds the autopilot may steer within (mirrors the
    // BatcherConfig normalization: 0 floor = "same as workers")
    let (pool_min, pool_max) = if sc.deployment.max_workers > 0 {
        let floor = if sc.deployment.min_workers > 0 {
            sc.deployment.min_workers
        } else {
            sc.deployment.workers
        };
        (floor, sc.deployment.max_workers)
    } else {
        (sc.deployment.workers, sc.deployment.workers)
    };
    let mut decisions: Vec<Decision> = Vec::new();

    // scripted events, time-sorted, consumed front to back
    let mut events = sc.events.clone();
    events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
    let mut next_event = 0usize;

    let mut timeline: Vec<SwitchRecord> = Vec::new();
    let mut receivers = Vec::new();
    let mut intervals: Vec<Interval> = Vec::new();
    let mut dash = Dashboard::new();
    let mut submitted = 0u64;
    let mut next_arrival = 0usize;
    let mut last_completed = 0u64;
    let mut budget = 1.0f64;
    // loopback-fleet re-probe cadence in ticks (the scenario knob
    // mirroring serve's --reprobe-interval-ms); 0 = never, matching a
    // serve loop that left the flag unset
    let reprobe_every = if sc.deployment.reprobe_interval_ms > 0 {
        (sc.deployment.reprobe_interval_ms / sc.tick_ms).max(1) as usize
    } else {
        0
    };
    let started = Instant::now();

    for i in 0..total_ticks {
        let t_s = i as f64 * tick_s;

        // 1. scripted events due this tick
        while next_event < events.len() && events[next_event].at_s <= t_s {
            match events[next_event].kind {
                EventKind::Budget(b) => {
                    if let BudgetSource::Constant(cur) = &mut source {
                        *cur = b;
                    }
                }
                EventKind::SetOp { op, drain } => {
                    let mode = if drain { SwitchMode::Drain } else { SwitchMode::Immediate };
                    if let Some(rig) = fleet.as_mut() {
                        rig.control.set_operating_point(op, mode)?;
                    }
                    server.set_operating_point_with(op, mode)?;
                    obs::publish(ObsEvent::OpSwitch {
                        op,
                        mode: mode_tag(mode).to_string(),
                        trigger: "scripted".to_string(),
                        class: None,
                    });
                    timeline.push(SwitchRecord {
                        t_s,
                        op,
                        mode: mode_tag(mode).to_string(),
                        forced: true,
                    });
                }
                EventKind::BatteryDrop(delta) => {
                    apply_env(&mut source, EnvEvent::BatteryDrop { delta })
                }
                EventKind::ThermalSpike(delta_c) => {
                    apply_env(&mut source, EnvEvent::ThermalSpike { delta_c })
                }
                EventKind::HarvestScale(factor) => {
                    apply_env(&mut source, EnvEvent::HarvestScale { factor })
                }
                EventKind::TariffWindow { scale, secs } => {
                    apply_env(&mut source, EnvEvent::TariffWindow { scale, secs })
                }
            }
            next_event += 1;
        }

        // 2. budget sample + control walk (fleet hears first, so a
        //    drained upgrade is acked fleet-wide before the local flip)
        budget = source.sample(i, tick_s, powers[server.operating_point()]);
        let now = Instant::now();
        if let Some(mp) = multi.as_mut() {
            let m = server.metrics();
            // the scenario-level tracker keeps observing the aggregate
            // stream, so the report's headline trajectory stays
            // comparable with the classless baseline pass
            if let Some(tr) = tracker.as_mut() {
                tr.observe(m.latency.clone(), t_s);
            }
            let mut inputs = Vec::with_capacity(mp.len());
            let mut violated = Vec::with_capacity(mp.len());
            for (c, tr) in class_trackers.iter_mut().enumerate() {
                let (p95_ms, window, v) = tr.observe(m.per_class[c].latency.clone(), t_s);
                violated.push(v);
                inputs.push(TickInputs {
                    t_s,
                    p95_ms,
                    window,
                    env_budget: budget,
                    live_workers: server.live_workers(),
                    min_workers: pool_min,
                    max_workers: pool_max,
                    has_fleet: fleet.is_some(),
                });
            }
            for (c, out) in mp.tick(&inputs, now).into_iter().enumerate() {
                if let Some((idx, mode)) = out.switch {
                    if let Some(rig) = fleet.as_mut() {
                        rig.control.set_operating_point_class(Some(c), idx, mode)?;
                    }
                    server.set_class_operating_point_with(c, idx, mode)?;
                    obs::publish(ObsEvent::OpSwitch {
                        op: idx,
                        mode: mode_tag(mode).to_string(),
                        trigger: "autopilot".to_string(),
                        class: Some(sc.tenants[c].name.clone()),
                    });
                    timeline.push(SwitchRecord {
                        t_s,
                        op: idx,
                        mode: mode_tag(mode).to_string(),
                        forced: false,
                    });
                }
                // the pool and the fleet chunk plan are deployment-wide
                // levers: the premium pilot owns them, so capacity is
                // never grown or narrowed on a best-effort whim
                if c == 0 {
                    if let Some(target) = out.pool_target {
                        server.set_pool_target(target);
                    }
                    if let Some(q) = out.chunk_quantum_us {
                        if let Some(rig) = fleet.as_mut() {
                            rig.stats.set_chunk_quantum_us(q);
                        }
                    }
                }
                let d = out.decision;
                let acted = out.switch.is_some()
                    || d.op_action != OpAction::None
                    || d.pool_action != PoolAction::None
                    || d.chunk_action != ChunkAction::None;
                if acted || violated[c] || (i + 1) % ticks_per_interval == 0 {
                    decisions.push(d);
                }
            }
        } else if let Some(ap) = pilot.as_mut() {
            let tr = tracker.as_mut().expect("autopilot implies an SLO tracker");
            let (p95_ms, window, violated) = tr.observe(server.metrics().latency, t_s);
            let out = ap.tick(
                &TickInputs {
                    t_s,
                    p95_ms,
                    window,
                    env_budget: budget,
                    live_workers: server.live_workers(),
                    min_workers: pool_min,
                    max_workers: pool_max,
                    has_fleet: fleet.is_some(),
                },
                now,
            );
            if let Some((idx, mode)) = out.switch {
                if let Some(rig) = fleet.as_mut() {
                    rig.control.set_operating_point(idx, mode)?;
                }
                server.set_operating_point_with(idx, mode)?;
                obs::publish(ObsEvent::OpSwitch {
                    op: idx,
                    mode: mode_tag(mode).to_string(),
                    trigger: "autopilot".to_string(),
                    class: None,
                });
                timeline.push(SwitchRecord {
                    t_s,
                    op: idx,
                    mode: mode_tag(mode).to_string(),
                    forced: false,
                });
            }
            if let Some(target) = out.pool_target {
                server.set_pool_target(target);
            }
            if let Some(q) = out.chunk_quantum_us {
                if let Some(rig) = fleet.as_mut() {
                    rig.stats.set_chunk_quantum_us(q);
                }
            }
            let d = out.decision;
            let acted = out.switch.is_some()
                || d.op_action != OpAction::None
                || d.pool_action != PoolAction::None
                || d.chunk_action != ChunkAction::None;
            // keep the committed log small: action ticks, SLO-violation
            // ticks, and one heartbeat per reporting interval
            if acted || violated || (i + 1) % ticks_per_interval == 0 {
                decisions.push(d);
            }
        } else {
            if let Some((idx, mode)) = controller.observe_with_mode(budget, now) {
                if let Some(rig) = fleet.as_mut() {
                    rig.control.set_operating_point(idx, mode)?;
                }
                server.set_operating_point_with(idx, mode)?;
                obs::publish(ObsEvent::OpSwitch {
                    op: idx,
                    mode: mode_tag(mode).to_string(),
                    trigger: "budget".to_string(),
                    class: None,
                });
                timeline.push(SwitchRecord {
                    t_s,
                    op: idx,
                    mode: mode_tag(mode).to_string(),
                    forced: false,
                });
            }
            if let Some(tr) = tracker.as_mut() {
                tr.observe(server.metrics().latency, t_s);
            }
        }

        // 2b. scheduled re-probe of disconnected fleet peers (a no-op
        //     while every worker is healthy)
        if reprobe_every > 0 && (i + 1) % reprobe_every == 0 {
            if let Some(rig) = fleet.as_mut() {
                rig.control.reprobe();
            }
        }

        // 3. replay arrivals due before this tick's deadline
        let deadline = started + tick * (i as u32 + 1);
        loop {
            let now = Instant::now();
            let elapsed_us = now.duration_since(started).as_micros() as u64;
            while next_arrival < trace.len() && trace[next_arrival].at_us <= elapsed_us {
                let a: Arrival = trace[next_arrival];
                let at = a.image as usize * ctx.elems;
                let img = &ctx.pool[at..at + ctx.elems];
                for _ in 0..a.count {
                    if run_tenanted {
                        let c = pick_tenant(&sc.tenants, &mut class_rng);
                        if let Some(rx) = server.submit_class(c, img.to_vec())? {
                            receivers.push(rx);
                        }
                    } else {
                        receivers.push(server.submit(img.to_vec())?);
                    }
                    submitted += 1;
                }
                next_arrival += 1;
            }
            if now >= deadline {
                break;
            }
            let mut sleep = deadline - now;
            if next_arrival < trace.len() {
                let next_at = started + Duration::from_micros(trace[next_arrival].at_us);
                if next_at <= now {
                    continue; // more arrivals already due
                }
                sleep = sleep.min(next_at - now);
            }
            std::thread::sleep(sleep.min(Duration::from_millis(5)));
        }

        // refresh the bench-owned gauges once per tick so concurrent
        // scrapes see the budget/OP the loop is actually running under
        {
            let mut g = gauges.lock().unwrap();
            g.op = server.operating_point();
            g.budget = budget;
            g.submitted = submitted;
        }

        // 4. interval snapshot
        if (i + 1) % ticks_per_interval == 0 || i + 1 == total_ticks {
            let m = server.metrics();
            let interval_s = if (i + 1) % ticks_per_interval == 0 {
                ticks_per_interval as f64 * tick_s
            } else {
                ((i + 1) % ticks_per_interval) as f64 * tick_s
            };
            let snap = Interval {
                t_s: (i + 1) as f64 * tick_s,
                img_per_s: (m.completed - last_completed) as f64 / interval_s,
                submitted,
                completed: m.completed,
                inflight: server.inflight(),
                workers: server.live_workers(),
                op: server.operating_point(),
                budget,
                p99_us: m.latency.percentile_us(99.0),
            };
            last_completed = m.completed;
            let snap_t_s = snap.t_s;
            let snap_op = snap.op;
            intervals.push(snap);
            if ctx.dashboard {
                dash.observe(registry, &sc.name, snap_t_s, &op_names[snap_op]);
            }
        }
    }

    // drain: wait for every outstanding response
    let mut ok = 0u64;
    for rx in receivers {
        if rx.recv_timeout(Duration::from_secs(30)).is_ok() {
            ok += 1;
        }
    }
    if ctx.dashboard {
        dash.finish();
    }
    let wall = started.elapsed().as_secs_f64();
    let final_workers = server.live_workers();
    let m = server.shutdown().snapshot();

    let fleet_report = if let Some(mut rig) = fleet.take() {
        rig.control.shutdown_fleet();
        let (workers, requeues, evictions) = rig.stats.snapshot();
        for h in rig.handles {
            h.join();
        }
        let workers = workers
            .into_iter()
            .map(|(addr, w)| FleetWorkerReport {
                addr,
                requests: w.requests,
                batches: w.batches,
                errors: w.errors,
                mean_latency_us: w.mean_latency_us(),
                evicted: w.evicted,
                reprobes: w.reprobes,
            })
            .collect();
        Some(FleetReport { requeues, evictions, workers })
    } else {
        None
    };

    let per_op = m
        .per_op
        .iter()
        .enumerate()
        .map(|(i, o)| OpReport {
            index: i,
            name: op_names[i].clone(),
            power: powers[i],
            requests: o.requests,
            latency: o.latency,
        })
        .collect();
    let drain = timeline.iter().filter(|r| r.mode == "drain").count() as u64;
    let forced = timeline.iter().filter(|r| r.forced).count() as u64;
    let budget_violations = if let Some(mp) = multi.as_ref() {
        mp.pilots().iter().map(|p| p.controller().budget_violations).sum()
    } else {
        pilot
            .as_ref()
            .map(|p| p.controller().budget_violations)
            .unwrap_or(controller.budget_violations)
    };
    let autopilot = match (slo_cfg, tracker) {
        (Some(apcfg), Some(tr)) => {
            let first_downgrade_t_s = decisions
                .iter()
                .find(|d| d.op_action == OpAction::Down)
                .map(|d| d.t_s);
            let mut rep = AutopilotReport {
                slo_p95_ms: apcfg.slo_p95_ms,
                power_envelope: apcfg.power_envelope,
                slo_violation_ticks: tr.violation_ticks,
                first_violation_t_s: tr.first_violation_t_s,
                first_downgrade_t_s,
                decisions,
                baseline: None,
            };
            if ctx.mode == ApMode::Observe {
                // an uncontrolled pass doubles as its own baseline, so
                // a standalone `--autopilot off` run still records the
                // trajectory; the paired run lifts this into the
                // closed-loop report
                rep.baseline = Some(AutopilotBaseline {
                    slo_violation_ticks: tr.violation_ticks,
                    first_violation_t_s: tr.first_violation_t_s,
                    p95_timeline: tr.p95_timeline,
                });
            }
            Some(rep)
        }
        _ => None,
    };
    // per-class slice of the run: serving counters from the batcher's
    // class metrics, steering counters from each class's pilot/window
    let tenants = multi.as_ref().map(|mp| {
        sc.tenants
            .iter()
            .enumerate()
            .map(|(c, t)| TenantReport {
                name: t.name.clone(),
                priority: t.priority,
                share: t.share,
                slo_p95_ms: Some(t.slo_p95_ms),
                submitted: m.per_class[c].submitted,
                completed: m.per_class[c].completed,
                rejected: m.per_class[c].rejected,
                retagged_batches: m.per_class[c].retagged_batches,
                slo_violation_ticks: class_trackers[c].violation_ticks,
                cap_saturated_ticks: mp.pilots()[c].cap_saturated_ticks,
                latency: m.per_class[c].latency.clone(),
            })
            .collect()
    });
    let created_unix = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);

    Ok(BenchReport {
        version: REPORT_VERSION,
        scenario: sc.name.clone(),
        description: sc.description.clone(),
        provenance: Provenance {
            seed: ctx.seed,
            config_hash: format!("{:016x}", sc.config_hash()),
            trace_hash: format!("{:016x}", arrivals::trace_hash(&trace)),
            created_unix,
            generator: format!("qos-nets bench {}", env!("CARGO_PKG_VERSION")),
        },
        duration_s: wall,
        throughput: Throughput {
            submitted,
            completed: m.completed,
            ok,
            img_per_s: m.completed as f64 / wall.max(1e-9),
            batches: m.batches,
            mean_batch: m.mean_batch,
        },
        latency: m.latency,
        queue: m.queue,
        per_op,
        switches: Switches {
            total: timeline.len() as u64,
            drain,
            immediate: timeline.len() as u64 - drain,
            forced,
            budget_violations,
            retagged_batches: m.retagged_batches,
            timeline,
        },
        scaling: Scaling {
            scale_ups: m.scale_ups,
            scale_downs: m.scale_downs,
            spawn_failures: m.spawn_failures,
            peak_workers: m.peak_workers,
            final_workers,
        },
        fleet: fleet_report,
        autopilot,
        tenants,
        intervals,
    })
}

/// Driver-owned values the `"bench"` registry collector exposes (and
/// the dashboard reads back): submitted count, live budget, OP in
/// force.  Updated once per tick under a mutex the scrape thread
/// shares.
#[derive(Default)]
struct BenchGauges {
    op: usize,
    budget: f64,
    submitted: u64,
}

/// Metric families derived from [`BenchGauges`] plus the static ladder
/// powers and scenario envelope.
fn bench_families(g: &BenchGauges, powers: &[f64], envelope: f64) -> Vec<MetricFamily> {
    vec![
        MetricFamily::new(
            "qos_nets_requests_submitted_total",
            "Images the bench driver has submitted to the server.",
            Kind::Counter,
            vec![Sample::plain(g.submitted as f64)],
        ),
        MetricFamily::new(
            "qos_nets_power_budget",
            "Normalized power budget from the scenario's QoS source.",
            Kind::Gauge,
            vec![Sample::plain(g.budget)],
        ),
        MetricFamily::new(
            "qos_nets_power_envelope",
            "Power envelope the autopilot steers under (1.0 = unconstrained).",
            Kind::Gauge,
            vec![Sample::plain(envelope)],
        ),
        MetricFamily::new(
            "qos_nets_op_index",
            "Operating point currently in force (ladder index).",
            Kind::Gauge,
            vec![Sample::plain(g.op as f64)],
        ),
        MetricFamily::new(
            "qos_nets_op_power",
            "Relative power draw of the operating point in force.",
            Kind::Gauge,
            vec![Sample::plain(powers.get(g.op).copied().unwrap_or(0.0))],
        ),
    ]
}

/// Weight-proportional tenant pick for one arrival.  Draws from its
/// own seeded stream so the arrival trace — and with it `trace_hash` —
/// is identical between the classless baseline pass and the tenanted
/// closed-loop pass.
fn pick_tenant(tenants: &[TenantSpec], rng: &mut Rng) -> usize {
    let total: f64 = tenants.iter().map(|t| t.weight).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut x = rng.f64() * total;
    for (i, t) in tenants.iter().enumerate() {
        x -= t.weight;
        if x < 0.0 {
            return i;
        }
    }
    tenants.len() - 1
}

fn mode_tag(mode: SwitchMode) -> &'static str {
    match mode {
        SwitchMode::Drain => "drain",
        SwitchMode::Immediate => "immediate",
    }
}

fn apply_env(source: &mut BudgetSource, event: EnvEvent) {
    if let BudgetSource::Env(sim, _) = source {
        sim.apply(event);
    }
}
