//! Live ANSI terminal dashboard for `qos-nets bench --dashboard`.
//!
//! Plain escape-code rendering (cursor-up + clear-line), no terminal
//! crate: a fixed block of lines is redrawn in place once per sampling
//! interval, with a unicode sparkline of recent throughput.
//!
//! Every number on the panel is read back from the process-wide
//! [`Registry`] — the same families `--metrics-addr` exposes — so the
//! panel and a concurrent scrape can never disagree.  The dashboard
//! keeps only presentation state of its own (the throughput ring the
//! sparkline draws, derived from deltas of the completed counter).

use crate::obs::Registry;

const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
/// Sparkline width (most recent intervals shown).
const SPARK_WIDTH: usize = 30;
/// Lines the panel occupies (header + spark + latency + pool).
const PANEL_LINES: usize = 4;

/// Redraws a small metrics panel in place.
pub struct Dashboard {
    drawn_once: bool,
    /// `(t_s, completed)` at the previous observation, for the
    /// throughput delta.
    last: Option<(f64, f64)>,
    /// Recent per-interval throughput (img/s), newest last.
    rates: Vec<f64>,
}

impl Default for Dashboard {
    fn default() -> Self {
        Self::new()
    }
}

impl Dashboard {
    pub fn new() -> Self {
        Dashboard { drawn_once: false, last: None, rates: Vec::new() }
    }

    /// Sample the registry at bench time `t_s` and redraw the panel.
    /// `op_name` names the ladder rung behind the `qos_nets_op_index`
    /// gauge (the registry exports the index, not the name).
    pub fn observe(&mut self, reg: &Registry, scenario: &str, t_s: f64, op_name: &str) {
        let read = |name: &str| reg.value(name, &[]).unwrap_or(0.0);
        let completed = read("qos_nets_requests_completed_total");
        let submitted = read("qos_nets_requests_submitted_total");
        let inflight = read("qos_nets_inflight");
        let workers = read("qos_nets_workers");
        let op = read("qos_nets_op_index");
        let budget = read("qos_nets_power_budget");
        let p99_us = reg.value("qos_nets_latency_us", &[("quantile", "0.99")]).unwrap_or(0.0);

        let img_per_s = match self.last {
            Some((t0, c0)) if t_s > t0 => (completed - c0) / (t_s - t0),
            _ => 0.0,
        };
        self.last = Some((t_s, completed));
        self.rates.push(img_per_s);
        if self.rates.len() > SPARK_WIDTH {
            self.rates.remove(0);
        }

        if self.drawn_once {
            // move back to the top of the panel and overwrite it
            print!("\x1b[{PANEL_LINES}A");
        }
        self.drawn_once = true;
        let clear = "\x1b[2K";
        println!(
            "{clear}bench {scenario}  t={t_s:>6.1}s  op={} ({op_name})  budget={budget:.2}",
            op as usize
        );
        println!("{clear}  {img_per_s:>8.1} img/s  {}", sparkline(&self.rates));
        println!("{clear}  p99<={:.2} ms (cumulative)  inflight={}", p99_us / 1e3, inflight as u64);
        println!(
            "{clear}  workers={}  submitted={}  completed={}",
            workers as usize, submitted as u64, completed as u64
        );
    }

    /// Leave the panel on screen and move on (end of run).
    pub fn finish(&mut self) {
        if self.drawn_once {
            println!();
        }
    }
}

/// Throughput sparkline over the most recent intervals, scaled to the
/// window's own maximum.
fn sparkline(rates: &[f64]) -> String {
    let window = &rates[rates.len().saturating_sub(SPARK_WIDTH)..];
    let max = window.iter().copied().fold(0.0f64, f64::max);
    if max <= 0.0 {
        return SPARK[0].to_string().repeat(window.len().max(1));
    }
    window
        .iter()
        .map(|r| {
            let level = (r / max * (SPARK.len() - 1) as f64).round() as usize;
            SPARK[level.min(SPARK.len() - 1)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparkline_scales_to_the_window_max() {
        let s: Vec<char> = sparkline(&[0.0, 50.0, 100.0]).chars().collect();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], SPARK[0]);
        assert_eq!(s[2], SPARK[7]);
    }

    #[test]
    fn sparkline_windows_long_histories_and_survives_all_zero() {
        let hist: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert_eq!(sparkline(&hist).chars().count(), SPARK_WIDTH);
        assert_eq!(sparkline(&[0.0, 0.0, 0.0]).chars().count(), 3);
    }

    #[test]
    fn observe_derives_throughput_from_completed_deltas() {
        use crate::obs::metrics::{Kind, MetricFamily, Sample};
        fn completed(n: f64) -> Vec<MetricFamily> {
            let s = vec![Sample::plain(n)];
            vec![MetricFamily::new("qos_nets_requests_completed_total", "", Kind::Counter, s)]
        }
        let reg = Registry::default();
        reg.register("t", || completed(200.0));
        let mut d = Dashboard::new();
        // first sample has no baseline: rate must be 0, not `completed/t`
        d.observe(&reg, "unit", 1.0, "op0");
        assert_eq!(d.rates, vec![0.0]);
        reg.register("t", || completed(500.0));
        d.observe(&reg, "unit", 3.0, "op0");
        assert_eq!(d.rates[1], 150.0);
    }
}
