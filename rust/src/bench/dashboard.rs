//! Live ANSI terminal dashboard for `qos-nets bench --dashboard`.
//!
//! Plain escape-code rendering (cursor-up + clear-line), no terminal
//! crate: a fixed block of lines is redrawn in place once per sampling
//! interval, with a unicode sparkline of recent throughput.  Purely
//! additive — the recorded report is identical with or without it.

use crate::bench::report::Interval;

const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
/// Sparkline width (most recent intervals shown).
const SPARK_WIDTH: usize = 30;
/// Lines the panel occupies (header + spark + latency + pool).
const PANEL_LINES: usize = 4;

/// Redraws a small metrics panel in place.
pub struct Dashboard {
    drawn_once: bool,
}

impl Default for Dashboard {
    fn default() -> Self {
        Self::new()
    }
}

impl Dashboard {
    pub fn new() -> Self {
        Dashboard { drawn_once: false }
    }

    /// Render the panel for the newest interval.  `history` is the full
    /// interval list so far (newest last); `op_name` names the rung in
    /// force at the snapshot.
    pub fn render(&mut self, scenario: &str, history: &[Interval], op_name: &str) {
        let Some(snap) = history.last() else {
            return;
        };
        if self.drawn_once {
            // move back to the top of the panel and overwrite it
            print!("\x1b[{PANEL_LINES}A");
        }
        self.drawn_once = true;
        let clear = "\x1b[2K";
        println!(
            "{clear}bench {scenario}  t={:>6.1}s  op={} ({op_name})  budget={:.2}",
            snap.t_s, snap.op, snap.budget
        );
        println!("{clear}  {:>8.1} img/s  {}", snap.img_per_s, sparkline(history));
        println!(
            "{clear}  p99<={:.2} ms (cumulative)  inflight={}",
            snap.p99_us as f64 / 1e3,
            snap.inflight
        );
        println!(
            "{clear}  workers={}  submitted={}  completed={}",
            snap.workers, snap.submitted, snap.completed
        );
    }

    /// Leave the panel on screen and move on (end of run).
    pub fn finish(&mut self) {
        if self.drawn_once {
            println!();
        }
    }
}

/// Throughput sparkline over the most recent intervals, scaled to the
/// window's own maximum.
fn sparkline(history: &[Interval]) -> String {
    let window = &history[history.len().saturating_sub(SPARK_WIDTH)..];
    let max = window.iter().map(|i| i.img_per_s).fold(0.0f64, f64::max);
    if max <= 0.0 {
        return SPARK[0].to_string().repeat(window.len().max(1));
    }
    window
        .iter()
        .map(|i| {
            let level = (i.img_per_s / max * (SPARK.len() - 1) as f64).round() as usize;
            SPARK[level.min(SPARK.len() - 1)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iv(img_per_s: f64) -> Interval {
        Interval { img_per_s, ..Default::default() }
    }

    #[test]
    fn sparkline_scales_to_the_window_max() {
        let hist: Vec<Interval> = [0.0, 50.0, 100.0].into_iter().map(iv).collect();
        let s: Vec<char> = sparkline(&hist).chars().collect();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], SPARK[0]);
        assert_eq!(s[2], SPARK[7]);
    }

    #[test]
    fn sparkline_windows_long_histories_and_survives_all_zero() {
        let hist: Vec<Interval> = (0..100).map(|i| iv(i as f64)).collect();
        assert_eq!(sparkline(&hist).chars().count(), SPARK_WIDTH);
        let flat: Vec<Interval> = (0..3).map(|_| iv(0.0)).collect();
        assert_eq!(sparkline(&flat).chars().count(), 3);
    }
}
