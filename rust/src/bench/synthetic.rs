//! Self-contained deployments for the bench harness: a tiny native
//! model with a three-rung OP ladder, and a matching stub ladder.
//!
//! The native fixture mirrors the integration-test tiny graph (1 conv +
//! GAP + dense, 1184 MACs) so `qos-nets bench` runs real LUT inference
//! with zero on-disk artifacts: weights are generated from a fixed seed
//! and the ladder swaps the conv/dense multipliers (exact -> bam7) the
//! same way a stored plan would.  Every bench ladder — native or stub —
//! has exactly [`LADDER_RUNGS`] rungs at relative powers 1.0/0.8/0.6 so
//! scenarios and scripted `set_op` events are portable across backends.

use std::collections::HashMap;
use std::sync::Arc;

use crate::backend::stub::stub_op;
use crate::bench::scenario::LADDER_RUNGS;
use crate::engine::OperatingPoint;
use crate::muldb::MulDb;
use crate::nn::{Graph, LayerParams, ModelParams};
use crate::util::json;
use crate::util::rng::Rng;

/// Image elements per native bench input (4x4x2).
pub const NATIVE_ELEMS: usize = 32;
/// Image elements per stub bench input.
pub const STUB_ELEMS: usize = 2;
/// Logit classes the stub backend reports.
pub const STUB_CLASSES: usize = 8;
/// Distinct images in each deployment's pool.
pub const POOL_IMAGES: usize = 16;

/// The approximate-multiplier index the frugal rungs use (the bam7
/// family member in `MulDb::generate()`).
const FRUGAL_MUL: usize = 9;

fn tiny_graph_json() -> json::Json {
    json::parse(
        r#"{
        "name": "bench-tiny", "input_shape": [4, 4, 2], "total_macs": 1184,
        "nodes": [
          {"id":0,"kind":"input","inputs":[],"name":"input","out_shape":[4,4,2]},
          {"id":1,"kind":"conv","inputs":[0],"name":"c1","out_shape":[4,4,4],
           "cin":2,"cout":4,"ksize":3,"stride":1,"pad":1,"groups":1,
           "has_bn":false,"act":"relu","macs_per_out":18,"macs_total":1152,
           "quant":{"in":{"scale":0.01,"zero_point":128},"w":{"scale":0.02,"zero_point":128}}},
          {"id":2,"kind":"gap","inputs":[1],"name":"gap","out_shape":[4]},
          {"id":3,"kind":"dense","inputs":[2],"name":"fc","out_shape":[2],
           "cin":4,"cout":2,"ksize":0,"stride":1,"pad":0,"groups":1,
           "has_bn":false,"act":"none","macs_per_out":4,"macs_total":8,
           "quant":{"in":{"scale":0.02,"zero_point":100},"w":{"scale":0.02,"zero_point":128}}},
          {"id":4,"kind":"output","inputs":[3],"name":"output","out_shape":[2]}
        ]}"#,
    )
    .unwrap()
}

/// Build the native bench deployment: graph, multiplier family and a
/// three-rung ladder sharing one parameter set.
pub fn native_ladder() -> (Arc<Graph>, Arc<MulDb>, Vec<OperatingPoint>) {
    let graph = Arc::new(Graph::from_json(&tiny_graph_json()).unwrap());
    let db = Arc::new(MulDb::generate());
    let mut rng = Rng::new(11);
    let w1: Vec<f32> = (0..3 * 3 * 2 * 4).map(|_| rng.normal() as f32 * 0.2).collect();
    let wfc: Vec<f32> = (0..4 * 2).map(|_| rng.normal() as f32 * 0.3).collect();

    let q_codes = |w: &[f32], s: f32, z: i32| -> Vec<i32> {
        w.iter()
            .map(|&x| ((x / s).round_ties_even() as i32 + z).clamp(0, 255))
            .collect()
    };
    let mut layers = HashMap::new();
    layers.insert(
        "c1".to_string(),
        LayerParams {
            w_codes: q_codes(&w1, 0.02, 128),
            w_shape: vec![3, 3, 2, 4],
            post_scale: vec![0.01 * 0.02; 4],
            post_bias: vec![0.0; 4],
        },
    );
    layers.insert(
        "fc".to_string(),
        LayerParams {
            w_codes: q_codes(&wfc, 0.02, 128),
            w_shape: vec![4, 2],
            post_scale: vec![0.02 * 0.02; 2],
            post_bias: vec![0.0; 2],
        },
    );
    let params = ModelParams { layers };

    let rung = |name: &str, c1: usize, fc: usize, power: f64| OperatingPoint {
        name: name.to_string(),
        assignment: [("c1".to_string(), c1), ("fc".to_string(), fc)].into_iter().collect(),
        params: params.clone(),
        relative_power: power,
    };
    let ops = vec![
        rung("exact", 0, 0, 1.0),
        rung("mid", FRUGAL_MUL, 0, 0.8),
        rung("frugal", FRUGAL_MUL, FRUGAL_MUL, 0.6),
    ];
    debug_assert_eq!(ops.len(), LADDER_RUNGS);
    (graph, db, ops)
}

/// The stub/fleet ladder: parameter-free rungs at the same powers as
/// the native one, so QoS trajectories are comparable across backends.
pub fn stub_ladder() -> Vec<OperatingPoint> {
    let ops = vec![stub_op("exact", 1.0), stub_op("mid", 0.8), stub_op("frugal", 0.6)];
    debug_assert_eq!(ops.len(), LADDER_RUNGS);
    ops
}

/// A flattened pool of [`POOL_IMAGES`] native inputs (seeded, so the
/// trace's image indices always resolve to the same pixels).
pub fn native_image_pool(seed: u64) -> (Vec<f32>, usize) {
    let mut rng = Rng::new(seed);
    let images = (0..POOL_IMAGES * NATIVE_ELEMS).map(|_| rng.f64() as f32).collect();
    (images, NATIVE_ELEMS)
}

/// A flattened pool of stub inputs; image `i` deterministically argmaxes
/// to class `i % STUB_CLASSES` under the stub backend.
pub fn stub_image_pool() -> (Vec<f32>, usize) {
    let mut images = Vec::with_capacity(POOL_IMAGES * STUB_ELEMS);
    for i in 0..POOL_IMAGES {
        images.push((i % STUB_CLASSES) as f32);
        images.push(0.0);
    }
    (images, STUB_ELEMS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, NativeBackend};

    #[test]
    fn native_ladder_runs_on_the_engine_and_rungs_disagree_with_exact() {
        let (graph, db, ops) = native_ladder();
        let (pool, elems) = native_image_pool(3);
        let mut be = NativeBackend::new(graph, db);
        be.prepare(&ops).unwrap();
        let img = &pool[..elems];
        let exact = be.forward(0, img, 1).unwrap();
        let frugal = be.forward(2, img, 1).unwrap();
        assert_eq!(exact.len(), 2);
        assert_eq!(frugal.len(), 2);
        assert!(exact.iter().all(|x| x.is_finite()));
        // bam7 on both layers must actually change the logits
        assert_ne!(exact, frugal);
    }

    #[test]
    fn ladders_share_shape_and_powers() {
        let (_, _, native) = native_ladder();
        let stub = stub_ladder();
        assert_eq!(native.len(), stub.len());
        for (n, s) in native.iter().zip(&stub) {
            assert_eq!(n.name, s.name);
            assert_eq!(n.relative_power, s.relative_power);
        }
    }

    #[test]
    fn image_pools_are_deterministic() {
        assert_eq!(native_image_pool(3).0, native_image_pool(3).0);
        let (pool, elems) = stub_image_pool();
        assert_eq!(pool.len(), POOL_IMAGES * elems);
        assert_eq!(pool[2 * elems] as usize, 2 % STUB_CLASSES);
    }
}
