//! Scenario-driven load harness: `qos-nets bench`.
//!
//! Answers "how does the serving stack behave under *this* load
//! pattern?" with a recorded, replayable artifact instead of an
//! anecdote.  The moving parts:
//!
//!   * [`scenario`] — the declarative JSON schema (arrival process,
//!     batch mix, deployment shape, scripted QoS/environment events)
//!     plus six built-in scenarios covering the interesting regimes;
//!   * [`arrivals`] — scenarios expand into a fully materialized,
//!     seeded arrival trace before the run, so identical seeds replay
//!     identical request streams (the trace hash lands in provenance);
//!   * [`synthetic`] — self-contained deployments: a tiny native model
//!     with a three-rung multiplier ladder, a delayed stub, or a
//!     loopback fleet of stub workers — no on-disk artifacts needed;
//!   * [`driver`] — one generic measurement loop over [`crate::server`]
//!     replaying the trace open-loop while the QoS controller walks the
//!     ladder from the scenario's budget source;
//!   * [`report`] — the versioned `BENCH_<scenario>.json` perf record
//!     (throughput, per-OP quantiles, switch timeline, scale events,
//!     per-worker attribution) CI stores as a trend artifact;
//!   * [`dashboard`] — optional live ANSI panel (`--dashboard`).

pub mod arrivals;
pub mod dashboard;
pub mod driver;
pub mod report;
pub mod scenario;
pub mod synthetic;

pub use driver::{run_scenario, BenchOpts};
pub use report::{BenchReport, REPORT_VERSION};
pub use scenario::{builtin, Scenario, BUILTIN_NAMES};
