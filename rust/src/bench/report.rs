//! `BENCH_<scenario>.json`: the machine-readable perf-trajectory
//! record one bench run emits.
//!
//! The report is versioned ([`REPORT_VERSION`]) and carries full
//! provenance — seed, a hash of the exact scenario config, and a hash
//! of the materialized arrival trace — so two reports are comparable
//! iff their provenance matches.  [`BenchReport::from_json`] validates
//! as strictly as the scenario parser: CI trend tooling should fail
//! loudly on a schema drift, not chart garbage.

use anyhow::{bail, Context, Result};

use crate::autopilot::Decision;
use crate::util::json::{self, Json};
use crate::util::stats::LatencySummary;

/// Bump on any incompatible schema change to the report JSON.
pub const REPORT_VERSION: u64 = 1;

/// Everything needed to decide whether two reports are comparable.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    pub seed: u64,
    /// Hex FNV-1a of the canonical scenario JSON.
    pub config_hash: String,
    /// Hex FNV-1a of the materialized arrival trace.
    pub trace_hash: String,
    /// Wall-clock seconds since the Unix epoch at run end.
    pub created_unix: u64,
    /// Tool + version string, e.g. `qos-nets bench 0.1.0`.
    pub generator: String,
}

/// Whole-run throughput counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Throughput {
    /// Images submitted by the load generator.
    pub submitted: u64,
    /// Images the server completed (from its own metrics).
    pub completed: u64,
    /// Responses actually received by the generator before the drain
    /// timeout.
    pub ok: u64,
    pub img_per_s: f64,
    pub batches: u64,
    pub mean_batch: f64,
}

/// Per-rung serving slice: requests + latency under one ladder index.
#[derive(Debug, Clone, PartialEq)]
pub struct OpReport {
    pub index: usize,
    pub name: String,
    pub power: f64,
    pub requests: u64,
    pub latency: LatencySummary,
}

/// One OP switch as it happened, for replaying the ladder walk.
#[derive(Debug, Clone, PartialEq)]
pub struct SwitchRecord {
    pub t_s: f64,
    /// Destination `OpTable` index.
    pub op: usize,
    /// `"drain"` or `"immediate"`.
    pub mode: String,
    /// True for scripted `set_op` events (bypassed the controller).
    pub forced: bool,
}

/// Ladder-walk counters plus the full switch timeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Switches {
    pub total: u64,
    pub drain: u64,
    pub immediate: u64,
    pub forced: u64,
    pub budget_violations: u64,
    pub retagged_batches: u64,
    pub timeline: Vec<SwitchRecord>,
}

/// Elastic-pool activity over the run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Scaling {
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub spawn_failures: u64,
    pub peak_workers: usize,
    pub final_workers: usize,
}

/// Per-remote-worker attribution when the run served through a fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetWorkerReport {
    pub addr: String,
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_latency_us: f64,
    pub evicted: bool,
    /// Re-probe handshakes the control loop aimed at this worker
    /// (omitted from the JSON while zero, so pre-existing reports stay
    /// byte-identical).
    pub reprobes: u64,
}

/// Fleet-level counters (absent for in-process deployments).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetReport {
    pub requeues: u64,
    pub evictions: u64,
    pub workers: Vec<FleetWorkerReport>,
}

/// One tenant class's slice of the run, present only for multi-tenant
/// scenarios (the `tenants` array is omitted otherwise, keeping
/// single-tenant reports byte-identical to the pre-tenancy schema).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantReport {
    pub name: String,
    /// Strict scheduling priority (0 = premium, sheds last).
    pub priority: u32,
    /// Admission weight against the other classes.
    pub share: f64,
    /// Per-class p95 SLO, ms (`None` = rides the deployment objective).
    pub slo_p95_ms: Option<f64>,
    pub submitted: u64,
    pub completed: u64,
    /// Requests bounced by weighted admission — the shedding evidence:
    /// under overload these should be best-effort until premium's own
    /// SLO is violated.
    pub rejected: u64,
    /// Batches retagged to a cheaper OP after this class downgraded.
    pub retagged_batches: u64,
    /// Autopilot ticks whose windowed per-class p95 exceeded the
    /// class's SLO.
    pub slo_violation_ticks: u64,
    /// Pressured ticks where the class controller wanted to shed
    /// further but its rung cap already pinned the floor.
    pub cap_saturated_ticks: u64,
    /// End-to-end latency over this class's completed requests.
    pub latency: LatencySummary,
}

/// The autopilot-off control run paired with an autopilot run: same
/// scenario, same seed, plain budget-driven QoS control — the evidence
/// that the SLO pressure was real and the autopilot's sheds earned
/// their keep.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AutopilotBaseline {
    /// Control ticks whose windowed p95 exceeded the SLO.
    pub slo_violation_ticks: u64,
    /// First tick at which the windowed p95 exceeded the SLO.
    pub first_violation_t_s: Option<f64>,
    /// `(t_s, p95_ms)` per decision-log tick (windowed p95).
    pub p95_timeline: Vec<(f64, f64)>,
}

/// Autopilot activity over the run (absent when the autopilot was
/// off and no paired baseline was recorded).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AutopilotReport {
    pub slo_p95_ms: f64,
    /// 1.0 = no operator envelope beyond the environmental budget.
    pub power_envelope: f64,
    pub slo_violation_ticks: u64,
    pub first_violation_t_s: Option<f64>,
    /// First accuracy downgrade the autopilot commanded (`op_down`).
    pub first_downgrade_t_s: Option<f64>,
    /// The decision log: action ticks plus interval-boundary ticks.
    pub decisions: Vec<Decision>,
    pub baseline: Option<AutopilotBaseline>,
}

/// One sampling-interval snapshot: the trajectory the dashboard draws
/// and trend tooling charts.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Interval {
    /// Interval end, seconds into the run.
    pub t_s: f64,
    /// Completion rate over this interval.
    pub img_per_s: f64,
    /// Cumulative counters at the interval boundary.
    pub submitted: u64,
    pub completed: u64,
    pub inflight: usize,
    pub workers: usize,
    /// Ladder index in force at the boundary.
    pub op: usize,
    /// Budget sampled at the boundary.
    pub budget: f64,
    /// Cumulative p99, microseconds (log2-bucket upper bound).
    pub p99_us: u64,
}

/// The full record of one bench run; see the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    pub version: u64,
    pub scenario: String,
    pub description: String,
    pub provenance: Provenance,
    /// Wall-clock run length, seconds.
    pub duration_s: f64,
    pub throughput: Throughput,
    /// End-to-end latency over all completed requests.
    pub latency: LatencySummary,
    /// Queue (submit -> batch formation) latency.
    pub queue: LatencySummary,
    pub per_op: Vec<OpReport>,
    pub switches: Switches,
    pub scaling: Scaling,
    pub fleet: Option<FleetReport>,
    pub autopilot: Option<AutopilotReport>,
    /// Per-tenant-class slices; `None` for single-tenant runs (and
    /// omitted from the JSON entirely).
    pub tenants: Option<Vec<TenantReport>>,
    pub intervals: Vec<Interval>,
}

fn summary_to_json(s: &LatencySummary) -> Json {
    Json::obj(vec![
        ("count", Json::num(s.count as f64)),
        ("mean_us", Json::num(s.mean_us)),
        ("p50_us", Json::num(s.p50_us as f64)),
        ("p95_us", Json::num(s.p95_us as f64)),
        ("p99_us", Json::num(s.p99_us as f64)),
        ("max_us", Json::num(s.max_us as f64)),
    ])
}

fn summary_from_json(v: &Json, what: &str) -> Result<LatencySummary> {
    let f = |key: &str| -> Result<f64> {
        v.get(key)
            .and_then(|x| x.as_f64())
            .with_context(|| format!("report: {what}: missing or non-numeric {key:?}"))
    };
    Ok(LatencySummary {
        count: f("count")? as u64,
        mean_us: f("mean_us")?,
        p50_us: f("p50_us")? as u64,
        p95_us: f("p95_us")? as u64,
        p99_us: f("p99_us")? as u64,
        max_us: f("max_us")? as u64,
    })
}

fn req_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(|x| x.as_f64())
        .with_context(|| format!("report: missing or non-numeric {key:?}"))
}

fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(|x| x.as_str())
        .with_context(|| format!("report: missing or non-string {key:?}"))
}

impl BenchReport {
    /// Serialize; [`BenchReport::from_json`] inverts this exactly.
    pub fn to_json(&self) -> Json {
        let p = &self.provenance;
        let provenance = Json::obj(vec![
            ("seed", Json::num(p.seed as f64)),
            ("config_hash", Json::str(p.config_hash.clone())),
            ("trace_hash", Json::str(p.trace_hash.clone())),
            ("created_unix", Json::num(p.created_unix as f64)),
            ("generator", Json::str(p.generator.clone())),
        ]);
        let t = &self.throughput;
        let throughput = Json::obj(vec![
            ("submitted", Json::num(t.submitted as f64)),
            ("completed", Json::num(t.completed as f64)),
            ("ok", Json::num(t.ok as f64)),
            ("img_per_s", Json::num(t.img_per_s)),
            ("batches", Json::num(t.batches as f64)),
            ("mean_batch", Json::num(t.mean_batch)),
        ]);
        let per_op = self
            .per_op
            .iter()
            .map(|o| {
                Json::obj(vec![
                    ("index", Json::num(o.index as f64)),
                    ("name", Json::str(o.name.clone())),
                    ("power", Json::num(o.power)),
                    ("requests", Json::num(o.requests as f64)),
                    ("latency", summary_to_json(&o.latency)),
                ])
            })
            .collect();
        let s = &self.switches;
        let timeline = s
            .timeline
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("t_s", Json::num(r.t_s)),
                    ("op", Json::num(r.op as f64)),
                    ("mode", Json::str(r.mode.clone())),
                    ("forced", Json::Bool(r.forced)),
                ])
            })
            .collect();
        let switches = Json::obj(vec![
            ("total", Json::num(s.total as f64)),
            ("drain", Json::num(s.drain as f64)),
            ("immediate", Json::num(s.immediate as f64)),
            ("forced", Json::num(s.forced as f64)),
            ("budget_violations", Json::num(s.budget_violations as f64)),
            ("retagged_batches", Json::num(s.retagged_batches as f64)),
            ("timeline", Json::Arr(timeline)),
        ]);
        let sc = &self.scaling;
        let scaling = Json::obj(vec![
            ("scale_ups", Json::num(sc.scale_ups as f64)),
            ("scale_downs", Json::num(sc.scale_downs as f64)),
            ("spawn_failures", Json::num(sc.spawn_failures as f64)),
            ("peak_workers", Json::num(sc.peak_workers as f64)),
            ("final_workers", Json::num(sc.final_workers as f64)),
        ]);
        let fleet = match &self.fleet {
            None => Json::Null,
            Some(f) => Json::obj(vec![
                ("requeues", Json::num(f.requeues as f64)),
                ("evictions", Json::num(f.evictions as f64)),
                (
                    "workers",
                    Json::Arr(
                        f.workers
                            .iter()
                            .map(|w| {
                                let mut fields = vec![
                                    ("addr", Json::str(w.addr.clone())),
                                    ("requests", Json::num(w.requests as f64)),
                                    ("batches", Json::num(w.batches as f64)),
                                    ("errors", Json::num(w.errors as f64)),
                                    ("mean_latency_us", Json::num(w.mean_latency_us)),
                                    ("evicted", Json::Bool(w.evicted)),
                                ];
                                if w.reprobes > 0 {
                                    fields.push(("reprobes", Json::num(w.reprobes as f64)));
                                }
                                Json::obj(fields)
                            })
                            .collect(),
                    ),
                ),
            ]),
        };
        let opt_t = |t: Option<f64>| t.map(Json::num).unwrap_or(Json::Null);
        let autopilot = match &self.autopilot {
            None => Json::Null,
            Some(a) => {
                let baseline = match &a.baseline {
                    None => Json::Null,
                    Some(b) => Json::obj(vec![
                        ("slo_violation_ticks", Json::num(b.slo_violation_ticks as f64)),
                        ("first_violation_t_s", opt_t(b.first_violation_t_s)),
                        (
                            "p95_timeline",
                            Json::Arr(
                                b.p95_timeline
                                    .iter()
                                    .map(|&(t, p95)| {
                                        Json::Arr(vec![Json::num(t), Json::num(p95)])
                                    })
                                    .collect(),
                            ),
                        ),
                    ]),
                };
                Json::obj(vec![
                    ("slo_p95_ms", Json::num(a.slo_p95_ms)),
                    ("power_envelope", Json::num(a.power_envelope)),
                    ("slo_violation_ticks", Json::num(a.slo_violation_ticks as f64)),
                    ("first_violation_t_s", opt_t(a.first_violation_t_s)),
                    ("first_downgrade_t_s", opt_t(a.first_downgrade_t_s)),
                    ("decisions", Json::Arr(a.decisions.iter().map(|d| d.to_json()).collect())),
                    ("baseline", baseline),
                ])
            }
        };
        let intervals = self
            .intervals
            .iter()
            .map(|i| {
                Json::obj(vec![
                    ("t_s", Json::num(i.t_s)),
                    ("img_per_s", Json::num(i.img_per_s)),
                    ("submitted", Json::num(i.submitted as f64)),
                    ("completed", Json::num(i.completed as f64)),
                    ("inflight", Json::num(i.inflight as f64)),
                    ("workers", Json::num(i.workers as f64)),
                    ("op", Json::num(i.op as f64)),
                    ("budget", Json::num(i.budget)),
                    ("p99_us", Json::num(i.p99_us as f64)),
                ])
            })
            .collect();
        let mut root = vec![
            ("version", Json::num(self.version as f64)),
            ("scenario", Json::str(self.scenario.clone())),
            ("description", Json::str(self.description.clone())),
            ("provenance", provenance),
            ("duration_s", Json::num(self.duration_s)),
            ("throughput", throughput),
            ("latency", summary_to_json(&self.latency)),
            ("queue", summary_to_json(&self.queue)),
            ("per_op", Json::Arr(per_op)),
            ("switches", switches),
            ("scaling", scaling),
            ("fleet", fleet),
            ("autopilot", autopilot),
        ];
        // the tenants array only exists for multi-tenant runs, so
        // single-tenant reports stay byte-identical to the pre-tenancy
        // schema
        if let Some(tenants) = &self.tenants {
            let arr = tenants
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        ("name", Json::str(t.name.clone())),
                        ("priority", Json::num(t.priority as f64)),
                        ("share", Json::num(t.share)),
                        ("slo_p95_ms", t.slo_p95_ms.map(Json::num).unwrap_or(Json::Null)),
                        ("submitted", Json::num(t.submitted as f64)),
                        ("completed", Json::num(t.completed as f64)),
                        ("rejected", Json::num(t.rejected as f64)),
                        ("retagged_batches", Json::num(t.retagged_batches as f64)),
                        ("slo_violation_ticks", Json::num(t.slo_violation_ticks as f64)),
                        ("cap_saturated_ticks", Json::num(t.cap_saturated_ticks as f64)),
                        ("latency", summary_to_json(&t.latency)),
                    ])
                })
                .collect();
            root.push(("tenants", Json::Arr(arr)));
        }
        root.push(("intervals", Json::Arr(intervals)));
        Json::obj(root)
    }

    /// Parse + validate a report (strict: wrong version or any missing
    /// required field is an error).
    pub fn from_json(v: &Json) -> Result<BenchReport> {
        let version = req_f64(v, "version")? as u64;
        if version != REPORT_VERSION {
            bail!("report version {version} unsupported (this build reads {REPORT_VERSION})");
        }
        let p = v.get("provenance").context("report: missing provenance")?;
        let provenance = Provenance {
            seed: req_f64(p, "seed")? as u64,
            config_hash: req_str(p, "config_hash")?.to_string(),
            trace_hash: req_str(p, "trace_hash")?.to_string(),
            created_unix: req_f64(p, "created_unix")? as u64,
            generator: req_str(p, "generator")?.to_string(),
        };
        let t = v.get("throughput").context("report: missing throughput")?;
        let throughput = Throughput {
            submitted: req_f64(t, "submitted")? as u64,
            completed: req_f64(t, "completed")? as u64,
            ok: req_f64(t, "ok")? as u64,
            img_per_s: req_f64(t, "img_per_s")?,
            batches: req_f64(t, "batches")? as u64,
            mean_batch: req_f64(t, "mean_batch")?,
        };
        let per_op = v
            .get("per_op")
            .and_then(|x| x.as_arr())
            .context("report: missing per_op array")?
            .iter()
            .map(|o| {
                Ok(OpReport {
                    index: req_f64(o, "index")? as usize,
                    name: req_str(o, "name")?.to_string(),
                    power: req_f64(o, "power")?,
                    requests: req_f64(o, "requests")? as u64,
                    latency: summary_from_json(
                        o.get("latency").context("report: per_op entry missing latency")?,
                        "per_op latency",
                    )?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let s = v.get("switches").context("report: missing switches")?;
        let timeline = s
            .get("timeline")
            .and_then(|x| x.as_arr())
            .context("report: switches missing timeline array")?
            .iter()
            .map(|r| {
                let mode = req_str(r, "mode")?.to_string();
                if mode != "drain" && mode != "immediate" {
                    bail!("report: unknown switch mode {mode:?}");
                }
                Ok(SwitchRecord {
                    t_s: req_f64(r, "t_s")?,
                    op: req_f64(r, "op")? as usize,
                    mode,
                    forced: r.get("forced").and_then(|x| x.as_bool()).unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let switches = Switches {
            total: req_f64(s, "total")? as u64,
            drain: req_f64(s, "drain")? as u64,
            immediate: req_f64(s, "immediate")? as u64,
            forced: req_f64(s, "forced")? as u64,
            budget_violations: req_f64(s, "budget_violations")? as u64,
            retagged_batches: req_f64(s, "retagged_batches")? as u64,
            timeline,
        };
        let sc = v.get("scaling").context("report: missing scaling")?;
        let scaling = Scaling {
            scale_ups: req_f64(sc, "scale_ups")? as u64,
            scale_downs: req_f64(sc, "scale_downs")? as u64,
            spawn_failures: req_f64(sc, "spawn_failures")? as u64,
            peak_workers: req_f64(sc, "peak_workers")? as usize,
            final_workers: req_f64(sc, "final_workers")? as usize,
        };
        let fleet = match v.get("fleet") {
            None | Some(Json::Null) => None,
            Some(f) => {
                let workers = f
                    .get("workers")
                    .and_then(|x| x.as_arr())
                    .context("report: fleet missing workers array")?
                    .iter()
                    .map(|w| {
                        Ok(FleetWorkerReport {
                            addr: req_str(w, "addr")?.to_string(),
                            requests: req_f64(w, "requests")? as u64,
                            batches: req_f64(w, "batches")? as u64,
                            errors: req_f64(w, "errors")? as u64,
                            mean_latency_us: req_f64(w, "mean_latency_us")?,
                            evicted: w.get("evicted").and_then(|x| x.as_bool()).unwrap_or(false),
                            reprobes: w.get("reprobes").and_then(|x| x.as_f64()).unwrap_or(0.0)
                                as u64,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Some(FleetReport {
                    requeues: req_f64(f, "requeues")? as u64,
                    evictions: req_f64(f, "evictions")? as u64,
                    workers,
                })
            }
        };
        let opt_t = |j: Option<&Json>, what: &str| -> Result<Option<f64>> {
            match j {
                None | Some(Json::Null) => Ok(None),
                Some(x) => Ok(Some(
                    x.as_f64().with_context(|| format!("report: non-numeric {what}"))?,
                )),
            }
        };
        let autopilot = match v.get("autopilot") {
            None | Some(Json::Null) => None,
            Some(a) => {
                let decisions = a
                    .get("decisions")
                    .and_then(|x| x.as_arr())
                    .context("report: autopilot missing decisions array")?
                    .iter()
                    .map(|d| Decision::from_json(d).map_err(|e| anyhow::anyhow!("report: {e}")))
                    .collect::<Result<Vec<_>>>()?;
                let baseline = match a.get("baseline") {
                    None | Some(Json::Null) => None,
                    Some(b) => {
                        let p95_timeline = b
                            .get("p95_timeline")
                            .and_then(|x| x.as_arr())
                            .context("report: baseline missing p95_timeline array")?
                            .iter()
                            .map(|pair| {
                                let pair = pair
                                    .as_arr()
                                    .context("report: p95_timeline entry not a pair")?;
                                match pair {
                                    [t, p95] => Ok((
                                        t.as_f64().context("report: p95_timeline t")?,
                                        p95.as_f64().context("report: p95_timeline p95")?,
                                    )),
                                    _ => bail!("report: p95_timeline entry not a pair"),
                                }
                            })
                            .collect::<Result<Vec<_>>>()?;
                        Some(AutopilotBaseline {
                            slo_violation_ticks: req_f64(b, "slo_violation_ticks")? as u64,
                            first_violation_t_s: opt_t(
                                b.get("first_violation_t_s"),
                                "baseline first_violation_t_s",
                            )?,
                            p95_timeline,
                        })
                    }
                };
                Some(AutopilotReport {
                    slo_p95_ms: req_f64(a, "slo_p95_ms")?,
                    power_envelope: req_f64(a, "power_envelope")?,
                    slo_violation_ticks: req_f64(a, "slo_violation_ticks")? as u64,
                    first_violation_t_s: opt_t(
                        a.get("first_violation_t_s"),
                        "autopilot first_violation_t_s",
                    )?,
                    first_downgrade_t_s: opt_t(
                        a.get("first_downgrade_t_s"),
                        "autopilot first_downgrade_t_s",
                    )?,
                    decisions,
                    baseline,
                })
            }
        };
        let tenants = match v.get("tenants").and_then(|x| x.as_arr()) {
            None => None,
            Some(arr) => Some(
                arr.iter()
                    .map(|t| {
                        Ok(TenantReport {
                            name: req_str(t, "name")?.to_string(),
                            priority: req_f64(t, "priority")? as u32,
                            share: req_f64(t, "share")?,
                            slo_p95_ms: t.get("slo_p95_ms").and_then(|x| x.as_f64()),
                            submitted: req_f64(t, "submitted")? as u64,
                            completed: req_f64(t, "completed")? as u64,
                            rejected: req_f64(t, "rejected")? as u64,
                            retagged_batches: req_f64(t, "retagged_batches")? as u64,
                            slo_violation_ticks: req_f64(t, "slo_violation_ticks")? as u64,
                            cap_saturated_ticks: req_f64(t, "cap_saturated_ticks")? as u64,
                            latency: summary_from_json(
                                t.get("latency").context("report: tenant missing latency")?,
                                "tenant latency",
                            )?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
            ),
        };
        let intervals = v
            .get("intervals")
            .and_then(|x| x.as_arr())
            .context("report: missing intervals array")?
            .iter()
            .map(|i| {
                Ok(Interval {
                    t_s: req_f64(i, "t_s")?,
                    img_per_s: req_f64(i, "img_per_s")?,
                    submitted: req_f64(i, "submitted")? as u64,
                    completed: req_f64(i, "completed")? as u64,
                    inflight: req_f64(i, "inflight")? as usize,
                    workers: req_f64(i, "workers")? as usize,
                    op: req_f64(i, "op")? as usize,
                    budget: req_f64(i, "budget")?,
                    p99_us: req_f64(i, "p99_us")? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(BenchReport {
            version,
            scenario: req_str(v, "scenario")?.to_string(),
            description: v.get("description").and_then(|x| x.as_str()).unwrap_or("").to_string(),
            provenance,
            duration_s: req_f64(v, "duration_s")?,
            throughput,
            latency: summary_from_json(
                v.get("latency").context("report: missing latency")?,
                "latency",
            )?,
            queue: summary_from_json(v.get("queue").context("report: missing queue")?, "queue")?,
            per_op,
            switches,
            scaling,
            fleet,
            autopilot,
            tenants,
            intervals,
        })
    }

    /// Pretty-print to a file (the `BENCH_<scenario>.json` artifact).
    pub fn write_to(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, json::to_string_pretty(&self.to_json()))
            .with_context(|| format!("writing bench report to {}", path.display()))
    }

    /// Parse a report file.
    pub fn read_from(path: &std::path::Path) -> Result<BenchReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report from {}", path.display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        BenchReport {
            version: REPORT_VERSION,
            scenario: "steady_state".into(),
            description: "test".into(),
            provenance: Provenance {
                seed: 7,
                config_hash: "deadbeef".into(),
                trace_hash: "cafebabe".into(),
                created_unix: 1_700_000_000,
                generator: "qos-nets bench test".into(),
            },
            duration_s: 2.0,
            throughput: Throughput {
                submitted: 100,
                completed: 100,
                ok: 100,
                img_per_s: 50.0,
                batches: 30,
                mean_batch: 3.3,
            },
            latency: LatencySummary {
                count: 100,
                mean_us: 900.0,
                p50_us: 1024,
                p95_us: 2048,
                p99_us: 4096,
                max_us: 3000,
            },
            queue: LatencySummary::default(),
            per_op: vec![OpReport {
                index: 0,
                name: "exact".into(),
                power: 1.0,
                requests: 100,
                latency: LatencySummary::default(),
            }],
            switches: Switches {
                total: 2,
                drain: 1,
                immediate: 1,
                forced: 0,
                budget_violations: 0,
                retagged_batches: 0,
                timeline: vec![
                    SwitchRecord { t_s: 0.0, op: 0, mode: "drain".into(), forced: false },
                    SwitchRecord { t_s: 0.4, op: 2, mode: "immediate".into(), forced: false },
                ],
            },
            scaling: Scaling { peak_workers: 2, final_workers: 2, ..Default::default() },
            autopilot: Some(AutopilotReport {
                slo_p95_ms: 100.0,
                power_envelope: 1.0,
                slo_violation_ticks: 0,
                first_violation_t_s: None,
                first_downgrade_t_s: Some(0.4),
                decisions: vec![Decision {
                    t_s: 0.4,
                    p95_ms: 65.5,
                    power: 0.6,
                    budget: 0.9,
                    op: 2,
                    workers: 2,
                    op_action: crate::autopilot::OpAction::Down,
                    pool_action: crate::autopilot::PoolAction::None,
                    chunk_action: crate::autopilot::ChunkAction::None,
                    bound: crate::autopilot::Bound::Latency,
                    cap_saturated: false,
                    class: None,
                }],
                baseline: Some(AutopilotBaseline {
                    slo_violation_ticks: 7,
                    first_violation_t_s: Some(0.55),
                    p95_timeline: vec![(0.05, 16.4), (0.55, 131.1)],
                }),
            }),
            fleet: Some(FleetReport {
                requeues: 0,
                evictions: 0,
                workers: vec![FleetWorkerReport {
                    addr: "127.0.0.1:9".into(),
                    requests: 100,
                    batches: 30,
                    errors: 0,
                    mean_latency_us: 800.0,
                    evicted: false,
                    reprobes: 0,
                }],
            }),
            tenants: None,
            intervals: vec![Interval {
                t_s: 0.5,
                img_per_s: 50.0,
                submitted: 25,
                completed: 25,
                inflight: 0,
                workers: 2,
                op: 0,
                budget: 1.0,
                p99_us: 4096,
            }],
        }
    }

    #[test]
    fn report_round_trips_through_json() {
        let r = sample();
        let text = json::to_string_pretty(&r.to_json());
        let back = BenchReport::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);

        // and with no fleet / autopilot sections (null in the JSON):
        // pre-autopilot reports keep parsing
        let mut r = sample();
        r.fleet = None;
        r.autopilot = None;
        let back =
            BenchReport::from_json(&json::parse(&json::to_string(&r.to_json())).unwrap()).unwrap();
        assert_eq!(back.fleet, None);
        assert_eq!(back.autopilot, None);

        // and with an autopilot section but no baseline
        let mut r = sample();
        r.autopilot.as_mut().unwrap().baseline = None;
        let back =
            BenchReport::from_json(&json::parse(&json::to_string(&r.to_json())).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn tenant_sections_round_trip_and_are_omitted_when_absent() {
        // single-tenant: no tenants key, no zero-valued reprobes key —
        // the schema is byte-compatible with pre-tenancy reports
        let text = json::to_string(&sample().to_json());
        assert!(!text.contains("\"tenants\""));
        assert!(!text.contains("\"reprobes\""));

        let mut r = sample();
        r.fleet.as_mut().unwrap().workers[0].reprobes = 3;
        r.tenants = Some(vec![
            TenantReport {
                name: "premium".into(),
                priority: 0,
                share: 3.0,
                slo_p95_ms: Some(100.0),
                submitted: 90,
                completed: 90,
                rejected: 0,
                retagged_batches: 0,
                slo_violation_ticks: 0,
                cap_saturated_ticks: 0,
                latency: LatencySummary::default(),
            },
            TenantReport {
                name: "best_effort".into(),
                priority: 1,
                share: 1.0,
                slo_p95_ms: None,
                submitted: 40,
                completed: 25,
                rejected: 15,
                retagged_batches: 2,
                slo_violation_ticks: 6,
                cap_saturated_ticks: 4,
                latency: LatencySummary::default(),
            },
        ]);
        let back =
            BenchReport::from_json(&json::parse(&json::to_string(&r.to_json())).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn malformed_autopilot_sections_are_rejected() {
        // an unknown decision tag must fail parsing, not chart garbage
        let mut v = sample().to_json();
        let text = json::to_string(&v).replace("op_down", "op_sideways");
        v = json::parse(&text).unwrap();
        let err = BenchReport::from_json(&v).unwrap_err();
        assert!(format!("{err:#}").contains("op_action"), "{err:#}");

        // a decisions array is required once the section is present
        let mut r = sample();
        r.autopilot = Some(AutopilotReport::default());
        let mut v = r.to_json();
        if let Json::Obj(pairs) = &mut v {
            for (k, val) in pairs.iter_mut() {
                if k == "autopilot" {
                    if let Json::Obj(a) = val {
                        a.retain(|(k, _)| k != "decisions");
                    }
                }
            }
        }
        let err = BenchReport::from_json(&v).unwrap_err();
        assert!(format!("{err:#}").contains("decisions"), "{err:#}");
    }

    #[test]
    fn wrong_version_and_missing_fields_are_rejected() {
        let mut v = sample().to_json();
        if let Json::Obj(pairs) = &mut v {
            pairs[0].1 = Json::num(99.0);
        }
        let err = BenchReport::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        let r = sample();
        let mut v = r.to_json();
        if let Json::Obj(pairs) = &mut v {
            pairs.retain(|(k, _)| k != "switches");
        }
        assert!(BenchReport::from_json(&v).is_err());
    }

    #[test]
    fn unknown_switch_modes_are_rejected() {
        let mut r = sample();
        r.switches.timeline[0].mode = "casual".into();
        let err = BenchReport::from_json(&r.to_json()).unwrap_err();
        assert!(format!("{err:#}").contains("casual"));
    }
}
