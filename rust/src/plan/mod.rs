//! Unified planning API: one `Planner` trait from search to serving.
//!
//! The paper's contribution is a *search algorithm* evaluated against a
//! family of baseline mappers on identical inputs (Table 1).  This
//! module is the planning-side mirror of the [`crate::backend`] seam:
//! every mapper — the QoS-Nets clustered search, the ALWANN genetic
//! baseline, and the simple single-OP baselines — implements
//! [`Planner`] and produces the same first-class artifact, a typed,
//! versioned [`OpPlan`]:
//!
//!   * [`Planner`]        `plan(&PlanInputs) -> OpPlan` + `name`/`describe`
//!   * [`PlanInputs`]     the shared search inputs (error model, tolerances,
//!     layer stats, scale ladder, budget, seed)
//!   * [`OpPlan`]         the artifact: per-OP assignments over an explicit
//!     `layer_names` header, the multiplier subset, provenance, and a
//!     JSON round-trip that stays wire-compatible with `assignment.json`
//!   * [`planner_by_name`] the string-keyed registry behind
//!     `search --algo qos|alwann|homogeneous|lvrm|pnam|tpm|gradient`
//!
//! Downstream, an `OpPlan` feeds everything the old tuple plumbing fed:
//! [`OpPlan::load_operating_points`] builds the `Vec<OperatingPoint>`
//! that `OpTable::new` / `Backend::prepare` take, and [`OpPlan::ladder`]
//! builds the `LadderEntry` list the QoS controller consumes — so a
//! stored plan drives eval, serving, and reporting through one path.

use std::collections::{BTreeSet, HashMap};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::baselines::{self, alwann};
use crate::engine::OperatingPoint;
use crate::errmodel::{self, SigmaE};
use crate::muldb::MulDb;
use crate::nn::LayerStats;
use crate::pipeline::{self, Experiment};
use crate::qos::LadderEntry;
use crate::selection::{self, SearchConfig};
use crate::util::json::{self, Json};

/// Wire-format version written by [`OpPlan::to_json`].  Legacy
/// `assignment.json` files (PR 0–2) carry no `version` field and parse
/// as version 0; writing always upgrades to the current version.
pub const PLAN_VERSION: u64 = 1;

/// Every registered planner name, in the order the `baselines`
/// comparison table prints them (qos last, like the paper's Table 1).
pub const PLANNER_NAMES: [&str; 7] = [
    "homogeneous",
    "gradient",
    "lvrm",
    "pnam",
    "tpm",
    "alwann",
    "qos",
];

// ---------------------------------------------------------------------------
// Inputs
// ---------------------------------------------------------------------------

/// Everything a mapper needs, shared verbatim across all of them so the
/// comparison stays honest: the same error model, tolerances, layer
/// statistics, scale ladder, instance budget and seed.
pub struct PlanInputs<'a> {
    /// The multiplier family (LUT error maps + power model).
    pub db: &'a MulDb,
    /// sigma_e error-model matrix (multiplier x layer).
    pub se: &'a SigmaE,
    /// Per-layer tolerance vector (kappa-scaled, see `Experiment::load`).
    pub sigma_g: &'a [f64],
    /// Per-layer operand statistics (MAC counts drive the power model).
    pub stats: &'a [LayerStats],
    /// Layer names, in graph order — the `OpPlan::layer_names` header.
    pub layer_names: &'a [String],
    /// Operating-point tolerance scales, most accurate first.
    pub scales: Vec<f64>,
    /// Multiplier-instance budget (the paper's n).
    pub n_multipliers: usize,
    pub seed: u64,
    /// Experiment name stamped into the plan.
    pub experiment: String,
}

impl<'a> PlanInputs<'a> {
    /// Borrow the planning inputs out of a loaded experiment.  The
    /// caller owns the sigma_e matrix (`errmodel::sigma_e(db, &exp.stats)`)
    /// so several planners can share one computation.
    pub fn from_experiment(exp: &'a Experiment, db: &'a MulDb, se: &'a SigmaE) -> PlanInputs<'a> {
        PlanInputs {
            db,
            se,
            sigma_g: &exp.sigma_g,
            stats: &exp.stats,
            layer_names: &exp.layer_names,
            scales: exp.scales(),
            n_multipliers: exp.n_multipliers(),
            seed: exp.seed(),
            experiment: exp.name.clone(),
        }
    }
}

// ---------------------------------------------------------------------------
// The artifact
// ---------------------------------------------------------------------------

/// One multiplier instance a plan deploys.
#[derive(Debug, Clone, PartialEq)]
pub struct MulRef {
    /// Id in the [`MulDb`] the plan was searched against.
    pub id: usize,
    pub name: String,
    /// Relative power vs the accurate multiplier.
    pub power: f64,
}

/// One operating point of a plan: a full layer -> multiplier assignment
/// at one tolerance scale.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanOp {
    /// OP name; `op{i}` by convention (the retraining overlays
    /// `bn_op{i}.qten` / `params_full_op{i}.qten` key off the index).
    pub name: String,
    /// Tolerance scale this OP was searched at.
    pub scale: f64,
    /// MAC-weighted relative multiplication power.
    pub relative_power: f64,
    /// Multiplier id per layer, aligned with [`OpPlan::layer_names`].
    pub assignment: Vec<usize>,
}

/// Where a plan came from: which mapper, under what seed and config.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// Registered planner name (see [`PLANNER_NAMES`]).
    pub planner: String,
    pub seed: u64,
    /// FNV-1a hash of the planning configuration (scales, budget, seed,
    /// problem shape) — cheap staleness detection for stored plans.
    pub config_hash: String,
}

/// The typed, versioned planning artifact: what every [`Planner`]
/// produces and what eval/serving/reporting consume.
///
/// Serialized as `assignment.json`, wire-compatible with the legacy
/// format (the Python stage-B retrainer keeps reading the per-OP
/// `assignment` objects; a `version` field plus the `layer_names`
/// header and `provenance` are additive).
#[derive(Debug, Clone, PartialEq)]
pub struct OpPlan {
    /// Wire-format version this plan was parsed from (0 = legacy file).
    pub version: u64,
    /// Experiment the plan belongs to.
    pub experiment: String,
    /// Instance budget the planner ran under; `subset.len()` never
    /// exceeds it.
    pub n_multipliers: usize,
    /// Layer names, in graph order; every `PlanOp::assignment` indexes
    /// parallel to this header.
    pub layer_names: Vec<String>,
    /// Distinct multiplier instances the plan deploys.
    pub subset: Vec<MulRef>,
    /// The operating-point ladder, most accurate first.
    pub ops: Vec<PlanOp>,
    /// k-means inertia of the clustering (QoS-Nets planner only).
    pub kmeans_inertia: Option<f64>,
    /// Planner provenance (absent on legacy files).
    pub provenance: Option<Provenance>,
}

impl OpPlan {
    /// The `layer name -> multiplier id` map of one OP (the shape
    /// `pipeline::build_operating_point` and stage B consume).
    pub fn assignment_map(&self, op_idx: usize) -> HashMap<String, usize> {
        self.layer_names
            .iter()
            .cloned()
            .zip(self.ops[op_idx].assignment.iter().copied())
            .collect()
    }

    /// The QoS ladder of this plan: one [`LadderEntry`] per OP, with
    /// `table_index` = position in `ops` — valid `OpTable`/`forward`
    /// indices when the plan is loaded in order (as
    /// [`load_operating_points`](Self::load_operating_points) does).
    pub fn ladder(&self) -> Vec<LadderEntry> {
        self.ops
            .iter()
            .enumerate()
            .map(|(i, op)| LadderEntry {
                name: op.name.clone(),
                power: op.relative_power,
                table_index: i,
            })
            .collect()
    }

    // -- JSON round trip ----------------------------------------------------

    /// Serialize to the `assignment.json` wire format (always the
    /// current [`PLAN_VERSION`], even for plans parsed from legacy
    /// files — writing upgrades).
    pub fn to_json(&self) -> Json {
        let ops: Vec<Json> = self
            .ops
            .iter()
            .enumerate()
            .map(|(i, op)| {
                let amap: Vec<(String, Json)> = self
                    .layer_names
                    .iter()
                    .zip(&op.assignment)
                    .map(|(name, &mid)| (name.clone(), Json::num(mid as f64)))
                    .collect();
                Json::obj(vec![
                    ("index", Json::num(i as f64)),
                    ("name", Json::str(op.name.clone())),
                    ("scale", Json::num(op.scale)),
                    ("relative_power", Json::num(op.relative_power)),
                    ("assignment", Json::Obj(amap)),
                ])
            })
            .collect();
        let subset: Vec<Json> = self
            .subset
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("id", Json::num(m.id as f64)),
                    ("name", Json::str(m.name.clone())),
                    ("power", Json::num(m.power)),
                ])
            })
            .collect();
        let mut pairs: Vec<(&str, Json)> = vec![
            ("version", Json::num(PLAN_VERSION as f64)),
            ("experiment", Json::str(self.experiment.clone())),
            ("n_multipliers", Json::num(self.n_multipliers as f64)),
            (
                "layer_names",
                Json::Arr(self.layer_names.iter().map(|n| Json::str(n.clone())).collect()),
            ),
            ("subset", Json::Arr(subset)),
            ("operating_points", Json::Arr(ops)),
        ];
        if let Some(k) = self.kmeans_inertia {
            pairs.push(("kmeans_inertia", Json::num(k)));
        }
        if let Some(p) = &self.provenance {
            pairs.push((
                "provenance",
                Json::obj(vec![
                    ("planner", Json::str(p.planner.clone())),
                    ("seed", Json::num(p.seed as f64)),
                    ("config_hash", Json::str(p.config_hash.clone())),
                ]),
            ));
        }
        Json::obj(pairs)
    }

    /// Parse a plan from the wire format.  Legacy files (no `version`,
    /// no `layer_names`, no per-OP `name`) still load: the layer header
    /// is recovered from the first OP's assignment-object key order
    /// (the JSON codec preserves it) and OPs are named `op{i}`.
    pub fn from_json(v: &Json) -> Result<OpPlan> {
        let version = v.get("version").and_then(|x| x.as_usize()).unwrap_or(0) as u64;
        // refuse files from a newer format instead of silently parsing
        // them into defaulted fields (every layer would fall back to
        // the exact multiplier and serve a wrong ladder)
        anyhow::ensure!(
            version <= PLAN_VERSION,
            "assignment.json is plan version {version}, this build reads <= {PLAN_VERSION}"
        );
        let experiment = v
            .get("experiment")
            .and_then(|x| x.as_str())
            .unwrap_or("")
            .to_string();
        let ops_json = v
            .req("operating_points")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("operating_points must be an array")?;
        let layer_names: Vec<String> = match v.get("layer_names").and_then(|x| x.as_arr()) {
            Some(arr) => arr
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect(),
            None => match ops_json.first().and_then(|op| op.get("assignment")) {
                Some(Json::Obj(pairs)) => pairs.iter().map(|(k, _)| k.clone()).collect(),
                _ => Vec::new(),
            },
        };
        let mut ops = Vec::with_capacity(ops_json.len());
        for (i, op) in ops_json.iter().enumerate() {
            let name = op
                .get("name")
                .and_then(|x| x.as_str())
                .map(str::to_string)
                .unwrap_or_else(|| format!("op{i}"));
            let scale = op.get("scale").and_then(|x| x.as_f64()).unwrap_or(1.0);
            let relative_power = op
                .get("relative_power")
                .and_then(|x| x.as_f64())
                .unwrap_or(1.0);
            // the power ladder feeds the QoS controller's sort and every
            // budget comparison — refuse NaN/inf here, at load time,
            // instead of serving a ladder that can never be selected
            anyhow::ensure!(
                relative_power.is_finite(),
                "operating_points[{i}] ({name:?}): non-finite relative_power {relative_power}"
            );
            let amap: HashMap<&str, usize> = match op.get("assignment") {
                Some(Json::Obj(pairs)) => pairs
                    .iter()
                    .map(|(k, val)| (k.as_str(), val.as_usize().unwrap_or(0)))
                    .collect(),
                _ => HashMap::new(),
            };
            let assignment: Vec<usize> = layer_names
                .iter()
                .map(|n| amap.get(n.as_str()).copied().unwrap_or(0))
                .collect();
            ops.push(PlanOp {
                name,
                scale,
                relative_power,
                assignment,
            });
        }
        let subset: Vec<MulRef> = match v.get("subset").and_then(|x| x.as_arr()) {
            Some(arr) => arr
                .iter()
                .map(|e| MulRef {
                    id: e.get("id").and_then(|x| x.as_usize()).unwrap_or(0),
                    name: e
                        .get("name")
                        .and_then(|x| x.as_str())
                        .unwrap_or("")
                        .to_string(),
                    power: e.get("power").and_then(|x| x.as_f64()).unwrap_or(1.0),
                })
                .collect(),
            None => Vec::new(),
        };
        let n_multipliers = v
            .get("n_multipliers")
            .and_then(|x| x.as_usize())
            .unwrap_or_else(|| subset.len().max(1));
        let kmeans_inertia = v.get("kmeans_inertia").and_then(|x| x.as_f64());
        let provenance = v.get("provenance").map(|p| Provenance {
            planner: p
                .get("planner")
                .and_then(|x| x.as_str())
                .unwrap_or("")
                .to_string(),
            seed: p.get("seed").and_then(|x| x.as_usize()).unwrap_or(0) as u64,
            config_hash: p
                .get("config_hash")
                .and_then(|x| x.as_str())
                .unwrap_or("")
                .to_string(),
        });
        Ok(OpPlan {
            version,
            experiment,
            n_multipliers,
            layer_names,
            subset,
            ops,
            kmeans_inertia,
            provenance,
        })
    }

    /// Write the plan to `path` (pretty-printed, like stage A's files).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), json::to_string_pretty(&self.to_json()))
            .with_context(|| format!("write {}", path.as_ref().display()))?;
        Ok(())
    }

    /// Read a plan back from `path` (ours, legacy, or hand-edited).
    pub fn load(path: impl AsRef<Path>) -> Result<OpPlan> {
        let raw = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        let v = json::parse(&raw).map_err(anyhow::Error::msg)?;
        OpPlan::from_json(&v)
    }

    /// Write the plan to the experiment's canonical `assignment.json`.
    pub fn save_for(&self, exp: &Experiment) -> Result<PathBuf> {
        let path = exp.dir.join("assignment.json");
        self.save(&path)?;
        Ok(path)
    }

    /// Load the experiment's stored plan.
    pub fn load_for(exp: &Experiment) -> Result<OpPlan> {
        OpPlan::load(exp.dir.join("assignment.json")).with_context(|| {
            format!("no plan for {:?}; run `search --exp {}` first", exp.name, exp.name)
        })
    }

    // -- Serving handoff ----------------------------------------------------

    /// Build the full engine OP ladder from this plan, applying the
    /// per-OP retraining overlays when present (`mode`: "none" | "bn" |
    /// "full").  The returned vector is in plan order, so its indices
    /// match [`ladder`](Self::ladder) and feed `OpTable::new` /
    /// `Backend::prepare` directly.
    pub fn load_operating_points(
        &self,
        exp: &Experiment,
        mode: &str,
    ) -> Result<Vec<OperatingPoint>> {
        let mut out = Vec::with_capacity(self.ops.len());
        for (i, op) in self.ops.iter().enumerate() {
            let overlay = match mode {
                "bn" => {
                    let p = exp.dir.join(format!("bn_op{i}.qten"));
                    p.exists().then_some(p)
                }
                "full" => {
                    let p = exp.dir.join(format!("params_full_op{i}.qten"));
                    p.exists().then_some(p)
                }
                _ => None,
            };
            if matches!(mode, "bn" | "full") && overlay.is_none() {
                crate::obs::log!(
                    Warn,
                    "OP{i}: no {mode} overlay found (run stage B retraining); using base params"
                );
            }
            out.push(pipeline::build_operating_point(
                exp,
                &op.name,
                self.assignment_map(i),
                op.relative_power,
                overlay.as_deref(),
            )?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Plan diffing
// ---------------------------------------------------------------------------

/// One layer whose assignment differs between two plans within one
/// operating point.  `None` marks a layer absent from that side.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDelta {
    pub layer: String,
    pub from: Option<usize>,
    pub to: Option<usize>,
}

/// One operating point compared across two plans (matched by ladder
/// position — plan index is the `OpTable`/`forward` index).
#[derive(Debug, Clone, PartialEq)]
pub struct OpDelta {
    /// `a_name -> b_name` (they usually agree; both are kept so renames
    /// are visible).
    pub name_a: Option<String>,
    pub name_b: Option<String>,
    pub power_a: Option<f64>,
    pub power_b: Option<f64>,
    /// Layers whose multiplier assignment changed, in `a`'s layer order
    /// (layers only in `b` follow).
    pub changed: Vec<LayerDelta>,
}

impl OpDelta {
    /// Relative-power delta `b - a` when both sides have this OP.
    pub fn power_delta(&self) -> Option<f64> {
        Some(self.power_b? - self.power_a?)
    }
}

/// Structured comparison of two [`OpPlan`]s — what `qos-nets plan diff`
/// prints: per-layer assignment deltas, per-OP power deltas, and the
/// provenance of each side.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanDiff {
    /// One entry per ladder position of the longer plan.
    pub ops: Vec<OpDelta>,
    /// Multiplier ids deployed only by `a` / only by `b`.
    pub subset_only_a: Vec<usize>,
    pub subset_only_b: Vec<usize>,
    pub provenance_a: Option<Provenance>,
    pub provenance_b: Option<Provenance>,
}

impl PlanDiff {
    /// True when the plans deploy identical assignments and powers
    /// (provenance may still differ — two planners can agree).
    pub fn is_same_deployment(&self) -> bool {
        self.subset_only_a.is_empty()
            && self.subset_only_b.is_empty()
            && self.ops.iter().all(|op| {
                op.changed.is_empty()
                    && op.name_a.is_some()
                    && op.name_b.is_some()
                    && op.power_delta().is_some_and(|d| d.abs() < 1e-12)
            })
    }

    /// Machine-readable form of the diff (`plan diff --json`): the same
    /// structure the human table prints — per-OP name/power deltas and
    /// layer-level assignment changes — plus the verdict, so CI and
    /// scripts can gate on `same_deployment` without scraping text.
    pub fn to_json(&self) -> Json {
        fn opt_num(v: Option<f64>) -> Json {
            match v {
                Some(x) => Json::num(x),
                None => Json::Null,
            }
        }
        fn opt_id(v: Option<usize>) -> Json {
            match v {
                Some(id) => Json::num(id as f64),
                None => Json::Null,
            }
        }
        fn opt_str(v: &Option<String>) -> Json {
            match v {
                Some(s) => Json::str(s.clone()),
                None => Json::Null,
            }
        }
        fn prov(p: &Option<Provenance>) -> Json {
            match p {
                Some(p) => Json::obj(vec![
                    ("planner", Json::str(p.planner.clone())),
                    ("seed", Json::num(p.seed as f64)),
                    ("config_hash", Json::str(p.config_hash.clone())),
                ]),
                None => Json::Null,
            }
        }
        fn ids(v: &[usize]) -> Json {
            Json::Arr(v.iter().map(|&id| Json::num(id as f64)).collect())
        }
        let ops = self
            .ops
            .iter()
            .enumerate()
            .map(|(i, op)| {
                let changed = op
                    .changed
                    .iter()
                    .map(|d| {
                        Json::obj(vec![
                            ("layer", Json::str(d.layer.clone())),
                            ("from", opt_id(d.from)),
                            ("to", opt_id(d.to)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("index", Json::num(i as f64)),
                    ("name_a", opt_str(&op.name_a)),
                    ("name_b", opt_str(&op.name_b)),
                    ("power_a", opt_num(op.power_a)),
                    ("power_b", opt_num(op.power_b)),
                    ("power_delta", opt_num(op.power_delta())),
                    ("changed", Json::Arr(changed)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("same_deployment", Json::Bool(self.is_same_deployment())),
            ("subset_only_a", ids(&self.subset_only_a)),
            ("subset_only_b", ids(&self.subset_only_b)),
            ("provenance_a", prov(&self.provenance_a)),
            ("provenance_b", prov(&self.provenance_b)),
            ("ops", Json::Arr(ops)),
        ])
    }
}

impl OpPlan {
    /// Compare `self` (side `a`) against `other` (side `b`): per-OP
    /// per-layer assignment deltas, power deltas, subset and provenance
    /// differences.  OPs are matched by ladder position, layers by
    /// name, so plans over different layer headers diff meaningfully.
    pub fn diff(&self, other: &OpPlan) -> PlanDiff {
        let n_ops = self.ops.len().max(other.ops.len());
        let mut ops = Vec::with_capacity(n_ops);
        for i in 0..n_ops {
            let a = self.ops.get(i);
            let b = other.ops.get(i);
            let amap = a.map(|_| self.assignment_map(i));
            let bmap = b.map(|_| other.assignment_map(i));
            let mut changed = Vec::new();
            // a's layer order first, then layers b alone knows about
            for layer in self.layer_names.iter().chain(
                other
                    .layer_names
                    .iter()
                    .filter(|l| !self.layer_names.contains(*l)),
            ) {
                let from = amap.as_ref().and_then(|m| m.get(layer.as_str()).copied());
                let to = bmap.as_ref().and_then(|m| m.get(layer.as_str()).copied());
                if from != to {
                    changed.push(LayerDelta {
                        layer: layer.clone(),
                        from,
                        to,
                    });
                }
            }
            ops.push(OpDelta {
                name_a: a.map(|o| o.name.clone()),
                name_b: b.map(|o| o.name.clone()),
                power_a: a.map(|o| o.relative_power),
                power_b: b.map(|o| o.relative_power),
                changed,
            });
        }
        let ids_a: BTreeSet<usize> = self.subset.iter().map(|m| m.id).collect();
        let ids_b: BTreeSet<usize> = other.subset.iter().map(|m| m.id).collect();
        PlanDiff {
            ops,
            subset_only_a: ids_a.difference(&ids_b).copied().collect(),
            subset_only_b: ids_b.difference(&ids_a).copied().collect(),
            provenance_a: self.provenance.clone(),
            provenance_b: other.provenance.clone(),
        }
    }

    /// Human name of a deployed multiplier id, from this plan's subset.
    pub fn mul_name(&self, id: usize) -> Option<&str> {
        self.subset
            .iter()
            .find(|m| m.id == id)
            .map(|m| m.name.as_str())
    }
}

// ---------------------------------------------------------------------------
// The trait + shared assembly
// ---------------------------------------------------------------------------

/// One mapping algorithm: consumes the shared [`PlanInputs`], produces
/// a typed [`OpPlan`].  Implementations must be deterministic in
/// `inputs.seed`.
pub trait Planner {
    /// Registry key (`search --algo <name>`).
    fn name(&self) -> &'static str;

    /// One-line description for tables and `--help`-style output.
    fn describe(&self) -> &'static str;

    /// Run the mapper.
    fn plan(&self, inputs: &PlanInputs) -> Result<OpPlan>;
}

/// FNV-1a over the canonical config description (see
/// [`Provenance::config_hash`]).  Uses the shared byte-wise form so
/// hashes stay identical to the ones stamped into existing plans.
fn config_hash(planner: &str, inputs: &PlanInputs) -> String {
    let desc = format!(
        "planner={planner};n={};scales={:?};seed={};layers={};muldb={}",
        inputs.n_multipliers,
        inputs.scales,
        inputs.seed,
        inputs.layer_names.len(),
        inputs.db.len()
    );
    format!("{:016x}", crate::util::hash::fnv1a_bytes(desc.bytes()))
}

/// Assemble a plan from per-OP assignment rows — the shared tail of
/// every planner: per-OP MAC-weighted power, the deployed subset,
/// provenance.  `budget` is the instance budget the plan is audited
/// against (`subset.len() <= budget` is asserted).
pub fn plan_from_assignments(
    planner: &str,
    inputs: &PlanInputs,
    assignments: Vec<Vec<usize>>,
    budget: usize,
    kmeans_inertia: Option<f64>,
) -> OpPlan {
    let ops: Vec<PlanOp> = assignments
        .into_iter()
        .enumerate()
        .map(|(i, a)| PlanOp {
            name: format!("op{i}"),
            scale: inputs.scales.get(i).copied().unwrap_or(1.0),
            relative_power: errmodel::relative_power(inputs.db, inputs.stats, &a),
            assignment: a,
        })
        .collect();
    let ids: BTreeSet<usize> = ops.iter().flat_map(|o| o.assignment.iter().copied()).collect();
    let subset: Vec<MulRef> = ids
        .into_iter()
        .map(|id| MulRef {
            id,
            name: inputs.db.specs[id].name.clone(),
            power: inputs.db.power(id),
        })
        .collect();
    assert!(
        subset.len() <= budget,
        "{planner}: {} distinct instances exceed the declared budget {budget}",
        subset.len()
    );
    OpPlan {
        version: PLAN_VERSION,
        experiment: inputs.experiment.clone(),
        n_multipliers: budget,
        layer_names: inputs.layer_names.to_vec(),
        subset,
        ops,
        kmeans_inertia,
        provenance: Some(Provenance {
            planner: planner.to_string(),
            seed: inputs.seed,
            config_hash: config_hash(planner, inputs),
        }),
    }
}

// ---------------------------------------------------------------------------
// Planners
// ---------------------------------------------------------------------------

/// The QoS-Nets clustered multi-OP search (paper Sec. 3.1 + 3.2),
/// wrapping [`selection::search`]: one shared instance subset across
/// every operating point — the paper's contribution.
pub struct QosNetsPlanner;

impl Planner for QosNetsPlanner {
    fn name(&self) -> &'static str {
        "qos"
    }

    fn describe(&self) -> &'static str {
        "QoS-Nets clustered search: preference vectors -> k-means -> shared n-instance subset across all OPs"
    }

    fn plan(&self, inputs: &PlanInputs) -> Result<OpPlan> {
        let cfg = SearchConfig {
            n_multipliers: inputs.n_multipliers,
            scales: inputs.scales.clone(),
            seed: inputs.seed,
            restarts: 8,
        };
        let sol = selection::search(inputs.db, inputs.se, inputs.sigma_g, inputs.stats, &cfg);
        Ok(plan_from_assignments(
            self.name(),
            inputs,
            sol.assignment,
            inputs.n_multipliers,
            Some(sol.kmeans_inertia),
        ))
    }
}

/// The ALWANN genetic tile-mapping baseline [Mrazek et al. 2019],
/// wrapping [`alwann::evolve`]: one evolved Pareto front, then one OP
/// per tolerance scale picked from it (cheapest front member feasible
/// at that scale).  Each pick re-tiles independently, so the honest
/// cross-OP budget is `n_multipliers * scales.len()` — exactly the
/// instance-sharing gap QoS-Nets closes.
pub struct AlwannPlanner;

impl Planner for AlwannPlanner {
    fn name(&self) -> &'static str {
        "alwann"
    }

    fn describe(&self) -> &'static str {
        "ALWANN NSGA-II tile mapping: evolved Pareto front, one OP per scale (no cross-OP instance sharing)"
    }

    fn plan(&self, inputs: &PlanInputs) -> Result<OpPlan> {
        let cfg = alwann::GaConfig {
            n_tiles: inputs.n_multipliers,
            seed: inputs.seed,
            ..Default::default()
        };
        let front = alwann::evolve(inputs.db, inputs.se, inputs.sigma_g, inputs.stats, &cfg);
        anyhow::ensure!(!front.is_empty(), "ALWANN evolution produced an empty front");
        let mut assignments = Vec::with_capacity(inputs.scales.len());
        for &s in &inputs.scales {
            let scaled: Vec<f64> = inputs.sigma_g.iter().map(|g| s * g).collect();
            let scored: Vec<(f64, &alwann::Evaluated)> = front
                .iter()
                .map(|e| {
                    (
                        baselines::quality_penalty(inputs.se, &scaled, &e.chromosome.assignment()),
                        e,
                    )
                })
                .collect();
            // cheapest feasible member; most accurate one as the
            // escape hatch (mirrors selection::pick_for_centroid)
            let best = scored
                .iter()
                .filter(|(pen, _)| *pen <= 1e-9)
                .min_by(|a, b| a.1.power.partial_cmp(&b.1.power).unwrap())
                .or_else(|| scored.iter().min_by(|a, b| a.0.partial_cmp(&b.0).unwrap()))
                .map(|(_, e)| *e)
                .expect("non-empty front");
            assignments.push(best.chromosome.assignment());
        }
        let budget = inputs.n_multipliers * inputs.scales.len().max(1);
        Ok(plan_from_assignments(self.name(), inputs, assignments, budget, None))
    }
}

/// Which simple baseline a [`BaselinePlanner`] adapts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// One multiplier for the whole network [De la Parra et al. 2020].
    Homogeneous,
    /// Unconstrained per-layer gradient search [Trommer et al. 2022].
    Gradient,
    /// LVRM-style divide & conquer at layer granularity.
    Lvrm,
    /// PNAM-style positive/negative error pairing.
    Pnam,
    /// TPM-style global threshold query.
    Tpm,
}

/// Adapter that lifts the free-function baselines in
/// [`crate::baselines`] into the [`Planner`] trait: one assignment per
/// tolerance scale, each produced by the wrapped mapper.
pub struct BaselinePlanner(pub Baseline);

impl BaselinePlanner {
    fn assignment_at(&self, inputs: &PlanInputs, scale: f64) -> Vec<usize> {
        let (db, se, sg, stats) = (inputs.db, inputs.se, inputs.sigma_g, inputs.stats);
        match self.0 {
            Baseline::Homogeneous => {
                let scaled: Vec<f64> = sg.iter().map(|g| scale * g).collect();
                let j = baselines::homogeneous_pick(db, se, &scaled, stats, 0.0);
                vec![j; se.l]
            }
            Baseline::Gradient => baselines::gradient_search(db, se, sg, scale),
            Baseline::Lvrm => baselines::lvrm_divide_conquer(db, se, sg, scale),
            Baseline::Pnam => baselines::pnam_mapping(db, se, sg, stats, scale),
            Baseline::Tpm => baselines::tpm_threshold(db, se, sg, scale),
        }
    }

    /// The honest instance budget of the wrapped mapper: homogeneous
    /// deploys one instance per OP; the per-layer mappers are
    /// unconstrained (up to one instance per (layer, OP), capped by the
    /// family size) — the impracticality QoS-Nets' n-constraint fixes.
    fn budget(&self, inputs: &PlanInputs) -> usize {
        let o = inputs.scales.len().max(1);
        match self.0 {
            Baseline::Homogeneous => o,
            _ => inputs.db.len().min(inputs.se.l * o),
        }
    }
}

impl Planner for BaselinePlanner {
    fn name(&self) -> &'static str {
        match self.0 {
            Baseline::Homogeneous => "homogeneous",
            Baseline::Gradient => "gradient",
            Baseline::Lvrm => "lvrm",
            Baseline::Pnam => "pnam",
            Baseline::Tpm => "tpm",
        }
    }

    fn describe(&self) -> &'static str {
        match self.0 {
            Baseline::Homogeneous => "one multiplier for the whole network (cheapest zero-penalty instance)",
            Baseline::Gradient => "unconstrained per-layer pick (cheapest tolerance-respecting instance per layer)",
            Baseline::Lvrm => "LVRM-style divide & conquer over layer segments",
            Baseline::Pnam => "PNAM-style positive/negative error-mean pairing",
            Baseline::Tpm => "TPM-style binary-searched global threshold",
        }
    }

    fn plan(&self, inputs: &PlanInputs) -> Result<OpPlan> {
        let assignments: Vec<Vec<usize>> = inputs
            .scales
            .iter()
            .map(|&s| self.assignment_at(inputs, s))
            .collect();
        Ok(plan_from_assignments(
            self.name(),
            inputs,
            assignments,
            self.budget(inputs),
            None,
        ))
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Resolve a registered planner by name (`search --algo <name>`).
pub fn planner_by_name(name: &str) -> Option<Box<dyn Planner>> {
    match name {
        "qos" | "qos-nets" | "qosnets" => Some(Box::new(QosNetsPlanner)),
        "alwann" | "ga" => Some(Box::new(AlwannPlanner)),
        "homogeneous" => Some(Box::new(BaselinePlanner(Baseline::Homogeneous))),
        "gradient" => Some(Box::new(BaselinePlanner(Baseline::Gradient))),
        "lvrm" => Some(Box::new(BaselinePlanner(Baseline::Lvrm))),
        "pnam" => Some(Box::new(BaselinePlanner(Baseline::Pnam))),
        "tpm" => Some(Box::new(BaselinePlanner(Baseline::Tpm))),
        _ => None,
    }
}

/// Every registered planner, in [`PLANNER_NAMES`] order.
pub fn all_planners() -> Vec<Box<dyn Planner>> {
    PLANNER_NAMES
        .iter()
        .map(|n| planner_by_name(n).expect("registered planner"))
        .collect()
}

/// End-to-end convenience for the CLI: build the shared inputs for an
/// experiment and run one registered planner.
pub fn plan_experiment(algo: &str, exp: &Experiment, db: &MulDb) -> Result<OpPlan> {
    let planner = planner_by_name(algo).with_context(|| {
        format!(
            "unknown planner {algo:?} (one of: {})",
            PLANNER_NAMES.join("|")
        )
    })?;
    let se = errmodel::sigma_e(db, &exp.stats);
    let inputs = PlanInputs::from_experiment(exp, db, &se);
    planner.plan(&inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_stats(n: usize) -> Vec<LayerStats> {
        (0..n)
            .map(|i| LayerStats {
                name: format!("l{i}"),
                act_hist: vec![1.0 / 256.0; 256],
                w_hist: vec![1.0 / 256.0; 256],
                k_fanin: 64 * (i + 1),
                macs_total: 10_000 * (i + 1),
                s_act: 0.02,
                z_act: 128,
                s_w: 0.01,
                z_w: 128,
                bn_scale: 0.5,
                out_rms: 1.0,
            })
            .collect()
    }

    fn fixture(l: usize) -> (MulDb, SigmaE, Vec<f64>, Vec<LayerStats>, Vec<String>) {
        let db = MulDb::generate();
        let stats = fake_stats(l);
        let se = errmodel::sigma_e(&db, &stats);
        let sigma_g: Vec<f64> = (0..l).map(|i| 0.05 + 0.03 * i as f64).collect();
        let names: Vec<String> = (0..l).map(|i| format!("l{i}")).collect();
        (db, se, sigma_g, stats, names)
    }

    #[test]
    fn qos_planner_matches_direct_search() {
        let (db, se, sigma_g, stats, names) = fixture(8);
        let inputs = PlanInputs {
            db: &db,
            se: &se,
            sigma_g: &sigma_g,
            stats: &stats,
            layer_names: &names,
            scales: vec![0.3, 1.0],
            n_multipliers: 4,
            seed: 1,
            experiment: "t".into(),
        };
        let plan = QosNetsPlanner.plan(&inputs).unwrap();
        let sol = selection::search(
            &db,
            &se,
            &sigma_g,
            &stats,
            &SearchConfig {
                n_multipliers: 4,
                scales: vec![0.3, 1.0],
                seed: 1,
                restarts: 8,
            },
        );
        assert_eq!(plan.ops.len(), 2);
        for (op, a) in plan.ops.iter().zip(&sol.assignment) {
            assert_eq!(&op.assignment, a);
        }
        assert_eq!(
            plan.subset.iter().map(|m| m.id).collect::<Vec<_>>(),
            sol.subset
        );
        assert_eq!(plan.kmeans_inertia, Some(sol.kmeans_inertia));
        let prov = plan.provenance.as_ref().unwrap();
        assert_eq!(prov.planner, "qos");
        assert_eq!(prov.seed, 1);
        assert!(!prov.config_hash.is_empty());
    }

    #[test]
    fn ladder_mirrors_ops_in_table_order() {
        let (db, se, sigma_g, stats, names) = fixture(6);
        let inputs = PlanInputs {
            db: &db,
            se: &se,
            sigma_g: &sigma_g,
            stats: &stats,
            layer_names: &names,
            scales: vec![0.3, 1.0],
            n_multipliers: 3,
            seed: 2,
            experiment: "t".into(),
        };
        let plan = QosNetsPlanner.plan(&inputs).unwrap();
        let ladder = plan.ladder();
        assert_eq!(ladder.len(), plan.ops.len());
        for (i, (e, op)) in ladder.iter().zip(&plan.ops).enumerate() {
            assert_eq!(e.table_index, i);
            assert_eq!(e.name, op.name);
            assert_eq!(e.power, op.relative_power);
        }
    }

    #[test]
    fn from_json_rejects_non_finite_power() {
        use crate::util::json::Json;
        let mk = |power: f64| {
            Json::obj(vec![
                ("version", Json::num(PLAN_VERSION as f64)),
                ("experiment", Json::str("t")),
                ("n_multipliers", Json::num(1.0)),
                ("layer_names", Json::Arr(vec![Json::str("l0")])),
                (
                    "operating_points",
                    Json::Arr(vec![Json::obj(vec![
                        ("name", Json::str("op0")),
                        ("relative_power", Json::num(power)),
                        ("assignment", Json::Obj(vec![("l0".to_string(), Json::num(0.0))])),
                    ])]),
                ),
            ])
        };
        assert!(OpPlan::from_json(&mk(0.7)).is_ok());
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let err = OpPlan::from_json(&mk(bad)).unwrap_err().to_string();
            assert!(err.contains("non-finite relative_power"), "{err}");
        }
    }

    #[test]
    fn config_hash_is_sensitive_to_seed_and_budget() {
        let (db, se, sigma_g, stats, names) = fixture(4);
        let mk = |seed: u64, n: usize| PlanInputs {
            db: &db,
            se: &se,
            sigma_g: &sigma_g,
            stats: &stats,
            layer_names: &names,
            scales: vec![1.0],
            n_multipliers: n,
            seed,
            experiment: "t".into(),
        };
        let a = config_hash("qos", &mk(0, 4));
        assert_eq!(a, config_hash("qos", &mk(0, 4)));
        assert_ne!(a, config_hash("qos", &mk(1, 4)));
        assert_ne!(a, config_hash("qos", &mk(0, 3)));
        assert_ne!(a, config_hash("tpm", &mk(0, 4)));
    }
}
