//! qos-nets — L3 coordinator CLI.
//!
//! The leader entrypoint: search / baselines / eval (native + PJRT) /
//! serve / report / selftest.  See `cli::USAGE`.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use qos_nets::baselines::{self, alwann};
use qos_nets::cli::{Args, USAGE};
use qos_nets::engine::OperatingPoint;
use qos_nets::errmodel;
use qos_nets::muldb::MulDb;
use qos_nets::pipeline::{self, Experiment};
use qos_nets::qos::{budget_trace, LadderEntry, QosConfig, QosController};
use qos_nets::runtime;
use qos_nets::server::{BatcherConfig, Server};
use qos_nets::util::json::{self, Json};
use qos_nets::util::tensorio;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let args = Args::parse(&argv);
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "muldb" => cmd_muldb(),
        "search" => cmd_search(args),
        "baselines" => cmd_baselines(args),
        "eval" => cmd_eval(args),
        "eval-pjrt" => cmd_eval_pjrt(args),
        "serve" => cmd_serve(args),
        "report" => cmd_report(args),
        "selftest" => cmd_selftest(args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn load_db(args: &Args) -> Result<Arc<MulDb>> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let db = if Path::new(artifacts).join("luts.bin").exists() {
        MulDb::load(artifacts)?
    } else {
        MulDb::generate()
    };
    Ok(Arc::new(db))
}

fn cmd_muldb() -> Result<()> {
    let db = MulDb::generate();
    println!(
        "{:>3} {:16} {:>8} {:>10} {:>10} {:>10}",
        "id", "name", "power", "MED", "MRED", "bias"
    );
    for s in &db.specs {
        let st = db.error_stats(s.id);
        println!(
            "{:>3} {:16} {:>8.3} {:>10.2} {:>10.5} {:>10.2}",
            s.id, s.name, s.power, st.med, st.mred, st.mean
        );
    }
    println!("digest: {}", db.digest());
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let exp = Experiment::load(artifacts, args.get_or("exp", "quick"))?;
    let db = load_db(args)?;
    let t0 = Instant::now();
    let (se, sol) = pipeline::run_search(&exp, &db);
    let path = pipeline::write_assignment(&exp, &db, &sol)?;
    println!(
        "[{}] search over {} layers x {} multipliers, {} operating points in {:?}",
        exp.name,
        se.l,
        se.m,
        exp.scales().len(),
        t0.elapsed()
    );
    println!(
        "subset ({} of n={}): {}",
        sol.subset.len(),
        exp.n_multipliers(),
        sol.subset
            .iter()
            .map(|&m| db.specs[m].name.clone())
            .collect::<Vec<_>>()
            .join(", ")
    );
    for (i, p) in sol.power.iter().enumerate() {
        println!(
            "  OP{i} (scale {:.2}): relative multiplication power {:.2}% (saving {:.1}%)",
            exp.scales()[i],
            100.0 * p,
            100.0 * (1.0 - p)
        );
    }
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_baselines(args: &Args) -> Result<()> {
    let exp = Experiment::load(args.get_or("artifacts", "artifacts"), args.get_or("exp", "quick"))?;
    let db = load_db(args)?;
    let se = errmodel::sigma_e(&db, &exp.stats);
    let scale = args.get_f64("scale", 1.0);

    let mut rows: Vec<(String, Vec<usize>)> = Vec::new();
    rows.push((
        "gradient_search[16]".into(),
        baselines::gradient_search(&db, &se, &exp.sigma_g, scale),
    ));
    rows.push((
        "lvrm_style[15]".into(),
        baselines::lvrm_divide_conquer(&db, &se, &exp.sigma_g, scale),
    ));
    rows.push((
        "pnam_style[14]".into(),
        baselines::pnam_mapping(&db, &se, &exp.sigma_g, &exp.stats, scale),
    ));
    rows.push((
        "tpm_style[13]".into(),
        baselines::tpm_threshold(&db, &se, &exp.sigma_g, scale),
    ));
    let hom = baselines::homogeneous_pick(&db, &se, &exp.sigma_g, &exp.stats, 0.0);
    rows.push((format!("homogeneous[2]:{}", db.specs[hom].name), vec![hom; se.l]));
    let ga = alwann::evolve(
        &db,
        &se,
        &exp.sigma_g,
        &exp.stats,
        &alwann::GaConfig {
            n_tiles: exp.n_multipliers(),
            seed: exp.seed(),
            ..Default::default()
        },
    );
    if let Some(best) = alwann::pick_feasible(&ga) {
        rows.push(("alwann_ga[9]".into(), best.chromosome.assignment()));
    }
    let (_, sol) = pipeline::run_search(&exp, &db);
    rows.push(("qos_nets(op_last)".into(), sol.assignment.last().unwrap().clone()));

    println!(
        "{:28} {:>8} {:>9} {:>7} {:>6}",
        "method", "power", "penalty", "#AMs", "layers"
    );
    for (name, a) in &rows {
        let power = errmodel::relative_power(&db, &exp.stats, a);
        let pen = baselines::quality_penalty(&se, &exp.sigma_g, a);
        let distinct: std::collections::BTreeSet<usize> = a.iter().cloned().collect();
        println!(
            "{:28} {:>7.2}% {:>9.4} {:>7} {:>6}",
            name,
            100.0 * power,
            pen,
            distinct.len(),
            a.len()
        );
    }
    Ok(())
}

/// Build the OP list for an experiment from assignment.json (+ overlays).
fn load_ops(exp: &Experiment, mode: &str) -> Result<Vec<OperatingPoint>> {
    let assignments = pipeline::read_assignment(exp)?;
    let mut ops = Vec::new();
    for (i, (_scale, power, amap)) in assignments.into_iter().enumerate() {
        let overlay = match mode {
            "bn" => {
                let p = exp.dir.join(format!("bn_op{i}.qten"));
                p.exists().then_some(p)
            }
            "full" => {
                let p = exp.dir.join(format!("params_full_op{i}.qten"));
                p.exists().then_some(p)
            }
            _ => None,
        };
        if matches!(mode, "bn" | "full") && overlay.is_none() {
            eprintln!(
                "warning: OP{i}: no {mode} overlay found (run stage B retraining); using base params"
            );
        }
        ops.push(pipeline::build_operating_point(
            exp,
            &format!("op{i}"),
            amap,
            power,
            overlay.as_deref(),
        )?);
    }
    Ok(ops)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let exp = Experiment::load(args.get_or("artifacts", "artifacts"), args.get_or("exp", "quick"))?;
    let db = load_db(args)?;
    let mode = args.get_or("mode", "bn");
    let batch = args.get_usize("batch", 32);
    let limit = args.get("limit").and_then(|s| s.parse().ok());

    let exact = pipeline::exact_operating_point(&exp)?;
    let base = pipeline::eval_operating_point(&exp, &db, &exact, batch, limit)?;
    println!(
        "[{}] baseline (8-bit, exact mult): top1={:.2}% top5={:.2}% (n={})",
        exp.name,
        100.0 * base.top1,
        100.0 * base.top5,
        base.n
    );

    for (i, op) in load_ops(&exp, mode)?.iter().enumerate() {
        let t0 = Instant::now();
        let r = pipeline::eval_operating_point(&exp, &db, op, batch, limit)?;
        println!(
            "[{}] OP{i} ({} mode): power={:.2}% top1={:.2}% ({:+.2}pp) top5={:.2}% ({:+.2}pp) [{:?}]",
            exp.name,
            mode,
            100.0 * op.relative_power,
            100.0 * r.top1,
            100.0 * (r.top1 - base.top1),
            100.0 * r.top5,
            100.0 * (r.top5 - base.top5),
            t0.elapsed()
        );
    }
    Ok(())
}

fn cmd_eval_pjrt(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let exp = Experiment::load(artifacts, args.get_or("exp", "quick"))?;
    let rt = runtime::Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let model = rt.load(&exp.dir, "model")?;
    let batch = model.export_batch;
    let limit = args.get_usize("limit", 64);

    let (lr_u, lr_v, max_rank) = runtime::load_lowrank(artifacts)?;
    let tensors = exp.load_params_tensors()?;
    let assignments = pipeline::read_assignment(&exp)?;
    let (images, labels) = exp.load_testset()?;
    let elems = exp.image_elems();
    let classes = exp.num_classes();

    for (i, (_s, power, amap)) in assignments.iter().enumerate() {
        let overlay_path = exp.dir.join(format!("bn_op{i}.qten"));
        let overlay = if overlay_path.exists() {
            tensorio::load(&overlay_path)?
        } else {
            HashMap::new()
        };
        let bufs =
            runtime::build_op_buffers(&model, amap, &lr_u, &lr_v, max_rank, &tensors, &overlay)?;
        let n = (limit.min(labels.len()) / batch).max(1) * batch;
        let mut top1 = 0usize;
        let t0 = Instant::now();
        for s in (0..n).step_by(batch) {
            let x = runtime::literal_f32(
                &images[s * elems..(s + batch) * elems],
                &[
                    batch,
                    exp.graph.input_shape[0],
                    exp.graph.input_shape[1],
                    exp.graph.input_shape[2],
                ],
            )?;
            let logits = model.execute_with_op(x, &bufs)?;
            for b in 0..batch {
                let row = &logits[b * classes..(b + 1) * classes];
                let arg = row
                    .iter()
                    .enumerate()
                    .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap();
                if arg == labels[s + b] as usize {
                    top1 += 1;
                }
            }
        }
        println!(
            "[{}] PJRT OP{i}: power={:.2}% top1={:.2}% (n={}) in {:?}",
            exp.name,
            100.0 * power,
            100.0 * top1 as f64 / n as f64,
            n,
            t0.elapsed()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let exp = Experiment::load(args.get_or("artifacts", "artifacts"), args.get_or("exp", "quick"))?;
    let db = load_db(args)?;
    let mode = args.get_or("mode", "bn");
    let secs = args.get_f64("secs", 3.0);
    let rate = args.get_f64("rate", 200.0); // requests/second
    let trace_kind = args.get_or("trace", "sine");

    let ops = load_ops(&exp, mode)?;
    anyhow::ensure!(!ops.is_empty(), "no operating points; run `search` first");
    let ladder: Vec<LadderEntry> = ops
        .iter()
        .map(|o| LadderEntry {
            name: o.name.clone(),
            power: o.relative_power,
        })
        .collect();
    let mut controller = QosController::new(ladder, QosConfig::default());

    let server = Server::start(
        exp.graph.clone(),
        db.clone(),
        ops,
        BatcherConfig {
            max_batch: args.get_usize("max-batch", 16),
            max_wait: Duration::from_millis(4),
            workers: args.get_usize("workers", 2),
        },
    )?;

    let (images, _) = exp.load_testset()?;
    let elems = exp.image_elems();
    let n_img = images.len() / elems;

    let steps = (secs * 20.0) as usize; // budget update every 50 ms
    let trace = budget_trace(trace_kind, steps, exp.seed());
    let mut receivers = Vec::new();
    let mut rng = qos_nets::util::rng::Rng::new(42);
    let started = Instant::now();
    let mut submitted = 0u64;
    let mut energy = 0.0f64; // sum of per-request relative power
    for (step, &budget) in trace.iter().enumerate() {
        if let Some(idx) = controller.observe(budget, Instant::now()) {
            server.set_operating_point(idx);
        }
        let step_end = started + Duration::from_millis(50 * (step as u64 + 1));
        while Instant::now() < step_end {
            let i = rng.below(n_img);
            let img = images[i * elems..(i + 1) * elems].to_vec();
            receivers.push(server.submit(img)?);
            submitted += 1;
            energy += server.ops()[server.operating_point()].relative_power;
            let gap = Duration::from_secs_f64(rng.exp(rate));
            std::thread::sleep(gap.min(Duration::from_millis(20)));
        }
    }
    // drain
    let mut ok = 0u64;
    for rx in receivers {
        if rx.recv_timeout(Duration::from_secs(30)).is_ok() {
            ok += 1;
        }
    }
    let wall = started.elapsed();
    let m = server.shutdown();
    println!(
        "[{}] serve: {} requests in {:.2}s ({:.1} req/s), {} completed",
        exp.name,
        submitted,
        wall.as_secs_f64(),
        submitted as f64 / wall.as_secs_f64(),
        ok
    );
    println!(
        "  latency: mean={:.2}ms p50<={:.2}ms p99<={:.2}ms max={:.2}ms  queue mean={:.2}ms",
        m.latency.mean_us() / 1e3,
        m.latency.percentile_us(50.0) as f64 / 1e3,
        m.latency.percentile_us(99.0) as f64 / 1e3,
        m.latency.max_us() as f64 / 1e3,
        m.queue_latency.mean_us() / 1e3,
    );
    println!(
        "  mean batch={:.2}  OP switches={} budget violations={}",
        m.mean_batch(),
        controller.switches,
        controller.budget_violations
    );
    for (i, c) in m.per_op_requests.iter().enumerate() {
        println!(
            "  OP{i}: {c} requests ({:.1}%)",
            100.0 * *c as f64 / m.completed.max(1) as f64
        );
    }
    println!(
        "  mean relative multiplication power over run: {:.2}%",
        100.0 * energy / submitted.max(1) as f64
    );
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("fig3");
    let exp = Experiment::load(args.get_or("artifacts", "artifacts"), args.get_or("exp", "quick"))?;
    let db = load_db(args)?;
    match which {
        "fig1" => {
            // sigma_g vector + sigma_e matrix dump (the Fig. 1 pipeline output)
            let se = errmodel::sigma_e(&db, &exp.stats);
            let mut rows = Vec::new();
            for (k, name) in exp.layer_names.iter().enumerate() {
                rows.push(Json::obj(vec![
                    ("layer", Json::str(name.clone())),
                    ("sigma_g", Json::num(exp.sigma_g[k])),
                    (
                        "sigma_e",
                        Json::Arr(se.column(k).into_iter().map(Json::num).collect()),
                    ),
                ]));
            }
            println!("{}", json::to_string_pretty(&Json::Arr(rows)));
        }
        "fig2" => {
            // scaled preference vectors + cluster assignment per (OP, layer)
            let se = errmodel::sigma_e(&db, &exp.stats);
            let usable =
                qos_nets::selection::usable_multipliers(&se, &exp.sigma_g, &exp.scales());
            let points =
                qos_nets::selection::preference_vectors(&se, &exp.sigma_g, &exp.scales(), &usable);
            let (_, sol) = pipeline::run_search(&exp, &db);
            let l = exp.layer_names.len();
            let mut rows = Vec::new();
            for (idx, p) in points.iter().enumerate() {
                rows.push(Json::obj(vec![
                    ("op", Json::num((idx / l) as f64)),
                    ("layer", Json::str(exp.layer_names[idx % l].clone())),
                    (
                        "preference",
                        Json::Arr(p.iter().map(|&x| Json::num(x)).collect()),
                    ),
                    (
                        "multiplier",
                        Json::num(sol.assignment[idx / l][idx % l] as f64),
                    ),
                ]));
            }
            println!("{}", json::to_string_pretty(&Json::Arr(rows)));
        }
        "fig3" => {
            // per-layer multiplier assignment per OP + power lines (paper Fig. 3)
            let assignments = pipeline::read_assignment(&exp)?;
            anyhow::ensure!(!assignments.is_empty(), "run `search` first");
            for (i, (scale, power, amap)) in assignments.iter().enumerate() {
                println!("# OP{i} scale={scale} relative_power={:.4}", power);
                println!("layer_index,layer,multiplier_id,multiplier,power");
                for (k, name) in exp.layer_names.iter().enumerate() {
                    let mid = *amap.get(name).unwrap_or(&0);
                    println!("{k},{name},{mid},{},{:.3}", db.specs[mid].name, db.power(mid));
                }
                println!();
            }
        }
        other => bail!("unknown report {other:?} (fig1|fig2|fig3)"),
    }
    Ok(())
}

/// Integration self-test: PJRT kernel artifact vs native lutmm, and PJRT
/// model artifact vs native engine on a handful of images.
fn cmd_selftest(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let exp = Experiment::load(artifacts, args.get_or("exp", "quick"))?;
    let db = load_db(args)?;
    let rt = runtime::Runtime::cpu()?;

    // --- kernel artifact vs native hot loop (bit-exact) ---
    let kernel = rt.load(&exp.dir, "kernel")?;
    let (m, k, n) = {
        let s = &kernel.signature;
        (s[0].shape[0], s[0].shape[1], s[1].shape[1])
    };
    let mut rng = qos_nets::util::rng::Rng::new(1);
    let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32).collect();
    let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
    let mid = 9; // bam7
    let (za, zw, zo) = (128i32, 117i32, 30i32);
    let s_req = 1e-4f32;
    let inputs = vec![
        runtime::literal_i32(&a, &[m, k])?,
        runtime::literal_i32(&w, &[k, n])?,
        runtime::literal_i32(db.lut(mid), &[256, 256])?,
        runtime::literal_f32(&[s_req], &[1])?,
        runtime::literal_i32(&[za, zw, zo], &[3])?,
    ];
    let pjrt_out = kernel.execute_i32(&inputs)?;

    // native recompute
    use qos_nets::engine::lutmm;
    let mut at = vec![0i32; k * m];
    for mm in 0..m {
        for kk in 0..k {
            at[kk * m + mm] = a[mm * k + kk];
        }
    }
    let mut wt = vec![0i32; n * k];
    for kk in 0..k {
        for nn in 0..n {
            wt[nn * k + kk] = w[kk * n + nn];
        }
    }
    let wlut = lutmm::transpose_lut(db.lut(mid));
    let mut acc = vec![0i32; m * n];
    lutmm::lut_matmul_acc(&at, &wt, &wlut, m, k, n, &mut acc);
    let (sa, sw) = lutmm::code_sums(&at, &wt, m, k, n);
    lutmm::apply_corrections(&mut acc, &sa, &sw, m, k, n, za, zw);
    let native: Vec<i32> = acc
        .iter()
        .map(|&c| {
            let q = (c as f32 * s_req).round_ties_even() + zo as f32;
            q.clamp(0.0, 255.0) as i32
        })
        .collect();
    anyhow::ensure!(pjrt_out == native, "kernel artifact != native lutmm");
    println!("selftest: PJRT kernel artifact == native LUT matmul ({m}x{k}x{n}) OK");

    // --- model artifact vs native engine (surrogate-vs-exact tolerance) ---
    let model = rt.load(&exp.dir, "model")?;
    let batch = model.export_batch;
    let (images, labels) = exp.load_testset()?;
    let elems = exp.image_elems();
    let classes = exp.num_classes();
    let (lr_u, lr_v, max_rank) = runtime::load_lowrank(artifacts)?;
    let tensors = exp.load_params_tensors()?;
    let assignments = pipeline::read_assignment(&exp).unwrap_or_default();
    let amap: HashMap<String, usize> = if assignments.is_empty() {
        exp.layer_names.iter().map(|l| (l.clone(), 0usize)).collect()
    } else {
        assignments.last().unwrap().2.clone()
    };
    let bufs =
        runtime::build_op_buffers(&model, &amap, &lr_u, &lr_v, max_rank, &tensors, &HashMap::new())?;
    let x = runtime::literal_f32(
        &images[..batch * elems],
        &[
            batch,
            exp.graph.input_shape[0],
            exp.graph.input_shape[1],
            exp.graph.input_shape[2],
        ],
    )?;
    let pjrt_logits = model.execute_with_op(x, &bufs)?;

    let op = pipeline::build_operating_point(&exp, "st", amap, 1.0, None)?;
    let mut eng = qos_nets::engine::Engine::new(exp.graph.clone(), db.clone());
    let native_logits = eng.forward(&op, &images[..batch * elems], batch)?;
    let mut agree = 0;
    for b in 0..batch {
        let arg = |v: &[f32]| {
            v.iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        };
        let p = arg(&pjrt_logits[b * classes..(b + 1) * classes]);
        let nl = arg(&native_logits[b * classes..(b + 1) * classes]);
        if p == nl {
            agree += 1;
        }
    }
    println!(
        "selftest: PJRT model vs native engine top-1 agreement {agree}/{batch} (labels {:?})",
        &labels[..batch.min(4)]
    );
    anyhow::ensure!(agree * 10 >= batch * 7, "PJRT/native agreement too low");
    println!("selftest OK");
    Ok(())
}
