//! qos-nets — L3 coordinator CLI.
//!
//! Thin entrypoint: flag parsing lives in `qos_nets::cli`, the
//! subcommand implementations in `qos_nets::cli::commands` (search /
//! baselines / eval / serve / report / selftest, each generic over the
//! unified inference `Backend`).  See `cli::USAGE`.

use qos_nets::cli::{commands, Args, USAGE};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let args = Args::parse(&argv);
    if let Err(e) = commands::dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
