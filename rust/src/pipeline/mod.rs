//! End-to-end orchestration: glue between exported artifacts, the search
//! algorithms, the unified inference backends and the report generators.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::backend::{self, Backend, NativeBackend};
use crate::engine::OperatingPoint;
use crate::errmodel::{self, SigmaE};
use crate::muldb::MulDb;
use crate::nn::{self, Graph, LayerStats, ModelParams};
use crate::selection::{self, SearchConfig, Solution};
use crate::util::json::{self, Json};
use crate::util::tensorio::{self, Tensor};

/// Everything stage A exported for one experiment.
pub struct Experiment {
    pub name: String,
    pub dir: PathBuf,
    pub artifacts: PathBuf,
    pub graph: Arc<Graph>,
    pub layer_names: Vec<String>,
    pub sigma_g: Vec<f64>,
    pub stats: Vec<LayerStats>,
    pub config: Json,
}

impl Experiment {
    pub fn load(artifacts: impl AsRef<Path>, name: &str) -> Result<Self> {
        let artifacts = artifacts.as_ref().to_path_buf();
        let dir = artifacts.join(name);
        let graph = Arc::new(Graph::load(dir.join("graph.json"))?);
        let (layer_names, mut sigma_g) = nn::load_sensitivity(dir.join("sensitivity.json"))?;
        // deterministic-error safety factor (see configs.py tolerance_factor)
        let exp_raw_cfg = std::fs::read_to_string(dir.join("exp.json"))?;
        let exp_cfg = json::parse(&exp_raw_cfg).map_err(anyhow::Error::msg)?;
        let kappa = exp_cfg
            .get("config")
            .and_then(|c| c.get("tolerance_factor"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.3);
        for s in sigma_g.iter_mut() {
            *s *= kappa;
        }
        let stats = nn::load_layer_stats(dir.join("layer_stats.json"), &layer_names)?;
        let exp_raw = std::fs::read_to_string(dir.join("exp.json"))?;
        let exp = json::parse(&exp_raw).map_err(anyhow::Error::msg)?;
        let config = exp.req("config").map_err(anyhow::Error::msg)?.clone();
        Ok(Experiment {
            name: name.to_string(),
            dir,
            artifacts,
            graph,
            layer_names,
            sigma_g,
            stats,
            config,
        })
    }

    pub fn scales(&self) -> Vec<f64> {
        self.config
            .get("scales")
            .and_then(|v| v.f64_vec())
            .unwrap_or_else(|| vec![1.0])
    }

    pub fn n_multipliers(&self) -> usize {
        self.config
            .get("n_multipliers")
            .and_then(|v| v.as_usize())
            .unwrap_or(4)
    }

    pub fn seed(&self) -> u64 {
        self.config.get("seed").and_then(|v| v.as_usize()).unwrap_or(0) as u64
    }

    pub fn num_classes(&self) -> usize {
        // classifier output width
        self.graph
            .approx_layers()
            .last()
            .map(|n| n.cout)
            .unwrap_or(10)
    }

    pub fn image_elems(&self) -> usize {
        self.graph.input_shape.iter().product()
    }

    pub fn load_testset(&self) -> Result<(Vec<f32>, Vec<i32>)> {
        let t = tensorio::load(self.dir.join("testset.qten"))?;
        let images = t.get("images").context("images")?.as_f32()?.to_vec();
        let labels = t.get("labels").context("labels")?.as_i32()?.to_vec();
        Ok((images, labels))
    }

    pub fn load_params_tensors(&self) -> Result<HashMap<String, Tensor>> {
        tensorio::load(self.dir.join("params.qten"))
    }
}

/// Run the QoS-Nets search for an experiment; returns (sigma_e, solution).
pub fn run_search(exp: &Experiment, db: &MulDb) -> (SigmaE, Solution) {
    let se = errmodel::sigma_e(db, &exp.stats);
    let cfg = SearchConfig {
        n_multipliers: exp.n_multipliers(),
        scales: exp.scales(),
        seed: exp.seed(),
        restarts: 8,
    };
    let sol = selection::search(db, &se, &exp.sigma_g, &exp.stats, &cfg);
    (se, sol)
}

/// assignment.json payload consumed by the Python stage B and by `eval`.
pub fn solution_to_json(exp: &Experiment, db: &MulDb, sol: &Solution) -> Json {
    let scales = exp.scales();
    let ops: Vec<Json> = sol
        .assignment
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let amap: Vec<(String, Json)> = exp
                .layer_names
                .iter()
                .zip(a)
                .map(|(name, &mid)| (name.clone(), Json::num(mid as f64)))
                .collect();
            Json::obj(vec![
                ("index", Json::num(i as f64)),
                ("scale", Json::num(scales[i])),
                ("relative_power", Json::num(sol.power[i])),
                ("assignment", Json::Obj(amap)),
            ])
        })
        .collect();
    let subset: Vec<Json> = sol
        .subset
        .iter()
        .map(|&mid| {
            Json::obj(vec![
                ("id", Json::num(mid as f64)),
                ("name", Json::str(db.specs[mid].name.clone())),
                ("power", Json::num(db.power(mid))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("experiment", Json::str(exp.name.clone())),
        ("n_multipliers", Json::num(exp.n_multipliers() as f64)),
        ("subset", Json::Arr(subset)),
        ("operating_points", Json::Arr(ops)),
        ("kmeans_inertia", Json::num(sol.kmeans_inertia)),
    ])
}

pub fn write_assignment(exp: &Experiment, db: &MulDb, sol: &Solution) -> Result<PathBuf> {
    let path = exp.dir.join("assignment.json");
    std::fs::write(&path, json::to_string_pretty(&solution_to_json(exp, db, sol)))?;
    Ok(path)
}

/// Read assignment.json back (ours or hand-edited).
pub fn read_assignment(exp: &Experiment) -> Result<Vec<(f64, f64, HashMap<String, usize>)>> {
    let raw = std::fs::read_to_string(exp.dir.join("assignment.json"))?;
    let v = json::parse(&raw).map_err(anyhow::Error::msg)?;
    let mut out = Vec::new();
    for op in v
        .req("operating_points")
        .map_err(anyhow::Error::msg)?
        .as_arr()
        .unwrap_or(&[])
    {
        let scale = op.get("scale").and_then(|x| x.as_f64()).unwrap_or(1.0);
        let power = op.get("relative_power").and_then(|x| x.as_f64()).unwrap_or(1.0);
        let mut amap = HashMap::new();
        if let Some(Json::Obj(pairs)) = op.get("assignment") {
            for (k, val) in pairs {
                amap.insert(k.clone(), val.as_usize().unwrap_or(0));
            }
        }
        out.push((scale, power, amap));
    }
    Ok(out)
}

/// Build an engine OperatingPoint from an assignment map + optional BN
/// overlay file (bn_op{idx}.qten from stage B).
pub fn build_operating_point(
    exp: &Experiment,
    name: &str,
    assignment: HashMap<String, usize>,
    relative_power: f64,
    overlay: Option<&Path>,
) -> Result<OperatingPoint> {
    let params = ModelParams::load(&exp.graph, exp.dir.join("params.qten"), overlay)?;
    Ok(OperatingPoint {
        name: name.to_string(),
        assignment,
        params,
        relative_power,
    })
}

/// Build the full OP ladder for an experiment from assignment.json,
/// applying the per-OP retraining overlays when present (`mode`:
/// "none" | "bn" | "full").
pub fn load_operating_points(exp: &Experiment, mode: &str) -> Result<Vec<OperatingPoint>> {
    let assignments = read_assignment(exp)?;
    let mut ops = Vec::new();
    for (i, (_scale, power, amap)) in assignments.into_iter().enumerate() {
        let overlay = match mode {
            "bn" => {
                let p = exp.dir.join(format!("bn_op{i}.qten"));
                p.exists().then_some(p)
            }
            "full" => {
                let p = exp.dir.join(format!("params_full_op{i}.qten"));
                p.exists().then_some(p)
            }
            _ => None,
        };
        if matches!(mode, "bn" | "full") && overlay.is_none() {
            eprintln!(
                "warning: OP{i}: no {mode} overlay found (run stage B retraining); using base params"
            );
        }
        ops.push(build_operating_point(
            exp,
            &format!("op{i}"),
            amap,
            power,
            overlay.as_deref(),
        )?);
    }
    Ok(ops)
}

/// Evaluate one operating point on the exported test set (native
/// backend; `backend::evaluate` is the shared implementation).
pub fn eval_operating_point(
    exp: &Experiment,
    db: &Arc<MulDb>,
    op: &OperatingPoint,
    batch: usize,
    limit: Option<usize>,
) -> Result<backend::EvalResult> {
    let (images, labels) = exp.load_testset()?;
    let mut be = NativeBackend::new(exp.graph.clone(), db.clone());
    be.prepare(std::slice::from_ref(op))?;
    backend::evaluate(&mut be, 0, &images, &labels, exp.image_elems(), batch, limit)
}

/// The exact-everywhere baseline OP (quantized but accurate multipliers).
pub fn exact_operating_point(exp: &Experiment) -> Result<OperatingPoint> {
    let assignment: HashMap<String, usize> =
        exp.layer_names.iter().map(|n| (n.clone(), 0usize)).collect();
    build_operating_point(exp, "exact", assignment, 1.0, None)
}
