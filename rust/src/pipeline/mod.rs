//! End-to-end orchestration: loading stage-A artifacts into an
//! [`Experiment`] and turning stored assignments into engine
//! [`OperatingPoint`]s.  Planning itself (search algorithms and the
//! `assignment.json` round trip) lives behind the [`crate::plan`]
//! `Planner`/`OpPlan` seam; this module keeps the artifact-level
//! building blocks those plans are materialized with.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::backend::{self, Backend, NativeBackend};
use crate::engine::OperatingPoint;
use crate::muldb::MulDb;
use crate::nn::{self, Graph, LayerStats, ModelParams};
use crate::util::json::{self, Json};
use crate::util::tensorio::{self, Tensor};

/// Everything stage A exported for one experiment.
pub struct Experiment {
    pub name: String,
    pub dir: PathBuf,
    pub artifacts: PathBuf,
    pub graph: Arc<Graph>,
    pub layer_names: Vec<String>,
    pub sigma_g: Vec<f64>,
    pub stats: Vec<LayerStats>,
    pub config: Json,
}

impl Experiment {
    pub fn load(artifacts: impl AsRef<Path>, name: &str) -> Result<Self> {
        let artifacts = artifacts.as_ref().to_path_buf();
        let dir = artifacts.join(name);
        let graph = Arc::new(Graph::load(dir.join("graph.json"))?);
        let (layer_names, mut sigma_g) = nn::load_sensitivity(dir.join("sensitivity.json"))?;
        // exp.json is read and parsed exactly once; both the tolerance
        // factor and the retained config come from the same parse
        let exp_raw = std::fs::read_to_string(dir.join("exp.json"))?;
        let exp = json::parse(&exp_raw).map_err(anyhow::Error::msg)?;
        let config = exp.req("config").map_err(anyhow::Error::msg)?.clone();
        // deterministic-error safety factor (see configs.py tolerance_factor)
        let kappa = config
            .get("tolerance_factor")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.3);
        for s in sigma_g.iter_mut() {
            *s *= kappa;
        }
        let stats = nn::load_layer_stats(dir.join("layer_stats.json"), &layer_names)?;
        Ok(Experiment {
            name: name.to_string(),
            dir,
            artifacts,
            graph,
            layer_names,
            sigma_g,
            stats,
            config,
        })
    }

    pub fn scales(&self) -> Vec<f64> {
        self.config
            .get("scales")
            .and_then(|v| v.f64_vec())
            .unwrap_or_else(|| vec![1.0])
    }

    pub fn n_multipliers(&self) -> usize {
        self.config
            .get("n_multipliers")
            .and_then(|v| v.as_usize())
            .unwrap_or(4)
    }

    pub fn seed(&self) -> u64 {
        self.config.get("seed").and_then(|v| v.as_usize()).unwrap_or(0) as u64
    }

    pub fn num_classes(&self) -> usize {
        // classifier output width
        self.graph
            .approx_layers()
            .last()
            .map(|n| n.cout)
            .unwrap_or(10)
    }

    pub fn image_elems(&self) -> usize {
        self.graph.input_shape.iter().product()
    }

    pub fn load_testset(&self) -> Result<(Vec<f32>, Vec<i32>)> {
        let t = tensorio::load(self.dir.join("testset.qten"))?;
        let images = t.get("images").context("images")?.as_f32()?.to_vec();
        let labels = t.get("labels").context("labels")?.as_i32()?.to_vec();
        Ok((images, labels))
    }

    pub fn load_params_tensors(&self) -> Result<HashMap<String, Tensor>> {
        tensorio::load(self.dir.join("params.qten"))
    }
}

/// Build an engine OperatingPoint from an assignment map + optional BN
/// overlay file (bn_op{idx}.qten from stage B).
pub fn build_operating_point(
    exp: &Experiment,
    name: &str,
    assignment: HashMap<String, usize>,
    relative_power: f64,
    overlay: Option<&Path>,
) -> Result<OperatingPoint> {
    let params = ModelParams::load(&exp.graph, exp.dir.join("params.qten"), overlay)?;
    Ok(OperatingPoint {
        name: name.to_string(),
        assignment,
        params,
        relative_power,
    })
}

/// Evaluate one operating point on the exported test set (native
/// backend; `backend::evaluate` is the shared implementation).
pub fn eval_operating_point(
    exp: &Experiment,
    db: &Arc<MulDb>,
    op: &OperatingPoint,
    batch: usize,
    limit: Option<usize>,
) -> Result<backend::EvalResult> {
    let (images, labels) = exp.load_testset()?;
    let mut be = NativeBackend::new(exp.graph.clone(), db.clone());
    be.prepare(std::slice::from_ref(op))?;
    backend::evaluate(&mut be, 0, &images, &labels, exp.image_elems(), batch, limit)
}

/// The exact-everywhere baseline OP (quantized but accurate multipliers).
pub fn exact_operating_point(exp: &Experiment) -> Result<OperatingPoint> {
    let assignment: HashMap<String, usize> =
        exp.layer_names.iter().map(|n| (n.clone(), 0usize)).collect();
    build_operating_point(exp, "exact", assignment, 1.0, None)
}
