//! `qos-nets` subcommand implementations, one module per command.
//!
//! Every inference-carrying command (`eval`, `serve`) goes through the
//! unified [`crate::backend::Backend`] trait, selected with
//! `--backend native|pjrt`; `dispatch` is the single entry the binary
//! calls.

mod baselines;
mod eval;
mod muldb;
mod report;
mod search;
mod selftest;
mod serve;

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::cli::{Args, USAGE};
use crate::muldb::MulDb;
use crate::pipeline::Experiment;

pub fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "muldb" => muldb::run(args),
        "search" => search::run(args),
        "baselines" => baselines::run(args),
        "eval" => eval::run(args),
        "eval-pjrt" => {
            eprintln!(
                "note: `eval-pjrt` is deprecated; use `eval --backend pjrt` \
                 (keeping the old default of --limit 64)"
            );
            eval::run_with_backend(args, "pjrt", Some(64))
        }
        "serve" => serve::run(args),
        "report" => report::run(args),
        "selftest" => selftest::run(args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// The multiplier family: the exported LUT bundle when present, else the
/// generated in-memory family (identical content, see `MulDb::digest`).
pub(crate) fn load_db(args: &Args) -> Result<Arc<MulDb>> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let db = if Path::new(artifacts).join("luts.bin").exists() {
        MulDb::load(artifacts)?
    } else {
        MulDb::generate()
    };
    Ok(Arc::new(db))
}

pub(crate) fn load_experiment(args: &Args) -> Result<Experiment> {
    Experiment::load(args.get_or("artifacts", "artifacts"), args.get_or("exp", "quick"))
}
