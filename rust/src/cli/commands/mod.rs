//! `qos-nets` subcommand implementations, one module per command.
//!
//! Every inference-carrying command (`eval`, `serve`, `worker`) goes
//! through the unified [`crate::backend::Backend`] trait, selected with
//! `--backend native|pjrt` (plus `--fleet host:port,...` to serve or
//! evaluate over remote fleet workers); `dispatch` is the single entry
//! the binary calls.

mod baselines;
mod bench;
mod eval;
mod muldb;
mod plan;
mod report;
mod search;
mod selftest;
mod serve;
mod worker;

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::cli::{Args, USAGE};
use crate::engine::lutmm::{self, LutKernel};
use crate::muldb::MulDb;
use crate::pipeline::Experiment;

pub fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "muldb" => muldb::run(args),
        "search" => search::run(args),
        "baselines" => baselines::run(args),
        "eval" => eval::run(args),
        "eval-pjrt" => {
            eprintln!(
                "note: `eval-pjrt` is deprecated; use `eval --backend pjrt` \
                 (keeping the old default of --limit 64)"
            );
            eval::run_with_backend(args, "pjrt", Some(64))
        }
        "serve" => serve::run(args),
        "worker" => worker::run(args),
        "bench" => bench::run(args),
        "plan" => plan::run(args),
        "report" => report::run(args),
        "selftest" => selftest::run(args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// The multiplier family: the exported LUT bundle when present, else the
/// generated in-memory family (identical content, see `MulDb::digest`).
pub(crate) fn load_db(args: &Args) -> Result<Arc<MulDb>> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let db = if Path::new(artifacts).join("luts.bin").exists() {
        MulDb::load(artifacts)?
    } else {
        MulDb::generate()
    };
    Ok(Arc::new(db))
}

pub(crate) fn load_experiment(args: &Args) -> Result<Experiment> {
    Experiment::load(args.get_or("artifacts", "artifacts"), args.get_or("exp", "quick"))
}

/// Resolve the `--kernel scalar|avx2|threaded|auto` flag shared by the
/// native-backend commands (`eval`, `serve`, `worker`).  Absent =
/// [`lutmm::default_kernel`]: the `QOS_NETS_KERNEL` env var when set,
/// else feature detection.
pub(crate) fn native_kernel(args: &Args) -> Result<Arc<dyn LutKernel>> {
    match args.get("kernel") {
        Some(name) => lutmm::kernel_by_name(name),
        None => Ok(lutmm::default_kernel()),
    }
}

/// Parse the `--fleet host:port,host:port,...` flag shared by `serve`
/// and `eval`; `Ok(None)` when the flag is absent.
pub(crate) fn fleet_addrs(args: &Args) -> Result<Option<Vec<String>>> {
    let Some(fleet) = args.get("fleet") else {
        return Ok(None);
    };
    let addrs: Vec<String> = fleet
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    anyhow::ensure!(!addrs.is_empty(), "--fleet needs at least one host:port");
    Ok(Some(addrs))
}
