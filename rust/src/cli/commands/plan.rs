//! `qos-nets plan diff a.json b.json`: compare two stored `OpPlan`
//! artifacts — per-layer assignment deltas per operating point, per-OP
//! power deltas, subset and provenance differences.  Useful for
//! auditing what a planner change (or a re-run under a new seed)
//! actually did to a deployment before serving it.  `--json` emits the
//! same diff as machine-readable JSON (for CI gates and scripts); the
//! human table stays the default.

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::plan::{OpPlan, PlanDiff, Provenance};
use crate::util::json;

pub fn run(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("diff") => diff(args),
        Some(other) => bail!("unknown plan subcommand {other:?} (expected: diff)"),
        None => bail!("usage: qos-nets plan diff <a.json> <b.json>"),
    }
}

fn provenance_line(p: &Option<Provenance>) -> String {
    match p {
        Some(p) => format!(
            "planner={} seed={} config_hash={}",
            p.planner, p.seed, p.config_hash
        ),
        None => "(none — legacy plan)".to_string(),
    }
}

fn mul_label(plan: &OpPlan, id: Option<usize>) -> String {
    match id {
        None => "-".to_string(),
        Some(id) => match plan.mul_name(id) {
            Some(name) => format!("{id} ({name})"),
            None => id.to_string(),
        },
    }
}

fn diff(args: &Args) -> Result<()> {
    let [path_a, path_b] = match &args.positional[1..] {
        [a, b] => [a, b],
        _ => bail!("usage: qos-nets plan diff <a.json> <b.json>"),
    };
    let a = OpPlan::load(path_a)?;
    let b = OpPlan::load(path_b)?;
    let d: PlanDiff = a.diff(&b);

    if args.has("json") {
        println!("{}", json::to_string_pretty(&d.to_json()));
        return Ok(());
    }

    println!("plan diff: {path_a} (a) vs {path_b} (b)");
    println!(
        "  a: experiment={} version={} ops={} budget n={}",
        a.experiment,
        a.version,
        a.ops.len(),
        a.n_multipliers
    );
    println!(
        "  b: experiment={} version={} ops={} budget n={}",
        b.experiment,
        b.version,
        b.ops.len(),
        b.n_multipliers
    );
    println!("  provenance a: {}", provenance_line(&d.provenance_a));
    println!("  provenance b: {}", provenance_line(&d.provenance_b));

    if !d.subset_only_a.is_empty() || !d.subset_only_b.is_empty() {
        let fmt = |plan: &OpPlan, ids: &[usize]| -> String {
            ids.iter()
                .map(|&id| mul_label(plan, Some(id)))
                .collect::<Vec<_>>()
                .join(", ")
        };
        if !d.subset_only_a.is_empty() {
            println!("  multipliers only in a: {}", fmt(&a, &d.subset_only_a));
        }
        if !d.subset_only_b.is_empty() {
            println!("  multipliers only in b: {}", fmt(&b, &d.subset_only_b));
        }
    } else {
        println!("  deployed multiplier subset: identical");
    }

    let mut changed_layers = 0usize;
    for (i, op) in d.ops.iter().enumerate() {
        let label = match (&op.name_a, &op.name_b) {
            (Some(na), Some(nb)) if na == nb => na.clone(),
            (Some(na), Some(nb)) => format!("{na} -> {nb}"),
            (Some(na), None) => format!("{na} (only in a)"),
            (None, Some(nb)) => format!("{nb} (only in b)"),
            (None, None) => "?".to_string(),
        };
        match (op.power_a, op.power_b) {
            (Some(pa), Some(pb)) => println!(
                "  OP{i} [{label}]: power {:.2}% -> {:.2}% ({:+.2}pp), {} layer(s) changed",
                100.0 * pa,
                100.0 * pb,
                100.0 * (pb - pa),
                op.changed.len()
            ),
            (Some(pa), None) => println!("  OP{i} [{label}]: power {:.2}% -> (absent)", 100.0 * pa),
            (None, Some(pb)) => println!("  OP{i} [{label}]: (absent) -> power {:.2}%", 100.0 * pb),
            (None, None) => {}
        }
        for delta in &op.changed {
            println!(
                "      {}: {} -> {}",
                delta.layer,
                mul_label(&a, delta.from),
                mul_label(&b, delta.to)
            );
            changed_layers += 1;
        }
    }

    if d.is_same_deployment() {
        println!("  verdict: identical deployments (assignments, powers, subset)");
    } else {
        println!(
            "  verdict: {} assignment delta(s) across {} operating point(s)",
            changed_layers,
            d.ops.len()
        );
    }
    Ok(())
}
