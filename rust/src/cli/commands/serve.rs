//! `qos-nets serve --backend native|pjrt [--fleet host:port,...]`: QoS
//! serving demo — the elastic batching server (generic over
//! [`Backend`]) under a synthetic power-budget trace, the QoS
//! controller walking the OP ladder live (draining upgrades, immediate
//! downgrades) while the scaling supervisor grows/shrinks the worker
//! pool with the offered load.
//!
//! With `--fleet`, the backend inside each server worker is a
//! [`FleetBackend`] scattering batches across remote worker daemons
//! (`qos-nets worker`), a separate control-plane connection broadcasts
//! every controller switch fleet-wide (drained upgrades are acked by
//! every surviving worker before the local switch applies), and the
//! final report adds per-remote-worker attribution.  Each heartbeat
//! tick also re-probes evicted workers (recovered ones rejoin with
//! their stats preserved) and, with `--registry ADDR`, admits workers
//! that announced themselves via `worker --join`.  `--pipeline N` pins
//! the per-connection in-flight Forward window (default: library
//! default or the `QOS_NETS_FLEET_PIPELINE` override).
//!
//! Observability: `--metrics-addr HOST:PORT` serves the Prometheus
//! text endpoint (server, fleet and event-counter families) for the
//! duration of the run; `--flight-recorder [DIR]` attaches the event
//! ring and dumps it to a versioned JSON file on SLO violations (with
//! a cooldown), on fleet evictions, and on operator request
//! (`GET /dump` on the metrics endpoint).

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::autopilot::{Autopilot, AutopilotConfig, TickInputs};
#[cfg(feature = "pjrt")]
use crate::backend::PjrtBackend;
use crate::backend::{Backend, NativeBackend, OpTable};
use crate::cli::commands::{fleet_addrs, load_db, load_experiment, native_kernel};
use crate::cli::Args;
use crate::fleet::{FleetBackend, FleetRegistry, FleetStats};
use crate::obs::{self, MetricsServer, ObsEvent, Recorder};
use crate::pipeline::Experiment;
use crate::plan::OpPlan;
use crate::qos::{budget_trace, ClassSet, QosConfig, QosController, SwitchMode};
use crate::server::{BatcherConfig, Server};
use crate::util::rng::Rng;
use crate::util::stats::LatencyHistogram;

/// `--autopilot` rig: the closed-loop controller plus the pool bounds
/// it may steer within.
struct ApRig {
    pilot: Autopilot,
    pool_min: usize,
    pool_max: usize,
}

pub fn run(args: &Args) -> Result<()> {
    let exp = load_experiment(args)?;
    let mode = args.get_or("mode", "bn");
    let which = args.get_or("backend", "native");

    // the stored plan (written by any registered planner) is the single
    // source of the served OP ladder
    let ops = OpPlan::load_for(&exp)?.load_operating_points(&exp, mode)?;
    anyhow::ensure!(!ops.is_empty(), "plan has no operating points; re-run `search`");
    let table = OpTable::new(ops);
    let controller = QosController::new(table.ladder(), QosConfig::default());

    // a fleet provides its own parallelism, so the local pool defaults
    // to a single scatter/gather worker there
    let default_workers = if args.has("fleet") { 1 } else { 2 };
    let workers = args.get_usize("workers", default_workers);
    let max_workers = args.get_usize("max-workers", workers);
    // fixed pool unless bounds are passed explicitly, so plain
    // `--workers N` keeps its pre-elastic meaning; the min default
    // stays under an explicit ceiling so --max-workers is honored
    let min_workers = args.get_usize("min-workers", workers.min(max_workers));
    // --tenant NAME:SLO_MS:SHARE (repeatable, flag order = priority) or
    // --tenants-file F: carve the deployment into tenant classes —
    // per-class batch queues, per-class (op, mode) words, weighted
    // admission under --max-inflight, per-class metrics
    let tenants = match args.get("tenants-file") {
        Some(path) => ClassSet::from_json_file(std::path::Path::new(path))?,
        None => ClassSet::from_flags(&args.get_all("tenant"))?,
    };
    if tenants.is_multi() {
        println!("tenants: {} classes ({})", tenants.len(), tenants.names().join(", "));
    }
    let mut cfg = BatcherConfig {
        max_batch: args.get_usize("max-batch", 16),
        max_wait: Duration::from_millis(4),
        workers,
        min_workers,
        max_workers,
        retag_downgrades: args.has("retag-downgrades"),
        classes: tenants.len(),
        class_names: tenants.names(),
        admit_fracs: tenants.admit_fracs(),
        max_inflight: args.get_usize("max-inflight", 0),
        ..BatcherConfig::default()
    };
    // supervisor cadence knobs; unset keeps the library defaults
    if let Some(ms) = args.get("scale-interval-ms").and_then(|s| s.parse::<u64>().ok()) {
        cfg.scale_interval = Duration::from_millis(ms);
    }
    if let Some(n) = args.get("scale-up-after").and_then(|s| s.parse::<u32>().ok()) {
        cfg.scale_up_after = n;
    }
    if let Some(n) = args.get("scale-down-after").and_then(|s| s.parse::<u32>().ok()) {
        cfg.scale_down_after = n;
    }

    // `--autopilot`: a latency SLO joins the power budget in one
    // closed-loop controller (OP ladder x pool size x fleet chunk plan)
    let pilot = if args.has("autopilot") {
        let slo = args.get_f64("slo-p95-ms", 100.0);
        let envelope = args.get_f64("power-envelope", 1.0);
        anyhow::ensure!(slo.is_finite() && slo > 0.0, "--slo-p95-ms must be > 0");
        anyhow::ensure!(
            envelope.is_finite() && envelope > 0.0 && envelope <= 1.0,
            "--power-envelope must be in (0, 1]"
        );
        println!("autopilot: slo p95<={slo}ms, power envelope {envelope}");
        Some(ApRig {
            pilot: Autopilot::new(
                table.ladder(),
                QosConfig::default(),
                AutopilotConfig {
                    slo_p95_ms: slo,
                    power_envelope: envelope,
                    recover_after: 20,      // 1 s of 50 ms budget steps
                    pool_recover_after: 50, // 2.5 s
                    cooldown_ticks: 4,      // 200 ms
                    ..AutopilotConfig::default()
                },
            ),
            pool_min: min_workers.max(1),
            pool_max: max_workers.max(1),
        })
    } else {
        None
    };

    if let Some(addrs) = fleet_addrs(args)? {
        let pipeline = args.get_usize("pipeline", 0);
        let registry = match args.get("registry") {
            Some(addr) => {
                let reg = FleetRegistry::bind(addr)?;
                println!(
                    "fleet registry on {} — workers join with `qos-nets worker --join {}`",
                    reg.addr(),
                    reg.addr()
                );
                Some(reg)
            }
            None => None,
        };
        let stats = FleetStats::default();
        // control plane: its own connections, so switch broadcasts and
        // heartbeats never interleave with in-flight batches
        let control = FleetBackend::connect_with(&addrs, stats.clone())?;
        let control = if pipeline > 0 {
            control.with_pipeline_window(pipeline)
        } else {
            control
        };
        control.check_mode(mode)?;
        println!(
            "fleet: {} worker(s) connected ({}), pipeline window {}",
            control.live_workers(),
            addrs.join(", "),
            control.pipeline_window(),
        );
        let st = stats.clone();
        let server = Server::start(
            move |_w| {
                let be = FleetBackend::connect_with(&addrs, st.clone())?;
                Ok(if pipeline > 0 {
                    be.with_pipeline_window(pipeline)
                } else {
                    be
                })
            },
            table,
            cfg,
        )?;
        let fleet = Some((control, stats, registry));
        return drive(args, &exp, server, controller, pilot, fleet, tenants);
    }
    anyhow::ensure!(
        !args.has("registry"),
        "--registry needs a fleet coordinator (pass --fleet too)"
    );

    // the worker factory runs on each worker's own thread; capture only
    // cheap cloneable state so the closure is Send + Sync
    match which {
        "native" => {
            let graph = exp.graph.clone();
            let db = load_db(args)?;
            let kernel = native_kernel(args)?;
            println!("native kernel: {}", kernel.name());
            let server = Server::start(
                move |_w| Ok(NativeBackend::with_kernel(graph.clone(), db.clone(), kernel.clone())),
                table,
                cfg,
            )?;
            drive(args, &exp, server, controller, pilot, None, tenants)
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let artifacts = exp.artifacts.clone();
            let dir = exp.dir.clone();
            let ishape = exp.graph.input_shape.clone();
            let classes = exp.num_classes();
            let use_bn = mode != "none";
            let server = Server::start(
                move |_w| {
                    let mut be = PjrtBackend::open(&artifacts, &dir, &ishape, classes)?;
                    be.set_bn_overlays(use_bn);
                    Ok(be)
                },
                table,
                cfg,
            )?;
            drive(args, &exp, server, controller, pilot, None, tenants)
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!("this build has no PJRT support (rebuild with the `pjrt` feature)"),
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    }
}

/// The serving loop itself, written once for every backend.  With a
/// fleet control plane attached, every controller switch is broadcast
/// fleet-wide first (Drain = acked by every surviving worker) and the
/// fleet is heartbeat-probed at the cadence the workers advertised in
/// their handshakes (fleet-wide minimum, so one short-leashed worker
/// tightens eviction for the whole deployment).
fn drive<B: Backend + 'static>(
    args: &Args,
    exp: &Experiment,
    server: Server<B>,
    mut controller: QosController,
    mut pilot: Option<ApRig>,
    mut fleet: Option<(FleetBackend, FleetStats, Option<FleetRegistry>)>,
    tenants: ClassSet,
) -> Result<()> {
    let secs = args.get_f64("secs", 3.0);
    let rate = args.get_f64("rate", 200.0); // requests/second
    let trace_kind = args.get_or("trace", "sine");

    // --flight-recorder [DIR]: ring-buffer the event stream and dump
    // it on SLO violations, evictions, or operator request
    let recorder = if args.has("flight-recorder") {
        let dir = PathBuf::from(args.get_or("flight-recorder", "."));
        let rec = Arc::new(Recorder::with_defaults());
        obs::attach_recorder(rec.clone());
        println!("flight recorder armed (dumps to {})", dir.display());
        Some((rec, dir))
    } else {
        None
    };
    // --metrics-addr HOST:PORT: Prometheus text endpoint; the same
    // registry the final report numbers come from
    let _metrics = match args.get("metrics-addr") {
        Some(addr) => {
            let srv = MetricsServer::start(addr, recorder.as_ref().map(|(r, _)| r.clone()))?;
            println!("metrics endpoint on http://{}/metrics", srv.local_addr());
            Some(srv)
        }
        None => None,
    };
    obs::registry().register("server", server.metrics_collector());
    match fleet.as_ref() {
        Some((_, stats, _)) => obs::registry().register("fleet", stats.metrics_collector()),
        None => obs::registry().unregister("fleet"),
    }

    let (images, _) = exp.load_testset()?;
    let elems = exp.image_elems();
    let n_img = images.len() / elems;

    let steps = (secs * 20.0) as usize; // budget update every 50 ms
    let trace = budget_trace(trace_kind, steps, exp.seed());
    // probe cadence from the workers' handshakes, quantized to 50 ms
    // steps (minimum one step)
    let (hb_every, hb_timeout) = fleet
        .as_ref()
        .map(|(c, _, _)| ((c.hb_interval().as_millis() as u64 / 50).max(1), c.hb_timeout()))
        .unwrap_or((20, Duration::from_millis(500)));
    // --reprobe-interval-ms: decouple evicted-worker re-probing from
    // the heartbeat cadence (unset keeps the legacy behavior: re-probe
    // on every heartbeat tick), quantized to 50 ms steps
    let reprobe_every = args
        .get("reprobe-interval-ms")
        .and_then(|s| s.parse::<u64>().ok())
        .map(|ms| (ms / 50).max(1));
    let mut receivers = Vec::new();
    let mut rng = Rng::new(42);
    let started = Instant::now();
    let mut submitted = 0u64;
    let mut drains = 0u64;
    let mut fleet_acks = 0u64;
    let mut energy = 0.0f64; // sum of per-request relative power
    // sliding ~500 ms p95 window for the autopilot (ring of cumulative
    // histograms, differenced against the oldest entry)
    let mut hist: VecDeque<LatencyHistogram> = VecDeque::new();
    const WINDOW_STEPS: usize = 10;
    // flight-dump trigger state: violation/eviction high-water marks,
    // plus a dump cooldown so a sustained SLO breach writes one file
    // every ~5 s instead of one per 50 ms step
    const DUMP_COOLDOWN_STEPS: usize = 100;
    let mut seen_violations = 0u64;
    let mut seen_evictions = 0u64;
    let mut last_slo_dump: Option<usize> = None;
    for (step, &budget) in trace.iter().enumerate() {
        let switch = match pilot.as_mut() {
            Some(rig) => {
                let cur = server.metrics().latency;
                let win = match hist.front() {
                    Some(earlier) => cur.since(earlier),
                    None => cur.clone(),
                };
                hist.push_back(cur);
                if hist.len() > WINDOW_STEPS {
                    hist.pop_front();
                }
                let out = rig.pilot.tick(
                    &TickInputs {
                        t_s: step as f64 * 0.05,
                        p95_ms: win.percentile_us(95.0) as f64 / 1000.0,
                        window: win.count(),
                        env_budget: budget,
                        live_workers: server.live_workers(),
                        min_workers: rig.pool_min,
                        max_workers: rig.pool_max,
                        has_fleet: fleet.is_some(),
                    },
                    Instant::now(),
                );
                if let Some(target) = out.pool_target {
                    server.set_pool_target(target);
                }
                if let Some(q) = out.chunk_quantum_us {
                    if let Some((_, stats, _)) = fleet.as_ref() {
                        stats.set_chunk_quantum_us(q);
                    }
                }
                if out.switch.is_some()
                    || out.pool_target.is_some()
                    || out.chunk_quantum_us.is_some()
                {
                    let d = &out.decision;
                    println!(
                        "  autopilot t={:.2}s p95={:.1}ms op={} workers={} bound={} [{} {} {}]",
                        d.t_s,
                        d.p95_ms,
                        d.op,
                        d.workers,
                        d.bound.as_str(),
                        d.op_action.as_str(),
                        d.pool_action.as_str(),
                        d.chunk_action.as_str()
                    );
                }
                if let Some((rec, dir)) = recorder.as_ref() {
                    if rig.pilot.slo_violations > seen_violations {
                        seen_violations = rig.pilot.slo_violations;
                        if last_slo_dump.is_none_or(|s| step - s >= DUMP_COOLDOWN_STEPS) {
                            last_slo_dump = Some(step);
                            obs::note_flight_dump("slo_violation");
                            match rec.dump_to(dir, "slo_violation") {
                                Ok(p) => {
                                    println!("flight recorder: SLO violation -> {}", p.display())
                                }
                                Err(e) => obs::log!(Error, "flight dump failed: {e:#}"),
                            }
                        }
                    }
                }
                out.switch
            }
            None => controller.observe_with_mode(budget, Instant::now()),
        };
        if let Some((idx, mode)) = switch {
            if mode == SwitchMode::Drain {
                drains += 1;
            }
            if let Some((control, _, _)) = fleet.as_mut() {
                // fleet first: a drained upgrade is only reported once
                // every surviving remote worker has acked the barrier
                let n = control.set_operating_point(idx, mode)? as u64;
                if mode == SwitchMode::Drain {
                    fleet_acks += n;
                }
            }
            server.set_operating_point_with(idx, mode)?;
            let piloted = pilot.is_some();
            obs::publish(ObsEvent::OpSwitch {
                op: idx,
                mode: match mode {
                    SwitchMode::Drain => "drain",
                    SwitchMode::Immediate => "immediate",
                }
                .to_string(),
                trigger: if piloted { "autopilot" } else { "budget" }.to_string(),
                class: None,
            });
        }
        if let Some((control, stats, registry)) = fleet.as_mut() {
            let hb_tick = step as u64 % hb_every == hb_every - 1;
            let reprobe_tick = match reprobe_every {
                Some(every) => step as u64 % every == every - 1,
                None => hb_tick,
            };
            if hb_tick {
                control.heartbeat(hb_timeout);
                // grow: workers that announced via `worker --join`
                if let Some(reg) = registry {
                    let pending = reg.take_new();
                    if !pending.is_empty() {
                        let n = control.admit(&pending);
                        println!("fleet: admitted {n}/{} joining worker(s)", pending.len());
                    }
                }
            }
            if reprobe_tick {
                // heal: evicted workers that recovered rejoin with
                // their stats (and the OP ladder) restored
                let rejoined = control.reprobe();
                if rejoined > 0 {
                    println!("fleet: {rejoined} evicted worker(s) rejoined");
                }
            }
            if hb_tick {
                // any new eviction since the last probe flushes the
                // flight ring (membership loss is exactly the moment
                // the preceding seconds of events matter)
                if let Some((rec, dir)) = recorder.as_ref() {
                    let (_, _, evictions) = stats.snapshot();
                    if evictions > seen_evictions {
                        seen_evictions = evictions;
                        obs::note_flight_dump("eviction");
                        match rec.dump_to(dir, "eviction") {
                            Ok(p) => println!("flight recorder: eviction -> {}", p.display()),
                            Err(e) => obs::log!(Error, "flight dump failed: {e:#}"),
                        }
                    }
                }
            }
        }
        let step_end = started + Duration::from_millis(50 * (step as u64 + 1));
        while Instant::now() < step_end {
            let i = rng.below(n_img);
            let img = images[i * elems..(i + 1) * elems].to_vec();
            if tenants.is_multi() {
                // share-weighted tenant mix; rejected submissions
                // (weighted admission under --max-inflight) show up in
                // the per-class rejected counters, not here
                let class = pick_class(&tenants, &mut rng);
                if let Some(rx) = server.submit_class(class, img)? {
                    receivers.push(rx);
                }
            } else {
                receivers.push(server.submit(img)?);
            }
            submitted += 1;
            energy += server.ops()[server.operating_point()].relative_power;
            let gap = Duration::from_secs_f64(rng.exp(rate));
            std::thread::sleep(gap.min(Duration::from_millis(20)));
        }
    }
    // drain
    let mut ok = 0u64;
    for rx in receivers {
        if rx.recv_timeout(Duration::from_secs(30)).is_ok() {
            ok += 1;
        }
    }
    let wall = started.elapsed();
    let live = server.live_workers();
    let op_names: Vec<String> = server.ops().iter().map(|o| o.name.clone()).collect();
    let m = server.shutdown();
    println!(
        "[{}] serve: {} requests in {:.2}s ({:.1} req/s), {} completed",
        exp.name,
        submitted,
        wall.as_secs_f64(),
        submitted as f64 / wall.as_secs_f64(),
        ok
    );
    let lat = m.latency.summary();
    println!(
        "  latency: mean={:.2}ms p50<={:.2}ms p99<={:.2}ms max={:.2}ms  queue mean={:.2}ms",
        lat.mean_us / 1e3,
        lat.p50_us as f64 / 1e3,
        lat.p99_us as f64 / 1e3,
        lat.max_us as f64 / 1e3,
        m.queue_latency.mean_us() / 1e3,
    );
    let (switches, budget_violations) = match &pilot {
        Some(rig) => (
            rig.pilot.controller().switches,
            rig.pilot.controller().budget_violations,
        ),
        None => (controller.switches, controller.budget_violations),
    };
    println!(
        "  mean batch={:.2}  OP switches={} ({} draining) budget violations={}",
        m.mean_batch(),
        switches,
        drains,
        budget_violations
    );
    if let Some(rig) = &pilot {
        println!(
            "  autopilot: slo p95<={:.0}ms envelope={:.2}  ticks={} slo violations={}",
            rig.pilot.config().slo_p95_ms,
            rig.pilot.config().power_envelope,
            rig.pilot.ticks,
            rig.pilot.slo_violations
        );
    }
    println!(
        "  workers: live={live} peak={} scale-ups={} scale-downs={} spawn-failures={} retagged-batches={}",
        m.peak_workers, m.scale_ups, m.scale_downs, m.spawn_failures, m.retagged_batches
    );
    for (i, c) in m.per_op_requests.iter().enumerate() {
        let h = m.per_op_latency[i].summary();
        println!(
            "  OP{i} ({}): {c} requests ({:.1}%)  latency mean={:.2}ms p99<={:.2}ms",
            op_names[i],
            100.0 * *c as f64 / m.completed.max(1) as f64,
            h.mean_us / 1e3,
            h.p99_us as f64 / 1e3,
        );
    }
    if tenants.is_multi() {
        for (i, pc) in m.per_class.iter().enumerate() {
            let t = tenants.get(i);
            println!(
                "  class {} (priority {}): submitted={} completed={} rejected={} retagged-batches={}  p99<={:.2}ms",
                t.name,
                t.priority,
                pc.submitted,
                pc.completed,
                pc.rejected,
                pc.retagged_batches,
                pc.latency.p99_us as f64 / 1e3,
            );
        }
    }
    println!(
        "  mean relative multiplication power over run: {:.2}%",
        100.0 * energy / submitted.max(1) as f64
    );
    if let Some((control, stats, _registry)) = fleet {
        let (workers, requeues, evictions) = stats.snapshot();
        let rejoins: u64 = workers.iter().map(|(_, w)| w.rejoins).sum();
        println!(
            "  fleet: {} worker(s) live at end, drain acks={fleet_acks} requeued chunks={requeues} evictions={evictions} rejoins={rejoins}",
            control.live_workers()
        );
        for (addr, w) in workers {
            let mut tags = String::new();
            if w.evicted {
                tags.push_str("  [evicted]");
            }
            if w.rejoins > 0 {
                tags.push_str(&format!("  [rejoined x{}]", w.rejoins));
            }
            println!(
                "    {addr}: {} requests in {} batches  mean={:.2}ms ewma/img={:.0}us errors={}{tags}",
                w.requests,
                w.batches,
                w.mean_latency_us() / 1e3,
                w.ewma_img_us,
                w.errors,
            );
            // transport-health line: the counters the eviction /
            // requeue / drain-barrier machinery accumulated
            let mean_drain_ms = if w.drain_waits > 0 {
                w.drain_wait_us as f64 / w.drain_waits as f64 / 1e3
            } else {
                0.0
            };
            println!(
                "      hb-misses={} requeued-chunks={} drain-waits={} (mean {:.2}ms) reprobes={}",
                w.hb_misses, w.requeues, w.drain_waits, mean_drain_ms, w.reprobes,
            );
        }
    }
    if let Some((rec, _)) = &recorder {
        obs::detach_recorder(rec);
    }
    Ok(())
}

/// Share-weighted tenant pick for the synthetic load mix.
fn pick_class(tenants: &ClassSet, rng: &mut Rng) -> usize {
    let total: f64 = tenants.iter().map(|c| c.share).sum();
    let mut x = rng.f64() * total;
    for (i, c) in tenants.iter().enumerate() {
        x -= c.share;
        if x < 0.0 {
            return i;
        }
    }
    tenants.len() - 1
}
