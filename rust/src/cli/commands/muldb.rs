//! `qos-nets muldb`: print the approximate-multiplier family.

use anyhow::Result;

use crate::cli::Args;
use crate::muldb::MulDb;

pub fn run(_args: &Args) -> Result<()> {
    let db = MulDb::generate();
    println!(
        "{:>3} {:16} {:>8} {:>10} {:>10} {:>10}",
        "id", "name", "power", "MED", "MRED", "bias"
    );
    for s in &db.specs {
        let st = db.error_stats(s.id);
        println!(
            "{:>3} {:16} {:>8.3} {:>10.2} {:>10.5} {:>10.2}",
            s.id, s.name, s.power, st.med, st.mred, st.mean
        );
    }
    println!("digest: {}", db.digest());
    Ok(())
}
