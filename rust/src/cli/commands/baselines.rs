//! `qos-nets baselines`: run every baseline mapping algorithm on the
//! same error model and print the power/penalty table.

use anyhow::Result;

use crate::baselines::{self, alwann};
use crate::cli::commands::{load_db, load_experiment};
use crate::cli::Args;
use crate::errmodel;
use crate::pipeline;

pub fn run(args: &Args) -> Result<()> {
    let exp = load_experiment(args)?;
    let db = load_db(args)?;
    let se = errmodel::sigma_e(&db, &exp.stats);
    let scale = args.get_f64("scale", 1.0);

    let mut rows: Vec<(String, Vec<usize>)> = Vec::new();
    rows.push((
        "gradient_search[16]".into(),
        baselines::gradient_search(&db, &se, &exp.sigma_g, scale),
    ));
    rows.push((
        "lvrm_style[15]".into(),
        baselines::lvrm_divide_conquer(&db, &se, &exp.sigma_g, scale),
    ));
    rows.push((
        "pnam_style[14]".into(),
        baselines::pnam_mapping(&db, &se, &exp.sigma_g, &exp.stats, scale),
    ));
    rows.push((
        "tpm_style[13]".into(),
        baselines::tpm_threshold(&db, &se, &exp.sigma_g, scale),
    ));
    let hom = baselines::homogeneous_pick(&db, &se, &exp.sigma_g, &exp.stats, 0.0);
    rows.push((format!("homogeneous[2]:{}", db.specs[hom].name), vec![hom; se.l]));
    let ga = alwann::evolve(
        &db,
        &se,
        &exp.sigma_g,
        &exp.stats,
        &alwann::GaConfig {
            n_tiles: exp.n_multipliers(),
            seed: exp.seed(),
            ..Default::default()
        },
    );
    if let Some(best) = alwann::pick_feasible(&ga) {
        rows.push(("alwann_ga[9]".into(), best.chromosome.assignment()));
    }
    let (_, sol) = pipeline::run_search(&exp, &db);
    rows.push(("qos_nets(op_last)".into(), sol.assignment.last().unwrap().clone()));

    println!(
        "{:28} {:>8} {:>9} {:>7} {:>6}",
        "method", "power", "penalty", "#AMs", "layers"
    );
    for (name, a) in &rows {
        let power = errmodel::relative_power(&db, &exp.stats, a);
        let pen = baselines::quality_penalty(&se, &exp.sigma_g, a);
        let distinct: std::collections::BTreeSet<usize> = a.iter().cloned().collect();
        println!(
            "{:28} {:>7.2}% {:>9.4} {:>7} {:>6}",
            name,
            100.0 * power,
            pen,
            distinct.len(),
            a.len()
        );
    }
    Ok(())
}
