//! `qos-nets baselines`: run **every registered planner** (baselines
//! and QoS-Nets alike) through the one [`crate::plan::Planner`] code
//! path on identical inputs and print the comparison table — the
//! paper's Table 1 shape, with QoS-Nets as the last row.

use anyhow::Result;

use crate::baselines;
use crate::cli::commands::{load_db, load_experiment};
use crate::cli::Args;
use crate::errmodel;
use crate::plan::{self, PlanInputs, Planner};

pub fn run(args: &Args) -> Result<()> {
    let exp = load_experiment(args)?;
    let db = load_db(args)?;
    let se = errmodel::sigma_e(&db, &exp.stats);
    let inputs = PlanInputs::from_experiment(&exp, &db, &se);

    println!(
        "[{}] {} layers x {} multipliers, scales {:?}, budget n={}",
        exp.name,
        se.l,
        se.m,
        inputs.scales,
        inputs.n_multipliers
    );
    println!(
        "{:14} {:>8} {:>9} {:>7} {:>5}  description",
        "planner", "power", "penalty", "#AMs", "OPs"
    );
    for planner in plan::all_planners() {
        let p = match planner.plan(&inputs) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{}: planning failed: {e:#}", planner.name());
                continue;
            }
        };
        // report the scale-1.0 rung (last by convention) so every row
        // is judged against the same tolerance
        let op = p.ops.last().expect("planner produced no operating points");
        let scaled: Vec<f64> = exp.sigma_g.iter().map(|g| op.scale * g).collect();
        let pen = baselines::quality_penalty(&se, &scaled, &op.assignment);
        println!(
            "{:14} {:>7.2}% {:>9.4} {:>7} {:>5}  {}",
            planner.name(),
            100.0 * op.relative_power,
            pen,
            p.subset.len(),
            p.ops.len(),
            planner.describe()
        );
    }
    Ok(())
}
