//! `qos-nets report <fig1|fig2|fig3>`: dump the paper-figure data series.

use anyhow::{bail, Result};

use crate::cli::commands::{load_db, load_experiment};
use crate::cli::Args;
use crate::errmodel;
use crate::plan::{self, OpPlan};
use crate::selection;
use crate::util::json::{self, Json};

pub fn run(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("fig3");
    let exp = load_experiment(args)?;
    let db = load_db(args)?;
    match which {
        "fig1" => {
            // sigma_g vector + sigma_e matrix dump (the Fig. 1 pipeline output)
            let se = errmodel::sigma_e(&db, &exp.stats);
            let mut rows = Vec::new();
            for (k, name) in exp.layer_names.iter().enumerate() {
                rows.push(Json::obj(vec![
                    ("layer", Json::str(name.clone())),
                    ("sigma_g", Json::num(exp.sigma_g[k])),
                    (
                        "sigma_e",
                        Json::Arr(se.column(k).into_iter().map(Json::num).collect()),
                    ),
                ]));
            }
            println!("{}", json::to_string_pretty(&Json::Arr(rows)));
        }
        "fig2" => {
            // scaled preference vectors + cluster assignment per (OP, layer)
            let se = errmodel::sigma_e(&db, &exp.stats);
            let usable = selection::usable_multipliers(&se, &exp.sigma_g, &exp.scales());
            let points =
                selection::preference_vectors(&se, &exp.sigma_g, &exp.scales(), &usable);
            let sol = plan::plan_experiment("qos", &exp, &db)?;
            let l = exp.layer_names.len();
            let mut rows = Vec::new();
            for (idx, p) in points.iter().enumerate() {
                rows.push(Json::obj(vec![
                    ("op", Json::num((idx / l) as f64)),
                    ("layer", Json::str(exp.layer_names[idx % l].clone())),
                    (
                        "preference",
                        Json::Arr(p.iter().map(|&x| Json::num(x)).collect()),
                    ),
                    (
                        "multiplier",
                        Json::num(sol.ops[idx / l].assignment[idx % l] as f64),
                    ),
                ]));
            }
            println!("{}", json::to_string_pretty(&Json::Arr(rows)));
        }
        "fig3" => {
            // per-layer multiplier assignment per OP + power lines (paper Fig. 3)
            let plan = OpPlan::load_for(&exp)?;
            anyhow::ensure!(!plan.ops.is_empty(), "plan has no operating points; re-run `search`");
            for op in &plan.ops {
                println!(
                    "# {} scale={} relative_power={:.4}",
                    op.name, op.scale, op.relative_power
                );
                println!("layer_index,layer,multiplier_id,multiplier,power");
                for (k, name) in plan.layer_names.iter().enumerate() {
                    let mid = op.assignment[k];
                    println!("{k},{name},{mid},{},{:.3}", db.specs[mid].name, db.power(mid));
                }
                println!();
            }
            if let Some(p) = &plan.provenance {
                println!("# planner={} seed={} config_hash={}", p.planner, p.seed, p.config_hash);
            }
        }
        other => bail!("unknown report {other:?} (fig1|fig2|fig3)"),
    }
    Ok(())
}
