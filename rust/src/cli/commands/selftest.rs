//! `qos-nets selftest`: cross-layer integration checks — PJRT kernel
//! artifact vs the native LUT hot loop (bit-exact), and the PJRT model
//! artifact vs the native engine through the unified `Backend` trait.
//! Requires the `pjrt` cargo feature (the whole point is the
//! cross-substrate comparison).

#[cfg(feature = "pjrt")]
use std::collections::HashMap;

use anyhow::Result;

#[cfg(feature = "pjrt")]
use crate::backend::{Backend, NativeBackend, PjrtBackend};
#[cfg(feature = "pjrt")]
use crate::cli::commands::{load_db, load_experiment};
use crate::cli::Args;
#[cfg(feature = "pjrt")]
use crate::engine::lutmm;
#[cfg(feature = "pjrt")]
use crate::pipeline;
#[cfg(feature = "pjrt")]
use crate::plan::OpPlan;
#[cfg(feature = "pjrt")]
use crate::runtime;
#[cfg(feature = "pjrt")]
use crate::util::rng::Rng;

#[cfg(not(feature = "pjrt"))]
pub fn run(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "selftest compares the PJRT artifacts against the native engine; \
         rebuild with the `pjrt` feature (on by default)"
    )
}

#[cfg(feature = "pjrt")]
pub fn run(args: &Args) -> Result<()> {
    let exp = load_experiment(args)?;
    let db = load_db(args)?;
    let rt = runtime::Runtime::cpu()?;

    // --- kernel artifact vs native hot loop (bit-exact) ---
    let kernel = rt.load(&exp.dir, "kernel")?;
    let (m, k, n) = {
        let s = &kernel.signature;
        (s[0].shape[0], s[0].shape[1], s[1].shape[1])
    };
    let mut rng = Rng::new(1);
    let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32).collect();
    let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32).collect();
    let mid = 9; // bam7
    let (za, zw, zo) = (128i32, 117i32, 30i32);
    let s_req = 1e-4f32;
    let inputs = vec![
        runtime::literal_i32(&a, &[m, k])?,
        runtime::literal_i32(&w, &[k, n])?,
        runtime::literal_i32(db.lut(mid), &[256, 256])?,
        runtime::literal_f32(&[s_req], &[1])?,
        runtime::literal_i32(&[za, zw, zo], &[3])?,
    ];
    let pjrt_out = kernel.execute_i32(&inputs)?;

    // native recompute
    let mut at = vec![0i32; k * m];
    for mm in 0..m {
        for kk in 0..k {
            at[kk * m + mm] = a[mm * k + kk];
        }
    }
    let mut wt = vec![0i32; n * k];
    for kk in 0..k {
        for nn in 0..n {
            wt[nn * k + kk] = w[kk * n + nn];
        }
    }
    let wlut = lutmm::transpose_lut(db.lut(mid));
    let mut acc = vec![0i32; m * n];
    lutmm::lut_matmul_acc(&at, &wt, &wlut, m, k, n, &mut acc);
    let (sa, sw) = lutmm::code_sums(&at, &wt, m, k, n);
    lutmm::apply_corrections(&mut acc, &sa, &sw, m, k, n, za, zw);
    let native: Vec<i32> = acc
        .iter()
        .map(|&c| {
            let q = (c as f32 * s_req).round_ties_even() + zo as f32;
            q.clamp(0.0, 255.0) as i32
        })
        .collect();
    anyhow::ensure!(pjrt_out == native, "kernel artifact != native lutmm");
    println!("selftest: PJRT kernel artifact == native LUT matmul ({m}x{k}x{n}) OK");

    // --- model artifact vs native engine, both through the Backend trait ---
    let (images, labels) = exp.load_testset()?;
    let elems = exp.image_elems();
    let classes = exp.num_classes();
    let amap: HashMap<String, usize> = match OpPlan::load_for(&exp) {
        Ok(plan) if !plan.ops.is_empty() => plan.assignment_map(plan.ops.len() - 1),
        _ => exp.layer_names.iter().map(|l| (l.clone(), 0usize)).collect(),
    };
    let op = pipeline::build_operating_point(&exp, "st", amap, 1.0, None)?;
    let table = [op];

    let mut pjrt =
        PjrtBackend::open(&exp.artifacts, &exp.dir, &exp.graph.input_shape, classes)?;
    pjrt.prepare(&table)?;
    let batch = pjrt.export_batch();
    let pjrt_logits = pjrt.forward(0, &images[..batch * elems], batch)?;

    let mut native = NativeBackend::new(exp.graph.clone(), db.clone());
    native.prepare(&table)?;
    let native_logits = native.forward(0, &images[..batch * elems], batch)?;

    let mut agree = 0;
    for b in 0..batch {
        let arg = |v: &[f32]| {
            v.iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        };
        let p = arg(&pjrt_logits[b * classes..(b + 1) * classes]);
        let nl = arg(&native_logits[b * classes..(b + 1) * classes]);
        if p == nl {
            agree += 1;
        }
    }
    println!(
        "selftest: PJRT model vs native engine top-1 agreement {agree}/{batch} (labels {:?})",
        &labels[..batch.min(4)]
    );
    anyhow::ensure!(agree * 10 >= batch * 7, "PJRT/native agreement too low");
    println!("selftest OK");
    Ok(())
}
