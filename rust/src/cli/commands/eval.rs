//! `qos-nets eval --backend native|pjrt`: evaluate the exact baseline
//! plus every searched operating point through the unified [`Backend`]
//! trait — the native LUT engine and the PJRT runtime share this exact
//! code path (the old `eval` / `eval-pjrt` pair collapsed into one).

use std::time::Instant;

use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
use crate::backend::PjrtBackend;
use crate::backend::{self, Backend, NativeBackend};
use crate::cli::commands::{fleet_addrs, load_db, load_experiment, native_kernel};
use crate::cli::Args;
use crate::fleet::FleetBackend;
use crate::pipeline::{self, Experiment};
use crate::plan::OpPlan;

pub fn run(args: &Args) -> Result<()> {
    let which = args.get_or("backend", "native").to_string();
    run_with_backend(args, &which, None)
}

/// Build the requested backend for an experiment.  `mode` controls
/// whether the PJRT backend applies BN overlays ("none" disables them,
/// mirroring the native backend's overlay-free operating points).  A
/// `--fleet host:port,...` flag overrides `--backend`: evaluation then
/// scatters over remote worker daemons instead of a local substrate.
pub(crate) fn make_backend(
    args: &Args,
    exp: &Experiment,
    which: &str,
    mode: &str,
) -> Result<Box<dyn Backend>> {
    if let Some(addrs) = fleet_addrs(args)? {
        let be = FleetBackend::connect(&addrs)?;
        be.check_mode(mode)?;
        println!("fleet: {} worker(s) connected", be.live_workers());
        return Ok(Box::new(be));
    }
    match which {
        "native" => {
            let be = NativeBackend::with_kernel(exp.graph.clone(), load_db(args)?, native_kernel(args)?);
            println!("native kernel: {}", be.kernel_name());
            Ok(Box::new(be))
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let mut be = PjrtBackend::open(
                &exp.artifacts,
                &exp.dir,
                &exp.graph.input_shape,
                exp.num_classes(),
            )?;
            be.set_bn_overlays(mode != "none");
            println!("PJRT platform: {}", be.platform());
            Ok(Box::new(be))
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => {
            let _ = mode;
            bail!("this build has no PJRT support (rebuild with the `pjrt` feature)")
        }
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    }
}

/// `default_limit` preserves the deprecated `eval-pjrt` behavior (cap
/// at 64 samples unless --limit is given); `eval` itself passes None.
pub fn run_with_backend(args: &Args, which: &str, default_limit: Option<usize>) -> Result<()> {
    let exp = load_experiment(args)?;
    let mode = args.get_or("mode", "bn");
    let batch = args.get_usize("batch", 32);
    let limit = args.get("limit").and_then(|s| s.parse().ok()).or(default_limit);

    // table[0] is the exact 8-bit baseline, table[1..] the OP ladder
    // from the stored plan (any registered planner writes the same shape)
    let plan = OpPlan::load_for(&exp)?;
    let mut table = vec![pipeline::exact_operating_point(&exp)?];
    table.extend(plan.load_operating_points(&exp, mode)?);

    let mut be = make_backend(args, &exp, which, mode)?;
    be.prepare(&table)?;

    let (images, labels) = exp.load_testset()?;
    let elems = exp.image_elems();

    let base = backend::evaluate(be.as_mut(), 0, &images, &labels, elems, batch, limit)?;
    println!(
        "[{}] baseline (8-bit, exact mult, {} backend): top1={:.2}% top5={:.2}% (n={})",
        exp.name,
        be.name(),
        100.0 * base.top1,
        100.0 * base.top5,
        base.n
    );

    for (i, op) in table.iter().enumerate().skip(1) {
        let t0 = Instant::now();
        let r = backend::evaluate(be.as_mut(), i, &images, &labels, elems, batch, limit)?;
        println!(
            "[{}] {} ({} mode, {} backend): power={:.2}% top1={:.2}% ({:+.2}pp) top5={:.2}% ({:+.2}pp) [{:?}]",
            exp.name,
            op.name,
            mode,
            be.name(),
            100.0 * op.relative_power,
            100.0 * r.top1,
            100.0 * (r.top1 - base.top1),
            100.0 * r.top5,
            100.0 * (r.top5 - base.top5),
            t0.elapsed()
        );
    }
    Ok(())
}
