//! `qos-nets worker --listen ADDR --backend native|pjrt`: one fleet
//! worker daemon.
//!
//! The worker loads its experiment artifacts and stored plan *locally*
//! (weights never cross the wire), builds an OP catalog — the exact
//! 8-bit baseline plus every rung of the plan's ladder, with the
//! retraining overlays of `--mode` applied — and then answers the
//! fleet wire protocol until a coordinator sends `Shutdown`.  Pair it
//! with `serve --fleet` or `eval --fleet` on the coordinator side.
//!
//! `--hb-interval-ms` / `--hb-timeout-ms` set the heartbeat cadence
//! this worker advertises in `HelloAck`; coordinators take the
//! fleet-wide minimum, so a short leash here shortens eviction time
//! for the whole deployment (the `heterogeneous_fleet` bench scenario
//! exercises exactly this).
//!
//! `--join HOST:PORT` announces the bound address to a coordinator's
//! fleet registry (`serve --registry`) before serving, so the fleet
//! grows under load without restarting the coordinator.  `--advertise`
//! overrides the announced address when the worker sits behind NAT or
//! binds a wildcard.

use std::net::TcpListener;
use std::time::Duration;

use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
use crate::backend::PjrtBackend;
use crate::backend::NativeBackend;
use crate::cli::commands::{load_db, load_experiment, native_kernel};
use crate::cli::Args;
use crate::fleet::worker;
use crate::fleet::worker::WorkerOptions;
use crate::fleet::{register_with, DEFAULT_HB_INTERVAL_MS, DEFAULT_HB_TIMEOUT_MS};
use crate::pipeline;
use crate::plan::OpPlan;

pub fn run(args: &Args) -> Result<()> {
    let exp = load_experiment(args)?;
    let mode = args.get_or("mode", "bn");
    let which = args.get_or("backend", "native");
    let listen = args.get_or("listen", "127.0.0.1:7070");
    let hb_interval_ms = args.get_usize("hb-interval-ms", DEFAULT_HB_INTERVAL_MS as usize);
    let hb_timeout_ms = args.get_usize("hb-timeout-ms", DEFAULT_HB_TIMEOUT_MS as usize);
    anyhow::ensure!(hb_interval_ms > 0 && hb_timeout_ms > 0, "heartbeat cadence must be > 0 ms");

    // the catalog: everything a coordinator may ask this worker to make
    // resident — the exact baseline (eval ladders start with it) plus
    // the stored plan's OPs, resolved by name at Prepare time
    let plan = OpPlan::load_for(&exp)?;
    let mut catalog = vec![pipeline::exact_operating_point(&exp)?];
    catalog.extend(plan.load_operating_points(&exp, mode)?);

    let listener = TcpListener::bind(listen)?;
    let addr = listener.local_addr()?;
    let name = format!("{}@{addr}", exp.name);
    let names: Vec<&str> = catalog.iter().map(|o| o.name.as_str()).collect();
    println!(
        "[{}] fleet worker `{name}`: backend={which} mode={mode} listening on {addr}",
        exp.name
    );
    println!("  catalog ({} OPs): {}", names.len(), names.join(", "));
    println!("  heartbeat: interval {hb_interval_ms} ms, timeout {hb_timeout_ms} ms (advertised)");
    println!("  stop with a coordinator Shutdown frame (e.g. fleet teardown)");

    // announce ourselves to a coordinator's registry before serving; the
    // coordinator admits pending workers on its next heartbeat tick
    if let Some(registry) = args.get("join") {
        let advertised = addr.to_string();
        let advertise = args.get_or("advertise", &advertised);
        register_with(registry, advertise)?;
        println!("  joined fleet registry at {registry} (advertised as {advertise})");
    }

    let opts = WorkerOptions::new(name, mode).heartbeat(
        Duration::from_millis(hb_interval_ms as u64),
        Duration::from_millis(hb_timeout_ms as u64),
    );
    match which {
        "native" => {
            let graph = exp.graph.clone();
            let db = load_db(args)?;
            let kernel = native_kernel(args)?;
            println!("  native kernel: {}", kernel.name());
            worker::run_with(listener, opts, catalog, move |_conn| {
                Ok(NativeBackend::with_kernel(graph.clone(), db.clone(), kernel.clone()))
            })
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let artifacts = exp.artifacts.clone();
            let dir = exp.dir.clone();
            let ishape = exp.graph.input_shape.clone();
            let classes = exp.num_classes();
            let use_bn = mode != "none";
            worker::run_with(listener, opts, catalog, move |_conn| {
                let mut be = PjrtBackend::open(&artifacts, &dir, &ishape, classes)?;
                be.set_bn_overlays(use_bn);
                Ok(be)
            })
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!("this build has no PJRT support (rebuild with the `pjrt` feature)"),
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    }
}
