//! `qos-nets worker --listen ADDR --backend native|pjrt`: one fleet
//! worker daemon.
//!
//! The worker loads its experiment artifacts and stored plan *locally*
//! (weights never cross the wire), builds an OP catalog — the exact
//! 8-bit baseline plus every rung of the plan's ladder, with the
//! retraining overlays of `--mode` applied — and then answers the
//! fleet wire protocol until a coordinator sends `Shutdown`.  Pair it
//! with `serve --fleet` or `eval --fleet` on the coordinator side.

use std::net::TcpListener;

use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
use crate::backend::PjrtBackend;
use crate::backend::NativeBackend;
use crate::cli::commands::{load_db, load_experiment, native_kernel};
use crate::cli::Args;
use crate::fleet::worker;
use crate::pipeline;
use crate::plan::OpPlan;

pub fn run(args: &Args) -> Result<()> {
    let exp = load_experiment(args)?;
    let mode = args.get_or("mode", "bn");
    let which = args.get_or("backend", "native");
    let listen = args.get_or("listen", "127.0.0.1:7070");

    // the catalog: everything a coordinator may ask this worker to make
    // resident — the exact baseline (eval ladders start with it) plus
    // the stored plan's OPs, resolved by name at Prepare time
    let plan = OpPlan::load_for(&exp)?;
    let mut catalog = vec![pipeline::exact_operating_point(&exp)?];
    catalog.extend(plan.load_operating_points(&exp, mode)?);

    let listener = TcpListener::bind(listen)?;
    let addr = listener.local_addr()?;
    let name = format!("{}@{addr}", exp.name);
    let names: Vec<&str> = catalog.iter().map(|o| o.name.as_str()).collect();
    println!(
        "[{}] fleet worker `{name}`: backend={which} mode={mode} listening on {addr}",
        exp.name
    );
    println!("  catalog ({} OPs): {}", names.len(), names.join(", "));
    println!("  stop with a coordinator Shutdown frame (e.g. fleet teardown)");

    match which {
        "native" => {
            let graph = exp.graph.clone();
            let db = load_db(args)?;
            let kernel = native_kernel(args)?;
            println!("  native kernel: {}", kernel.name());
            worker::run(listener, name, mode, catalog, move |_conn| {
                Ok(NativeBackend::with_kernel(graph.clone(), db.clone(), kernel.clone()))
            })
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let artifacts = exp.artifacts.clone();
            let dir = exp.dir.clone();
            let ishape = exp.graph.input_shape.clone();
            let classes = exp.num_classes();
            let use_bn = mode != "none";
            worker::run(listener, name, mode, catalog, move |_conn| {
                let mut be = PjrtBackend::open(&artifacts, &dir, &ishape, classes)?;
                be.set_bn_overlays(use_bn);
                Ok(be)
            })
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!("this build has no PJRT support (rebuild with the `pjrt` feature)"),
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    }
}
