//! `qos-nets bench --scenario NAME|FILE.json`: scenario-driven load
//! harness.
//!
//! Resolves the scenario (built-in name first, then a JSON file path),
//! runs it through [`crate::bench::driver::run_scenario`] and writes
//! the versioned `BENCH_<scenario>.json` perf record.  `--seed` and
//! `--secs` override the scenario without editing it (both are
//! recorded in the report's provenance), `--dashboard` renders the
//! live ANSI panel, `--metrics-addr HOST:PORT` serves the Prometheus
//! text endpoint while the run is in flight, `--list` and
//! `--print-scenario` introspect the built-ins without running
//! anything.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::bench::driver::{run_scenario, BenchOpts};
use crate::bench::scenario::{builtin, Scenario, BUILTIN_NAMES};
use crate::cli::Args;
use crate::util::json;

pub fn run(args: &Args) -> Result<()> {
    if args.has("list") {
        println!("built-in bench scenarios:");
        for name in BUILTIN_NAMES {
            let sc = builtin(name).expect("builtin");
            println!("  {name:<20} {:.0}s  {}", sc.duration_s, sc.description);
        }
        return Ok(());
    }

    let which = args.get("scenario").context(
        "bench needs --scenario NAME|FILE.json (see `qos-nets bench --list` for built-ins)",
    )?;
    let sc = load_scenario(which)?;

    if args.has("print-scenario") {
        println!("{}", json::to_string_pretty(&sc.to_json()));
        return Ok(());
    }

    // `--autopilot on|off` (bare `--autopilot` = on); unset defers to
    // the scenario: engaged iff it declares `slo_p95_ms`
    let autopilot = match args.get("autopilot") {
        Some("on") => Some(true),
        Some("off") => Some(false),
        Some(other) => bail!("--autopilot takes on|off, got {other:?}"),
        None if args.has("autopilot") => Some(true),
        None => None,
    };
    let opts = BenchOpts {
        seed: args.get("seed").and_then(|s| s.parse().ok()),
        secs: args.get("secs").and_then(|s| s.parse().ok()),
        dashboard: args.has("dashboard"),
        autopilot,
        metrics_addr: args.get("metrics-addr").map(str::to_string),
    };
    if let Some(addr) = opts.metrics_addr.as_deref() {
        println!("metrics: will serve http://{addr}/metrics for the duration of the run");
    }
    println!(
        "bench {}: {} (seed {}, {:.1}s)",
        sc.name,
        sc.description,
        opts.seed.unwrap_or(sc.seed),
        opts.secs.unwrap_or(sc.duration_s)
    );
    let report = run_scenario(&sc, &opts)?;

    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("BENCH_{}.json", sc.name)));
    report.write_to(&out)?;

    let t = &report.throughput;
    println!(
        "bench {}: {} submitted, {} completed ({:.1} img/s) in {:.2}s -> {}",
        report.scenario,
        t.submitted,
        t.completed,
        t.img_per_s,
        report.duration_s,
        out.display()
    );
    println!(
        "  latency: mean={:.2}ms p50<={:.2}ms p95<={:.2}ms p99<={:.2}ms",
        report.latency.mean_us / 1e3,
        report.latency.p50_us as f64 / 1e3,
        report.latency.p95_us as f64 / 1e3,
        report.latency.p99_us as f64 / 1e3
    );
    let s = &report.switches;
    println!(
        "  switches: {} total ({} drain, {} immediate, {} forced)  budget violations={}  retagged={}",
        s.total, s.drain, s.immediate, s.forced, s.budget_violations, s.retagged_batches
    );
    for o in &report.per_op {
        println!(
            "  OP{} ({}, power {:.2}): {} requests  p99<={:.2}ms",
            o.index,
            o.name,
            o.power,
            o.requests,
            o.latency.p99_us as f64 / 1e3
        );
    }
    let sc_ = &report.scaling;
    println!(
        "  workers: peak={} final={} scale-ups={} scale-downs={}",
        sc_.peak_workers, sc_.final_workers, sc_.scale_ups, sc_.scale_downs
    );
    if let Some(ap) = &report.autopilot {
        let fmt_t = |t: Option<f64>| match t {
            Some(t) => format!("{t:.2}s"),
            None => "-".to_string(),
        };
        println!(
            "  autopilot: slo p95<={:.0}ms envelope={:.2}  violations={} first_violation={} first_downgrade={}  decisions={}",
            ap.slo_p95_ms,
            ap.power_envelope,
            ap.slo_violation_ticks,
            fmt_t(ap.first_violation_t_s),
            fmt_t(ap.first_downgrade_t_s),
            ap.decisions.len()
        );
        if let Some(b) = &ap.baseline {
            println!(
                "    baseline (autopilot off, same seed): violations={} first_violation={}",
                b.slo_violation_ticks,
                fmt_t(b.first_violation_t_s)
            );
        }
    }
    if let Some(f) = &report.fleet {
        println!(
            "  fleet: {} worker(s), requeues={} evictions={}",
            f.workers.len(),
            f.requeues,
            f.evictions
        );
        for w in &f.workers {
            println!(
                "    {}: {} requests in {} batches  mean={:.2}ms{}",
                w.addr,
                w.requests,
                w.batches,
                w.mean_latency_us / 1e3,
                if w.evicted { "  [evicted]" } else { "" }
            );
        }
    }
    Ok(())
}

/// Built-in name first; anything else is read as a JSON file.
fn load_scenario(which: &str) -> Result<Scenario> {
    if let Some(sc) = builtin(which) {
        return Ok(sc);
    }
    let text = std::fs::read_to_string(which).with_context(|| {
        format!(
            "no built-in scenario {which:?} and no such file \
             (built-ins: {})",
            BUILTIN_NAMES.join(", ")
        )
    })?;
    let v = json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {which}: {e}"))?;
    Scenario::from_json(&v).with_context(|| format!("loading scenario from {which}"))
}
