//! `qos-nets search`: the QoS-Nets clustered multi-OP search.

use std::time::Instant;

use anyhow::Result;

use crate::cli::commands::{load_db, load_experiment};
use crate::cli::Args;
use crate::pipeline;

pub fn run(args: &Args) -> Result<()> {
    let exp = load_experiment(args)?;
    let db = load_db(args)?;
    let t0 = Instant::now();
    let (se, sol) = pipeline::run_search(&exp, &db);
    let path = pipeline::write_assignment(&exp, &db, &sol)?;
    println!(
        "[{}] search over {} layers x {} multipliers, {} operating points in {:?}",
        exp.name,
        se.l,
        se.m,
        exp.scales().len(),
        t0.elapsed()
    );
    println!(
        "subset ({} of n={}): {}",
        sol.subset.len(),
        exp.n_multipliers(),
        sol.subset
            .iter()
            .map(|&m| db.specs[m].name.clone())
            .collect::<Vec<_>>()
            .join(", ")
    );
    for (i, p) in sol.power.iter().enumerate() {
        println!(
            "  OP{i} (scale {:.2}): relative multiplication power {:.2}% (saving {:.1}%)",
            exp.scales()[i],
            100.0 * p,
            100.0 * (1.0 - p)
        );
    }
    println!("wrote {}", path.display());
    Ok(())
}
