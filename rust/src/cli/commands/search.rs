//! `qos-nets search --algo <planner>`: run any registered mapper — the
//! QoS-Nets clustered search (default) or one of the baselines — and
//! write the typed, versioned `OpPlan` to `assignment.json`.  Every
//! algorithm goes through the same [`crate::plan::Planner`] code path,
//! so the artifact that reaches eval/serving is identical in shape.

use std::time::Instant;

use anyhow::Result;

use crate::cli::commands::{load_db, load_experiment};
use crate::cli::Args;
use crate::plan;

pub fn run(args: &Args) -> Result<()> {
    let exp = load_experiment(args)?;
    let db = load_db(args)?;
    let algo = args.get_or("algo", "qos");
    let t0 = Instant::now();
    let plan = plan::plan_experiment(algo, &exp, &db)?;
    let path = plan.save_for(&exp)?;
    println!(
        "[{}] planner `{algo}` over {} layers x {} multipliers, {} operating points in {:?}",
        exp.name,
        plan.layer_names.len(),
        db.len(),
        plan.ops.len(),
        t0.elapsed()
    );
    println!(
        "subset ({} of budget {}): {}",
        plan.subset.len(),
        plan.n_multipliers,
        plan.subset
            .iter()
            .map(|m| m.name.as_str())
            .collect::<Vec<_>>()
            .join(", ")
    );
    for op in &plan.ops {
        println!(
            "  {} (scale {:.2}): relative multiplication power {:.2}% (saving {:.1}%)",
            op.name,
            op.scale,
            100.0 * op.relative_power,
            100.0 * (1.0 - op.relative_power)
        );
    }
    println!("wrote {} (plan version {})", path.display(), plan::PLAN_VERSION);
    Ok(())
}
