//! Hand-rolled CLI (clap is unavailable offline): the `Args` flag parser
//! lives here; the subcommand implementations live in [`commands`], one
//! file per subcommand, dispatched by [`commands::dispatch`].

pub mod commands;

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    /// Every occurrence of each valued flag, in argv order (repeatable
    /// flags like `--tenant` read them all; `get` takes the last).
    flags: HashMap<String, Vec<String>>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `cmd [positional...] [--flag value | --switch]...`.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            out.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // value if next token exists and is not another flag
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.flags.entry(name.to_string()).or_default().push((*v).clone());
                        it.next();
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        out
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every value a repeatable flag was given, in argv order (empty
    /// when the flag is absent).
    pub fn get_all(&self, name: &str) -> Vec<String> {
        self.flags.get(name).cloned().unwrap_or_default()
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

pub const USAGE: &str = "qos-nets — adaptive approximate NN inference (QoS-Nets reproduction)

USAGE: qos-nets <command> [--flags]

COMMANDS
  muldb                         print the approximate-multiplier family
  search    --exp E [--algo A]  run a registered planner and write the
                                typed OpPlan to artifacts/E/assignment.json
                                (A: qos|alwann|homogeneous|lvrm|pnam|tpm|
                                gradient, default qos; every algorithm
                                goes through the same Planner code path)
  baselines --exp E             run every registered planner on identical
                                inputs, print one comparison table
                                (paper Table 1 shape, qos included)
  eval      --exp E [--backend B] [--mode M] [--kernel K] [--fleet H:P,...]
                                evaluate every operating point through the
                                unified Backend trait (B: native|pjrt,
                                default native; M: none|bn|full, default bn
                                — pjrt honors bn overlays only; --fleet
                                evaluates over remote fleet workers)
  serve     --exp E [--backend B] [--kernel K] [--secs S]
            [--workers N] [--min-workers N] [--max-workers N]
            [--scale-interval-ms N] [--scale-up-after N]
            [--scale-down-after N]
            [--fleet H:P,H:P,...] [--pipeline N] [--registry ADDR]
            [--reprobe-interval-ms N] [--retag-downgrades]
            [--tenant NAME:SLO_MS:SHARE]... [--tenants-file F.json]
            [--max-inflight N]
            [--autopilot [--slo-p95-ms MS] [--power-envelope F]]
            [--metrics-addr HOST:PORT] [--flight-recorder [DIR]]
                                QoS serving demo: elastic batching server
                                with a power-budget trace driving OP
                                switches (draining upgrades / immediate
                                downgrades) and load-driven worker
                                scaling (B: native|pjrt, default native;
                                --fleet scatters batches across remote
                                workers over pipelined connections and
                                broadcasts OP switches fleet-wide;
                                --pipeline pins the in-flight Forward
                                window per worker, 1 = lockstep;
                                --registry binds a join endpoint so
                                `worker --join` grows the fleet under
                                load; --retag-downgrades lets an
                                immediate downgrade retag already-formed
                                batches to the cheaper OP;
                                --scale-interval-ms/--scale-up-after/
                                --scale-down-after tune the supervisor's
                                sampling cadence and hysteresis;
                                --reprobe-interval-ms re-probes evicted
                                fleet workers on its own cadence instead
                                of every heartbeat tick;
                                --tenant (repeatable, flag order =
                                priority: first = premium) or
                                --tenants-file carve the deployment into
                                tenant classes — per-class queues and
                                (op, mode) words, per-class metrics, and
                                share-weighted admission under
                                --max-inflight (0 = unlimited): under
                                overload best-effort classes are
                                rejected first, premium only when the
                                deployment is hard-full;
                                --autopilot closes the loop on a latency
                                SLO: one controller jointly steers the
                                OP ladder, the worker pool and the fleet
                                chunk plan against --slo-p95-ms (default
                                100) under --power-envelope (default 1.0
                                = env budget only), shedding accuracy
                                before latency and recovering accuracy
                                only after sustained headroom;
                                --metrics-addr serves the Prometheus
                                text endpoint for the run's duration,
                                --flight-recorder arms the event ring —
                                dumped to DIR (default .) on SLO
                                violations, evictions, and GET /dump)
  worker    --exp E [--listen ADDR] [--backend B] [--mode M] [--kernel K]
            [--hb-interval-ms N] [--hb-timeout-ms N]
            [--join HOST:PORT] [--advertise ADDR]
                                fleet worker daemon: serves the
                                experiment's OP catalog (exact baseline
                                + plan ladder) over the fleet wire
                                protocol until a coordinator sends
                                Shutdown (default ADDR 127.0.0.1:7070;
                                the hb flags set the heartbeat cadence
                                advertised in HelloAck — coordinators
                                probe at the fleet-wide minimum; --join
                                announces the worker to a coordinator's
                                --registry endpoint, --advertise
                                overrides the announced address)
  bench     --scenario NAME|FILE.json [--seed N] [--secs S] [--out FILE]
            [--dashboard] [--list] [--print-scenario] [--autopilot on|off]
            [--metrics-addr HOST:PORT]
                                scenario-driven load harness: replays a
                                seeded open-loop arrival trace against
                                the deployment the scenario describes
                                (native synthetic model, delayed stub,
                                or loopback fleet), walks the OP ladder
                                from its budget source, and writes the
                                versioned BENCH_<scenario>.json perf
                                record (per-OP quantiles, switch
                                timeline, scale events); --list shows
                                the built-in scenarios; scenarios with
                                an slo_p95_ms target engage the SLO
                                autopilot (override with --autopilot
                                on|off) and run twice on one seed, so
                                the report carries the closed-loop
                                decision log plus the uncontrolled
                                baseline p95 timeline; --metrics-addr
                                serves the Prometheus text endpoint
                                (same registry the --dashboard panel
                                reads) while the run is in flight
  plan      diff A.json B.json [--json]
                                compare two stored OpPlans: per-layer
                                assignment deltas per OP, per-OP power
                                deltas, subset + provenance differences
                                (--json emits the same diff machine-
                                readable for CI gates)
  report    <fig1|fig2|fig3> --exp E   dump figure data series
  selftest  --exp E             cross-layer integration checks

DEPRECATED
  eval-pjrt --exp E             alias for `eval --backend pjrt`

COMMON FLAGS
  --artifacts DIR   artifacts directory (default: artifacts)
  --limit N         cap evaluation set size
  --batch N         engine batch size (default 32)
  --kernel K        native LUT matmul kernel: scalar|avx2|threaded|auto
                    (native backend only; default auto = runtime feature
                    detection, AVX2 where the CPU has it; threaded shards
                    M-tiles across all hardware threads; the
                    QOS_NETS_KERNEL env var sets the default)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = parse(&["search", "--exp", "quick", "--verbose", "--limit", "10"]);
        assert_eq!(a.command, "search");
        assert_eq!(a.get("exp"), Some("quick"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("limit", 0), 10);
    }

    #[test]
    fn repeated_flags_keep_every_value_and_get_takes_the_last() {
        let a = parse(&["serve", "--tenant", "premium:100:3", "--tenant", "be:250:1"]);
        assert_eq!(a.get_all("tenant"), vec!["premium:100:3", "be:250:1"]);
        assert_eq!(a.get("tenant"), Some("be:250:1"));
        assert!(a.get_all("missing").is_empty());
    }

    #[test]
    fn positional_args() {
        let a = parse(&["report", "fig3", "--exp", "table4_mnv2"]);
        assert_eq!(a.positional, vec!["fig3"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&["eval"]);
        assert_eq!(a.get_or("exp", "quick"), "quick");
        assert_eq!(a.get_f64("x", 1.5), 1.5);
    }
}
