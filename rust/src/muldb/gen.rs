//! Behavioural multiplier definitions — must stay bit-identical to
//! `python/compile/muldb.py` (guarded by the SHA-256 golden test).

use super::{MulSpec, Technique};

pub const N_OPERAND: usize = 256;

// ---------------------------------------------------------------------------
// Scalar behavioural models (u8 codes in, exact integer out).
// ---------------------------------------------------------------------------

#[inline]
pub fn mul_exact(a: u32, b: u32) -> u32 {
    a * b
}

#[inline]
pub fn mul_trunc_op(a: u32, b: u32, k: u32) -> u32 {
    let mask = !((1u32 << k) - 1) & 0xFF;
    (a & mask) * (b & mask)
}

pub fn mul_bam(a: u32, b: u32, h: u32) -> u32 {
    let mut acc = 0u32;
    for i in 0..8 {
        if (a >> i) & 1 == 0 {
            continue;
        }
        for j in 0..8 {
            if (b >> j) & 1 == 1 && i + j >= h {
                acc += 1 << (i + j);
            }
        }
    }
    acc
}

pub fn bam_compensation(h: u32) -> u32 {
    let mut total = 0u32;
    for i in 0..8 {
        for j in 0..8 {
            if i + j < h {
                total += 1 << (i + j);
            }
        }
    }
    (total + 2) / 4
}

pub fn mul_bamc(a: u32, b: u32, h: u32) -> u32 {
    mul_bam(a, b, h) + bam_compensation(h)
}

#[inline]
fn bit_length(x: u32) -> u32 {
    32 - x.leading_zeros()
}

fn drum_approx_operand(x: u32, k: u32) -> u32 {
    if x < (1 << k) {
        return x;
    }
    let msb = bit_length(x) - 1;
    let shift = msb - k + 1;
    ((x >> shift) | 1) << shift
}

pub fn mul_drum(a: u32, b: u32, k: u32) -> u32 {
    if a == 0 || b == 0 {
        return 0;
    }
    drum_approx_operand(a, k) * drum_approx_operand(b, k)
}

pub fn mul_mitchell(a: u32, b: u32, frac_bits: u32) -> u32 {
    if a == 0 || b == 0 {
        return 0;
    }
    let f = frac_bits;
    let la = bit_length(a) - 1;
    let lb = bit_length(b) - 1;
    let fa = ((a - (1 << la)) << f) >> la;
    let fb = ((b - (1 << lb)) << f) >> lb;
    let lsum = ((la + lb) << f) + fa + fb;
    let k = lsum >> f;
    let frac = lsum & ((1 << f) - 1);
    (((1 << f) + frac) << k) >> f
}

pub fn mul_loa(a: u32, b: u32, h: u32) -> u32 {
    let mask = (1u32 << h) - 1;
    let (ah, al) = (a >> h, a & mask);
    let (bh, bl) = (b >> h, b & mask);
    ((ah * bh) << (2 * h)) + (((ah * bl) + (bh * al)) << h) + (al | bl)
}

#[inline]
pub fn mul_otrunc(a: u32, b: u32, k: u32) -> u32 {
    (a * b) & !((1u32 << k) - 1)
}

#[inline]
pub fn mul_otruncc(a: u32, b: u32, k: u32) -> u32 {
    mul_otrunc(a, b, k) + (1 << (k - 1))
}

pub fn eval(tech: Technique, param: u32, a: u32, b: u32) -> u32 {
    match tech {
        Technique::Exact => mul_exact(a, b),
        Technique::Trunc => mul_trunc_op(a, b, param),
        Technique::Bam => mul_bam(a, b, param),
        Technique::Bamc => mul_bamc(a, b, param),
        Technique::Drum => mul_drum(a, b, param),
        Technique::Mitch => mul_mitchell(a, b, param),
        Technique::Loa => mul_loa(a, b, param),
        Technique::Otrunc => mul_otrunc(a, b, param),
        Technique::Otruncc => mul_otruncc(a, b, param),
    }
}

// ---------------------------------------------------------------------------
// Power model (structural proxy; identical formulas to the Python side).
// ---------------------------------------------------------------------------

fn bam_power(h: u32) -> f64 {
    let mut kept = 0;
    for i in 0..8u32 {
        for j in 0..8u32 {
            if i + j >= h {
                kept += 1;
            }
        }
    }
    kept as f64 / 64.0
}

pub fn power_model(tech: Technique, param: u32) -> f64 {
    let p = param as f64;
    match tech {
        Technique::Exact => 1.0,
        Technique::Trunc => ((8.0 - p) / 8.0) * ((8.0 - p) / 8.0),
        Technique::Bam => bam_power(param),
        Technique::Bamc => bam_power(param) + 0.01,
        Technique::Drum => (p * p) / 64.0 + 0.08,
        Technique::Mitch => 0.11 + p * 0.012,
        Technique::Loa => (64.0 - p * p) / 64.0 + 0.008,
        Technique::Otrunc => 1.0 - p * 0.06,
        Technique::Otruncc => 1.0 - p * 0.06 + 0.005,
    }
}

/// The fixed 37-instance search space (order defines the dense ids).
pub fn family() -> Vec<MulSpec> {
    let mut specs: Vec<(Technique, u32)> = vec![(Technique::Exact, 0)];
    specs.extend((1..=4).map(|k| (Technique::Trunc, k)));
    specs.extend((3..=10).map(|h| (Technique::Bam, h)));
    specs.extend((3..=8).map(|h| (Technique::Bamc, h)));
    specs.extend((3..=6).map(|k| (Technique::Drum, k)));
    specs.extend([7, 5, 3].map(|f| (Technique::Mitch, f)));
    specs.extend([3, 4, 5, 6].map(|h| (Technique::Loa, h)));
    specs.extend([2, 4, 6, 8].map(|k| (Technique::Otrunc, k)));
    specs.extend([4, 6, 8].map(|k| (Technique::Otruncc, k)));
    assert_eq!(specs.len(), 37);
    specs
        .into_iter()
        .enumerate()
        .map(|(id, (tech, param))| MulSpec {
            id,
            name: if tech == Technique::Exact {
                "am8u_exact".to_string()
            } else {
                format!("am8u_{}{}", tech.as_str(), param)
            },
            technique: tech,
            param,
            power: power_model(tech, param),
        })
        .collect()
}

/// Materialize one instance's 256x256 LUT (row-major, lut[a*256+b]).
pub fn build_lut(spec: &MulSpec) -> Vec<i32> {
    let mut lut = vec![0i32; N_OPERAND * N_OPERAND];
    for a in 0..N_OPERAND as u32 {
        for b in 0..N_OPERAND as u32 {
            lut[(a as usize) * N_OPERAND + b as usize] =
                eval(spec.technique, spec.param, a, b) as i32;
        }
    }
    lut
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drum_unbiasing_sets_lsb() {
        // 0b11010000 with k=4 keeps 1101 and forces the kept LSB to 1
        assert_eq!(drum_approx_operand(0b1101_0000, 4), 0b1101_0000);
        assert_eq!(drum_approx_operand(0b1100_0000, 4), 0b1101_0000);
        assert_eq!(drum_approx_operand(7, 4), 7); // below 2^k untouched
    }

    #[test]
    fn mitchell_exact_on_powers_of_two() {
        for (a, b) in [(1u32, 1u32), (2, 4), (16, 8), (128, 2)] {
            assert_eq!(mul_mitchell(a, b, 7), a * b, "{a}*{b}");
        }
    }

    #[test]
    fn bam_upper_bound_is_exact() {
        // h = 0 drops nothing
        for (a, b) in [(0u32, 0u32), (255, 255), (13, 77)] {
            assert_eq!(mul_bam(a, b, 0), a * b);
        }
    }

    #[test]
    fn otrunc_only_clears_low_bits() {
        for (a, b) in [(255u32, 255u32), (17, 31)] {
            let p = a * b;
            assert_eq!(mul_otrunc(a, b, 4), p & !0xF);
        }
    }
}
