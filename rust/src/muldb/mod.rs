//! Approximate-multiplier database — bit-exact Rust mirror of
//! `python/compile/muldb.py` (the EvoApprox8b substitute).
//!
//! Both sides generate the same 37 u8 x u8 -> u32 behavioural models and
//! the same 256x256 LUT stack; the SHA-256 of the serialized stack is the
//! cross-language golden value (`tests::digest_matches_python` +
//! `python/tests/test_muldb.py`).  The Rust side can therefore either
//! load `artifacts/luts.bin` or regenerate the family offline.

mod gen;

pub use gen::*;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json;

/// One multiplier instance in the search space.
#[derive(Debug, Clone, PartialEq)]
pub struct MulSpec {
    pub id: usize,
    pub name: String,
    pub technique: Technique,
    pub param: u32,
    /// Relative power vs the accurate multiplier (structural proxy).
    pub power: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Technique {
    Exact,
    Trunc,
    Bam,
    Bamc,
    Drum,
    Mitch,
    Loa,
    Otrunc,
    Otruncc,
}

impl Technique {
    pub fn as_str(&self) -> &'static str {
        match self {
            Technique::Exact => "exact",
            Technique::Trunc => "trunc",
            Technique::Bam => "bam",
            Technique::Bamc => "bamc",
            Technique::Drum => "drum",
            Technique::Mitch => "mitch",
            Technique::Loa => "loa",
            Technique::Otrunc => "otrunc",
            Technique::Otruncc => "otruncc",
        }
    }
}

/// The whole family with materialized LUTs.
pub struct MulDb {
    pub specs: Vec<MulSpec>,
    /// specs.len() x 65536, row-major `lut[id][a * 256 + b]`.
    pub luts: Vec<Vec<i32>>,
}

impl MulDb {
    /// Regenerate the family from the behavioural definitions.
    pub fn generate() -> Self {
        let specs = family();
        let luts = specs.iter().map(|s| build_lut(s)).collect();
        MulDb { specs, luts }
    }

    /// Load `luts.bin` + `muldb.json` from the artifacts directory and
    /// verify the digest matches our own generator (drift check).
    pub fn load(artifacts: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts.as_ref();
        let meta_raw = std::fs::read_to_string(dir.join("muldb.json"))
            .with_context(|| format!("read {}/muldb.json", dir.display()))?;
        let meta = json::parse(&meta_raw).map_err(anyhow::Error::msg)?;
        let blob = std::fs::read(dir.join("luts.bin"))?;
        if blob.len() < 12 || &blob[..4] != b"QLUT" {
            bail!("luts.bin: bad magic");
        }
        let count = u32::from_le_bytes(blob[4..8].try_into().unwrap()) as usize;
        let entries = u32::from_le_bytes(blob[8..12].try_into().unwrap()) as usize;
        if entries != 65536 {
            bail!("luts.bin: expected 65536 entries per LUT, got {entries}");
        }
        let body = &blob[12..];
        if body.len() != count * entries * 4 {
            bail!("luts.bin: truncated body");
        }
        let mut luts = Vec::with_capacity(count);
        for i in 0..count {
            let lut: Vec<i32> = body[i * entries * 4..(i + 1) * entries * 4]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            luts.push(lut);
        }
        let mut specs = Vec::new();
        for m in meta.req("multipliers").map_err(anyhow::Error::msg)?.as_arr().unwrap_or(&[]) {
            let tech = match m.get("technique").and_then(|v| v.as_str()).unwrap_or("") {
                "exact" => Technique::Exact,
                "trunc" => Technique::Trunc,
                "bam" => Technique::Bam,
                "bamc" => Technique::Bamc,
                "drum" => Technique::Drum,
                "mitch" => Technique::Mitch,
                "loa" => Technique::Loa,
                "otrunc" => Technique::Otrunc,
                "otruncc" => Technique::Otruncc,
                other => bail!("unknown technique {other}"),
            };
            specs.push(MulSpec {
                id: m.get("id").and_then(|v| v.as_usize()).context("id")?,
                name: m.get("name").and_then(|v| v.as_str()).context("name")?.to_string(),
                technique: tech,
                param: m.get("param").and_then(|v| v.as_i64()).unwrap_or(0) as u32,
                power: m.get("power").and_then(|v| v.as_f64()).context("power")?,
            });
        }
        if specs.len() != luts.len() {
            bail!("muldb.json count {} != luts.bin count {}", specs.len(), luts.len());
        }
        let db = MulDb { specs, luts };
        // drift check against our own generator
        let own = MulDb::generate();
        if own.digest() != db.digest() {
            bail!(
                "LUT digest mismatch: artifacts {} vs generator {} — python/rust muldb drift",
                &db.digest()[..16],
                &own.digest()[..16]
            );
        }
        Ok(db)
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn lut(&self, id: usize) -> &[i32] {
        &self.luts[id]
    }

    pub fn power(&self, id: usize) -> f64 {
        self.specs[id].power
    }

    pub fn by_name(&self, name: &str) -> Option<&MulSpec> {
        self.specs.iter().find(|s| s.name == name)
    }

    /// SHA-256 over the Python-compatible serialization.
    pub fn digest(&self) -> String {
        use sha2::{Digest, Sha256};
        let mut h = Sha256::new();
        h.update(b"QLUT");
        h.update((self.luts.len() as u32).to_le_bytes());
        h.update(65536u32.to_le_bytes());
        for lut in &self.luts {
            for v in lut {
                h.update(v.to_le_bytes());
            }
        }
        let out = h.finalize();
        out.iter().map(|b| format!("{b:02x}")).collect()
    }

    /// Error statistics over the uniform operand distribution.
    pub fn error_stats(&self, id: usize) -> ErrorStats {
        let lut = &self.luts[id];
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        let mut abs = 0.0f64;
        let mut wce = 0.0f64;
        let mut red_sum = 0.0f64;
        let mut red_n = 0usize;
        for a in 0..256usize {
            for b in 0..256usize {
                let exact = (a * b) as f64;
                let e = lut[a * 256 + b] as f64 - exact;
                sum += e;
                sq += e * e;
                abs += e.abs();
                wce = wce.max(e.abs());
                if exact > 0.0 {
                    red_sum += e.abs() / exact;
                    red_n += 1;
                }
            }
        }
        let n = 65536.0;
        let mean = sum / n;
        ErrorStats {
            mean,
            std: (sq / n - mean * mean).max(0.0).sqrt(),
            med: abs / n,
            mred: red_sum / red_n as f64,
            wce,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorStats {
    pub mean: f64,
    pub std: f64,
    pub med: f64,
    pub mred: f64,
    pub wce: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_has_37_instances() {
        let db = MulDb::generate();
        assert_eq!(db.len(), 37);
        assert_eq!(db.specs[0].name, "am8u_exact");
        assert!((db.specs[0].power - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exact_lut_is_product() {
        let db = MulDb::generate();
        let lut = db.lut(0);
        for a in 0..256usize {
            for b in 0..256usize {
                assert_eq!(lut[a * 256 + b], (a * b) as i32);
            }
        }
    }

    #[test]
    fn approximate_luts_bounded_error() {
        let db = MulDb::generate();
        for s in &db.specs {
            let st = db.error_stats(s.id);
            // every instance is sane: wce below full-scale product
            assert!(st.wce < 65025.0, "{}: wce {}", s.name, st.wce);
            if s.technique != Technique::Exact {
                assert!(st.med > 0.0, "{}: degenerate error", s.name);
            }
        }
    }

    #[test]
    fn power_spread_covers_pareto_range() {
        let db = MulDb::generate();
        let min = db.specs.iter().map(|s| s.power).fold(f64::MAX, f64::min);
        let max = db.specs.iter().map(|s| s.power).fold(f64::MIN, f64::max);
        assert!(min < 0.2, "cheapest instance {min}");
        assert!((max - 1.0).abs() < 1e-12);
    }

    /// Golden digest, generated by python/compile/muldb.py.  If this
    /// fails, the two behavioural models have drifted apart.
    #[test]
    fn digest_matches_python() {
        let db = MulDb::generate();
        assert_eq!(
            db.digest(),
            "351117ce8837aa4c469a02f8a2c6d5f6a3a9aab0cba8f4c4c29d05926d27c723"
        );
    }
}
