//! Fleet worker daemon: wraps any [`Backend`] behind the wire protocol.
//!
//! One daemon owns a `TcpListener` and an *OP catalog* (every operating
//! point it can serve, by name — for the CLI that is the exact baseline
//! plus the stored plan's ladder).  Each coordinator connection gets
//! its own handler thread and its own backend instance built by the
//! factory *inside* that thread (backends need not be `Send`, exactly
//! like `server::Server` workers); `Prepare` resolves the requested
//! ladder against the catalog by name, cross-checks the expected
//! relative power, and makes it resident.
//!
//! Each connection is split into a *reader* and a *compute* half so the
//! coordinator can pipeline: the reader thread answers latency-critical
//! control frames (`Hello`, `Heartbeat`, `Shutdown`) inline and queues
//! everything else ([`Work`]) to the compute half, which owns the
//! (non-`Send`) backend on the handler thread and answers through a
//! shared, mutex-serialized writer.  Up to [`WORKER_MAX_INFLIGHT`]
//! id-tagged Forwards may be queued per connection (advertised in
//! `HelloAck`); replies echo the request id, so they stay matchable
//! even though control replies interleave.  The queue is FIFO, which
//! keeps `SetOp { drain: true }`/`Drain` ordered *behind* every Forward
//! the coordinator sent first on the same connection.
//!
//! Cross-connection semantics live in the daemon's shared state:
//!
//! * **Drain barrier.**  Forwards from every connection run inside a
//!   `Gate` read section; `SetOp { drain: true }` and `Drain` wait
//!   until no forward is in flight anywhere in the process (new
//!   forwards block while a drain is pending, so a busy worker cannot
//!   starve the barrier), then apply and ack — the per-worker barrier
//!   the coordinator counts before reporting a fleet switch complete.
//! * **Current OP.**  `SetOp` updates a process-wide index used by
//!   `Forward` frames that omit `op` (edge clients that rely on the
//!   fleet-broadcast operating point instead of picking their own).
//! * **Shutdown.**  A `Shutdown` frame acks, then stops the accept
//!   loop and closes every registered connection, so the daemon winds
//!   down promptly even with idle coordinators attached.
//!
//! [`WorkerHandle::kill`] closes the listener and every live connection
//! *without* the ack dance — the failure-injection hook the loopback
//! tests use to simulate a worker dying mid-stream.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::backend::Backend;
use crate::engine::OperatingPoint;
use crate::fleet::wire::{
    self, Frame, LadderRung, DEFAULT_HB_INTERVAL_MS, DEFAULT_HB_TIMEOUT_MS, PROTOCOL_VERSION,
};
use crate::obs::{self, ObsEvent};

/// Pipelining capability one worker connection advertises in
/// `HelloAck`: the queue between the reader and the compute half is
/// unbounded, but coordinators should not build windows deeper than
/// this (beyond it, queued batches only add memory pressure and switch
/// latency, never throughput).
pub const WORKER_MAX_INFLIGHT: u64 = 64;

/// Draining gate: forwards enter read sections, a drain waits for all
/// of them to leave while blocking new entries (writer-preferring, so a
/// loaded worker cannot starve the barrier the way an `RwLock` could).
/// Sections and barriers are keyed by tenant class: a class-scoped
/// barrier waits only for that class's forwards and blocks only that
/// class's new entries, so a premium switch never stalls behind a
/// best-effort backlog.  Un-classed barriers (`None`) keep the legacy
/// whole-process semantics.
#[derive(Default)]
struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
}

#[derive(Default)]
struct GateState {
    /// In-flight forwards per class id (grown on demand; un-classed
    /// forwards count as class 0).
    inflight: Vec<usize>,
    /// Classes with a pending class-scoped barrier.
    draining: Vec<bool>,
    /// A pending whole-process barrier (legacy un-classed drain).
    draining_all: bool,
}

impl GateState {
    fn slot(&mut self, class: usize) -> &mut usize {
        if self.inflight.len() <= class {
            self.inflight.resize(class + 1, 0);
        }
        &mut self.inflight[class]
    }

    fn drain_flag(&mut self, class: usize) -> &mut bool {
        if self.draining.len() <= class {
            self.draining.resize(class + 1, false);
        }
        &mut self.draining[class]
    }

    fn blocked(&self, class: usize) -> bool {
        self.draining_all || self.draining.get(class).copied().unwrap_or(false)
    }
}

/// An in-flight read section of a [`Gate`], from [`Gate::enter`].  The
/// count is decremented on drop, so a forward that *panics* (backend
/// bug, malformed payload tripping an internal assert) unwinds the
/// handler thread without leaving the in-flight count stuck nonzero —
/// which would wedge every future drain barrier process-wide.
struct GateSection<'a> {
    gate: &'a Gate,
    class: usize,
}

impl Drop for GateSection<'_> {
    fn drop(&mut self) {
        let mut g = self.gate.state.lock().unwrap();
        *g.slot(self.class) -= 1;
        self.gate.cv.notify_all();
    }
}

impl Gate {
    /// Begin a forward for one class; blocks while a barrier covering
    /// that class is pending.  The section ends when the returned
    /// handle drops (including by unwind).
    fn enter(&self, class: usize) -> GateSection<'_> {
        let mut g = self.state.lock().unwrap();
        while g.blocked(class) {
            g = self.cv.wait(g).unwrap();
        }
        *g.slot(class) += 1;
        GateSection { gate: self, class }
    }

    /// Run `f` once every in-flight forward of `class` (every class
    /// when `None`) has completed; new forwards in the barrier's scope
    /// wait until `f` returns.  The drain flag is re-asserted on every
    /// wakeup, so overlapping drains (two coordinator connections
    /// issuing barriers at once) keep their writer preference even
    /// after the first drain clears the flag.
    fn drain<T>(&self, class: Option<usize>, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let mut g = self.state.lock().unwrap();
        loop {
            let clear = match class {
                None => {
                    g.draining_all = true;
                    g.inflight.iter().sum::<usize>() == 0
                }
                Some(c) => {
                    *g.drain_flag(c) = true;
                    g.inflight.get(c).copied().unwrap_or(0) == 0
                }
            };
            if clear {
                break;
            }
            g = self.cv.wait(g).unwrap();
        }
        obs::publish(ObsEvent::WorkerBarrier { waited_us: t0.elapsed().as_micros() as u64 });
        let out = f();
        match class {
            None => g.draining_all = false,
            Some(c) => *g.drain_flag(c) = false,
        }
        drop(g);
        self.cv.notify_all();
        out
    }
}

/// Identity and cadence knobs for one worker daemon, the argument
/// bundle behind [`spawn_with`]/[`run_with`].  The heartbeat pair is
/// advertised in `HelloAck` so coordinators can probe at the cadence
/// each worker was actually launched with — a short-leashed edge
/// worker shortens fleet eviction time without redeploying peers.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Identity reported in `HelloAck` and error messages.
    pub name: String,
    /// Retraining-overlay mode the catalog was built with (`bn`,
    /// `full`, `none`; empty when not applicable).
    pub mode: String,
    /// How often this worker expects to be heartbeat-probed.
    pub hb_interval: Duration,
    /// How long a probe may go unanswered before eviction.
    pub hb_timeout: Duration,
}

impl WorkerOptions {
    /// Options with the legacy hard-coded heartbeat cadence.
    pub fn new(name: impl Into<String>, mode: impl Into<String>) -> Self {
        WorkerOptions {
            name: name.into(),
            mode: mode.into(),
            hb_interval: Duration::from_millis(DEFAULT_HB_INTERVAL_MS),
            hb_timeout: Duration::from_millis(DEFAULT_HB_TIMEOUT_MS),
        }
    }

    /// Override the advertised heartbeat cadence.
    pub fn heartbeat(mut self, interval: Duration, timeout: Duration) -> Self {
        self.hb_interval = interval;
        self.hb_timeout = timeout;
        self
    }
}

/// State shared by every connection handler of one daemon.
struct WorkerShared {
    name: String,
    /// Retraining-overlay mode the catalog was built with (advertised
    /// in `HelloAck` so coordinators can cross-check their own
    /// `--mode`); empty when not applicable (in-process test workers).
    mode: String,
    /// Heartbeat cadence advertised in `HelloAck`.
    hb_interval: Duration,
    hb_timeout: Duration,
    /// Index into the *prepared* ladder used by `Forward` frames that
    /// omit `op`; updated by un-classed `SetOp` frames.
    current_op: AtomicUsize,
    /// Per-class current OP, installed by class-tagged `SetOp` frames
    /// (grown on demand); a class with no entry falls back to the
    /// process-wide `current_op`.
    class_ops: Mutex<Vec<Option<usize>>>,
    /// Images forwarded since startup (reported in `Pong`).
    served: AtomicU64,
    stop: AtomicBool,
    gate: Gate,
    /// Clones of every *live* connection keyed by connection id, so
    /// shutdown/kill can unblock handler threads stuck in a read; each
    /// handler removes its entry on exit, so closed peers do not leak
    /// file descriptors in a long-running daemon.
    conns: Mutex<Vec<(usize, TcpStream)>>,
}

impl WorkerShared {
    /// Current OP for a `Forward` that omitted `op`: the class's own
    /// word when a class-tagged `SetOp` installed one, else the
    /// process-wide legacy word.
    fn op_for(&self, class: Option<usize>) -> usize {
        if let Some(c) = class {
            if let Some(op) = self.class_ops.lock().unwrap().get(c).and_then(|o| *o) {
                return op;
            }
        }
        self.current_op.load(Ordering::Acquire)
    }

    /// Install a `SetOp`: class-tagged frames write their class's own
    /// word, un-classed frames the process-wide one — superseding every
    /// per-class override, because a legacy whole-process switch means
    /// the whole process.
    fn store_op(&self, class: Option<usize>, op: usize) {
        match class {
            None => {
                self.current_op.store(op, Ordering::Release);
                self.class_ops.lock().unwrap().clear();
            }
            Some(c) => {
                let mut ops = self.class_ops.lock().unwrap();
                if ops.len() <= c {
                    ops.resize(c + 1, None);
                }
                ops[c] = Some(op);
            }
        }
    }

    fn close_all(&self) {
        self.stop.store(true, Ordering::Release);
        for (_, c) in self.conns.lock().unwrap().iter() {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
    }

    fn forget_conn(&self, conn_id: usize) {
        self.conns.lock().unwrap().retain(|(id, _)| *id != conn_id);
    }
}

/// Handle to a spawned worker daemon (in-process use and tests; the
/// `qos-nets worker` CLI wraps [`run`] instead).
pub struct WorkerHandle {
    addr: SocketAddr,
    shared: Arc<WorkerShared>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl WorkerHandle {
    /// The bound address (resolves `127.0.0.1:0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Images forwarded so far.
    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Acquire)
    }

    /// Abrupt death: close the listener and every live connection
    /// without acking anything — coordinators see I/O errors on
    /// whatever was in flight.  Joins the daemon threads before
    /// returning.
    pub fn kill(mut self) {
        self.shared.close_all();
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }

    /// Wait for the daemon to wind down (a coordinator's `Shutdown`
    /// frame, or a prior `kill`).
    pub fn join(mut self) {
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

/// Spawn a worker daemon on `listener` with the legacy heartbeat
/// cadence.  See [`spawn_with`] for the full option set.
pub fn spawn<B, F>(
    listener: TcpListener,
    name: impl Into<String>,
    mode: impl Into<String>,
    catalog: Vec<OperatingPoint>,
    factory: F,
) -> Result<WorkerHandle>
where
    B: Backend + 'static,
    F: Fn(usize) -> Result<B> + Send + Sync + 'static,
{
    spawn_with(listener, WorkerOptions::new(name, mode), catalog, factory)
}

/// Spawn a worker daemon on `listener`.  `catalog` is every operating
/// point this worker can make resident, resolved by name at `Prepare`
/// time; `opts` carries identity, the overlay mode the catalog was
/// built with (empty = not applicable, advertised in `HelloAck` for
/// coordinator-side cross-checks) and the heartbeat cadence to
/// advertise; `factory(conn_id)` builds one backend per coordinator
/// connection on that connection's own thread.
pub fn spawn_with<B, F>(
    listener: TcpListener,
    opts: WorkerOptions,
    catalog: Vec<OperatingPoint>,
    factory: F,
) -> Result<WorkerHandle>
where
    B: Backend + 'static,
    F: Fn(usize) -> Result<B> + Send + Sync + 'static,
{
    let addr = listener.local_addr().context("worker listener address")?;
    listener
        .set_nonblocking(true)
        .context("worker listener nonblocking")?;
    let shared = Arc::new(WorkerShared {
        name: opts.name,
        mode: opts.mode,
        hb_interval: opts.hb_interval,
        hb_timeout: opts.hb_timeout,
        current_op: AtomicUsize::new(0),
        class_ops: Mutex::new(Vec::new()),
        served: AtomicU64::new(0),
        stop: AtomicBool::new(false),
        gate: Gate::default(),
        conns: Mutex::new(Vec::new()),
    });
    let shared2 = shared.clone();
    let catalog = Arc::new(catalog);
    let factory = Arc::new(factory);
    let accept = std::thread::spawn(move || {
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut next_conn = 0usize;
        while !shared2.stop.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_nonblocking(false);
                    let conn_id = next_conn;
                    next_conn += 1;
                    if let Ok(clone) = stream.try_clone() {
                        shared2.conns.lock().unwrap().push((conn_id, clone));
                    }
                    let shared3 = shared2.clone();
                    let catalog3 = catalog.clone();
                    let factory3 = factory.clone();
                    handlers.retain(|h| !h.is_finished());
                    handlers.push(std::thread::spawn(move || {
                        handle_conn(stream, conn_id, &shared3, &catalog3, factory3.as_ref());
                        shared3.forget_conn(conn_id);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
        // stop requested: unblock handlers stuck in reads, then join
        shared2.close_all();
        for h in handlers {
            let _ = h.join();
        }
    });
    Ok(WorkerHandle {
        addr,
        shared,
        accept: Some(accept),
    })
}

/// Blocking daemon entry for the CLI: spawn + wait until a `Shutdown`
/// frame (or `kill`) winds the daemon down.  Legacy heartbeat cadence;
/// see [`run_with`].
pub fn run<B, F>(
    listener: TcpListener,
    name: impl Into<String>,
    mode: impl Into<String>,
    catalog: Vec<OperatingPoint>,
    factory: F,
) -> Result<()>
where
    B: Backend + 'static,
    F: Fn(usize) -> Result<B> + Send + Sync + 'static,
{
    run_with(listener, WorkerOptions::new(name, mode), catalog, factory)
}

/// Blocking daemon entry with the full option set ([`WorkerOptions`]).
pub fn run_with<B, F>(
    listener: TcpListener,
    opts: WorkerOptions,
    catalog: Vec<OperatingPoint>,
    factory: F,
) -> Result<()>
where
    B: Backend + 'static,
    F: Fn(usize) -> Result<B> + Send + Sync + 'static,
{
    spawn_with(listener, opts, catalog, factory)?.join();
    Ok(())
}

/// Resolve a `Prepare` ladder against the catalog: every rung by name,
/// with the coordinator's expected relative power cross-checked so
/// mismatched plans fail loudly at prepare time, not as silently wrong
/// logits.
fn resolve_ladder(
    catalog: &[OperatingPoint],
    ladder: &[LadderRung],
) -> std::result::Result<Vec<OperatingPoint>, String> {
    if ladder.is_empty() {
        return Err("prepare: empty ladder".to_string());
    }
    let mut out = Vec::with_capacity(ladder.len());
    for rung in ladder {
        let Some(op) = catalog.iter().find(|o| o.name == rung.name) else {
            let names: Vec<&str> = catalog.iter().map(|o| o.name.as_str()).collect();
            return Err(format!(
                "prepare: OP {:?} not in this worker's catalog [{}]",
                rung.name,
                names.join(", ")
            ));
        };
        if (op.relative_power - rung.power).abs() > 1e-6 {
            return Err(format!(
                "prepare: OP {:?} power mismatch (worker plan {:.6}, coordinator {:.6}) — stale assignment.json?",
                rung.name, op.relative_power, rung.power
            ));
        }
        out.push(op.clone());
    }
    Ok(out)
}

/// Work the reader half queues to the compute half of one connection.
/// FIFO order is load-bearing: a drain barrier queued after N Forwards
/// executes after all N have entered the gate, which is what lets the
/// coordinator pipeline Forwards and still trust the barrier.
enum Work {
    Forward {
        id: Option<u64>,
        op: Option<usize>,
        batch: usize,
        class: Option<usize>,
        payload: Vec<f32>,
    },
    Prepare {
        ladder: Vec<LadderRung>,
    },
    SetOp {
        op: usize,
        drain: bool,
        class: Option<usize>,
    },
    Drain,
}

/// Reader half of one connection: answers latency-critical control
/// frames inline (through the shared writer) and queues everything else
/// to the compute half.  Exits on stream close/error or `Shutdown`;
/// dropping `tx` on exit is what winds the compute half down.
fn reader_loop(
    mut stream: TcpStream,
    tx: std::sync::mpsc::Sender<Work>,
    writer: &Mutex<TcpStream>,
    shared: &WorkerShared,
    catalog: &[OperatingPoint],
    backend_name: &str,
    classes: usize,
) {
    loop {
        let (frame, payload) = match wire::read_frame(&mut stream) {
            Ok(x) => x,
            Err(_) => break, // connection closed / daemon stopping
        };
        let inline: Option<Frame> = match frame {
            Frame::Hello { version } => Some(if version == PROTOCOL_VERSION {
                Frame::HelloAck {
                    worker: shared.name.clone(),
                    backend: backend_name.to_string(),
                    mode: shared.mode.clone(),
                    classes,
                    catalog: catalog.iter().map(|o| o.name.clone()).collect(),
                    hb_interval_ms: shared.hb_interval.as_millis() as u64,
                    hb_timeout_ms: shared.hb_timeout.as_millis() as u64,
                    max_inflight: WORKER_MAX_INFLIGHT,
                }
            } else {
                Frame::err(format!(
                    "protocol version mismatch: worker {PROTOCOL_VERSION}, coordinator {version}"
                ))
            }),
            Frame::Forward { id, op, batch, class } => {
                if tx.send(Work::Forward { id, op, batch, class, payload }).is_err() {
                    break;
                }
                None
            }
            Frame::Prepare { ladder } => {
                if tx.send(Work::Prepare { ladder }).is_err() {
                    break;
                }
                None
            }
            Frame::SetOp { op, drain, class } => {
                if tx.send(Work::SetOp { op, drain, class }).is_err() {
                    break;
                }
                None
            }
            Frame::Drain => {
                if tx.send(Work::Drain).is_err() {
                    break;
                }
                None
            }
            Frame::Heartbeat => Some(Frame::Pong {
                current_op: shared.current_op.load(Ordering::Acquire),
                served: shared.served.load(Ordering::Acquire),
            }),
            Frame::Shutdown => {
                let mut w = writer.lock().unwrap();
                let _ = wire::write_frame(&mut *w, &Frame::Ok, &[]);
                drop(w);
                shared.close_all();
                break;
            }
            other => Some(Frame::err(format!(
                "unexpected {} frame from coordinator",
                other.type_name()
            ))),
        };
        if let Some(reply) = inline {
            let mut w = writer.lock().unwrap();
            if wire::write_frame(&mut *w, &reply, &[]).is_err() {
                break;
            }
        }
    }
}

/// Compute half of one connection: owns the (non-`Send`) backend on the
/// handler thread, drains the FIFO work queue, and answers through the
/// shared writer.  A write failure shuts the socket down to unblock the
/// reader half, then exits.
fn compute_loop<B: Backend>(
    rx: std::sync::mpsc::Receiver<Work>,
    backend: &mut B,
    shared: &WorkerShared,
    catalog: &[OperatingPoint],
    writer: &Mutex<TcpStream>,
) {
    let mut prepared = 0usize;
    while let Ok(work) = rx.recv() {
        let (reply, out): (Frame, Vec<f32>) = match work {
            Work::Prepare { ladder } => match resolve_ladder(catalog, &ladder) {
                Ok(ops) => match backend.prepare(&ops) {
                    Ok(()) => {
                        prepared = ops.len();
                        (Frame::Ok, Vec::new())
                    }
                    Err(e) => (Frame::err(format!("{e:#}")), Vec::new()),
                },
                Err(message) => (Frame::err(message), Vec::new()),
            },
            Work::Forward { id, op, batch, class, payload } => {
                let op_idx = op.unwrap_or_else(|| shared.op_for(class));
                if prepared == 0 {
                    (Frame::Err { id, message: "forward before prepare".to_string() }, Vec::new())
                } else if batch == 0 || payload.is_empty() || payload.len() % batch != 0 {
                    let message = format!("bad forward: {} elems for batch {batch}", payload.len());
                    (Frame::Err { id, message }, Vec::new())
                } else {
                    let section = shared.gate.enter(class.unwrap_or(0));
                    let r = backend.forward(op_idx, &payload, batch);
                    drop(section);
                    match r {
                        Ok(logits) => {
                            shared.served.fetch_add(batch as u64, Ordering::AcqRel);
                            (Frame::Logits { id, classes: backend.num_classes() }, logits)
                        }
                        Err(e) => (Frame::Err { id, message: format!("{e:#}") }, Vec::new()),
                    }
                }
            }
            Work::SetOp { op, drain, class } => {
                if drain {
                    // the barrier inherits the frame's scope: classed
                    // switches drain only their class's forwards
                    shared.gate.drain(class, || shared.store_op(class, op));
                    (Frame::Ok, Vec::new())
                } else {
                    shared.store_op(class, op);
                    continue; // fire-and-forget
                }
            }
            Work::Drain => {
                shared.gate.drain(None, || ());
                (Frame::Ok, Vec::new())
            }
        };
        let mut w = writer.lock().unwrap();
        if wire::write_frame(&mut *w, &reply, &out).is_err() {
            let _ = w.shutdown(std::net::Shutdown::Both);
            break;
        }
    }
}

/// One coordinator connection: reader half on a scoped thread, compute
/// half (owning the backend, which need not be `Send`) on this thread.
fn handle_conn<B, F>(
    mut stream: TcpStream,
    conn_id: usize,
    shared: &WorkerShared,
    catalog: &[OperatingPoint],
    factory: &F,
) where
    B: Backend + 'static,
    F: Fn(usize) -> Result<B> + Send + Sync + 'static,
{
    let mut backend = match factory(conn_id) {
        Ok(b) => b,
        Err(e) => {
            // answer whatever arrives first with the init failure
            if let Ok((_frame, _)) = wire::read_frame(&mut stream) {
                let msg = format!("worker {}: backend init failed: {e:#}", shared.name);
                let _ = wire::write_frame(&mut stream, &Frame::err(msg), &[]);
            }
            return;
        }
    };
    let backend_name = backend.name().to_string();
    let classes = backend.num_classes();
    let writer = match stream.try_clone() {
        Ok(w) => Mutex::new(w),
        Err(_) => return,
    };
    let (tx, rx) = std::sync::mpsc::channel::<Work>();
    std::thread::scope(|scope| {
        let writer_ref = &writer;
        let name_ref = backend_name.as_str();
        scope.spawn(move || {
            reader_loop(stream, tx, writer_ref, shared, catalog, name_ref, classes);
        });
        compute_loop(rx, &mut backend, shared, catalog, &writer);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn gate_blocks_drain_until_inflight_work_exits() {
        let gate = Arc::new(Gate::default());
        let progress = Arc::new(AtomicU32::new(0));
        let section = gate.enter(0);
        let g2 = gate.clone();
        let p2 = progress.clone();
        let drainer = std::thread::spawn(move || {
            g2.drain(None, || p2.store(1, Ordering::Release));
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(progress.load(Ordering::Acquire), 0, "drain ran with work in flight");
        drop(section);
        drainer.join().unwrap();
        assert_eq!(progress.load(Ordering::Acquire), 1);
    }

    #[test]
    fn gate_defers_new_entries_while_draining() {
        let gate = Arc::new(Gate::default());
        let section = gate.enter(0);
        let g2 = gate.clone();
        let drainer = std::thread::spawn(move || g2.drain(None, || ()));
        let g3 = gate.clone();
        let entered = Arc::new(AtomicU32::new(0));
        let e3 = entered.clone();
        std::thread::sleep(Duration::from_millis(10));
        let late = std::thread::spawn(move || {
            let s = g3.enter(0);
            e3.store(1, Ordering::Release);
            drop(s);
        });
        // the late entry must wait behind the pending drain
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(entered.load(Ordering::Acquire), 0, "entry slipped past a pending drain");
        drop(section);
        drainer.join().unwrap();
        late.join().unwrap();
        assert_eq!(entered.load(Ordering::Acquire), 1);
    }

    #[test]
    fn gate_survives_a_panicking_forward() {
        // a forward that panics must still release its read section
        // (RAII), or every future drain barrier wedges process-wide
        let gate = Arc::new(Gate::default());
        let g2 = gate.clone();
        let panicker = std::thread::spawn(move || {
            let _section = g2.enter(0);
            panic!("backend blew up mid-forward");
        });
        assert!(panicker.join().is_err());
        // the barrier must complete promptly despite the panic
        gate.drain(None, || ());
    }

    #[test]
    fn class_scoped_drain_ignores_other_classes_inflight_work() {
        let gate = Arc::new(Gate::default());
        // best-effort (class 1) work is in flight...
        let be_section = gate.enter(1);
        // ...yet a premium (class 0) barrier completes immediately: a
        // premium switch never stalls behind a best-effort backlog
        gate.drain(Some(0), || ());
        // a best-effort barrier still waits for its own class
        let g2 = gate.clone();
        let done = Arc::new(AtomicU32::new(0));
        let d2 = done.clone();
        let drainer = std::thread::spawn(move || {
            g2.drain(Some(1), || d2.store(1, Ordering::Release));
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(done.load(Ordering::Acquire), 0, "barrier skipped in-flight work");
        // and premium entries are not blocked by the pending
        // best-effort barrier
        drop(gate.enter(0));
        drop(be_section);
        drainer.join().unwrap();
        assert_eq!(done.load(Ordering::Acquire), 1);
    }

    #[test]
    fn resolve_ladder_checks_names_and_powers() {
        let cat = vec![
            crate::backend::stub::stub_op("op0", 0.8),
            crate::backend::stub::stub_op("op1", 0.5),
        ];
        let ok = resolve_ladder(
            &cat,
            &[
                LadderRung { name: "op1".into(), power: 0.5 },
                LadderRung { name: "op0".into(), power: 0.8 },
            ],
        )
        .unwrap();
        assert_eq!(ok.len(), 2);
        assert_eq!(ok[0].name, "op1"); // coordinator order, not catalog order
        let missing = resolve_ladder(&cat, &[LadderRung { name: "nope".into(), power: 0.5 }]);
        assert!(missing.unwrap_err().contains("not in this worker's catalog"));
        let drift = resolve_ladder(&cat, &[LadderRung { name: "op0".into(), power: 0.9 }]);
        assert!(drift.unwrap_err().contains("power mismatch"));
        assert!(resolve_ladder(&cat, &[]).unwrap_err().contains("empty ladder"));
    }
}
