//! Fleet serving: a coordinator/worker RPC subsystem behind the
//! unified [`crate::backend::Backend`] trait.
//!
//! The paper's runtime story — operating points switched cheaply as
//! conditions change — scales past one process here: many edge workers,
//! each wrapping any local backend (native LUT engine or PJRT), are
//! driven by a coordinator that scatters batches across them over
//! pipelined, multiplexed connections (several id-tagged Forwards in
//! flight per worker, chunk sizes skewed toward fast workers by an
//! observed-latency EWMA), gathers logits in completion order and
//! reassembles them in submission order, fails over when a worker dies
//! mid-stream, and broadcasts OP switches fleet-wide with the same
//! `SwitchMode` semantics the in-process server uses (`Drain` =
//! per-worker barrier acked before the switch is reported complete;
//! `Immediate` = fire-and-forget).  Membership is dynamic: failing
//! workers move `Live → Suspect → Evicted`, a re-probe brings
//! recovered ones back (`Evicted → Rejoining → Live`), and a registry
//! join path (`worker --join`) grows the fleet under load.
//!
//!   * [`wire`]        the std-only TCP frame protocol (JSON header +
//!     raw f32 payload, the QTEN idiom)
//!   * [`worker`]      the worker daemon (`qos-nets worker`): wraps any
//!     `Backend` behind the protocol, with a process-wide drain gate
//!     and a reader/compute split per connection for pipelining
//!   * [`coordinator`] [`FleetBackend`]: the fleet *as* a `Backend` —
//!     it slots into `server::Server`, `backend::evaluate` and the CLI
//!     exactly like the native engine does — plus the membership state
//!     machine in [`FleetStats`]
//!   * [`registry`]    [`FleetRegistry`]: the coordinator-side listener
//!     behind `worker --join`, feeding `FleetBackend::admit`
//!
//! The loopback integration tests (`rust/tests/fleet.rs`) pin the
//! contract: a fleet of in-process workers is bit-identical to a single
//! `NativeBackend` over the same request stream, including across a
//! worker being killed mid-stream and rejoining later (driven by the
//! deterministic fault-injection proxy in `rust/tests/common/chaos.rs`).

pub mod coordinator;
pub mod registry;
pub mod wire;
pub mod worker;

pub use coordinator::{FleetBackend, FleetStats, MemberState, WorkerStats, CHUNK_QUANTUM_US};
pub use registry::{register_with, FleetRegistry};
pub use wire::{Frame, LadderRung, DEFAULT_HB_INTERVAL_MS, DEFAULT_HB_TIMEOUT_MS, PROTOCOL_VERSION};
pub use worker::{WorkerHandle, WorkerOptions, WORKER_MAX_INFLIGHT};
