//! Coordinator side of the fleet: [`FleetBackend`] implements the
//! unified [`Backend`] trait over a set of remote workers.
//!
//! * **Scatter/gather.**  `forward` splits a batch into contiguous
//!   chunks, one per live worker, runs them in parallel (scoped
//!   threads, one per peer connection) and reassembles the logits in
//!   submission order — so the fleet is bit-identical to a single
//!   backend serving the same stream, regardless of how the batch was
//!   split.
//! * **Failure semantics.**  A chunk whose worker dies mid-call evicts
//!   that worker and is *requeued* onto the survivors (round-robin,
//!   bounded by [`FleetBackend::with_max_retries`]); the forward only
//!   fails once a chunk exhausts its retries or no workers remain.  No
//!   request is ever silently dropped.
//! * **Fleet-wide switching.**  [`FleetBackend::set_operating_point`]
//!   broadcasts `SetOp` with the PR-2 [`SwitchMode`] semantics: `Drain`
//!   writes the barrier frame to every live worker first (so they all
//!   drain concurrently), then collects one ack per surviving worker
//!   before returning; `Immediate` is fire-and-forget.
//! * **Attribution.**  Every instance records per-worker request/batch
//!   counts, cumulative latency and eviction state into a shared
//!   [`FleetStats`]; `serve --fleet` hands one handle to every server
//!   worker's backend and prints the per-worker table at the end (the
//!   heterogeneous-pool attribution follow-on from the elastic-server
//!   PR).

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::Backend;
use crate::engine::OperatingPoint;
use crate::fleet::wire::{self, Frame, LadderRung, PROTOCOL_VERSION};
use crate::qos::SwitchMode;

/// Default socket read/write timeout for data-plane calls; a hung
/// worker is indistinguishable from a dead one past this.
const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-worker serving statistics (see [`FleetStats`]).
#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    /// Images this worker served.
    pub requests: u64,
    /// Forward calls (chunks) this worker served.
    pub batches: u64,
    /// I/O or protocol failures observed talking to this worker.
    pub errors: u64,
    /// Cumulative wall time of successful forward calls, microseconds.
    pub latency_us_sum: u64,
    /// Whether some coordinator connection evicted this worker.
    pub evicted: bool,
}

impl WorkerStats {
    /// Mean per-chunk forward latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.latency_us_sum as f64 / self.batches as f64
        }
    }
}

#[derive(Default)]
struct FleetStatsInner {
    workers: BTreeMap<String, WorkerStats>,
    requeues: u64,
    evictions: u64,
}

/// Shared per-worker attribution registry, keyed by worker address.
/// Cheap to clone; every [`FleetBackend`] built from the same handle
/// (e.g. one per server worker thread) folds into the same table.
#[derive(Clone, Default)]
pub struct FleetStats {
    inner: Arc<Mutex<FleetStatsInner>>,
}

impl FleetStats {
    fn with_worker(&self, addr: &str, f: impl FnOnce(&mut WorkerStats)) {
        let mut inner = self.inner.lock().unwrap();
        f(inner.workers.entry(addr.to_string()).or_default());
    }

    fn record_requeue(&self) {
        self.inner.lock().unwrap().requeues += 1;
    }

    /// Mark one worker evicted.  The counter is per *worker*, not per
    /// coordinator connection: several backends sharing this registry
    /// (one per server worker thread + the control plane) all losing
    /// the same dead worker still count one eviction.
    fn record_eviction(&self, addr: &str) {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let w = inner.workers.entry(addr.to_string()).or_default();
        if !w.evicted {
            w.evicted = true;
            inner.evictions += 1;
        }
    }

    /// Snapshot: per-worker stats (sorted by address), total requeued
    /// chunks, total evictions.
    pub fn snapshot(&self) -> (Vec<(String, WorkerStats)>, u64, u64) {
        let inner = self.inner.lock().unwrap();
        (
            inner.workers.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
            inner.requeues,
            inner.evictions,
        )
    }
}

/// One remote worker as this coordinator sees it.
struct Peer {
    addr: String,
    /// Overlay mode the worker advertised in `HelloAck` (empty = not
    /// applicable, e.g. in-process test workers).
    mode: String,
    /// Heartbeat cadence the worker advertised in `HelloAck`.
    hb_interval_ms: u64,
    hb_timeout_ms: u64,
    /// `None` once evicted.
    stream: Option<TcpStream>,
}

/// One scatter/gather work item: images `[start..start + len)` of the
/// current forward call, with its requeue budget consumed so far.
#[derive(Clone, Copy)]
struct Chunk {
    start: usize,
    len: usize,
    attempts: usize,
}

/// What one chunk call produced.
enum ChunkOutcome {
    Logits(Vec<f32>),
    /// Worker-side application error (bad OP index, backend failure):
    /// deterministic, so retrying elsewhere would fail too — fatal.
    App(String),
    /// Transport failure: the worker is gone; requeue the chunk.
    Io,
}

/// Drop a peer's connection and account the failure — the single place
/// eviction bookkeeping lives (the `evictions` counter stays per
/// worker, deduplicated inside [`FleetStats`]).
fn evict(peer: &mut Peer, stats: &FleetStats) {
    peer.stream = None;
    stats.with_worker(&peer.addr, |w| w.errors += 1);
    stats.record_eviction(&peer.addr);
}

/// Strict request/response exchange with one peer; evicts on transport
/// failure (the stream is poisoned mid-frame, so it cannot be reused).
fn call(
    peer: &mut Peer,
    stats: &FleetStats,
    frame: &Frame,
    payload: &[f32],
) -> Result<(Frame, Vec<f32>)> {
    let Some(stream) = peer.stream.as_mut() else {
        bail!("worker {} already evicted", peer.addr);
    };
    let r = wire::write_frame(stream, frame, payload).and_then(|()| wire::read_frame(stream));
    match r {
        Ok(reply) => Ok(reply),
        Err(e) => {
            evict(peer, stats);
            Err(e.context(format!("worker {}", peer.addr)))
        }
    }
}

/// A remote-fleet [`Backend`]: scatter/gather over TCP workers with
/// failover, plus the fleet-wide control plane (switch broadcast,
/// heartbeats, shutdown).  See the module docs.
pub struct FleetBackend {
    peers: Vec<Peer>,
    classes: usize,
    stats: FleetStats,
    /// Requeue budget per chunk after its first failed attempt.
    max_retries: usize,
    io_timeout: Duration,
}

impl FleetBackend {
    /// Connect to every worker and run the `Hello` handshake.  All
    /// workers must agree on the classifier width; any unreachable
    /// address fails the whole connect (a misspelled fleet member
    /// should not silently shrink the fleet at startup).
    pub fn connect(addrs: &[String]) -> Result<FleetBackend> {
        Self::connect_with(addrs, FleetStats::default())
    }

    /// [`connect`](Self::connect) into a shared [`FleetStats`] registry
    /// (one per serving process, many backends).
    pub fn connect_with(addrs: &[String], stats: FleetStats) -> Result<FleetBackend> {
        anyhow::ensure!(!addrs.is_empty(), "fleet: no worker addresses given");
        let mut peers = Vec::with_capacity(addrs.len());
        let mut classes: Option<usize> = None;
        for addr in addrs {
            let mut stream = TcpStream::connect(addr.as_str())
                .with_context(|| format!("connect to fleet worker {addr}"))?;
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(Some(DEFAULT_IO_TIMEOUT)).ok();
            stream.set_write_timeout(Some(DEFAULT_IO_TIMEOUT)).ok();
            wire::write_frame(&mut stream, &Frame::Hello { version: PROTOCOL_VERSION }, &[])
                .with_context(|| format!("hello to fleet worker {addr}"))?;
            let (reply, _) = wire::read_frame(&mut stream)
                .with_context(|| format!("hello ack from fleet worker {addr}"))?;
            let (c, mode, hb_interval_ms, hb_timeout_ms) = match reply {
                Frame::HelloAck { classes, mode, hb_interval_ms, hb_timeout_ms, .. } => {
                    (classes, mode, hb_interval_ms, hb_timeout_ms)
                }
                Frame::Err { message } => bail!("fleet worker {addr} refused hello: {message}"),
                other => bail!("fleet worker {addr}: unexpected {} to hello", other.type_name()),
            };
            match classes {
                None => classes = Some(c),
                Some(prev) if prev != c => bail!(
                    "fleet workers disagree on classifier width ({prev} vs {c} at {addr}) — mixed experiments?"
                ),
                Some(_) => {}
            }
            stats.with_worker(addr, |_| {}); // register for attribution
            peers.push(Peer {
                addr: addr.clone(),
                mode,
                hb_interval_ms,
                hb_timeout_ms,
                stream: Some(stream),
            });
        }
        Ok(FleetBackend {
            peers,
            classes: classes.expect("at least one worker"),
            stats,
            max_retries: 2,
            io_timeout: DEFAULT_IO_TIMEOUT,
        })
    }

    /// Override the per-chunk requeue budget (default 2).
    pub fn with_max_retries(mut self, retries: usize) -> Self {
        self.max_retries = retries;
        self
    }

    /// Workers still connected.
    pub fn live_workers(&self) -> usize {
        self.peers.iter().filter(|p| p.stream.is_some()).count()
    }

    /// The shared attribution registry this backend records into.
    pub fn stats(&self) -> &FleetStats {
        &self.stats
    }

    /// Heartbeat probe interval hint: the tightest cadence any peer
    /// advertised in its handshake — one short-leashed worker speeds up
    /// eviction for the whole fleet.  Falls back to the wire-level
    /// default for an (impossible) empty peer set.
    pub fn hb_interval(&self) -> Duration {
        let ms = self
            .peers
            .iter()
            .map(|p| p.hb_interval_ms.max(1))
            .min()
            .unwrap_or(wire::DEFAULT_HB_INTERVAL_MS);
        Duration::from_millis(ms)
    }

    /// Per-probe timeout hint, minimum over the fleet (companion to
    /// [`hb_interval`](Self::hb_interval)).
    pub fn hb_timeout(&self) -> Duration {
        let ms = self
            .peers
            .iter()
            .map(|p| p.hb_timeout_ms.max(1))
            .min()
            .unwrap_or(wire::DEFAULT_HB_TIMEOUT_MS);
        Duration::from_millis(ms)
    }

    /// Cross-check the coordinator's retraining-overlay mode against
    /// what every worker advertised in its handshake.  `Prepare` alone
    /// cannot catch this: relative powers are mode-independent (the
    /// overlays only swap tensors), so a `--mode` mismatch would
    /// silently serve different logits.  Workers advertising an empty
    /// mode (in-process test workers) are skipped.
    pub fn check_mode(&self, expected: &str) -> Result<()> {
        for peer in &self.peers {
            if !peer.mode.is_empty() && peer.mode != expected {
                bail!(
                    "fleet worker {} serves mode {:?} but this coordinator runs --mode {:?}; \
                     restart the worker with the matching --mode",
                    peer.addr,
                    peer.mode,
                    expected
                );
            }
        }
        Ok(())
    }

    /// Broadcast an operating-point switch fleet-wide.
    ///
    /// `Drain` first writes the barrier frame to every live worker (so
    /// the whole fleet drains concurrently), then reads one ack per
    /// worker; workers that fail either phase are evicted.  Returns the
    /// number of surviving workers that acked — the coordinator only
    /// reports the switch complete once every survivor has.
    /// `Immediate` is a fire-and-forget store on every worker.
    pub fn set_operating_point(&mut self, op: usize, mode: SwitchMode) -> Result<usize> {
        let drain = mode == SwitchMode::Drain;
        let frame = Frame::SetOp { op, drain };
        let stats = self.stats.clone();
        let mut sent = Vec::new();
        for (i, peer) in self.peers.iter_mut().enumerate() {
            let Some(stream) = peer.stream.as_mut() else { continue };
            match wire::write_frame(stream, &frame, &[]) {
                Ok(()) => sent.push(i),
                Err(_) => evict(peer, &stats),
            }
        }
        if sent.is_empty() {
            bail!("fleet: no live workers to switch");
        }
        if !drain {
            return Ok(sent.len());
        }
        // collect one ack per worker *before* reporting any failure —
        // bailing mid-loop would leave the remaining workers' buffered
        // acks unread and desynchronize their request/response streams
        let mut acks = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        for i in sent {
            let peer = &mut self.peers[i];
            let Some(stream) = peer.stream.as_mut() else { continue };
            match wire::read_frame(stream) {
                Ok((Frame::Ok, _)) => acks += 1,
                Ok((other, _)) => {
                    // a worker that rejects (or mangles) the switch is
                    // evicted: leaving it serving a different OP than
                    // the rest of the fleet would be silently wrong
                    let msg = match other {
                        Frame::Err { message } => message,
                        other => format!("unexpected {} to drain switch", other.type_name()),
                    };
                    evict(peer, &stats);
                    if first_err.is_none() {
                        first_err =
                            Some(anyhow!("fleet worker {}: {msg}", peer.addr));
                    }
                }
                Err(_) => evict(peer, &stats),
            }
        }
        if let Some(e) = first_err {
            return Err(e.context("fleet drain switch rejected"));
        }
        if acks == 0 {
            bail!("fleet: every worker died during the drain switch");
        }
        Ok(acks)
    }

    /// Probe every live worker with a `Heartbeat` under `timeout`;
    /// workers that fail to `Pong` in time are evicted.  Returns the
    /// live count afterwards.
    pub fn heartbeat(&mut self, timeout: Duration) -> usize {
        let stats = self.stats.clone();
        for peer in &mut self.peers {
            let Some(stream) = peer.stream.as_mut() else { continue };
            stream.set_read_timeout(Some(timeout)).ok();
            let ok = wire::write_frame(stream, &Frame::Heartbeat, &[]).is_ok()
                && matches!(wire::read_frame(stream), Ok((Frame::Pong { .. }, _)));
            if ok {
                stream.set_read_timeout(Some(self.io_timeout)).ok();
            } else {
                evict(peer, &stats);
            }
        }
        self.live_workers()
    }

    /// Fleet-wide barrier without a switch: every surviving worker acks
    /// once it has no forward in flight.  Returns the ack count.
    pub fn drain_fleet(&mut self) -> Result<usize> {
        let stats = self.stats.clone();
        let mut acks = 0usize;
        for peer in &mut self.peers {
            if peer.stream.is_none() {
                continue;
            }
            match call(peer, &stats, &Frame::Drain, &[]) {
                Ok((Frame::Ok, _)) => acks += 1,
                Ok((Frame::Err { message }, _)) => {
                    bail!("fleet worker {} failed to drain: {message}", peer.addr)
                }
                Ok(_) | Err(_) => {} // evicted by `call`
            }
        }
        Ok(acks)
    }

    /// Ask every live worker daemon to wind down; returns how many
    /// acked.  Used by operators tearing a fleet down from the
    /// coordinator side.
    pub fn shutdown_fleet(&mut self) -> usize {
        let stats = self.stats.clone();
        let mut acks = 0usize;
        for peer in &mut self.peers {
            if peer.stream.is_none() {
                continue;
            }
            if let Ok((Frame::Ok, _)) = call(peer, &stats, &Frame::Shutdown, &[]) {
                acks += 1;
            }
            peer.stream = None;
        }
        acks
    }

    /// Split `batch` into one contiguous chunk per live worker (the
    /// first `batch % live` chunks get the extra image).
    fn split(batch: usize, live: usize) -> Vec<Chunk> {
        let base = batch / live;
        let extra = batch % live;
        let mut chunks = Vec::new();
        let mut start = 0;
        for i in 0..live {
            let len = base + usize::from(i < extra);
            if len > 0 {
                chunks.push(Chunk { start, len, attempts: 0 });
            }
            start += len;
        }
        chunks
    }

    /// Run one round of chunk calls, one scoped thread per live peer
    /// (each peer serves its assigned chunks sequentially on its own
    /// connection).  Returns every chunk with its outcome.
    fn scatter_round(
        peers: &mut [Peer],
        stats: &FleetStats,
        assignments: Vec<Vec<Chunk>>,
        op_idx: usize,
        images: &[f32],
        elems: usize,
    ) -> Vec<(Chunk, ChunkOutcome)> {
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (peer, chunks) in peers.iter_mut().zip(assignments) {
                if chunks.is_empty() {
                    continue;
                }
                let stats = stats.clone();
                handles.push(s.spawn(move || {
                    let mut out = Vec::with_capacity(chunks.len());
                    for chunk in chunks {
                        let data = &images[chunk.start * elems..(chunk.start + chunk.len) * elems];
                        let frame = Frame::Forward { op: Some(op_idx), batch: chunk.len };
                        let t0 = Instant::now();
                        let outcome = match call(peer, &stats, &frame, data) {
                            Ok((Frame::Logits { .. }, logits)) => {
                                stats.with_worker(&peer.addr, |w| {
                                    w.requests += chunk.len as u64;
                                    w.batches += 1;
                                    w.latency_us_sum += t0.elapsed().as_micros() as u64;
                                });
                                ChunkOutcome::Logits(logits)
                            }
                            Ok((Frame::Err { message }, _)) => ChunkOutcome::App(message),
                            Ok((other, _)) => {
                                // protocol confusion: poison the stream
                                evict(peer, &stats);
                                ChunkOutcome::App(format!(
                                    "worker {}: unexpected {} to forward",
                                    peer.addr,
                                    other.type_name()
                                ))
                            }
                            Err(_) => ChunkOutcome::Io,
                        };
                        out.push((chunk, outcome));
                    }
                    out
                }));
            }
            handles.into_iter().flat_map(|h| h.join().expect("fleet chunk thread")).collect()
        })
    }
}

impl Backend for FleetBackend {
    /// Broadcast the ladder to every worker (names + expected powers;
    /// each worker resolves the OPs from its local catalog and makes
    /// them resident).  A worker that *rejects* the ladder fails
    /// prepare — a fleet serving mismatched plans is a configuration
    /// error, not a failover case; workers that die are evicted.
    fn prepare(&mut self, ops: &[OperatingPoint]) -> Result<()> {
        anyhow::ensure!(!ops.is_empty(), "fleet prepare: empty ladder");
        let ladder: Vec<LadderRung> = ops
            .iter()
            .map(|o| LadderRung { name: o.name.clone(), power: o.relative_power })
            .collect();
        let frame = Frame::Prepare { ladder };
        let stats = self.stats.clone();
        let mut prepared = 0usize;
        for peer in &mut self.peers {
            if peer.stream.is_none() {
                continue;
            }
            match call(peer, &stats, &frame, &[]) {
                Ok((Frame::Ok, _)) => prepared += 1,
                Ok((Frame::Err { message }, _)) => {
                    bail!("fleet worker {} rejected prepare: {message}", peer.addr)
                }
                Ok((other, _)) => bail!(
                    "fleet worker {}: unexpected {} to prepare",
                    peer.addr,
                    other.type_name()
                ),
                Err(_) => {} // evicted by `call`
            }
        }
        anyhow::ensure!(prepared > 0, "fleet prepare: no live workers");
        Ok(())
    }

    /// Scatter the batch across live workers, gather logits in order,
    /// rebalancing chunks from dead workers onto survivors (bounded
    /// retries per chunk).
    fn forward(&mut self, op_idx: usize, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            batch > 0 && !images.is_empty() && images.len() % batch == 0,
            "bad fleet input: {} elems for batch {batch}",
            images.len()
        );
        let elems = images.len() / batch;
        let live = self.live_workers();
        anyhow::ensure!(live > 0, "fleet forward: no live workers");
        let mut pending = Self::split(batch, live);
        let mut gathered: Vec<(usize, Vec<f32>)> = Vec::new();
        while !pending.is_empty() {
            // assign pending chunks round-robin over the live peers
            let mut assignments: Vec<Vec<Chunk>> = vec![Vec::new(); self.peers.len()];
            {
                let live_idx: Vec<usize> = self
                    .peers
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| p.stream.is_some())
                    .map(|(i, _)| i)
                    .collect();
                if live_idx.is_empty() {
                    bail!(
                        "fleet forward: all workers lost with {} images still queued",
                        pending.iter().map(|c| c.len).sum::<usize>()
                    );
                }
                for (i, chunk) in pending.drain(..).enumerate() {
                    assignments[live_idx[i % live_idx.len()]].push(chunk);
                }
            }
            let outcomes = Self::scatter_round(
                &mut self.peers,
                &self.stats,
                assignments,
                op_idx,
                images,
                elems,
            );
            for (chunk, outcome) in outcomes {
                match outcome {
                    ChunkOutcome::Logits(logits) => {
                        anyhow::ensure!(
                            logits.len() == chunk.len * self.classes,
                            "fleet worker returned {} logits for {} images",
                            logits.len(),
                            chunk.len
                        );
                        gathered.push((chunk.start, logits));
                    }
                    ChunkOutcome::App(message) => bail!("fleet forward failed: {message}"),
                    ChunkOutcome::Io => {
                        let attempts = chunk.attempts + 1;
                        if attempts > self.max_retries {
                            bail!(
                                "fleet forward: chunk of {} images failed {} times (retry budget {})",
                                chunk.len,
                                attempts,
                                self.max_retries
                            );
                        }
                        self.stats.record_requeue();
                        pending.push(Chunk { attempts, ..chunk });
                    }
                }
            }
        }
        gathered.sort_by_key(|(start, _)| *start);
        let mut out = Vec::with_capacity(batch * self.classes);
        for (_, logits) in gathered {
            out.extend_from_slice(&logits);
        }
        Ok(out)
    }

    fn name(&self) -> &str {
        "fleet"
    }

    fn num_classes(&self) -> usize {
        self.classes
    }
}

impl Drop for FleetBackend {
    fn drop(&mut self) {
        // orderly close: workers see EOF, not RST, on coordinator exit
        for peer in &mut self.peers {
            if let Some(s) = peer.stream.take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_the_batch_in_order_without_empty_chunks() {
        for (batch, live) in [(8usize, 3usize), (2, 4), (1, 1), (7, 7), (16, 2)] {
            let chunks = FleetBackend::split(batch, live);
            assert!(chunks.len() <= live);
            let mut expect_start = 0;
            for c in &chunks {
                assert!(c.len > 0);
                assert_eq!(c.start, expect_start);
                expect_start += c.len;
            }
            assert_eq!(expect_start, batch);
        }
    }
}
