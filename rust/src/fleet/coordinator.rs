//! Coordinator side of the fleet: [`FleetBackend`] implements the
//! unified [`Backend`] trait over a set of remote workers.
//!
//! * **Pipelined scatter/gather.**  `forward` carves the batch into
//!   contiguous chunks pulled from a shared work queue, one scoped
//!   pump thread per live worker connection.  Each pump keeps up to
//!   `min(pipeline window, worker max_inflight)` id-tagged Forwards in
//!   flight, reads replies in completion order and reassembles them by
//!   id — so a fast worker streams through many chunks while a slow
//!   one chews on its first, and the result is still bit-identical to
//!   a single backend serving the same stream.  Chunk sizes come from
//!   each worker's observed per-image latency (EWMA in
//!   [`FleetStats`]): fast workers pull big chunks, slow workers pull
//!   small ones, and a heterogeneous fleet stops being paced by its
//!   slowest box.  `QOS_NETS_FLEET_PIPELINE=off` (or any window
//!   number) overrides the default window of
//!   [`DEFAULT_PIPELINE_WINDOW`]; window 1 is the legacy lockstep
//!   request/response mode.
//! * **Membership.**  Workers move through a state machine instead of
//!   being evicted for life: `Live → Suspect` on the first failure,
//!   `Suspect → Evicted` on the second (each failed chunk is requeued
//!   onto survivors either way), `Evicted → Rejoining → Live` when a
//!   re-probe completes a fresh Hello/Prepare/SetOp handshake.  All
//!   transitions are single-sourced through
//!   [`FleetStats::report_failure`]/[`FleetStats::mark_live`], so the
//!   `evictions` counter moves exactly once per membership epoch no
//!   matter how many backends (heartbeat and data plane included)
//!   observe the same dead worker.  A registry join
//!   ([`FleetBackend::admit`], fed by `fleet::registry`) grows the
//!   fleet under load; other backends sharing the same [`FleetStats`]
//!   adopt admitted workers on their next forward.
//! * **Fleet-wide switching.**  [`FleetBackend::set_operating_point`]
//!   broadcasts `SetOp` with the PR-2 [`SwitchMode`] semantics: `Drain`
//!   writes the barrier frame to every live worker first (so they all
//!   drain concurrently), then collects one ack per surviving worker
//!   before returning; `Immediate` is a fire-and-forget store.  Worker
//!   connections queue frames FIFO, so a drain barrier sent after
//!   pipelined Forwards acks only once all of them have completed.
//! * **Attribution.**  Every instance records per-worker request/batch
//!   counts, cumulative latency, EWMA and membership state into a
//!   shared [`FleetStats`]; `serve --fleet` hands one handle to every
//!   server worker's backend and prints the per-worker table at the
//!   end.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::Backend;
use crate::engine::OperatingPoint;
use crate::fleet::wire::{self, Frame, LadderRung, PROTOCOL_VERSION};
use crate::obs::{self, member_state_str, metrics::{Kind, MetricFamily, Sample}, ObsEvent};
use crate::qos::SwitchMode;

/// Default socket read/write timeout for data-plane calls; a hung
/// worker is indistinguishable from a dead one past this.
const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// In-flight Forwards per worker connection unless overridden by
/// [`FleetBackend::with_pipeline_window`] or `QOS_NETS_FLEET_PIPELINE`.
pub const DEFAULT_PIPELINE_WINDOW: usize = 4;

/// Default target service time for one chunk, microseconds: a worker's
/// chunk size is chosen so `chunk_len * ewma_img_us ≈` this quantum,
/// which is what skews chunk sizes toward fast workers.  Overridable at
/// runtime per fleet via [`FleetStats::set_chunk_quantum_us`] — the
/// autopilot narrows the quantum under latency pressure (smaller
/// chunks, finer interleaving) and widens it back when headroom
/// returns.
pub const CHUNK_QUANTUM_US: f64 = 5_000.0;

/// Smoothing factor for the per-image latency EWMA.
const EWMA_ALPHA: f64 = 0.3;

/// Handshake/readmit timeout used on the data-plane refresh path, so a
/// dead host cannot stall `forward` for the full I/O timeout.
const REFRESH_TIMEOUT: Duration = Duration::from_millis(250);

/// Where one worker stands in the membership state machine.  The
/// two-strike path `Live → Suspect → Evicted` tolerates one transient
/// failure per epoch; `Rejoining` marks an evicted worker mid-re-probe
/// until a fresh handshake completes and [`FleetStats::mark_live`]
/// starts its next epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MemberState {
    /// Serving (or never yet observed failing).
    #[default]
    Live,
    /// One failure this epoch; the next probe either readmits or
    /// evicts.
    Suspect,
    /// Two failures without a successful handshake in between; only a
    /// re-probe ([`FleetBackend::reprobe`]) or a registry re-join can
    /// bring it back.
    Evicted,
    /// An eviction survivor with a re-probe in progress.
    Rejoining,
}

/// Per-worker serving statistics and membership state (see
/// [`FleetStats`]).
#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    /// Images this worker served.
    pub requests: u64,
    /// Forward calls (chunks) this worker served.
    pub batches: u64,
    /// I/O or protocol failures observed talking to this worker.
    pub errors: u64,
    /// Cumulative wall time of successful forward calls, microseconds.
    pub latency_us_sum: u64,
    /// Legacy view of `state == Evicted` (kept for reports).
    pub evicted: bool,
    /// Membership state, single-sourced across every backend sharing
    /// the registry.
    pub state: MemberState,
    /// Membership epoch: bumped every time the worker (re)enters
    /// `Live`, so each epoch's eviction counts exactly once.
    pub epoch: u64,
    /// Completed eviction → live round trips.
    pub rejoins: u64,
    /// EWMA of per-image forward latency, microseconds (0 until the
    /// first successful chunk); drives latency-aware chunk sizing.
    pub ewma_img_us: f64,
    /// Heartbeat probes this worker failed to answer.
    pub hb_misses: u64,
    /// Chunks lost to transport failures on this worker (each went
    /// back onto the shared queue for a survivor to serve).
    pub requeues: u64,
    /// Drain barriers this worker acked (OP switches + explicit
    /// drains).
    pub drain_waits: u64,
    /// Cumulative time the coordinator spent waiting on this worker's
    /// drain-barrier acks, microseconds.
    pub drain_wait_us: u64,
    /// Re-probe handshakes aimed at this worker (successful or not);
    /// surfaced in the per-worker fleet report so operators can see
    /// how hard the control loop is working a flapping box.
    pub reprobes: u64,
    /// Forwards currently in flight on this worker's connection.
    pub inflight: u64,
    /// Epoch whose eviction has already been counted (dedup across
    /// heartbeat + data plane + multiple backends).
    counted_epoch: Option<u64>,
}

impl WorkerStats {
    /// Mean per-chunk forward latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.latency_us_sum as f64 / self.batches as f64
        }
    }
}

#[derive(Default)]
struct FleetStatsInner {
    workers: BTreeMap<String, WorkerStats>,
    requeues: u64,
    evictions: u64,
    /// Runtime chunk-quantum override, microseconds; 0 = use
    /// [`CHUNK_QUANTUM_US`].  Lives here (not on [`FleetBackend`])
    /// because every backend built from the same handle — one per
    /// server worker thread — shares this registry, so one setter call
    /// reaches every pump.
    chunk_quantum_us: f64,
}

/// Shared per-worker attribution registry and membership authority,
/// keyed by worker address.  Cheap to clone; every [`FleetBackend`]
/// built from the same handle (e.g. one per server worker thread)
/// folds into the same table, and membership transitions observed by
/// any of them are visible to all.
#[derive(Clone, Default)]
pub struct FleetStats {
    inner: Arc<Mutex<FleetStatsInner>>,
}

impl FleetStats {
    fn with_worker(&self, addr: &str, f: impl FnOnce(&mut WorkerStats)) {
        let mut inner = self.inner.lock().unwrap();
        f(inner.workers.entry(addr.to_string()).or_default());
    }

    fn record_requeue(&self) {
        self.inner.lock().unwrap().requeues += 1;
    }

    /// Fold one successful chunk into the worker's counters and its
    /// per-image latency EWMA.
    fn record_success(&self, addr: &str, images: usize, latency_us: u64) {
        let per_img = latency_us as f64 / images.max(1) as f64;
        self.with_worker(addr, |w| {
            w.requests += images as u64;
            w.batches += 1;
            w.latency_us_sum += latency_us;
            w.ewma_img_us = if w.ewma_img_us <= 0.0 {
                per_img
            } else {
                (1.0 - EWMA_ALPHA) * w.ewma_img_us + EWMA_ALPHA * per_img
            };
        });
    }

    fn ewma_img_us(&self, addr: &str) -> f64 {
        self.inner.lock().unwrap().workers.get(addr).map_or(0.0, |w| w.ewma_img_us)
    }

    /// Override the per-chunk service-time quantum for every backend
    /// sharing this registry (clamped to at least 100 us so a zero or
    /// negative target cannot degenerate to one-image chunks fleet-wide
    /// by accident).  The autopilot's chunk-plan actuator.
    pub fn set_chunk_quantum_us(&self, quantum_us: f64) {
        self.inner.lock().unwrap().chunk_quantum_us = quantum_us.max(100.0);
    }

    /// Restore the default chunk quantum ([`CHUNK_QUANTUM_US`]).
    pub fn reset_chunk_quantum(&self) {
        self.inner.lock().unwrap().chunk_quantum_us = 0.0;
    }

    /// The chunk quantum currently in force (default or override).
    pub fn chunk_quantum_us(&self) -> f64 {
        match self.inner.lock().unwrap().chunk_quantum_us {
            q if q > 0.0 => q,
            _ => CHUNK_QUANTUM_US,
        }
    }

    /// The worker's current membership state (`Live` if never seen —
    /// a fresh address has nothing held against it).
    pub fn state_of(&self, addr: &str) -> MemberState {
        self.inner.lock().unwrap().workers.get(addr).map_or(MemberState::Live, |w| w.state)
    }

    fn live_addrs(&self) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .workers
            .iter()
            .filter(|(_, w)| w.state == MemberState::Live)
            .map(|(a, _)| a.clone())
            .collect()
    }

    /// Advance the state machine on a failure: `Live → Suspect` (first
    /// strike), anything else `→ Evicted`.  The `evictions` counter
    /// moves only on the first eviction of each membership epoch, so a
    /// worker failing heartbeat and forward in the same tick — or
    /// observed dead by several backends — still counts once.
    fn report_failure(&self, addr: &str) -> MemberState {
        let (from, to) = {
            let mut guard = self.inner.lock().unwrap();
            let inner = &mut *guard;
            let w = inner.workers.entry(addr.to_string()).or_default();
            w.errors += 1;
            let from = w.state;
            w.state = match w.state {
                MemberState::Live => MemberState::Suspect,
                MemberState::Suspect | MemberState::Rejoining | MemberState::Evicted => {
                    if w.counted_epoch != Some(w.epoch) {
                        w.counted_epoch = Some(w.epoch);
                        inner.evictions += 1;
                    }
                    w.evicted = true;
                    MemberState::Evicted
                }
            };
            (from, w.state)
        };
        if from != to {
            obs::publish(ObsEvent::Membership {
                addr: addr.to_string(),
                from: member_state_str(from).to_string(),
                to: member_state_str(to).to_string(),
            });
        }
        to
    }

    /// A fresh handshake completed: back to `Live`, opening the next
    /// membership epoch.  Counters (requests, latency, EWMA) persist
    /// across the round trip — a rejoining worker keeps its history.
    fn mark_live(&self, addr: &str) {
        let mut from = None;
        self.with_worker(addr, |w| {
            if w.state != MemberState::Live {
                if matches!(w.state, MemberState::Evicted | MemberState::Rejoining) {
                    w.rejoins += 1;
                }
                from = Some(w.state);
                w.state = MemberState::Live;
                w.evicted = false;
                w.epoch += 1;
            }
        });
        if let Some(from) = from {
            obs::publish(ObsEvent::Membership {
                addr: addr.to_string(),
                from: member_state_str(from).to_string(),
                to: "live".to_string(),
            });
        }
    }

    /// Flag an evicted worker as having a re-probe in progress.
    fn set_rejoining(&self, addr: &str) {
        let mut moved = false;
        self.with_worker(addr, |w| {
            if w.state == MemberState::Evicted {
                w.state = MemberState::Rejoining;
                moved = true;
            }
        });
        if moved {
            obs::publish(ObsEvent::Membership {
                addr: addr.to_string(),
                from: "evicted".to_string(),
                to: "rejoining".to_string(),
            });
        }
    }

    /// A heartbeat probe went unanswered: bump the worker's miss
    /// counter and publish the event (failure bookkeeping stays in
    /// [`fail`]/[`FleetStats::report_failure`]).
    fn record_hb_miss(&self, addr: &str) {
        self.with_worker(addr, |w| w.hb_misses += 1);
        obs::publish(ObsEvent::HeartbeatMiss { addr: addr.to_string() });
    }

    /// Fold one acked drain barrier (OP switch or explicit drain) into
    /// the worker's wait accounting.
    fn record_drain_wait(&self, addr: &str, waited_us: u64) {
        self.with_worker(addr, |w| {
            w.drain_waits += 1;
            w.drain_wait_us += waited_us;
        });
    }

    /// Snapshot: per-worker stats (sorted by address), total requeued
    /// chunks, total evictions.
    pub fn snapshot(&self) -> (Vec<(String, WorkerStats)>, u64, u64) {
        let inner = self.inner.lock().unwrap();
        (
            inner.workers.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
            inner.requeues,
            inner.evictions,
        )
    }

    /// A scrape-time collector for [`crate::obs::Registry::register`]:
    /// membership gauges plus per-worker attribution series, read from
    /// this registry when the endpoint is scraped (the same snapshot
    /// the `serve --fleet` report prints).
    pub fn metrics_collector(&self) -> impl Fn() -> Vec<MetricFamily> + Send + Sync + 'static {
        let stats = self.clone();
        move || {
            let (workers, _, _) = stats.snapshot();
            let mut by_state = [0usize; 4];
            for (_, w) in &workers {
                let slot = match w.state {
                    MemberState::Live => 0,
                    MemberState::Suspect => 1,
                    MemberState::Evicted => 2,
                    MemberState::Rejoining => 3,
                };
                by_state[slot] += 1;
            }
            let states = ["live", "suspect", "evicted", "rejoining"];
            let mut fams = vec![
                MetricFamily::new(
                    "qos_nets_fleet_workers",
                    "Fleet workers by membership state.",
                    Kind::Gauge,
                    states
                        .iter()
                        .zip(by_state)
                        .map(|(s, n)| Sample::with(&[("state", s)], n as f64))
                        .collect(),
                ),
                MetricFamily::new(
                    "qos_nets_fleet_chunk_quantum_us",
                    "Per-chunk service-time quantum in force, microseconds.",
                    Kind::Gauge,
                    vec![Sample::plain(stats.chunk_quantum_us())],
                ),
            ];
            let per_worker: [(&str, &str, Kind, fn(&WorkerStats) -> f64); 11] = [
                (
                    "qos_nets_fleet_worker_requests_total",
                    "Images served per fleet worker.",
                    Kind::Counter,
                    |w| w.requests as f64,
                ),
                (
                    "qos_nets_fleet_worker_chunks_total",
                    "Forward chunks served per fleet worker.",
                    Kind::Counter,
                    |w| w.batches as f64,
                ),
                (
                    "qos_nets_fleet_worker_errors_total",
                    "I/O and protocol failures per fleet worker.",
                    Kind::Counter,
                    |w| w.errors as f64,
                ),
                (
                    "qos_nets_fleet_worker_rejoins_total",
                    "Completed eviction-to-live round trips per fleet worker.",
                    Kind::Counter,
                    |w| w.rejoins as f64,
                ),
                (
                    "qos_nets_fleet_worker_hb_misses_total",
                    "Unanswered heartbeat probes per fleet worker.",
                    Kind::Counter,
                    |w| w.hb_misses as f64,
                ),
                (
                    "qos_nets_fleet_worker_requeues_total",
                    "Chunks lost to transport failures per fleet worker.",
                    Kind::Counter,
                    |w| w.requeues as f64,
                ),
                (
                    "qos_nets_fleet_worker_drain_waits_total",
                    "Drain barriers acked per fleet worker.",
                    Kind::Counter,
                    |w| w.drain_waits as f64,
                ),
                (
                    "qos_nets_fleet_worker_drain_wait_us_total",
                    "Cumulative drain-barrier wait per fleet worker, microseconds.",
                    Kind::Counter,
                    |w| w.drain_wait_us as f64,
                ),
                (
                    "qos_nets_fleet_worker_reprobes_total",
                    "Re-probe handshakes aimed at each fleet worker.",
                    Kind::Counter,
                    |w| w.reprobes as f64,
                ),
                (
                    "qos_nets_fleet_worker_ewma_img_us",
                    "EWMA per-image forward latency per fleet worker, microseconds.",
                    Kind::Gauge,
                    |w| w.ewma_img_us,
                ),
                (
                    "qos_nets_fleet_worker_inflight",
                    "Forwards in flight per fleet worker connection.",
                    Kind::Gauge,
                    |w| w.inflight as f64,
                ),
            ];
            for (name, help, kind, get) in per_worker {
                fams.push(MetricFamily::new(
                    name,
                    help,
                    kind,
                    workers
                        .iter()
                        .map(|(addr, w)| Sample::with(&[("addr", addr)], get(w)))
                        .collect(),
                ));
            }
            fams
        }
    }
}

/// One remote worker as this coordinator sees it.
struct Peer {
    addr: String,
    /// Overlay mode the worker advertised in `HelloAck` (empty = not
    /// applicable, e.g. in-process test workers).
    mode: String,
    /// Heartbeat cadence the worker advertised in `HelloAck`.
    hb_interval_ms: u64,
    hb_timeout_ms: u64,
    /// Pipelining capability the worker advertised in `HelloAck`
    /// (legacy workers advertise nothing and get 1 = lockstep).
    max_inflight: u64,
    /// `None` while suspect/evicted.
    stream: Option<TcpStream>,
}

/// One scatter/gather work item: images `[start..start + len)` of the
/// current forward call, with its requeue budget consumed so far.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Chunk {
    start: usize,
    len: usize,
    attempts: usize,
}

/// What one chunk call produced.
enum ChunkOutcome {
    Logits(Vec<f32>),
    /// Worker-side application error (bad OP index, backend failure):
    /// deterministic, so retrying elsewhere would fail too — fatal.
    App(String),
    /// Transport failure: the worker is gone; requeue the chunk.
    Io,
}

/// Drop a peer's poisoned connection and advance the membership state
/// machine — the single place failure bookkeeping lives, so heartbeat
/// and data-plane failures can never double-count an eviction.
fn fail(peer: &mut Peer, stats: &FleetStats) {
    peer.stream = None;
    stats.report_failure(&peer.addr);
}

/// Strict request/response exchange with one peer; reports on
/// transport failure (the stream is poisoned mid-frame, so it cannot
/// be reused).
fn call(
    peer: &mut Peer,
    stats: &FleetStats,
    frame: &Frame,
    payload: &[f32],
) -> Result<(Frame, Vec<f32>)> {
    let Some(stream) = peer.stream.as_mut() else {
        bail!("worker {} not connected", peer.addr);
    };
    let r = wire::write_frame(stream, frame, payload).and_then(|()| wire::read_frame(stream));
    match r {
        Ok(reply) => Ok(reply),
        Err(e) => {
            fail(peer, stats);
            Err(e.context(format!("worker {}", peer.addr)))
        }
    }
}

/// What one completed `Hello` exchange yields.
struct Handshake {
    stream: TcpStream,
    classes: usize,
    mode: String,
    hb_interval_ms: u64,
    hb_timeout_ms: u64,
    max_inflight: u64,
}

/// Connect to `addr` under `timeout` and run the `Hello` exchange.
fn handshake(addr: &str, timeout: Duration) -> Result<Handshake> {
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("resolve fleet worker {addr}"))?
        .next()
        .with_context(|| format!("fleet worker {addr} resolves to no address"))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout)
        .with_context(|| format!("connect to fleet worker {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout)).ok();
    stream.set_write_timeout(Some(timeout)).ok();
    wire::write_frame(&mut stream, &Frame::Hello { version: PROTOCOL_VERSION }, &[])
        .with_context(|| format!("hello to fleet worker {addr}"))?;
    let (reply, _) = wire::read_frame(&mut stream)
        .with_context(|| format!("hello ack from fleet worker {addr}"))?;
    match reply {
        Frame::HelloAck {
            classes,
            mode,
            hb_interval_ms,
            hb_timeout_ms,
            max_inflight,
            ..
        } => Ok(Handshake {
            stream,
            classes,
            mode,
            hb_interval_ms,
            hb_timeout_ms,
            max_inflight,
        }),
        Frame::Err { message, .. } => bail!("fleet worker {addr} refused hello: {message}"),
        other => bail!("fleet worker {addr}: unexpected {} to hello", other.type_name()),
    }
}

/// The pipeline window configured via `QOS_NETS_FLEET_PIPELINE`:
/// `off`/`0`/`false` force the legacy lockstep mode (window 1), a
/// number sets the window, anything else (or unset) takes the default.
fn pipeline_from_env() -> usize {
    match std::env::var("QOS_NETS_FLEET_PIPELINE") {
        Ok(v) => {
            let v = v.trim().to_ascii_lowercase();
            if v == "off" || v == "0" || v == "false" {
                1
            } else {
                v.parse().ok().filter(|&n| n >= 1).unwrap_or(DEFAULT_PIPELINE_WINDOW)
            }
        }
        Err(_) => DEFAULT_PIPELINE_WINDOW,
    }
}

/// Images one chunk should carry for a worker with this per-image
/// EWMA: size toward the service-time quantum (the fleet's current
/// one — see [`FleetStats::set_chunk_quantum_us`]), `fallback` (the
/// even share) before any latency has been observed.
fn chunk_target(quantum_us: f64, ewma_img_us: f64, fallback: usize) -> usize {
    if ewma_img_us <= 0.0 {
        fallback.max(1)
    } else {
        ((quantum_us / ewma_img_us) as usize).max(1)
    }
}

/// Carve up to `want` images off the front of the work queue.  Spans
/// that have already failed once (`attempts > 0`) are taken whole so
/// the retry budget stays attached to the same images.
fn take_chunk(queue: &Mutex<VecDeque<Chunk>>, want: usize) -> Option<Chunk> {
    let mut q = queue.lock().unwrap();
    let front = q.front_mut()?;
    if front.len <= want || front.attempts > 0 {
        return q.pop_front();
    }
    let take = Chunk { start: front.start, len: want, attempts: 0 };
    front.start += want;
    front.len -= want;
    Some(take)
}

/// One worker connection's pump for one forward call: keep the window
/// full of id-tagged Forwards pulled from the shared queue, read
/// replies in completion order, match them back by id.  On transport
/// failure every in-flight chunk becomes an `Io` outcome and the peer
/// moves through the membership machine; on an application error the
/// pump stops pulling but still drains its in-flight replies, so the
/// connection stays frame-aligned for the next call.
#[allow(clippy::too_many_arguments)]
fn peer_pump(
    peer: &mut Peer,
    stats: FleetStats,
    queue: &Mutex<VecDeque<Chunk>>,
    window: usize,
    fallback: usize,
    class: Option<usize>,
    op_idx: usize,
    images: &[f32],
    elems: usize,
) -> Vec<(Chunk, ChunkOutcome)> {
    let addr = peer.addr.clone();
    let Some(mut stream) = peer.stream.take() else {
        return Vec::new();
    };
    let win = window.min(peer.max_inflight.max(1) as usize).max(1);
    let mut out: Vec<(Chunk, ChunkOutcome)> = Vec::new();
    let mut inflight: VecDeque<(u64, Chunk, Instant)> = VecDeque::new();
    let mut next_id: u64 = 1;
    let mut pulling = true;
    let mut healthy = true;
    let find = |inflight: &VecDeque<(u64, Chunk, Instant)>, id: Option<u64>| -> Option<usize> {
        match id {
            Some(id) => inflight.iter().position(|(q, _, _)| *q == id),
            // a reply without an id is only unambiguous in lockstep
            None if inflight.len() == 1 => Some(0),
            None => None,
        }
    };
    loop {
        let quantum_us = stats.chunk_quantum_us();
        while pulling && inflight.len() < win {
            let want = chunk_target(quantum_us, stats.ewma_img_us(&addr), fallback);
            let Some(chunk) = take_chunk(queue, want) else { break };
            let frame = Frame::Forward {
                id: Some(next_id),
                op: Some(op_idx),
                batch: chunk.len,
                class,
            };
            let data = &images[chunk.start * elems..(chunk.start + chunk.len) * elems];
            if wire::write_frame(&mut stream, &frame, data).is_err() {
                stats.with_worker(&addr, |w| w.requeues += 1);
                out.push((chunk, ChunkOutcome::Io));
                healthy = false;
                break;
            }
            stats.with_worker(&addr, |w| w.inflight += 1);
            inflight.push_back((next_id, chunk, Instant::now()));
            next_id += 1;
        }
        if !healthy || inflight.is_empty() {
            break;
        }
        match wire::read_frame(&mut stream) {
            Ok((Frame::Logits { id, .. }, logits)) => match find(&inflight, id) {
                Some(pos) => {
                    let (_, chunk, t0) = inflight.remove(pos).expect("indexed in-flight entry");
                    let latency_us = t0.elapsed().as_micros() as u64;
                    stats.with_worker(&addr, |w| w.inflight = w.inflight.saturating_sub(1));
                    stats.record_success(&addr, chunk.len, latency_us);
                    if obs::recording() {
                        obs::publish(ObsEvent::FleetChunk {
                            addr: addr.clone(),
                            op: op_idx,
                            images: chunk.len,
                            latency_us,
                        });
                    }
                    out.push((chunk, ChunkOutcome::Logits(logits)));
                }
                None => healthy = false, // reply for nothing in flight
            },
            Ok((Frame::Err { id, message }, _)) => match find(&inflight, id) {
                Some(pos) => {
                    let (_, chunk, _) = inflight.remove(pos).expect("indexed in-flight entry");
                    stats.with_worker(&addr, |w| {
                        w.errors += 1;
                        w.inflight = w.inflight.saturating_sub(1);
                    });
                    out.push((chunk, ChunkOutcome::App(message)));
                    pulling = false;
                }
                None => healthy = false,
            },
            Ok(_) | Err(_) => healthy = false, // protocol confusion / transport
        }
    }
    if healthy {
        peer.stream = Some(stream);
    } else {
        let lost = inflight.len() as u64;
        stats.with_worker(&addr, |w| {
            w.inflight = w.inflight.saturating_sub(lost);
            w.requeues += lost;
        });
        for (_, chunk, _) in inflight {
            out.push((chunk, ChunkOutcome::Io));
        }
        drop(stream);
        fail(peer, &stats);
    }
    out
}

/// A remote-fleet [`Backend`]: pipelined scatter/gather over TCP
/// workers with failover and dynamic membership, plus the fleet-wide
/// control plane (switch broadcast, heartbeats, re-probe, registry
/// admission, shutdown).  See the module docs.
pub struct FleetBackend {
    peers: Vec<Peer>,
    classes: usize,
    stats: FleetStats,
    /// Requeue budget per chunk after its first failed attempt.
    max_retries: usize,
    io_timeout: Duration,
    /// In-flight Forwards per worker connection (1 = lockstep).
    pipeline: usize,
    /// The ladder broadcast by the last successful `prepare`, replayed
    /// on every rejoin handshake (a fresh connection means a fresh
    /// worker-side backend with nothing resident).
    ladder: Option<Vec<LadderRung>>,
    /// The OP this backend last broadcast, replayed on rejoin so a
    /// recovered worker serves the fleet's current point, not rung 0.
    current_op: Option<usize>,
    /// Per-tenant-class OP overrides last broadcast
    /// ([`set_operating_point_class`](Self::set_operating_point_class)),
    /// replayed on rejoin after `current_op` so a recovered worker
    /// serves every class at the fleet's current point.
    class_ops: BTreeMap<usize, usize>,
}

impl FleetBackend {
    /// Connect to every worker and run the `Hello` handshake.  All
    /// workers must agree on the classifier width; any unreachable
    /// address fails the whole connect (a misspelled fleet member
    /// should not silently shrink the fleet at startup).
    pub fn connect(addrs: &[String]) -> Result<FleetBackend> {
        Self::connect_with(addrs, FleetStats::default())
    }

    /// [`connect`](Self::connect) into a shared [`FleetStats`] registry
    /// (one per serving process, many backends).
    pub fn connect_with(addrs: &[String], stats: FleetStats) -> Result<FleetBackend> {
        anyhow::ensure!(!addrs.is_empty(), "fleet: no worker addresses given");
        let mut peers = Vec::with_capacity(addrs.len());
        let mut classes: Option<usize> = None;
        for addr in addrs {
            let hs = handshake(addr, DEFAULT_IO_TIMEOUT)?;
            match classes {
                None => classes = Some(hs.classes),
                Some(prev) if prev != hs.classes => bail!(
                    "fleet workers disagree on classifier width ({prev} vs {c} at {addr}) — mixed experiments?",
                    c = hs.classes
                ),
                Some(_) => {}
            }
            hs.stream.set_read_timeout(Some(DEFAULT_IO_TIMEOUT)).ok();
            hs.stream.set_write_timeout(Some(DEFAULT_IO_TIMEOUT)).ok();
            stats.with_worker(addr, |_| {}); // register for attribution
            peers.push(Peer {
                addr: addr.clone(),
                mode: hs.mode,
                hb_interval_ms: hs.hb_interval_ms,
                hb_timeout_ms: hs.hb_timeout_ms,
                max_inflight: hs.max_inflight,
                stream: Some(hs.stream),
            });
        }
        Ok(FleetBackend {
            peers,
            classes: classes.expect("at least one worker"),
            stats,
            max_retries: 2,
            io_timeout: DEFAULT_IO_TIMEOUT,
            pipeline: pipeline_from_env(),
            ladder: None,
            current_op: None,
            class_ops: BTreeMap::new(),
        })
    }

    /// Override the per-chunk requeue budget (default 2).
    pub fn with_max_retries(mut self, retries: usize) -> Self {
        self.max_retries = retries;
        self
    }

    /// Override the pipeline window (in-flight Forwards per worker
    /// connection; 1 = legacy lockstep).  Defaults to
    /// [`DEFAULT_PIPELINE_WINDOW`] or the `QOS_NETS_FLEET_PIPELINE`
    /// environment override.
    pub fn with_pipeline_window(mut self, window: usize) -> Self {
        self.pipeline = window.max(1);
        self
    }

    /// The configured pipeline window.
    pub fn pipeline_window(&self) -> usize {
        self.pipeline
    }

    /// Workers currently connected.
    pub fn live_workers(&self) -> usize {
        self.peers.iter().filter(|p| p.stream.is_some()).count()
    }

    /// The shared attribution registry this backend records into.
    pub fn stats(&self) -> &FleetStats {
        &self.stats
    }

    /// Heartbeat probe interval hint: the tightest cadence any peer
    /// advertised in its handshake — one short-leashed worker speeds up
    /// eviction for the whole fleet.  Falls back to the wire-level
    /// default for an (impossible) empty peer set.
    pub fn hb_interval(&self) -> Duration {
        let ms = self
            .peers
            .iter()
            .map(|p| p.hb_interval_ms.max(1))
            .min()
            .unwrap_or(wire::DEFAULT_HB_INTERVAL_MS);
        Duration::from_millis(ms)
    }

    /// Per-probe timeout hint, minimum over the fleet (companion to
    /// [`hb_interval`](Self::hb_interval)).
    pub fn hb_timeout(&self) -> Duration {
        let ms = self
            .peers
            .iter()
            .map(|p| p.hb_timeout_ms.max(1))
            .min()
            .unwrap_or(wire::DEFAULT_HB_TIMEOUT_MS);
        Duration::from_millis(ms)
    }

    /// Cross-check the coordinator's retraining-overlay mode against
    /// what every worker advertised in its handshake.  `Prepare` alone
    /// cannot catch this: relative powers are mode-independent (the
    /// overlays only swap tensors), so a `--mode` mismatch would
    /// silently serve different logits.  Workers advertising an empty
    /// mode (in-process test workers) are skipped.
    pub fn check_mode(&self, expected: &str) -> Result<()> {
        for peer in &self.peers {
            if !peer.mode.is_empty() && peer.mode != expected {
                bail!(
                    "fleet worker {} serves mode {:?} but this coordinator runs --mode {:?}; \
                     restart the worker with the matching --mode",
                    peer.addr,
                    peer.mode,
                    expected
                );
            }
        }
        Ok(())
    }

    /// Re-run the full admission handshake (`Hello`, then `Prepare`
    /// with the stored ladder, then `SetOp` to the fleet's current OP)
    /// against peer `i` and, on success, mark it live.  Used by the
    /// refresh path, heartbeat second strikes, [`reprobe`](Self::reprobe)
    /// and registry admission.
    fn readmit(&mut self, i: usize, timeout: Duration) -> Result<()> {
        let addr = self.peers[i].addr.clone();
        let hs = handshake(&addr, timeout)?;
        anyhow::ensure!(
            hs.classes == self.classes,
            "rejoining worker {addr} changed classifier width ({} vs fleet {})",
            hs.classes,
            self.classes
        );
        let mut stream = hs.stream;
        if let Some(ladder) = &self.ladder {
            wire::write_frame(&mut stream, &Frame::Prepare { ladder: ladder.clone() }, &[])
                .with_context(|| format!("prepare to rejoining worker {addr}"))?;
            match wire::read_frame(&mut stream)
                .with_context(|| format!("prepare ack from rejoining worker {addr}"))?
            {
                (Frame::Ok, _) => {}
                (Frame::Err { message, .. }, _) => {
                    bail!("rejoining worker {addr} rejected prepare: {message}")
                }
                (other, _) => {
                    bail!("rejoining worker {addr}: unexpected {} to prepare", other.type_name())
                }
            }
        }
        if let Some(op) = self.current_op {
            // fire-and-forget: align the recovered worker with the
            // fleet's current operating point
            wire::write_frame(&mut stream, &Frame::SetOp { op, drain: false, class: None }, &[])
                .with_context(|| format!("set_op to rejoining worker {addr}"))?;
        }
        for (&class, &op) in &self.class_ops {
            let frame = Frame::SetOp { op, drain: false, class: Some(class) };
            wire::write_frame(&mut stream, &frame, &[])
                .with_context(|| format!("class set_op to rejoining worker {addr}"))?;
        }
        stream.set_read_timeout(Some(self.io_timeout)).ok();
        stream.set_write_timeout(Some(self.io_timeout)).ok();
        let peer = &mut self.peers[i];
        peer.mode = hs.mode;
        peer.hb_interval_ms = hs.hb_interval_ms;
        peer.hb_timeout_ms = hs.hb_timeout_ms;
        peer.max_inflight = hs.max_inflight;
        peer.stream = Some(stream);
        self.stats.mark_live(&addr);
        Ok(())
    }

    /// Adopt a worker address this backend has no peer entry for yet
    /// (admitted via the registry, possibly by a different backend
    /// sharing the same [`FleetStats`]).
    fn try_adopt(&mut self, addr: &str, timeout: Duration) -> Result<()> {
        self.peers.push(Peer {
            addr: addr.to_string(),
            mode: String::new(),
            hb_interval_ms: wire::DEFAULT_HB_INTERVAL_MS,
            hb_timeout_ms: wire::DEFAULT_HB_TIMEOUT_MS,
            max_inflight: 1,
            stream: None,
        });
        let i = self.peers.len() - 1;
        match self.readmit(i, timeout) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.peers.pop();
                Err(e)
            }
        }
    }

    /// Data-plane membership refresh, run at the top of every forward:
    /// give each `Suspect` peer one quick chance to rejoin (second
    /// failure evicts it), and adopt workers other backends admitted
    /// into the shared registry.  Bounded by [`REFRESH_TIMEOUT`] per
    /// attempt so a dead host cannot stall the data plane.
    fn refresh_peers(&mut self) {
        let probe = self.io_timeout.min(REFRESH_TIMEOUT);
        for i in 0..self.peers.len() {
            if self.peers[i].stream.is_some() {
                continue;
            }
            let addr = self.peers[i].addr.clone();
            if self.stats.state_of(&addr) != MemberState::Suspect {
                continue;
            }
            if self.readmit(i, probe).is_err() {
                self.stats.report_failure(&addr);
            }
        }
        if self.ladder.is_some() {
            let known: BTreeSet<String> = self.peers.iter().map(|p| p.addr.clone()).collect();
            for addr in self.stats.live_addrs() {
                if !known.contains(&addr) {
                    let _ = self.try_adopt(&addr, probe);
                }
            }
        }
    }

    /// Re-probe every disconnected peer — including `Evicted` ones,
    /// which the data plane no longer retries — re-admitting each that
    /// completes a fresh handshake.  Returns how many rejoined.  Run
    /// this from a control loop (the serve loop runs it on heartbeat
    /// ticks) to pick recovered workers back up.
    pub fn reprobe(&mut self) -> usize {
        let timeout = self.io_timeout.min(Duration::from_millis(500));
        let mut rejoined = 0usize;
        for i in 0..self.peers.len() {
            if self.peers[i].stream.is_some() {
                continue;
            }
            let addr = self.peers[i].addr.clone();
            self.stats.with_worker(&addr, |w| w.reprobes += 1);
            if self.stats.state_of(&addr) == MemberState::Evicted {
                self.stats.set_rejoining(&addr);
            }
            match self.readmit(i, timeout) {
                Ok(()) => rejoined += 1,
                Err(_) => {
                    self.stats.report_failure(&addr);
                }
            }
        }
        rejoined
    }

    /// Registry admission: handshake each newly announced address and
    /// add it to this backend's peer set (and, via the shared stats
    /// registry, make it adoptable by every sibling backend).  Already
    /// known addresses are left to [`reprobe`](Self::reprobe).
    /// Returns how many workers joined.
    pub fn admit(&mut self, addrs: &[String]) -> usize {
        let timeout = self.io_timeout.min(Duration::from_millis(500));
        let mut joined = 0usize;
        for addr in addrs {
            if let Some(i) = self.peers.iter().position(|p| p.addr == *addr) {
                if self.peers[i].stream.is_none() {
                    self.stats.set_rejoining(addr);
                    match self.readmit(i, timeout) {
                        Ok(()) => joined += 1,
                        Err(_) => {
                            self.stats.report_failure(addr);
                        }
                    }
                }
                continue;
            }
            if self.try_adopt(addr, timeout).is_ok() {
                joined += 1;
            }
        }
        joined
    }

    /// Broadcast an operating-point switch fleet-wide.
    ///
    /// `Drain` first writes the barrier frame to every live worker (so
    /// the whole fleet drains concurrently), then reads one ack per
    /// worker; workers that fail either phase leave the live set.
    /// Returns the number of surviving workers that acked — the
    /// coordinator only reports the switch complete once every
    /// survivor has.  `Immediate` is a fire-and-forget store on every
    /// worker.
    pub fn set_operating_point(&mut self, op: usize, mode: SwitchMode) -> Result<usize> {
        self.set_operating_point_class(None, op, mode)
    }

    /// [`set_operating_point`](Self::set_operating_point) scoped to one
    /// tenant class: the `SetOp` frame carries the class id, so each
    /// worker's drain barrier waits only on that class's in-flight
    /// forwards — a premium switch never queues behind a best-effort
    /// drain.  `None` is the legacy whole-fleet switch.
    pub fn set_operating_point_class(
        &mut self,
        class: Option<usize>,
        op: usize,
        mode: SwitchMode,
    ) -> Result<usize> {
        let drain = mode == SwitchMode::Drain;
        let frame = Frame::SetOp { op, drain, class };
        let stats = self.stats.clone();
        let mut sent = Vec::new();
        for (i, peer) in self.peers.iter_mut().enumerate() {
            let Some(stream) = peer.stream.as_mut() else { continue };
            match wire::write_frame(stream, &frame, &[]) {
                Ok(()) => sent.push(i),
                Err(_) => fail(peer, &stats),
            }
        }
        if sent.is_empty() {
            bail!("fleet: no live workers to switch");
        }
        if !drain {
            self.store_broadcast_op(class, op);
            obs::publish(ObsEvent::OpSwitch {
                op,
                mode: "immediate".to_string(),
                trigger: "fleet".to_string(),
                class: class.map(|c| c.to_string()),
            });
            return Ok(sent.len());
        }
        // collect one ack per worker *before* reporting any failure —
        // bailing mid-loop would leave the remaining workers' buffered
        // acks unread and desynchronize their request/response streams
        let mut acks = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        for i in sent {
            let peer = &mut self.peers[i];
            let Some(stream) = peer.stream.as_mut() else { continue };
            let t0 = Instant::now();
            match wire::read_frame(stream) {
                Ok((Frame::Ok, _)) => {
                    acks += 1;
                    stats.record_drain_wait(&peer.addr, t0.elapsed().as_micros() as u64);
                }
                Ok((other, _)) => {
                    // a worker that rejects (or mangles) the switch
                    // leaves the live set: keeping it serving a
                    // different OP than the rest of the fleet would be
                    // silently wrong
                    let msg = match other {
                        Frame::Err { message, .. } => message,
                        other => format!("unexpected {} to drain switch", other.type_name()),
                    };
                    fail(peer, &stats);
                    if first_err.is_none() {
                        first_err = Some(anyhow!("fleet worker {}: {msg}", peer.addr));
                    }
                }
                Err(_) => fail(peer, &stats),
            }
        }
        if let Some(e) = first_err {
            return Err(e.context("fleet drain switch rejected"));
        }
        if acks == 0 {
            bail!("fleet: every worker died during the drain switch");
        }
        self.store_broadcast_op(class, op);
        // published only after every surviving worker acked its
        // barrier, so recorded event order reflects the guarantee:
        // pre-switch FleetChunk events precede this, post-switch ones
        // follow it
        obs::publish(ObsEvent::OpSwitch {
            op,
            mode: "drain".to_string(),
            trigger: "fleet".to_string(),
            class: class.map(|c| c.to_string()),
        });
        Ok(acks)
    }

    /// Remember what the last switch broadcast so rejoin handshakes can
    /// replay it: a whole-fleet switch supersedes every per-class
    /// override, a class-scoped one layers on top.
    fn store_broadcast_op(&mut self, class: Option<usize>, op: usize) {
        match class {
            None => {
                self.current_op = Some(op);
                self.class_ops.clear();
            }
            Some(c) => {
                self.class_ops.insert(c, op);
            }
        }
    }

    /// Probe every live worker with a `Heartbeat` under `timeout`, then
    /// give each `Suspect` peer its second strike: a fresh handshake
    /// readmits it, a failed one evicts it.  Returns the live count
    /// afterwards.
    pub fn heartbeat(&mut self, timeout: Duration) -> usize {
        let stats = self.stats.clone();
        for peer in &mut self.peers {
            let Some(stream) = peer.stream.as_mut() else { continue };
            stream.set_read_timeout(Some(timeout)).ok();
            let ok = wire::write_frame(stream, &Frame::Heartbeat, &[]).is_ok()
                && matches!(wire::read_frame(stream), Ok((Frame::Pong { .. }, _)));
            if ok {
                stream.set_read_timeout(Some(self.io_timeout)).ok();
            } else {
                stats.record_hb_miss(&peer.addr);
                fail(peer, &stats);
            }
        }
        for i in 0..self.peers.len() {
            if self.peers[i].stream.is_some() {
                continue;
            }
            let addr = self.peers[i].addr.clone();
            if self.stats.state_of(&addr) != MemberState::Suspect {
                continue;
            }
            if self.readmit(i, timeout).is_err() {
                self.stats.report_failure(&addr);
            }
        }
        self.live_workers()
    }

    /// Fleet-wide barrier without a switch: every surviving worker acks
    /// once it has no forward in flight.  Returns the ack count.
    pub fn drain_fleet(&mut self) -> Result<usize> {
        let stats = self.stats.clone();
        let mut acks = 0usize;
        for peer in &mut self.peers {
            if peer.stream.is_none() {
                continue;
            }
            let t0 = Instant::now();
            match call(peer, &stats, &Frame::Drain, &[]) {
                Ok((Frame::Ok, _)) => {
                    acks += 1;
                    stats.record_drain_wait(&peer.addr, t0.elapsed().as_micros() as u64);
                }
                Ok((Frame::Err { message, .. }, _)) => {
                    bail!("fleet worker {} failed to drain: {message}", peer.addr)
                }
                Ok(_) | Err(_) => {} // handled by `call`
            }
        }
        Ok(acks)
    }

    /// Ask every live worker daemon to wind down; returns how many
    /// acked.  Used by operators tearing a fleet down from the
    /// coordinator side.
    pub fn shutdown_fleet(&mut self) -> usize {
        let stats = self.stats.clone();
        let mut acks = 0usize;
        for peer in &mut self.peers {
            if peer.stream.is_none() {
                continue;
            }
            if let Ok((Frame::Ok, _)) = call(peer, &stats, &Frame::Shutdown, &[]) {
                acks += 1;
            }
            peer.stream = None;
        }
        acks
    }

    /// Run one pipelined round: every live peer pumps chunks from the
    /// shared queue until it drains.  Returns every chunk with its
    /// outcome.
    #[allow(clippy::too_many_arguments)]
    fn scatter_round(
        peers: &mut [Peer],
        stats: &FleetStats,
        queue: &Mutex<VecDeque<Chunk>>,
        window: usize,
        fallback: usize,
        class: Option<usize>,
        op_idx: usize,
        images: &[f32],
        elems: usize,
    ) -> Vec<(Chunk, ChunkOutcome)> {
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for peer in peers.iter_mut() {
                if peer.stream.is_none() {
                    continue;
                }
                let stats = stats.clone();
                handles.push(s.spawn(move || {
                    peer_pump(peer, stats, queue, window, fallback, class, op_idx, images, elems)
                }));
            }
            handles.into_iter().flat_map(|h| h.join().expect("fleet peer thread")).collect()
        })
    }

    /// The shared body of [`Backend::forward`] and
    /// [`Backend::forward_class`]: scatter/gather with an optional
    /// tenant-class tag stamped onto every `Forward` frame.
    fn forward_tagged(
        &mut self,
        class: Option<usize>,
        op_idx: usize,
        images: &[f32],
        batch: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            batch > 0 && !images.is_empty() && images.len() % batch == 0,
            "bad fleet input: {} elems for batch {batch}",
            images.len()
        );
        let elems = images.len() / batch;
        self.refresh_peers();
        anyhow::ensure!(self.live_workers() > 0, "fleet forward: no live workers");
        let window = self.pipeline;
        let mut pending: VecDeque<Chunk> = VecDeque::new();
        pending.push_back(Chunk { start: 0, len: batch, attempts: 0 });
        let mut gathered: Vec<(usize, Vec<f32>)> = Vec::new();
        while !pending.is_empty() {
            let live = self.live_workers();
            if live == 0 {
                bail!(
                    "fleet forward: all workers lost with {} images still queued",
                    pending.iter().map(|c| c.len).sum::<usize>()
                );
            }
            let fallback = (batch / (live * window)).max(1);
            let queue = Mutex::new(std::mem::take(&mut pending));
            let outcomes = Self::scatter_round(
                &mut self.peers,
                &self.stats,
                &queue,
                window,
                fallback,
                class,
                op_idx,
                images,
                elems,
            );
            // spans no pump pulled (every peer died first) go back too
            pending = queue.into_inner().unwrap();
            for (chunk, outcome) in outcomes {
                match outcome {
                    ChunkOutcome::Logits(logits) => {
                        anyhow::ensure!(
                            logits.len() == chunk.len * self.classes,
                            "fleet worker returned {} logits for {} images",
                            logits.len(),
                            chunk.len
                        );
                        gathered.push((chunk.start, logits));
                    }
                    ChunkOutcome::App(message) => bail!("fleet forward failed: {message}"),
                    ChunkOutcome::Io => {
                        let attempts = chunk.attempts + 1;
                        if attempts > self.max_retries {
                            bail!(
                                "fleet forward: chunk of {} images failed {} times (retry budget {})",
                                chunk.len,
                                attempts,
                                self.max_retries
                            );
                        }
                        self.stats.record_requeue();
                        obs::publish(ObsEvent::Requeue { images: chunk.len, attempts });
                        pending.push_back(Chunk { attempts, ..chunk });
                    }
                }
            }
        }
        gathered.sort_by_key(|(start, _)| *start);
        let mut out = Vec::with_capacity(batch * self.classes);
        for (_, logits) in gathered {
            out.extend_from_slice(&logits);
        }
        anyhow::ensure!(
            out.len() == batch * self.classes,
            "fleet forward reassembled {} logits for batch {batch}",
            out.len()
        );
        Ok(out)
    }
}

impl Backend for FleetBackend {
    /// Broadcast the ladder to every worker (names + expected powers;
    /// each worker resolves the OPs from its local catalog and makes
    /// them resident).  A worker that *rejects* the ladder fails
    /// prepare — a fleet serving mismatched plans is a configuration
    /// error, not a failover case; workers that die leave the live
    /// set.  The ladder is kept for replay on every rejoin handshake.
    fn prepare(&mut self, ops: &[OperatingPoint]) -> Result<()> {
        anyhow::ensure!(!ops.is_empty(), "fleet prepare: empty ladder");
        let ladder: Vec<LadderRung> = ops
            .iter()
            .map(|o| LadderRung { name: o.name.clone(), power: o.relative_power })
            .collect();
        let frame = Frame::Prepare { ladder: ladder.clone() };
        let stats = self.stats.clone();
        let mut prepared = 0usize;
        for peer in &mut self.peers {
            if peer.stream.is_none() {
                continue;
            }
            match call(peer, &stats, &frame, &[]) {
                Ok((Frame::Ok, _)) => prepared += 1,
                Ok((Frame::Err { message, .. }, _)) => {
                    bail!("fleet worker {} rejected prepare: {message}", peer.addr)
                }
                Ok((other, _)) => bail!(
                    "fleet worker {}: unexpected {} to prepare",
                    peer.addr,
                    other.type_name()
                ),
                Err(_) => {} // handled by `call`
            }
        }
        anyhow::ensure!(prepared > 0, "fleet prepare: no live workers");
        self.ladder = Some(ladder);
        Ok(())
    }

    /// Scatter the batch across live workers (pipelined, latency-aware
    /// chunk sizing), gather logits in completion order, reassemble in
    /// submission order, rebalancing chunks from dead workers onto
    /// survivors (bounded retries per chunk).
    fn forward(&mut self, op_idx: usize, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        self.forward_tagged(None, op_idx, images, batch)
    }

    /// [`forward`](Backend::forward) with the tenant class stamped on
    /// every `Forward` frame, so worker-side gates account the chunk to
    /// that class and class-scoped drain barriers wait only on their
    /// own traffic.
    fn forward_class(
        &mut self,
        class: usize,
        op_idx: usize,
        images: &[f32],
        batch: usize,
    ) -> Result<Vec<f32>> {
        self.forward_tagged(Some(class), op_idx, images, batch)
    }

    fn name(&self) -> &str {
        "fleet"
    }

    fn num_classes(&self) -> usize {
        self.classes
    }
}

impl Drop for FleetBackend {
    fn drop(&mut self) {
        // orderly close: workers see EOF, not RST, on coordinator exit
        for peer in &mut self.peers {
            if let Some(s) = peer.stream.take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_chunk_carves_the_span_exactly_and_keeps_requeues_whole() {
        let queue = Mutex::new(VecDeque::from([Chunk { start: 0, len: 10, attempts: 0 }]));
        let a = take_chunk(&queue, 4).unwrap();
        assert_eq!(a, Chunk { start: 0, len: 4, attempts: 0 });
        let b = take_chunk(&queue, 100).unwrap(); // want > remaining: take all
        assert_eq!(b, Chunk { start: 4, len: 6, attempts: 0 });
        assert!(take_chunk(&queue, 1).is_none());

        // a requeued span keeps its identity (and attempts budget)
        let queue = Mutex::new(VecDeque::from([Chunk { start: 3, len: 9, attempts: 1 }]));
        let whole = take_chunk(&queue, 2).unwrap();
        assert_eq!(whole, Chunk { start: 3, len: 9, attempts: 1 });
        assert!(take_chunk(&queue, 1).is_none());
    }

    #[test]
    fn chunk_target_scales_inversely_with_observed_latency() {
        let q = CHUNK_QUANTUM_US;
        assert_eq!(chunk_target(q, 0.0, 8), 8); // no history: even share
        let fast = chunk_target(q, CHUNK_QUANTUM_US / 100.0, 8); // 100 img/quantum
        let slow = chunk_target(q, CHUNK_QUANTUM_US * 4.0, 8); // 4 quanta/img
        assert_eq!(fast, 100);
        assert_eq!(slow, 1); // clamped at one image
        assert!(fast > slow);
    }

    #[test]
    fn chunk_quantum_override_is_shared_and_resettable() {
        let stats = FleetStats::default();
        assert_eq!(stats.chunk_quantum_us(), CHUNK_QUANTUM_US);
        // a clone shares the registry, so the override reaches every
        // backend built from the same handle
        let sibling = stats.clone();
        stats.set_chunk_quantum_us(1_000.0);
        assert_eq!(sibling.chunk_quantum_us(), 1_000.0);
        // halving the quantum halves the chunk target at fixed EWMA
        assert_eq!(chunk_target(sibling.chunk_quantum_us(), 100.0, 8), 10);
        // degenerate targets clamp instead of collapsing to 1-image chunks
        stats.set_chunk_quantum_us(0.0);
        assert_eq!(sibling.chunk_quantum_us(), 100.0);
        stats.reset_chunk_quantum();
        assert_eq!(sibling.chunk_quantum_us(), CHUNK_QUANTUM_US);
    }

    #[test]
    fn membership_counts_one_eviction_per_epoch() {
        let stats = FleetStats::default();
        // first strike suspects, second evicts, further failures in the
        // same tick (heartbeat + forward both observing the death) are
        // absorbed without recounting
        assert_eq!(stats.report_failure("w"), MemberState::Suspect);
        assert_eq!(stats.report_failure("w"), MemberState::Evicted);
        assert_eq!(stats.report_failure("w"), MemberState::Evicted);
        assert_eq!(stats.snapshot().2, 1);

        // a re-probe in progress that fails falls back to Evicted
        stats.set_rejoining("w");
        assert_eq!(stats.state_of("w"), MemberState::Rejoining);
        assert_eq!(stats.report_failure("w"), MemberState::Evicted);
        assert_eq!(stats.snapshot().2, 1);

        // rejoin opens a new epoch whose eviction counts again
        stats.mark_live("w");
        let (workers, _, evictions) = stats.snapshot();
        let w = &workers.iter().find(|(a, _)| a == "w").unwrap().1;
        assert_eq!(w.state, MemberState::Live);
        assert_eq!(w.rejoins, 1);
        assert!(!w.evicted);
        assert_eq!(evictions, 1);
        assert_eq!(stats.report_failure("w"), MemberState::Suspect);
        assert_eq!(stats.report_failure("w"), MemberState::Evicted);
        assert_eq!(stats.snapshot().2, 2);
    }

    #[test]
    fn ewma_tracks_per_image_latency_and_survives_rejoin() {
        let stats = FleetStats::default();
        stats.record_success("w", 10, 10_000); // 1000 us/img
        assert!((stats.ewma_img_us("w") - 1000.0).abs() < 1e-9);
        stats.record_success("w", 10, 20_000); // 2000 us/img
        let blended = 0.7 * 1000.0 + 0.3 * 2000.0;
        assert!((stats.ewma_img_us("w") - blended).abs() < 1e-9);
        // eviction and rejoin keep the latency history
        stats.report_failure("w");
        stats.report_failure("w");
        stats.mark_live("w");
        assert!((stats.ewma_img_us("w") - blended).abs() < 1e-9);
        let (workers, _, _) = stats.snapshot();
        assert_eq!(workers[0].1.requests, 20);
    }
}
