//! Fleet wire protocol: length-prefixed frames over TCP, std-only.
//!
//! Every frame is `b"QFLT" | u32 header_len | header JSON | u32
//! payload_len | payload bytes`, little-endian lengths, payload a flat
//! f32 array (images or logits) — the same JSON-header-plus-raw-data
//! idiom as the QTEN tensor container (`util::tensorio`), reusing the
//! in-tree codec (`util::json`) so the protocol needs no new
//! dependencies.
//!
//! Control frames are strictly request/response: the coordinator
//! writes one frame and, when the frame type warrants a reply
//! ([`Frame::expects_reply`]), reads exactly one frame back.  The
//! single fire-and-forget frame is `SetOp { drain: false }` — the
//! paper's "lightweight switching" applied fleet-wide, where waiting
//! for acks would defeat the point of an urgent downgrade.  `Forward`
//! is the exception since the data plane became pipelined: it carries
//! a request `id`, the coordinator may have several Forwards in flight
//! per connection (up to the worker's advertised `max_inflight`), and
//! the worker echoes the id on the matching `Logits`/`Err` so replies
//! can arrive and be reassembled in completion order.  A worker that
//! omits `max_inflight` from its `HelloAck` is treated as strictly
//! request/response (`max_inflight = 1`), so old workers keep working.
//!
//! | frame       | direction     | payload  | reply                  |
//! |-------------|---------------|----------|------------------------|
//! | `Hello`     | coord → worker| —        | `HelloAck` / `Err`     |
//! | `Prepare`   | coord → worker| —        | `Ok` / `Err`           |
//! | `Forward`   | coord → worker| images   | `Logits` / `Err` (id-tagged, pipelined) |
//! | `SetOp`     | coord → worker| —        | `Ok` iff `drain`       |
//! | `Heartbeat` | coord → worker| —        | `Pong`                 |
//! | `Drain`     | coord → worker| —        | `Ok` (after barrier)   |
//! | `Shutdown`  | coord → worker| —        | `Ok` (then daemon exits)|
//! | `Register`  | worker → registry| —     | `Ok` / `Err`           |

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Wire-format version carried in `Hello`; a worker refuses a
/// coordinator from a different major version instead of mis-parsing
/// its frames.
pub const PROTOCOL_VERSION: u64 = 1;

/// Heartbeat cadence a worker advertises when none was configured, and
/// the value `HelloAck` parsing assumes for pre-advert workers that
/// omit the fields — matches the hard-coded probe the serve loop used
/// before the cadence became configurable.
pub const DEFAULT_HB_INTERVAL_MS: u64 = 1000;

/// Per-probe timeout companion to [`DEFAULT_HB_INTERVAL_MS`].
pub const DEFAULT_HB_TIMEOUT_MS: u64 = 500;

/// Per-frame magic, so a desynchronized stream fails loudly instead of
/// interpreting tensor bytes as a header length.
const MAGIC: &[u8; 4] = b"QFLT";

/// Sanity cap on the JSON header (a ladder of thousands of OPs fits in
/// a fraction of this).  Public so robustness tests can assert the
/// parser never allocates past it.
pub const MAX_HEADER_BYTES: usize = 1 << 20;

/// Sanity cap on the f32 payload: 256 Mi elements = 1 GiB, far above
/// any realistic batch, low enough to refuse garbage lengths.  Public
/// for the same reason as [`MAX_HEADER_BYTES`].
pub const MAX_PAYLOAD_BYTES: usize = 1 << 30;

/// One rung of the ladder as `Prepare` describes it: the OP name the
/// worker must resolve from its local catalog, plus the relative power
/// the coordinator expects (cross-checked worker-side, so a fleet never
/// silently serves mismatched plans).
#[derive(Debug, Clone, PartialEq)]
pub struct LadderRung {
    pub name: String,
    pub power: f64,
}

/// Every frame of the fleet protocol.  See the module table for
/// direction, payload and reply conventions.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Coordinator handshake; `version` must match [`PROTOCOL_VERSION`].
    Hello { version: u64 },
    /// Worker's handshake answer: identity, backend kind, the
    /// retraining-overlay mode its catalog was built with (`bn`,
    /// `full`, `none`; empty when not applicable, e.g. in-process test
    /// workers), classifier width, the OP names it can resolve in
    /// `Prepare`, and the heartbeat cadence the worker advertises —
    /// the probe interval it was launched with plus the per-probe
    /// timeout after which the coordinator should consider it dead.
    /// Coordinators take the fleet-wide minimum, so one short-leashed
    /// worker tightens eviction time for the whole deployment.
    /// `max_inflight` is the pipelining capability advert: how many
    /// id-tagged Forwards the worker accepts concurrently on one
    /// connection (legacy workers omit it and get 1 = lockstep).
    HelloAck {
        worker: String,
        backend: String,
        mode: String,
        classes: usize,
        catalog: Vec<String>,
        hb_interval_ms: u64,
        hb_timeout_ms: u64,
        max_inflight: u64,
    },
    /// Make this ladder resident (in order; `Forward::op` indexes it).
    Prepare { ladder: Vec<LadderRung> },
    /// Run one batch; payload = `[batch, H, W, C]` images flattened.
    /// `op` indexes the prepared ladder; `None` uses the worker's
    /// current OP (set by `SetOp`).  `id` is the pipelining request
    /// tag the worker echoes on the matching reply; `None` keeps the
    /// legacy strict request/response semantics.  `class` tags the
    /// batch with its tenant class so a per-class drain barrier counts
    /// only its own in-flight forwards; `None` = untagged
    /// (single-tenant, the legacy encoding).
    Forward { id: Option<u64>, op: Option<usize>, batch: usize, class: Option<usize> },
    /// `Forward` answer; payload = `[batch, classes]` logits flattened.
    /// `id` echoes the request tag when the Forward carried one.
    Logits { id: Option<u64>, classes: usize },
    /// Fleet-wide switch: `drain` = barrier (worker finishes in-flight
    /// forwards, applies, acks `Ok`); `!drain` = fire-and-forget store.
    /// `class` scopes a drain barrier to one tenant class's in-flight
    /// forwards, so a premium switch never stalls behind a best-effort
    /// drain; `None` keeps the fleet-wide (all-class) semantics.
    SetOp { op: usize, drain: bool, class: Option<usize> },
    /// Liveness probe.
    Heartbeat,
    /// `Heartbeat` answer with a peek at the worker's state.
    Pong { current_op: usize, served: u64 },
    /// Standalone barrier: ack once no forward is in flight.
    Drain,
    /// Stop the worker daemon (acked, then the process winds down).
    Shutdown,
    /// Worker → registry announcement: "admit `addr` into the fleet".
    /// Sent by `worker --join host:port` to a coordinator-side
    /// registry listener; acked `Ok` once recorded.
    Register { addr: String },
    /// Generic success ack.
    Ok,
    /// Generic failure answer; the connection stays usable.  `id`
    /// echoes the request tag when answering a pipelined `Forward`, so
    /// an application-level failure doesn't desynchronize the other
    /// in-flight requests on the connection.
    Err { id: Option<u64>, message: String },
}

impl Frame {
    /// Shorthand for an id-less [`Frame::Err`] (control-plane errors).
    pub fn err(message: impl Into<String>) -> Frame {
        Frame::Err { id: None, message: message.into() }
    }
}

impl Frame {
    /// The `type` tag this frame serializes under.
    pub fn type_name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::HelloAck { .. } => "hello_ack",
            Frame::Prepare { .. } => "prepare",
            Frame::Forward { .. } => "forward",
            Frame::Logits { .. } => "logits",
            Frame::SetOp { .. } => "set_op",
            Frame::Heartbeat => "heartbeat",
            Frame::Pong { .. } => "pong",
            Frame::Drain => "drain",
            Frame::Shutdown => "shutdown",
            Frame::Register { .. } => "register",
            Frame::Ok => "ok",
            Frame::Err { .. } => "err",
        }
    }

    /// Whether the sender should read a response frame after writing
    /// this one.  `SetOp { drain: false }` is the only fire-and-forget
    /// request; answer frames never expect replies themselves.
    pub fn expects_reply(&self) -> bool {
        match self {
            Frame::Hello { .. }
            | Frame::Prepare { .. }
            | Frame::Forward { .. }
            | Frame::Heartbeat
            | Frame::Drain
            | Frame::Shutdown
            | Frame::Register { .. } => true,
            Frame::SetOp { drain, .. } => *drain,
            Frame::HelloAck { .. }
            | Frame::Logits { .. }
            | Frame::Pong { .. }
            | Frame::Ok
            | Frame::Err { .. } => false,
        }
    }

    fn to_header(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("type", Json::str(self.type_name()))];
        match self {
            Frame::Hello { version } => {
                pairs.push(("version", Json::num(*version as f64)));
            }
            Frame::HelloAck {
                worker,
                backend,
                mode,
                classes,
                catalog,
                hb_interval_ms,
                hb_timeout_ms,
                max_inflight,
            } => {
                pairs.push(("worker", Json::str(worker.clone())));
                pairs.push(("backend", Json::str(backend.clone())));
                pairs.push(("mode", Json::str(mode.clone())));
                pairs.push(("classes", Json::num(*classes as f64)));
                pairs.push((
                    "catalog",
                    Json::Arr(catalog.iter().map(|n| Json::str(n.clone())).collect()),
                ));
                pairs.push(("hb_interval_ms", Json::num(*hb_interval_ms as f64)));
                pairs.push(("hb_timeout_ms", Json::num(*hb_timeout_ms as f64)));
                pairs.push(("max_inflight", Json::num(*max_inflight as f64)));
            }
            Frame::Prepare { ladder } => {
                let rungs: Vec<Json> = ladder
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("name", Json::str(r.name.clone())),
                            ("power", Json::num(r.power)),
                        ])
                    })
                    .collect();
                pairs.push(("ladder", Json::Arr(rungs)));
            }
            Frame::Forward { id, op, batch, class } => {
                if let Some(id) = id {
                    pairs.push(("id", Json::num(*id as f64)));
                }
                if let Some(op) = op {
                    pairs.push(("op", Json::num(*op as f64)));
                }
                pairs.push(("batch", Json::num(*batch as f64)));
                if let Some(class) = class {
                    pairs.push(("class", Json::num(*class as f64)));
                }
            }
            Frame::Logits { id, classes } => {
                if let Some(id) = id {
                    pairs.push(("id", Json::num(*id as f64)));
                }
                pairs.push(("classes", Json::num(*classes as f64)));
            }
            Frame::SetOp { op, drain, class } => {
                pairs.push(("op", Json::num(*op as f64)));
                pairs.push(("drain", Json::Bool(*drain)));
                if let Some(class) = class {
                    pairs.push(("class", Json::num(*class as f64)));
                }
            }
            Frame::Pong { current_op, served } => {
                pairs.push(("current_op", Json::num(*current_op as f64)));
                pairs.push(("served", Json::num(*served as f64)));
            }
            Frame::Register { addr } => {
                pairs.push(("addr", Json::str(addr.clone())));
            }
            Frame::Err { id, message } => {
                if let Some(id) = id {
                    pairs.push(("id", Json::num(*id as f64)));
                }
                pairs.push(("message", Json::str(message.clone())));
            }
            Frame::Heartbeat | Frame::Drain | Frame::Shutdown | Frame::Ok => {}
        }
        Json::obj(pairs)
    }

    fn from_header(v: &Json) -> Result<Frame> {
        let kind = v
            .get("type")
            .and_then(|t| t.as_str())
            .context("frame header has no type")?;
        let req_usize = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("{kind} frame: missing {key}"))
        };
        let opt_id = || v.get("id").and_then(|x| x.as_usize()).map(|x| x as u64);
        Ok(match kind {
            "hello" => Frame::Hello {
                version: req_usize("version")? as u64,
            },
            "hello_ack" => Frame::HelloAck {
                worker: v.get("worker").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                backend: v.get("backend").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                mode: v.get("mode").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                classes: req_usize("classes")?,
                catalog: v
                    .get("catalog")
                    .and_then(|x| x.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|n| n.as_str().map(str::to_string))
                    .collect(),
                // lenient: pre-heartbeat-advert workers omit these, so
                // fall back to the historical hard-coded cadence
                hb_interval_ms: v
                    .get("hb_interval_ms")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(DEFAULT_HB_INTERVAL_MS as usize) as u64,
                hb_timeout_ms: v
                    .get("hb_timeout_ms")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(DEFAULT_HB_TIMEOUT_MS as usize) as u64,
                // lenient: pre-pipelining workers omit the capability
                // advert and get strict request/response
                max_inflight: v
                    .get("max_inflight")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(1)
                    .max(1) as u64,
            },
            "prepare" => Frame::Prepare {
                ladder: v
                    .get("ladder")
                    .and_then(|x| x.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|r| LadderRung {
                        name: r.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                        power: r.get("power").and_then(|x| x.as_f64()).unwrap_or(1.0),
                    })
                    .collect(),
            },
            "forward" => Frame::Forward {
                id: opt_id(),
                op: v.get("op").and_then(|x| x.as_usize()),
                batch: req_usize("batch")?,
                // lenient: pre-tenancy coordinators omit the class tag
                class: v.get("class").and_then(|x| x.as_usize()),
            },
            "logits" => Frame::Logits {
                id: opt_id(),
                classes: req_usize("classes")?,
            },
            "set_op" => Frame::SetOp {
                op: req_usize("op")?,
                drain: v.get("drain").and_then(|x| x.as_bool()).unwrap_or(false),
                // lenient: pre-tenancy coordinators switch all classes
                class: v.get("class").and_then(|x| x.as_usize()),
            },
            "heartbeat" => Frame::Heartbeat,
            "pong" => Frame::Pong {
                current_op: req_usize("current_op")?,
                served: req_usize("served")? as u64,
            },
            "drain" => Frame::Drain,
            "shutdown" => Frame::Shutdown,
            "register" => Frame::Register {
                addr: v
                    .get("addr")
                    .and_then(|x| x.as_str())
                    .with_context(|| format!("{kind} frame: missing addr"))?
                    .to_string(),
            },
            "ok" => Frame::Ok,
            "err" => Frame::Err {
                id: opt_id(),
                message: v.get("message").and_then(|x| x.as_str()).unwrap_or("").to_string(),
            },
            other => bail!("unknown frame type {other:?}"),
        })
    }
}

/// f32 elements converted per `write_all` on the payload path: a stack
/// buffer of `4 * PAYLOAD_CHUNK_ELEMS` bytes per chunk, so large image
/// payloads never need a payload-sized intermediate allocation.
const PAYLOAD_CHUNK_ELEMS: usize = 2048;

/// Write one frame (header + f32 payload) and flush.  Lengths are
/// validated against the same caps the reader enforces, so an
/// oversized frame fails loudly sender-side instead of desynchronizing
/// the peer (and the `u32` length prefixes can never silently wrap).
pub fn write_frame(w: &mut impl Write, frame: &Frame, payload: &[f32]) -> Result<()> {
    let header = json::to_string(&frame.to_header());
    if header.len() > MAX_HEADER_BYTES {
        bail!("frame header of {} bytes exceeds the {MAX_HEADER_BYTES}-byte cap", header.len());
    }
    let payload_bytes = payload.len() * 4;
    if payload_bytes > MAX_PAYLOAD_BYTES {
        bail!("frame payload of {payload_bytes} bytes exceeds the {MAX_PAYLOAD_BYTES}-byte cap");
    }
    w.write_all(MAGIC)?;
    w.write_all(&(header.len() as u32).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    w.write_all(&(payload_bytes as u32).to_le_bytes())?;
    let mut buf = [0u8; 4 * PAYLOAD_CHUNK_ELEMS];
    for chunk in payload.chunks(PAYLOAD_CHUNK_ELEMS) {
        for (j, v) in chunk.iter().enumerate() {
            buf[j * 4..j * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf[..chunk.len() * 4])?;
    }
    w.flush()?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read one frame; validates magic and length sanity before allocating.
pub fn read_frame(r: &mut impl Read) -> Result<(Frame, Vec<f32>)> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("read frame magic")?;
    if &magic != MAGIC {
        bail!("bad frame magic {magic:?} (stream desynchronized?)");
    }
    let hlen = read_u32(r)? as usize;
    if hlen == 0 || hlen > MAX_HEADER_BYTES {
        bail!("frame header length {hlen} out of range");
    }
    let mut hbuf = vec![0u8; hlen];
    r.read_exact(&mut hbuf).context("read frame header")?;
    let header = json::parse(std::str::from_utf8(&hbuf)?).map_err(anyhow::Error::msg)?;
    let frame = Frame::from_header(&header)?;
    let plen = read_u32(r)? as usize;
    if plen % 4 != 0 || plen > MAX_PAYLOAD_BYTES {
        bail!("frame payload length {plen} invalid (must be 4-aligned, <= 1 GiB)");
    }
    let mut pbuf = vec![0u8; plen];
    r.read_exact(&mut pbuf).context("read frame payload")?;
    let payload = pbuf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((frame, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(frame: Frame, payload: &[f32]) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame, payload).unwrap();
        let (got, got_payload) = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(got, frame);
        assert_eq!(got_payload, payload);
    }

    #[test]
    fn every_frame_round_trips() {
        roundtrip(Frame::Hello { version: PROTOCOL_VERSION }, &[]);
        roundtrip(
            Frame::HelloAck {
                worker: "w0".into(),
                backend: "stub".into(),
                mode: "bn".into(),
                classes: 10,
                catalog: vec!["exact".into(), "op0".into()],
                hb_interval_ms: 250,
                hb_timeout_ms: 100,
                max_inflight: 64,
            },
            &[],
        );
        roundtrip(
            Frame::Prepare {
                ladder: vec![
                    LadderRung { name: "op0".into(), power: 0.85 },
                    LadderRung { name: "op1".into(), power: 0.57 },
                ],
            },
            &[],
        );
        roundtrip(
            Frame::Forward { id: Some(7), op: Some(1), batch: 2, class: None },
            &[1.0, -2.5, 0.0, 3e-9],
        );
        roundtrip(Frame::Forward { id: None, op: None, batch: 1, class: None }, &[0.5]);
        roundtrip(Frame::Forward { id: Some(9), op: Some(0), batch: 1, class: Some(1) }, &[0.5]);
        roundtrip(Frame::Logits { id: Some(7), classes: 2 }, &[0.1, 0.9]);
        roundtrip(Frame::Logits { id: None, classes: 2 }, &[0.1, 0.9]);
        roundtrip(Frame::SetOp { op: 1, drain: true, class: None }, &[]);
        roundtrip(Frame::SetOp { op: 0, drain: false, class: None }, &[]);
        roundtrip(Frame::SetOp { op: 2, drain: true, class: Some(0) }, &[]);
        roundtrip(Frame::Heartbeat, &[]);
        roundtrip(Frame::Pong { current_op: 2, served: 12345 }, &[]);
        roundtrip(Frame::Drain, &[]);
        roundtrip(Frame::Shutdown, &[]);
        roundtrip(Frame::Register { addr: "10.0.0.3:7070".into() }, &[]);
        roundtrip(Frame::Ok, &[]);
        roundtrip(Frame::err("no such op"), &[]);
        roundtrip(Frame::Err { id: Some(12), message: "forward blew up".into() }, &[]);
    }

    #[test]
    fn hello_ack_without_heartbeat_fields_gets_the_legacy_cadence() {
        // a pre-advert worker's HelloAck omits the hb_* fields; the
        // parser must fall back to the historical hard-coded cadence
        // rather than erroring or inventing zeros
        let header = r#"{"type":"hello_ack","worker":"old","backend":"stub","mode":"","classes":4,"catalog":["exact"]}"#;
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(header.len() as u32).to_le_bytes());
        buf.extend_from_slice(header.as_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        let (frame, _) = read_frame(&mut Cursor::new(&buf)).unwrap();
        match frame {
            Frame::HelloAck { hb_interval_ms, hb_timeout_ms, max_inflight, .. } => {
                assert_eq!(hb_interval_ms, DEFAULT_HB_INTERVAL_MS);
                assert_eq!(hb_timeout_ms, DEFAULT_HB_TIMEOUT_MS);
                // and no pipelining capability advert means lockstep
                assert_eq!(max_inflight, 1);
            }
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn consecutive_frames_share_a_stream() {
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &Frame::Forward { id: None, op: Some(0), batch: 1, class: None },
            &[7.0],
        )
        .unwrap();
        write_frame(&mut buf, &Frame::Heartbeat, &[]).unwrap();
        let mut cur = Cursor::new(&buf);
        let (f1, p1) = read_frame(&mut cur).unwrap();
        let (f2, p2) = read_frame(&mut cur).unwrap();
        assert_eq!(f1, Frame::Forward { id: None, op: Some(0), batch: 1, class: None });
        assert_eq!(p1, vec![7.0]);
        assert_eq!(f2, Frame::Heartbeat);
        assert!(p2.is_empty());
    }

    #[test]
    fn rejects_bad_magic_and_garbage_lengths() {
        let err = read_frame(&mut Cursor::new(b"NOPE\0\0\0\0")).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");

        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd header len
        let err = read_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn only_requests_expect_replies_and_immediate_setop_does_not() {
        assert!(Frame::Hello { version: 1 }.expects_reply());
        assert!(Frame::Forward { id: None, op: None, batch: 1, class: None }.expects_reply());
        assert!(Frame::SetOp { op: 0, drain: true, class: None }.expects_reply());
        assert!(Frame::SetOp { op: 0, drain: true, class: Some(1) }.expects_reply());
        assert!(Frame::Register { addr: "127.0.0.1:7070".into() }.expects_reply());
        assert!(!Frame::SetOp { op: 0, drain: false, class: None }.expects_reply());
        assert!(!Frame::Ok.expects_reply());
        assert!(!Frame::Logits { id: None, classes: 2 }.expects_reply());
    }
}
