//! Registry join path: how a fleet grows under load.
//!
//! The coordinator binds a [`FleetRegistry`] next to its serving loop;
//! a newly launched worker announces itself with `worker --join
//! host:port`, which sends one `Register { addr }` frame
//! ([`register_with`]) carrying the address the *worker* serves on.
//! The registry records the announcement and acks; the serving loop
//! drains [`FleetRegistry::take_new`] on its heartbeat ticks and feeds
//! the addresses into [`FleetBackend::admit`], which runs the normal
//! admission handshake (Hello/Prepare/SetOp) before the newcomer sees
//! any traffic.  Registration is deliberately one-shot and dumb — no
//! health state lives here; membership stays single-sourced in
//! [`FleetStats`].
//!
//! [`FleetBackend::admit`]: crate::fleet::FleetBackend::admit
//! [`FleetStats`]: crate::fleet::FleetStats

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::fleet::wire::{self, Frame};

/// Per-connection socket timeout: a registration is one small frame
/// each way, so anything slower is a stuck peer, not a slow one.
const REGISTER_TIMEOUT: Duration = Duration::from_secs(2);

/// Coordinator-side listener collecting `Register` announcements.
/// Dropping it stops the accept loop.
pub struct FleetRegistry {
    addr: SocketAddr,
    inner: Arc<RegistryInner>,
    accept: Option<std::thread::JoinHandle<()>>,
}

struct RegistryInner {
    stop: AtomicBool,
    pending: Mutex<Vec<String>>,
}

impl FleetRegistry {
    /// Bind the registry listener (e.g. `127.0.0.1:0` for an ephemeral
    /// port) and start accepting registrations in the background.
    pub fn bind(addr: &str) -> Result<FleetRegistry> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind fleet registry on {addr}"))?;
        let addr = listener.local_addr().context("fleet registry address")?;
        listener.set_nonblocking(true).context("fleet registry nonblocking")?;
        let inner = Arc::new(RegistryInner {
            stop: AtomicBool::new(false),
            pending: Mutex::new(Vec::new()),
        });
        let inner2 = inner.clone();
        let accept = std::thread::spawn(move || {
            while !inner2.stop.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((stream, _peer)) => handle_register(stream, &inner2),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(FleetRegistry { addr, inner, accept: Some(accept) })
    }

    /// The bound address (resolves `127.0.0.1:0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Drain the worker addresses that registered since the last call
    /// (deduplicated within one drain window).
    pub fn take_new(&self) -> Vec<String> {
        std::mem::take(&mut *self.inner.pending.lock().unwrap())
    }
}

impl Drop for FleetRegistry {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

/// One registration connection: read one frame, record, ack.
fn handle_register(mut stream: TcpStream, inner: &RegistryInner) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(REGISTER_TIMEOUT));
    let _ = stream.set_write_timeout(Some(REGISTER_TIMEOUT));
    let reply = match wire::read_frame(&mut stream) {
        Ok((Frame::Register { addr }, _)) => {
            let mut pending = inner.pending.lock().unwrap();
            if !pending.contains(&addr) {
                pending.push(addr);
            }
            Frame::Ok
        }
        Ok((other, _)) => Frame::err(format!(
            "fleet registry: unexpected {} frame (want register)",
            other.type_name()
        )),
        Err(_) => return,
    };
    let _ = wire::write_frame(&mut stream, &reply, &[]);
}

/// Worker-side client for `worker --join`: announce `advertise` (the
/// address this worker serves on) to the coordinator's registry.
pub fn register_with(registry: &str, advertise: &str) -> Result<()> {
    let mut stream = TcpStream::connect(registry)
        .with_context(|| format!("connect to fleet registry {registry}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(5))).ok();
    wire::write_frame(&mut stream, &Frame::Register { addr: advertise.to_string() }, &[])
        .with_context(|| format!("register with fleet registry {registry}"))?;
    match wire::read_frame(&mut stream)
        .with_context(|| format!("register ack from fleet registry {registry}"))?
    {
        (Frame::Ok, _) => Ok(()),
        (Frame::Err { message, .. }, _) => {
            anyhow::bail!("fleet registry {registry} refused registration: {message}")
        }
        (other, _) => {
            anyhow::bail!("fleet registry {registry}: unexpected {} to register", other.type_name())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_round_trip_collects_and_dedups_addresses() {
        let reg = FleetRegistry::bind("127.0.0.1:0").unwrap();
        let at = reg.addr().to_string();
        register_with(&at, "10.0.0.1:7070").unwrap();
        register_with(&at, "10.0.0.2:7070").unwrap();
        register_with(&at, "10.0.0.1:7070").unwrap(); // duplicate
        let mut got = reg.take_new();
        got.sort();
        assert_eq!(got, vec!["10.0.0.1:7070".to_string(), "10.0.0.2:7070".to_string()]);
        assert!(reg.take_new().is_empty());
    }

    #[test]
    fn registry_rejects_non_register_frames_with_a_clear_error() {
        let reg = FleetRegistry::bind("127.0.0.1:0").unwrap();
        let mut stream = TcpStream::connect(reg.addr()).unwrap();
        wire::write_frame(&mut stream, &Frame::Heartbeat, &[]).unwrap();
        let (reply, _) = wire::read_frame(&mut stream).unwrap();
        match reply {
            Frame::Err { message, .. } => assert!(message.contains("want register"), "{message}"),
            other => panic!("registry answered {other:?}"),
        }
    }
}
