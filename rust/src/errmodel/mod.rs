//! Error model (paper Fig. 1, from Trommer et al. [16]):
//! converts each approximate multiplier's LUT error map plus per-layer
//! operand statistics into an estimate of the layer-output error standard
//! deviation, in the same (post-BN) units as the AGN sigma_g.
//!
//! For multiplier j with error e_j(a, w) = lut_j[a, w] - a*w and layer k
//! with operand histograms pa_k, pw_k, fan-in K_k and scales s_a, s_w:
//!
//!   mean_j,k = `E[e_j]`            (under pa_k (x) pw_k)
//!   var_j,k  = E[e_j^2] - mean^2
//!   sigma_e[j, k] = sqrt(K_k * var_j,k) * s_a * s_w * bn_scale_k
//!
//! The paper ignores the error *mean* entirely (retraining compensates
//! it, Sec. 3.3).  Empirically that is only true for the *average* shift:
//! the input-dependent part of a biased multiplier's mean error (think
//! Mitchell's systematic underestimation) survives bias/BN compensation
//! and compounds across layers.  We therefore add a residual-bias term
//!
//!   sigma_eff^2 = K * var  +  (BIAS_RESIDUAL * K * |mean|)^2
//!
//! with BIAS_RESIDUAL = 0.1 (the fraction of the systematic shift that
//! varies with the input and thus cannot be folded into `b' = b - E[X]`).
//! Setting it to 0 recovers the paper's model exactly; the ablation bench
//! quantifies the difference.

use crate::muldb::MulDb;
use crate::nn::LayerStats;

/// Residual fraction of the systematic error mean that retraining cannot
/// compensate (input-dependent bias). 0 = the paper's variance-only model.
pub const BIAS_RESIDUAL: f64 = 0.1;

/// sigma_e estimates: `m x l` matrix, row per multiplier, column per layer.
#[derive(Debug, Clone)]
pub struct SigmaE {
    pub m: usize,
    pub l: usize,
    data: Vec<f64>,
}

impl SigmaE {
    #[inline]
    pub fn get(&self, mul: usize, layer: usize) -> f64 {
        self.data[mul * self.l + layer]
    }

    pub fn row(&self, mul: usize) -> &[f64] {
        &self.data[mul * self.l..(mul + 1) * self.l]
    }

    /// Column (one layer across all multipliers).
    pub fn column(&self, layer: usize) -> Vec<f64> {
        (0..self.m).map(|j| self.get(j, layer)).collect()
    }
}

/// First and second moments of one multiplier's error under a product
/// distribution given by two 256-bin histograms.
pub fn error_moments(lut: &[i32], pa: &[f64], pw: &[f64]) -> (f64, f64) {
    debug_assert_eq!(lut.len(), 65536);
    // marginalize over w first: for each a, E_w[e], E_w[e^2]
    let mut mean = 0.0f64;
    let mut second = 0.0f64;
    for a in 0..256usize {
        if pa[a] == 0.0 {
            continue;
        }
        let row = &lut[a * 256..(a + 1) * 256];
        let mut m1 = 0.0f64;
        let mut m2 = 0.0f64;
        for w in 0..256usize {
            if pw[w] == 0.0 {
                continue;
            }
            let e = row[w] as f64 - (a * w) as f64;
            m1 += pw[w] * e;
            m2 += pw[w] * e * e;
        }
        mean += pa[a] * m1;
        second += pa[a] * m2;
    }
    (mean, second)
}

/// Build the full sigma_e matrix (variance + residual-bias terms).
pub fn sigma_e(db: &MulDb, stats: &[LayerStats]) -> SigmaE {
    sigma_e_with_bias(db, stats, BIAS_RESIDUAL)
}

/// sigma_e with an explicit residual-bias coefficient (0 = paper model).
pub fn sigma_e_with_bias(db: &MulDb, stats: &[LayerStats], bias_residual: f64) -> SigmaE {
    let m = db.len();
    let l = stats.len();
    let mut data = vec![0.0f64; m * l];
    for (j, lut) in db.luts.iter().enumerate() {
        for (k, st) in stats.iter().enumerate() {
            let (mean, second) = error_moments(lut, &st.act_hist, &st.w_hist);
            let var = (second - mean * mean).max(0.0);
            let kf = st.k_fanin as f64;
            let bias_term = bias_residual * kf * mean.abs();
            let std_acc = (kf * var + bias_term * bias_term).sqrt();
            data[j * l + k] = std_acc * st.s_act * st.s_w * st.bn_scale;
        }
    }
    SigmaE { m, l, data }
}

/// Mean (systematic) component of the layer-output error, post-BN units —
/// used by diagnostics and the PNAM-style baselines.
pub fn error_mean(db: &MulDb, mul: usize, st: &LayerStats) -> f64 {
    let (mean, _) = error_moments(db.lut(mul), &st.act_hist, &st.w_hist);
    mean * st.k_fanin as f64 * st.s_act * st.s_w * st.bn_scale
}

/// Relative power of a full assignment (MAC-weighted; paper Sec. 4).
pub fn relative_power(db: &MulDb, stats: &[LayerStats], assignment: &[usize]) -> f64 {
    assert_eq!(stats.len(), assignment.len());
    let total: f64 = stats.iter().map(|s| s.macs_total as f64).sum();
    let weighted: f64 = stats
        .iter()
        .zip(assignment)
        .map(|(s, &mid)| s.macs_total as f64 * db.power(mid))
        .sum();
    weighted / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::muldb::MulDb;

    fn uniform_hist() -> Vec<f64> {
        vec![1.0 / 256.0; 256]
    }

    fn fake_stats(k_fanin: usize) -> LayerStats {
        LayerStats {
            name: "t".into(),
            act_hist: uniform_hist(),
            w_hist: uniform_hist(),
            k_fanin,
            macs_total: 1000,
            s_act: 0.01,
            z_act: 128,
            s_w: 0.02,
            z_w: 128,
            bn_scale: 1.0,
            out_rms: 1.0,
        }
    }

    #[test]
    fn exact_multiplier_has_zero_sigma() {
        let db = MulDb::generate();
        let stats = vec![fake_stats(100)];
        let se = sigma_e(&db, &stats);
        assert_eq!(se.get(0, 0), 0.0);
    }

    #[test]
    fn moments_match_muldb_stats_under_uniform() {
        let db = MulDb::generate();
        let (mean, second) = error_moments(db.lut(9), &uniform_hist(), &uniform_hist());
        let st = db.error_stats(9);
        assert!((mean - st.mean).abs() < 1e-6, "{mean} vs {}", st.mean);
        let var = second - mean * mean;
        assert!((var.sqrt() - st.std).abs() < 1e-6);
    }

    #[test]
    fn sigma_scales_with_sqrt_fanin() {
        // variance-only model (paper): std scales with sqrt(K)
        let db = MulDb::generate();
        let s1 = sigma_e_with_bias(&db, &[fake_stats(100)], 0.0);
        let s4 = sigma_e_with_bias(&db, &[fake_stats(400)], 0.0);
        for j in 1..db.len() {
            let ratio = s4.get(j, 0) / s1.get(j, 0).max(1e-30);
            assert!((ratio - 2.0).abs() < 1e-9, "mul {j}: ratio {ratio}");
        }
    }

    #[test]
    fn bias_term_penalizes_biased_multipliers() {
        let db = MulDb::generate();
        let stats = vec![fake_stats(576)];
        let paper = sigma_e_with_bias(&db, &stats, 0.0);
        let ours = sigma_e(&db, &stats);
        // mitch7 (mean -606) must be penalized much harder than bamc5
        // (mean -0.25) by the residual-bias term
        let mitch = db.by_name("am8u_mitch7").unwrap().id;
        let bamc = db.by_name("am8u_bamc5").unwrap().id;
        let mitch_ratio = ours.get(mitch, 0) / paper.get(mitch, 0);
        let bamc_ratio = ours.get(bamc, 0) / paper.get(bamc, 0);
        assert!(mitch_ratio > 2.0, "mitch ratio {mitch_ratio}");
        assert!(bamc_ratio < 1.05, "bamc ratio {bamc_ratio}");
    }

    #[test]
    fn relative_power_exact_is_one() {
        let db = MulDb::generate();
        let stats = vec![fake_stats(10), fake_stats(20)];
        assert!((relative_power(&db, &stats, &[0, 0]) - 1.0).abs() < 1e-12);
        let p = relative_power(&db, &stats, &[4, 4]); // trunc4 = 0.25
        assert!((p - 0.25).abs() < 1e-12);
    }
}
