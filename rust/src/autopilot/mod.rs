//! SLO autopilot: one closed-loop controller over the three levers the
//! repo previously drove independently — the QoS operating-point ladder
//! (accuracy ↔ power), the elastic worker pool (capacity ↔ power), and
//! the fleet chunk plan (interleaving granularity ↔ tail latency).
//!
//! The paper's QoS story is precisely that a platform with multiple
//! operating points can trade accuracy for resources *under pressure*;
//! this module closes that loop.  Each control tick the [`Autopilot`]
//! consumes a windowed p95 latency (from `ServerMetrics::snapshot()`
//! deltas), the environmental power budget (`qos::envsim`), an operator
//! power envelope, and the pool/fleet state, and emits at most one
//! action per axis plus a [`Decision`] record for the audit log.
//!
//! ## Precedence
//!
//! 1. **Power first.**  The effective budget handed to the wrapped
//!    [`QosController`] is `min(env budget, power envelope)` — power
//!    constraints always bind, and budget-driven downgrades keep their
//!    `Immediate` urgency.
//! 2. **Shed accuracy before shedding latency.**  Under latency
//!    pressure (windowed p95 above `pressure_frac * slo`), the
//!    autopilot first grows the worker pool if the ceiling allows
//!    (capacity costs no accuracy), then pushes its *latency cap* one
//!    rung toward frugal ([`QosController::observe_capped`]) so the SLO
//!    is defended by degrading accuracy, not by violating latency.
//!    With a fleet attached it also narrows the chunk quantum for finer
//!    interleaving.
//! 3. **Recover accuracy only after sustained headroom.**  Only after
//!    `recover_after` *consecutive* clear ticks (p95 under
//!    `clear_frac * slo`) does the cap relax one rung — the upgrade
//!    then rides the normal draining switch path — and only after the
//!    longer `pool_recover_after` streak does the pool shrink.
//! 4. **Hysteresis everywhere.**  Per-axis cooldowns pace consecutive
//!    actions, the pressure/clear thresholds are deliberately apart
//!    (`clear_frac < pressure_frac`), and the wrapped controller keeps
//!    its own upgrade margin + dwell — so OP and pool decisions cannot
//!    flap against each other under an oscillating budget.
//!
//! The autopilot never touches a server directly: `tick` returns a
//! [`TickOutcome`] and the caller (`serve --autopilot`, the bench
//! driver) actuates the switch through the existing fleet-first
//! broadcast + `set_operating_point_with` path, the pool target through
//! `Server::set_pool_target`, and the chunk quantum through
//! `FleetStats::set_chunk_quantum_us` — Drain/Immediate semantics and
//! the supervisor's thread ownership are preserved unchanged.

use std::time::Instant;

use crate::fleet::CHUNK_QUANTUM_US;
use crate::qos::{LadderEntry, QosConfig, QosController, SwitchMode};
use crate::util::json::Json;

/// Knobs for [`Autopilot`].  The defaults assume the control tick is
/// the bench interval (~500 ms) and a log2-bucketed p95, whose readings
/// double between rungs — hence a `pressure_frac` well below 1.0, so
/// the shed fires one bucket *before* the SLO bucket is reached.
#[derive(Debug, Clone)]
pub struct AutopilotConfig {
    /// The latency SLO: windowed p95 must stay at or under this.
    pub slo_p95_ms: f64,
    /// Operator power envelope (relative multiplication power, 0..=1);
    /// 1.0 = only the environmental budget binds.
    pub power_envelope: f64,
    /// p95 above `pressure_frac * slo_p95_ms` = latency pressure.
    pub pressure_frac: f64,
    /// p95 at or under `clear_frac * slo_p95_ms` = headroom tick.
    pub clear_frac: f64,
    /// Minimum samples in the p95 window before it is trusted (an
    /// almost-empty window's p95 is one batch's noise).
    pub min_window: u64,
    /// Consecutive headroom ticks before one accuracy-recovery step.
    pub recover_after: u32,
    /// Consecutive headroom ticks before the pool shrinks (longer than
    /// `recover_after`: accuracy recovers first, capacity leaves last).
    pub pool_recover_after: u32,
    /// Ticks between consecutive actions on the same axis.
    pub cooldown_ticks: u32,
    /// Chunk quantum while narrowed, microseconds.
    pub chunk_narrow_us: f64,
}

impl Default for AutopilotConfig {
    fn default() -> Self {
        AutopilotConfig {
            slo_p95_ms: 100.0,
            power_envelope: 1.0,
            pressure_frac: 0.5,
            clear_frac: 0.4,
            min_window: 16,
            recover_after: 4,
            pool_recover_after: 10,
            cooldown_ticks: 2,
            chunk_narrow_us: CHUNK_QUANTUM_US / 2.0,
        }
    }
}

/// Everything the autopilot observes on one control tick.
#[derive(Debug, Clone, Copy)]
pub struct TickInputs {
    /// Wall-clock offset of this tick, seconds (stamped into the log).
    pub t_s: f64,
    /// Windowed p95 end-to-end latency, milliseconds (0 when the
    /// window is empty).
    pub p95_ms: f64,
    /// Requests completed inside the window.
    pub window: u64,
    /// Environmental power budget (envsim governor or scripted trace).
    pub env_budget: f64,
    /// Workers currently live / pool bounds.
    pub live_workers: usize,
    pub min_workers: usize,
    pub max_workers: usize,
    /// Whether a fleet chunk planner is attached (enables chunk
    /// actions).
    pub has_fleet: bool,
}

/// Which constraint drove this tick's decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    /// The power budget/envelope limits the OP below the latency cap.
    Power,
    /// Latency pressure (p95 approaching the SLO) drove the tick.
    Latency,
    /// Sustained headroom drove a recovery action.
    Headroom,
    /// Nothing bound; steady state.
    None,
}

/// Operating-point action taken this tick (as seen on the ladder:
/// `Down` = toward frugal, `Up` = toward accurate).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpAction {
    None,
    Down,
    Up,
}

/// Worker-pool action taken this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolAction {
    None,
    Grow,
    Shrink,
}

/// Fleet chunk-plan action taken this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkAction {
    None,
    Narrow,
    Widen,
}

impl Bound {
    pub fn as_str(self) -> &'static str {
        match self {
            Bound::Power => "power",
            Bound::Latency => "latency",
            Bound::Headroom => "headroom",
            Bound::None => "none",
        }
    }

    pub fn parse(s: &str) -> Option<Bound> {
        Some(match s {
            "power" => Bound::Power,
            "latency" => Bound::Latency,
            "headroom" => Bound::Headroom,
            "none" => Bound::None,
            _ => return None,
        })
    }
}

impl OpAction {
    pub fn as_str(self) -> &'static str {
        match self {
            OpAction::None => "none",
            OpAction::Down => "op_down",
            OpAction::Up => "op_up",
        }
    }

    pub fn parse(s: &str) -> Option<OpAction> {
        Some(match s {
            "none" => OpAction::None,
            "op_down" => OpAction::Down,
            "op_up" => OpAction::Up,
            _ => return None,
        })
    }
}

impl PoolAction {
    pub fn as_str(self) -> &'static str {
        match self {
            PoolAction::None => "none",
            PoolAction::Grow => "pool_grow",
            PoolAction::Shrink => "pool_shrink",
        }
    }

    pub fn parse(s: &str) -> Option<PoolAction> {
        Some(match s {
            "none" => PoolAction::None,
            "pool_grow" => PoolAction::Grow,
            "pool_shrink" => PoolAction::Shrink,
            _ => return None,
        })
    }
}

impl ChunkAction {
    pub fn as_str(self) -> &'static str {
        match self {
            ChunkAction::None => "none",
            ChunkAction::Narrow => "chunk_narrow",
            ChunkAction::Widen => "chunk_widen",
        }
    }

    pub fn parse(s: &str) -> Option<ChunkAction> {
        Some(match s {
            "none" => ChunkAction::None,
            "chunk_narrow" => ChunkAction::Narrow,
            "chunk_widen" => ChunkAction::Widen,
            _ => return None,
        })
    }
}

/// One line of the autopilot's audit log: what it saw, what it did,
/// and which constraint bound.  Serialized into the bench report's
/// `autopilot.decisions` timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    /// Tick timestamp, seconds from run start.
    pub t_s: f64,
    /// Windowed p95 observed this tick, milliseconds.
    pub p95_ms: f64,
    /// Relative power of the OP in force *after* the tick.
    pub power: f64,
    /// Effective power budget (min of env budget and envelope).
    pub budget: f64,
    /// `OpTable` index in force after the tick.
    pub op: usize,
    /// Live workers observed at the tick.
    pub workers: usize,
    pub op_action: OpAction,
    pub pool_action: PoolAction,
    pub chunk_action: ChunkAction,
    pub bound: Bound,
    /// The tick wanted to shed further but the rung cap already pinned
    /// the ladder at its frugal floor — the saturation the cap would
    /// otherwise swallow silently (see
    /// [`QosController::observe_with_mode_capped_signal`]).
    pub cap_saturated: bool,
    /// Tenant class this decision steered (`None` single-tenant).
    pub class: Option<String>,
}

impl Decision {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("t_s", Json::num(self.t_s)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("power", Json::num(self.power)),
            ("budget", Json::num(self.budget)),
            ("op", Json::num(self.op as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("op_action", Json::str(self.op_action.as_str())),
            ("pool_action", Json::str(self.pool_action.as_str())),
            ("chunk_action", Json::str(self.chunk_action.as_str())),
            ("bound", Json::str(self.bound.as_str())),
        ];
        // omitted when default, so pre-tenancy decision logs (and the
        // committed bench baselines embedding them) stay byte-identical
        if self.cap_saturated {
            fields.push(("cap_saturated", Json::Bool(true)));
        }
        if let Some(class) = &self.class {
            fields.push(("class", Json::str(class)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Decision, String> {
        let field = |k: &str| -> Result<f64, String> {
            j.req(k)?.as_f64().ok_or_else(|| format!("decision.{k}: not a number"))
        };
        let tag = |k: &str| -> Result<String, String> {
            Ok(j.req(k)?
                .as_str()
                .ok_or_else(|| format!("decision.{k}: not a string"))?
                .to_string())
        };
        Ok(Decision {
            t_s: field("t_s")?,
            p95_ms: field("p95_ms")?,
            power: field("power")?,
            budget: field("budget")?,
            op: field("op")? as usize,
            workers: field("workers")? as usize,
            op_action: OpAction::parse(&tag("op_action")?)
                .ok_or_else(|| "decision.op_action: unknown tag".to_string())?,
            pool_action: PoolAction::parse(&tag("pool_action")?)
                .ok_or_else(|| "decision.pool_action: unknown tag".to_string())?,
            chunk_action: ChunkAction::parse(&tag("chunk_action")?)
                .ok_or_else(|| "decision.chunk_action: unknown tag".to_string())?,
            bound: Bound::parse(&tag("bound")?)
                .ok_or_else(|| "decision.bound: unknown tag".to_string())?,
            // lenient: pre-tenancy logs carry neither key
            cap_saturated: j.get("cap_saturated").and_then(|x| x.as_bool()).unwrap_or(false),
            class: j.get("class").and_then(|x| x.as_str()).map(str::to_string),
        })
    }
}

/// What the caller must actuate after one [`Autopilot::tick`].
#[derive(Debug, Clone)]
pub struct TickOutcome {
    /// OP switch to apply (table index + mode), through the usual
    /// fleet-first broadcast then `set_operating_point_with`.
    pub switch: Option<(usize, SwitchMode)>,
    /// New explicit worker-pool target (`Server::set_pool_target`).
    pub pool_target: Option<usize>,
    /// New fleet chunk quantum (`FleetStats::set_chunk_quantum_us`).
    pub chunk_quantum_us: Option<f64>,
    /// Audit-log record for this tick.
    pub decision: Decision,
}

/// The closed-loop controller; see the module docs for the precedence
/// rules.  Wraps a [`QosController`] so budget hysteresis, dwell and
/// Drain/Immediate mode selection stay exactly the serving stack's.
#[derive(Debug)]
pub struct Autopilot {
    cfg: AutopilotConfig,
    controller: QosController,
    /// Latency cap: sorted-ladder position the controller may not rise
    /// above (0 = uncapped).  Latency pressure pushes it toward frugal;
    /// sustained headroom relaxes it back.
    lat_cap: usize,
    /// Consecutive clear (headroom) ticks.
    headroom_ticks: u32,
    op_cooldown: u32,
    pool_cooldown: u32,
    chunk_cooldown: u32,
    chunk_narrowed: bool,
    /// Tenant class label stamped into decisions and events (`None`
    /// single-tenant — see [`Autopilot::with_class`]).
    class: Option<String>,
    /// Control ticks run.
    pub ticks: u64,
    /// Ticks whose observed p95 exceeded the SLO.
    pub slo_violations: u64,
    /// Pressured ticks that wanted to shed further but found the rung
    /// cap already pinned at the frugal floor (satellite signal of
    /// [`QosController::observe_with_mode_capped_signal`]): demand the
    /// ladder could not absorb.
    pub cap_saturated_ticks: u64,
}

impl Autopilot {
    /// Build over a ladder (e.g. `OpTable::ladder()`); `qos` carries
    /// the deployment's usual hysteresis knobs into the wrapped
    /// controller.
    pub fn new(ladder: Vec<LadderEntry>, qos: QosConfig, cfg: AutopilotConfig) -> Self {
        Autopilot {
            cfg,
            controller: QosController::new(ladder, qos),
            lat_cap: 0,
            headroom_ticks: 0,
            op_cooldown: 0,
            pool_cooldown: 0,
            chunk_cooldown: 0,
            chunk_narrowed: false,
            class: None,
            ticks: 0,
            slo_violations: 0,
            cap_saturated_ticks: 0,
        }
    }

    /// Tag this pilot with a tenant class: its decisions and published
    /// events carry the label (multi-tenant deployments run one pilot
    /// per class — see [`MultiAutopilot`]).
    pub fn with_class(mut self, class: impl Into<String>) -> Self {
        self.class = Some(class.into());
        self
    }

    /// The wrapped controller (switch/violation counters, ladder).
    pub fn controller(&self) -> &QosController {
        &self.controller
    }

    /// Current latency cap (sorted-ladder position; 0 = uncapped).
    pub fn lat_cap(&self) -> usize {
        self.lat_cap
    }

    /// The configuration in force.
    pub fn config(&self) -> &AutopilotConfig {
        &self.cfg
    }

    /// Whether `p95_ms` violates the SLO.
    pub fn violates_slo(&self, p95_ms: f64) -> bool {
        p95_ms > self.cfg.slo_p95_ms
    }

    /// One control tick; pure with respect to the serving stack — the
    /// caller actuates the returned [`TickOutcome`].
    pub fn tick(&mut self, inp: &TickInputs, now: Instant) -> TickOutcome {
        self.ticks += 1;
        self.op_cooldown = self.op_cooldown.saturating_sub(1);
        self.pool_cooldown = self.pool_cooldown.saturating_sub(1);
        self.chunk_cooldown = self.chunk_cooldown.saturating_sub(1);

        let slo = self.cfg.slo_p95_ms;
        let have_signal = inp.window >= self.cfg.min_window;
        let pressured = have_signal && inp.p95_ms > self.cfg.pressure_frac * slo;
        // an empty window is headroom (nothing in flight can miss the
        // SLO); a sub-min_window one is ambiguous and holds the line
        let clear = inp.window == 0 || (have_signal && inp.p95_ms <= self.cfg.clear_frac * slo);
        if have_signal && inp.p95_ms > slo {
            self.slo_violations += 1;
        }
        if clear {
            self.headroom_ticks += 1;
        } else {
            self.headroom_ticks = 0;
        }

        let n_rungs = self.controller.ladder().len();
        let mut pool_action = PoolAction::None;
        let mut pool_target = None;
        let mut chunk_action = ChunkAction::None;
        let mut chunk_quantum_us = None;
        let mut recovery = false;

        if pressured {
            // capacity before accuracy: a bigger pool sheds latency
            // without spending accuracy; only when the ceiling is
            // reached does the OP ladder give ground
            if inp.live_workers < inp.max_workers && self.pool_cooldown == 0 {
                pool_action = PoolAction::Grow;
                pool_target = Some(inp.live_workers + 1);
                self.pool_cooldown = self.cfg.cooldown_ticks;
            } else {
                // cap one rung past wherever the controller actually is
                // (the budget may already hold it below the cap — a
                // cap-relative step would burn a tick on a no-op)
                let shed_to = (self.controller.current() + 1).min(n_rungs - 1);
                if shed_to > self.lat_cap && self.op_cooldown == 0 {
                    self.lat_cap = shed_to;
                    self.op_cooldown = self.cfg.cooldown_ticks;
                }
            }
            // finer interleaving is accuracy-free: narrow alongside
            // whichever lever moved
            if inp.has_fleet && !self.chunk_narrowed && self.chunk_cooldown == 0 {
                chunk_action = ChunkAction::Narrow;
                chunk_quantum_us = Some(self.cfg.chunk_narrow_us);
                self.chunk_narrowed = true;
                self.chunk_cooldown = self.cfg.cooldown_ticks;
            }
        } else if self.headroom_ticks >= self.cfg.recover_after {
            // recovery, most valuable lever first: accuracy, then chunk
            // plan, then (after the longer streak) capacity — one axis
            // per tick, each restart of the streak re-earned
            if self.lat_cap > 0 && self.op_cooldown == 0 {
                self.lat_cap -= 1;
                self.op_cooldown = self.cfg.cooldown_ticks;
                self.headroom_ticks = 0;
                recovery = true;
            } else if inp.has_fleet && self.chunk_narrowed && self.chunk_cooldown == 0 {
                chunk_action = ChunkAction::Widen;
                chunk_quantum_us = Some(CHUNK_QUANTUM_US);
                self.chunk_narrowed = false;
                self.chunk_cooldown = self.cfg.cooldown_ticks;
                recovery = true;
            } else if self.headroom_ticks >= self.cfg.pool_recover_after
                && inp.live_workers > inp.min_workers
                && self.pool_cooldown == 0
            {
                pool_action = PoolAction::Shrink;
                pool_target = Some(inp.live_workers - 1);
                self.pool_cooldown = self.cfg.cooldown_ticks;
                self.headroom_ticks = 0;
                recovery = true;
            }
        }

        // power precedence: the real (env ∧ envelope) budget flows to
        // the wrapped controller unchanged, the latency cap rides along
        // as a floor on frugality — so budget-driven downgrades stay
        // Immediate and upgrade hysteresis works on genuine recovery
        let power_limit = inp.env_budget.min(self.cfg.power_envelope);
        let before = self.controller.current();
        let (switch, saturated) =
            self.controller.observe_with_mode_capped_signal(power_limit, self.lat_cap, now);
        // only a *pressured* saturated tick counts: the tick wanted to
        // shed further and the floor-pinned cap swallowed the step
        let cap_saturated = pressured && saturated;
        if cap_saturated {
            self.cap_saturated_ticks += 1;
        }
        let after = self.controller.current();
        let op_action = match after.cmp(&before) {
            std::cmp::Ordering::Greater => OpAction::Down,
            std::cmp::Ordering::Less => OpAction::Up,
            std::cmp::Ordering::Equal => OpAction::None,
        };

        let lat_cap_power = self.controller.ladder()[self.lat_cap].power;
        let bound = if pressured {
            Bound::Latency
        } else if recovery || op_action == OpAction::Up {
            Bound::Headroom
        } else if power_limit < lat_cap_power {
            Bound::Power
        } else {
            Bound::None
        };

        let decision = Decision {
            t_s: inp.t_s,
            p95_ms: inp.p95_ms,
            power: self.controller.current_entry().power,
            budget: power_limit,
            op: self.controller.current_table_index(),
            workers: inp.live_workers,
            op_action,
            pool_action,
            chunk_action,
            bound,
            cap_saturated,
            class: self.class.clone(),
        };
        crate::obs::publish(crate::obs::ObsEvent::AutopilotDecision {
            t_s: decision.t_s,
            p95_ms: decision.p95_ms,
            op: decision.op,
            workers: decision.workers,
            op_action: decision.op_action.as_str().to_string(),
            pool_action: decision.pool_action.as_str().to_string(),
            chunk_action: decision.chunk_action.as_str().to_string(),
            bound: decision.bound.as_str().to_string(),
            class: decision.class.clone(),
        });
        TickOutcome { switch, pool_target, chunk_quantum_us, decision }
    }
}

/// Per-class autopilots steering one shared power envelope with strict
/// priority.  Class 0 (premium) is allocated first: each pilot in id
/// order sees the envelope *remaining* after every higher-priority
/// class's chosen rung was charged at that class's traffic weight —
/// so when the shared budget tightens, the best-effort pilots inherit
/// the squeeze and shed first while premium sheds last.  With a single
/// class of weight 1 the allocation is the identity and every decision
/// matches the bare [`Autopilot`] bit for bit.
#[derive(Debug)]
pub struct MultiAutopilot {
    pilots: Vec<Autopilot>,
    /// Normalized traffic weight per class (what fraction of the
    /// deployment's multiplication power the class's rung choice
    /// charges against the shared envelope).
    weights: Vec<f64>,
}

impl MultiAutopilot {
    /// `pilots` in class-id (premium-first) order; `weights` are
    /// normalized to sum 1 (uniform when empty or non-positive).
    pub fn new(pilots: Vec<Autopilot>, weights: Vec<f64>) -> Self {
        let n = pilots.len().max(1);
        let mut weights = if weights.len() == pilots.len() {
            weights
        } else {
            vec![1.0; pilots.len()]
        };
        let sum: f64 = weights.iter().copied().filter(|w| *w > 0.0).sum();
        if sum > 0.0 {
            for w in &mut weights {
                *w = w.max(0.0) / sum;
            }
        } else {
            weights = vec![1.0 / n as f64; pilots.len()];
        }
        MultiAutopilot { pilots, weights }
    }

    pub fn len(&self) -> usize {
        self.pilots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pilots.is_empty()
    }

    /// The per-class pilots, in class-id order.
    pub fn pilots(&self) -> &[Autopilot] {
        &self.pilots
    }

    /// One control tick for every class, premium first.  `inputs[c]`
    /// carries class `c`'s own latency window; its `env_budget` is the
    /// *shared* environmental budget, which this allocator narrows to
    /// the class's slice before the pilot sees it.
    pub fn tick(&mut self, inputs: &[TickInputs], now: Instant) -> Vec<TickOutcome> {
        assert_eq!(inputs.len(), self.pilots.len());
        // the shared envelope: every class observes the same env budget
        let mut remaining = inputs.first().map(|i| i.env_budget).unwrap_or(1.0);
        let mut out = Vec::with_capacity(self.pilots.len());
        for (c, pilot) in self.pilots.iter_mut().enumerate() {
            let w = self.weights[c];
            // a class may spend up to the leftover envelope scaled by
            // its weight (a light class's rung barely dents the total,
            // so its effective budget saturates at 1.0)
            let eff = if w > 0.0 { (remaining / w).clamp(0.0, 1.0) } else { 1.0 };
            let inp = TickInputs { env_budget: inputs[c].env_budget.min(eff), ..inputs[c] };
            let outcome = pilot.tick(&inp, now);
            // charge the chosen rung before the next (lower-priority)
            // class is allocated
            remaining = (remaining - w * outcome.decision.power).max(0.0);
            out.push(outcome);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn ladder() -> Vec<LadderEntry> {
        vec![
            LadderEntry { name: "exact".into(), power: 1.0, table_index: 0 },
            LadderEntry { name: "mid".into(), power: 0.8, table_index: 1 },
            LadderEntry { name: "frugal".into(), power: 0.6, table_index: 2 },
        ]
    }

    // zero margin so a full budget can reach the power-1.0 top rung
    // (the pre-existing margin quirk is covered in qos::tests)
    fn qos() -> QosConfig {
        QosConfig { upgrade_margin: 0.0, min_dwell: Duration::ZERO }
    }

    fn pilot(cfg: AutopilotConfig) -> Autopilot {
        Autopilot::new(ladder(), qos(), cfg)
    }

    /// Inputs for a fixed 2-worker pool with a trusted latency window.
    fn inputs(t_s: f64, p95_ms: f64, env_budget: f64) -> TickInputs {
        TickInputs {
            t_s,
            p95_ms,
            window: 100,
            env_budget,
            live_workers: 2,
            min_workers: 2,
            max_workers: 2,
            has_fleet: false,
        }
    }

    #[test]
    fn power_bound_tick_downgrades_immediately_and_logs_power() {
        let mut p = pilot(AutopilotConfig { slo_p95_ms: 100.0, ..Default::default() });
        let t = Instant::now();
        // settle at the top: ample budget, low latency
        let o = p.tick(&inputs(0.0, 20.0, 1.0), t);
        assert_eq!(o.switch, Some((0, SwitchMode::Drain)));
        // budget collapse with latency still fine: power binds, the
        // downgrade is Immediate, and no pool/chunk action fires
        let o = p.tick(&inputs(0.5, 20.0, 0.7), t);
        assert_eq!(o.switch, Some((2, SwitchMode::Immediate)));
        assert_eq!(o.decision.bound, Bound::Power);
        assert_eq!(o.decision.op_action, OpAction::Down);
        assert_eq!(o.decision.pool_action, PoolAction::None);
        assert_eq!(o.pool_target, None);
        assert_eq!(p.lat_cap(), 0, "power pressure must not move the latency cap");
    }

    #[test]
    fn envelope_caps_the_op_even_with_full_env_budget() {
        let cfg = AutopilotConfig {
            slo_p95_ms: 100.0,
            power_envelope: 0.9,
            ..Default::default()
        };
        let mut p = pilot(cfg);
        let t = Instant::now();
        let o = p.tick(&inputs(0.0, 20.0, 1.0), t);
        // min(1.0, 0.9) = 0.9 only fits the 0.8 rung
        assert_eq!(o.switch, Some((1, SwitchMode::Drain)));
        assert_eq!(o.decision.budget, 0.9);
        let o = p.tick(&inputs(0.5, 20.0, 1.0), t);
        assert_eq!(o.switch, None);
        assert_eq!(o.decision.bound, Bound::Power);
    }

    #[test]
    fn latency_pressure_sheds_accuracy_before_latency() {
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            cooldown_ticks: 0,
            ..Default::default()
        });
        let t = Instant::now();
        p.tick(&inputs(0.0, 20.0, 1.0), t); // settle at exact
        // p95 climbing toward the SLO (over pressure_frac, under the
        // SLO itself): accuracy is shed while latency is still intact
        let o = p.tick(&inputs(0.5, 60.0, 1.0), t);
        assert_eq!(o.decision.bound, Bound::Latency);
        assert_eq!(o.decision.op_action, OpAction::Down);
        assert_eq!(o.switch, Some((1, SwitchMode::Immediate)));
        assert_eq!(p.lat_cap(), 1);
        assert_eq!(p.slo_violations, 0, "60ms < 100ms SLO: not a violation");
        // still pressured: the cap walks to the frugal floor and stops
        let o = p.tick(&inputs(1.0, 60.0, 1.0), t);
        assert_eq!(o.switch, Some((2, SwitchMode::Immediate)));
        let o = p.tick(&inputs(1.5, 60.0, 1.0), t);
        assert_eq!(o.switch, None);
        assert_eq!(p.lat_cap(), 2);
    }

    #[test]
    fn pool_grows_before_accuracy_is_spent() {
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            cooldown_ticks: 0,
            ..Default::default()
        });
        let t = Instant::now();
        let elastic = |t_s: f64, p95: f64, live: usize| TickInputs {
            live_workers: live,
            min_workers: 1,
            max_workers: 3,
            ..inputs(t_s, p95, 1.0)
        };
        p.tick(&elastic(0.0, 20.0, 1), t);
        // pressure with pool headroom: grow, keep the accurate rung
        let o = p.tick(&elastic(0.5, 60.0, 1), t);
        assert_eq!(o.decision.pool_action, PoolAction::Grow);
        assert_eq!(o.pool_target, Some(2));
        assert_eq!(o.decision.op_action, OpAction::None);
        assert_eq!(p.lat_cap(), 0);
        let o = p.tick(&elastic(1.0, 60.0, 2), t);
        assert_eq!(o.pool_target, Some(3));
        // ceiling reached: only now does accuracy give ground
        let o = p.tick(&elastic(1.5, 60.0, 3), t);
        assert_eq!(o.decision.pool_action, PoolAction::None);
        assert_eq!(o.decision.op_action, OpAction::Down);
        assert_eq!(p.lat_cap(), 1);
    }

    #[test]
    fn recovery_requires_sustained_headroom_then_upgrades_with_drain() {
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            recover_after: 3,
            cooldown_ticks: 0,
            ..Default::default()
        });
        let t = Instant::now();
        p.tick(&inputs(0.0, 20.0, 1.0), t);
        p.tick(&inputs(0.5, 60.0, 1.0), t); // shed to mid
        assert_eq!(p.lat_cap(), 1);
        // two clear ticks: not sustained yet, the cap holds
        assert_eq!(p.tick(&inputs(1.0, 20.0, 1.0), t).switch, None);
        assert_eq!(p.tick(&inputs(1.5, 20.0, 1.0), t).switch, None);
        assert_eq!(p.lat_cap(), 1);
        // third consecutive clear tick: the cap relaxes and the upgrade
        // rides the draining switch path
        let o = p.tick(&inputs(2.0, 20.0, 1.0), t);
        assert_eq!(o.switch, Some((0, SwitchMode::Drain)));
        assert_eq!(o.decision.bound, Bound::Headroom);
        assert_eq!(o.decision.op_action, OpAction::Up);
        assert_eq!(p.lat_cap(), 0);
    }

    #[test]
    fn ambiguous_p95_between_thresholds_holds_the_line() {
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            recover_after: 2,
            cooldown_ticks: 0,
            ..Default::default()
        });
        let t = Instant::now();
        p.tick(&inputs(0.0, 20.0, 1.0), t);
        p.tick(&inputs(0.5, 60.0, 1.0), t); // shed
        assert_eq!(p.lat_cap(), 1);
        // p95 at 45ms: under pressure_frac*slo (50) but over
        // clear_frac*slo (40) — neither sheds further nor recovers,
        // for arbitrarily many ticks
        for i in 0..20 {
            let o = p.tick(&inputs(1.0 + i as f64, 45.0, 1.0), t);
            assert_eq!(o.switch, None);
            assert_eq!(o.decision.bound, Bound::None);
        }
        assert_eq!(p.lat_cap(), 1);
    }

    #[test]
    fn cooldown_suppresses_back_to_back_op_sheds() {
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            cooldown_ticks: 2,
            ..Default::default()
        });
        let t = Instant::now();
        p.tick(&inputs(0.0, 20.0, 1.0), t);
        let o = p.tick(&inputs(0.5, 60.0, 1.0), t);
        assert_eq!(o.decision.op_action, OpAction::Down); // shed fires
        // next pressured tick: cooldown holds the second shed back
        let o = p.tick(&inputs(1.0, 60.0, 1.0), t);
        assert_eq!(o.decision.op_action, OpAction::None);
        assert_eq!(p.lat_cap(), 1);
        // cooldown expired: the second shed lands
        let o = p.tick(&inputs(1.5, 60.0, 1.0), t);
        assert_eq!(o.decision.op_action, OpAction::Down);
        assert_eq!(p.lat_cap(), 2);
    }

    #[test]
    fn no_flap_under_oscillating_budget_or_latency() {
        // the wrapped controller keeps its upgrade margin + the
        // autopilot requires sustained headroom: an oscillating budget
        // and a latency signal bouncing across the pressure threshold
        // must not produce an up/down switch pair every period
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            recover_after: 4,
            cooldown_ticks: 2,
            ..Default::default()
        });
        let t = Instant::now();
        p.tick(&inputs(0.0, 20.0, 1.0), t);
        let mut switches = 0u64;
        for i in 0..40 {
            // latency alternates 60ms (pressured) / 45ms (ambiguous);
            // budget alternates 1.0 / 0.85
            let p95 = if i % 2 == 0 { 60.0 } else { 45.0 };
            let budget = if i % 2 == 0 { 1.0 } else { 0.85 };
            if p.tick(&inputs(0.5 * i as f64, p95, budget), t).switch.is_some() {
                switches += 1;
            }
        }
        // the shed ratchets down (at most to the floor) but never
        // bounces back up: headroom is never sustained for 4 ticks
        assert!(switches <= 2, "flapped: {switches} switches");
        assert_eq!(p.controller().current(), 2);
        assert_eq!(p.lat_cap(), 2);
    }

    #[test]
    fn chunk_plan_narrows_under_pressure_and_widens_after_headroom() {
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            recover_after: 2,
            cooldown_ticks: 0,
            ..Default::default()
        });
        let t = Instant::now();
        let fleet = |t_s: f64, p95: f64| TickInputs { has_fleet: true, ..inputs(t_s, p95, 1.0) };
        p.tick(&fleet(0.0, 20.0), t);
        let o = p.tick(&fleet(0.5, 60.0), t);
        assert_eq!(o.decision.chunk_action, ChunkAction::Narrow);
        assert_eq!(o.chunk_quantum_us, Some(CHUNK_QUANTUM_US / 2.0));
        // already narrowed: continued pressure does not re-narrow (the
        // cap keeps walking toward frugal instead)
        let o = p.tick(&fleet(1.0, 60.0), t);
        assert_eq!(o.decision.chunk_action, ChunkAction::None);
        assert_eq!(p.lat_cap(), 2);
        // sustained headroom: accuracy recovers first — one cap rung
        // per earned streak — and only once fully recovered does the
        // chunk plan widen on the next streak
        p.tick(&fleet(1.5, 20.0), t);
        let o = p.tick(&fleet(2.0, 20.0), t);
        assert_eq!(o.decision.op_action, OpAction::Up);
        assert_eq!(o.decision.chunk_action, ChunkAction::None);
        p.tick(&fleet(2.5, 20.0), t);
        let o = p.tick(&fleet(3.0, 20.0), t);
        assert_eq!(o.decision.op_action, OpAction::Up);
        assert_eq!(p.lat_cap(), 0);
        p.tick(&fleet(3.5, 20.0), t);
        let o = p.tick(&fleet(4.0, 20.0), t);
        assert_eq!(o.decision.chunk_action, ChunkAction::Widen);
        assert_eq!(o.chunk_quantum_us, Some(CHUNK_QUANTUM_US));
    }

    #[test]
    fn pool_shrinks_only_after_the_longer_headroom_streak() {
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            recover_after: 2,
            pool_recover_after: 4,
            cooldown_ticks: 0,
            ..Default::default()
        });
        let t = Instant::now();
        let elastic = |t_s: f64, p95: f64, live: usize| TickInputs {
            live_workers: live,
            min_workers: 1,
            max_workers: 3,
            ..inputs(t_s, p95, 1.0)
        };
        p.tick(&elastic(0.0, 20.0, 1), t);
        let o = p.tick(&elastic(0.5, 60.0, 1), t); // grow under pressure
        assert_eq!(o.pool_target, Some(2));
        // headroom streak: ticks 1..=3 are clear; the pool holds until
        // the streak reaches pool_recover_after (4)
        for i in 0..3 {
            let o = p.tick(&elastic(1.0 + 0.5 * i as f64, 20.0, 2), t);
            assert_eq!(o.decision.pool_action, PoolAction::None);
        }
        let o = p.tick(&elastic(3.0, 20.0, 2), t);
        assert_eq!(o.decision.pool_action, PoolAction::Shrink);
        assert_eq!(o.pool_target, Some(1));
        assert_eq!(o.decision.bound, Bound::Headroom);
    }

    #[test]
    fn untrusted_window_takes_no_latency_action() {
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            min_window: 16,
            cooldown_ticks: 0,
            ..Default::default()
        });
        let t = Instant::now();
        p.tick(&inputs(0.0, 20.0, 1.0), t);
        // a huge p95 over a 3-sample window is one batch's noise
        let o = p.tick(&TickInputs { window: 3, ..inputs(0.5, 500.0, 1.0) }, t);
        assert_eq!(o.decision.bound, Bound::None);
        assert_eq!(p.lat_cap(), 0);
        assert_eq!(p.slo_violations, 0);
    }

    #[test]
    fn decision_json_round_trips() {
        let d = Decision {
            t_s: 1.5,
            p95_ms: 65.536,
            power: 0.8,
            budget: 0.9,
            op: 1,
            workers: 2,
            op_action: OpAction::Down,
            pool_action: PoolAction::None,
            chunk_action: ChunkAction::Narrow,
            bound: Bound::Latency,
            cap_saturated: false,
            class: None,
        };
        let j = d.to_json();
        assert_eq!(Decision::from_json(&j).unwrap(), d);
        // the tenancy fields are omitted at their defaults, so
        // pre-tenancy decision logs parse and re-serialize unchanged
        let text = crate::util::json::to_string(&j);
        assert!(!text.contains("cap_saturated") && !text.contains("class"), "{text}");
        let tagged = Decision {
            cap_saturated: true,
            class: Some("premium".to_string()),
            ..d.clone()
        };
        assert_eq!(Decision::from_json(&tagged.to_json()).unwrap(), tagged);
        assert!(Decision::from_json(&Json::obj(vec![("t_s", Json::num(0.0))])).is_err());
    }

    #[test]
    fn saturated_sheds_are_counted_once_the_cap_pins_the_floor() {
        let mut p = pilot(AutopilotConfig {
            slo_p95_ms: 100.0,
            cooldown_ticks: 0,
            ..Default::default()
        });
        let t = Instant::now();
        p.tick(&inputs(0.0, 20.0, 1.0), t); // settle at exact
        // walking the cap down is demand the ladder absorbs
        let o = p.tick(&inputs(0.5, 60.0, 1.0), t);
        assert!(!o.decision.cap_saturated);
        p.tick(&inputs(1.0, 60.0, 1.0), t); // cap reaches the floor
        assert_eq!(p.cap_saturated_ticks, 0);
        // the floor is pinned: every further pressured tick wanted to
        // shed and could not — the saturation the cap used to swallow
        let o = p.tick(&inputs(1.5, 60.0, 1.0), t);
        assert!(o.decision.cap_saturated);
        let o = p.tick(&inputs(2.0, 60.0, 1.0), t);
        assert!(o.decision.cap_saturated);
        assert_eq!(p.cap_saturated_ticks, 2);
        // a clear tick is not saturation even while the cap sits low
        let o = p.tick(&inputs(2.5, 20.0, 1.0), t);
        assert!(!o.decision.cap_saturated);
        assert_eq!(p.cap_saturated_ticks, 2);
    }

    #[test]
    fn single_class_multi_pilot_matches_the_bare_autopilot() {
        let t = Instant::now();
        let cfg = || AutopilotConfig { slo_p95_ms: 100.0, ..Default::default() };
        let mut solo = pilot(cfg());
        let mut multi = MultiAutopilot::new(vec![pilot(cfg())], vec![1.0]);
        let trace = [(20.0, 1.0), (60.0, 0.85), (60.0, 0.7), (45.0, 1.0), (20.0, 1.0)];
        for (i, (p95, budget)) in trace.iter().enumerate() {
            let inp = inputs(0.5 * i as f64, *p95, *budget);
            let a = solo.tick(&inp, t);
            let b = multi.tick(&[inp], t).remove(0);
            assert_eq!(b.switch, a.switch, "tick {i}");
            assert_eq!(b.decision, a.decision, "tick {i}");
        }
    }

    #[test]
    fn shared_envelope_charges_premium_before_best_effort() {
        let t = Instant::now();
        let mk = || pilot(AutopilotConfig { slo_p95_ms: 100.0, ..Default::default() });
        let mut multi = MultiAutopilot::new(
            vec![mk().with_class("premium"), mk().with_class("best_effort")],
            vec![1.0, 1.0], // normalized to an even traffic split
        );
        // ample budget: both classes settle at the accurate top rung
        let settle = multi.tick(&[inputs(0.0, 20.0, 1.0), inputs(0.0, 20.0, 1.0)], t);
        assert_eq!(settle[0].decision.op, 0);
        assert_eq!(settle[1].decision.op, 0);
        // collapse below the frugal floor: premium is allocated the
        // full env budget first; best-effort only sees what premium's
        // floor rung left of the shared envelope
        let outs = multi.tick(&[inputs(0.5, 20.0, 0.5), inputs(0.5, 20.0, 0.5)], t);
        assert_eq!(outs[0].decision.budget, 0.5);
        assert!(
            (outs[1].decision.budget - 0.4).abs() < 1e-12,
            "best-effort budget {}",
            outs[1].decision.budget
        );
        assert_eq!(outs[0].decision.class.as_deref(), Some("premium"));
        assert_eq!(outs[1].decision.class.as_deref(), Some("best_effort"));
    }
}
