//! QoS-Nets: adaptive approximate neural-network inference.
//!
//! A reproduction of *QoS-Nets: Adaptive Approximate Neural Network
//! Inference* (arXiv 2410.07762): a searched ladder of **operating
//! points** (assignments of approximate-multiplier instances to layers)
//! lets a platform trade accuracy against multiplication power at
//! runtime, switching rungs cheaply as environmental conditions change.
//!
//! This crate is the Rust coordinator (L3) of the three-layer
//! reproduction — see DESIGN.md for the layer split and
//! `docs/ARCHITECTURE.md` for the serving architecture (ingress →
//! batcher → elastic worker pool → backend, the OpTable/ladder
//! relationship to the QoS controller, the LUT-transpose layout, and
//! how the native and PJRT backends realize one [`backend::Backend`]
//! trait).
//!
//! Modules:
//!   * [`muldb`]     approximate-multiplier family (LUTs, power model)
//!   * [`nn`]        model graph / parameter / statistics loading
//!   * [`errmodel`]  sigma_e error model (paper Fig. 1)
//!   * [`selection`] preference vectors + k-means search (Sec. 3.1, 3.2)
//!   * [`baselines`] ALWANN GA, homogeneous, gradient search, LVRM/PNAM/TPM
//!   * [`plan`]      unified `Planner` trait + typed `OpPlan` artifact: one
//!     planning API over the QoS-Nets search and every baseline mapper
//!   * [`engine`]    native bit-exact LUT inference engine, with a
//!     runtime-selected matmul kernel (`engine::lutmm::LutKernel`:
//!     scalar / AVX2 gather / threaded M-tile sharding)
//!   * `runtime`     PJRT loader/executor for the AOT HLO artifacts
//!     (behind the `pjrt` feature; `--no-default-features` builds the
//!     native + stub paths without the `xla_extension` archive)
//!   * [`backend`]   unified `Backend` trait + OpTable over both engines
//!   * [`qos`]       operating-point controller (budget + hysteresis +
//!     switch-mode policy)
//!   * [`autopilot`] SLO autopilot: one closed-loop controller over OP
//!     ladder × worker-pool size × fleet chunk plan, driven by a p95
//!     latency SLO and a power envelope
//!   * [`server`]    elastic batching inference server, generic over
//!     `Backend`: load-driven worker scaling, per-OP latency
//!     attribution, draining OP-switch barriers
//!   * [`fleet`]     coordinator/worker RPC serving: a TCP wire
//!     protocol, a worker daemon wrapping any `Backend`, and
//!     `FleetBackend` — scatter/gather with failover plus fleet-wide
//!     OP-switch broadcast, itself a `Backend`
//!   * [`bench`]     scenario-driven load harness: replayable arrival
//!     traces, scripted QoS/environment events, versioned
//!     `BENCH_*.json` perf-trajectory reports, live dashboard
//!   * [`obs`]       unified observability: event bus, flight
//!     recorder, Prometheus-text metrics registry + scrape endpoint,
//!     leveled `obs::log!` diagnostics
//!   * [`pipeline`]  artifact-level orchestration
//!   * [`cli`]       flag parsing + subcommands for the `qos-nets` binary
//!   * [`util`]      JSON / tensor IO / PRNG / stats substrates

pub mod autopilot;
pub mod backend;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod engine;
pub mod errmodel;
pub mod fleet;
pub mod muldb;
pub mod nn;
pub mod obs;
pub mod pipeline;
pub mod plan;
pub mod qos;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod selection;
pub mod server;
pub mod util;
