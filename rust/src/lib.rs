//! QoS-Nets: adaptive approximate neural-network inference.
//!
//! Rust coordinator (L3) of the three-layer reproduction — see DESIGN.md.
//! Modules:
//!   * [`muldb`]     approximate-multiplier family (LUTs, power model)
//!   * [`nn`]        model graph / parameter / statistics loading
//!   * [`errmodel`]  sigma_e error model (paper Fig. 1)
//!   * [`selection`] preference vectors + k-means search (Sec. 3.1, 3.2)
//!   * [`baselines`] ALWANN GA, homogeneous, gradient search, LVRM/PNAM/TPM
//!   * [`engine`]    native bit-exact LUT inference engine
//!   * [`runtime`]   PJRT loader/executor for the AOT HLO artifacts
//!   * [`backend`]   unified `Backend` trait + OpTable over both engines
//!   * [`qos`]       operating-point controller (budget + hysteresis)
//!   * [`server`]    batching inference server, generic over `Backend`
//!   * [`pipeline`]  artifact-level orchestration
//!   * [`cli`]       flag parsing + subcommands for the `qos-nets` binary
//!   * [`util`]      JSON / tensor IO / PRNG / stats substrates

pub mod backend;
pub mod baselines;
pub mod cli;
pub mod engine;
pub mod errmodel;
pub mod muldb;
pub mod nn;
pub mod pipeline;
pub mod qos;
pub mod runtime;
pub mod selection;
pub mod server;
pub mod util;
