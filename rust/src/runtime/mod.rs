//! PJRT runtime: load the AOT-compiled HLO text artifacts and execute
//! them on the CPU PJRT client (the `xla` crate).
//!
//! One `PjRtLoadedExecutable` per artifact, compiled once at startup.
//! Operating-point switching = swapping the per-layer U/V/BN input
//! literals (the executable itself is OP-agnostic — DESIGN.md
//! "reconfiguration = input buffers").

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json;
use crate::util::tensorio::Tensor;

/// Ordered input description mirrored from hlo_signature.json.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

pub struct Runtime {
    client: xla::PjRtClient,
}

pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    pub signature: Vec<InputSpec>,
    pub export_batch: usize,
    pub rank: usize,
}

/// Per-operating-point input bundle (everything after `x` in signature
/// order), kept as *pre-minted literals*: `build_op_buffers` converts
/// each host buffer to an `xla::Literal` once per `prepare`, so the
/// execute hot path only mints the `x` literal instead of rebuilding
/// the whole U/V/BN bundle on every call.
pub struct OpBuffers {
    pub literals: Vec<xla::Literal>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text artifact with its signature entry
    /// (`which` = "model" | "kernel").
    pub fn load(&self, exp_dir: impl AsRef<Path>, which: &str) -> Result<LoadedModel> {
        let dir = exp_dir.as_ref();
        let sig_raw = std::fs::read_to_string(dir.join("hlo_signature.json"))
            .with_context(|| format!("read {}/hlo_signature.json", dir.display()))?;
        let sig_json = json::parse(&sig_raw).map_err(anyhow::Error::msg)?;
        let entries = sig_json
            .req(which)
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("signature array")?;
        let signature: Vec<InputSpec> = entries
            .iter()
            .map(|e| InputSpec {
                name: e.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                shape: e
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect(),
                dtype: e.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32").to_string(),
            })
            .collect();

        let hlo_file = match which {
            "model" => "model.hlo.txt",
            "kernel" => "kernel.hlo.txt",
            other => bail!("unknown artifact kind {other}"),
        };
        let proto = xla::HloModuleProto::from_text_file(
            dir.join(hlo_file).to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;

        let export_batch = sig_json
            .get("export_batch")
            .and_then(|v| v.as_usize())
            .unwrap_or(1);
        let rank = sig_json.get("rank").and_then(|v| v.as_usize()).unwrap_or(8);
        Ok(LoadedModel {
            exe,
            signature,
            export_batch,
            rank,
        })
    }
}

pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

impl LoadedModel {
    /// Execute with literal inputs in signature order; returns the f32
    /// payload of the first tuple element.
    pub fn execute_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        if inputs.len() != self.signature.len() {
            bail!(
                "input count {} != signature {}",
                inputs.len(),
                self.signature.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute with a borrowed OP bundle: the freshly minted `x`
    /// literal plus the bundle's cached tail literals (no per-execute
    /// conversion of the OP tensors).
    pub fn execute_with_op(&self, x: xla::Literal, op: &OpBuffers) -> Result<Vec<f32>> {
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(1 + op.literals.len());
        inputs.push(&x);
        inputs.extend(op.literals.iter());
        if inputs.len() != self.signature.len() {
            bail!(
                "input count {} != signature {}",
                inputs.len(),
                self.signature.len()
            );
        }
        let result = self.exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute and return i32 payload (kernel artifact).
    pub fn execute_i32(&self, inputs: &[xla::Literal]) -> Result<Vec<i32>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }
}

/// Build the per-OP input literals (everything after `x`) for the model
/// artifact: U/V from the low-rank tables for the assigned multiplier,
/// gamma/beta/b from the (overlaid) parameter tensors.  Literals are
/// minted here, once per prepare, and reused by every execute.
pub fn build_op_buffers(
    model: &LoadedModel,
    assignment: &HashMap<String, usize>,
    lowrank_u: &[Vec<f32>], // per multiplier: 256 * max_rank, row-major
    lowrank_v: &[Vec<f32>],
    max_rank: usize,
    tensors: &HashMap<String, Tensor>,
    overlay: &HashMap<String, Tensor>,
) -> Result<OpBuffers> {
    let rank = model.rank;
    let mut literals: Vec<xla::Literal> = Vec::new();
    for spec in model.signature.iter().skip(1) {
        let (layer, field) = spec
            .name
            .rsplit_once('.')
            .with_context(|| format!("bad signature name {}", spec.name))?;
        match field {
            "U" | "V" => {
                let mid = *assignment.get(layer).unwrap_or(&0);
                let table = if field == "U" { &lowrank_u[mid] } else { &lowrank_v[mid] };
                // exact multiplier (id 0) has an all-zero error table
                let mut buf = vec![0f32; 256 * rank];
                if mid != 0 {
                    for a in 0..256 {
                        for r in 0..rank.min(max_rank) {
                            buf[a * rank + r] = table[a * max_rank + r];
                        }
                    }
                }
                literals.push(literal_f32(&buf, &spec.shape)?);
            }
            "gamma" | "beta" | "b" => {
                let key = format!("{layer}.{field}");
                let t = overlay
                    .get(&key)
                    .or_else(|| tensors.get(&key))
                    .with_context(|| format!("missing tensor {key}"))?;
                literals.push(literal_f32(t.as_f32()?, &spec.shape)?);
            }
            other => bail!("unknown signature field {other}"),
        }
    }
    Ok(OpBuffers { literals })
}

/// Load lowrank.bin: per-multiplier U and V tables (256 x rank, f32).
pub fn load_lowrank(artifacts: impl AsRef<Path>) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>, usize)> {
    let blob = std::fs::read(artifacts.as_ref().join("lowrank.bin"))?;
    if blob.len() < 16 || &blob[..4] != b"QLRK" {
        bail!("lowrank.bin: bad magic");
    }
    let count = u32::from_le_bytes(blob[4..8].try_into().unwrap()) as usize;
    let nop = u32::from_le_bytes(blob[8..12].try_into().unwrap()) as usize;
    let rank = u32::from_le_bytes(blob[12..16].try_into().unwrap()) as usize;
    let body = &blob[16..];
    let per = nop * rank * 4;
    if body.len() != 2 * count * per {
        bail!("lowrank.bin: truncated");
    }
    let read = |off: usize| -> Vec<f32> {
        body[off..off + per]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    };
    let u: Vec<Vec<f32>> = (0..count).map(|i| read(i * per)).collect();
    let v: Vec<Vec<f32>> = (0..count).map(|i| read(count * per + i * per)).collect();
    Ok((u, v, rank))
}
